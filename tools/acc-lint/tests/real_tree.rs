//! The check_workflows.py pattern: the linter's own test suite runs it
//! against the real tree, so `cargo test` (tier 1) and the CI lint job agree
//! by construction. A finding added to rust/src without an allowlist entry —
//! or an allowlist entry that stops matching anything — fails this test.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
}

#[test]
fn real_tree_is_clean_under_the_checked_in_allowlist() {
    let root = repo_root();
    let report = acc_lint::lint_tree(&root).expect("walk rust/src + rust/tests");
    assert!(
        report.files > 40,
        "expected the real tree, found only {} .rs files — wrong root?",
        report.files
    );
    let allow_text = std::fs::read_to_string(root.join("lint_allow.toml"))
        .expect("checked-in lint_allow.toml");
    let allow = acc_lint::parse_allowlist(&allow_text)
        .unwrap_or_else(|errs| panic!("lint_allow.toml is invalid: {errs:#?}"));
    let (kept, stale) = acc_lint::apply_allowlist(report.findings, &allow);
    for f in &kept {
        eprintln!("{f}");
    }
    assert!(
        kept.is_empty(),
        "{} unallowlisted finding(s) in the real tree (listed above): fix the \
         code or add a justified lint_allow.toml entry",
        kept.len()
    );
    let stale_desc: Vec<String> = stale
        .iter()
        .map(|&i| format!("line {}: {} {}", allow[i].line, allow[i].rule, allow[i].path))
        .collect();
    assert!(
        stale.is_empty(),
        "stale lint_allow.toml entries (match no finding): {stale_desc:?}"
    );
}

#[test]
fn every_allowlist_entry_suppresses_something() {
    // Redundant with stale-checking above, but gives a direct count in test
    // output: the allowlist documents exactly the waivers the tree needs.
    let root = repo_root();
    let report = acc_lint::lint_tree(&root).expect("walk tree");
    let allow_text = std::fs::read_to_string(root.join("lint_allow.toml"))
        .expect("checked-in lint_allow.toml");
    let allow = acc_lint::parse_allowlist(&allow_text).expect("valid allowlist");
    for e in &allow {
        let n = report.findings.iter().filter(|f| e.matches(f)).count();
        eprintln!("allow {} {} ({:?}): suppresses {n} finding(s)", e.rule, e.path, e.pattern);
        assert!(n > 0, "entry at line {} ({} {}) suppresses nothing", e.line, e.rule, e.path);
    }
}
