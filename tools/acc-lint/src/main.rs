//! acc-lint CLI: lint `rust/src` + `rust/tests` under `--root` against the
//! checked-in allowlist. Exit codes: 0 clean, 1 findings or stale allowlist
//! entries or an invalid allowlist, 2 usage / I/O errors. This is a hard CI
//! gate — see docs/static-analysis.md.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: acc-lint [--root DIR] [--allow FILE]\n\
  --root DIR    repo root containing rust/src and rust/tests (default .)\n\
  --allow FILE  allowlist path (default <root>/lint_allow.toml; missing = empty)";

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut allow_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage_error("--root needs a value"),
            },
            "--allow" => match args.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage_error("--allow needs a value"),
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => return usage_error(&format!("unknown argument `{other}`")),
        }
    }

    let allow_file = allow_path.unwrap_or_else(|| root.join("lint_allow.toml"));
    let allow = if allow_file.is_file() {
        let text = match std::fs::read_to_string(&allow_file) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("acc-lint: cannot read {}: {e}", allow_file.display());
                return ExitCode::from(2);
            }
        };
        match acc_lint::parse_allowlist(&text) {
            Ok(entries) => entries,
            Err(errs) => {
                for e in &errs {
                    println!("{}:{e}", allow_file.display());
                }
                println!("acc-lint: invalid allowlist ({} error(s))", errs.len());
                return ExitCode::from(1);
            }
        }
    } else {
        Vec::new()
    };

    let report = match acc_lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("acc-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let total = report.findings.len();
    let (kept, stale) = acc_lint::apply_allowlist(report.findings, &allow);
    for f in &kept {
        println!("{f}");
    }
    for i in &stale {
        let e = &allow[*i];
        println!(
            "{}:{}: stale [[allow]] entry ({} {}) matches no finding — remove it",
            allow_file.display(),
            e.line,
            e.rule,
            e.path
        );
    }
    println!(
        "acc-lint: {} file(s), {} finding(s) ({} allowlisted), {} stale allowlist entr{}",
        report.files,
        kept.len(),
        total - kept.len(),
        stale.len(),
        if stale.len() == 1 { "y" } else { "ies" }
    );
    if kept.is_empty() && stale.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage_error(msg: &str) -> ExitCode {
    eprintln!("acc-lint: {msg}\n{USAGE}");
    ExitCode::from(2)
}
