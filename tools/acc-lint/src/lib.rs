//! acc-lint: first-party invariant linter for the determinism and
//! panic-freedom contracts.
//!
//! The crate promises bit-identical embeddings at any thread count and typed
//! errors (never panics) on every persistence/serving surface. The invariants
//! that make those promises true are mechanical — IEEE `total_cmp` ordering,
//! no wall-clock/RNG/hash-iteration nondeterminism in result-affecting
//! modules, length-before-allocation in every byte codec, a `// SAFETY:`
//! justification on every `unsafe` — but until this tool they lived in
//! reviewers' heads. `acc-lint` walks `rust/src` and `rust/tests` with a
//! hand-rolled, comments/strings/attributes-aware lexer (std-only, no `syn`)
//! and enforces them as named rules:
//!
//! * **D1** — NaN-unsafe float comparators (`partial_cmp`, path-form
//!   `f32::max`/`f64::min`, …) in `rust/src`. The codebase standard is
//!   `total_cmp` or the `(distance, index)` lexicographic order.
//! * **D2** — nondeterminism sources (`Instant`/`SystemTime`, `thread_rng`,
//!   `HashMap`/`HashSet` with the randomized default hasher) in
//!   result-affecting modules.
//! * **P1** — panic sites (`unwrap`/`expect`/`panic!`/`todo!`/`unreachable!`)
//!   in the typed-error surfaces (`data::io`, `tsne::persist`, `tsne::serve`,
//!   `tsne::session`, the `knn` loaders).
//! * **C1** — allocation from a decoded length in the codec modules without a
//!   preceding size guard (`check_file_len`/`check_payload_len`/`MAX_*` cap).
//! * **U1** — every `unsafe` carries a `// SAFETY:` comment (same line, or on
//!   the comment/attribute lines directly above, or in the doc comment of an
//!   `unsafe fn`).
//!
//! Test code (`#[test]` fns, `#[cfg(test)]` items, everything under
//! `rust/tests`) is exempt from D1/D2/P1/C1; U1 applies everywhere. Findings
//! are suppressible only through the checked-in `lint_allow.toml` (rule +
//! path + reason, see `parse_allowlist`), and entries that match no finding
//! are themselves a hard error, so the allowlist cannot go stale.
//!
//! Known limits (by design — the lexer is type-blind): method-form `.max(`/
//! `.min(` on floats and `sort_by` closures that compare with `<` are not
//! detected; D1 catches the ident `partial_cmp` and the path forms only.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

// --------------------------------------------------------------------------
// Lexer
// --------------------------------------------------------------------------

/// Token class. Punct tokens hold exactly one character.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub line: usize,
    pub kind: Kind,
    pub text: String,
}

/// Per-line facts the U1 rule needs: whether a SAFETY/Safety comment touches
/// the line, whether any code token lives on it, and whether its first code
/// token opens an attribute (`#`), which the upward walk may skip.
#[derive(Debug, Clone, Copy, Default)]
pub struct LineInfo {
    pub has_safety: bool,
    pub has_code: bool,
    pub attr_only: bool,
}

pub struct Lexed {
    pub toks: Vec<Tok>,
    /// 1-based; index 0 is unused.
    pub lines: Vec<LineInfo>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn line_info(lines: &mut Vec<LineInfo>, line: usize) -> &mut LineInfo {
    if lines.len() <= line {
        lines.resize(line + 1, LineInfo::default());
    }
    &mut lines[line]
}

fn mark_safety_text(lines: &mut Vec<LineInfo>, line: usize, text: &str) {
    if text.contains("SAFETY") || text.contains("Safety") {
        line_info(lines, line).has_safety = true;
    }
}

/// Skip a non-raw string body starting just past the opening quote.
/// Returns (index past the closing quote, newlines crossed).
fn scan_string(chars: &[char], mut j: usize) -> (usize, usize) {
    let n = chars.len();
    let mut newlines = 0;
    while j < n {
        match chars[j] {
            '\\' => {
                // an escaped newline (line-continuation) still ends a line
                if j + 1 < n && chars[j + 1] == '\n' {
                    newlines += 1;
                }
                j += 2;
            }
            '"' => return (j + 1, newlines),
            '\n' => {
                newlines += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (n, newlines)
}

/// Skip a raw string. `j` points at the first `#` or the opening quote
/// (just past `r` / `br`). Returns None if this is not a raw string after
/// all (i.e. `r#ident`), otherwise (index past the close, newlines crossed).
fn scan_raw_string(chars: &[char], j: usize) -> Option<(usize, usize)> {
    let n = chars.len();
    let mut k = j;
    let mut hashes = 0usize;
    while k < n && chars[k] == '#' {
        hashes += 1;
        k += 1;
    }
    if k >= n || chars[k] != '"' {
        return None; // raw identifier r#ident, or stray `r#`
    }
    k += 1;
    let mut newlines = 0usize;
    while k < n {
        if chars[k] == '\n' {
            newlines += 1;
            k += 1;
            continue;
        }
        if chars[k] == '"' {
            let mut h = 0usize;
            while h < hashes && k + 1 + h < n && chars[k + 1 + h] == '#' {
                h += 1;
            }
            if h == hashes {
                return Some((k + 1 + hashes, newlines));
            }
        }
        k += 1;
    }
    Some((n, newlines))
}

/// Skip a char-literal body starting just past the opening quote.
/// Returns the index past the closing quote.
fn scan_char_body(chars: &[char], mut j: usize) -> usize {
    let n = chars.len();
    if j < n && chars[j] == '\\' {
        j += 1;
        if j < n && chars[j] == 'u' {
            j += 1;
            if j < n && chars[j] == '{' {
                while j < n && chars[j] != '}' {
                    j += 1;
                }
            }
        }
        j += 1; // the escaped char ('}' for \u, or n/t/\\/' ...)
    } else {
        j += 1;
    }
    if j < n && chars[j] == '\'' {
        j += 1;
    }
    j
}

/// Tokenize Rust source: comments, strings (incl. raw/byte), char literals,
/// and lifetimes are consumed without emitting tokens; idents, numbers, and
/// single-char puncts come out with line numbers.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut lines: Vec<LineInfo> = vec![LineInfo::default(); 2];
    let mut line = 1usize;
    let mut i = 0usize;

    macro_rules! push_tok {
        ($kind:expr, $text:expr) => {{
            let text: String = $text;
            let li = line_info(&mut lines, line);
            if !li.has_code {
                li.has_code = true;
                li.attr_only = text == "#";
            }
            toks.push(Tok { line, kind: $kind, text });
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //! doc comments).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            mark_safety_text(&mut lines, line, &text);
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            let mut text = String::new();
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    mark_safety_text(&mut lines, line, &text);
                    text.clear();
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    text.push(chars[i]);
                    i += 1;
                }
            }
            mark_safety_text(&mut lines, line, &text);
            continue;
        }
        // Raw strings r"…" / r#"…"# and raw identifiers r#ident.
        if c == 'r' && i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '#') {
            if let Some((ni, nl)) = scan_raw_string(&chars, i + 1) {
                i = ni;
                line += nl;
                continue;
            }
            // r#ident: lex the ident without the r# prefix.
            let start = i + 2;
            let mut j = start;
            while j < n && is_ident_cont(chars[j]) {
                j += 1;
            }
            push_tok!(Kind::Ident, chars[start..j].iter().collect());
            i = j;
            continue;
        }
        // Byte strings / byte chars: b"…", br#"…"#, b'x'.
        if c == 'b' && i + 1 < n {
            if chars[i + 1] == '"' {
                let (ni, nl) = scan_string(&chars, i + 2);
                i = ni;
                line += nl;
                continue;
            }
            if chars[i + 1] == '\'' {
                i = scan_char_body(&chars, i + 2);
                continue;
            }
            if chars[i + 1] == 'r' && i + 2 < n && (chars[i + 2] == '"' || chars[i + 2] == '#') {
                if let Some((ni, nl)) = scan_raw_string(&chars, i + 2) {
                    i = ni;
                    line += nl;
                    continue;
                }
            }
        }
        if c == '"' {
            let (ni, nl) = scan_string(&chars, i + 1);
            i = ni;
            line += nl;
            continue;
        }
        // Char literal vs lifetime: 'a' is a char, 'a / 'static / '_ are
        // lifetimes (an ident run NOT followed by a closing quote).
        if c == '\'' {
            let j = i + 1;
            if j < n && is_ident_start(chars[j]) {
                let mut k = j;
                while k < n && is_ident_cont(chars[k]) {
                    k += 1;
                }
                if k < n && chars[k] == '\'' {
                    i = k + 1; // char literal like 'a' or '_'
                } else {
                    i = k; // lifetime: no token
                }
                continue;
            }
            i = scan_char_body(&chars, i + 1);
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(chars[i]) {
                i += 1;
            }
            push_tok!(Kind::Ident, chars[start..i].iter().collect());
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let ch = chars[i];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    i += 1;
                } else if ch == '.' && i + 1 < n && chars[i + 1].is_ascii_digit() {
                    // consume the dot of 1.5 but not of 0..n
                    i += 1;
                } else {
                    break;
                }
            }
            push_tok!(Kind::Num, chars[start..i].iter().collect());
            continue;
        }
        push_tok!(Kind::Punct, c.to_string());
        i += 1;
    }

    Lexed { toks, lines }
}

// --------------------------------------------------------------------------
// Test-code detection
// --------------------------------------------------------------------------

/// Marks every token that belongs to a `#[test]` fn or a `#[cfg(test)]` item
/// (fn, mod, impl, use — anything up to its matching close brace or `;`).
/// `#[cfg(not(test))]` does NOT count as test code.
pub fn test_token_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].kind == Kind::Punct && toks[i].text == "#") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let mut inner = false;
        if j < toks.len() && toks[j].text == "!" {
            inner = true;
            j += 1;
        }
        if !(j < toks.len() && toks[j].text == "[") {
            i += 1;
            continue;
        }
        // Collect the idents inside the attribute, to its matching `]`.
        let mut depth = 1i32;
        j += 1;
        let mut idents: Vec<&str> = Vec::new();
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "[" if toks[j].kind == Kind::Punct => depth += 1,
                "]" if toks[j].kind == Kind::Punct => depth -= 1,
                t if toks[j].kind == Kind::Ident => idents.push(t),
                _ => {}
            }
            j += 1;
        }
        let has = |s: &str| idents.iter().any(|&x| x == s);
        let is_test_attr = !inner
            && ((idents.len() == 1 && idents[0] == "test")
                || (has("cfg") && has("test") && !has("not")));
        if !is_test_attr {
            i = j;
            continue;
        }
        // Skip any further attributes stacked between this one and the item.
        let mut k = j;
        while k < toks.len() && toks[k].kind == Kind::Punct && toks[k].text == "#" {
            let mut kk = k + 1;
            if kk < toks.len() && toks[kk].text == "!" {
                kk += 1;
            }
            if !(kk < toks.len() && toks[kk].text == "[") {
                break;
            }
            let mut d = 1i32;
            kk += 1;
            while kk < toks.len() && d > 0 {
                if toks[kk].kind == Kind::Punct {
                    if toks[kk].text == "[" {
                        d += 1;
                    } else if toks[kk].text == "]" {
                        d -= 1;
                    }
                }
                kk += 1;
            }
            k = kk;
        }
        // The item ends at its matched `{…}` or at a top-level `;`.
        let mut brace = 0i32;
        let mut saw_open = false;
        let mut end = toks.len();
        let mut m = k;
        while m < toks.len() {
            if toks[m].kind == Kind::Punct {
                match toks[m].text.as_str() {
                    "{" => {
                        brace += 1;
                        saw_open = true;
                    }
                    "}" => {
                        brace -= 1;
                        if saw_open && brace == 0 {
                            end = m + 1;
                            break;
                        }
                    }
                    ";" if !saw_open => {
                        end = m + 1;
                        break;
                    }
                    _ => {}
                }
            }
            m += 1;
        }
        for x in mask.iter_mut().take(end.min(toks.len())).skip(i) {
            *x = true;
        }
        i = end;
    }
    mask
}

// --------------------------------------------------------------------------
// Rules
// --------------------------------------------------------------------------

pub const RULE_IDS: [&str; 5] = ["D1", "D2", "P1", "C1", "U1"];

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub path: String,
    pub line: usize,
    pub msg: String,
    /// Trimmed source line, used by allowlist `pattern` matching.
    pub line_text: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Result-affecting modules: anything computed here can change the bytes of
/// an embedding, a persisted artifact, or a served frame. `common` (timers,
/// bench harness), `eval`/`metrics` (reporting), `viz`, `cli`/`main`
/// (process glue) and the xla-gated `runtime` are deliberately out of scope.
const D2_MODULES: &[&str] = &[
    "rust/src/gradient/",
    "rust/src/quadtree/",
    "rust/src/perplexity/",
    "rust/src/sparse/",
    "rust/src/knn/",
    "rust/src/fitsne/",
    "rust/src/parallel/",
    "rust/src/tsne/",
    "rust/src/data/",
];

/// Typed-error surfaces: these files promise `DataError`/`PersistError`/
/// `ServeError`/`StepError` instead of panics.
const P1_FILES: &[&str] = &[
    "rust/src/data/io.rs",
    "rust/src/tsne/persist.rs",
    "rust/src/tsne/serve.rs",
    "rust/src/tsne/session.rs",
    "rust/src/knn/mod.rs",
    "rust/src/knn/hnsw.rs",
];

/// Byte-codec modules where every decoded length must be guarded before it
/// reaches an allocator (the PR-4/PR-10 length-before-allocation rule).
const C1_FILES: &[&str] = &[
    "rust/src/data/io.rs",
    "rust/src/tsne/persist.rs",
    "rust/src/tsne/serve.rs",
];

const C1_DECODE: &[&str] = &[
    "read_exact",
    "read_to_end",
    "read_u32_le",
    "read_u64_le",
    "read_f64_le",
    "read_f64_slice_le",
];

const C1_GUARDS: &[&str] = &["check_file_len", "check_payload_len"];

/// Lint one file's source. `rel` is the repo-relative path (e.g.
/// `rust/src/tsne/serve.rs`); rule scoping keys off it.
pub fn lint_file(rel: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let mask = test_token_mask(&lx.toks);
    let src_lines: Vec<&str> = src.lines().collect();
    let mut out: Vec<Finding> = Vec::new();

    let mut push = |rule: &'static str, line: usize, msg: String| {
        let line_text = src_lines
            .get(line.wrapping_sub(1))
            .map(|s| s.trim().to_string())
            .unwrap_or_default();
        out.push(Finding { rule, path: rel.to_string(), line, msg, line_text });
    };

    let in_src = rel.starts_with("rust/src/");
    let d2_scoped = D2_MODULES.iter().any(|p| rel.starts_with(p));
    let p1_scoped = P1_FILES.contains(&rel);
    let c1_scoped = C1_FILES.contains(&rel);
    let toks = &lx.toks;

    let next_is = |ti: usize, s: &str| {
        toks.get(ti + 1)
            .map(|t| t.kind == Kind::Punct && t.text == s)
            .unwrap_or(false)
    };

    // ---- D1 / D2 / P1: per-ident scans over non-test code ----
    for (ti, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        // U1 applies to test code too; handled in its own pass below.
        if mask[ti] {
            continue;
        }
        let text = t.text.as_str();
        if in_src {
            match text {
                "partial_cmp" => push(
                    "D1",
                    t.line,
                    "NaN-unsafe `partial_cmp` — use IEEE `total_cmp` (or the \
                     `(distance, index)` lexicographic order)"
                        .to_string(),
                ),
                "max" | "min" => {
                    let path_form = ti >= 3
                        && toks[ti - 1].text == ":"
                        && toks[ti - 2].text == ":"
                        && (toks[ti - 3].text == "f32" || toks[ti - 3].text == "f64");
                    if path_form {
                        push(
                            "D1",
                            t.line,
                            format!(
                                "NaN-unsafe `{}::{}` — use `total_cmp`-based \
                                 selection (`max_r`/`min_r`)",
                                toks[ti - 3].text, text
                            ),
                        );
                    }
                }
                _ => {}
            }
        }
        if d2_scoped {
            let msg = match text {
                "Instant" | "SystemTime" => Some(
                    "wall-clock time in a result-affecting module breaks \
                     run-to-run determinism",
                ),
                "thread_rng" | "ThreadRng" | "OsRng" | "getrandom" => Some(
                    "OS-seeded randomness in a result-affecting module; use \
                     the seeded `common::rng` generators",
                ),
                "HashMap" | "HashSet" => Some(
                    "randomized-hasher map in a result-affecting module: \
                     iteration order varies per process; use \
                     `BTreeMap`/sorted vecs or justify in lint_allow.toml",
                ),
                "DefaultHasher" | "RandomState" => {
                    Some("randomly-seeded hasher in a result-affecting module")
                }
                _ => None,
            };
            if let Some(m) = msg {
                push("D2", t.line, m.to_string());
            }
        }
        if p1_scoped {
            match text {
                "unwrap" | "expect" if next_is(ti, "(") => push(
                    "P1",
                    t.line,
                    format!(
                        "`{}` on a typed-error surface — return the typed \
                         error instead of panicking",
                        text
                    ),
                ),
                "panic" | "todo" | "unimplemented" | "unreachable" if next_is(ti, "!") => push(
                    "P1",
                    t.line,
                    format!("`{}!` on a typed-error surface", text),
                ),
                _ => {}
            }
        }
    }

    // ---- C1: per-fn decoded-length-before-allocation tracking ----
    if c1_scoped {
        struct Frame {
            depth: i32,
            saw_decode: bool,
            saw_guard: bool,
        }
        let mut frames: Vec<Frame> = Vec::new();
        let mut depth = 0i32;
        // paren/bracket depth: a `;` inside `[u8; 4]` in a signature must not
        // clear `pending_fn`
        let mut group = 0i32;
        let mut pending_fn = false;

        // Any ident with a lowercase letter is a runtime value; uppercase
        // consts and literals are compile-time sizes.
        let is_dynamic = |range: &[Tok]| {
            range.iter().any(|t| {
                t.kind == Kind::Ident && t.text.chars().any(|c| c.is_ascii_lowercase())
            })
        };
        // First argument of a call whose `(` sits at `open`: tokens up to the
        // first top-level `,` or the matching `)`.
        let first_arg = |open: usize| -> Vec<Tok> {
            let mut d = 1i32;
            let mut m = open + 1;
            let mut arg = Vec::new();
            while m < toks.len() && d > 0 {
                if toks[m].kind == Kind::Punct {
                    match toks[m].text.as_str() {
                        "(" | "[" | "{" => d += 1,
                        ")" | "]" | "}" => d -= 1,
                        "," if d == 1 => break,
                        _ => {}
                    }
                }
                if d > 0 {
                    arg.push(toks[m].clone());
                }
                m += 1;
            }
            arg
        };

        let mut ti = 0usize;
        while ti < toks.len() {
            if mask[ti] {
                ti += 1;
                continue;
            }
            let t = &toks[ti];
            match t.kind {
                Kind::Punct => match t.text.as_str() {
                    "{" => {
                        depth += 1;
                        if pending_fn {
                            frames.push(Frame { depth, saw_decode: false, saw_guard: false });
                            pending_fn = false;
                        }
                    }
                    "}" => {
                        if frames.last().map(|f| f.depth == depth).unwrap_or(false) {
                            frames.pop();
                        }
                        depth -= 1;
                    }
                    "(" | "[" => group += 1,
                    ")" | "]" => group -= 1,
                    ";" => {
                        // trait method signature without a body
                        if group == 0 {
                            pending_fn = false;
                        }
                    }
                    _ => {}
                },
                Kind::Ident => {
                    let text = t.text.as_str();
                    if text == "fn" {
                        pending_fn = true;
                    } else if C1_DECODE.contains(&text) {
                        if let Some(f) = frames.last_mut() {
                            f.saw_decode = true;
                        }
                    } else if C1_GUARDS.contains(&text)
                        || (text.starts_with("MAX_") && text.len() > 4)
                    {
                        if let Some(f) = frames.last_mut() {
                            f.saw_guard = true;
                        }
                    } else if matches!(text, "with_capacity" | "resize" | "reserve" | "reserve_exact")
                        && next_is(ti, "(")
                    {
                        let unguarded = frames
                            .last()
                            .map(|f| f.saw_decode && !f.saw_guard)
                            .unwrap_or(false);
                        if unguarded && is_dynamic(&first_arg(ti + 1)) {
                            push(
                                "C1",
                                t.line,
                                format!(
                                    "`{}` from a decoded length with no preceding \
                                     size guard (`check_file_len`/`check_payload_len`\
                                     /`MAX_*` cap) in this fn",
                                    text
                                ),
                            );
                        }
                    } else if text == "vec" && next_is(ti, "!") {
                        // vec![elem; len] — only the repeat form allocates from
                        // a runtime length.
                        let open = ti + 2;
                        let opens = toks
                            .get(open)
                            .map(|t| {
                                t.kind == Kind::Punct
                                    && matches!(t.text.as_str(), "[" | "(" | "{")
                            })
                            .unwrap_or(false);
                        if opens {
                            let mut d = 1i32;
                            let mut m = open + 1;
                            let mut semi_at: Option<usize> = None;
                            let mut close = toks.len();
                            while m < toks.len() && d > 0 {
                                if toks[m].kind == Kind::Punct {
                                    match toks[m].text.as_str() {
                                        "(" | "[" | "{" => d += 1,
                                        ")" | "]" | "}" => {
                                            d -= 1;
                                            if d == 0 {
                                                close = m;
                                            }
                                        }
                                        ";" if d == 1 => semi_at = Some(m),
                                        _ => {}
                                    }
                                }
                                m += 1;
                            }
                            let unguarded = frames
                                .last()
                                .map(|f| f.saw_decode && !f.saw_guard)
                                .unwrap_or(false);
                            if let Some(s) = semi_at {
                                if unguarded && close > s && is_dynamic(&toks[s + 1..close]) {
                                    push(
                                        "C1",
                                        t.line,
                                        "`vec![_; len]` from a decoded length with no \
                                         preceding size guard in this fn"
                                            .to_string(),
                                    );
                                }
                            }
                        }
                    }
                }
                Kind::Num => {}
            }
            ti += 1;
        }
    }

    // ---- U1: every `unsafe` has a SAFETY comment (test code included) ----
    for t in toks.iter() {
        if t.kind == Kind::Ident && t.text == "unsafe" && !has_safety_comment(&lx.lines, t.line) {
            push(
                "U1",
                t.line,
                "`unsafe` without a `// SAFETY:` justification on this line or \
                 the comment lines directly above"
                    .to_string(),
            );
        }
    }

    out
}

/// SAFETY comment on the `unsafe` line itself, or on the contiguous run of
/// comment-only / attribute-only / blank lines directly above (doc comments
/// of an `unsafe fn` count — they contain "Safety"). The walk stops at the
/// first real code line.
fn has_safety_comment(lines: &[LineInfo], ln: usize) -> bool {
    let get = |l: usize| lines.get(l).copied().unwrap_or_default();
    if get(ln).has_safety {
        return true;
    }
    let mut l = ln;
    for _ in 0..8 {
        if l <= 1 {
            return false;
        }
        l -= 1;
        let li = get(l);
        if li.has_safety {
            return true;
        }
        if li.has_code && !li.attr_only {
            return false;
        }
    }
    false
}

// --------------------------------------------------------------------------
// Allowlist
// --------------------------------------------------------------------------

/// One `[[allow]]` entry from `lint_allow.toml`. `path` matches exactly, or
/// as a directory prefix when it ends with `/`. `pattern`, when present,
/// must be a substring of the flagged (trimmed) source line — use it to pin
/// an entry to one idiom instead of a whole file.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub pattern: Option<String>,
    pub reason: String,
    /// Line of the `[[allow]]` header, for stale-entry diagnostics.
    pub line: usize,
}

impl AllowEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        f.rule == self.rule
            && (f.path == self.path
                || (self.path.ends_with('/') && f.path.starts_with(self.path.as_str())))
            && self
                .pattern
                .as_ref()
                .is_none_or(|p| f.line_text.contains(p.as_str()))
    }
}

/// Parse the hand-rolled TOML subset: `[[allow]]` headers, `key = "value"`
/// (or `key = 'value'` literal strings, for patterns that contain quotes),
/// full-line `#` comments, blank lines. Anything else is an error — the
/// allowlist is itself linted. Every entry needs `rule` (a known rule id),
/// `path`, and a `reason` of at least 10 characters.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, Vec<String>> {
    struct Draft {
        line: usize,
        rule: Option<String>,
        path: Option<String>,
        pattern: Option<String>,
        reason: Option<String>,
    }
    let mut errs: Vec<String> = Vec::new();
    let mut entries: Vec<AllowEntry> = Vec::new();
    let mut cur: Option<Draft> = None;

    fn finish(d: Option<Draft>, errs: &mut Vec<String>, entries: &mut Vec<AllowEntry>) {
        let Some(d) = d else { return };
        let mut ok = true;
        match d.rule.as_deref() {
            None => {
                errs.push(format!("line {}: [[allow]] entry has no `rule`", d.line));
                ok = false;
            }
            Some(r) if !RULE_IDS.contains(&r) => {
                errs.push(format!(
                    "line {}: unknown rule `{}` (known: {})",
                    d.line,
                    r,
                    RULE_IDS.join(", ")
                ));
                ok = false;
            }
            _ => {}
        }
        if d.path.as_deref().map(str::is_empty).unwrap_or(true) {
            errs.push(format!("line {}: [[allow]] entry has no `path`", d.line));
            ok = false;
        }
        if d.reason.as_deref().map(str::len).unwrap_or(0) < 10 {
            errs.push(format!(
                "line {}: [[allow]] entry needs a substantive `reason` (>= 10 chars)",
                d.line
            ));
            ok = false;
        }
        if ok {
            entries.push(AllowEntry {
                rule: d.rule.unwrap_or_default(),
                path: d.path.unwrap_or_default(),
                pattern: d.pattern,
                reason: d.reason.unwrap_or_default(),
                line: d.line,
            });
        }
    }

    for (ln0, raw) in text.lines().enumerate() {
        let ln = ln0 + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            finish(cur.take(), &mut errs, &mut entries);
            cur = Some(Draft { line: ln, rule: None, path: None, pattern: None, reason: None });
            continue;
        }
        if line.starts_with('[') {
            errs.push(format!("line {}: unknown section `{}`", ln, line));
            continue;
        }
        let Some(d) = cur.as_mut() else {
            errs.push(format!("line {}: key outside any [[allow]] entry", ln));
            continue;
        };
        let Some((k, v)) = line.split_once('=') else {
            errs.push(format!("line {}: expected `key = \"value\"`", ln));
            continue;
        };
        let key = k.trim();
        let val = v.trim();
        let quoted = val.len() >= 2
            && ((val.starts_with('"') && val.ends_with('"'))
                || (val.starts_with('\'') && val.ends_with('\'')));
        if !quoted {
            errs.push(format!("line {}: value for `{}` must be a quoted string", ln, key));
            continue;
        }
        let inner = val[1..val.len() - 1].to_string();
        let slot = match key {
            "rule" => &mut d.rule,
            "path" => &mut d.path,
            "pattern" => &mut d.pattern,
            "reason" => &mut d.reason,
            _ => {
                errs.push(format!(
                    "line {}: unknown key `{}` (known: rule, path, pattern, reason)",
                    ln, key
                ));
                continue;
            }
        };
        if slot.is_some() {
            errs.push(format!("line {}: duplicate key `{}`", ln, key));
        } else {
            *slot = Some(inner);
        }
    }
    finish(cur.take(), &mut errs, &mut entries);

    if errs.is_empty() {
        Ok(entries)
    } else {
        Err(errs)
    }
}

/// Suppress findings matched by the allowlist. Returns the surviving
/// findings plus the indices of entries that matched nothing — stale entries
/// are a hard error at the call site, so the allowlist tracks the tree.
pub fn apply_allowlist(
    findings: Vec<Finding>,
    allow: &[AllowEntry],
) -> (Vec<Finding>, Vec<usize>) {
    let mut used = vec![false; allow.len()];
    let mut kept = Vec::new();
    for f in findings {
        let mut suppressed = false;
        for (i, e) in allow.iter().enumerate() {
            if e.matches(&f) {
                used[i] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            kept.push(f);
        }
    }
    let stale = used
        .iter()
        .enumerate()
        .filter_map(|(i, &u)| if u { None } else { Some(i) })
        .collect();
    (kept, stale)
}

// --------------------------------------------------------------------------
// Tree walk
// --------------------------------------------------------------------------

pub struct TreeReport {
    pub files: usize,
    pub findings: Vec<Finding>,
}

/// Lint `<root>/rust/src` and `<root>/rust/tests`. Errors if neither exists
/// (wrong `--root` beats a silently-green run on an empty directory).
pub fn lint_tree(root: &Path) -> io::Result<TreeReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    let mut any_dir = false;
    for sub in ["rust/src", "rust/tests"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            any_dir = true;
            collect_rs(&dir, &mut files)?;
        }
    }
    if !any_dir {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("{}: no rust/src or rust/tests under this root", root.display()),
        ));
    }
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        findings.extend(lint_file(&rel_path(root, f), &src));
    }
    findings.sort_by(|a, b| (a.path.as_str(), a.line).cmp(&(b.path.as_str(), b.line)));
    Ok(TreeReport { files: files.len(), findings })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

// --------------------------------------------------------------------------
// Fixture tests
// --------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_at(rel: &str, src: &str) -> Vec<(&'static str, usize)> {
        lint_file(rel, src).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    // ---- lexer ----

    #[test]
    fn lexer_skips_comments_strings_and_lifetimes() {
        let src = r##"
// partial_cmp in a line comment
/* partial_cmp in a /* nested */ block */
fn f<'a>(s: &'a str) -> char {
    let _msg = "partial_cmp in a string";
    let _raw = r#"partial_cmp in a raw "string""#;
    let _byte = b"partial_cmp";
    let _c = 'p';
    '\n'
}
"##;
        let lx = lex(src);
        assert!(!lx.toks.iter().any(|t| t.text == "partial_cmp"));
        // the lifetime 'a must not eat the rest of the file as a char literal
        assert!(lx.toks.iter().any(|t| t.text == "str"));
    }

    #[test]
    fn lexer_number_scan_does_not_eat_range_dots() {
        let lx = lex("for i in 0..n { let x = 1.5e3; }");
        let texts: Vec<&str> = lx.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"n"));
        assert!(texts.windows(2).any(|w| w[0] == "." && w[1] == "."));
    }

    #[test]
    fn lexer_counts_lines_through_string_continuations() {
        // a backslash-newline inside a string still ends a source line
        let src = "let s = \"one \\\n two\";\nlet after = 1;\n";
        let lx = lex(src);
        let after = lx.toks.iter().find(|t| t.text == "after").expect("after tok");
        assert_eq!(after.line, 3);
    }

    #[test]
    fn lexer_marks_safety_lines() {
        let src = "// SAFETY: disjoint rows\nunsafe { x() }\n";
        let lx = lex(src);
        assert!(lx.lines[1].has_safety);
        assert!(!lx.lines[2].has_safety);
    }

    // ---- test-code mask ----

    #[test]
    fn mask_covers_test_fns_and_cfg_test_mods() {
        let src = "
fn live() { a.partial_cmp(&b); }
#[test]
fn t() { a.partial_cmp(&b); }
#[cfg(test)]
mod tests {
    fn helper() { a.partial_cmp(&b); }
}
";
        let hits = rules_at("rust/src/gradient/mod.rs", src);
        assert_eq!(hits, vec![("D1", 2)]);
    }

    #[test]
    fn cfg_not_test_is_not_test_code() {
        let src = "#[cfg(not(test))]\nfn live() { a.partial_cmp(&b); }\n";
        assert_eq!(rules_at("rust/src/gradient/mod.rs", src), vec![("D1", 2)]);
    }

    // ---- D1 ----

    #[test]
    fn d1_flags_partial_cmp_and_path_form_minmax() {
        let src = "fn f(a: f64, b: f64) {\n    let _ = a.partial_cmp(&b);\n    let _ = f64::max(a, b);\n}\n";
        assert_eq!(
            rules_at("rust/src/knn/select.rs", src),
            vec![("D1", 2), ("D1", 3)]
        );
    }

    #[test]
    fn d1_allows_total_cmp_method_minmax_and_consts() {
        let src = "fn f(a: f64, b: f64) {\n    let _ = a.total_cmp(&b);\n    let _ = a.max(b);\n    let _ = f64::MAX;\n    let _ = f64::max_r(a, b);\n}\n";
        assert!(rules_at("rust/src/knn/select.rs", src).is_empty());
    }

    #[test]
    fn d1_skips_rust_tests_dir() {
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }\n";
        assert!(rules_at("rust/tests/integration.rs", src).is_empty());
    }

    // ---- D2 ----

    #[test]
    fn d2_flags_nondeterminism_in_scoped_modules_only() {
        let src = "use std::time::Instant;\nuse std::collections::HashMap;\n";
        assert_eq!(
            rules_at("rust/src/tsne/serve2.rs", src),
            vec![("D2", 1), ("D2", 2)]
        );
        assert!(rules_at("rust/src/common/timer.rs", src).is_empty());
        assert!(rules_at("rust/src/cli.rs", src).is_empty());
    }

    // ---- P1 ----

    #[test]
    fn p1_flags_panic_sites_in_typed_error_files() {
        let src = "fn f() {\n    x.unwrap();\n    y.expect(\"boom\");\n    panic!(\"no\");\n    unreachable!();\n}\n";
        assert_eq!(
            rules_at("rust/src/tsne/persist.rs", src),
            vec![("P1", 2), ("P1", 3), ("P1", 4), ("P1", 5)]
        );
        // same code outside the typed-error surfaces: no findings
        assert!(rules_at("rust/src/gradient/mod.rs", src).is_empty());
    }

    #[test]
    fn p1_ignores_unwrap_or_variants_and_test_code() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(|| 1); }\n#[test]\nfn t() { z.unwrap(); }\n";
        assert!(rules_at("rust/src/tsne/persist.rs", src).is_empty());
    }

    // ---- C1 ----

    const C1_BAD: &str = "
fn load(r: &mut R) -> io::Result<Vec<f64>> {
    let n = read_u64_le(r)? as usize;
    let mut v = Vec::with_capacity(n);
    Ok(v)
}
";

    #[test]
    fn c1_flags_unguarded_decoded_alloc() {
        assert_eq!(rules_at("rust/src/data/io.rs", C1_BAD), vec![("C1", 4)]);
        // same code outside the codec modules: no finding
        assert!(rules_at("rust/src/gradient/mod.rs", C1_BAD).is_empty());
    }

    #[test]
    fn c1_guard_before_alloc_passes() {
        let src = "
fn load(r: &mut R) -> io::Result<Vec<f64>> {
    let n = read_u64_le(r)? as usize;
    check_file_len(24 + 8 * n as u64, actual)?;
    let mut v = Vec::with_capacity(n);
    Ok(v)
}
";
        assert!(rules_at("rust/src/data/io.rs", src).is_empty());
    }

    #[test]
    fn c1_max_cap_counts_as_guard() {
        let src = "
fn load(r: &mut R) -> io::Result<Vec<u8>> {
    let n = read_u32_le(r)? as usize;
    if n > MAX_FRAME_PAYLOAD { return Err(too_big()); }
    let mut v = vec![0u8; n];
    Ok(v)
}
";
        assert!(rules_at("rust/src/tsne/serve.rs", src).is_empty());
    }

    #[test]
    fn c1_vec_macro_repeat_form_is_flagged() {
        let src = "
fn load(r: &mut R) -> io::Result<Vec<u8>> {
    let n = read_u32_le(r)? as usize;
    let v = vec![0u8; n];
    Ok(v)
}
";
        assert_eq!(rules_at("rust/src/tsne/serve.rs", src), vec![("C1", 4)]);
    }

    #[test]
    fn c1_static_sizes_and_decode_free_fns_pass() {
        let src = "
fn fresh(n: usize) -> Vec<f64> {
    let mut v = Vec::with_capacity(n);
    v
}
fn fixed(r: &mut R) -> io::Result<Vec<u8>> {
    let _x = read_u32_le(r)?;
    let v = vec![0u8; 16];
    let w = Vec::with_capacity(CAP);
    Ok(v)
}
";
        assert!(rules_at("rust/src/data/io.rs", src).is_empty());
    }

    #[test]
    fn c1_fn_scoping_resets_between_fns() {
        // decode in one fn must not taint an alloc in the next
        let src = "
fn a(r: &mut R) { let _ = read_u64_le(r); }
fn b(n: usize) -> Vec<u8> { Vec::with_capacity(n) }
";
        assert!(rules_at("rust/src/data/io.rs", src).is_empty());
    }

    // ---- U1 ----

    #[test]
    fn u1_requires_safety_comment() {
        let src = "fn f(p: *mut u8) {\n    unsafe { *p = 1; }\n}\n";
        assert_eq!(rules_at("rust/src/sparse/mod.rs", src), vec![("U1", 2)]);
    }

    #[test]
    fn u1_accepts_same_line_above_line_and_doc_comments() {
        let src = "
fn f(p: *mut u8) {
    // SAFETY: caller guarantees exclusivity
    unsafe { *p = 1; }
    unsafe { *p = 2; } // SAFETY: same line
}
/// Docs.
/// Safety: `i < len` and no aliasing.
#[inline(always)]
pub unsafe fn g(p: *mut u8) { }
";
        assert!(rules_at("rust/src/sparse/mod.rs", src).is_empty());
    }

    #[test]
    fn u1_walk_stops_at_code_lines() {
        let src = "
fn f(p: *mut u8) {
    // SAFETY: only covers the next statement
    let q = p;
    unsafe { *q = 1; }
}
";
        assert_eq!(rules_at("rust/src/sparse/mod.rs", src), vec![("U1", 5)]);
    }

    #[test]
    fn u1_applies_inside_test_code_too() {
        let src = "#[test]\nfn t() {\n    unsafe { x() };\n}\n";
        assert_eq!(rules_at("rust/src/sparse/mod.rs", src), vec![("U1", 3)]);
    }

    #[test]
    fn u1_ignores_unsafe_in_strings_and_comments() {
        let src = "// unsafe is scary\nfn f() { let _s = \"unsafe\"; }\n";
        assert!(rules_at("rust/src/sparse/mod.rs", src).is_empty());
    }

    // ---- allowlist ----

    const ALLOW_OK: &str = r#"
# serving metrics are timing-only
[[allow]]
rule = "D2"
path = "rust/src/tsne/serve.rs"
pattern = "Instant"
reason = "timing metrics only; values never reach frames"
"#;

    #[test]
    fn allowlist_parses_and_suppresses() {
        let allow = parse_allowlist(ALLOW_OK).expect("parses");
        assert_eq!(allow.len(), 1);
        let src = "use std::time::Instant;\n";
        let findings = lint_file("rust/src/tsne/serve.rs", src);
        assert_eq!(findings.len(), 1);
        let (kept, stale) = apply_allowlist(findings, &allow);
        assert!(kept.is_empty());
        assert!(stale.is_empty());
    }

    #[test]
    fn allowlist_pattern_narrows_the_entry() {
        let allow = parse_allowlist(ALLOW_OK).expect("parses");
        // HashMap is D2 too, but the pattern pins the entry to Instant
        let findings = lint_file("rust/src/tsne/serve.rs", "use std::collections::HashMap;\n");
        let (kept, stale) = apply_allowlist(findings, &allow);
        assert_eq!(kept.len(), 1);
        assert_eq!(stale, vec![0]);
    }

    #[test]
    fn allowlist_single_quoted_patterns_carry_double_quotes() {
        let toml = "[[allow]]\nrule = \"P1\"\npath = \"rust/src/tsne/serve.rs\"\npattern = 'expect(\"infallible\")'\nreason = \"documented infallible conversion\"\n";
        let allow = parse_allowlist(toml).expect("parses");
        assert_eq!(allow[0].pattern.as_deref(), Some("expect(\"infallible\")"));
    }

    #[test]
    fn allowlist_rejects_bad_entries() {
        for bad in [
            "[[allow]]\nrule = \"Z9\"\npath = \"x\"\nreason = \"long enough reason\"\n",
            "[[allow]]\npath = \"x\"\nreason = \"long enough reason\"\n",
            "[[allow]]\nrule = \"D1\"\nreason = \"long enough reason\"\n",
            "[[allow]]\nrule = \"D1\"\npath = \"x\"\nreason = \"short\"\n",
            "[[allow]]\nrule = \"D1\"\npath = \"x\"\nreason = \"long enough reason\"\nbogus = \"k\"\n",
            "rule = \"D1\"\n",
            "[allow]\n",
        ] {
            assert!(parse_allowlist(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn allowlist_dir_prefix_matching() {
        let toml = "[[allow]]\nrule = \"D2\"\npath = \"rust/src/tsne/\"\nreason = \"whole-module waiver for the example\"\n";
        let allow = parse_allowlist(toml).expect("parses");
        let findings = lint_file("rust/src/tsne/serve.rs", "use std::time::Instant;\n");
        let (kept, stale) = apply_allowlist(findings, &allow);
        assert!(kept.is_empty());
        assert!(stale.is_empty());
    }
}
