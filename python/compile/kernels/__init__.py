"""L1 — Pallas kernels for the Acc-t-SNE compute hot-spots.

Every kernel is written for TPU-style tiling (BlockSpec-friendly shapes,
MXU-aligned matmuls, VPU elementwise bodies) but lowered with
``interpret=True`` so the CPU PJRT client can execute the resulting HLO
(real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot run —
see DESIGN.md §Hardware-Adaptation).

Kernels:
- :mod:`.sqdist`          — MXU-tiled squared-Euclidean distance (KNN step).
- :mod:`.attractive`      — VPU attractive-force tile over gathered neighbors.
- :mod:`.morton`          — Algorithm 1 bit-interleave Morton encoding.
- :mod:`.repulsive_dense` — dense O(N²) repulsion tile (exact-gradient oracle
                            / TPU-friendly ablation of the BH traversal).
- :mod:`.ref`             — pure-jnp oracles for all of the above.
"""

from . import attractive, morton, ref, repulsive_dense, sqdist  # noqa: F401
