"""Pallas kernel: dense repulsion tile — the TPU-friendly ablation of the
Barnes-Hut traversal.

The BH DFS is pointer-chasing and data-dependent — hostile to MXU/VPU. The
TPU-native formulation is the dense O(N²) tile: all-pairs (1+d²)⁻¹ within a
[B, C] block, which is regular, maskable, and pipelines HBM→VMEM cleanly.
Used as (a) the exact-gradient oracle behind the accuracy tests and (b) the
`repulsive_dense` ablation bench.

VMEM estimate at (B, C) = (256, 2048), f32: yall tile 2048·2·4 = 16 KiB,
diff/q intermediates 256·2048·4 ≈ 2 MiB (fused by XLA in interpret path),
outputs ≈ 2 KiB — the C=2048 corpus block is sized to amortize the yi tile
reload while staying well under VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Artifact tile shape (rust/src/runtime/engines.rs must agree).
B_TILE = 256
C_TILE = 2048


def _kernel(yi_ref, ya_ref, raw_ref, z_ref):
    yi = yi_ref[...]  # [B, 2]
    ya = ya_ref[...]  # [C, 2]
    diff = yi[:, None, :] - ya[None, :, :]  # [B, C, 2]
    dsq = jnp.sum(diff * diff, axis=-1)
    q = 1.0 / (1.0 + dsq)
    raw_ref[...] = jnp.sum((q * q)[..., None] * diff, axis=1)
    z_ref[...] = jnp.sum(q, axis=1)


@jax.jit
def repulsive_dense_tile(yi, yall):
    """[B,2] × [C,2] → (raw [B,2], z [B]); self terms included (q=1 at d=0,
    force contribution 0) — callers subtract the self count from z."""
    b, _ = yi.shape
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, 2), yi.dtype),
            jax.ShapeDtypeStruct((b,), yi.dtype),
        ),
        interpret=True,
    )(yi, yall)
