"""Pallas kernel: attractive-force tile (paper §3.6, Algorithm 2).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper hand-gathers
y_j with AVX-512 `vgatherdpd` inside the row loop. On TPU the gather belongs
in XLA (L2 does `y[idx]`), and the kernel is the pure VPU body over the
pre-gathered [B, K, 2] tile: d², PQ = p/(1+d²), and the K-reduction — dense,
branch-free elementwise work.

VMEM estimate at (B, K) = (256, 96), f32: yj tile 256·96·2·4 = 192 KiB,
pv 96 KiB, yi/out 2·2 KiB → ≈ 300 KiB per grid step; the B=256 block keeps
the (8,128) VPU lanes saturated on the K-major reduction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Artifact shape (rust/src/runtime/engines.rs must agree): rows per batch and
# neighbors per row (K = ⌊3·30⌋ = 90 padded to 96 for lane alignment).
B_ROWS = 256
K_PAD = 96


def _kernel(yi_ref, yj_ref, pv_ref, o_ref):
    yi = yi_ref[...]  # [B, 2]
    yj = yj_ref[...]  # [B, K, 2]
    pv = pv_ref[...]  # [B, K]
    diff = yi[:, None, :] - yj
    dsq = jnp.sum(diff * diff, axis=-1)
    pq = pv / (1.0 + dsq)
    o_ref[...] = jnp.sum(pq[..., None] * diff, axis=1)


@jax.jit
def attractive_tile(yi, yj, pv):
    """[B,2], [B,K,2], [B,K] → [B,2]; zero-valued pv rows contribute nothing."""
    b, _ = yi.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, 2), yi.dtype),
        interpret=True,
    )(yi, yj, pv)
