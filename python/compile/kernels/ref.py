"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest asserts kernel == ref before aot.py may emit artifacts)."""

import jax.numpy as jnp


def sqdist(xq, xc):
    """Squared Euclidean distances: [BQ, D] × [BC, D] → [BQ, BC]."""
    diff = xq[:, None, :] - xc[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def attractive(yi, yj, pv):
    """Attractive force accumulation (paper Eq. 8 / Algorithm 2 inner loop).

    yi: [B, 2] embedding points; yj: [B, K, 2] gathered neighbor coordinates;
    pv: [B, K] sparse P values (0 padding contributes nothing).
    Returns [B, 2]: sum_k pv/(1+d²) * (yi - yj).
    """
    diff = yi[:, None, :] - yj  # [B, K, 2]
    dsq = jnp.sum(diff * diff, axis=-1)  # [B, K]
    pq = pv / (1.0 + dsq)
    return jnp.sum(pq[..., None] * diff, axis=1)


def morton32(pts, cent, r_span):
    """32-bit Morton codes (16 bits per dim) of 2-D points — Algorithm 1 with
    a 2¹⁵ scale. pts: [N, 2] float32; returns int32 codes."""
    y_root = cent - r_span  # [2]
    scale = jnp.float32(1 << 15) / r_span
    grid = (pts - y_root[None, :]) * scale
    grid = jnp.clip(grid, 0.0, float((1 << 16) - 1)).astype(jnp.uint32)

    def interleave16(m):
        m = m & jnp.uint32(0x0000FFFF)
        m = (m | (m << 8)) & jnp.uint32(0x00FF00FF)
        m = (m | (m << 4)) & jnp.uint32(0x0F0F0F0F)
        m = (m | (m << 2)) & jnp.uint32(0x33333333)
        m = (m | (m << 1)) & jnp.uint32(0x55555555)
        return m

    code = interleave16(grid[:, 0]) | (interleave16(grid[:, 1]) << 1)
    return code.astype(jnp.int32)


def repulsive_dense(yi, yall):
    """Dense repulsion tile: raw_b = Σ_c (1+d²)⁻² (yi_b − yall_c) and
    z_b = Σ_c (1+d²)⁻¹ (self/duplicate terms included — the caller subtracts
    the exact self count). yi: [B, 2], yall: [C, 2] → ([B, 2], [B])."""
    diff = yi[:, None, :] - yall[None, :, :]  # [B, C, 2]
    dsq = jnp.sum(diff * diff, axis=-1)
    q = 1.0 / (1.0 + dsq)
    raw = jnp.sum((q * q)[..., None] * diff, axis=1)
    z = jnp.sum(q, axis=1)
    return raw, z
