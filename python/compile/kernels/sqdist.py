"""Pallas kernel: MXU-tiled squared Euclidean distance.

The KNN hot loop is ‖q−c‖² = ‖q‖² + ‖c‖² − 2⟨q,c⟩; the ⟨q,c⟩ term is a
[BQ,D]×[D,BC] matmul — exactly what the MXU systolic array wants. The paper's
CPU version cache-blocks this; the TPU mapping tiles it for VMEM:

VMEM estimate at the default (BQ, BC, D) = (128, 128, 32), f32:
  x tile 128·32·4 = 16 KiB, c tile 16 KiB, out 128·128·4 = 64 KiB,
  norms 1 KiB → ≈ 97 KiB total, far under the ~16 MiB VMEM budget; the
  block shape is chosen to keep the MXU's 128×128 native tile fully fed
  rather than to fill VMEM. D is padded to 32 (zero features do not change
  distances).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Artifact tile shape (rust/src/runtime/engines.rs must agree).
BQ = 128
BC = 128
D_PAD = 32


def _kernel(xq_ref, xc_ref, o_ref):
    xq = xq_ref[...]  # [BQ, D]
    xc = xc_ref[...]  # [BC, D]
    # MXU: the single matmul of the tile.
    dots = jnp.dot(xq, xc.T, preferred_element_type=jnp.float32)
    # VPU: row/col norms + broadcast add.
    qn = jnp.sum(xq * xq, axis=1, keepdims=True)  # [BQ, 1]
    cn = jnp.sum(xc * xc, axis=1)  # [BC]
    o_ref[...] = qn + cn[None, :] - 2.0 * dots


@functools.partial(jax.jit, static_argnames=())
def sqdist_tile(xq, xc):
    """One distance tile: [BQ, D] × [BC, D] → [BQ, BC] (f32)."""
    bq, _ = xq.shape
    bc, _ = xc.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((bq, bc), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic lowering
    )(xq, xc)
