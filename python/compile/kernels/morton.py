"""Pallas kernel: Morton encoding (paper §3.3, Algorithm 1).

The magic-mask shift cascade is pure integer VPU work — the same code the
paper auto-vectorizes with AVX. 32-bit codes (16 bits/dim) here: the CPU-PJRT
artifact path keeps i32 (the rust `xla` crate's literal support), while the
production Rust encoder uses the full 64-bit version.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Artifact batch (rust/src/runtime/engines.rs must agree).
N_POINTS = 1024


def _interleave16(m):
    m = m & jnp.uint32(0x0000FFFF)
    m = (m | (m << 8)) & jnp.uint32(0x00FF00FF)
    m = (m | (m << 4)) & jnp.uint32(0x0F0F0F0F)
    m = (m | (m << 2)) & jnp.uint32(0x33333333)
    m = (m | (m << 1)) & jnp.uint32(0x55555555)
    return m


def _kernel(pts_ref, cent_ref, span_ref, o_ref):
    pts = pts_ref[...]  # [N, 2] f32
    cent = cent_ref[...]  # [2]
    r_span = span_ref[0]
    y_root = cent - r_span
    scale = jnp.float32(1 << 15) / r_span
    grid = (pts - y_root[None, :]) * scale
    grid = jnp.clip(grid, 0.0, float((1 << 16) - 1)).astype(jnp.uint32)
    code = _interleave16(grid[:, 0]) | (_interleave16(grid[:, 1]) << 1)
    o_ref[...] = code.astype(jnp.int32)


@jax.jit
def morton_codes(pts, cent, r_span):
    """[N,2] f32 points + root cell → [N] i32 Morton codes."""
    n, _ = pts.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(pts, cent, jnp.reshape(r_span, (1,)))
