"""AOT lowering: JAX/Pallas (L2/L1) → HLO **text** artifacts for the Rust
runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the published xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --outdir ../artifacts`` (from python/).
Emits one .hlo.txt per graph plus manifest.json recording the frozen shapes
that rust/src/runtime/engines.rs must agree with.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.attractive import B_ROWS, K_PAD
from .kernels.morton import N_POINTS
from .kernels.repulsive_dense import B_TILE, C_TILE
from .kernels.sqdist import BC, BQ, D_PAD


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts():
    """(name, lowered, manifest-entry) for every artifact."""
    arts = []

    lowered = jax.jit(model.knn_sqdist).lower(spec((BQ, D_PAD)), spec((BC, D_PAD)))
    arts.append(("knn_sqdist", lowered, {"bq": BQ, "bc": BC, "d": D_PAD, "dtype": "f32"}))

    n_src = 4096  # gather-source rows frozen into the attractive artifact
    lowered = jax.jit(model.attractive_batch_rows).lower(
        spec((n_src, 2)),
        spec((B_ROWS,), jnp.int32),
        spec((B_ROWS, K_PAD), jnp.int32),
        spec((B_ROWS, K_PAD)),
    )
    arts.append(
        ("attractive", lowered, {"n_src": n_src, "b": B_ROWS, "k": K_PAD, "dtype": "f32"})
    )

    lowered = jax.jit(model.morton_codes).lower(
        spec((N_POINTS, 2)), spec((2,)), spec(())
    )
    arts.append(("morton", lowered, {"n": N_POINTS, "dtype": "f32->i32"}))

    lowered = jax.jit(model.repulsive_dense).lower(spec((B_TILE, 2)), spec((C_TILE, 2)))
    arts.append(("repulsive_dense", lowered, {"b": B_TILE, "c": C_TILE, "dtype": "f32"}))

    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest = {}
    for name, lowered, meta in build_artifacts():
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
