"""L2 — JAX compute graphs composing the L1 Pallas kernels.

These are the functions aot.py lowers to HLO text. Gathers, padding and
reshapes live here (XLA-native ops); the dense tile math lives in the
kernels. Python never runs at serve time — the Rust runtime executes the
lowered artifacts via PJRT.
"""

import jax.numpy as jnp

from .kernels import attractive as attr_k
from .kernels import morton as morton_k
from .kernels import repulsive_dense as rep_k
from .kernels import sqdist as sq_k


def knn_sqdist(xq, xc):
    """Distance tile for the blocked-KNN hot loop: [BQ,D]×[BC,D] → [BQ,BC]."""
    return sq_k.sqdist_tile(xq, xc)


def attractive_batch_rows(y, rows, idx, val):
    """Attractive forces for a batch of CSR rows (paper Algorithm 2).

    y:    [N, 2]  full embedding (gather source);
    rows: [B]     int32 — which embedding rows this batch computes forces for;
    idx:  [B, K]  int32 neighbor columns (pad with 0);
    val:  [B, K]  p_ij values (pad with 0 ⇒ padded lanes contribute nothing).
    Returns [B, 2]. The gathers are XLA's job (TPU gather unit); the dense
    tile math is the Pallas kernel's.
    """
    yi = jnp.take(y, rows, axis=0)  # [B, 2]
    yj = jnp.take(y, idx.reshape(-1), axis=0).reshape(idx.shape + (2,))  # [B, K, 2]
    return attr_k.attractive_tile(yi, yj, val)


def morton_codes(pts, cent, r_span):
    """Morton codes of a point batch (Algorithm 1, 32-bit)."""
    return morton_k.morton_codes(pts, cent, r_span)


def repulsive_dense(yi, yall):
    """Dense repulsion tile (exact oracle / TPU ablation)."""
    return rep_k.repulsive_dense_tile(yi, yall)
