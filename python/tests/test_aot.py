"""L2/AOT correctness: the model graphs compose kernels correctly and the
lowering path produces parseable HLO text with the frozen artifact shapes."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref
from compile.kernels.attractive import B_ROWS, K_PAD
from compile.kernels.sqdist import BC, BQ, D_PAD


def test_attractive_batch_rows_matches_manual_gather():
    rng = np.random.default_rng(0)
    n, b, k = 64, 16, 8
    y = rng.standard_normal((n, 2)).astype(np.float32)
    rows = rng.integers(0, n, b).astype(np.int32)
    idx = rng.integers(0, n, (b, k)).astype(np.int32)
    val = np.abs(rng.standard_normal((b, k))).astype(np.float32) * 0.01
    got = np.asarray(model.attractive_batch_rows(y, rows, idx, val))
    want = np.asarray(ref.attractive(jnp.asarray(y[rows]), jnp.asarray(y[idx]), jnp.asarray(val)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_hlo_text_lowering_roundtrips_through_xla_parser():
    lowered = jax.jit(model.knn_sqdist).lower(
        jax.ShapeDtypeStruct((BQ, D_PAD), jnp.float32),
        jax.ShapeDtypeStruct((BC, D_PAD), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{BQ},{BC}]" in text, "output shape must be frozen in the HLO"


def test_all_artifacts_lower():
    arts = aot.build_artifacts()
    names = [a[0] for a in arts]
    assert names == ["knn_sqdist", "attractive", "morton", "repulsive_dense"]
    for name, lowered, meta in arts:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert len(text) > 200, name


def test_artifact_shapes_match_manifest_constants():
    arts = dict((a[0], a[2]) for a in aot.build_artifacts())
    assert arts["knn_sqdist"] == {"bq": BQ, "bc": BC, "d": D_PAD, "dtype": "f32"}
    assert arts["attractive"]["b"] == B_ROWS
    assert arts["attractive"]["k"] == K_PAD


def test_attractive_artifact_scale_numerics():
    # Full artifact-shaped invocation: B_ROWS rows, K_PAD neighbors, padding.
    rng = np.random.default_rng(1)
    n = 4096
    y = rng.standard_normal((n, 2)).astype(np.float32)
    rows = np.arange(B_ROWS, dtype=np.int32)
    idx = rng.integers(0, n, (B_ROWS, K_PAD)).astype(np.int32)
    val = np.abs(rng.standard_normal((B_ROWS, K_PAD))).astype(np.float32) * 1e-3
    val[:, 90:] = 0.0  # the real K=90 < K_PAD=96 padding pattern
    got = np.asarray(model.attractive_batch_rows(y, rows, idx, val))
    want = np.asarray(ref.attractive(jnp.asarray(y[rows]), jnp.asarray(y[idx]), jnp.asarray(val)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)
