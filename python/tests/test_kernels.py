"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and data distributions (the kernels are
shape-polymorphic pre-AOT; the frozen artifact shapes are separately pinned
by test_aot.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attractive, morton, ref, repulsive_dense, sqdist

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- sqdist

@given(
    bq=st.integers(1, 64),
    bc=st.integers(1, 64),
    d=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
def test_sqdist_matches_ref(bq, bc, d, seed):
    rng = np.random.default_rng(seed)
    xq, xc = rand(rng, bq, d, scale=3.0), rand(rng, bc, d, scale=3.0)
    got = np.asarray(sqdist.sqdist_tile(xq, xc))
    want = np.asarray(ref.sqdist(jnp.asarray(xq), jnp.asarray(xc)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sqdist_zero_distance_on_identical_rows():
    x = np.ones((8, 16), dtype=np.float32)
    got = np.asarray(sqdist.sqdist_tile(x, x))
    np.testing.assert_allclose(got, 0.0, atol=1e-4)


def test_sqdist_zero_padding_invariant():
    rng = np.random.default_rng(0)
    xq, xc = rand(rng, 16, 10), rand(rng, 16, 10)
    pad = lambda a: np.pad(a, ((0, 0), (0, 22)))
    got = np.asarray(sqdist.sqdist_tile(pad(xq), pad(xc)))
    want = np.asarray(ref.sqdist(jnp.asarray(xq), jnp.asarray(xc)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ attractive

@given(
    b=st.integers(1, 48),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31),
)
def test_attractive_matches_ref(b, k, seed):
    rng = np.random.default_rng(seed)
    yi = rand(rng, b, 2, scale=5.0)
    yj = rand(rng, b, k, 2, scale=5.0)
    pv = np.abs(rand(rng, b, k, scale=0.01))
    got = np.asarray(attractive.attractive_tile(yi, yj, pv))
    want = np.asarray(ref.attractive(jnp.asarray(yi), jnp.asarray(yj), jnp.asarray(pv)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_attractive_zero_padding_contributes_nothing():
    rng = np.random.default_rng(1)
    yi = rand(rng, 8, 2)
    yj = rand(rng, 8, 12, 2)
    pv = np.abs(rand(rng, 8, 12, scale=0.1))
    full = np.asarray(attractive.attractive_tile(yi, yj, pv))
    yj_pad = np.concatenate([yj, rand(rng, 8, 4, 2)], axis=1)
    pv_pad = np.concatenate([pv, np.zeros((8, 4), np.float32)], axis=1)
    padded = np.asarray(attractive.attractive_tile(yi, yj_pad, pv_pad))
    np.testing.assert_allclose(padded, full, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------- morton

@given(n=st.integers(1, 256), seed=st.integers(0, 2**31))
def test_morton_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    pts = rand(rng, n, 2, scale=4.0)
    cent = pts.mean(axis=0)
    r = np.float32(np.abs(pts - cent).max() + 1e-3)
    got = np.asarray(morton.morton_codes(pts, cent, r))
    want = np.asarray(ref.morton32(jnp.asarray(pts), jnp.asarray(cent), jnp.asarray(r)))
    np.testing.assert_array_equal(got, want)


def test_morton_paper_example():
    # Paper Fig. 2: dim0 = 3, dim1 = 7 → 47. Use a cell making grid = coords.
    # grid = (pts - (cent - r)) * 2^15 / r; choose cent=(0,0), r=2^15 so
    # grid = pts + 2^15... instead verify interleave property via ref equality
    # and z-ordering along the diagonal:
    pts = np.array([[i / 10.0, i / 10.0] for i in range(10)], dtype=np.float32)
    cent = np.array([0.45, 0.45], dtype=np.float32)
    codes = np.asarray(morton.morton_codes(pts, cent, np.float32(0.5)))
    # i32 is a reinterpretation of the u32 code (the rust side views it
    # unsigned too); compare in u32 space.
    codes_u = codes.view(np.uint32).astype(np.uint64)
    assert (np.diff(codes_u.astype(np.int64)) >= 0).all(), "diagonal points must be z-ordered"


# -------------------------------------------------------- repulsive_dense

@given(b=st.integers(1, 32), c=st.integers(1, 64), seed=st.integers(0, 2**31))
def test_repulsive_dense_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    yi = rand(rng, b, 2, scale=3.0)
    yall = rand(rng, c, 2, scale=3.0)
    raw_g, z_g = repulsive_dense.repulsive_dense_tile(yi, yall)
    raw_w, z_w = ref.repulsive_dense(jnp.asarray(yi), jnp.asarray(yall))
    np.testing.assert_allclose(np.asarray(raw_g), np.asarray(raw_w), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_g), np.asarray(z_w), rtol=1e-4, atol=1e-5)


def test_repulsive_dense_self_term_is_identity():
    y = np.array([[1.0, 2.0]], dtype=np.float32)
    raw, z = repulsive_dense.repulsive_dense_tile(y, y)
    np.testing.assert_allclose(np.asarray(raw), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(z), 1.0, rtol=1e-6)


def test_repulsive_far_points_vanish():
    yi = np.array([[0.0, 0.0]], dtype=np.float32)
    ya = np.array([[1e4, 1e4]], dtype=np.float32)
    raw, z = repulsive_dense.repulsive_dense_tile(yi, ya)
    assert abs(float(z[0])) < 1e-7
    assert np.abs(np.asarray(raw)).max() < 1e-7
