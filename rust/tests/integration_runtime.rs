//! Integration: the AOT artifacts (L1 Pallas kernels lowered through L2 JAX
//! graphs) executed from Rust via PJRT, validated against the native Rust
//! implementations — the three-layer composition proof.
//!
//! Requires `make artifacts` (skips with a message otherwise, so `cargo test`
//! works on a fresh checkout) and the `xla` cargo feature (off by default —
//! the xla-rs / anyhow crates are not on the offline mirror).
#![cfg(feature = "xla")]

use acc_tsne::common::rng::Rng;
use acc_tsne::gradient::attractive::{attractive_forces, Variant};
use acc_tsne::gradient::exact::exact_repulsive;
use acc_tsne::knn::{knn_reference, KnnEngine};
use acc_tsne::parallel::ThreadPool;
use acc_tsne::perplexity::{binary_search_perplexity, ParMode};
use acc_tsne::quadtree::morton::RootCell;
use acc_tsne::runtime::engines::{XlaAttractive, XlaKnn, XlaMorton, XlaRepulsiveDense};
use acc_tsne::runtime::Runtime;
use acc_tsne::sparse::symmetrize;
use acc_tsne::tsne::{run_tsne_custom, AttractiveEngine, Implementation, TsneConfig};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime integration: {e:#}");
            None
        }
    }
}

#[test]
fn xla_knn_matches_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let eng = XlaKnn::new(&rt).expect("compile knn artifact");
    let mut rng = Rng::new(1);
    let (n, d, k) = (300, 20, 10);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
    let pool = ThreadPool::new(2);
    let got: acc_tsne::knn::NeighborLists<f32> = eng.search(&pool, &data, n, d, k);
    let want = knn_reference(&data, n, d, k);
    let mut mismatches = 0;
    for i in 0..n {
        for j in 0..k {
            // f32 distance ties can reorder neighbors; compare distances.
            let g = got.distances_sq[i * k + j];
            let w = want.distances_sq[i * k + j];
            if (g - w).abs() > 1e-3 * (1.0 + w.abs()) {
                mismatches += 1;
            }
        }
    }
    assert!(
        mismatches <= n * k / 200,
        "xla knn disagrees with reference on {mismatches}/{} entries",
        n * k
    );
}

#[test]
fn xla_attractive_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let eng = XlaAttractive::new(&rt).expect("compile attractive artifact");
    let mut rng = Rng::new(2);
    let (n, d) = (500, 6);
    let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
    let pool = ThreadPool::new(4);
    let knn = acc_tsne::knn::BruteForceKnn::default().search(&pool, &data, n, d, 30);
    let cond = binary_search_perplexity(&pool, &knn, 10.0, ParMode::Parallel);
    let p = symmetrize(&pool, &knn, &cond.p);
    let y: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();

    let mut native = vec![0.0f64; 2 * n];
    attractive_forces(&pool, &p, &y, Variant::Scalar, &mut native);
    let mut xla_out = vec![0.0f64; 2 * n];
    AttractiveEngine::<f64>::compute(&eng, &pool, &p, &y, &mut xla_out);

    for i in 0..2 * n {
        assert!(
            (native[i] - xla_out[i]).abs() < 1e-4 * (1.0 + native[i].abs()),
            "idx {i}: native {} vs xla {}",
            native[i],
            xla_out[i]
        );
    }
}

#[test]
fn xla_morton_matches_native_prefix() {
    let Some(rt) = runtime_or_skip() else { return };
    let eng = XlaMorton::new(&rt).expect("compile morton artifact");
    let mut rng = Rng::new(3);
    let n = 1500; // crosses the 1024 batch boundary
    let pos: Vec<f32> = (0..2 * n).map(|_| rng.next_gaussian() as f32 * 3.0).collect();
    let pos64: Vec<f64> = pos.iter().map(|&v| v as f64).collect();
    let pool = ThreadPool::new(2);
    let root = RootCell::bounding(&pool, &pos64);
    let codes = eng
        .encode(&pos, [root.cent[0] as f32, root.cent[1] as f32], root.r_span as f32)
        .expect("morton artifact execution");
    assert_eq!(codes.len(), n);
    // The 32-bit artifact code must equal the top 32 bits of the 64-bit
    // native code (16 vs 32 bits per dim → shift by 32), modulo f32 grid
    // rounding at cell boundaries: allow a small mismatch budget.
    let mut native = vec![0u64; n];
    acc_tsne::quadtree::morton::encode_points(&pool, &pos64, &root, &mut native);
    let mismatches = (0..n)
        .filter(|&i| codes[i] != (native[i] >> 32) as u32)
        .count();
    assert!(
        mismatches < n / 20,
        "morton artifact disagrees on {mismatches}/{n} points"
    );
}

#[test]
fn xla_repulsive_dense_matches_exact() {
    let Some(rt) = runtime_or_skip() else { return };
    let eng = XlaRepulsiveDense::new(&rt).expect("compile repulsive artifact");
    let mut rng = Rng::new(4);
    let n = 700;
    let y: Vec<f32> = (0..2 * n).map(|_| rng.next_gaussian() as f32 * 2.0).collect();
    let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let pool = ThreadPool::new(4);
    let (raw, z) = eng.exact(&y).expect("repulsive artifact execution");
    let (want_raw, want_z) = exact_repulsive(&pool, &y64);
    assert!(
        ((z as f64) - want_z).abs() < 1e-3 * want_z,
        "Z {z} vs {want_z}"
    );
    for i in 0..2 * n {
        assert!(
            ((raw[i] as f64) - want_raw[i]).abs() < 1e-3 * (1.0 + want_raw[i].abs()),
            "idx {i}: {} vs {}",
            raw[i],
            want_raw[i]
        );
    }
}

#[test]
fn end_to_end_tsne_with_xla_attractive_engine() {
    // The full L3 pipeline with the L1/L2 attractive artifact on the hot path.
    let Some(rt) = runtime_or_skip() else { return };
    let eng = XlaAttractive::new(&rt).expect("compile attractive artifact");
    let ds = acc_tsne::data::synthetic::gaussian_mixture::<f64>(350, 8, 4, 8.0, 7);
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 60,
        n_threads: 4,
        // The AOT artifact bakes the original sparsity pattern; don't hand it
        // the Z-order-permuted P the AccTsne default layout would produce.
        layout: Some(acc_tsne::tsne::Layout::Original),
        ..TsneConfig::default()
    };
    let r_xla = run_tsne_custom(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne, Some(&eng));
    let r_native = run_tsne_custom(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne, None);
    assert!(r_xla.embedding.iter().all(|v| v.is_finite()));
    // Same seed, same schedule; only the attractive arithmetic differs (f32
    // round-trip) → KLs must land close.
    let rel = (r_xla.kl_divergence - r_native.kl_divergence).abs() / r_native.kl_divergence;
    assert!(
        rel < 0.05,
        "xla-engine KL {} vs native {}",
        r_xla.kl_divergence,
        r_native.kl_divergence
    );
}
