//! Property-based tests over the pipeline invariants, using the in-crate
//! mini-framework (`common::proptest`). Each property runs across dozens of
//! random seeds with edge-case-biased generators (duplicates, degenerate
//! geometry, tiny/odd sizes).

use acc_tsne::common::proptest::{check, gen_len, gen_points, Config};
use acc_tsne::common::rng::Rng;
use acc_tsne::fitsne::{fitsne_repulsive_into, FitsneParams, FitsneWorkspace};
use acc_tsne::gradient::exact::exact_repulsive;
use acc_tsne::gradient::repulsive::{repulsive_forces_scalar_into, repulsive_forces_tiled_into};
use acc_tsne::knn::{knn_reference, BruteForceKnn, KnnEngine};
use acc_tsne::parallel::sort::radix_sort_pairs;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::perplexity::{bsp_row, bsp_row_checked};
use acc_tsne::quadtree::builder_baseline::build_baseline;
use acc_tsne::quadtree::builder_morton::build_morton;
use acc_tsne::quadtree::morton::{quadrant_at, RootCell};
use acc_tsne::quadtree::summarize::{summarize_parallel, summarize_sequential};
use acc_tsne::quadtree::tree_stats;
use acc_tsne::quadtree::view::TraversalView;
use acc_tsne::quadtree::QuadTree;
use acc_tsne::tsne::{run_tsne, Implementation, Layout, TsneConfig};

fn pool() -> ThreadPool {
    ThreadPool::new(4)
}

/// Scalar repulsive pass with a locally-owned buffer (the `_into` API the
/// pipeline uses; the allocating wrapper is gone).
fn scalar_rep(pool: &ThreadPool, tree: &QuadTree<f64>, theta: f64) -> (Vec<f64>, f64) {
    let mut raw = vec![0.0f64; 2 * tree.n_points()];
    let z = repulsive_forces_scalar_into(pool, tree, theta, &mut raw);
    (raw, z)
}

#[test]
fn prop_morton_tree_always_valid() {
    let pool = pool();
    check("morton tree valid", Config { cases: 40, ..Config::default() }, |rng| {
        let n = gen_len(rng, 1, 800);
        let pos = gen_points(rng, 2 * n, 10.0);
        let tree = build_morton(&pool, &pos);
        tree.validate()
    });
}

#[test]
fn prop_baseline_tree_always_valid() {
    let pool = pool();
    check("baseline tree valid", Config { cases: 30, ..Config::default() }, |rng| {
        let n = gen_len(rng, 1, 500);
        let pos = gen_points(rng, 2 * n, 10.0);
        let tree = build_baseline(&pool, &pos);
        tree.validate()
    });
}

#[test]
fn prop_builders_agree_on_leaf_count_and_mass() {
    let pool = pool();
    check("builders agree", Config { cases: 25, ..Config::default() }, |rng| {
        let n = gen_len(rng, 2, 600);
        let pos = gen_points(rng, 2 * n, 5.0);
        let a = build_morton(&pool, &pos);
        let b = build_baseline(&pool, &pos);
        // identical subdivision rule ⇒ same root mass; leaf sets may differ
        // only at duplicate chains (documented) — compare total counts.
        if a.root().count != b.root().count {
            return Err(format!("mass {} vs {}", a.root().count, b.root().count));
        }
        let (sa, sb) = (tree_stats(&a), tree_stats(&b));
        // depth can differ only when duplicate chains exist (baseline chains
        // to the cap); if no multi-point leaves, depths must match.
        if sa.max_leaf_points == 1 && sb.max_leaf_points == 1 && sa.depth != sb.depth {
            return Err(format!("depth {} vs {} without duplicates", sa.depth, sb.depth));
        }
        Ok(())
    });
}

#[test]
fn prop_summarize_parallel_equals_sequential() {
    let pool = pool();
    check("summarize par == seq", Config { cases: 30, ..Config::default() }, |rng| {
        let n = gen_len(rng, 1, 700);
        let pos = gen_points(rng, 2 * n, 8.0);
        let mut a = build_morton(&pool, &pos);
        let mut b = a.clone();
        summarize_sequential(&mut a);
        summarize_parallel(&pool, &mut b);
        for (x, y) in a.nodes.iter().zip(b.nodes.iter()) {
            for d in 0..2 {
                if (x.com[d] - y.com[d]).abs() > 1e-10 {
                    return Err(format!("com mismatch {} vs {}", x.com[d], y.com[d]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bh_z_bounded_by_pair_count() {
    // Z = Σ_{i≠j} (1+d²)⁻¹ ∈ (0, n(n-1)] for any geometry, any θ.
    let pool = pool();
    check("Z bounds", Config { cases: 30, ..Config::default() }, |rng| {
        let n = gen_len(rng, 2, 400);
        let pos = gen_points(rng, 2 * n, 3.0);
        let theta = rng.next_f64();
        let mut tree = build_morton(&pool, &pos);
        summarize_parallel(&pool, &mut tree);
        let (_, z) = scalar_rep(&pool, &tree, theta);
        let bound = (n * (n - 1)) as f64;
        if !(z > 0.0 && z <= bound * 1.000001) {
            return Err(format!("Z {z} out of (0, {bound}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_bh_converges_to_exact_as_theta_shrinks() {
    let pool = pool();
    check("θ→0 convergence", Config { cases: 10, ..Config::default() }, |rng| {
        let n = 150 + rng.next_below(150);
        let pos = gen_points(rng, 2 * n, 4.0);
        let mut tree = build_morton(&pool, &pos);
        summarize_parallel(&pool, &mut tree);
        let (want, _) = exact_repulsive(&pool, &pos);
        let err_at = |theta: f64| {
            let (raw, _) = scalar_rep(&pool, &tree, theta);
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..2 * n {
                num += (raw[i] - want[i]) * (raw[i] - want[i]);
                den += want[i] * want[i] + 1e-30;
            }
            (num / den).sqrt()
        };
        let (e_high, e_low) = (err_at(0.9), err_at(0.1));
        if e_low > e_high + 1e-12 {
            return Err(format!("error grew as θ shrank: θ=0.9→{e_high}, θ=0.1→{e_low}"));
        }
        if e_low > 0.01 {
            return Err(format!("θ=0.1 error too large: {e_low}"));
        }
        Ok(())
    });
}

#[test]
fn prop_knn_blocked_equals_reference() {
    let pool = pool();
    check("knn == reference", Config { cases: 15, ..Config::default() }, |rng| {
        let n = gen_len(rng, 10, 250);
        let d = gen_len(rng, 1, 12);
        let k = 1 + rng.next_below((n - 1).min(20));
        let data = gen_points(rng, n * d, 5.0);
        let eng = BruteForceKnn {
            block_q: 1 + rng.next_below(80),
            block_c: 1 + rng.next_below(300),
        };
        let got = eng.search(&pool, &data, n, d, k);
        let want = knn_reference(&data, n, d, k);
        for i in 0..n {
            for j in 0..k {
                let (g, w) = (got.distances_sq[i * k + j], want.distances_sq[i * k + j]);
                if (g - w).abs() > 1e-9 * (1.0 + w.abs()) {
                    return Err(format!("row {i} pos {j}: {g} vs {w}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bsp_row_normalized_and_on_target() {
    check("bsp row", Config { cases: 60, ..Config::default() }, |rng| {
        let k = gen_len(rng, 3, 60);
        let u = 1.5 + rng.next_f64() * (k as f64 * 0.8 - 1.5);
        let dists: Vec<f64> = (0..k).map(|_| rng.next_f64() * 20.0 + 1e-3).collect();
        let mut out = vec![0.0; k];
        bsp_row(&dists, u, &mut out);
        let sum: f64 = out.iter().sum();
        if (sum - 1.0).abs() > 1e-9 {
            return Err(format!("row not normalized: {sum}"));
        }
        let h: f64 = out.iter().filter(|&&p| p > 0.0).map(|&p| -p * p.ln()).sum();
        let perp = h.exp();
        if (perp - u).abs() > 0.05 * u {
            return Err(format!("perplexity {perp} vs target {u}"));
        }
        Ok(())
    });
}

#[test]
fn prop_adversarial_bsp_rows_stay_finite_or_fall_back() {
    // Hostile distance rows: flat (all-equal), 1e±30 dynamic range,
    // duplicate-heavy (half the row at distance zero), and random-extreme.
    // Every row must come back finite, non-negative, and normalized; rows the
    // solver could not converge must be exactly the uniform fallback.
    check(
        "adversarial bsp rows",
        Config { cases: 60, ..Config::default() },
        |rng| {
            let k = gen_len(rng, 3, 60);
            let u = 1.5 + rng.next_f64() * (k as f64 * 0.8 - 1.5);
            let mode = rng.next_below(4);
            let dists: Vec<f64> = (0..k)
                .map(|i| match mode {
                    0 => 3.25,
                    1 => {
                        if i % 2 == 0 {
                            1e30
                        } else {
                            1e-30
                        }
                    }
                    2 => {
                        if i < k / 2 {
                            0.0
                        } else {
                            1.0 + i as f64
                        }
                    }
                    _ => 10f64.powf(rng.next_f64() * 60.0 - 30.0),
                })
                .collect();
            let mut out = vec![0.0; k];
            let (beta, converged) = bsp_row_checked(&dists, u, &mut out);
            if !beta.is_finite() {
                return Err(format!("mode {mode}: beta = {beta}"));
            }
            if out.iter().any(|p| !p.is_finite() || *p < 0.0) {
                return Err(format!("mode {mode}: non-finite or negative probability"));
            }
            let sum: f64 = out.iter().sum();
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("mode {mode}: row sums to {sum}"));
            }
            if !converged {
                let uniform = 1.0 / k as f64;
                if out.iter().any(|&p| p != uniform) {
                    return Err(format!("mode {mode}: fallback row is not exactly uniform"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coincident_clouds_yield_finite_trees_and_forces() {
    // Degenerate geometry: all points coincident, or coincident plus a
    // sub-epsilon jitter. Both builders must terminate with structurally
    // valid trees and finite cell geometry, and the repulsive pass must
    // return finite forces with Z > 0 — across 1/4/8-thread pools.
    check(
        "coincident clouds stay finite",
        Config { cases: 18, ..Config::default() },
        |rng| {
            let n = gen_len(rng, 2, 300);
            let cx = rng.next_f64() * 8.0 - 4.0;
            let cy = rng.next_f64() * 8.0 - 4.0;
            let jitter = [0.0, 1e-300, 1e-18][rng.next_below(3)];
            let mut pos = vec![0.0f64; 2 * n];
            for i in 0..n {
                pos[2 * i] = cx + i as f64 * jitter;
                pos[2 * i + 1] = cy - i as f64 * jitter;
            }
            let threads = [1, 4, 8][rng.next_below(3)];
            let pool = ThreadPool::new(threads);
            for (which, tree) in [
                ("morton", build_morton(&pool, &pos)),
                ("baseline", build_baseline(&pool, &pos)),
            ] {
                tree.validate().map_err(|e| format!("{which}: {e}"))?;
                for node in &tree.nodes {
                    if !node.width.is_finite() || node.center.iter().any(|c| !c.is_finite()) {
                        return Err(format!("{which}: non-finite cell geometry"));
                    }
                }
            }
            let mut tree = build_morton(&pool, &pos);
            summarize_parallel(&pool, &mut tree);
            let mut raw = vec![0.0f64; 2 * n];
            let z = repulsive_forces_scalar_into(&pool, &tree, 0.5, &mut raw);
            if !(z.is_finite() && z > 0.0) {
                return Err(format!("Z = {z} for a coincident cloud"));
            }
            if raw.iter().any(|v| !v.is_finite()) {
                return Err("non-finite repulsive force".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fitsne_matches_exact_oracle() {
    // FFT-engine parity against the O(n²) oracle across 1/4/8-thread pools:
    // p = 3 Lagrange interpolation gives a few-percent force accuracy and
    // ~1% on Z (the fitsne module's established tolerances), independent of
    // the thread count and of workspace reuse across cases.
    check(
        "fitsne == exact oracle",
        Config { cases: 10, ..Config::default() },
        |rng| {
            let n = 100 + gen_len(rng, 0, 400);
            let pos = gen_points(rng, 2 * n, 6.0);
            let threads = [1, 4, 8][rng.next_below(3)];
            let pool = ThreadPool::new(threads);
            let params = FitsneParams::default();
            let mut ws = FitsneWorkspace::new();
            let mut raw = vec![0.0f64; 2 * n];
            let z = fitsne_repulsive_into(&pool, &pos, &params, &mut ws, &mut raw);
            let (want, z_want) = exact_repulsive(&pool, &pos);
            let z_rel = (z - z_want).abs() / z_want;
            if z_rel > 0.02 {
                return Err(format!("n={n} t={threads}: Z rel error {z_rel}"));
            }
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..2 * n {
                num += (raw[i] - want[i]) * (raw[i] - want[i]);
                den += want[i] * want[i] + 1e-30;
            }
            let rel = (num / den).sqrt();
            if rel > 0.06 {
                return Err(format!("n={n} t={threads}: force rel-RMS {rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coincident_clouds_fitsne_forces_stay_finite() {
    // Degenerate geometry through the FFT engine: coincident (and
    // sub-epsilon-jittered) clouds collapse the bounding span to ~0; the
    // min_intervals clamp and the span-lattice fallback must keep the grid
    // finite, the forces finite, and Z > 0 — across 1/4/8-thread pools and
    // across workspace reuse between unrelated degenerate cases.
    check(
        "coincident clouds stay finite (fitsne)",
        Config { cases: 18, ..Config::default() },
        |rng| {
            let n = gen_len(rng, 2, 300);
            let cx = rng.next_f64() * 8.0 - 4.0;
            let cy = rng.next_f64() * 8.0 - 4.0;
            let jitter = [0.0, 1e-300, 1e-18][rng.next_below(3)];
            let mut pos = vec![0.0f64; 2 * n];
            for i in 0..n {
                pos[2 * i] = cx + i as f64 * jitter;
                pos[2 * i + 1] = cy - i as f64 * jitter;
            }
            let threads = [1, 4, 8][rng.next_below(3)];
            let pool = ThreadPool::new(threads);
            let params = FitsneParams::default();
            let mut ws = FitsneWorkspace::new();
            let mut raw = vec![0.0f64; 2 * n];
            let z = fitsne_repulsive_into(&pool, &pos, &params, &mut ws, &mut raw);
            if !(z.is_finite() && z > 0.0) {
                return Err(format!("Z = {z} for a coincident cloud"));
            }
            if raw.iter().any(|v| !v.is_finite()) {
                return Err("non-finite FFT repulsive force".into());
            }
            // A second pass through the same workspace must behave the same
            // (stale kernels from the first geometry fully masked).
            let z2 = fitsne_repulsive_into(&pool, &pos, &params, &mut ws, &mut raw);
            if z2 != z {
                return Err(format!("workspace reuse changed Z: {z} vs {z2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_radix_sort_equals_std_sort() {
    let pool = pool();
    check("radix == std", Config { cases: 20, ..Config::default() }, |rng| {
        let n = gen_len(rng, 0, 30_000);
        let mask = if rng.next_below(2) == 0 { u64::MAX } else { 0xFFFF };
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let mut k = keys.clone();
        let mut p: Vec<u32> = (0..n as u32).collect();
        radix_sort_pairs(&pool, &mut k, &mut p);
        let mut want = keys.clone();
        want.sort_unstable();
        if k != want {
            return Err("keys not sorted".into());
        }
        for i in 0..n {
            if keys[p[i] as usize] != k[i] {
                return Err(format!("payload broken at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_morton_codes_respect_quadrant_geometry() {
    let pool = pool();
    check("morton quadrants", Config { cases: 40, ..Config::default() }, |rng| {
        let n = gen_len(rng, 1, 200);
        let pos = gen_points(rng, 2 * n, 6.0);
        let root = RootCell::bounding(&pool, &pos);
        for i in 0..n {
            let (x, y) = (pos[2 * i], pos[2 * i + 1]);
            let code = root.encode(x, y);
            let q = quadrant_at(code, 0);
            let want = usize::from(x >= root.cent[0]) | (usize::from(y >= root.cent[1]) << 1);
            // boundary points may land either side of the integer grid line
            let on_boundary = (x - root.cent[0]).abs() < 1e-9 || (y - root.cent[1]).abs() < 1e-9;
            if q != want && !on_boundary {
                return Err(format!("point ({x},{y}): quadrant {q} vs {want}"));
            }
        }
        Ok(())
    });
}

/// Compare the SIMD-tiled repulsive kernel against the scalar DFS on one
/// configuration. Per lane the tiled kernel's accept set and accumulation
/// order are identical to the scalar traversal, so parity is FP-noise-tight.
fn tiled_scalar_parity(pos: &[f64], theta: f64, threads: usize) -> Result<(), String> {
    let n = pos.len() / 2;
    let pool = ThreadPool::new(threads);
    let mut tree = build_morton(&pool, pos);
    summarize_parallel(&pool, &mut tree);
    let mut want = vec![0.0f64; 2 * n];
    let mut got = vec![0.0f64; 2 * n];
    let z_scalar = repulsive_forces_scalar_into(&pool, &tree, theta, &mut want);
    let mut view = TraversalView::new();
    view.rebuild_parallel(&pool, &tree);
    let z_tiled = repulsive_forces_tiled_into(&pool, &tree, &view, theta, &mut got);
    if (z_scalar - z_tiled).abs() > 1e-10 * z_scalar.abs().max(1.0) {
        return Err(format!(
            "n={n} θ={theta} t={threads}: Z {z_scalar} vs {z_tiled}"
        ));
    }
    for i in 0..2 * n {
        if (want[i] - got[i]).abs() > 1e-10 * (1.0 + want[i].abs()) {
            return Err(format!(
                "n={n} θ={theta} t={threads} idx {i}: scalar {} vs tiled {}",
                want[i], got[i]
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_tiled_repulsive_matches_scalar() {
    // Random point sets across sizes straddling the 8-lane tile boundary,
    // exact (θ=0) and production (θ=0.5) traversals, 1/4/8-thread pools.
    check(
        "tiled == scalar",
        Config { cases: 24, ..Config::default() },
        |rng| {
            let n = gen_len(rng, 1, 900);
            let pos = gen_points(rng, 2 * n, 8.0);
            let theta = if rng.next_below(2) == 0 { 0.0 } else { 0.5 };
            let threads = [1, 4, 8][rng.next_below(3)];
            tiled_scalar_parity(&pos, theta, threads)
        },
    );
}

#[test]
fn prop_tiled_repulsive_matches_scalar_duplicate_heavy() {
    // Duplicate-heavy sets: multi-point leaves exercise the own-leaf
    // (exact, self-skipping) and foreign-leaf (count·COM) lane paths.
    check(
        "tiled == scalar (duplicates)",
        Config { cases: 16, ..Config::default() },
        |rng| {
            let n = gen_len(rng, 8, 400);
            let mut pos = gen_points(rng, 2 * n, 5.0);
            // collapse a random fraction of points onto a few shared sites
            let sites = 1 + rng.next_below(4);
            for i in 0..n {
                if rng.next_below(3) == 0 {
                    let s = rng.next_below(sites);
                    pos[2 * i] = s as f64 * 0.25 - 1.0;
                    pos[2 * i + 1] = s as f64 * -0.5 + 2.0;
                }
            }
            let theta = if rng.next_below(2) == 0 { 0.0 } else { 0.5 };
            let threads = [1, 4, 8][rng.next_below(3)];
            tiled_scalar_parity(&pos, theta, threads)
        },
    );
}

#[test]
fn prop_forces_antisymmetric_for_two_points() {
    // Newton's third law at the BH level for the 2-point system.
    let pool = pool();
    check("pairwise antisymmetry", Config { cases: 50, ..Config::default() }, |rng| {
        let mut rng2 = Rng::new(rng.next_u64());
        let pos = vec![
            rng2.next_gaussian(),
            rng2.next_gaussian(),
            rng2.next_gaussian(),
            rng2.next_gaussian(),
        ];
        let mut tree = build_morton(&pool, &pos);
        summarize_sequential(&mut tree);
        let (raw, _) = scalar_rep(&pool, &tree, 0.5);
        for d in 0..2 {
            let (a, b) = (raw[d], raw[2 + d]);
            if (a + b).abs() > 1e-12 * (1.0 + a.abs()) {
                return Err(format!("dim {d}: {a} + {b} != 0"));
            }
        }
        Ok(())
    });
}

/// Full-pipeline parity between the original and Z-order-persistent layouts
/// (the ISSUE-2 acceptance bar): same data, same config, only
/// `TsneConfig::layout` differs. Every value in the Z-order path is relocated
/// rather than recomputed and the CSR re-index preserves per-row entry order,
/// so over a short horizon the embeddings agree to FP noise. Sweeps
/// theta in {0, 0.5}, 1/4/8-thread pools, and duplicate-heavy inputs.
fn layout_parity(
    data: &[f64],
    n: usize,
    d: usize,
    theta: f64,
    threads: usize,
) -> Result<(), String> {
    let mut cfg = TsneConfig {
        perplexity: 5.0,
        theta,
        n_iter: 10,
        n_threads: threads,
        seed: 0xACC,
        layout: Some(Layout::Original),
        ..TsneConfig::default()
    };
    let a = run_tsne(data, n, d, &cfg, Implementation::AccTsne);
    cfg.layout = Some(Layout::Zorder);
    let b = run_tsne(data, n, d, &cfg, Implementation::AccTsne);
    for i in 0..2 * n {
        let (x, y) = (a.embedding[i], b.embedding[i]);
        if !x.is_finite() || (x - y).abs() > 1e-6 * (1.0 + x.abs()) {
            return Err(format!(
                "theta={theta} threads={threads} n={n} idx {i}: original {x} vs zorder {y}"
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_zorder_pipeline_matches_original_layout() {
    check(
        "zorder pipeline == original",
        Config { cases: 8, ..Config::default() },
        |rng| {
            let n = 40 + gen_len(rng, 0, 260);
            let d = 4;
            let data = gen_points(rng, n * d, 5.0);
            let theta = if rng.next_below(2) == 0 { 0.0 } else { 0.5 };
            let threads = [1, 4, 8][rng.next_below(3)];
            layout_parity(&data, n, d, theta, threads)
        },
    );
}

#[test]
fn prop_zorder_pipeline_matches_original_layout_duplicate_heavy() {
    // Duplicated input rows produce coincident embeddings-in-spirit: equal
    // morton codes, multi-point leaves, and radix-sort tie-breaking — the
    // layouts must still agree.
    check(
        "zorder == original (duplicates)",
        Config { cases: 6, ..Config::default() },
        |rng| {
            let n = 60 + gen_len(rng, 0, 140);
            let d = 4;
            let mut data = gen_points(rng, n * d, 5.0);
            let sites = 1 + rng.next_below(3);
            for i in 0..n {
                if rng.next_below(4) == 0 {
                    let site = rng.next_below(sites) as f64;
                    for dd in 0..d {
                        data[i * d + dd] = site * 0.5 - 1.0 + dd as f64 * 0.1;
                    }
                }
            }
            let theta = if rng.next_below(2) == 0 { 0.0 } else { 0.5 };
            let threads = [1, 4, 8][rng.next_below(3)];
            layout_parity(&data, n, d, theta, threads)
        },
    );
}
