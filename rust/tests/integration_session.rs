//! Integration: the session API's cross-module contracts — compat parity
//! with `run_tsne`, affinity reuse across seeds, and convergence-based
//! stopping on an easy dataset.
//!
//! The convergence tests are *calibrated*, not statistical: a reference
//! session records the (deterministic, fixed-thread-count) gradient-norm
//! trajectory, the stopping threshold is derived from it, and a fresh
//! session on the same seed must stop where the trajectory says. No
//! tolerance on iteration counts, no flake.

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{
    run_tsne, Affinities, Convergence, Implementation, StagePlan, StopReason, TsneConfig,
    TsneSession,
};

fn cfg(n_iter: usize) -> TsneConfig {
    TsneConfig {
        perplexity: 10.0,
        n_iter,
        n_threads: 4,
        seed: 7,
        ..TsneConfig::default()
    }
}

/// An easy, well-separated mixture: 300 points, 3 far-apart clusters.
fn easy_fit() -> Affinities<'static, f64> {
    let ds = gaussian_mixture::<f64>(300, 8, 3, 12.0, 31);
    let pool = ThreadPool::new(4);
    Affinities::fit(&pool, &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne()).expect("valid fit")
}

#[test]
fn run_until_early_exits_under_min_grad_norm_on_an_easy_mixture() {
    let aff = easy_fit();
    let mut c = cfg(0);
    c.update.exaggeration_iters = 100; // keep the calibration window cheap
    let budget = 700;

    // Reference: full budget, recording the grad-norm trajectory and KL.
    let plan = StagePlan::acc_tsne();
    let mut reference = TsneSession::new(&aff, plan, c).unwrap();
    let norms: Vec<f64> =
        (0..budget).map(|_| reference.step().expect("healthy step").grad_norm).collect();
    let kl_full = reference.finish().kl_divergence;

    // Threshold slightly above the smallest norm seen in the late window
    // [200, 650): the same-seed trajectory must cross it at that minimum's
    // iteration at the latest — strictly inside the budget.
    let window_min = norms[200..650].iter().cloned().fold(f64::INFINITY, f64::min);
    let conv = Convergence {
        max_iter: budget,
        min_grad_norm: window_min * (1.0 + 1e-9),
        n_iter_without_progress: 0,
    };
    let mut sess = TsneSession::new(&aff, plan, c).unwrap();
    let out = sess.run_until(conv);
    assert_eq!(out.reason, StopReason::GradNorm, "stopped by min_grad_norm");
    assert!(out.n_iter < budget, "early exit: {} !< {budget}", out.n_iter);
    assert!(out.n_iter > c.update.exaggeration_iters, "never stops during exaggeration");
    let r = sess.finish();
    assert_eq!(r.n_iter, out.n_iter, "result records the actual iteration count");
    // An easy mixture is essentially converged at the stopping point: the KL
    // must be no worse than the full-budget run (small tolerance for the
    // marginal tail-iteration polish the early exit skips).
    assert!(
        r.kl_divergence <= kl_full * 1.2 + 1e-9,
        "early-exit KL {} vs full-budget KL {}",
        r.kl_divergence,
        kl_full
    );
}

#[test]
fn run_until_no_progress_rule_fires_exactly_where_the_trajectory_says() {
    let aff = easy_fit();
    let mut c = cfg(0);
    c.update.exaggeration_iters = 80;
    let budget = 500;
    let window = 40;

    let plan = StagePlan::acc_tsne();
    let mut reference = TsneSession::new(&aff, plan, c).unwrap();
    let norms: Vec<f64> =
        (0..budget).map(|_| reference.step().expect("healthy step").grad_norm).collect();

    // Independent simulation of the documented rule: progress = beating the
    // best-seen norm by >0.1%, checked only after exaggeration.
    let mut best = f64::INFINITY;
    let mut since = 0usize;
    let mut predicted = budget;
    let mut predicted_reason = StopReason::MaxIter;
    for (i, &g) in norms.iter().enumerate() {
        if i + 1 <= c.update.exaggeration_iters {
            continue;
        }
        if g < best * (1.0 - 1e-3) {
            best = g;
            since = 0;
        } else {
            since += 1;
            if since >= window {
                predicted = i + 1;
                predicted_reason = StopReason::NoProgress;
                break;
            }
        }
    }

    let mut sess = TsneSession::new(&aff, plan, c).unwrap();
    let out = sess.run_until(Convergence {
        max_iter: budget,
        min_grad_norm: 0.0,
        n_iter_without_progress: window,
    });
    assert_eq!(out.n_iter, predicted);
    assert_eq!(out.reason, predicted_reason);
}

#[test]
fn compat_wrapper_matches_session_for_every_implementation() {
    // Bit-identical parity of the one-shot wrapper against fit + run for all
    // five presets (the per-step parity test lives in tsne::pipeline; this
    // one covers the preset matrix end to end).
    let ds = gaussian_mixture::<f64>(250, 8, 4, 6.0, 37);
    let c = cfg(15);
    let pool = ThreadPool::new(c.n_threads);
    for imp in Implementation::ALL {
        let wrapper = run_tsne(&ds.points, ds.n, ds.d, &c, imp);
        let plan = StagePlan::preset(imp);
        let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, c.perplexity, &plan)
            .expect("valid fit");
        let mut sess = TsneSession::new(&aff, plan, c).unwrap();
        sess.run(c.n_iter);
        let manual = sess.finish();
        assert_eq!(wrapper.embedding, manual.embedding, "{}", imp.name());
        assert_eq!(wrapper.kl_divergence, manual.kl_divergence, "{}", imp.name());
    }
}

#[test]
fn one_affinity_fit_supports_heterogeneous_descents() {
    // The fit-once/descend-many contract across *plans*, not just seeds:
    // the same Affinities instance drives the Z-order and original layouts
    // and both repulsive kernels, agreeing to FP noise over a short horizon.
    let aff = easy_fit();
    let c = cfg(10);
    let run_with = |plan: StagePlan| -> Vec<f64> {
        let mut sess = TsneSession::new(&aff, plan, c).unwrap();
        sess.run(c.n_iter);
        sess.finish().embedding
    };
    let base = run_with(StagePlan::acc_tsne());
    let variants = [
        StagePlan::acc_tsne().with_layout(acc_tsne::tsne::Layout::Original).unwrap(),
        StagePlan::acc_tsne()
            .with_repulsive(acc_tsne::gradient::repulsive::RepulsiveVariant::Scalar)
            .unwrap(),
    ];
    for plan in variants {
        let other = run_with(plan);
        for i in 0..base.len() {
            assert!(
                (base[i] - other[i]).abs() < 1e-6 * (1.0 + base[i].abs()),
                "idx {i} for {plan:?}"
            );
        }
    }
}
