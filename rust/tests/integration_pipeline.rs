//! Integration: full pipeline across implementations, datasets, precisions,
//! and thread counts — the cross-module behaviour the unit tests can't see.

use acc_tsne::common::timer::Step;
use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::data::synthetic::{gaussian_mixture, scrna_like};
use acc_tsne::data::pca::pca;
use acc_tsne::knn::KnnEngine;
use acc_tsne::metrics::neighbor_preservation;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};

fn cfg(n_iter: usize, threads: usize) -> TsneConfig {
    TsneConfig {
        perplexity: 10.0,
        n_iter,
        n_threads: threads,
        seed: 3,
        ..TsneConfig::default()
    }
}

#[test]
fn every_paper_dataset_analog_runs_end_to_end() {
    let pool = ThreadPool::new(4);
    for d in PaperDataset::ALL {
        let ds = d.generate::<f64>(0.002, 1, &pool);
        let r = run_tsne(&ds.points, ds.n, ds.d, &cfg(15, 4), Implementation::AccTsne);
        assert!(
            r.embedding.iter().all(|v| v.is_finite()),
            "{}: non-finite embedding",
            d.name()
        );
        assert!(r.kl_divergence.is_finite() && r.kl_divergence > 0.0, "{}", d.name());
    }
}

/// Fraction of embedding k-NN that share the query's class label — the
/// cluster-cohesion property Figures S1–S6 show visually.
fn knn_label_purity(embedding: &[f64], labels: &[u16], k: usize) -> f64 {
    let pool = ThreadPool::new(4);
    let n = labels.len();
    let nl = acc_tsne::knn::BruteForceKnn::default().search(&pool, embedding, n, 2, k);
    let mut same = 0usize;
    for i in 0..n {
        same += nl.neighbors(i).iter().filter(|&&j| labels[j as usize] == labels[i]).count();
    }
    same as f64 / (n * k) as f64
}

#[test]
fn acc_tsne_preserves_local_structure() {
    let ds = gaussian_mixture::<f64>(600, 10, 6, 10.0, 5);
    let r = run_tsne(&ds.points, ds.n, ds.d, &cfg(300, 0), Implementation::AccTsne);
    let pool = ThreadPool::new(4);
    // exact-identity neighbor preservation (weak signal at 300 iters)...
    let np = neighbor_preservation(&pool, &ds.points, ds.n, ds.d, &r.embedding, 10);
    assert!(np > 0.2, "neighbor preservation too low: {np}");
    // ...and the strong signal: embedding neighborhoods stay class-pure.
    let purity = knn_label_purity(&r.embedding, &ds.labels, 10);
    assert!(purity > 0.8, "kNN label purity too low: {purity}");
}

#[test]
fn thread_count_does_not_change_convergence_quality() {
    // Not bit-identical (fp reduction order differs per thread count via the
    // BH Z sum), but the converged KL must be equivalent.
    let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 6);
    let r1 = run_tsne(&ds.points, ds.n, ds.d, &cfg(150, 1), Implementation::AccTsne);
    let r8 = run_tsne(&ds.points, ds.n, ds.d, &cfg(150, 8), Implementation::AccTsne);
    let rel = (r1.kl_divergence - r8.kl_divergence).abs() / r1.kl_divergence;
    assert!(rel < 0.05, "1-thread KL {} vs 8-thread KL {}", r1.kl_divergence, r8.kl_divergence);
}

#[test]
fn step_times_are_recorded_for_all_pipeline_steps() {
    let ds = gaussian_mixture::<f64>(500, 8, 4, 6.0, 7);
    let r = run_tsne(&ds.points, ds.n, ds.d, &cfg(20, 4), Implementation::AccTsne);
    for step in [
        Step::Knn,
        Step::Bsp,
        Step::TreeBuild,
        Step::Summarize,
        Step::Attractive,
        Step::Repulsive,
        Step::Update,
    ] {
        assert!(
            r.step_times.get(step) > 0.0,
            "step {} recorded no time",
            step.name()
        );
    }
    // FIt-SNE flavor: no tree/summarize, repulsive carries the FFT work.
    let rf = run_tsne(&ds.points, ds.n, ds.d, &cfg(20, 4), Implementation::FitSne);
    assert_eq!(rf.step_times.get(Step::TreeBuild), 0.0);
    assert_eq!(rf.step_times.get(Step::Summarize), 0.0);
    assert!(rf.step_times.get(Step::Repulsive) > 0.0);
}

#[test]
fn scrna_pca_pipeline_composes() {
    // The mouse-brain preprocessing path: counts → PCA → t-SNE.
    let pool = ThreadPool::new(4);
    let raw = scrna_like::<f64>(800, 60, 8, 0.5, 9);
    let (pcs, eig) = pca(&pool, &raw.points, raw.n, 60, 20, 20, 1);
    assert!(eig[0] >= eig[1] && eig[1] >= eig[2], "eigenvalues must be sorted: {eig:?}");
    let r = run_tsne(&pcs, raw.n, 20, &cfg(250, 4), Implementation::AccTsne);
    assert!(r.kl_divergence.is_finite());
    // scRNA clusters overlap (dropout noise) — label purity is the robust
    // signal; exact kNN-identity preservation is weak on noisy count data.
    let purity = knn_label_purity(&r.embedding, &raw.labels, 10);
    assert!(purity > 0.5, "pipeline kNN label purity {purity}");
}

#[test]
fn same_seed_same_thread_count_is_deterministic() {
    let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 8);
    let a = run_tsne(&ds.points, ds.n, ds.d, &cfg(40, 4), Implementation::AccTsne);
    let b = run_tsne(&ds.points, ds.n, ds.d, &cfg(40, 4), Implementation::AccTsne);
    assert_eq!(a.embedding, b.embedding, "same seed+threads must be bit-identical");
}

#[test]
fn perplexity_and_theta_knobs_respected() {
    let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 10);
    let mut c = cfg(30, 4);
    c.perplexity = 5.0;
    c.theta = 0.2; // more exact
    let r_tight = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
    c.theta = 0.9; // more approximate
    let r_loose = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
    assert!(r_tight.kl_divergence.is_finite() && r_loose.kl_divergence.is_finite());
    // looser theta must not be slower (it prunes more)
    assert!(
        r_loose.step_times.get(Step::Repulsive) <= r_tight.step_times.get(Step::Repulsive) * 1.5
    );
}
