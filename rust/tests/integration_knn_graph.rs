//! Integration: the KNN-graph artifact's cross-process contracts.
//!
//! Three families of assertions, mirroring `integration_persist.rs`:
//!
//! 1. **Refit parity** — `Affinities::from_knn` on a saved + loaded
//!    `KnnGraph` is bit-identical to a fresh `Affinities::fit` at the same
//!    perplexity, plan, and thread count, for f32 and f64 (THE acceptance
//!    contract: one KNN run serves a whole perplexity sweep).
//! 2. **Hostility** — truncated files, flipped checksum bytes, wrong magic,
//!    future format versions, wrong scalar width, trailing garbage, and
//!    mismatched n/k/fingerprint metadata each return their matching typed
//!    `PersistError`/`FitError` without panicking.
//! 3. **Degenerate data** — duplicate-heavy datasets (all-zero KNN rows)
//!    flow through BSP into a finite, uniform `P`, never NaN.

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::knn::hnsw::HnswParams;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{
    Affinities, FitError, KnnEngineKind, KnnGraph, PersistError, Scalar, StagePlan, TsneConfig,
    TsneSession,
};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("acc_tsne_knn_itest_{}_{name}", std::process::id()));
    p
}

fn refit_round_trip_matches_fresh_fit<T: Scalar>(name: &str) {
    let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 21).cast::<T>();
    let pool = ThreadPool::new(4);
    let plan = StagePlan::acc_tsne();
    // Graph at the ⌊3u⌋ of the LARGEST sweep perplexity (u1 = 15 → k = 45).
    let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 15.0, &plan)
        .expect("valid build");
    let path = tmp(&format!("refit_{name}.bin"));
    graph.save(&path).unwrap();
    let loaded = KnnGraph::<T>::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.n(), graph.n());
    assert_eq!(loaded.k(), graph.k());
    assert_eq!(loaded.d(), graph.d());
    assert_eq!(loaded.engine(), graph.engine());
    assert_eq!(loaded.data_fingerprint(), graph.data_fingerprint());
    assert_eq!(loaded.neighbors().indices, graph.neighbors().indices);
    assert_eq!(loaded.neighbors().distances_sq, graph.neighbors().distances_sq);
    loaded.verify_source(&ds.points, ds.n, ds.d).expect("same data");
    // Every smaller perplexity re-fits from the loaded graph bit-identically
    // to a fresh full fit (KNN included) at that perplexity.
    for u2 in [5.0, 10.0, 15.0] {
        let refit = Affinities::from_knn(&pool, &loaded, u2, &plan).expect("u2 <= k/3");
        let fresh = Affinities::fit(&pool, &ds.points, ds.n, ds.d, u2, &plan).expect("fit");
        assert_eq!(refit.k(), fresh.k(), "{name} u2 = {u2}");
        assert_eq!(refit.perplexity(), fresh.perplexity());
        assert_eq!(refit.p().row_ptr, fresh.p().row_ptr, "{name} u2 = {u2}");
        assert_eq!(refit.p().col, fresh.p().col, "{name} u2 = {u2}");
        assert_eq!(refit.p().val, fresh.p().val, "{name} u2 = {u2}: P must be bit-identical");
    }
}

#[test]
fn refit_from_saved_graph_is_bit_identical_to_fresh_fit_f64() {
    refit_round_trip_matches_fresh_fit::<f64>("f64");
}

#[test]
fn refit_from_saved_graph_is_bit_identical_to_fresh_fit_f32() {
    refit_round_trip_matches_fresh_fit::<f32>("f32");
}

#[test]
fn refit_affinities_drive_bit_identical_sessions() {
    // End-to-end leg of the parity contract: a session over the re-fitted
    // affinities reproduces a session over the fresh fit exactly.
    let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 22);
    let pool = ThreadPool::new(4);
    let plan = StagePlan::acc_tsne();
    let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 12.0, &plan)
        .expect("valid build");
    let path = tmp("refit_session.bin");
    graph.save(&path).unwrap();
    let loaded = KnnGraph::<f64>::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let cfg = TsneConfig {
        perplexity: 8.0,
        n_iter: 30,
        n_threads: 0, // resolved identically on both sides (CI pins it)
        seed: 7,
        ..TsneConfig::default()
    };
    let run = |aff: &Affinities<'_, f64>| {
        let mut sess = TsneSession::new(aff, plan, cfg).unwrap();
        sess.run(cfg.n_iter);
        sess.finish()
    };
    let refit = Affinities::from_knn(&pool, &loaded, 8.0, &plan).expect("8 <= 12");
    let fresh = Affinities::fit(&pool, &ds.points, ds.n, ds.d, 8.0, &plan).expect("valid fit");
    let (a, b) = (run(&refit), run(&fresh));
    assert_eq!(a.embedding, b.embedding, "re-fit must be indistinguishable downstream");
    assert_eq!(a.kl_divergence, b.kl_divergence);
}

#[test]
fn duplicate_heavy_data_yields_finite_uniform_bsp_rows() {
    // 40 duplicates of one point: their KNN rows are all-zero distances, the
    // flattest possible Gaussian. P must come out finite (uniform over the
    // support before symmetrization), never NaN — and survive a descent.
    let mut ds = gaussian_mixture::<f64>(200, 6, 3, 10.0, 23);
    for i in 1..40 {
        for t in 0..ds.d {
            ds.points[i * ds.d + t] = ds.points[t];
        }
    }
    let pool = ThreadPool::new(4);
    let plan = StagePlan::acc_tsne();
    let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 10.0, &plan)
        .expect("valid build");
    // duplicates really do produce (numerically) all-zero rows
    assert!(graph.neighbors().dists(0).iter().all(|&v| v < 1e-18), "row 0 not all-zero");
    let aff = Affinities::from_knn(&pool, &graph, 10.0, &plan).expect("valid refit");
    assert!(aff.p().val.iter().all(|v| v.is_finite()), "P contains a non-finite value");
    assert!(aff.p().val.iter().all(|&v| v >= 0.0));
    let sum = aff.p().val.iter().sum::<f64>();
    assert!((sum - 1.0).abs() < 1e-9, "P must stay normalized, sum = {sum}");
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 20,
        n_threads: 4,
        seed: 7,
        ..TsneConfig::default()
    };
    let mut sess = TsneSession::new(&aff, plan, cfg).unwrap();
    sess.run(20);
    assert!(sess.finish().embedding.iter().all(|v| v.is_finite()));
    // Truncation through the tied (all-zero) rows: KBest's (distance, index)
    // total order makes the blocked engine prefix-stable even here, so a
    // re-fit at a SMALLER perplexity from the deep graph still matches a
    // fresh fit bit-for-bit.
    let refit = Affinities::from_knn(&pool, &graph, 5.0, &plan).expect("5 <= 10");
    let fresh = Affinities::fit(&pool, &ds.points, ds.n, ds.d, 5.0, &plan).expect("valid fit");
    assert_eq!(refit.p().row_ptr, fresh.p().row_ptr);
    assert_eq!(refit.p().col, fresh.p().col);
    assert_eq!(refit.p().val, fresh.p().val, "tied rows must truncate prefix-stably");
}

// ---------------------------------------------------------------------------
// Hostile inputs. Each writes a valid artifact, corrupts it in a specific
// way, and asserts the matching typed error — no panics, no garbage loads.
// ---------------------------------------------------------------------------

fn saved_graph_bytes() -> Vec<u8> {
    let ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 24);
    let pool = ThreadPool::new(4);
    let graph =
        KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne())
            .expect("valid build");
    let path = tmp("hostile_src.bin");
    graph.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

fn load_from_bytes(bytes: &[u8], name: &str) -> Result<KnnGraph<f64>, PersistError> {
    let path = tmp(name);
    std::fs::write(&path, bytes).unwrap();
    let r = KnnGraph::<f64>::load(&path);
    std::fs::remove_file(&path).ok();
    r
}

#[test]
fn knn_graph_truncated_file_is_a_typed_truncation_error() {
    let bytes = saved_graph_bytes();
    // inside the magic, inside the header, at the header boundary, inside
    // the payload, one byte short
    for cut in [3usize, 17, 28, bytes.len() / 2, bytes.len() - 1] {
        match load_from_bytes(&bytes[..cut], "hostile_trunc.bin") {
            Err(PersistError::Truncated) => {}
            other => panic!("cut at {cut}: expected Truncated, got {:?}", other.map(|_| ())),
        }
    }
    match load_from_bytes(&[], "hostile_empty.bin") {
        Err(PersistError::Truncated) => {}
        other => panic!("expected Truncated, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn knn_graph_flipped_byte_is_a_checksum_mismatch() {
    let bytes = saved_graph_bytes();
    // the stored checksum itself (header offset 20..28) ...
    let mut bad = bytes.clone();
    bad[20] ^= 0xFF;
    match load_from_bytes(&bad, "hostile_cksum.bin") {
        Err(PersistError::ChecksumMismatch { stored, computed }) => assert_ne!(stored, computed),
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|_| ())),
    }
    // ... and a payload byte in the distance array, far from any length
    // field, where only the checksum can catch the flip
    let mut bad = bytes.clone();
    let last = bad.len() - 3;
    bad[last] ^= 0x01;
    match load_from_bytes(&bad, "hostile_payload.bin") {
        Err(PersistError::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn knn_graph_wrong_magic_is_a_typed_error() {
    let mut bytes = saved_graph_bytes();
    bytes[..8].copy_from_slice(b"NOTMAGIC");
    match load_from_bytes(&bytes, "hostile_magic.bin") {
        Err(PersistError::BadMagic { found }) => assert_eq!(&found, b"NOTMAGIC"),
        other => panic!("expected BadMagic, got {:?}", other.map(|_| ())),
    }
    // an affinities artifact loaded as a KNN graph is also "wrong magic"
    let ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 25);
    let pool = ThreadPool::new(2);
    let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne())
        .expect("valid fit");
    let path = tmp("hostile_kind.bin");
    aff.save(&path).unwrap();
    match KnnGraph::<f64>::load(&path) {
        Err(PersistError::BadMagic { .. }) => {}
        other => panic!("expected BadMagic, got {:?}", other.map(|_| ())),
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn knn_graph_future_version_is_a_typed_error() {
    let mut bytes = saved_graph_bytes();
    // version field: u32 LE at offset 8
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    match load_from_bytes(&bytes, "hostile_version.bin") {
        Err(PersistError::UnsupportedVersion { found: 99, supported }) => {
            assert_eq!(supported, acc_tsne::tsne::persist::FORMAT_VERSION)
        }
        other => panic!("expected UnsupportedVersion, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn knn_graph_wrong_scalar_width_is_a_typed_error() {
    let bytes = saved_graph_bytes(); // f64 artifact
    let path = tmp("hostile_width.bin");
    std::fs::write(&path, &bytes).unwrap();
    match KnnGraph::<f32>::load(&path) {
        Err(PersistError::ScalarWidthMismatch { found: 8, expected: 4 }) => {}
        other => panic!("expected ScalarWidthMismatch, got {:?}", other.map(|_| ())),
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn knn_graph_trailing_garbage_is_a_typed_error() {
    let mut bytes = saved_graph_bytes();
    bytes.extend_from_slice(b"junk");
    match load_from_bytes(&bytes, "hostile_trailing.bin") {
        Err(PersistError::Corrupt(msg)) => assert!(msg.contains("trailing"), "{msg}"),
        other => panic!("expected Corrupt(trailing), got {:?}", other.map(|_| ())),
    }
}

#[test]
fn knn_graph_metadata_mismatches_are_typed_fit_errors() {
    let ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 26);
    let pool = ThreadPool::new(4);
    let plan = StagePlan::acc_tsne();
    let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 10.0, &plan)
        .expect("valid build");
    // wrong n
    match graph.verify_source(&ds.points[..100 * ds.d], 100, ds.d) {
        Err(FitError::GraphMismatch(msg)) => assert!(msg.contains("n = 100"), "{msg}"),
        other => panic!("expected GraphMismatch, got {other:?}"),
    }
    // wrong d
    match graph.verify_source(&ds.points, ds.n, ds.d + 1) {
        Err(FitError::GraphMismatch(_)) => {}
        other => panic!("expected GraphMismatch, got {other:?}"),
    }
    // same shape, different data → fingerprint
    let other_ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 27);
    match graph.verify_source(&other_ds.points, other_ds.n, other_ds.d) {
        Err(FitError::GraphMismatch(msg)) => assert!(msg.contains("fingerprint"), "{msg}"),
        other => panic!("expected GraphMismatch, got {other:?}"),
    }
    // a perplexity the stored k cannot support (k = 30, needs ⌊3·20⌋ = 60)
    match Affinities::from_knn(&pool, &graph, 20.0, &plan) {
        Err(FitError::GraphTooShallow { needed: 60, k: 30, .. }) => {}
        other => panic!("expected GraphTooShallow, got {:?}", other.map(|_| ())),
    }
    // out-of-range perplexities never reach a panic either
    for bad in [f64::NAN, 0.0, -1.0, f64::INFINITY] {
        match Affinities::from_knn(&pool, &graph, bad, &plan) {
            Err(FitError::InvalidPerplexity { .. }) => {}
            other => panic!("perplexity {bad}: got {:?}", other.map(|_| ())),
        }
    }
}

// ---------------------------------------------------------------------------
// Approximate (HNSW) graphs through the same artifact machinery. The graph is
// a different engine but the SAME artifact type — everything below must hold
// with zero persistence-layer changes.
// ---------------------------------------------------------------------------

#[test]
fn hnsw_graph_round_trips_byte_identically_with_metadata() {
    let ds = gaussian_mixture::<f64>(220, 7, 4, 8.0, 31);
    let pool = ThreadPool::new(4);
    let graph =
        KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 20, &HnswParams::default())
            .expect("valid build");
    assert!(graph.engine().starts_with("hnsw(m="), "params in metadata: {}", graph.engine());
    assert!(graph.is_approximate());
    let p1 = tmp("hnsw_rt1.bin");
    let p2 = tmp("hnsw_rt2.bin");
    graph.save(&p1).unwrap();
    let loaded = KnnGraph::<f64>::load(&p1).unwrap();
    loaded.save(&p2).unwrap();
    let (b1, b2) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(b1, b2, "save → load → save must be byte-identical");
    assert_eq!(loaded.engine(), graph.engine(), "HNSW params survive the round trip");
    assert!(loaded.is_approximate());
    assert_eq!(loaded.neighbors().indices, graph.neighbors().indices);
    assert_eq!(loaded.neighbors().distances_sq, graph.neighbors().distances_sq);
    loaded.verify_source(&ds.points, ds.n, ds.d).expect("same data");
}

#[test]
fn hnsw_refit_from_loaded_graph_matches_in_memory_refit() {
    // The BSP-only sweep contract on an approximate graph: re-fits from the
    // persisted artifact are bit-identical to re-fits from the in-memory
    // build, at every perplexity the stored k supports. (Unlike the exact
    // engine there is no fresh-full-fit parity here — prefix stability is
    // per-build by design, so the loaded graph IS the reference.)
    let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 32);
    let pool = ThreadPool::new(4);
    let plan = StagePlan::acc_tsne();
    let graph =
        KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 45, &HnswParams::default())
            .expect("valid build");
    let path = tmp("hnsw_refit.bin");
    graph.save(&path).unwrap();
    let loaded = KnnGraph::<f64>::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for u in [5.0, 10.0, 15.0] {
        let a = Affinities::from_knn(&pool, &loaded, u, &plan).expect("3u <= k");
        let b = Affinities::from_knn(&pool, &graph, u, &plan).expect("3u <= k");
        assert_eq!(a.p().row_ptr, b.p().row_ptr, "u = {u}");
        assert_eq!(a.p().col, b.p().col, "u = {u}");
        assert_eq!(a.p().val, b.p().val, "u = {u}: P must be bit-identical");
    }
    // ⌊3u⌋ > k is still the typed depth error, approximate or not.
    match Affinities::from_knn(&pool, &loaded, 20.0, &plan) {
        Err(FitError::GraphTooShallow { needed: 60, k: 45, .. }) => {}
        other => panic!("expected GraphTooShallow, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn hnsw_engine_family_mismatch_is_a_typed_fit_error() {
    let ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 33);
    let pool = ThreadPool::new(2);
    let plan = StagePlan::acc_tsne();
    let exact = KnnGraph::build(&pool, &ds.points, ds.n, ds.d, 15, &plan).expect("valid build");
    let approx =
        KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 15, &HnswParams::default())
            .expect("valid build");
    exact.require_engine(KnnEngineKind::Exact).expect("exact graph serves exact requests");
    approx.require_engine(KnnEngineKind::Hnsw).expect("hnsw graph serves hnsw requests");
    match exact.require_engine(KnnEngineKind::Hnsw) {
        Err(FitError::GraphEngineMismatch { expected, found }) => {
            assert_eq!(expected, "approximate (hnsw)");
            assert!(!found.starts_with("hnsw"), "{found}");
        }
        other => panic!("expected GraphEngineMismatch, got {other:?}"),
    }
    match approx.require_engine(KnnEngineKind::Exact) {
        Err(FitError::GraphEngineMismatch { expected, found }) => {
            assert_eq!(expected, "exact");
            assert!(found.starts_with("hnsw"), "{found}");
        }
        other => panic!("expected GraphEngineMismatch, got {other:?}"),
    }
}

#[test]
fn hnsw_coincident_clouds_build_valid_thread_invariant_graphs() {
    // Duplicate-heavy data through build_approximate: 50 of 180 points
    // coincide exactly. The graph must stay valid (persistable), identical
    // at 1/4/8 threads, and its all-zero rows must flow through BSP finitely.
    let mut ds = gaussian_mixture::<f64>(180, 6, 3, 10.0, 34);
    for i in 1..50 {
        for t in 0..ds.d {
            ds.points[i * ds.d + t] = ds.points[t];
        }
    }
    let build = |nt: usize| {
        KnnGraph::build_approximate(
            &ThreadPool::new(nt),
            &ds.points,
            ds.n,
            ds.d,
            12,
            &HnswParams::default(),
        )
        .expect("valid build")
    };
    let g1 = build(1);
    for nt in [4usize, 8] {
        let g = build(nt);
        assert_eq!(g.neighbors().indices, g1.neighbors().indices, "{nt} threads");
        assert_eq!(g.neighbors().distances_sq, g1.neighbors().distances_sq, "{nt} threads");
        assert_eq!(g.engine(), g1.engine());
    }
    assert!(g1.neighbors().dists(0).iter().all(|&v| v < 1e-18), "row 0 not all-zero");
    // The coincident rows survive persistence validation and a BSP fit.
    let path = tmp("hnsw_coincident.bin");
    g1.save(&path).expect("degenerate rows are still a valid artifact");
    let loaded = KnnGraph::<f64>::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let aff = Affinities::from_knn(&ThreadPool::new(4), &loaded, 4.0, &StagePlan::acc_tsne())
        .expect("valid refit");
    assert!(aff.p().val.iter().all(|v| v.is_finite()), "P contains a non-finite value");
}

#[test]
fn hnsw_artifact_is_checksum_guarded_like_any_other() {
    // One hostile-input spot check on the approximate artifact: a flipped
    // payload byte is a checksum mismatch, not a silently-wrong graph.
    let ds = gaussian_mixture::<f64>(150, 6, 3, 8.0, 35);
    let pool = ThreadPool::new(2);
    let graph =
        KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 10, &HnswParams::default())
            .expect("valid build");
    let path = tmp("hnsw_hostile.bin");
    graph.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let last = bytes.len() - 3;
    bytes[last] ^= 0x01;
    match load_from_bytes(&bytes, "hnsw_hostile_flip.bin") {
        Err(PersistError::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|_| ())),
    }
}
