//! Integration: the persistence layer's cross-process contracts.
//!
//! Two families of assertions:
//!
//! 1. **Fidelity** — `Affinities` save → load → save is byte-identical, a
//!    loaded artifact feeds sessions bit-identical to the in-memory fit, and
//!    a session checkpointed to disk at iteration k and resumed runs on
//!    bit-identical to an uninterrupted run (fixed thread count), under both
//!    `--layout original` and `--layout zorder`.
//! 2. **Hostility** — truncated files, flipped checksum bytes, wrong magic,
//!    future format versions, wrong scalar width, and trailing garbage each
//!    return their matching typed `PersistError` without panicking.

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{
    Affinities, Layout, PersistError, SessionCheckpoint, StagePlan, TsneConfig, TsneSession,
};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("acc_tsne_itest_{}_{name}", std::process::id()));
    p
}

fn cfg(n_iter: usize) -> TsneConfig {
    TsneConfig {
        perplexity: 10.0,
        n_iter,
        // 0 ⇒ available_cores(), which honors ACC_TSNE_NUM_THREADS /
        // RAYON_NUM_THREADS — CI's thread-count matrix pins these tests to
        // 1/4/8 threads. Every bit-identity comparison below is
        // within-process, so the resolved count is the same on both sides.
        n_threads: 0,
        seed: 7,
        ..TsneConfig::default()
    }
}

fn fit(n: usize, seed: u64) -> Affinities<'static, f64> {
    let ds = gaussian_mixture::<f64>(n, 8, 4, 8.0, seed);
    let pool = ThreadPool::new(4);
    Affinities::fit(&pool, &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne()).expect("valid fit")
}

#[test]
fn persist_affinities_save_load_save_is_byte_identical() {
    let aff = fit(300, 1);
    let p1 = tmp("aff_a.bin");
    let p2 = tmp("aff_b.bin");
    aff.save(&p1).unwrap();
    let loaded = Affinities::<f64>::load(&p1).unwrap();
    assert_eq!(loaded.n(), aff.n());
    assert_eq!(loaded.perplexity(), aff.perplexity());
    assert_eq!(loaded.k(), aff.k());
    assert_eq!(loaded.p().row_ptr, aff.p().row_ptr);
    assert_eq!(loaded.p().col, aff.p().col);
    assert_eq!(loaded.p().val, aff.p().val);
    loaded.save(&p2).unwrap();
    let (a, b) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    assert_eq!(a, b, "save → load → save must be byte-identical");
    std::fs::remove_file(p1).ok();
    std::fs::remove_file(p2).ok();
}

#[test]
fn persist_loaded_affinities_feed_bit_identical_sessions() {
    let aff = fit(300, 2);
    let path = tmp("aff_session.bin");
    aff.save(&path).unwrap();
    let loaded = Affinities::<f64>::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let c = cfg(30);
    let run = |a: &Affinities<'_, f64>| {
        let mut sess = TsneSession::new(a, StagePlan::acc_tsne(), c).unwrap();
        sess.run(c.n_iter);
        sess.finish()
    };
    let (mem, disk) = (run(&aff), run(&loaded));
    assert_eq!(mem.embedding, disk.embedding, "loaded fit must be indistinguishable");
    assert_eq!(mem.kl_divergence, disk.kl_divergence);
}

#[test]
fn persist_checkpoint_resume_is_bit_identical_across_layouts() {
    // THE acceptance contract: checkpoint at k, restart from the file, run to
    // n == an uninterrupted n-iteration run, exactly, at a fixed thread
    // count, for both layouts.
    let aff = fit(300, 3);
    for layout in [Layout::Original, Layout::Zorder] {
        let plan = StagePlan::acc_tsne().with_layout(layout).unwrap();
        let c = cfg(0);
        let mut uninterrupted = TsneSession::new(&aff, plan, c).unwrap();
        uninterrupted.run(50);
        let want = uninterrupted.finish();

        let path = tmp(&format!("ckpt_{}.bin", layout.name()));
        let mut first = TsneSession::new(&aff, plan, c).unwrap();
        first.run(20);
        first.checkpoint(&path).unwrap();
        drop(first); // the "restart": only the file carries the state

        let mut resumed = TsneSession::restore(&aff, plan, c, &path).unwrap();
        assert_eq!(resumed.iterations(), 20);
        resumed.run(30);
        let got = resumed.finish();
        std::fs::remove_file(&path).ok();
        assert_eq!(got.embedding, want.embedding, "layout {layout}");
        assert_eq!(got.kl_divergence, want.kl_divergence, "layout {layout}");
        assert_eq!(got.n_iter, want.n_iter);
    }
}

#[test]
fn persist_checkpoint_mid_run_does_not_perturb_the_trajectory() {
    let aff = fit(250, 4);
    let c = cfg(0);
    let plan = StagePlan::acc_tsne();
    let mut plain = TsneSession::new(&aff, plan, c).unwrap();
    plain.run(30);
    let want = plain.finish();

    let path = tmp("ckpt_noperturb.bin");
    let mut observed = TsneSession::new(&aff, plan, c).unwrap();
    for _ in 0..6 {
        observed.run(5);
        observed.checkpoint(&path).unwrap();
    }
    std::fs::remove_file(&path).ok();
    let got = observed.finish();
    assert_eq!(got.embedding, want.embedding);
}

#[test]
fn persist_checkpoint_file_round_trips_through_disk_exactly() {
    let aff = fit(250, 5);
    let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg(0)).unwrap();
    sess.run(25);
    let ck = sess.to_checkpoint();
    let path = tmp("ckpt_rt.bin");
    ck.save(&path).unwrap();
    let back = SessionCheckpoint::<f64>::load(&path).unwrap();
    assert_eq!(back, ck, "disk round trip preserves every field bit-for-bit");
    // save → load → save byte identity for checkpoints too
    let path2 = tmp("ckpt_rt2.bin");
    back.save(&path2).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
    std::fs::remove_file(path).ok();
    std::fs::remove_file(path2).ok();
}

// ---------------------------------------------------------------------------
// Hostile inputs. Each writes a valid artifact, corrupts it in a specific
// way, and asserts the matching typed error — no panics, no garbage loads.
// ---------------------------------------------------------------------------

fn saved_affinities_bytes() -> Vec<u8> {
    let aff = fit(200, 6);
    let path = tmp("hostile_src.bin");
    aff.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

fn load_from_bytes(bytes: &[u8], name: &str) -> Result<Affinities<'static, f64>, PersistError> {
    let path = tmp(name);
    std::fs::write(&path, bytes).unwrap();
    let r = Affinities::<f64>::load(&path);
    std::fs::remove_file(&path).ok();
    r
}

#[test]
fn persist_truncated_file_is_a_typed_truncation_error() {
    let bytes = saved_affinities_bytes();
    // every kind of cut: inside the magic, inside the header, at the header
    // boundary, and inside the payload
    for cut in [3usize, 17, 28, bytes.len() / 2, bytes.len() - 1] {
        match load_from_bytes(&bytes[..cut], "hostile_trunc.bin") {
            Err(PersistError::Truncated) => {}
            other => panic!("cut at {cut}: expected Truncated, got {:?}", other.map(|_| ())),
        }
    }
    // the empty file too
    match load_from_bytes(&[], "hostile_empty.bin") {
        Err(PersistError::Truncated) => {}
        other => panic!("expected Truncated, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn persist_flipped_checksum_byte_is_a_checksum_mismatch() {
    let bytes = saved_affinities_bytes();
    // flip a byte of the stored checksum itself (header offset 20..28) ...
    let mut bad = bytes.clone();
    bad[20] ^= 0xFF;
    match load_from_bytes(&bad, "hostile_cksum.bin") {
        Err(PersistError::ChecksumMismatch { stored, computed }) => {
            assert_ne!(stored, computed)
        }
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|_| ())),
    }
    // ... and a byte of the payload (the checksum's other side). The flipped
    // byte sits in the val array, far from any length field, so the payload
    // still parses shape-wise and only the checksum can catch it.
    let mut bad = bytes.clone();
    let last = bad.len() - 3;
    bad[last] ^= 0x01;
    match load_from_bytes(&bad, "hostile_payload.bin") {
        Err(PersistError::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn persist_wrong_magic_is_a_typed_error() {
    let mut bytes = saved_affinities_bytes();
    bytes[..8].copy_from_slice(b"NOTMAGIC");
    match load_from_bytes(&bytes, "hostile_magic.bin") {
        Err(PersistError::BadMagic { found }) => assert_eq!(&found, b"NOTMAGIC"),
        other => panic!("expected BadMagic, got {:?}", other.map(|_| ())),
    }
    // a checkpoint file loaded as affinities is also "wrong magic"
    let aff = fit(200, 7);
    let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg(0)).unwrap();
    sess.run(2);
    let path = tmp("hostile_kind.bin");
    sess.checkpoint(&path).unwrap();
    match Affinities::<f64>::load(&path) {
        Err(PersistError::BadMagic { .. }) => {}
        other => panic!("expected BadMagic, got {:?}", other.map(|_| ())),
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn persist_future_version_is_a_typed_error() {
    let mut bytes = saved_affinities_bytes();
    // version field: u32 LE at offset 8
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    match load_from_bytes(&bytes, "hostile_version.bin") {
        Err(PersistError::UnsupportedVersion { found: 99, supported }) => {
            assert_eq!(supported, acc_tsne::tsne::persist::FORMAT_VERSION)
        }
        other => panic!("expected UnsupportedVersion, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn persist_wrong_scalar_width_is_a_typed_error() {
    let bytes = saved_affinities_bytes(); // f64 artifact
    let path = tmp("hostile_width.bin");
    std::fs::write(&path, &bytes).unwrap();
    match Affinities::<f32>::load(&path) {
        Err(PersistError::ScalarWidthMismatch { found: 8, expected: 4 }) => {}
        other => panic!("expected ScalarWidthMismatch, got {:?}", other.map(|_| ())),
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn persist_trailing_garbage_is_a_typed_error() {
    let mut bytes = saved_affinities_bytes();
    bytes.extend_from_slice(b"junk");
    match load_from_bytes(&bytes, "hostile_trailing.bin") {
        Err(PersistError::Corrupt(msg)) => assert!(msg.contains("trailing"), "{msg}"),
        other => panic!("expected Corrupt(trailing), got {:?}", other.map(|_| ())),
    }
}

#[test]
fn persist_restore_rejects_checkpoint_from_a_different_fit() {
    let aff = fit(300, 8);
    let aff_other = fit(200, 9);
    let c = cfg(0);
    let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), c).unwrap();
    sess.run(5);
    let path = tmp("ckpt_mismatch.bin");
    sess.checkpoint(&path).unwrap();
    match TsneSession::restore(&aff_other, StagePlan::acc_tsne(), c, &path) {
        Err(PersistError::Mismatch(msg)) => {
            assert!(msg.contains("300") && msg.contains("200"), "{msg}")
        }
        other => panic!("expected Mismatch, got {:?}", other.map(|_| ())),
    }
    // Same n, same P, but a different fit perplexity: the affinity
    // fingerprint (nnz + perplexity) must catch it.
    let aff_refit = Affinities::from_csr(aff.p().clone(), 12.0).expect("valid CSR");
    match TsneSession::restore(&aff_refit, StagePlan::acc_tsne(), c, &path) {
        Err(PersistError::Mismatch(msg)) => {
            assert!(msg.contains("different fit"), "{msg}")
        }
        other => panic!("expected fingerprint Mismatch, got {:?}", other.map(|_| ())),
    }
    std::fs::remove_file(path).ok();
}
