//! Integration: numerical-accuracy claims — BH gradient vs the exact O(N²)
//! oracle through whole gradient iterations, KL parity across implementations
//! (paper Table 3), and f32 vs f64 (Table S1).

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::gradient::attractive::{attractive_forces, Variant};
use acc_tsne::gradient::combine_gradient;
use acc_tsne::gradient::exact::{exact_gradient, exact_kl};
use acc_tsne::gradient::repulsive::repulsive_forces_scalar_into;
use acc_tsne::gradient::update::random_init;
use acc_tsne::knn::{BruteForceKnn, KnnEngine};
use acc_tsne::parallel::ThreadPool;
use acc_tsne::perplexity::{binary_search_perplexity, ParMode};
use acc_tsne::quadtree::builder_morton::build_morton;
use acc_tsne::quadtree::summarize::summarize_parallel;
use acc_tsne::sparse::{symmetrize, CsrMatrix};
use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};

fn sparse_p(n: usize, d: usize, seed: u64, pool: &ThreadPool) -> (CsrMatrix<f64>, Vec<f64>) {
    let ds = gaussian_mixture::<f64>(n, d, 5, 4.0, seed);
    let knn = BruteForceKnn::default().search(pool, &ds.points, n, d, 15);
    let cond = binary_search_perplexity(pool, &knn, 5.0, ParMode::Parallel);
    (symmetrize(pool, &knn, &cond.p), ds.points)
}

#[test]
fn bh_gradient_tracks_exact_gradient_through_descent() {
    let pool = ThreadPool::new(4);
    let n = 300;
    let (p, _) = sparse_p(n, 6, 1, &pool);
    let mut y = random_init::<f64>(n, 2);
    // Walk a few real descent steps, comparing BH vs exact gradient each time.
    let mut attr = vec![0.0; 2 * n];
    let mut grad = vec![0.0; 2 * n];
    let mut rep_raw = vec![0.0; 2 * n];
    for it in 0..5 {
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let z = repulsive_forces_scalar_into(&pool, &tree, 0.5, &mut rep_raw);
        attractive_forces(&pool, &p, &y, Variant::Simd, &mut attr);
        combine_gradient(&pool, &attr, &rep_raw, z, 1.0, &mut grad);
        let exact = exact_gradient(&pool, &p, &y);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..2 * n {
            num += (grad[i] - exact[i]) * (grad[i] - exact[i]);
            den += exact[i] * exact[i];
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "iter {it}: BH gradient relative RMS {rel}");
        // take an exact-gradient step to move somewhere new
        for i in 0..2 * n {
            y[i] -= 2.0 * exact[i];
        }
    }
}

#[test]
fn reported_kl_close_to_exact_kl() {
    // The pipeline reports KL with the BH-approximated Z; on small data we can
    // afford the exact Z and the two must agree closely (θ=0.5).
    let ds = gaussian_mixture::<f64>(350, 8, 4, 8.0, 3);
    let pool = ThreadPool::new(4);
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 200,
        n_threads: 4,
        ..TsneConfig::default()
    };
    let r = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
    let knn = BruteForceKnn::default().search(&pool, &ds.points, ds.n, ds.d, 30);
    let cond = binary_search_perplexity(&pool, &knn, 10.0, ParMode::Parallel);
    let p = symmetrize(&pool, &knn, &cond.p);
    let exact = exact_kl(&pool, &p, &r.embedding);
    let rel = (r.kl_divergence - exact).abs() / exact;
    // The pipeline reports with the Z of the *last gradient evaluation*
    // (computed before the final position update — sklearn's convention), so
    // a few percent of drift vs the exact post-update KL is expected.
    assert!(rel < 0.05, "reported {} vs exact {}", r.kl_divergence, exact);
}

#[test]
fn table3_parity_all_implementations_on_one_dataset() {
    let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 4);
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 250,
        n_threads: 4,
        ..TsneConfig::default()
    };
    let kls: Vec<(String, f64)> = Implementation::ALL
        .iter()
        .map(|&imp| {
            (
                imp.name().to_string(),
                run_tsne(&ds.points, ds.n, ds.d, &cfg, imp).kl_divergence,
            )
        })
        .collect();
    let min = kls.iter().map(|(_, k)| *k).fold(f64::INFINITY, f64::min);
    let max = kls.iter().map(|(_, k)| *k).fold(0.0, f64::max);
    assert!(
        max / min < 1.35,
        "implementations disagree on quality: {kls:?}"
    );
}

#[test]
fn f32_and_f64_converge_to_same_quality() {
    let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 5);
    let ds32 = ds.cast::<f32>();
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 200,
        n_threads: 4,
        ..TsneConfig::default()
    };
    let r64 = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
    let r32 = run_tsne(&ds32.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
    let rel = (r64.kl_divergence - r32.kl_divergence).abs() / r64.kl_divergence;
    assert!(rel < 0.1, "f64 {} vs f32 {}", r64.kl_divergence, r32.kl_divergence);
}
