//! Fault-injection harness for the persistence layer: drive each artifact
//! format through storage media that fail in controlled ways and prove two
//! guarantees at every fault point:
//!
//! 1. the previous good artifact at the target path always survives, byte
//!    for byte, and still loads;
//! 2. a torn staging file never loads — even if something promotes it over
//!    the artifact path, the loader rejects it with a typed `PersistError`,
//!    never a panic or a silently-wrong artifact.
//!
//! Faults injected, for all three formats (`Affinities`, `SessionCheckpoint`,
//! `KnnGraph`):
//! - a write error at EVERY write boundary of the save (each payload buffer
//!   flush and the header checksum patch);
//! - a short write (a prefix persists, then the error hits) at every
//!   boundary — the disk-full torn-file case;
//! - a rename failure, and a crash between staging and rename (cleanup never
//!   runs, the staging file is abandoned).
//!
//! The serving wire protocol gets the same treatment: a torn/short frame
//! write to a client fails with a typed error, the partial bytes never parse
//! back into a frame, and neither the live session the frame was drawn from
//! nor the cached `Affinities` artifact is perturbed.

use acc_tsne::data::io::Medium;
use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{
    Affinities, KnnGraph, PersistError, SessionCheckpoint, StagePlan, TsneConfig, TsneSession,
};
use std::cell::Cell;
use std::fs::File;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::rc::Rc;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("acc_tsne_fault_{}_{name}", std::process::id()));
    p
}

/// `<name>.tmp` sibling — mirrors the persist layer's staging-path rule.
fn staging(path: &Path) -> PathBuf {
    let mut name = path.file_name().expect("artifact path has a name").to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

#[derive(Clone, Copy, Debug, Default)]
struct Faults {
    /// Fail the k-th write syscall to the staging file (0-based).
    fail_write_at: Option<usize>,
    /// Bytes of the failing write that persist before the error fires — a
    /// short write followed by disk-full, the classic torn-file producer.
    short_by: usize,
    /// Fail the staging → final rename.
    fail_rename: bool,
    /// Simulate a crash at the fault: cleanup never runs, torn staging
    /// files are abandoned on disk.
    crash: bool,
}

/// A [`Medium`] over the real filesystem that injects the configured
/// [`Faults`]. Tests are single-threaded, so the write counter is a plain
/// `Rc<Cell>` shared with the handles it creates.
struct FaultMedium {
    faults: Faults,
    writes: Rc<Cell<usize>>,
}

impl FaultMedium {
    fn new(faults: Faults) -> FaultMedium {
        FaultMedium { faults, writes: Rc::new(Cell::new(0)) }
    }

    /// Write syscalls the staging file has seen (fault-free saves use this
    /// to count the boundaries the fault sweep must cover).
    fn writes_seen(&self) -> usize {
        self.writes.get()
    }
}

struct FaultFile {
    inner: File,
    faults: Faults,
    writes: Rc<Cell<usize>>,
}

impl Write for FaultFile {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let k = self.writes.get();
        self.writes.set(k + 1);
        if self.faults.fail_write_at == Some(k) {
            let keep = self.faults.short_by.min(buf.len());
            if keep > 0 {
                self.inner.write_all(&buf[..keep])?;
            }
            self.inner.flush()?;
            return Err(std::io::Error::new(std::io::ErrorKind::Other, "injected write fault"));
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

impl Seek for FaultFile {
    fn seek(&mut self, pos: SeekFrom) -> std::io::Result<u64> {
        self.inner.seek(pos)
    }
}

impl Medium for FaultMedium {
    type Writer = FaultFile;

    fn create(&self, path: &Path) -> std::io::Result<FaultFile> {
        Ok(FaultFile {
            inner: File::create(path)?,
            faults: self.faults,
            writes: Rc::clone(&self.writes),
        })
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        if self.faults.fail_rename {
            return Err(std::io::Error::new(std::io::ErrorKind::Other, "injected rename fault"));
        }
        std::fs::rename(from, to)
    }

    fn remove(&self, path: &Path) -> std::io::Result<()> {
        if self.faults.crash {
            return Ok(()); // the process died before cleanup could run
        }
        std::fs::remove_file(path)
    }
}

/// The generic proof, run per format. `save_a` writes the pre-existing good
/// artifact via the normal filesystem path; `save_b` writes a *different*
/// artifact through an injected medium; `load` opens whatever sits at the
/// path.
fn prove_fault_tolerance(
    name: &str,
    save_a: &dyn Fn(&Path),
    save_b: &dyn Fn(&FaultMedium, &Path) -> Result<(), PersistError>,
    load: &dyn Fn(&Path) -> Result<(), PersistError>,
) {
    let path = tmp(&format!("{name}_artifact.bin"));
    let stage = staging(&path);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&stage).ok();

    // Count the write boundaries of a fault-free save of B on a scratch
    // path, and keep its bytes so "the old artifact survived" cannot pass
    // vacuously (A and B must actually differ).
    let scratch = tmp(&format!("{name}_scratch.bin"));
    let counting = FaultMedium::new(Faults::default());
    save_b(&counting, &scratch).expect("fault-free save through the medium");
    let boundaries = counting.writes_seen();
    assert!(boundaries >= 2, "{name}: expected at least a payload flush and the checksum patch");
    let bytes_b = std::fs::read(&scratch).unwrap();
    std::fs::remove_file(&scratch).ok();

    save_a(&path);
    let bytes_a = std::fs::read(&path).unwrap();
    assert_ne!(bytes_a, bytes_b, "{name}: artifacts A and B must differ");
    load(&path).expect("artifact A loads before any fault");

    // A write error at every boundary × clean/short-write × cleanup/crash.
    // short_by = 7 tears mid-field everywhere (every field is ≥ 4 bytes) and
    // keeps even the final checksum patch (8 bytes) incomplete.
    for k in 0..boundaries {
        for short_by in [0usize, 7] {
            for crash in [false, true] {
                let medium = FaultMedium::new(Faults {
                    fail_write_at: Some(k),
                    short_by,
                    crash,
                    ..Faults::default()
                });
                let err = save_b(&medium, &path)
                    .expect_err("save through a failing medium must error");
                assert!(
                    matches!(err, PersistError::Io(_)),
                    "{name}: boundary {k}: expected Io, got {err:?}"
                );
                assert_eq!(
                    std::fs::read(&path).unwrap(),
                    bytes_a,
                    "{name}: boundary {k} short {short_by} crash {crash}: previous artifact torn"
                );
                load(&path).unwrap_or_else(|e| {
                    panic!("{name}: boundary {k}: previous artifact no longer loads: {e}")
                });
                if crash {
                    // The crash abandoned a torn staging file. Promote it
                    // over the artifact path — the worst case a non-atomic
                    // writer would allow — and prove it never loads.
                    let torn = std::fs::read(&stage)
                        .expect("crash leaves the torn staging file behind");
                    if torn != bytes_b {
                        std::fs::copy(&stage, &path).unwrap();
                        match load(&path) {
                            Err(
                                PersistError::Truncated
                                | PersistError::ChecksumMismatch { .. }
                                | PersistError::Corrupt(_),
                            ) => {}
                            Err(other) => panic!(
                                "{name}: boundary {k}: torn file gave unexpected error {other:?}"
                            ),
                            Ok(()) => {
                                panic!("{name}: boundary {k}: torn file loaded successfully")
                            }
                        }
                        std::fs::write(&path, &bytes_a).unwrap();
                    }
                    std::fs::remove_file(&stage).ok();
                } else {
                    assert!(
                        !stage.exists(),
                        "{name}: boundary {k}: failed save must clean up its staging file"
                    );
                }
            }
        }
    }

    // A rename failure (with cleanup) and a crash between the fully-written
    // staging file and the rename (no cleanup at all).
    for crash in [false, true] {
        let medium = FaultMedium::new(Faults { fail_rename: true, crash, ..Faults::default() });
        let err = save_b(&medium, &path).expect_err("rename fault must error");
        assert!(matches!(err, PersistError::Io(_)), "{name}: rename: expected Io, got {err:?}");
        assert_eq!(std::fs::read(&path).unwrap(), bytes_a, "{name}: rename fault tore the artifact");
        load(&path).expect("previous artifact still loads after rename fault");
        if crash {
            // The abandoned staging file is complete — but the artifact path
            // still serves A, which is the whole point of staging.
            assert_eq!(std::fs::read(&stage).unwrap(), bytes_b);
            std::fs::remove_file(&stage).ok();
        } else {
            assert!(!stage.exists(), "{name}: rename failure must clean up the staging file");
        }
    }

    std::fs::remove_file(&path).ok();
}

fn pool() -> ThreadPool {
    ThreadPool::new(2)
}

fn fit(n: usize, seed: u64) -> Affinities<'static, f64> {
    let ds = gaussian_mixture::<f64>(n, 8, 4, 8.0, seed);
    Affinities::fit(&pool(), &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne())
        .expect("valid fit")
}

#[test]
fn fault_injection_affinities_survive_and_torn_files_never_load() {
    let a = fit(160, 11);
    let b = fit(160, 22);
    prove_fault_tolerance(
        "affinities",
        &|path| a.save(path).unwrap(),
        &|medium, path| b.save_on(medium, path),
        &|path| Affinities::<f64>::load(path).map(|_| ()),
    );
}

#[test]
fn fault_injection_checkpoints_survive_and_torn_files_never_load() {
    let aff = fit(300, 33);
    let cfg = TsneConfig { perplexity: 10.0, n_threads: 2, seed: 7, ..TsneConfig::default() };
    let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
    sess.run(5);
    let ck_a = sess.to_checkpoint();
    sess.run(4);
    let ck_b = sess.to_checkpoint();
    prove_fault_tolerance(
        "checkpoint",
        &|path| ck_a.save(path).unwrap(),
        &|medium, path| ck_b.save_on(medium, path),
        &|path| SessionCheckpoint::<f64>::load(path).map(|_| ()),
    );
}

#[test]
fn fault_injection_knn_graphs_survive_and_torn_files_never_load() {
    let plan = StagePlan::acc_tsne();
    let ds_a = gaussian_mixture::<f64>(200, 8, 4, 8.0, 44);
    let ds_b = gaussian_mixture::<f64>(200, 8, 4, 8.0, 55);
    let p = pool();
    let a = KnnGraph::build(&p, &ds_a.points, ds_a.n, ds_a.d, 10, &plan).unwrap();
    let b = KnnGraph::build(&p, &ds_b.points, ds_b.n, ds_b.d, 10, &plan).unwrap();
    prove_fault_tolerance(
        "knn_graph",
        &|path| a.save(path).unwrap(),
        &|medium, path| b.save_on(medium, path),
        &|path| KnnGraph::<f64>::load(path).map(|_| ()),
    );
}

#[test]
fn fault_injection_hnsw_graphs_survive_and_torn_files_never_load() {
    // Same proof over the approximate artifact: the HNSW engine only changes
    // the rows and the (longer) engine-metadata string, and neither may
    // weaken the torn-file guarantees.
    use acc_tsne::knn::hnsw::HnswParams;
    let ds_a = gaussian_mixture::<f64>(200, 8, 4, 8.0, 66);
    let ds_b = gaussian_mixture::<f64>(200, 8, 4, 8.0, 77);
    let p = pool();
    let params = HnswParams::default();
    let a = KnnGraph::build_approximate(&p, &ds_a.points, ds_a.n, ds_a.d, 10, &params).unwrap();
    let b = KnnGraph::build_approximate(&p, &ds_b.points, ds_b.n, ds_b.d, 10, &params).unwrap();
    assert!(a.is_approximate() && b.is_approximate());
    prove_fault_tolerance(
        "hnsw_graph",
        &|path| a.save(path).unwrap(),
        &|medium, path| b.save_on(medium, path),
        &|path| KnnGraph::<f64>::load(path).map(|_| ()),
    );
}

/// The serving analog of the torn-file proof: fail the frame codec at every
/// write boundary of a snapshot frame (magic, head, payload, checksum), with
/// and without a short write, and prove that (1) the writer surfaces a plain
/// `io::Error`, (2) the torn byte prefix never parses back into a [`Frame`],
/// and (3) the session the snapshot was drawn from and the cached artifact
/// it descends are both untouched — the session finishes bit-identical to an
/// uninterrupted run and the cache still serves the same live allocation.
#[test]
fn fault_injection_torn_serve_frames_never_corrupt_sessions_or_cached_artifacts() {
    use acc_tsne::tsne::serve::{
        read_frame, write_frame, ArtifactCache, CacheKey, Frame, ServeError,
    };
    use std::sync::Arc;

    /// An in-memory stream that fails its `fail_at`-th write, keeping
    /// `short_by` bytes of it — the socket-side twin of [`FaultFile`].
    struct FailingSink {
        buf: Vec<u8>,
        writes: usize,
        fail_at: usize,
        short_by: usize,
    }

    impl Write for FailingSink {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            let k = self.writes;
            self.writes += 1;
            if k == self.fail_at {
                let keep = self.short_by.min(b.len());
                self.buf.extend_from_slice(&b[..keep]);
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "injected frame fault",
                ));
            }
            self.buf.extend_from_slice(b);
            Ok(b.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    let ds = gaussian_mixture::<f64>(160, 8, 4, 8.0, 88);
    let aff = Arc::new(
        Affinities::fit(&pool(), &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne()).unwrap(),
    );
    let cache = ArtifactCache::new(2);
    let key = CacheKey::for_points(&ds.points, ds.n, ds.d, 10.0);
    cache.insert(key, Arc::clone(&aff));
    let held = cache.lookup(&key).expect("cache hit");

    let cfg = TsneConfig { perplexity: 10.0, n_threads: 2, seed: 9, ..TsneConfig::default() };
    let n_iter = 20;
    let baseline = {
        let mut s = TsneSession::new(&held, StagePlan::acc_tsne(), cfg).unwrap();
        s.run(n_iter);
        s.finish().embedding
    };

    let mut sess = TsneSession::new(&held, StagePlan::acc_tsne(), cfg).unwrap();
    sess.run(n_iter / 2);
    let frame = Frame::Snapshot {
        iter: sess.iterations() as u64,
        kl: sess.kl(),
        grad_norm: sess.last_grad_norm(),
        embedding: sess.embedding(),
    };

    // A fault-free pass counts the write boundaries the sweep must cover.
    let mut clean = FailingSink { buf: Vec::new(), writes: 0, fail_at: usize::MAX, short_by: 0 };
    write_frame(&mut clean, &frame).expect("fault-free frame write");
    let boundaries = clean.writes;
    let full = clean.buf;
    assert!(boundaries >= 4, "magic + head + payload + checksum");

    for k in 0..boundaries {
        for short_by in [0usize, 3] {
            let mut sink = FailingSink { buf: Vec::new(), writes: 0, fail_at: k, short_by };
            let err = write_frame(&mut sink, &frame).expect_err("torn frame write must error");
            assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
            assert!(
                sink.buf.len() < full.len(),
                "boundary {k} short {short_by}: the torn stream must be a strict prefix"
            );
            match read_frame(&mut &sink.buf[..]) {
                Err(ServeError::Io(_) | ServeError::Protocol(_)) => {}
                Ok(f) => panic!("torn frame at boundary {k} short {short_by} parsed as {f:?}"),
                Err(other) => panic!("boundary {k}: unexpected error family: {other:?}"),
            }
        }
    }

    // The session the frames were drawn from never noticed: it lands exactly
    // where the uninterrupted baseline did.
    sess.run(n_iter - n_iter / 2);
    let finished = sess.finish().embedding;
    assert_eq!(finished.len(), baseline.len());
    for (i, (a, b)) in baseline.iter().zip(&finished).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "coordinate {i} diverged after torn frame writes");
    }
    // ... and the cache still serves the same live allocation.
    let again = cache.lookup(&key).expect("artifact still cached");
    assert!(Arc::ptr_eq(&again, &aff));
}
