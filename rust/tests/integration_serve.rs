//! End-to-end tests for the embedding-as-a-service daemon (`tsne::serve`)
//! over real loopback sockets.
//!
//! The serving contract under test:
//! - N concurrent clients stream progressive frames and every final frame is
//!   **bit-identical** to a direct in-process `TsneSession` at the same
//!   thread count (the determinism matrix runs this file under
//!   RAYON_NUM_THREADS ∈ {1, 4, 8});
//! - identical request bytes hit the artifact cache (one fit, N−1 hits) and
//!   concurrent lookups share one `Affinities` allocation;
//! - a mid-stream client disconnect tears down only that session — every
//!   other stream completes unperturbed, and the detached session resumes
//!   bit-identically;
//! - eviction never invalidates an artifact under an active session;
//! - hostile bytes on the wire come back as typed error frames, never a
//!   wedged server.

use std::net::TcpStream;
use std::sync::Arc;

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::data::Dataset;
use acc_tsne::parallel::pool::available_cores;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::serve::{
    self, read_frame, run_client, write_request, ArtifactCache, CacheKey, Frame, Request,
    ServeConfig, ServeError, WIRE_PROTOCOL,
};
use acc_tsne::tsne::{Affinities, StagePlan, TsneConfig, TsneSession};

const PERPLEXITY: f64 = 12.0;
const THETA: f64 = 0.5;

fn dataset(seed: u64) -> Dataset<f64> {
    gaussian_mixture::<f64>(256, 16, 4, 4.0, seed)
}

fn request(ds: &Dataset<f64>, n_iter: usize, every: usize, seed: u64) -> Request {
    Request {
        resume_id: 0,
        n: ds.n as u64,
        d: ds.d as u64,
        n_iter: n_iter as u64,
        snapshot_every: every as u64,
        seed,
        perplexity: PERPLEXITY,
        theta: THETA,
        points: ds.points.clone(),
    }
}

/// Ground truth: a direct in-process session at `nt` threads.
fn direct_embedding(
    ds: &Dataset<f64>,
    n_iter: usize,
    seed: u64,
    nt: usize,
) -> Vec<f64> {
    let pool = ThreadPool::new(nt);
    let plan = StagePlan::auto_for(ds.n);
    let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, PERPLEXITY, &plan).expect("fit");
    let cfg = TsneConfig {
        perplexity: PERPLEXITY,
        theta: THETA,
        n_iter,
        seed,
        n_threads: nt,
        ..TsneConfig::default()
    };
    let mut sess = TsneSession::new(&aff, plan, cfg).expect("session");
    sess.run(n_iter);
    sess.finish().embedding
}

fn assert_bits(want: &[f64], got: &[f64], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.to_bits(), g.to_bits(), "{what}: coordinate {i}: {g:e} vs {w:e}");
    }
}

/// The acceptance headline: ≥ 8 concurrent sessions over one shared pool,
/// progressive frames on every stream, every final frame bit-identical to a
/// direct session at the same thread count — including a disconnect→resume
/// leg. `run_smoke` *is* the CI smoke (`acc-tsne serve --smoke 8`); driving
/// it here keeps the contract under `cargo test` and the determinism matrix.
#[test]
fn serve_eight_concurrent_clients_bit_identical_to_direct_runs() {
    let report = serve::run_smoke(8, 0, 30, 17).expect("smoke must verify");
    assert_eq!(report.clients, 8);
    assert_eq!(report.n_threads, available_cores());
    assert_eq!(report.stats.cache_misses, 1, "same bytes ⇒ one fit");
    assert!(report.stats.cache_hits >= 8, "7 fleet hits + the resume leg's fresh request");
    assert_eq!(report.stats.sessions_detached, 1);
    assert_eq!(report.stats.sessions_resumed, 1);
    assert!(report.stats.sessions_completed >= 9, "8 clients + the resumed session");
    assert_eq!(report.stats.protocol_errors, 0);
    assert!(report.stats.steps >= 8 * 30);
    assert!(report.stats.step_p99_s >= report.stats.step_p50_s);
}

/// The regression test for the mid-stream-disconnect fix: victim B hangs up
/// while survivor A is mid-run; A must complete bit-identically (no pool
/// poisoning, no partial frame leaking into A's stream — its codec would
/// reject the bytes), and B resumes bit-identically later.
#[test]
fn serve_mid_stream_disconnect_tears_down_only_that_session() {
    let nt = available_cores();
    let n_iter = 60usize;
    let ds = dataset(5);
    let mut server = serve::start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        n_threads: nt,
        ..ServeConfig::default()
    })
    .expect("server");
    let addr = server.addr().to_string();

    // Survivor A: a full run with a snapshot every iteration — if B's
    // teardown leaked a partial frame into A's stream, A's checksummed
    // codec would fail loudly.
    let a_addr = addr.clone();
    let a_req = request(&ds, n_iter, 1, 1000);
    let a = std::thread::spawn(move || run_client(&a_addr, &a_req).expect("survivor client"));

    // Victim B: connect, read the Hello, hang up mid-run.
    let b_id = {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        write_request(&mut stream, &request(&ds, n_iter, 0, 2000)).expect("request");
        match read_frame(&mut stream).expect("hello") {
            Frame::Hello { session_id, .. } => session_id,
            other => panic!("expected Hello, got {other:?}"),
        }
    };

    let a_run = a.join().expect("survivor thread");
    assert_eq!(a_run.snapshots, n_iter - 1, "one frame per iteration, last rides in Final");
    let want_a = direct_embedding(&ds, n_iter, 1000, nt);
    assert_bits(&want_a, &a_run.embedding, "survivor");

    // B's session was parked, not poisoned: it resumes and lands exactly
    // where an uninterrupted run would.
    let resumed = serve::poll_resume(&addr, b_id, 500).expect("resume");
    let want_b = direct_embedding(&ds, n_iter, 2000, nt);
    assert_bits(&want_b, &resumed.embedding, "resumed victim");

    let stats = server.stats();
    assert_eq!(stats.sessions_detached, 1);
    assert_eq!(stats.sessions_resumed, 1);
    assert!(stats.sessions_completed >= 2, "survivor + resumed victim");
    server.shutdown();
}

/// Identical request bytes must fit once: the second client's Hello carries
/// `cache_hit` and, at the same seed, its trajectory is the same fit run
/// twice — bit-identical output is the strongest possible "same artifact"
/// check.
#[test]
fn serve_cache_hit_skips_the_fit_for_identical_bytes() {
    let ds = dataset(7);
    let mut server = serve::start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        n_threads: 2,
        ..ServeConfig::default()
    })
    .expect("server");
    let addr = server.addr().to_string();
    let req = request(&ds, 25, 5, 42);
    let first = run_client(&addr, &req).expect("first client");
    let second = run_client(&addr, &req).expect("second client");
    assert!(!first.cache_hit, "a fresh server has nothing cached");
    assert!(second.cache_hit, "identical bytes at the same perplexity must hit");
    assert_bits(&first.embedding, &second.embedding, "same fit, same seed");
    // A 1-ulp perturbation is a different fingerprint — it must miss.
    let mut tweaked = req.clone();
    tweaked.points[3] = tweaked.points[3].next_up();
    let third = run_client(&addr, &tweaked).expect("third client");
    assert!(!third.cache_hit, "different bytes must not reuse the artifact");
    let stats = server.stats();
    assert_eq!(stats.cache_misses, 2);
    assert_eq!(stats.cache_hits, 1);
    server.shutdown();
}

/// Concurrent lookups of one key return clones of one shared allocation —
/// N sessions over one fit, the crate's fit-once/descend-many contract
/// extended across threads.
#[test]
fn serve_concurrent_cache_lookups_share_one_artifact() {
    let ds = dataset(9);
    let pool = ThreadPool::new(2);
    let plan = StagePlan::acc_tsne();
    let aff = Arc::new(
        Affinities::fit(&pool, &ds.points, ds.n, ds.d, PERPLEXITY, &plan).expect("fit"),
    );
    let cache = Arc::new(ArtifactCache::new(4));
    let key = CacheKey::for_points(&ds.points, ds.n, ds.d, PERPLEXITY);
    cache.insert(key, Arc::clone(&aff));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || cache.lookup(&key).expect("hit"))
        })
        .collect();
    for h in handles {
        let got = h.join().expect("lookup thread");
        assert!(Arc::ptr_eq(&got, &aff), "every concurrent hit shares the same allocation");
    }
    assert_eq!(cache.hits(), 8);
    assert_eq!(cache.misses(), 0);
}

/// LRU eviction drops only the cache's own reference: a session actively
/// stepping on an evicted artifact keeps it alive and finishes bit-identical
/// to a session whose artifact was never evicted.
#[test]
fn serve_cache_eviction_never_drops_an_artifact_under_an_active_session() {
    let ds = dataset(13);
    let pool = ThreadPool::new(2);
    let plan = StagePlan::auto_for(ds.n);
    let cfg = TsneConfig {
        perplexity: PERPLEXITY,
        theta: THETA,
        n_iter: 20,
        seed: 3,
        n_threads: 2,
        ..TsneConfig::default()
    };
    let aff = Arc::new(
        Affinities::fit(&pool, &ds.points, ds.n, ds.d, PERPLEXITY, &plan).expect("fit"),
    );
    let baseline = {
        let mut sess = TsneSession::new(&aff, plan, cfg).expect("session");
        sess.run(20);
        sess.finish().embedding
    };

    let cache = ArtifactCache::new(1);
    let key = CacheKey::for_points(&ds.points, ds.n, ds.d, PERPLEXITY);
    cache.insert(key, Arc::clone(&aff));
    let held = cache.lookup(&key).expect("hit");
    let mut sess = TsneSession::new(&held, plan, cfg).expect("session over cached artifact");
    sess.run(10);
    // Capacity 1: inserting a different fit evicts the artifact mid-descent.
    let other = dataset(14);
    let other_aff = Arc::new(
        Affinities::fit(&pool, &other.points, other.n, other.d, PERPLEXITY, &plan).expect("fit"),
    );
    cache.insert(CacheKey::for_points(&other.points, other.n, other.d, PERPLEXITY), other_aff);
    assert!(cache.lookup(&key).is_none(), "the original entry is gone from the cache");
    // ... but the session never notices: its Arc keeps the artifact alive.
    sess.run(10);
    assert_bits(&baseline, &sess.finish().embedding, "evicted-under-session");
}

/// Hostile bytes come back as a typed error frame on the wire (the CLI
/// exit-code families), and the server keeps serving afterwards.
#[test]
fn serve_hostile_requests_get_typed_error_frames_and_the_server_survives() {
    let ds = dataset(21);
    let mut server = serve::start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        n_threads: 1,
        ..ServeConfig::default()
    })
    .expect("server");
    let addr = server.addr().to_string();

    // Unsupported protocol version (version field patched after encode).
    let mut buf = Vec::new();
    {
        let req = request(&ds, 5, 0, 1);
        serve::write_request(&mut buf, &req).expect("encode");
        buf[8] = 0xFF; // version LSB — also breaks the checksum; both are protocol errors
    }
    let mut stream = TcpStream::connect(&addr).expect("connect");
    use std::io::Write as _;
    stream.write_all(&buf).expect("send");
    match read_frame(&mut stream) {
        Ok(Frame::Error { code, .. }) => assert_eq!(code, WIRE_PROTOCOL),
        other => panic!("expected a protocol error frame, got {other:?}"),
    }
    drop(stream);

    // An empty-shape request is rejected by the size guards.
    let hostile = Request { n: 0, d: 0, ..request(&ds, 5, 0, 1) };
    match run_client(&addr, &hostile) {
        Err(ServeError::Remote { code, .. }) => assert_eq!(code, WIRE_PROTOCOL),
        other => panic!("expected a remote protocol error, got {other:?}"),
    }

    // The server is not wedged: a well-formed run still completes.
    let ok = run_client(&addr, &request(&ds, 10, 0, 1)).expect("server still serves");
    assert_eq!(ok.final_iter, 10);
    let stats = server.stats();
    assert!(stats.protocol_errors >= 2);
    server.shutdown();
}
