//! Regenerates paper Table S1: Acc-t-SNE in f32 vs f64 (time + KL) across
//! the six datasets, plus the f32 end-to-end sweep of the repulsive kernel
//! (scalar DFS vs SIMD-tiled at 16 lanes).

use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!("# Table S1 bench: scale={} iters={}", cfg.scale, cfg.n_iter);
    experiments::table_s1_precision(&cfg, &PaperDataset::ALL);
    experiments::table_s1_f32_repulsive_sweep(&cfg, &PaperDataset::ALL);
}
