//! Regenerates paper Table 4: single-thread end-to-end time of all five
//! implementations on the mouse-brain analog.

use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!("# Table 4 bench: scale={} iters={} (1 thread)", cfg.scale, cfg.n_iter);
    experiments::table4_single_thread(&cfg);
}
