//! Regenerates paper Figure 4: end-to-end time of all five implementations
//! across the six datasets on all cores, with speedups over sklearn-like.
//!
//! Scaled-down defaults; set ACC_TSNE_SCALE / ACC_TSNE_ITERS for larger runs.

use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!(
        "# Fig 4 bench: scale={} iters={} threads={}",
        cfg.scale,
        cfg.n_iter,
        cfg.resolved_threads()
    );
    experiments::fig4_end_to_end(&cfg, &PaperDataset::ALL);
}
