//! Regenerates paper Figure 1b: step-time profile of the daal4py-like
//! baseline on the mouse-brain analog (the "flat profile" motivating the
//! paper's accelerate-every-step strategy).

use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!("# Fig 1b bench: scale={} iters={}", cfg.scale, cfg.n_iter);
    experiments::fig1b_profile(&cfg);
}
