//! Regenerates paper Figure 6a/6b: per-step multicore scaling of the
//! daal4py-like baseline and Acc-t-SNE on the mouse-brain analog.

use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!(
        "# Fig 6 bench: scale={} iters={} cores={:?}",
        cfg.scale,
        cfg.n_iter,
        cfg.core_sweep()
    );
    experiments::fig6_step_scaling(&cfg);
}
