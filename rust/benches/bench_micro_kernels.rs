//! Ablation micro-benchmarks for the individual design choices of paper §3:
//!   morton encode: scalar vs SIMD;
//!   sort: std sort vs parallel radix;
//!   tree build: baseline level-wise vs morton;
//!   summarize: sequential vs parallel;
//!   attractive: scalar vs +prefetch vs +SIMD;
//!   repulsive: baseline-tree layout vs morton (Z-order) layout;
//!   repulsive: scalar vs SIMD-tiled (SoA traversal view, masked Eq. 9) —
//!     also snapshotted to BENCH_repulsive.json for the perf trajectory;
//!   BSP: sequential vs parallel;
//!   KNN graph: save/load + BSP-only perplexity re-fit vs a full fit
//!     (`knn_graph.*` keys of BENCH_gradient_loop.json — the serving cost of
//!     a perplexity sweep);
//!   gradient loop: original vs Z-order-persistent layout (per-step times
//!     from the pipeline itself) — snapshotted to BENCH_gradient_loop.json;
//!   guardrails: the finite-input scan at the fit boundary and the in-loop
//!     divergence guard's marginal cost (`guardrails.{validate,step_check}_s`
//!     keys of BENCH_gradient_loop.json);
//!   FIt-SNE engine: cold step (buffer growth + kernel FFTs) vs steady-state
//!     step on a persistent workspace, plus the BH↔FIt per-step crossover
//!     sweep that motivates `StagePlan::auto_for` — snapshotted to
//!     BENCH_fitsne.json (`fitsne.*` and `crossover.*` keys);
//!   KNN recall: HNSW build + ef_search sweep vs the exact brute-force
//!     engine, recall@k per beam width — snapshotted to BENCH_knn.json
//!     (`knn_recall.*` keys; recall values carry no `_s` suffix so the
//!     trend checker treats them as informational, not timings);
//!   serving: the `tsne::serve` daemon under N ∈ {1, 4, 8} concurrent
//!     clients over loopback TCP — fleet throughput, scheduler step-latency
//!     p50/p99, and the artifact-cache miss→hit Hello latency — snapshotted
//!     to BENCH_serving.json (`serving.*` keys; `sessions_per_s` is a rate,
//!     which the trend checker exempts from slower-is-worse warnings).

use acc_tsne::common::bench::Bencher;
use acc_tsne::common::rng::Rng;
use acc_tsne::common::timer::Step;
use acc_tsne::data::first_non_finite;
use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::fitsne::{fitsne_repulsive_into, FitsneParams, FitsneWorkspace};
use acc_tsne::gradient::attractive::{attractive_forces, Variant};
use acc_tsne::gradient::repulsive::{repulsive_forces_scalar_into, repulsive_forces_tiled_into};
use acc_tsne::knn::hnsw::{HnswIndex, HnswParams, DEFAULT_EF_SEARCH};
use acc_tsne::knn::{BruteForceKnn, KnnEngine};
use acc_tsne::parallel::sort::radix_sort_pairs;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::perplexity::{binary_search_perplexity, ParMode};
use acc_tsne::quadtree::builder_baseline::build_baseline;
use acc_tsne::quadtree::builder_morton::build_morton;
use acc_tsne::quadtree::morton::{encode_points, encode_points_simd, RootCell};
use acc_tsne::quadtree::summarize::{summarize_parallel, summarize_sequential};
use acc_tsne::quadtree::view::TraversalView;
use acc_tsne::sparse::{symmetrize, CsrMatrix};
use acc_tsne::tsne::serve::{run_client, start as serve_start, Request, ServeConfig};
use acc_tsne::tsne::{Affinities, KnnGraph, Layout, StagePlan, TsneConfig, TsneSession};

fn env_n() -> usize {
    std::env::var("ACC_TSNE_MICRO_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000)
}

fn env_loop_iters() -> usize {
    std::env::var("ACC_TSNE_LOOP_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60)
}

fn main() {
    let n = env_n();
    let pool = ThreadPool::with_all_cores();
    let mut rng = Rng::new(42);
    // Clustered embedding (realistic mid-optimization geometry).
    let mut pos = Vec::with_capacity(2 * n);
    for i in 0..n {
        let c = (i % 13) as f64;
        pos.push(c * 8.0 + rng.next_gaussian());
        pos.push((c * 3.0) % 11.0 + rng.next_gaussian());
    }
    println!("# micro bench: n={n}, threads={}", pool.n_threads());

    // --- morton encode
    let root = RootCell::bounding(&pool, &pos);
    let mut codes = vec![0u64; n];
    let mut b = Bencher::new("morton_encode").sampling(1, 20, 3.0);
    b.bench("scalar+mt", || encode_points(&pool, &pos, &root, &mut codes));
    b.bench("simd+mt", || encode_points_simd(&pool, &pos, &root, &mut codes));
    let seq_pool = ThreadPool::new(1);
    b.bench("scalar-1t", || encode_points(&seq_pool, &pos, &root, &mut codes));
    b.bench("simd-1t", || encode_points_simd(&seq_pool, &pos, &root, &mut codes));
    b.report();

    // --- sort
    encode_points_simd(&pool, &pos, &root, &mut codes);
    let mut b = Bencher::new("sort_morton_codes").sampling(1, 10, 3.0);
    b.bench("std_sort_unstable", || {
        let mut zipped: Vec<(u64, u32)> = codes.iter().copied().zip(0u32..).collect();
        zipped.sort_unstable_by_key(|&(k, _)| k);
        zipped.len()
    });
    b.bench("parallel_radix", || {
        let mut k = codes.clone();
        let mut p: Vec<u32> = (0..n as u32).collect();
        radix_sort_pairs(&pool, &mut k, &mut p);
        k.len()
    });
    b.report();

    // --- tree build
    let mut b = Bencher::new("tree_build").sampling(1, 10, 5.0);
    b.bench("baseline_levelwise_seq", || build_baseline(&pool, &pos).nodes.len());
    b.bench("morton_parallel", || build_morton(&pool, &pos).nodes.len());
    b.bench("morton_1thread", || build_morton(&seq_pool, &pos).nodes.len());
    b.report();

    // --- summarize
    let tree_m = build_morton(&pool, &pos);
    let mut b = Bencher::new("summarize").sampling(1, 10, 3.0);
    b.bench("sequential", || {
        let mut t = tree_m.clone();
        summarize_sequential(&mut t);
    });
    b.bench("parallel_subtrees", || {
        let mut t = tree_m.clone();
        summarize_parallel(&pool, &mut t);
    });
    b.report();

    // --- repulsive: layout ablation
    let mut tm = build_morton(&pool, &pos);
    summarize_parallel(&pool, &mut tm);
    let mut tb = build_baseline(&pool, &pos);
    summarize_sequential(&mut tb);
    let mut rep_out = vec![0.0f64; 2 * n];
    let mut b = Bencher::new("repulsive_layout").sampling(1, 8, 5.0);
    b.bench("baseline_tree_bfs_layout", || {
        repulsive_forces_scalar_into(&pool, &tb, 0.5, &mut rep_out)
    });
    b.bench("morton_tree_zorder_layout", || {
        repulsive_forces_scalar_into(&pool, &tm, 0.5, &mut rep_out)
    });
    b.report();

    // --- repulsive kernel: scalar DFS vs SIMD-tiled over the SoA view
    // (the paper's §3.5 headline kernel; snapshot goes to BENCH_repulsive.json
    // so later PRs have a perf trajectory).
    let mut view = TraversalView::new();
    view.rebuild_parallel(&pool, &tm);
    let mut b = Bencher::new("repulsive_kernel").sampling(1, 8, 8.0);
    let s_scalar = b.bench("scalar", || {
        repulsive_forces_scalar_into(&pool, &tm, 0.5, &mut rep_out)
    });
    let s_tiled = b.bench("simd_tiled", || {
        repulsive_forces_tiled_into(&pool, &tm, &view, 0.5, &mut rep_out)
    });
    let s_tiled_build = b.bench("simd_tiled+view_rebuild", || {
        view.rebuild_parallel(&pool, &tm);
        repulsive_forces_tiled_into(&pool, &tm, &view, 0.5, &mut rep_out)
    });
    b.bench("scalar-1t", || {
        repulsive_forces_scalar_into(&seq_pool, &tm, 0.5, &mut rep_out)
    });
    b.bench("simd_tiled-1t", || {
        repulsive_forces_tiled_into(&seq_pool, &tm, &view, 0.5, &mut rep_out)
    });
    b.report();
    let mut snapshot = String::from("{\n");
    snapshot.push_str("  \"bench\": \"repulsive_kernel\",\n");
    snapshot.push_str(&format!("  \"n\": {n},\n"));
    snapshot.push_str(&format!("  \"threads\": {},\n", pool.n_threads()));
    snapshot.push_str("  \"theta\": 0.5,\n");
    snapshot.push_str(&format!("  \"scalar_mean_s\": {:.6e},\n", s_scalar.mean));
    snapshot.push_str(&format!("  \"simd_tiled_mean_s\": {:.6e},\n", s_tiled.mean));
    snapshot.push_str(&format!(
        "  \"simd_tiled_with_view_rebuild_mean_s\": {:.6e},\n",
        s_tiled_build.mean
    ));
    snapshot.push_str(&format!(
        "  \"speedup_kernel\": {:.3},\n",
        s_scalar.mean / s_tiled.mean.max(1e-12)
    ));
    snapshot.push_str(&format!(
        "  \"speedup_incl_view\": {:.3}\n}}\n",
        s_scalar.mean / s_tiled_build.mean.max(1e-12)
    ));
    if let Err(e) = std::fs::write("BENCH_repulsive.json", &snapshot) {
        eprintln!("warning: could not write BENCH_repulsive.json: {e}");
    } else {
        println!("[json] BENCH_repulsive.json");
    }

    // --- attractive variants (needs a real sparse P)
    let an = n.min(50_000);
    let d = 10;
    let data: Vec<f64> = (0..an * d).map(|_| rng.next_gaussian()).collect();
    let knn = BruteForceKnn::default().search(&pool, &data, an, d, 90);
    let cond = binary_search_perplexity(&pool, &knn, 30.0, ParMode::Parallel);
    let p = symmetrize(&pool, &knn, &cond.p);
    let y: Vec<f64> = (0..2 * an).map(|_| rng.next_gaussian() * 10.0).collect();
    let mut out = vec![0.0f64; 2 * an];
    let mut b = Bencher::new(&format!("attractive (n={an}, k=90)")).sampling(1, 15, 4.0);
    b.bench("scalar", || attractive_forces(&pool, &p, &y, Variant::Scalar, &mut out));
    b.bench("prefetch", || attractive_forces(&pool, &p, &y, Variant::Prefetch, &mut out));
    b.bench("simd+prefetch", || attractive_forces(&pool, &p, &y, Variant::Simd, &mut out));
    b.bench("scalar-1t", || attractive_forces(&seq_pool, &p, &y, Variant::Scalar, &mut out));
    b.bench("prefetch-1t", || attractive_forces(&seq_pool, &p, &y, Variant::Prefetch, &mut out));
    b.bench("simd+prefetch-1t", || attractive_forces(&seq_pool, &p, &y, Variant::Simd, &mut out));
    b.report();

    // --- KNN graph persistence + perplexity re-fit (the multi-perplexity
    // serving path: KNN once, BSP per sweep point). fit_s is the full
    // KNN+BSP fit the artifact amortizes; refit_bsp_s is what each further
    // perplexity costs from a built/loaded graph.
    let knn_plan = StagePlan::acc_tsne();
    let mut b = Bencher::new(&format!("knn_refit (n={an}, d={d})")).sampling(1, 3, 10.0);
    let fit_s = b
        .bench("fit_full", || {
            Affinities::fit(&pool, &data, an, d, 30.0, &knn_plan).expect("valid fit").n()
        })
        .mean;
    let graph = KnnGraph::build_for_perplexity(&pool, &data, an, d, 30.0, &knn_plan)
        .expect("valid build");
    let graph_path =
        std::env::temp_dir().join(format!("acc_tsne_bench_knn_{}.bin", std::process::id()));
    let knn_save_s = b.bench("graph_save", || graph.save(&graph_path).expect("bench save")).mean;
    let knn_load_s = b
        .bench("graph_load", || KnnGraph::<f64>::load(&graph_path).expect("bench load").n())
        .mean;
    let refit_bsp_s = b
        .bench("refit_bsp", || {
            Affinities::from_knn(&pool, &graph, 10.0, &knn_plan).expect("valid refit").n()
        })
        .mean;
    b.report();
    std::fs::remove_file(&graph_path).ok();
    println!(
        "  one graph, sweep of m perplexities: fit {:.3}s once vs {:.3}s per re-fit \
         ({:.1}x per sweep point)",
        fit_s,
        refit_bsp_s,
        fit_s / refit_bsp_s.max(1e-12)
    );

    // --- θ ablation: BH speed/accuracy trade-off (paper Eq. 9's knob).
    let an2 = n.min(20_000);
    let y2: Vec<f64> = (0..2 * an2).map(|_| rng.next_gaussian() * 10.0).collect();
    let mut t2 = build_morton(&pool, &y2);
    summarize_parallel(&pool, &mut t2);
    let (exact_raw, _) = acc_tsne::gradient::exact::exact_repulsive(&pool, &y2);
    let mut rep2 = vec![0.0f64; 2 * an2];
    let mut b = Bencher::new(&format!("theta_ablation (n={an2})")).sampling(1, 8, 3.0);
    for theta in [0.2, 0.5, 0.8] {
        let s = b.bench(&format!("theta={theta}"), || {
            repulsive_forces_scalar_into(&pool, &t2, theta, &mut rep2)
        });
        repulsive_forces_scalar_into(&pool, &t2, theta, &mut rep2);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..2 * an2 {
            num += (rep2[i] - exact_raw[i]).powi(2);
            den += exact_raw[i] * exact_raw[i];
        }
        println!(
            "  theta={theta}: {:.3}ms, force rel-RMS error {:.2e}",
            s.mean * 1e3,
            (num / den).sqrt()
        );
    }
    b.report();

    // --- BSP
    let mut b = Bencher::new("bsp").sampling(1, 10, 3.0);
    b.bench("sequential", || {
        binary_search_perplexity(&pool, &knn, 30.0, ParMode::Sequential).betas.len()
    });
    b.bench("parallel", || {
        binary_search_perplexity(&pool, &knn, 30.0, ParMode::Parallel).betas.len()
    });
    b.report();

    // --- gradient loop: original vs Z-order-persistent layout. A synthetic
    // uniform-random sparse P (k=32) stands in for the KNN graph (building a
    // real one at bench scale would dwarf the loop being measured) and models
    // the early-phase neighbor scatter; as descent clusters P-neighbors the
    // Z-order layout's CSR re-index localizes the y-gathers. Per-step times
    // come from the pipeline's own StepTimes; the attractive + update sweeps
    // are the ones expected to win in Z-order at n >= 65k.
    let iters = env_loop_iters();
    let k = 32usize;
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col = Vec::with_capacity(n * k);
    row_ptr.push(0usize);
    let mut row_buf: Vec<u32> = Vec::with_capacity(k);
    for _ in 0..n {
        // Strictly-ascending unique columns per row (the CSR invariant
        // Affinities::from_csr debug-asserts; duplicates from the raw draw
        // are dropped, so rows hold up to k entries).
        row_buf.clear();
        for _ in 0..k {
            row_buf.push(rng.next_below(n) as u32);
        }
        row_buf.sort_unstable();
        row_buf.dedup();
        col.extend_from_slice(&row_buf);
        row_ptr.push(col.len());
    }
    let nnz = col.len();
    let p_loop = CsrMatrix::<f64> {
        n,
        row_ptr,
        col,
        val: vec![1.0 / nnz as f64; nnz],
    };
    debug_assert!(p_loop.validate().is_ok());
    let base_cfg = TsneConfig {
        n_iter: iters,
        seed: 42,
        n_threads: pool.n_threads(),
        ..TsneConfig::default()
    };
    // One Affinities instance drives the layout A/B *and* the adoption sweep
    // below — the session API's fit-once/descend-many contract, with no
    // per-run copy of P.
    let aff_loop = Affinities::from_csr(p_loop, 30.0).expect("valid synthetic CSR");

    // --- affinities persistence (the serving layer's cold-start path:
    // loading a cached fit instead of redoing KNN+BSP). Times a full
    // checksummed write + read-back of the nnz-heavy artifact.
    let persist_path =
        std::env::temp_dir().join(format!("acc_tsne_bench_aff_{}.bin", std::process::id()));
    let mut b = Bencher::new("affinities_persist").sampling(1, 5, 5.0);
    let save_s = b.bench("save", || aff_loop.save(&persist_path).expect("bench save")).mean;
    let load_s = b
        .bench("load", || Affinities::<f64>::load(&persist_path).expect("bench load").n())
        .mean;
    b.report();
    std::fs::remove_file(&persist_path).ok();
    let run_plan = |plan: StagePlan| {
        let mut sess = TsneSession::new(&aff_loop, plan, base_cfg).expect("valid plan");
        sess.run(iters);
        sess.finish()
    };
    let r_orig = run_plan(StagePlan::acc_tsne().with_layout(Layout::Original).expect("valid"));
    let r_z = run_plan(StagePlan::acc_tsne().with_layout(Layout::Zorder).expect("valid"));
    let steps = [
        (Step::TreeBuild, "tree_build"),
        (Step::Summarize, "summarize"),
        (Step::Attractive, "attractive"),
        (Step::Repulsive, "repulsive"),
        (Step::Update, "update"),
    ];
    println!("\n== gradient loop layout (n={n}, iters={iters}, k={k}) ==");
    println!("{:<12} {:>12} {:>12} {:>8}", "step", "original(s)", "zorder(s)", "speedup");
    for (step, name) in steps {
        let (a, b) = (r_orig.step_times.get(step), r_z.step_times.get(step));
        println!("{name:<12} {a:>12.4} {b:>12.4} {:>7.2}x", a / b.max(1e-12));
    }
    let (ta, tz) = (r_orig.step_times.gradient_total(), r_z.step_times.gradient_total());
    println!("{:<12} {ta:>12.4} {tz:>12.4} {:>7.2}x", "TOTAL", ta / tz.max(1e-12));

    // --- Z-order adoption-threshold sweep (closes the ROADMAP follow-up:
    // the 5% default was picked, not measured). Only the plan's
    // adopt_drift_pct varies — 0% re-adopts on any drift (max locality, max
    // re-index cost), 100% would never adopt at all.
    let adopt_pcts = [0usize, 2, 5, 10, 20];
    let mut adopt_results = Vec::new();
    for &pct in &adopt_pcts {
        let plan = StagePlan::acc_tsne().with_adopt_drift_pct(pct).expect("pct in range");
        if plan == StagePlan::acc_tsne() {
            // pct 5 is the preset default: plan-identical to the zorder A/B
            // run above, so reuse its measurement instead of re-running.
            adopt_results.push((pct, r_z.step_times.clone()));
            continue;
        }
        let mut sess = TsneSession::new(&aff_loop, plan, base_cfg).expect("valid plan");
        sess.run(iters);
        adopt_results.push((pct, sess.finish().step_times));
    }
    println!("\n== adoption-threshold sweep (n={n}, iters={iters}, zorder layout) ==");
    println!(
        "{:<10} {:>14} {:>14} {:>12} {:>12}",
        "adopt_pct", "tree+adopt(s)", "attractive(s)", "update(s)", "gradient(s)"
    );
    for (pct, st) in &adopt_results {
        println!(
            "{pct:<10} {:>14.4} {:>14.4} {:>12.4} {:>12.4}",
            st.get(Step::TreeBuild),
            st.get(Step::Attractive),
            st.get(Step::Update),
            st.gradient_total()
        );
    }

    // --- guardrails: the measurable cost of the robustness layer.
    // validate_s is the O(n·d) finite-input scan every fit/build pays at
    // the boundary; step_check_s is the marginal per-iteration cost of
    // divergence guarding at interval 1 (the worst case — the default
    // interval of 50 pays the last-good capture 50x less often), measured
    // as guarded-minus-unguarded short runs.
    let guard_iters = iters.min(20).max(1);
    let run_guarded = |every: usize| {
        let mut sess =
            TsneSession::new(&aff_loop, StagePlan::acc_tsne(), base_cfg).expect("valid plan");
        sess.set_guard_interval(every);
        sess.run(guard_iters);
        sess.finish().kl_divergence
    };
    let mut b = Bencher::new(&format!("guardrails (n={an}, d={d})")).sampling(1, 6, 4.0);
    let validate_s = b.bench("validate_scan", || first_non_finite(&data, d).is_none()).mean;
    let s_guard_off = b.bench("loop_guard_off", || run_guarded(0));
    let s_guard_on = b.bench("loop_guard_every_iter", || run_guarded(1));
    b.report();
    let step_check_s = ((s_guard_on.mean - s_guard_off.mean) / guard_iters as f64).max(0.0);

    let mut js = String::from("{\n  \"bench\": \"gradient_loop\",\n");
    js.push_str(&format!(
        "  \"n\": {n},\n  \"threads\": {},\n  \"iters\": {iters},\n",
        pool.n_threads()
    ));
    for (label, r) in [("original", &r_orig), ("zorder", &r_z)] {
        js.push_str(&format!("  \"{label}\": {{\n"));
        for (i, (step, name)) in steps.iter().enumerate() {
            let sep = if i + 1 < steps.len() { "," } else { "" };
            js.push_str(&format!("    \"{name}_s\": {:.6e}{sep}\n", r.step_times.get(*step)));
        }
        js.push_str("  },\n");
    }
    js.push_str("  \"adopt_sweep\": {\n");
    for (i, (pct, st)) in adopt_results.iter().enumerate() {
        let sep = if i + 1 < adopt_results.len() { "," } else { "" };
        js.push_str(&format!(
            "    \"pct{pct}\": {{ \"tree_build_s\": {:.6e}, \"attractive_s\": {:.6e}, \
             \"update_s\": {:.6e}, \"gradient_total_s\": {:.6e} }}{sep}\n",
            st.get(Step::TreeBuild),
            st.get(Step::Attractive),
            st.get(Step::Update),
            st.gradient_total()
        ));
    }
    js.push_str("  },\n");
    js.push_str(&format!(
        "  \"persist\": {{ \"save_s\": {save_s:.6e}, \"load_s\": {load_s:.6e} }},\n"
    ));
    js.push_str(&format!(
        "  \"knn_graph\": {{ \"fit_s\": {fit_s:.6e}, \"save_s\": {knn_save_s:.6e}, \
         \"load_s\": {knn_load_s:.6e}, \"refit_bsp_s\": {refit_bsp_s:.6e} }},\n"
    ));
    js.push_str(&format!(
        "  \"guardrails\": {{ \"validate_s\": {validate_s:.6e}, \
         \"step_check_s\": {step_check_s:.6e} }},\n"
    ));
    js.push_str(&format!(
        "  \"speedup_attractive\": {:.3},\n",
        r_orig.step_times.get(Step::Attractive) / r_z.step_times.get(Step::Attractive).max(1e-12)
    ));
    js.push_str(&format!(
        "  \"speedup_update\": {:.3},\n",
        r_orig.step_times.get(Step::Update) / r_z.step_times.get(Step::Update).max(1e-12)
    ));
    js.push_str(&format!("  \"speedup_gradient_total\": {:.3}\n}}\n", ta / tz.max(1e-12)));
    if let Err(e) = std::fs::write("BENCH_gradient_loop.json", &js) {
        eprintln!("warning: could not write BENCH_gradient_loop.json: {e}");
    } else {
        println!("[json] BENCH_gradient_loop.json");
    }

    // --- FIt-SNE engine: cold step (buffer growth + kernel grid FFTs) vs
    // steady state on a persistent workspace (allocation-free, cached
    // kernels). kernel_rebuilds over the steady samples must stay 0 — a
    // non-zero value means the span-lattice cache is thrashing.
    let fit_params = FitsneParams::default();
    let mut fit_raw = vec![0.0f64; 2 * n];
    let mut b = Bencher::new(&format!("fitsne (n={n})")).sampling(1, 8, 5.0);
    let s_cold = b.bench("cold_step", || {
        let mut ws = FitsneWorkspace::new();
        fitsne_repulsive_into(&pool, &pos, &fit_params, &mut ws, &mut fit_raw)
    });
    let mut fit_ws = FitsneWorkspace::new();
    fitsne_repulsive_into(&pool, &pos, &fit_params, &mut fit_ws, &mut fit_raw);
    let rebuilds_before = fit_ws.kernel_rebuilds();
    let s_steady = b.bench("steady_step", || {
        fitsne_repulsive_into(&pool, &pos, &fit_params, &mut fit_ws, &mut fit_raw)
    });
    let steady_rebuilds = fit_ws.kernel_rebuilds() - rebuilds_before;
    b.bench("steady_step-1t", || {
        fitsne_repulsive_into(&seq_pool, &pos, &fit_params, &mut fit_ws, &mut fit_raw)
    });
    b.report();

    // --- BH↔FIt crossover sweep: full BH repulsive step (tree build +
    // summarize + view rebuild + tiled kernel, all O(n log n)) vs the
    // steady-state FIt step (scatter/gather O(n), bounded-grid FFT). The
    // first size where FIt wins is the empirical basis for FFT_CROSSOVER_N.
    let sweep_sizes = [10_000usize, 25_000, 50_000, 100_000, 200_000];
    let mut sweep = Vec::new();
    for &sn in sweep_sizes.iter().filter(|&&sn| sn <= n) {
        let ys = &pos[..2 * sn];
        let mut raw_s = vec![0.0f64; 2 * sn];
        let mut bsw = Bencher::new(&format!("crossover (n={sn})")).sampling(1, 5, 4.0);
        let bh = bsw.bench("bh_step", || {
            let mut t = build_morton(&pool, ys);
            summarize_parallel(&pool, &mut t);
            let mut v = TraversalView::new();
            v.rebuild_parallel(&pool, &t);
            repulsive_forces_tiled_into(&pool, &t, &v, 0.5, &mut raw_s)
        });
        let mut ws_s = FitsneWorkspace::new();
        fitsne_repulsive_into(&pool, ys, &fit_params, &mut ws_s, &mut raw_s);
        let fit = bsw.bench("fit_step", || {
            fitsne_repulsive_into(&pool, ys, &fit_params, &mut ws_s, &mut raw_s)
        });
        bsw.report();
        sweep.push((sn, bh.mean, fit.mean));
    }
    // Smallest swept size where the steady FIt step already beats BH
    // (0 = FIt never won within this sweep's range).
    let estimate_n = sweep.iter().find(|&&(_, bh, fit)| fit < bh).map_or(0, |&(sn, _, _)| sn);
    println!("\n== BH↔FIt crossover (threads={}) ==", pool.n_threads());
    println!("{:<10} {:>12} {:>12}", "n", "bh_step(s)", "fit_step(s)");
    for (sn, bh, fit) in &sweep {
        println!("{sn:<10} {bh:>12.5} {fit:>12.5}");
    }
    println!("crossover estimate: n={estimate_n}");

    let mut fj = String::from("{\n  \"bench\": \"fitsne\",\n");
    fj.push_str(&format!("  \"n\": {n},\n  \"threads\": {},\n", pool.n_threads()));
    fj.push_str("  \"fitsne\": {\n");
    fj.push_str(&format!("    \"cold_step_s\": {:.6e},\n", s_cold.mean));
    fj.push_str(&format!("    \"step_s\": {:.6e},\n", s_steady.mean));
    fj.push_str(&format!("    \"kernel_rebuilds\": {steady_rebuilds}\n  }},\n"));
    fj.push_str("  \"crossover\": {\n");
    for (sn, bh, fit) in &sweep {
        fj.push_str(&format!(
            "    \"n{sn}\": {{ \"bh_step_s\": {bh:.6e}, \"fit_step_s\": {fit:.6e} }},\n"
        ));
    }
    fj.push_str(&format!("    \"estimate_n\": {estimate_n}\n  }}\n}}\n"));
    if let Err(e) = std::fs::write("BENCH_fitsne.json", &fj) {
        eprintln!("warning: could not write BENCH_fitsne.json: {e}");
    } else {
        println!("[json] BENCH_fitsne.json");
    }

    // --- KNN recall: the approximate engine's speed/recall frontier. One
    // deterministic HNSW build, then an ef_search sweep against the exact
    // brute-force rows — recall@k is the mean per-row overlap. This is the
    // measurement behind the ">= 0.9 recall at the default beam" contract
    // (StagePlan::auto_for swaps in HNSW above FFT_CROSSOVER_N).
    let kn = (n / 4).clamp(2_000, 50_000);
    let kd = 16usize;
    let kk = 10usize;
    let kds = gaussian_mixture::<f64>(kn, kd, 16, 6.0, 77);
    let mut b = Bencher::new(&format!("knn_recall (n={kn}, d={kd}, k={kk})")).sampling(1, 3, 10.0);
    let exact = BruteForceKnn::default().search(&pool, &kds.points, kn, kd, kk);
    let s_exact = b.bench("exact_search", || {
        BruteForceKnn::default().search(&pool, &kds.points, kn, kd, kk).n
    });
    let params = HnswParams::default();
    let s_build = b.bench("hnsw_build", || {
        HnswIndex::build(&pool, &kds.points, kn, kd, &params).len()
    });
    let index = HnswIndex::build(&pool, &kds.points, kn, kd, &params);
    let recall_vs_exact = |approx: &acc_tsne::knn::NeighborLists<f64>| -> f64 {
        let mut hits = 0usize;
        for i in 0..kn {
            let truth = exact.neighbors(i);
            hits += approx.neighbors(i).iter().filter(|j| truth.contains(j)).count();
        }
        hits as f64 / (kn * kk) as f64
    };
    let ef_sweep = [16usize, 32, 64, 128, 256];
    let mut sweep_rows = Vec::new();
    let mut default_recall = 0.0f64;
    for &ef in &ef_sweep {
        let s = b.bench(&format!("hnsw_search ef={ef}"), || index.search_all(&pool, kk, ef).n);
        let rows = index.search_all(&pool, kk, ef);
        let recall = recall_vs_exact(&rows);
        if ef == DEFAULT_EF_SEARCH {
            default_recall = recall;
        }
        println!("  ef={ef}: {:.3}ms, recall@{kk} {recall:.4}", s.mean * 1e3);
        sweep_rows.push((ef, s.mean, recall));
    }
    b.report();

    let mut kj = String::from("{\n  \"bench\": \"knn\",\n");
    kj.push_str(&format!(
        "  \"n\": {kn},\n  \"d\": {kd},\n  \"k\": {kk},\n  \"threads\": {},\n",
        pool.n_threads()
    ));
    kj.push_str("  \"knn_recall\": {\n");
    kj.push_str(&format!("    \"build_s\": {:.6e},\n", s_build.mean));
    kj.push_str(&format!("    \"exact_search_s\": {:.6e},\n", s_exact.mean));
    kj.push_str(&format!("    \"default_ef\": {DEFAULT_EF_SEARCH},\n"));
    kj.push_str(&format!("    \"default_recall\": {default_recall:.4},\n"));
    for (i, (ef, mean, recall)) in sweep_rows.iter().enumerate() {
        let sep = if i + 1 < sweep_rows.len() { "," } else { "" };
        kj.push_str(&format!(
            "    \"ef{ef}\": {{ \"search_s\": {mean:.6e}, \"recall\": {recall:.4} }}{sep}\n"
        ));
    }
    kj.push_str("  }\n}\n");
    if let Err(e) = std::fs::write("BENCH_knn.json", &kj) {
        eprintln!("warning: could not write BENCH_knn.json: {e}");
    } else {
        println!("[json] BENCH_knn.json");
    }

    // --- serving: the embedding daemon (tsne::serve) under concurrent load.
    // One fresh server per fleet size, so every fleet pays exactly one
    // affinity fit (the cache miss) and N−1 artifact-cache hits.
    // sessions_per_s is fleet-completion throughput (a rate — the trend
    // checker exempts it); step p50/p99 come from the scheduler's per-turn
    // samples; cache_{miss,hit}_s is the connect→Hello latency, which is
    // exactly the fit-vs-lookup cost a client observes.
    let serve_n = 512usize;
    let serve_iters = env_loop_iters().clamp(10, 40);
    let sds = gaussian_mixture::<f64>(serve_n, 16, 4, 4.0, 11);
    println!("\n== serving (n={serve_n}, iters={serve_iters}, threads={}) ==", pool.n_threads());
    let fleet_sizes = [1usize, 4, 8];
    let mut fleet_rows = Vec::new();
    let mut cache_miss_s = 0.0f64;
    let mut cache_hit_s = 0.0f64;
    for &fleet in &fleet_sizes {
        let mut server = serve_start(&ServeConfig {
            addr: "127.0.0.1:0".into(),
            n_threads: pool.n_threads(),
            ..ServeConfig::default()
        })
        .expect("bench server");
        let addr = server.addr().to_string();
        let make_req = |seed: u64| Request {
            resume_id: 0,
            n: sds.n as u64,
            d: sds.d as u64,
            n_iter: serve_iters as u64,
            snapshot_every: (serve_iters / 4).max(1) as u64,
            seed,
            perplexity: 12.0,
            theta: 0.5,
            points: sds.points.clone(),
        };
        let t0 = std::time::Instant::now();
        let joins: Vec<_> = (0..fleet)
            .map(|i| {
                let addr = addr.clone();
                let req = make_req(100 + i as u64);
                std::thread::spawn(move || run_client(&addr, &req).expect("bench client"))
            })
            .collect();
        let runs: Vec<_> =
            joins.into_iter().map(|j| j.join().expect("bench client thread")).collect();
        let wall = t0.elapsed().as_secs_f64();
        if fleet == 1 {
            cache_miss_s = runs[0].hello_secs;
            // The same bytes again on the warm server: the cache-hit path.
            cache_hit_s = run_client(&addr, &make_req(999)).expect("bench client").hello_secs;
        }
        let stats = server.stats();
        server.shutdown();
        let sessions_per_s = fleet as f64 / wall.max(1e-12);
        println!(
            "  n{fleet}: {sessions_per_s:.2} sessions/s, step p50 {:.3e}s p99 {:.3e}s \
             (cache hits/misses {}/{})",
            stats.step_p50_s, stats.step_p99_s, stats.cache_hits, stats.cache_misses
        );
        fleet_rows.push((fleet, sessions_per_s, stats));
    }
    println!("  cache: miss {cache_miss_s:.3e}s -> hit {cache_hit_s:.3e}s to Hello");

    let mut sj = String::from("{\n  \"bench\": \"serving\",\n");
    sj.push_str(&format!(
        "  \"n\": {serve_n},\n  \"d\": 16,\n  \"iters\": {serve_iters},\n  \"threads\": {},\n",
        pool.n_threads()
    ));
    sj.push_str("  \"serving\": {\n");
    sj.push_str(&format!("    \"cache_miss_s\": {cache_miss_s:.6e},\n"));
    sj.push_str(&format!("    \"cache_hit_s\": {cache_hit_s:.6e},\n"));
    for (i, (fleet, sessions_per_s, stats)) in fleet_rows.iter().enumerate() {
        let sep = if i + 1 < fleet_rows.len() { "," } else { "" };
        sj.push_str(&format!(
            "    \"n{fleet}\": {{ \"sessions_per_s\": {sessions_per_s:.4}, \
             \"step_p50_s\": {:.6e}, \"step_p99_s\": {:.6e} }}{sep}\n",
            stats.step_p50_s, stats.step_p99_s
        ));
    }
    sj.push_str("  }\n}\n");
    if let Err(e) = std::fs::write("BENCH_serving.json", &sj) {
        eprintln!("warning: could not write BENCH_serving.json: {e}");
    } else {
        println!("[json] BENCH_serving.json");
    }
}
