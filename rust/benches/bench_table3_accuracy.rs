//! Regenerates paper Table 3: KL divergence of sklearn-like, daal4py-like and
//! Acc-t-SNE across the six datasets (accuracy parity claim).

use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!("# Table 3 bench: scale={} iters={}", cfg.scale, cfg.n_iter);
    experiments::table3_accuracy(&cfg, &PaperDataset::ALL);
}
