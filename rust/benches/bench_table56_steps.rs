//! Regenerates paper Tables 5 (single-thread) and 6 (all cores): per-step
//! daal4py-like vs Acc-t-SNE on the mouse-brain analog.

use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!("# Table 5/6 bench: scale={} iters={}", cfg.scale, cfg.n_iter);
    experiments::table56_steps(&cfg, 1);
    experiments::table56_steps(&cfg, cfg.resolved_threads());
}
