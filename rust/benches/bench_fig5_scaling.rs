//! Regenerates paper Figure 5: end-to-end multicore scaling of all five
//! implementations on the mouse-brain analog (speedup vs own 1-core time).

use acc_tsne::eval::{experiments, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    println!(
        "# Fig 5 bench: scale={} iters={} cores={:?}",
        cfg.scale,
        cfg.n_iter,
        cfg.core_sweep()
    );
    experiments::fig5_scaling(&cfg);
}
