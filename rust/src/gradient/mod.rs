//! Gradient computation and descent (pipeline steps 5–6 + update).
//!
//! The KL gradient splits into attractive and repulsive parts (paper Eq. 6–8):
//!
//! ```text
//! ∂C/∂y_i = 4 · ( exag · F_attr_i  −  F_rep_raw_i / Z )
//! F_attr_i    = Σ_j  p_ij (1+‖y_i−y_j‖²)⁻¹ (y_i − y_j)        — over sparse P
//! F_rep_raw_i = Σ_j  (1+‖y_i−y_j‖²)⁻² (y_i − y_j)             — BH-approximated
//! Z           = Σ_{k≠l} (1+‖y_k−y_l‖²)⁻¹                      — BH-accumulated
//! ```
//!
//! - [`attractive`] — Algorithm 2 with scalar / +software-prefetch / +SIMD variants.
//! - [`repulsive`] — Barnes-Hut quadtree traversal (Eq. 9 criterion).
//! - [`exact`] — O(N²) oracle for both, used by tests and the accuracy harness.
//! - [`update`] — gains/momentum/early-exaggeration descent step.

pub mod attractive;
pub mod exact;
pub mod repulsive;
pub mod update;

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Combine attractive and repulsive accumulations into the KL gradient
/// (in-place into `grad`). `exaggeration` scales the attractive term (the
/// early-exaggeration trick multiplies P).
///
/// The pipeline's hot loop no longer calls this: it runs the fused
/// combine+update sweep ([`update::Optimizer::fused_combine_step`], one pass
/// over `2n` instead of three, arithmetically identical per element). This
/// standalone combine remains for the exact-gradient oracle tests and
/// callers that need the gradient vector itself.
pub fn combine_gradient<T: Real>(
    pool: &ThreadPool,
    attr: &[T],
    rep_raw: &[T],
    z: T,
    exaggeration: T,
    grad: &mut [T],
) {
    let n2 = grad.len();
    assert_eq!(attr.len(), n2);
    assert_eq!(rep_raw.len(), n2);
    let inv_z = T::ONE / z.max_r(T::TINY);
    let four = T::TWO * T::TWO;
    let gs = SyncSlice::new(grad);
    parallel_for(pool, n2, Schedule::Static, |range| {
        for i in range {
            let g = four * (exaggeration * attr[i] - rep_raw[i] * inv_z);
            // SAFETY: disjoint — slot i
            unsafe { *gs.get_mut(i) = g };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_matches_formula() {
        let pool = ThreadPool::new(2);
        let attr = vec![1.0f64, -2.0, 0.5, 0.0];
        let rep = vec![4.0f64, 2.0, -1.0, 8.0];
        let mut grad = vec![0.0f64; 4];
        combine_gradient(&pool, &attr, &rep, 2.0, 3.0, &mut grad);
        for i in 0..4 {
            let want = 4.0 * (3.0 * attr[i] - rep[i] / 2.0);
            assert!((grad[i] - want).abs() < 1e-12);
        }
    }
}
