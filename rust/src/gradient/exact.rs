//! Exact O(N²) force/gradient oracles.
//!
//! Used by (1) the test suite, to bound the BH and FIt-SNE approximation
//! errors; (2) the accuracy harness (Table 3's KL needs the exact Z on small
//! datasets); (3) the `repulsive_dense` hardware-adaptation ablation (the
//! TPU-friendly dense-tile formulation mirrored by the Pallas kernel).

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use crate::sparse::CsrMatrix;

/// Exact repulsive accumulations: `raw_i = Σ_{j≠i} (1+d²)⁻² (y_i−y_j)` and
/// `Z = Σ_{k≠l} (1+d²)⁻¹` (ordered pairs).
pub fn exact_repulsive<T: Real>(pool: &ThreadPool, y: &[T]) -> (Vec<T>, T) {
    let n = y.len() / 2;
    let mut raw = vec![T::ZERO; 2 * n];
    let nt = pool.n_threads();
    let mut z_parts = vec![T::ZERO; nt];
    {
        let rs = SyncSlice::new(&mut raw);
        let zs = SyncSlice::new(&mut z_parts);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            let mut z_local = T::ZERO;
            for i in s..e {
                let yix = y[2 * i];
                let yiy = y[2 * i + 1];
                let mut fx = T::ZERO;
                let mut fy = T::ZERO;
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    let dx = yix - y[2 * j];
                    let dy = yiy - y[2 * j + 1];
                    let q = T::ONE / (T::ONE + dx * dx + dy * dy);
                    z_local += q;
                    let qq = q * q;
                    fx += qq * dx;
                    fy += qq * dy;
                }
                // SAFETY: disjoint — slots 2i, 2i+1
                unsafe {
                    *rs.get_mut(2 * i) = fx;
                    *rs.get_mut(2 * i + 1) = fy;
                }
            }
            // SAFETY: disjoint — one partial-sum slot per tid
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let mut z = T::ZERO;
    for zp in z_parts {
        z += zp;
    }
    (raw, z)
}

/// Exact KL gradient: `∂C/∂y_i = 4 Σ_j (p_ij − q_ij) q_ij Z (y_i − y_j)`
/// with dense Q. `p` supplies the sparse P (zero elsewhere). The oracle for
/// end-to-end gradient tests.
pub fn exact_gradient<T: Real>(pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T]) -> Vec<T> {
    let n = p.n;
    assert_eq!(y.len(), 2 * n);
    // Z first (exact).
    let (_, z) = exact_repulsive(pool, y);
    let mut grad = vec![T::ZERO; 2 * n];
    {
        let gs = SyncSlice::new(&mut grad);
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                let yix = y[2 * i];
                let yiy = y[2 * i + 1];
                let (cols, vals) = p.row(i);
                let mut gx = T::ZERO;
                let mut gy = T::ZERO;
                // attractive part over sparse P
                for (c, v) in cols.iter().zip(vals.iter()) {
                    let j = *c as usize;
                    let dx = yix - y[2 * j];
                    let dy = yiy - y[2 * j + 1];
                    let qz_inv = T::ONE / (T::ONE + dx * dx + dy * dy); // q_ij * Z
                    gx += *v * qz_inv * dx;
                    gy += *v * qz_inv * dy;
                }
                // repulsive part over all pairs
                let mut rx = T::ZERO;
                let mut ry = T::ZERO;
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    let dx = yix - y[2 * j];
                    let dy = yiy - y[2 * j + 1];
                    let u = T::ONE / (T::ONE + dx * dx + dy * dy);
                    // q_ij² Z (y_i−y_j) = u²/Z (y_i−y_j)
                    rx += u * u * dx;
                    ry += u * u * dy;
                }
                let four = T::TWO * T::TWO;
                // SAFETY: disjoint — slots 2i, 2i+1
                unsafe {
                    *gs.get_mut(2 * i) = four * (gx - rx / z);
                    *gs.get_mut(2 * i + 1) = four * (gy - ry / z);
                }
            }
        });
    }
    grad
}

/// Exact KL divergence over the sparse-P support with exact Z:
/// `C = Σ_{(i,j) ∈ P} p_ij ln(p_ij / q_ij)` (the quantity Table 3 reports).
pub fn exact_kl<T: Real>(pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T]) -> f64 {
    let (_, z) = exact_repulsive(pool, y);
    kl_with_z(p, y, z.to_f64())
}

/// KL over sparse-P support given a (possibly BH-approximated) Z.
pub fn kl_with_z<T: Real>(p: &CsrMatrix<T>, y: &[T], z: f64) -> f64 {
    let mut c = 0.0f64;
    for i in 0..p.n {
        let (cols, vals) = p.row(i);
        for (cc, v) in cols.iter().zip(vals.iter()) {
            let pij = v.to_f64();
            if pij <= 0.0 {
                continue;
            }
            let j = *cc as usize;
            let dx = (y[2 * i] - y[2 * j]).to_f64();
            let dy = (y[2 * i + 1] - y[2 * j + 1]).to_f64();
            let qij = (1.0 / (1.0 + dx * dx + dy * dy)) / z;
            c += pij * (pij / qij.max(f64::MIN_POSITIVE)).ln();
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::knn::{BruteForceKnn, KnnEngine};
    use crate::perplexity::{binary_search_perplexity, ParMode};
    use crate::sparse::symmetrize;

    fn setup(n: usize, seed: u64) -> (CsrMatrix<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let d = 4;
        let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        let knn = BruteForceKnn::default().search(&pool, &data, n, d, 12);
        let cond = binary_search_perplexity(&pool, &knn, 4.0, ParMode::Parallel);
        let p = symmetrize(&pool, &knn, &cond.p);
        let y: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 0.1).collect();
        (p, y)
    }

    #[test]
    fn z_counts_ordered_pairs_at_large_distance() {
        // Two far points: q ≈ 1/d², Z tiny; two coincident: q = 1 each way.
        let pool = ThreadPool::new(1);
        let y = vec![0.0f64, 0.0, 0.0, 0.0];
        let (_, z) = exact_repulsive(&pool, &y);
        assert!((z - 2.0).abs() < 1e-12, "two coincident points: Z = 2·1");
    }

    #[test]
    fn gradient_is_descent_direction() {
        // Numerically verify: C(y - ε·grad) < C(y).
        let (p, y) = setup(80, 1);
        let pool = ThreadPool::new(4);
        let grad = exact_gradient(&pool, &p, &y);
        let c0 = exact_kl(&pool, &p, &y);
        let eps = 1e-3;
        let y2: Vec<f64> = y.iter().zip(grad.iter()).map(|(a, g)| a - eps * g).collect();
        let c1 = exact_kl(&pool, &p, &y2);
        assert!(c1 < c0, "KL must decrease along -grad: {c0} -> {c1}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (p, y) = setup(30, 2);
        let pool = ThreadPool::new(2);
        let grad = exact_gradient(&pool, &p, &y);
        let h = 1e-6;
        for probe in [0usize, 7, 13, 42] {
            let mut yp = y.clone();
            let mut ym = y.clone();
            yp[probe] += h;
            ym[probe] -= h;
            let fd = (exact_kl(&pool, &p, &yp) - exact_kl(&pool, &p, &ym)) / (2.0 * h);
            assert!(
                (grad[probe] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "idx {probe}: analytic {} vs fd {fd}",
                grad[probe]
            );
        }
    }

    #[test]
    fn kl_nonnegative_at_optimum_neighborhood() {
        // KL of any configuration is ≥ 0 up to the sparse-support truncation;
        // at random far-flung y it should be clearly positive.
        let (p, mut y) = setup(60, 3);
        for v in y.iter_mut() {
            *v *= 100.0;
        }
        let pool = ThreadPool::new(2);
        assert!(exact_kl(&pool, &p, &y) > 0.0);
    }

    #[test]
    fn parallel_matches_single_thread() {
        let (p, y) = setup(120, 4);
        let g1 = exact_gradient(&ThreadPool::new(1), &p, &y);
        let g8 = exact_gradient(&ThreadPool::new(8), &p, &y);
        for i in 0..g1.len() {
            assert!((g1[i] - g8[i]).abs() < 1e-12 * (1.0 + g1[i].abs()));
        }
    }
}
