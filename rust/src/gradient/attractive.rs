//! Attractive force computation (pipeline step 5, paper §3.6, Algorithm 2).
//!
//! `F_attr_i = Σ_{j ∈ row i of P} p_ij (1+‖y_i−y_j‖²)⁻¹ (y_i−y_j)` — a sparse
//! CSR row sweep. Rows are independent → parallel over i (daal4py already does
//! this); the paper's contribution is single-thread speed:
//!
//! - [`Variant::Scalar`] — Algorithm 2 verbatim (the daal4py inner loop).
//! - [`Variant::Prefetch`] — plus `_mm_prefetch` of the `y_j` coordinates
//!   `PF_DIST` nonzeros ahead: the neighbor gather is a pseudo-random walk
//!   over an array of N points, guaranteed cache misses once 16·N bytes
//!   exceed L2 (paper: "software prefetching the y_j values of a later y_i
//!   while we are processing the current y_i").
//! - [`Variant::Simd`] — plus hand-vectorization: 8 (f64) / 16 (f32) nonzeros
//!   per iteration with portable-SIMD gathers standing in for the paper's
//!   AVX-512 `vgatherdpd` (compiled to AVX-512 under `target-cpu=native`).

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use crate::sparse::CsrMatrix;
use std::simd::num::SimdFloat;
use std::simd::{f32x16, f64x8, Simd};

/// How far ahead (in nonzeros) the prefetch variant reaches.
pub const PF_DIST: usize = 32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Scalar,
    Prefetch,
    Simd,
}

impl Variant {
    pub const ALL: [Variant; 3] = [Variant::Scalar, Variant::Prefetch, Variant::Simd];

    /// Stable, parseable name. [`Variant::Simd`] historically reported
    /// itself as "simd+prefetch", which nothing could parse back;
    /// [`FromStr`](std::str::FromStr) still accepts that legacy spelling.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Prefetch => "prefetch",
            Variant::Simd => "simd",
        }
    }

    /// [`FromStr`](std::str::FromStr) without the error payload.
    pub fn from_name(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Variant {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(Variant::Scalar),
            "prefetch" => Ok(Variant::Prefetch),
            "simd" | "simd+prefetch" => Ok(Variant::Simd),
            _ => Err(format!(
                "unknown attractive variant '{s}' (expected: scalar, prefetch, simd)"
            )),
        }
    }
}

/// SIMD row kernels, implemented for f32/f64 (portable-SIMD lane widths differ).
pub trait AttractiveSimd: Real {
    /// Accumulate Σ PQ·(y_i − y_j) over one CSR row with SIMD gathers.
    fn attr_row_simd(y: &[Self], cols: &[u32], vals: &[Self], yix: Self, yiy: Self) -> (Self, Self);
}

macro_rules! impl_attr_simd {
    ($t:ty, $vec:ty, $lanes:expr) => {
        impl AttractiveSimd for $t {
            #[inline]
            fn attr_row_simd(
                y: &[Self],
                cols: &[u32],
                vals: &[Self],
                yix: Self,
                yiy: Self,
            ) -> (Self, Self) {
                let n = cols.len();
                let mut accx = <$vec>::splat(0.0);
                let mut accy = <$vec>::splat(0.0);
                let one = <$vec>::splat(1.0);
                let vyix = <$vec>::splat(yix);
                let vyiy = <$vec>::splat(yiy);
                let mut t = 0usize;
                while t + $lanes <= n {
                    let mut idx = [0usize; $lanes];
                    for l in 0..$lanes {
                        idx[l] = 2 * cols[t + l] as usize;
                    }
                    let ix = Simd::<usize, $lanes>::from_array(idx);
                    // gather y_j coordinates (interleaved storage)
                    let xj = <$vec>::gather_or_default(y, ix);
                    let yj = <$vec>::gather_or_default(y, ix + Simd::splat(1));
                    let v = <$vec>::from_slice(&vals[t..t + $lanes]);
                    let dx = vyix - xj;
                    let dy = vyiy - yj;
                    let pq = v / (one + dx * dx + dy * dy);
                    accx += pq * dx;
                    accy += pq * dy;
                    t += $lanes;
                }
                let mut fx = accx.reduce_sum();
                let mut fy = accy.reduce_sum();
                // scalar tail
                while t < n {
                    let j = cols[t] as usize;
                    let dx = yix - y[2 * j];
                    let dy = yiy - y[2 * j + 1];
                    let pq = vals[t] / (1.0 + dx * dx + dy * dy);
                    fx += pq * dx;
                    fy += pq * dy;
                    t += 1;
                }
                (fx, fy)
            }
        }
    };
}

impl_attr_simd!(f64, f64x8, 8);
impl_attr_simd!(f32, f32x16, 16);

#[inline(always)]
fn prefetch_point<T>(y: &[T], j: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint with no memory effects; any address is
    // sound, and 2*j stays within the point array the caller indexes next.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch(y.as_ptr().add(2 * j) as *const i8, _MM_HINT_T0);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (y, j);
    }
}

#[inline(always)]
fn scalar_row<T: Real>(y: &[T], cols: &[u32], vals: &[T], yix: T, yiy: T) -> (T, T) {
    let mut fx = T::ZERO;
    let mut fy = T::ZERO;
    for (c, v) in cols.iter().zip(vals.iter()) {
        let j = *c as usize;
        let dx = yix - y[2 * j];
        let dy = yiy - y[2 * j + 1];
        let pq = *v / (T::ONE + dx * dx + dy * dy);
        fx += pq * dx;
        fy += pq * dy;
    }
    (fx, fy)
}

#[inline(always)]
fn prefetch_row<T: Real>(
    y: &[T],
    all_cols: &[u32],
    row_start: usize,
    row_end: usize,
    yix: T,
    yiy: T,
    vals: &[T],
) -> (T, T) {
    let mut fx = T::ZERO;
    let mut fy = T::ZERO;
    let nnz = all_cols.len();
    for ind in row_start..row_end {
        // reach PF_DIST nonzeros ahead — possibly into the next rows,
        // exactly the "later y_i" the paper describes.
        let pf = ind + PF_DIST;
        if pf < nnz {
            prefetch_point(y, all_cols[pf] as usize);
        }
        let j = all_cols[ind] as usize;
        let dx = yix - y[2 * j];
        let dy = yiy - y[2 * j + 1];
        let pq = vals[ind] / (T::ONE + dx * dx + dy * dy);
        fx += pq * dx;
        fy += pq * dy;
    }
    (fx, fy)
}

/// Compute attractive forces for all points: `out[2i..2i+2] = F_attr_i`.
/// Parallel over rows (static: row lengths ≈ uniform at ⌊3u⌋..2⌊3u⌋).
pub fn attractive_forces<T: AttractiveSimd>(
    pool: &ThreadPool,
    p: &CsrMatrix<T>,
    y: &[T],
    variant: Variant,
    out: &mut [T],
) {
    let n = p.n;
    assert_eq!(y.len(), 2 * n);
    assert_eq!(out.len(), 2 * n);
    let os = SyncSlice::new(out);
    parallel_for(pool, n, Schedule::Static, |range| {
        for i in range {
            let yix = y[2 * i];
            let yiy = y[2 * i + 1];
            let (s, e) = (p.row_ptr[i], p.row_ptr[i + 1]);
            let (fx, fy) = match variant {
                Variant::Scalar => scalar_row(y, &p.col[s..e], &p.val[s..e], yix, yiy),
                Variant::Prefetch => prefetch_row(y, &p.col, s, e, yix, yiy, &p.val),
                Variant::Simd => {
                    // prefetch the next row's gathers while SIMD chews this one
                    let pf_end = (e + PF_DIST).min(p.col.len());
                    for pf in e..pf_end {
                        prefetch_point(y, p.col[pf] as usize);
                    }
                    T::attr_row_simd(y, &p.col[s..e], &p.val[s..e], yix, yiy)
                }
            };
            // SAFETY: disjoint — slots 2i, 2i+1
            unsafe {
                *os.get_mut(2 * i) = fx;
                *os.get_mut(2 * i + 1) = fy;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::knn::{BruteForceKnn, KnnEngine};
    use crate::perplexity::{binary_search_perplexity, ParMode};
    use crate::sparse::symmetrize;

    fn setup(n: usize, seed: u64) -> (CsrMatrix<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let d = 5;
        let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        let knn = BruteForceKnn::default().search(&pool, &data, n, d, 15);
        let cond = binary_search_perplexity(&pool, &knn, 5.0, ParMode::Parallel);
        let p = symmetrize(&pool, &knn, &cond.p);
        let y: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 1e-2).collect();
        (p, y)
    }

    /// Dense reference: F_attr_i = Σ_j p_ij (1+d²)⁻¹ (y_i − y_j).
    fn reference(p: &CsrMatrix<f64>, y: &[f64]) -> Vec<f64> {
        let n = p.n;
        let mut out = vec![0.0; 2 * n];
        for i in 0..n {
            let (cols, vals) = p.row(i);
            for (c, v) in cols.iter().zip(vals.iter()) {
                let j = *c as usize;
                let dx = y[2 * i] - y[2 * j];
                let dy = y[2 * i + 1] - y[2 * j + 1];
                let pq = v / (1.0 + dx * dx + dy * dy);
                out[2 * i] += pq * dx;
                out[2 * i + 1] += pq * dy;
            }
        }
        out
    }

    #[test]
    fn all_variants_match_reference() {
        let (p, y) = setup(300, 1);
        let pool = ThreadPool::new(4);
        let want = reference(&p, &y);
        for variant in [Variant::Scalar, Variant::Prefetch, Variant::Simd] {
            let mut got = vec![0.0; y.len()];
            attractive_forces(&pool, &p, &y, variant, &mut got);
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                    "{} idx {i}: {g} vs {w}",
                    variant.name()
                );
            }
        }
    }

    #[test]
    fn f32_simd_matches_f32_scalar() {
        let (p, y) = setup(200, 2);
        let p32 = CsrMatrix::<f32> {
            n: p.n,
            row_ptr: p.row_ptr.clone(),
            col: p.col.clone(),
            val: p.val.iter().map(|&v| v as f32).collect(),
        };
        let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let pool = ThreadPool::new(2);
        let mut a = vec![0.0f32; y32.len()];
        let mut b = vec![0.0f32; y32.len()];
        attractive_forces(&pool, &p32, &y32, Variant::Scalar, &mut a);
        attractive_forces(&pool, &p32, &y32, Variant::Simd, &mut b);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() <= 1e-5 * (1.0 + a[i].abs()), "idx {i}");
        }
    }

    #[test]
    fn attraction_pulls_towards_neighbors() {
        // Two points connected by P: force on each points toward the other.
        let p = CsrMatrix::<f64> {
            n: 2,
            row_ptr: vec![0, 1, 2],
            col: vec![1, 0],
            val: vec![0.5, 0.5],
        };
        let y = vec![0.0, 0.0, 1.0, 0.0]; // point 1 to the right of point 0
        let pool = ThreadPool::new(1);
        let mut out = vec![0.0; 4];
        attractive_forces(&pool, &p, &y, Variant::Scalar, &mut out);
        // gradient descent moves AGAINST F_attr: F_attr_0 = pq*(y0-y1) < 0 → good
        assert!(out[0] < 0.0, "force on 0 points left (towards 1 after − sign in update)");
        assert!(out[2] > 0.0);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn empty_rows_ok() {
        let p = CsrMatrix::<f64> {
            n: 3,
            row_ptr: vec![0, 0, 2, 2], // row 0 and 2 empty
            col: vec![0, 2],
            val: vec![0.3, 0.7],
        };
        let y = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0];
        let pool = ThreadPool::new(2);
        for variant in [Variant::Scalar, Variant::Prefetch, Variant::Simd] {
            let mut out = vec![9.0; 6];
            attractive_forces(&pool, &p, &y, variant, &mut out);
            assert_eq!(out[0], 0.0, "{}", variant.name());
            assert_eq!(out[4], 0.0);
        }
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::from_name(v.name()), Some(v));
            assert_eq!(v.to_string(), v.name());
            assert_eq!(v.name().parse::<Variant>(), Ok(v));
        }
        // the legacy unparseable label is accepted as an alias
        assert_eq!(Variant::from_name("simd+prefetch"), Some(Variant::Simd));
        assert_eq!(Variant::from_name("bogus"), None);
        let err = "bogus".parse::<Variant>().unwrap_err();
        assert!(err.contains("prefetch"), "error lists the choices: {err}");
    }

    #[test]
    fn deterministic_across_threads() {
        let (p, y) = setup(500, 3);
        let mut a = vec![0.0; y.len()];
        let mut b = vec![0.0; y.len()];
        attractive_forces(&ThreadPool::new(1), &p, &y, Variant::Simd, &mut a);
        attractive_forces(&ThreadPool::new(8), &p, &y, Variant::Simd, &mut b);
        assert_eq!(a, b);
    }
}
