//! Gradient-descent update with gains, momentum, and early exaggeration —
//! the standard vdMaaten/sklearn schedule the paper runs (1000 iterations,
//! sklearn defaults).

use crate::common::float::Real;
use crate::common::rng::Rng;
use crate::parallel::par_for::static_chunk;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Descent hyper-parameters (sklearn-2022 defaults, as used by the paper).
#[derive(Clone, Copy, Debug)]
pub struct UpdateParams {
    pub learning_rate: f64,
    pub momentum_early: f64,
    pub momentum_late: f64,
    /// Iteration at which momentum switches and exaggeration stops.
    pub exaggeration_iters: usize,
    pub early_exaggeration: f64,
    pub min_gain: f64,
}

impl Default for UpdateParams {
    fn default() -> Self {
        UpdateParams {
            learning_rate: 200.0,
            momentum_early: 0.5,
            momentum_late: 0.8,
            exaggeration_iters: 250,
            early_exaggeration: 12.0,
            min_gain: 0.01,
        }
    }
}

/// Mutable optimizer state.
#[derive(Clone, Debug)]
pub struct Optimizer<T: Real> {
    pub velocity: Vec<T>,
    pub gains: Vec<T>,
    pub params: UpdateParams,
    /// Per-thread partial sums of the squared gradient norm (scratch of
    /// [`Self::fused_combine_step`]; kept here so the hot loop stays
    /// allocation-free).
    norm_partials: Vec<T>,
}

impl<T: Real> Optimizer<T> {
    pub fn new(n: usize, params: UpdateParams) -> Self {
        Optimizer {
            velocity: vec![T::ZERO; 2 * n],
            gains: vec![T::ONE; 2 * n],
            params,
            norm_partials: Vec::new(),
        }
    }

    /// Current exaggeration factor at `iter`.
    #[inline]
    pub fn exaggeration(&self, iter: usize) -> T {
        if iter < self.params.exaggeration_iters {
            T::from_f64(self.params.early_exaggeration)
        } else {
            T::ONE
        }
    }

    #[inline]
    fn schedule(&self, iter: usize) -> (T, T, T) {
        let momentum = T::from_f64(if iter < self.params.exaggeration_iters {
            self.params.momentum_early
        } else {
            self.params.momentum_late
        });
        (
            momentum,
            T::from_f64(self.params.learning_rate),
            T::from_f64(self.params.min_gain),
        )
    }

    /// One descent step: gains update (0.2/0.8 rule), momentum, position
    /// update, then recentring (paper/sklearn keep the embedding zero-mean).
    pub fn step(&mut self, pool: &ThreadPool, iter: usize, grad: &[T], y: &mut [T]) {
        let n2 = y.len();
        assert_eq!(grad.len(), n2);
        assert_eq!(self.velocity.len(), n2);
        let (momentum, eta, min_gain) = self.schedule(iter);
        {
            let vs = SyncSlice::new(&mut self.velocity);
            let gs = SyncSlice::new(&mut self.gains);
            let ys = SyncSlice::new(y);
            parallel_for(pool, n2, Schedule::Static, |range| {
                for i in range {
                    // SAFETY: disjoint — slot i
                    unsafe {
                        descent_update(
                            grad[i],
                            vs.get_mut(i),
                            gs.get_mut(i),
                            ys.get_mut(i),
                            momentum,
                            eta,
                            min_gain,
                        );
                    }
                }
            });
        }
        recenter(pool, y);
    }

    /// Fused combine + descent step — the gradient hot loop's single
    /// per-iteration sweep: computes the KL-gradient element
    /// `g_i = 4·(exag·attr_i − rep_raw_i / Z)` inline and immediately applies
    /// the gains/momentum/position update to it, one parallel pass over the
    /// `2n` coordinates instead of the three passes of
    /// [`combine_gradient`](crate::gradient::combine_gradient) + [`Self::step`]
    /// (write grad, read grad, write y). Per element the arithmetic — and
    /// therefore the FP result — is identical to the two-pass path
    /// (asserted bitwise by `fused_step_equals_combine_then_step`).
    ///
    /// Returns the **squared l2 norm of the gradient** (`Σ g_i²`), which the
    /// sweep materializes for free — the convergence controls of
    /// [`TsneSession::run_until`](crate::tsne::TsneSession::run_until) read
    /// it without an extra pass. The norm is accumulated per static chunk and
    /// the chunk partials are summed in thread-id order, so it is
    /// deterministic at a fixed thread count; the position/velocity/gains
    /// update itself is arithmetically untouched by the accumulation.
    pub fn fused_combine_step(
        &mut self,
        pool: &ThreadPool,
        iter: usize,
        attr: &[T],
        rep_raw: &[T],
        z: T,
        y: &mut [T],
    ) -> T {
        let n2 = y.len();
        assert_eq!(attr.len(), n2);
        assert_eq!(rep_raw.len(), n2);
        assert_eq!(self.velocity.len(), n2);
        let exaggeration = self.exaggeration(iter);
        let inv_z = T::ONE / z.max_r(T::TINY);
        let four = T::TWO * T::TWO;
        let (momentum, eta, min_gain) = self.schedule(iter);
        let nt = pool.n_threads();
        self.norm_partials.clear();
        self.norm_partials.resize(nt, T::ZERO);
        {
            let vs = SyncSlice::new(&mut self.velocity);
            let gs = SyncSlice::new(&mut self.gains);
            let ps = SyncSlice::new(&mut self.norm_partials);
            let ys = SyncSlice::new(y);
            // broadcast + static_chunk = parallel_for(Static) with the thread
            // id exposed, so each thread owns one norm-partial slot.
            pool.broadcast(|tid| {
                let (start, end) = static_chunk(n2, nt, tid);
                let mut acc = T::ZERO;
                for i in start..end {
                    let grad_i = four * (exaggeration * attr[i] - rep_raw[i] * inv_z);
                    acc += grad_i * grad_i;
                    // SAFETY: disjoint — slot i
                    unsafe {
                        descent_update(
                            grad_i,
                            vs.get_mut(i),
                            gs.get_mut(i),
                            ys.get_mut(i),
                            momentum,
                            eta,
                            min_gain,
                        );
                    }
                }
                // SAFETY: disjoint — slot tid
                unsafe { *ps.get_mut(tid) = acc };
            });
        }
        recenter(pool, y);
        let mut norm_sq = T::ZERO;
        for &p in &self.norm_partials {
            norm_sq += p;
        }
        norm_sq
    }
}

/// Gains (0.2/0.8 rule) + momentum + position update for one coordinate —
/// shared by [`Optimizer::step`] and [`Optimizer::fused_combine_step`] so the
/// two paths stay arithmetically identical.
#[inline(always)]
fn descent_update<T: Real>(
    grad_i: T,
    v: &mut T,
    g: &mut T,
    yy: &mut T,
    momentum: T,
    eta: T,
    min_gain: T,
) {
    // sign disagreement → growing step; agreement → shrink
    let same_sign = (grad_i > T::ZERO) == (*v > T::ZERO);
    *g = if same_sign {
        (*g * T::from_f64(0.8)).max_r(min_gain)
    } else {
        *g + T::from_f64(0.2)
    };
    *v = momentum * *v - eta * *g * grad_i;
    *yy += *v;
}

/// Subtract the mean so the embedding stays centered.
pub fn recenter<T: Real>(pool: &ThreadPool, y: &mut [T]) {
    let n = y.len() / 2;
    if n == 0 {
        return;
    }
    let mut mean = [T::ZERO; 2];
    for i in 0..n {
        mean[0] += y[2 * i];
        mean[1] += y[2 * i + 1];
    }
    let inv = T::ONE / T::from_usize(n);
    mean[0] *= inv;
    mean[1] *= inv;
    let ys = SyncSlice::new(y);
    parallel_for(pool, n, Schedule::Static, |range| {
        for i in range {
            // SAFETY: disjoint — slots 2i, 2i+1
            unsafe {
                *ys.get_mut(2 * i) -= mean[0];
                *ys.get_mut(2 * i + 1) -= mean[1];
            }
        }
    });
}

/// Random N(0, 1e-4) initial embedding (vdMaaten's initialization).
pub fn random_init<T: Real>(n: usize, seed: u64) -> Vec<T> {
    let mut rng = Rng::new(seed);
    (0..2 * n).map(|_| T::from_f64(rng.next_gaussian() * 1e-4)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_moves_against_gradient() {
        let pool = ThreadPool::new(2);
        let mut opt = Optimizer::<f64>::new(2, UpdateParams::default());
        let mut y = vec![0.0, 0.0, 1.0, 1.0];
        let grad = vec![1.0, 0.0, -1.0, 0.0];
        let y0 = y.clone();
        opt.step(&pool, 0, &grad, &mut y);
        // displacement (before recentring both moved oppositely): y0 moved -x, y1 moved +x
        let d0 = y[0] - y0[0];
        let d1 = y[2] - y0[2];
        assert!(d0 < d1, "relative motion must follow -grad: {d0} vs {d1}");
    }

    #[test]
    fn recenter_zeroes_mean() {
        let pool = ThreadPool::new(2);
        let mut y = vec![1.0, 2.0, 3.0, 6.0, 5.0, 10.0];
        recenter(&pool, &mut y);
        let mx: f64 = (0..3).map(|i| y[2 * i]).sum();
        let my: f64 = (0..3).map(|i| y[2 * i + 1]).sum();
        assert!(mx.abs() < 1e-12 && my.abs() < 1e-12);
    }

    #[test]
    fn gains_grow_on_sign_flip_and_clamp() {
        let pool = ThreadPool::new(1);
        let mut opt = Optimizer::<f64>::new(1, UpdateParams::default());
        let mut y = vec![0.0, 0.0];
        // First step establishes velocity sign; gradient positive → v negative.
        opt.step(&pool, 0, &[1.0, 1.0], &mut y);
        let g_after_1 = opt.gains[0];
        // Same-sign gradient again: v<0, grad>0 → signs differ → gain grows.
        opt.step(&pool, 1, &[1.0, 1.0], &mut y);
        assert!(opt.gains[0] > g_after_1);
        // Hammer with alternating huge gradients; gains must stay ≥ min_gain.
        for it in 2..60 {
            let s = if it % 2 == 0 { 1.0 } else { -1.0 };
            opt.step(&pool, it, &[s, s], &mut y);
        }
        assert!(opt.gains.iter().all(|&g| g >= 0.01));
    }

    #[test]
    fn exaggeration_schedule() {
        let opt = Optimizer::<f64>::new(1, UpdateParams::default());
        assert_eq!(opt.exaggeration(0), 12.0);
        assert_eq!(opt.exaggeration(249), 12.0);
        assert_eq!(opt.exaggeration(250), 1.0);
    }

    #[test]
    fn momentum_switch() {
        let pool = ThreadPool::new(1);
        let params = UpdateParams::default();
        let mut opt = Optimizer::<f64>::new(1, params);
        let mut y = vec![0.0, 0.0];
        // constant gradient: velocity magnitude grows with momentum
        for it in 0..5 {
            opt.step(&pool, it, &[1.0, 0.0], &mut y);
        }
        let v_early = opt.velocity[0].abs();
        for it in 250..255 {
            opt.step(&pool, it, &[1.0, 0.0], &mut y);
        }
        let v_late = opt.velocity[0].abs();
        assert!(v_late > v_early, "higher momentum accumulates more velocity");
    }

    #[test]
    fn fused_step_equals_combine_then_step() {
        use crate::common::rng::Rng;
        use crate::gradient::combine_gradient;
        let pool = ThreadPool::new(3);
        let n = 37;
        let mut rng = Rng::new(11);
        let attr: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();
        let rep: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 4.0).collect();
        let z = 3.7;
        let mut opt_a = Optimizer::<f64>::new(n, UpdateParams::default());
        let mut ya: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 1e-2).collect();
        let mut opt_b = opt_a.clone();
        let mut yb = ya.clone();
        let mut grad = vec![0.0f64; 2 * n];
        // spans the exaggeration/momentum switch at iter 250
        for iter in [0usize, 1, 5, 249, 250, 400] {
            combine_gradient(&pool, &attr, &rep, z, opt_a.exaggeration(iter), &mut grad);
            opt_a.step(&pool, iter, &grad, &mut ya);
            let norm_sq = opt_b.fused_combine_step(&pool, iter, &attr, &rep, z, &mut yb);
            // bitwise: the fused sweep must be arithmetically identical
            assert_eq!(ya, yb, "iter {iter}");
            assert_eq!(opt_a.velocity, opt_b.velocity, "iter {iter}");
            assert_eq!(opt_a.gains, opt_b.gains, "iter {iter}");
            // the returned squared norm matches the gradient vector (up to
            // chunked-summation FP noise)
            let want: f64 = grad.iter().map(|g| g * g).sum();
            assert!(
                (norm_sq - want).abs() <= 1e-10 * want.max(1.0),
                "iter {iter}: {norm_sq} vs {want}"
            );
        }
    }

    #[test]
    fn fused_norm_is_deterministic_across_calls() {
        use crate::common::rng::Rng;
        let pool = ThreadPool::new(4);
        let n = 501; // deliberately not a multiple of the thread count
        let mut rng = Rng::new(3);
        let attr: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();
        let rep: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();
        let run = || {
            let mut opt = Optimizer::<f64>::new(n, UpdateParams::default());
            let mut y = vec![0.25f64; 2 * n];
            opt.fused_combine_step(&pool, 3, &attr, &rep, 1.7, &mut y)
        };
        assert_eq!(run(), run(), "chunk-ordered reduction must be bit-stable");
    }

    #[test]
    fn random_init_scale() {
        let y = random_init::<f64>(1000, 42);
        assert_eq!(y.len(), 2000);
        let var: f64 = y.iter().map(|v| v * v).sum::<f64>() / 2000.0;
        assert!((var.sqrt() - 1e-4).abs() < 2e-5, "std {}", var.sqrt());
    }
}
