//! Repulsive force computation (pipeline step 6, paper §3.5): Barnes-Hut
//! traversal of the summarized quadtree.
//!
//! For each point, a DFS from the root; a cell is accepted as a single
//! pseudo-point when it satisfies Eq. 9, `r_cell² < θ² · ‖y_i − y_cell‖²`
//! (the vdMaaten squared form with `r_cell` = cell side length). Accepted
//! cells contribute `count · q²` to the force and `count · q` to the
//! normalization Z, with `q = (1+d²)⁻¹`.
//!
//! The layout story (the paper's §3.5 claim): traversal order = the tree's
//! point layout. On a morton tree the per-thread point batches are Z-order
//! neighbors that visit nearly the same nodes, which sit contiguously in
//! memory — measured as `tree_layout` in `bench_micro_kernels`.

use super::super::quadtree::{QuadTree, NO_CHILD};
use crate::common::float::Real;
use crate::parallel::{SyncSlice, ThreadPool};

/// Result of the repulsive step: raw (un-normalized) forces per point in
/// ORIGINAL index order, and the accumulated normalization Z.
pub struct Repulsion<T: Real> {
    pub raw: Vec<T>,
    pub z: T,
}

/// Compute BH-approximate repulsive accumulations for all points.
///
/// `theta` is the paper's θ accuracy knob (0.5 default; 0 = exact traversal).
pub fn repulsive_forces<T: Real>(pool: &ThreadPool, tree: &QuadTree<T>, theta: f64) -> Repulsion<T> {
    let n = tree.n_points();
    let theta_sq = T::from_f64(theta * theta);
    let mut raw = vec![T::ZERO; 2 * n];
    let nt = pool.n_threads();
    let mut z_parts = vec![T::ZERO; nt];
    {
        let rs = SyncSlice::new(&mut raw);
        let zs = SyncSlice::new(&mut z_parts);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            let mut stack: Vec<u32> = Vec::with_capacity(128);
            let mut z_local = T::ZERO;
            // Walk points in layout order (Z-order on morton trees): adjacent
            // points traverse nearly identical node sets.
            for p in s..e {
                let yix = tree.point_pos[2 * p];
                let yiy = tree.point_pos[2 * p + 1];
                let (fx, fy, z) = point_repulsion(tree, p, yix, yiy, theta_sq, &mut stack);
                z_local += z;
                let orig = tree.point_idx[p] as usize;
                // disjoint: each layout slot has a unique original index
                unsafe {
                    *rs.get_mut(2 * orig) = fx;
                    *rs.get_mut(2 * orig + 1) = fy;
                }
            }
            // disjoint: slot tid
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let mut z = T::ZERO;
    for zp in z_parts {
        z += zp;
    }
    Repulsion { raw, z }
}

#[inline]
fn point_repulsion<T: Real>(
    tree: &QuadTree<T>,
    p: usize,
    yix: T,
    yiy: T,
    theta_sq: T,
    stack: &mut Vec<u32>,
) -> (T, T, T) {
    let mut fx = T::ZERO;
    let mut fy = T::ZERO;
    let mut z = T::ZERO;
    stack.clear();
    stack.push(0);
    while let Some(ni) = stack.pop() {
        let node = &tree.nodes[ni as usize];
        let dx = yix - node.com[0];
        let dy = yiy - node.com[1];
        let dist_sq = dx * dx + dy * dy;
        let w = node.width;
        if node.is_leaf() {
            // Leaf: usually one point; multiple only for (near-)duplicates.
            let (s, e) = (node.point_start as usize, node.point_end as usize);
            if s <= p && p < e {
                // own leaf: iterate, skipping self
                for t in s..e {
                    if t == p {
                        continue;
                    }
                    let ddx = yix - tree.point_pos[2 * t];
                    let ddy = yiy - tree.point_pos[2 * t + 1];
                    let q = T::ONE / (T::ONE + ddx * ddx + ddy * ddy);
                    z += q;
                    let qq = q * q;
                    fx += qq * ddx;
                    fy += qq * ddy;
                }
            } else if e - s == 1 {
                let q = T::ONE / (T::ONE + dist_sq);
                z += q;
                let qq = q * q;
                fx += qq * dx;
                fy += qq * dy;
            } else {
                // foreign multi-point leaf: all points share (almost) one
                // location — the COM approximation is exact at grid resolution.
                let cnt = T::from_usize(node.count as usize);
                let q = T::ONE / (T::ONE + dist_sq);
                z += cnt * q;
                let qq = q * q;
                fx += cnt * qq * dx;
                fy += cnt * qq * dy;
            }
        } else if w * w < theta_sq * dist_sq {
            // Eq. 9 satisfied: summary stands in for the whole cell.
            let cnt = T::from_usize(node.count as usize);
            let q = T::ONE / (T::ONE + dist_sq);
            z += cnt * q;
            let qq = q * q;
            fx += cnt * qq * dx;
            fy += cnt * qq * dy;
        } else {
            for &c in &node.children {
                if c != NO_CHILD {
                    stack.push(c as u32);
                }
            }
        }
    }
    (fx, fy, z)
}

#[cfg(test)]
mod tests {
    use super::super::exact::exact_repulsive;
    use super::*;
    use crate::common::rng::Rng;
    use crate::quadtree::builder_baseline::build_baseline;
    use crate::quadtree::builder_morton::build_morton;
    use crate::quadtree::summarize::{summarize_parallel, summarize_sequential};

    fn random_y(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 3.0).collect()
    }

    #[test]
    fn theta_zero_matches_exact() {
        let n = 400;
        let y = random_y(n, 1);
        let pool = ThreadPool::new(4);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let got = repulsive_forces(&pool, &tree, 0.0);
        let (want, want_z) = exact_repulsive(&pool, &y);
        assert!(
            (got.z - want_z).abs() < 1e-9 * want_z,
            "Z {} vs {}",
            got.z,
            want_z
        );
        for i in 0..2 * n {
            assert!(
                (got.raw[i] - want[i]).abs() < 1e-9 * (1.0 + want[i].abs()),
                "idx {i}: {} vs {}",
                got.raw[i],
                want[i]
            );
        }
    }

    #[test]
    fn theta_half_approximates_exact() {
        let n = 1500;
        let y = random_y(n, 2);
        let pool = ThreadPool::new(4);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let got = repulsive_forces(&pool, &tree, 0.5);
        let (want, want_z) = exact_repulsive(&pool, &y);
        // Z within 1%
        assert!((got.z - want_z).abs() < 0.01 * want_z, "Z {} vs {want_z}", got.z);
        // force field within a few % in RMS
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..2 * n {
            num += (got.raw[i] - want[i]) * (got.raw[i] - want[i]);
            den += want[i] * want[i];
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "relative RMS error {rel}");
    }

    #[test]
    fn baseline_and_morton_trees_agree() {
        let n = 800;
        let y = random_y(n, 3);
        let pool = ThreadPool::new(4);
        let mut tm = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tm);
        let mut tb = build_baseline(&pool, &y);
        summarize_sequential(&mut tb);
        let a = repulsive_forces(&pool, &tm, 0.5);
        let b = repulsive_forces(&pool, &tb, 0.5);
        assert!((a.z - b.z).abs() < 1e-6 * a.z);
        for i in 0..2 * n {
            assert!(
                (a.raw[i] - b.raw[i]).abs() < 1e-6 * (1.0 + a.raw[i].abs()),
                "idx {i}"
            );
        }
    }

    #[test]
    fn duplicates_no_self_interaction_blowup() {
        let mut y = random_y(100, 4);
        for i in 0..10 {
            y[2 * i] = 1.5;
            y[2 * i + 1] = -2.5;
        }
        let pool = ThreadPool::new(2);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let rep = repulsive_forces(&pool, &tree, 0.5);
        assert!(rep.raw.iter().all(|v| v.is_finite()));
        assert!(rep.z.is_finite() && rep.z > 0.0);
        // Z counts ordered pairs: must be < n(n-1)
        assert!(rep.z < (100.0 * 99.0));
    }

    #[test]
    fn two_points_repel_directly() {
        let y = vec![0.0, 0.0, 1.0, 0.0];
        let pool = ThreadPool::new(1);
        let mut tree = build_morton(&pool, &y);
        summarize_sequential(&mut tree);
        let rep = repulsive_forces(&pool, &tree, 0.5);
        // raw_0 = (1+1)⁻² * (0-1) = -0.25 on x
        assert!((rep.raw[0] - (-0.25)).abs() < 1e-12);
        assert!((rep.raw[2] - 0.25).abs() < 1e-12);
        // Z = 2 * (1+1)⁻¹ = 1
        assert!((rep.z - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let y = random_y(600, 5);
        let pool1 = ThreadPool::new(1);
        let pool8 = ThreadPool::new(8);
        let mut t1 = build_morton(&pool1, &y);
        summarize_sequential(&mut t1);
        let mut t8 = build_morton(&pool8, &y);
        summarize_parallel(&pool8, &mut t8);
        let a = repulsive_forces(&pool1, &t1, 0.5);
        let b = repulsive_forces(&pool8, &t8, 0.5);
        // structures may be stitched differently; forces must agree to fp noise
        for i in 0..y.len() {
            assert!((a.raw[i] - b.raw[i]).abs() < 1e-10 * (1.0 + a.raw[i].abs()));
        }
    }
}
