//! Repulsive force computation (pipeline step 6, paper §3.5): Barnes-Hut
//! traversal of the summarized quadtree.
//!
//! For each point, a DFS from the root; a cell is accepted as a single
//! pseudo-point when it satisfies Eq. 9, `r_cell² < θ² · ‖y_i − y_cell‖²`
//! (the vdMaaten squared form with `r_cell` = cell side length). Accepted
//! cells contribute `count · q²` to the force and `count · q` to the
//! normalization Z, with `q = (1+d²)⁻¹`.
//!
//! Two kernels compute the same per-point accept sets:
//!
//! - [`RepulsiveVariant::Scalar`] — one point at a time, AoS `Node` reads:
//!   the daal4py-style loop the paper starts from.
//! - [`RepulsiveVariant::SimdTiled`] — the paper's §3.5 headline kernel:
//!   tiles of 8 (f64) / 16 (f32) Z-order-adjacent points traverse the tree
//!   *together* over the SoA [`TraversalView`]. Every stack entry carries an
//!   active-lane mask; the Eq. 9 test runs per lane (`std::simd` compare),
//!   lanes that accept a cell take the `count·q²` contribution via masked
//!   select, and only the lanes that reject descend into the children
//!   (shared descend, per-lane accept — the same batching trick as
//!   t-SNE-CUDA's warp traversal, on CPU vectors). Node data is splat-loaded
//!   from the dense SoA arrays, so a visit costs three cache lines instead
//!   of a scattered 70-byte struct read. Per lane, the accepted set and the
//!   accumulation order are *identical* to the scalar DFS, so the two
//!   variants agree to FP noise (the parity proptests assert 1e-10).
//!
//! The layout story (the paper's §3.5 claim): traversal order = the tree's
//! point layout. On a morton tree the points of a tile are Z-order neighbors
//! that visit nearly the same nodes — exactly why the shared-frontier tile
//! traversal does little extra work over the scalar DFS — measured as
//! `tree_layout` and `repulsive_kernel` in `bench_micro_kernels`.

use crate::common::float::Real;
use crate::parallel::{SyncSlice, ThreadPool};
use crate::quadtree::view::{TraversalView, NO_NODE};
use crate::quadtree::{QuadTree, NO_CHILD};
use std::simd::cmp::{SimdPartialEq, SimdPartialOrd};
use std::simd::num::SimdFloat;
use std::simd::{f32x16, f64x8, i32x16, i64x8, Mask};

/// Which repulsive kernel runs (a [`StagePlan`](crate::tsne::StagePlan)
/// knob; the compat wrappers also accept it via `TsneConfig::repulsive`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepulsiveVariant {
    /// Per-point scalar DFS over AoS nodes.
    Scalar,
    /// Tile-batched masked-SIMD DFS over the SoA traversal view.
    SimdTiled,
}

impl RepulsiveVariant {
    pub fn name(self) -> &'static str {
        match self {
            RepulsiveVariant::Scalar => "scalar",
            RepulsiveVariant::SimdTiled => "simd-tiled",
        }
    }

    /// [`FromStr`](std::str::FromStr) without the error payload.
    pub fn from_name(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl std::fmt::Display for RepulsiveVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for RepulsiveVariant {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(RepulsiveVariant::Scalar),
            "simd-tiled" | "tiled" | "simd" => Ok(RepulsiveVariant::SimdTiled),
            _ => Err(format!(
                "unknown repulsive variant '{s}' (expected: scalar, simd-tiled)"
            )),
        }
    }
}

/// Variant dispatcher writing into a caller-owned buffer; returns Z. All
/// repulsive entry points are allocation-free `_into` APIs (the old
/// `repulsive_forces` compatibility wrapper that allocated per call is gone;
/// benches and tests own their buffers like the pipeline does).
/// `theta` is the paper's θ accuracy knob (0.5 default; 0 = exact traversal).
/// `view` is required for [`RepulsiveVariant::SimdTiled`] (built once per
/// iteration after summarize); passing `None` there materializes a throwaway
/// view — correct, but the per-iteration callers should reuse one.
pub fn repulsive_forces_into<T: RepulsiveSimd>(
    pool: &ThreadPool,
    tree: &QuadTree<T>,
    view: Option<&TraversalView<T>>,
    theta: f64,
    variant: RepulsiveVariant,
    raw: &mut [T],
) -> T {
    match variant {
        RepulsiveVariant::Scalar => repulsive_forces_scalar_into(pool, tree, theta, raw),
        RepulsiveVariant::SimdTiled => match view {
            Some(v) => repulsive_forces_tiled_into(pool, tree, v, theta, raw),
            None => {
                let v = TraversalView::of(tree);
                repulsive_forces_tiled_into(pool, tree, &v, theta, raw)
            }
        },
    }
}

/// Scalar kernel into a caller-owned `raw` buffer (`2n`, original order).
pub fn repulsive_forces_scalar_into<T: Real>(
    pool: &ThreadPool,
    tree: &QuadTree<T>,
    theta: f64,
    raw: &mut [T],
) -> T {
    let n = tree.n_points();
    assert_eq!(raw.len(), 2 * n, "raw buffer must be 2n");
    let theta_sq = T::from_f64(theta * theta);
    let nt = pool.n_threads();
    let mut z_parts = vec![T::ZERO; nt];
    {
        let rs = SyncSlice::new(raw);
        let zs = SyncSlice::new(&mut z_parts);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            let mut stack: Vec<u32> = Vec::with_capacity(128);
            let mut z_local = T::ZERO;
            // Walk points in layout order (Z-order on morton trees): adjacent
            // points traverse nearly identical node sets.
            for p in s..e {
                let yix = tree.point_pos[2 * p];
                let yiy = tree.point_pos[2 * p + 1];
                let (fx, fy, z) = point_repulsion(tree, p, yix, yiy, theta_sq, &mut stack);
                z_local += z;
                let orig = tree.point_idx[p] as usize;
                // SAFETY: disjoint — each layout slot has a unique original index
                unsafe {
                    *rs.get_mut(2 * orig) = fx;
                    *rs.get_mut(2 * orig + 1) = fy;
                }
            }
            // SAFETY: disjoint — slot tid
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let mut z = T::ZERO;
    for zp in z_parts {
        z += zp;
    }
    z
}

/// Tile-batched SIMD kernel into a caller-owned `raw` buffer; returns Z.
/// `view` must mirror `tree` (same build + summarize).
pub fn repulsive_forces_tiled_into<T: RepulsiveSimd>(
    pool: &ThreadPool,
    tree: &QuadTree<T>,
    view: &TraversalView<T>,
    theta: f64,
    raw: &mut [T],
) -> T {
    let n = tree.n_points();
    assert_eq!(raw.len(), 2 * n, "raw buffer must be 2n");
    assert_eq!(view.n_nodes(), tree.nodes.len(), "view must mirror tree");
    let theta_sq = T::from_f64(theta * theta);
    let lanes = T::LANES;
    let n_tiles = n.div_ceil(lanes);
    let nt = pool.n_threads();
    let mut z_parts = vec![T::ZERO; nt];
    {
        let rs = SyncSlice::new(raw);
        let zs = SyncSlice::new(&mut z_parts);
        pool.broadcast(|tid| {
            // Tiles are Z-order-contiguous point groups; static chunking keeps
            // each thread on one contiguous span of the layout (cache story
            // identical to the scalar kernel's).
            let (ts, te) = crate::parallel::par_for::static_chunk(n_tiles, nt, tid);
            let mut stack: Vec<(u32, u64)> = Vec::with_capacity(256);
            let mut fx_buf = vec![T::ZERO; lanes];
            let mut fy_buf = vec![T::ZERO; lanes];
            let mut z_local = T::ZERO;
            for t in ts..te {
                let start = t * lanes;
                let len = lanes.min(n - start);
                z_local += T::tile_repulsion(
                    view,
                    &tree.point_pos,
                    start,
                    len,
                    theta_sq,
                    &mut stack,
                    &mut fx_buf,
                    &mut fy_buf,
                );
                for l in 0..len {
                    let orig = tree.point_idx[start + l] as usize;
                    // SAFETY: disjoint — each layout slot has a unique original index
                    unsafe {
                        *rs.get_mut(2 * orig) = fx_buf[l];
                        *rs.get_mut(2 * orig + 1) = fy_buf[l];
                    }
                }
            }
            // SAFETY: disjoint — slot tid
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let mut z = T::ZERO;
    for zp in z_parts {
        z += zp;
    }
    z
}

#[inline]
fn point_repulsion<T: Real>(
    tree: &QuadTree<T>,
    p: usize,
    yix: T,
    yiy: T,
    theta_sq: T,
    stack: &mut Vec<u32>,
) -> (T, T, T) {
    let mut fx = T::ZERO;
    let mut fy = T::ZERO;
    let mut z = T::ZERO;
    stack.clear();
    stack.push(0);
    while let Some(ni) = stack.pop() {
        let node = &tree.nodes[ni as usize];
        let dx = yix - node.com[0];
        let dy = yiy - node.com[1];
        let dist_sq = dx * dx + dy * dy;
        let w = node.width;
        if node.is_leaf() {
            // Leaf: usually one point; multiple only for (near-)duplicates.
            let (s, e) = (node.point_start as usize, node.point_end as usize);
            if s <= p && p < e {
                // own leaf: iterate, skipping self
                for t in s..e {
                    if t == p {
                        continue;
                    }
                    let ddx = yix - tree.point_pos[2 * t];
                    let ddy = yiy - tree.point_pos[2 * t + 1];
                    let q = T::ONE / (T::ONE + ddx * ddx + ddy * ddy);
                    z += q;
                    let qq = q * q;
                    fx += qq * ddx;
                    fy += qq * ddy;
                }
            } else if e - s == 1 {
                let q = T::ONE / (T::ONE + dist_sq);
                z += q;
                let qq = q * q;
                fx += qq * dx;
                fy += qq * dy;
            } else {
                // foreign multi-point leaf: all points share (almost) one
                // location — the COM approximation is exact at grid resolution.
                let cnt = T::from_usize(node.count as usize);
                let q = T::ONE / (T::ONE + dist_sq);
                z += cnt * q;
                let qq = q * q;
                fx += cnt * qq * dx;
                fy += cnt * qq * dy;
            }
        } else if w * w < theta_sq * dist_sq {
            // Eq. 9 satisfied: summary stands in for the whole cell.
            let cnt = T::from_usize(node.count as usize);
            let q = T::ONE / (T::ONE + dist_sq);
            z += cnt * q;
            let qq = q * q;
            fx += cnt * qq * dx;
            fy += cnt * qq * dy;
        } else {
            for &c in &node.children {
                if c != NO_CHILD {
                    stack.push(c as u32);
                }
            }
        }
    }
    (fx, fy, z)
}

/// Software prefetch of a node's traversal-hot SoA rows (the PR-1 follow-up).
///
/// Children are pushed onto the shared-frontier stack up to three pops before
/// they are visited (LIFO: the last child pushed is visited immediately, its
/// siblings after that subtree drains), so issuing the loads at push time
/// hides most of the five-SoA-row visit cost (com_x/com_y/width_sq/count +
/// the children block) once the view outgrows L2
/// (≥ ~100k-node trees, i.e. n ≳ 65k). Measured on the BENCH_repulsive.json
/// trend (`repulsive_kernel` group, CI snapshot): neutral at the 20k-node
/// CI size where the view is L2-resident, low-single-digit-% wins on the
/// 200k default where it is not; kept because the descend is bound by the
/// dependent child-row loads, not by instruction issue.
#[inline(always)]
fn prefetch_view_node<T: Real>(view: &TraversalView<T>, ni: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint with no memory effects; any address is
    // sound, and `ni` is a node index the traversal visits right after.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch(view.com_x.as_ptr().add(ni) as *const i8, _MM_HINT_T0);
        _mm_prefetch(view.com_y.as_ptr().add(ni) as *const i8, _MM_HINT_T0);
        _mm_prefetch(view.width_sq.as_ptr().add(ni) as *const i8, _MM_HINT_T0);
        _mm_prefetch(view.count.as_ptr().add(ni) as *const i8, _MM_HINT_T0);
        _mm_prefetch(view.children.as_ptr().add(4 * ni) as *const i8, _MM_HINT_T0);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (view, ni);
    }
}

/// Per-type tile kernel: one tile of ≤ LANES layout-adjacent points against
/// the whole tree. Writes per-lane forces into `fx_out`/`fy_out[..tile_len]`
/// and returns the tile's Z contribution.
pub trait RepulsiveSimd: Real {
    #[allow(clippy::too_many_arguments)]
    fn tile_repulsion(
        view: &TraversalView<Self>,
        point_pos: &[Self],
        tile_start: usize,
        tile_len: usize,
        theta_sq: Self,
        stack: &mut Vec<(u32, u64)>,
        fx_out: &mut [Self],
        fy_out: &mut [Self],
    ) -> Self;
}

macro_rules! impl_rep_simd {
    ($t:ty, $vec:ty, $ivec:ty, $ielem:ty, $mask:ty, $lanes:expr) => {
        impl RepulsiveSimd for $t {
            fn tile_repulsion(
                view: &TraversalView<$t>,
                point_pos: &[$t],
                tile_start: usize,
                tile_len: usize,
                theta_sq: $t,
                stack: &mut Vec<(u32, u64)>,
                fx_out: &mut [$t],
                fy_out: &mut [$t],
            ) -> $t {
                debug_assert!(1 <= tile_len && tile_len <= $lanes);
                // Lane coordinates; tail lanes replicate the last point but
                // start outside the active mask, so they contribute nothing.
                let mut xs = [0.0 as $t; $lanes];
                let mut ys = [0.0 as $t; $lanes];
                let mut ids_a = [-1 as $ielem; $lanes];
                for l in 0..$lanes {
                    let p = tile_start + l.min(tile_len - 1);
                    xs[l] = point_pos[2 * p];
                    ys[l] = point_pos[2 * p + 1];
                    if l < tile_len {
                        ids_a[l] = (tile_start + l) as $ielem;
                    }
                }
                let px = <$vec>::from_array(xs);
                let py = <$vec>::from_array(ys);
                let ids = <$ivec>::from_array(ids_a);
                let active0: u64 = (1u64 << tile_len) - 1;
                let vtheta = <$vec>::splat(theta_sq);
                let one = <$vec>::splat(1.0);
                let zero = <$vec>::splat(0.0);
                let mut fx = zero;
                let mut fy = zero;
                let mut zacc = zero;
                stack.clear();
                stack.push((0, active0));
                while let Some((ni, act_bits)) = stack.pop() {
                    let ni = ni as usize;
                    let act = <$mask>::from_bitmask(act_bits);
                    let dx = px - <$vec>::splat(view.com_x[ni]);
                    let dy = py - <$vec>::splat(view.com_y[ni]);
                    let dist_sq = dx * dx + dy * dy;
                    if view.is_leaf(ni) {
                        let s = view.leaf_start[ni];
                        let e = view.leaf_end[ni];
                        // Lanes whose own point lies inside this leaf walk its
                        // points exactly (skipping self); the rest take the
                        // count·COM stand-in — identical to the scalar paths
                        // (for a 1-point foreign leaf, COM IS the point).
                        let contained = ids.simd_ge(<$ivec>::splat(s as $ielem))
                            & ids.simd_lt(<$ivec>::splat(e as $ielem));
                        let foreign = act & !contained;
                        if foreign.any() {
                            let cnt = <$vec>::splat(view.count[ni]);
                            let q = one / (one + dist_sq);
                            zacc += foreign.select(cnt * q, zero);
                            let qq = q * q;
                            fx += foreign.select(cnt * qq * dx, zero);
                            fy += foreign.select(cnt * qq * dy, zero);
                        }
                        let own = act & contained;
                        if own.any() {
                            for p in s..e {
                                let p = p as usize;
                                let m = own & ids.simd_ne(<$ivec>::splat(p as $ielem));
                                if !m.any() {
                                    continue;
                                }
                                let ddx = px - <$vec>::splat(point_pos[2 * p]);
                                let ddy = py - <$vec>::splat(point_pos[2 * p + 1]);
                                let q = one / (one + ddx * ddx + ddy * ddy);
                                zacc += m.select(q, zero);
                                let qq = q * q;
                                fx += m.select(qq * ddx, zero);
                                fy += m.select(qq * ddy, zero);
                            }
                        }
                    } else {
                        // Eq. 9 per lane: accept takes the summary, the rest
                        // descend together (shared frontier).
                        let wsq = <$vec>::splat(view.width_sq[ni]);
                        let accept = wsq.simd_lt(vtheta * dist_sq);
                        let take = act & accept;
                        if take.any() {
                            let cnt = <$vec>::splat(view.count[ni]);
                            let q = one / (one + dist_sq);
                            zacc += take.select(cnt * q, zero);
                            let qq = q * q;
                            fx += take.select(cnt * qq * dx, zero);
                            fy += take.select(cnt * qq * dy, zero);
                        }
                        let descend = (act & !accept).to_bitmask();
                        if descend != 0 {
                            for &c in &view.children[4 * ni..4 * ni + 4] {
                                if c != NO_NODE {
                                    prefetch_view_node(view, c as usize);
                                    stack.push((c, descend));
                                }
                            }
                        }
                    }
                }
                let fxa = fx.to_array();
                let fya = fy.to_array();
                fx_out[..tile_len].copy_from_slice(&fxa[..tile_len]);
                fy_out[..tile_len].copy_from_slice(&fya[..tile_len]);
                zacc.reduce_sum()
            }
        }
    };
}

impl_rep_simd!(f64, f64x8, i64x8, i64, Mask<i64, 8>, 8);
impl_rep_simd!(f32, f32x16, i32x16, i32, Mask<i32, 16>, 16);

#[cfg(test)]
mod tests {
    use super::super::exact::exact_repulsive;
    use super::*;
    use crate::common::rng::Rng;
    use crate::quadtree::builder_baseline::build_baseline;
    use crate::quadtree::builder_morton::build_morton;
    use crate::quadtree::summarize::{summarize_parallel, summarize_sequential};

    fn random_y(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 3.0).collect()
    }

    /// Local (raw forces, Z) bundle — the tests own their buffers and call
    /// the `_into` APIs directly, like every production caller.
    struct Rep<T: Real> {
        raw: Vec<T>,
        z: T,
    }

    fn scalar<T: Real>(pool: &ThreadPool, tree: &QuadTree<T>, theta: f64) -> Rep<T> {
        let mut raw = vec![T::ZERO; 2 * tree.n_points()];
        let z = repulsive_forces_scalar_into(pool, tree, theta, &mut raw);
        Rep { raw, z }
    }

    fn tiled<T: RepulsiveSimd>(pool: &ThreadPool, tree: &QuadTree<T>, theta: f64) -> Rep<T> {
        let view = TraversalView::of(tree);
        let mut raw = vec![T::ZERO; 2 * tree.n_points()];
        let z = repulsive_forces_tiled_into(pool, tree, &view, theta, &mut raw);
        Rep { raw, z }
    }

    #[test]
    fn theta_zero_matches_exact() {
        let n = 400;
        let y = random_y(n, 1);
        let pool = ThreadPool::new(4);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let (want, want_z) = exact_repulsive(&pool, &y);
        for variant in [RepulsiveVariant::Scalar, RepulsiveVariant::SimdTiled] {
            let got = match variant {
                RepulsiveVariant::Scalar => scalar(&pool, &tree, 0.0),
                RepulsiveVariant::SimdTiled => tiled(&pool, &tree, 0.0),
            };
            assert!(
                (got.z - want_z).abs() < 1e-9 * want_z,
                "{}: Z {} vs {}",
                variant.name(),
                got.z,
                want_z
            );
            for i in 0..2 * n {
                assert!(
                    (got.raw[i] - want[i]).abs() < 1e-9 * (1.0 + want[i].abs()),
                    "{} idx {i}: {} vs {}",
                    variant.name(),
                    got.raw[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn theta_half_approximates_exact() {
        let n = 1500;
        let y = random_y(n, 2);
        let pool = ThreadPool::new(4);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let got = scalar(&pool, &tree, 0.5);
        let (want, want_z) = exact_repulsive(&pool, &y);
        // Z within 1%
        assert!((got.z - want_z).abs() < 0.01 * want_z, "Z {} vs {want_z}", got.z);
        // force field within a few % in RMS
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..2 * n {
            num += (got.raw[i] - want[i]) * (got.raw[i] - want[i]);
            den += want[i] * want[i];
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "relative RMS error {rel}");
    }

    #[test]
    fn tiled_matches_scalar_tightly() {
        // The acceptance bar: per-lane accept sets and accumulation order are
        // identical to the scalar DFS, so agreement is FP-noise-tight.
        for (n, seed) in [(63, 10), (64, 11), (65, 12), (1000, 13), (2500, 14)] {
            let y = random_y(n, seed);
            let pool = ThreadPool::new(4);
            let mut tree = build_morton(&pool, &y);
            summarize_parallel(&pool, &mut tree);
            for theta in [0.0, 0.5] {
                let a = scalar(&pool, &tree, theta);
                let b = tiled(&pool, &tree, theta);
                assert!(
                    (a.z - b.z).abs() <= 1e-10 * a.z.abs().max(1.0),
                    "n={n} θ={theta}: Z {} vs {}",
                    a.z,
                    b.z
                );
                for i in 0..2 * n {
                    assert!(
                        (a.raw[i] - b.raw[i]).abs() <= 1e-10 * (1.0 + a.raw[i].abs()),
                        "n={n} θ={theta} idx {i}: {} vs {}",
                        a.raw[i],
                        b.raw[i]
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_f32_matches_scalar_f32() {
        let n = 777;
        let y64 = random_y(n, 21);
        let y: Vec<f32> = y64.iter().map(|&v| v as f32).collect();
        let pool = ThreadPool::new(4);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let a = scalar(&pool, &tree, 0.5);
        let view = TraversalView::of(&tree);
        let mut raw = vec![0.0f32; 2 * n];
        let z = repulsive_forces_tiled_into(&pool, &tree, &view, 0.5, &mut raw);
        assert!((a.z - z).abs() <= 1e-4 * a.z.abs().max(1.0), "Z {} vs {z}", a.z);
        for i in 0..2 * n {
            assert!(
                (a.raw[i] - raw[i]).abs() <= 1e-4 * (1.0 + a.raw[i].abs()),
                "idx {i}: {} vs {}",
                a.raw[i],
                raw[i]
            );
        }
    }

    #[test]
    fn dispatcher_builds_view_on_demand() {
        let n = 300;
        let y = random_y(n, 22);
        let pool = ThreadPool::new(2);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        let mut a = vec![0.0; 2 * n];
        let mut b = vec![0.0; 2 * n];
        let za =
            repulsive_forces_into(&pool, &tree, None, 0.5, RepulsiveVariant::SimdTiled, &mut a);
        let view = TraversalView::of(&tree);
        let zb = repulsive_forces_into(
            &pool,
            &tree,
            Some(&view),
            0.5,
            RepulsiveVariant::SimdTiled,
            &mut b,
        );
        assert_eq!(za, zb);
        assert_eq!(a, b);
    }

    #[test]
    fn baseline_and_morton_trees_agree() {
        let n = 800;
        let y = random_y(n, 3);
        let pool = ThreadPool::new(4);
        let mut tm = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tm);
        let mut tb = build_baseline(&pool, &y);
        summarize_sequential(&mut tb);
        let a = scalar(&pool, &tm, 0.5);
        let b = scalar(&pool, &tb, 0.5);
        assert!((a.z - b.z).abs() < 1e-6 * a.z);
        for i in 0..2 * n {
            assert!(
                (a.raw[i] - b.raw[i]).abs() < 1e-6 * (1.0 + a.raw[i].abs()),
                "idx {i}"
            );
        }
        // the tiled kernel also works on baseline (BFS-layout) trees
        let c = tiled(&pool, &tb, 0.5);
        for i in 0..2 * n {
            assert!(
                (b.raw[i] - c.raw[i]).abs() <= 1e-10 * (1.0 + b.raw[i].abs()),
                "tiled-on-baseline idx {i}"
            );
        }
    }

    #[test]
    fn duplicates_no_self_interaction_blowup() {
        let mut y = random_y(100, 4);
        for i in 0..10 {
            y[2 * i] = 1.5;
            y[2 * i + 1] = -2.5;
        }
        let pool = ThreadPool::new(2);
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        for rep in [scalar(&pool, &tree, 0.5), tiled(&pool, &tree, 0.5)] {
            assert!(rep.raw.iter().all(|v| v.is_finite()));
            assert!(rep.z.is_finite() && rep.z > 0.0);
            // Z counts ordered pairs: must be < n(n-1)
            assert!(rep.z < (100.0 * 99.0));
        }
    }

    #[test]
    fn two_points_repel_directly() {
        let y = vec![0.0, 0.0, 1.0, 0.0];
        let pool = ThreadPool::new(1);
        let mut tree = build_morton(&pool, &y);
        summarize_sequential(&mut tree);
        for rep in [scalar(&pool, &tree, 0.5), tiled(&pool, &tree, 0.5)] {
            // raw_0 = (1+1)⁻² * (0-1) = -0.25 on x
            assert!((rep.raw[0] - (-0.25)).abs() < 1e-12);
            assert!((rep.raw[2] - 0.25).abs() < 1e-12);
            // Z = 2 * (1+1)⁻¹ = 1
            assert!((rep.z - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn single_point_has_zero_force_and_z() {
        let y = vec![0.25, -0.75];
        let pool = ThreadPool::new(1);
        let mut tree = build_morton(&pool, &y);
        summarize_sequential(&mut tree);
        let rep = tiled(&pool, &tree, 0.5);
        assert_eq!(rep.raw, vec![0.0, 0.0]);
        assert_eq!(rep.z, 0.0);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let y = random_y(600, 5);
        let pool1 = ThreadPool::new(1);
        let pool8 = ThreadPool::new(8);
        let mut t1 = build_morton(&pool1, &y);
        summarize_sequential(&mut t1);
        let mut t8 = build_morton(&pool8, &y);
        summarize_parallel(&pool8, &mut t8);
        // structures may be stitched differently; forces must agree to fp noise
        let a = scalar(&pool1, &t1, 0.5);
        let b = scalar(&pool8, &t8, 0.5);
        for i in 0..y.len() {
            assert!((a.raw[i] - b.raw[i]).abs() < 1e-10 * (1.0 + a.raw[i].abs()));
        }
        let c = tiled(&pool1, &t1, 0.5);
        let d = tiled(&pool8, &t8, 0.5);
        for i in 0..y.len() {
            assert!((c.raw[i] - d.raw[i]).abs() < 1e-10 * (1.0 + c.raw[i].abs()));
        }
    }
}
