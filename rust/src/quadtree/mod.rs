//! Quadtree construction (pipeline step 3) and summarization (step 4).
//!
//! Two builders produce the same [`QuadTree`] structure over the same
//! power-of-2 subdivision of the bounding square, differing exactly the way
//! daal4py and Acc-t-SNE differ in the paper:
//!
//! - [`builder_baseline`] — daal4py-style: level-by-level BFS; every split
//!   re-partitions the points of the cell, so each point is touched once per
//!   level of its leaf depth; sequential.
//! - [`builder_morton`] — Acc-t-SNE: morton-encode (Alg. 1), parallel radix
//!   sort, then each point is touched once; top levels built sequentially
//!   until there are ≥ 8×threads nodes, whole subtrees then built in parallel
//!   with dynamic scheduling, each stored contiguously; point coordinates are
//!   gathered into Z-order so leaf ranges are contiguous memory.
//!
//! [`summarize`] computes centers-of-mass bottom-up, sequential (daal4py) or
//! parallel (Acc-t-SNE) — step 4 of the pipeline.
//!
//! [`view`] flattens a summarized tree into the SoA [`view::TraversalView`]
//! (`com_x[] / com_y[] / width_sq[] / count[]` plus dense `u32` child and
//! leaf-range arrays): the layout the tile-batched SIMD repulsive kernel
//! ([`crate::gradient::repulsive`]) traverses. The AoS [`Node`] stays the
//! build/summarize representation; the view is materialized once per
//! iteration after summarize and its buffers are reused.

pub mod builder_baseline;
pub mod builder_morton;
pub mod morton;
pub mod summarize;
pub mod view;

use crate::common::float::Real;

/// Sentinel for "no child".
pub const NO_CHILD: i32 = -1;

/// A quadtree node. `children` indexes into `QuadTree::nodes`; a leaf has all
/// children == [`NO_CHILD`] and owns the gathered point range
/// `point_start..point_end` (more than one point only when the depth cap hit,
/// i.e. (near-)duplicate coordinates).
#[derive(Clone, Debug)]
pub struct Node<T: Real> {
    pub children: [i32; 4],
    /// Points in this subtree.
    pub count: u32,
    pub point_start: u32,
    pub point_end: u32,
    /// Geometric center of the square cell.
    pub center: [T; 2],
    /// Full side length of the cell (the paper's `r_cell` in Eq. 9).
    pub width: T,
    /// Center of mass — filled by [`summarize`].
    pub com: [T; 2],
}

impl<T: Real> Node<T> {
    #[inline(always)]
    pub fn is_leaf(&self) -> bool {
        self.children == [NO_CHILD; 4]
    }
}

/// A built quadtree. `point_pos`/`point_idx` hold the points in the builder's
/// layout order (Z-order for the morton builder, BFS-discovery order for the
/// baseline); leaves reference ranges of these arrays.
#[derive(Clone, Debug)]
pub struct QuadTree<T: Real> {
    pub nodes: Vec<Node<T>>,
    /// Interleaved x,y of the points in layout order.
    pub point_pos: Vec<T>,
    /// Original index of each laid-out point.
    pub point_idx: Vec<u32>,
    /// Roots of the parallel-built subtrees (morton builder; empty for
    /// baseline). Disjoint; their subtrees cover everything below the
    /// sequential top region.
    pub subtree_roots: Vec<u32>,
    /// Maximum node depth actually reached.
    pub depth: usize,
}

impl<T: Real> QuadTree<T> {
    #[inline]
    pub fn n_points(&self) -> usize {
        self.point_idx.len()
    }

    #[inline]
    pub fn root(&self) -> &Node<T> {
        &self.nodes[0]
    }

    /// The build's layout permutation: `layout_order()[slot]` is the index —
    /// in the coordinate slice the builder was given — of the point stored at
    /// `slot` of `point_pos` (Z-order for the morton builder, BFS-discovery
    /// order for the baseline). The Z-order-persistent gradient loop composes
    /// this into its global permutation instead of re-deriving it.
    #[inline]
    pub fn layout_order(&self) -> &[u32] {
        &self.point_idx
    }

    /// Number of points stored at a different slot than in the input order —
    /// 0 ⇔ the input was already in this tree's layout order. The gradient
    /// loop compares this against its re-permutation (adoption) threshold.
    pub fn layout_drift(&self) -> usize {
        self.point_idx
            .iter()
            .enumerate()
            .filter(|&(slot, &src)| src as usize != slot)
            .count()
    }

    /// Structural invariants — used heavily by tests/proptests:
    /// child counts sum to parent count, leaf point ranges partition the
    /// point array, every original index appears once, cell geometry nests.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_points();
        if self.nodes.is_empty() {
            return Err("no nodes".into());
        }
        if self.root().count as usize != n {
            return Err(format!("root count {} != n {}", self.root().count, n));
        }
        let mut seen = vec![false; n];
        let mut covered = 0usize;
        for (ni, node) in self.nodes.iter().enumerate() {
            if node.is_leaf() {
                let (s, e) = (node.point_start as usize, node.point_end as usize);
                if e <= s || e > n {
                    return Err(format!("leaf {ni} bad range {s}..{e}"));
                }
                if (e - s) as u32 != node.count {
                    return Err(format!("leaf {ni} count {} != range {}", node.count, e - s));
                }
                covered += e - s;
                for p in s..e {
                    let orig = self.point_idx[p] as usize;
                    if orig >= n || seen[orig] {
                        return Err(format!("point {orig} duplicated or out of range"));
                    }
                    seen[orig] = true;
                    // point inside cell (with fp slack); non-finite
                    // coordinates clamp to the grid edge during encoding, so
                    // containment is meaningless for them
                    let half = node.width.to_f64() * 0.5 * (1.0 + 1e-6) + 1e-9;
                    for d in 0..2 {
                        let v = self.point_pos[2 * p + d].to_f64();
                        if !v.is_finite() {
                            continue;
                        }
                        let c = node.center[d].to_f64();
                        if (v - c).abs() > half {
                            return Err(format!(
                                "leaf {ni}: point {p} dim {d} outside cell ({v} vs {c}±{half})"
                            ));
                        }
                    }
                }
            } else {
                let mut child_count = 0u32;
                for (q, &c) in node.children.iter().enumerate() {
                    if c == NO_CHILD {
                        continue;
                    }
                    let child = &self.nodes[c as usize];
                    child_count += child.count;
                    let w_ratio = node.width.to_f64() / child.width.to_f64();
                    if (w_ratio - 2.0).abs() > 1e-6 {
                        return Err(format!("node {ni} child {q}: width ratio {w_ratio}"));
                    }
                    // child center in the right quadrant
                    let dx = child.center[0].to_f64() - node.center[0].to_f64();
                    let dy = child.center[1].to_f64() - node.center[1].to_f64();
                    let want_dx = if q & 1 == 1 { 1.0 } else { -1.0 };
                    let want_dy = if q & 2 == 2 { 1.0 } else { -1.0 };
                    if dx.signum() != want_dx || dy.signum() != want_dy {
                        return Err(format!("node {ni} child {q} in wrong quadrant"));
                    }
                }
                if child_count != node.count {
                    return Err(format!(
                        "node {ni}: children sum {child_count} != count {}",
                        node.count
                    ));
                }
            }
        }
        if covered != n {
            return Err(format!("leaves cover {covered} of {n} points"));
        }
        Ok(())
    }

    /// Worst per-node COM error vs a direct recompute from children
    /// (post-summarize consistency check).
    pub fn com_residual(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for node in &self.nodes {
            if node.is_leaf() {
                continue;
            }
            let mut acc = [0.0f64; 2];
            let mut cnt = 0.0f64;
            for &c in &node.children {
                if c == NO_CHILD {
                    continue;
                }
                let ch = &self.nodes[c as usize];
                for d in 0..2 {
                    acc[d] += ch.com[d].to_f64() * ch.count as f64;
                }
                cnt += ch.count as f64;
            }
            for d in 0..2 {
                let want = acc[d] / cnt;
                worst = worst.max((node.com[d].to_f64() - want).abs());
            }
        }
        worst
    }
}

/// Statistics used by benches/EXPERIMENTS to characterize trees.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TreeStats {
    pub nodes: usize,
    pub leaves: usize,
    pub depth: usize,
    pub max_leaf_points: usize,
}

pub fn tree_stats<T: Real>(tree: &QuadTree<T>) -> TreeStats {
    let mut s = TreeStats {
        nodes: tree.nodes.len(),
        depth: tree.depth,
        ..Default::default()
    };
    for n in &tree.nodes {
        if n.is_leaf() {
            s.leaves += 1;
            s.max_leaf_points = s.max_leaf_points.max((n.point_end - n.point_start) as usize);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::builder_morton::build_morton;
    use super::*;
    use crate::common::rng::Rng;
    use crate::parallel::ThreadPool;

    #[test]
    fn stats_and_validate_on_small_tree() {
        let mut rng = Rng::new(1);
        let pos: Vec<f64> = (0..2 * 500).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        let st = tree_stats(&tree);
        assert!(st.leaves >= 500 / 4);
        assert!(st.depth >= 2);
        assert_eq!(tree.n_points(), 500);
    }
}
