//! Morton (Z-order) codes — paper §3.3, Algorithm 1.
//!
//! A 2-D point is mapped to a 64-bit code by scaling each coordinate to a
//! 32-bit integer grid over the root cell and bit-interleaving the two
//! dimensions (dim 0 on even bits, dim 1 on odd bits). Sorted codes give the
//! Z-order: points close in 2-D are close in the sorted order, a quadtree cell
//! is a contiguous code range, and the level-ℓ quadrant digit is the ℓ-th
//! 2-bit group from the top.
//!
//! Three implementations, all bit-identical:
//! - [`interleave_bits`] / [`morton2`] — scalar magic-mask cascade (Alg. 1 lines 8–21);
//! - [`encode_points`] — parallel scalar loop (compiler auto-vectorizes, as the paper notes);
//! - [`encode_points_simd`] — explicit `std::simd` u64×8 lanes.

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use std::simd::cmp::SimdOrd;
use std::simd::num::SimdFloat;
use std::simd::{f64x8, u64x8};

/// Levels resolvable by a 64-bit code with 32 bits per dimension.
pub const MAX_LEVEL: usize = 32;

/// Spread the low 32 bits of `v` onto the even bit positions (Alg. 1 lines 9–18).
#[inline(always)]
pub fn interleave_bits(v: u64) -> u64 {
    let mut m = v & 0x0000_0000_FFFF_FFFF;
    m = (m | (m << 16)) & 0x0000_FFFF_0000_FFFF;
    m = (m | (m << 8)) & 0x00FF_00FF_00FF_00FF;
    m = (m | (m << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    m = (m | (m << 2)) & 0x3333_3333_3333_3333;
    m = (m | (m << 1)) & 0x5555_5555_5555_5555;
    m
}

/// Inverse of [`interleave_bits`] (collect even bits back into the low 32).
#[inline(always)]
pub fn deinterleave_bits(v: u64) -> u64 {
    let mut m = v & 0x5555_5555_5555_5555;
    m = (m | (m >> 1)) & 0x3333_3333_3333_3333;
    m = (m | (m >> 2)) & 0x0F0F_0F0F_0F0F_0F0F;
    m = (m | (m >> 4)) & 0x00FF_00FF_00FF_00FF;
    m = (m | (m >> 8)) & 0x0000_FFFF_0000_FFFF;
    m = (m | (m >> 16)) & 0x0000_0000_FFFF_FFFF;
    m
}

/// Morton code of integer grid coordinates (x on even bits, y on odd).
#[inline(always)]
pub fn morton2(x: u64, y: u64) -> u64 {
    interleave_bits(x) | (interleave_bits(y) << 1)
}

/// Grid geometry of the root cell: the square centred at `cent` with
/// half-extent `r_span` (the "maximum span radius" of Alg. 1).
#[derive(Clone, Copy, Debug)]
pub struct RootCell {
    pub cent: [f64; 2],
    pub r_span: f64,
}

impl RootCell {
    /// Bounding square of a point set (paper: boundaries from min/max of Y).
    /// Expands the span slightly so the max point stays inside the open cell.
    ///
    /// Degenerate-geometry contract: the returned cell is always finite.
    /// Non-finite coordinates are excluded from the extents (their points
    /// clamp to the grid edge at encode time), an all-coincident cloud gets
    /// the minimal positive span instead of a zero cell, and extents so wide
    /// their difference would overflow are capped — `scale()` never divides
    /// by zero, infinity, or NaN.
    pub fn bounding<T: Real>(pool: &ThreadPool, pos: &[T]) -> RootCell {
        let n = pos.len() / 2;
        assert!(n > 0, "empty point set");
        let nt = pool.n_threads();
        let mut mins = vec![[f64::INFINITY; 2]; nt];
        let mut maxs = vec![[f64::NEG_INFINITY; 2]; nt];
        {
            let ms = SyncSlice::new(&mut mins);
            let xs = SyncSlice::new(&mut maxs);
            pool.broadcast(|tid| {
                let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
                let mut lo = [f64::INFINITY; 2];
                let mut hi = [f64::NEG_INFINITY; 2];
                for i in s..e {
                    for d in 0..2 {
                        let v = pos[2 * i + d].to_f64();
                        if v.is_finite() {
                            lo[d] = lo[d].min(v);
                            hi[d] = hi[d].max(v);
                        }
                    }
                }
                // SAFETY: disjoint — slot tid
                unsafe {
                    *ms.get_mut(tid) = lo;
                    *xs.get_mut(tid) = hi;
                }
            });
        }
        let mut lo = [f64::INFINITY; 2];
        let mut hi = [f64::NEG_INFINITY; 2];
        for t in 0..nt {
            for d in 0..2 {
                lo[d] = lo[d].min(mins[t][d]);
                hi[d] = hi[d].max(maxs[t][d]);
            }
        }
        Self::from_extents(lo, hi)
    }

    /// Sequential sibling of [`Self::bounding`] for the small-n builder path
    /// (no pool dispatch). Min/max reductions are order-independent, so the
    /// two produce identical cells.
    pub fn bounding_seq<T: Real>(pos: &[T]) -> RootCell {
        let n = pos.len() / 2;
        assert!(n > 0, "empty point set");
        let mut lo = [f64::INFINITY; 2];
        let mut hi = [f64::NEG_INFINITY; 2];
        for i in 0..n {
            for d in 0..2 {
                let v = pos[2 * i + d].to_f64();
                if v.is_finite() {
                    lo[d] = lo[d].min(v);
                    hi[d] = hi[d].max(v);
                }
            }
        }
        Self::from_extents(lo, hi)
    }

    /// Root square from per-dimension extents, with every non-finite escape
    /// hatch closed: a dimension that saw no finite coordinate (lo > hi)
    /// centers at 0; the halved-before-subtracting span cannot overflow and
    /// is floored for coincident clouds and capped so the 1e-9 inflation
    /// stays finite.
    fn from_extents(lo: [f64; 2], hi: [f64; 2]) -> RootCell {
        let mut cent = [0.0f64; 2];
        let mut span = f64::MIN_POSITIVE;
        for d in 0..2 {
            if lo[d] <= hi[d] {
                cent[d] = lo[d] * 0.5 + hi[d] * 0.5;
                span = span.max((hi[d] * 0.5 - lo[d] * 0.5).min(f64::MAX * 0.25));
            }
        }
        RootCell {
            cent,
            r_span: span * (1.0 + 1e-9),
        }
    }

    /// Scale factor of Alg. 1 line 5 (we use 32 significant bits per dim:
    /// grid coordinate = (y − y_root) · 2³¹ / r_span ∈ [0, 2³²)).
    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << 31) as f64 / self.r_span
    }

    /// Morton code of a single point (scalar reference path).
    #[inline]
    pub fn encode(&self, x: f64, y: f64) -> u64 {
        let scale = self.scale();
        let gx = clamp_grid((x - (self.cent[0] - self.r_span)) * scale);
        let gy = clamp_grid((y - (self.cent[1] - self.r_span)) * scale);
        morton2(gx, gy)
    }
}

const GRID_MAX: u64 = u32::MAX as u64;

#[inline(always)]
fn clamp_grid(v: f64) -> u64 {
    if v <= 0.0 {
        0
    } else if v >= GRID_MAX as f64 {
        GRID_MAX
    } else {
        v as u64
    }
}

/// Parallel scalar encoding of all points (`pos` is interleaved x0,y0,x1,y1,…).
pub fn encode_points<T: Real>(pool: &ThreadPool, pos: &[T], root: &RootCell, out: &mut [u64]) {
    let n = pos.len() / 2;
    assert_eq!(out.len(), n);
    let os = SyncSlice::new(out);
    parallel_for(pool, n, Schedule::Static, |range| {
        for i in range {
            let code = root.encode(pos[2 * i].to_f64(), pos[2 * i + 1].to_f64());
            // SAFETY: disjoint — slot i
            unsafe { *os.get_mut(i) = code };
        }
    });
}

/// Explicit-SIMD encoding: 8 points per iteration with `u64x8` lanes
/// (the paper's "SIMD parallelism … and explicit multithreading").
pub fn encode_points_simd<T: Real>(pool: &ThreadPool, pos: &[T], root: &RootCell, out: &mut [u64]) {
    let n = pos.len() / 2;
    assert_eq!(out.len(), n);
    let scale = f64x8::splat(root.scale());
    let x0 = f64x8::splat(root.cent[0] - root.r_span);
    let y0 = f64x8::splat(root.cent[1] - root.r_span);
    let zero = f64x8::splat(0.0);
    let gmax = u64x8::splat(GRID_MAX);
    let os = SyncSlice::new(out);
    parallel_for(pool, n / 8, Schedule::Static, |range| {
        let mut xs = [0.0f64; 8];
        let mut ys = [0.0f64; 8];
        for blk in range {
            let base = blk * 8;
            for l in 0..8 {
                xs[l] = pos[2 * (base + l)].to_f64();
                ys[l] = pos[2 * (base + l) + 1].to_f64();
            }
            let gx = ((f64x8::from_array(xs) - x0) * scale)
                .simd_max(zero)
                .cast::<u64>()
                .simd_min(gmax);
            let gy = ((f64x8::from_array(ys) - y0) * scale)
                .simd_max(zero)
                .cast::<u64>()
                .simd_min(gmax);
            let code = interleave_simd(gx) | (interleave_simd(gy) << u64x8::splat(1));
            for l in 0..8 {
                // SAFETY: disjoint — slots base..base+8 owned by this block
                unsafe { *os.get_mut(base + l) = code[l] };
            }
        }
    });
    // Scalar tail.
    for i in (n / 8) * 8..n {
        out[i] = root.encode(pos[2 * i].to_f64(), pos[2 * i + 1].to_f64());
    }
}

#[inline(always)]
fn interleave_simd(v: u64x8) -> u64x8 {
    let mut m = v & u64x8::splat(0x0000_0000_FFFF_FFFF);
    m = (m | (m << u64x8::splat(16))) & u64x8::splat(0x0000_FFFF_0000_FFFF);
    m = (m | (m << u64x8::splat(8))) & u64x8::splat(0x00FF_00FF_00FF_00FF);
    m = (m | (m << u64x8::splat(4))) & u64x8::splat(0x0F0F_0F0F_0F0F_0F0F);
    m = (m | (m << u64x8::splat(2))) & u64x8::splat(0x3333_3333_3333_3333);
    m = (m | (m << u64x8::splat(1))) & u64x8::splat(0x5555_5555_5555_5555);
    m
}

/// Quadrant digit (0..4) of `code` at tree `level` (level 0 = root split).
/// Bit 0 of the digit is dim 0 (x), bit 1 is dim 1 (y).
#[inline(always)]
pub fn quadrant_at(code: u64, level: usize) -> usize {
    debug_assert!(level < MAX_LEVEL);
    ((code >> (2 * (MAX_LEVEL - 1 - level))) & 3) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn paper_example_dim_values() {
        // Paper: dim0 = 3 (011b), dim1 = 7 (111b) → morton 101111b = 47.
        assert_eq!(morton2(3, 7), 47);
    }

    #[test]
    fn interleave_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let v = rng.next_u64() & 0xFFFF_FFFF;
            assert_eq!(deinterleave_bits(interleave_bits(v)), v);
        }
    }

    #[test]
    fn interleave_only_even_bits() {
        for v in [0u64, 1, 0xFFFF_FFFF, 0xDEAD_BEEF] {
            assert_eq!(interleave_bits(v) & 0xAAAA_AAAA_AAAA_AAAA, 0);
        }
    }

    #[test]
    fn z_order_preserves_locality() {
        // Points in the same quadrant share the top digit.
        let root = RootCell {
            cent: [0.0, 0.0],
            r_span: 1.0,
        };
        let q_of = |x: f64, y: f64| quadrant_at(root.encode(x, y), 0);
        assert_eq!(q_of(-0.5, -0.5), 0); // (low x, low y)
        assert_eq!(q_of(0.5, -0.5), 1); // (high x, low y)
        assert_eq!(q_of(-0.5, 0.5), 2);
        assert_eq!(q_of(0.5, 0.5), 3);
    }

    #[test]
    fn codes_monotone_along_diagonal() {
        let root = RootCell {
            cent: [0.0, 0.0],
            r_span: 1.0,
        };
        let mut prev = 0u64;
        for i in 0..100 {
            let t = -0.99 + 1.98 * i as f64 / 99.0;
            let c = root.encode(t, t);
            assert!(c >= prev, "diagonal must be non-decreasing in z-order");
            prev = c;
        }
    }

    #[test]
    fn clamping_handles_out_of_cell_points() {
        let root = RootCell {
            cent: [0.0, 0.0],
            r_span: 1.0,
        };
        let lo = root.encode(-100.0, -100.0);
        let hi = root.encode(100.0, 100.0);
        assert_eq!(lo, 0);
        assert_eq!(hi, morton2(GRID_MAX, GRID_MAX));
    }

    #[test]
    fn simd_matches_scalar() {
        let mut rng = Rng::new(42);
        let n = 1003; // non-multiple of 8 → exercises tail
        let pos: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 5.0).collect();
        let pool = ThreadPool::new(4);
        let root = RootCell::bounding(&pool, &pos);
        let mut scalar = vec![0u64; n];
        let mut simd = vec![0u64; n];
        encode_points(&pool, &pos, &root, &mut scalar);
        encode_points_simd(&pool, &pos, &root, &mut simd);
        assert_eq!(scalar, simd);
    }

    #[test]
    fn bounding_cell_contains_all_points() {
        let mut rng = Rng::new(7);
        let pos: Vec<f64> = (0..400).map(|_| rng.next_gaussian() * 3.0 + 1.0).collect();
        let pool = ThreadPool::new(2);
        let root = RootCell::bounding(&pool, &pos);
        for i in 0..200 {
            for d in 0..2 {
                let v = pos[2 * i + d];
                assert!(v >= root.cent[d] - root.r_span && v <= root.cent[d] + root.r_span);
            }
        }
    }

    #[test]
    fn bounding_single_point_degenerate() {
        let pool = ThreadPool::new(1);
        let root = RootCell::bounding(&pool, &[1.0f64, 2.0]);
        assert!(root.r_span > 0.0);
        let _ = root.encode(1.0, 2.0); // must not panic
    }

    #[test]
    fn bounding_ignores_non_finite_coordinates() {
        let pool = ThreadPool::new(2);
        // finite x extents: {1.0, -1.0, 3.0}; finite y extents: {0.5, 2.0, -4.0}
        let pos = vec![f64::NAN, 0.5, 1.0, f64::INFINITY, -1.0, 2.0, 3.0, -4.0];
        let root = RootCell::bounding(&pool, &pos);
        assert_eq!(root.cent, [1.0, -1.0]);
        assert!(root.r_span.is_finite() && root.r_span > 0.0);
        let seq = RootCell::bounding_seq(&pos);
        assert_eq!(seq.cent, root.cent);
        assert_eq!(seq.r_span, root.r_span);
    }

    #[test]
    fn bounding_all_non_finite_defaults_to_origin() {
        let pool = ThreadPool::new(1);
        let pos = vec![f64::NAN; 6];
        let root = RootCell::bounding(&pool, &pos);
        assert_eq!(root.cent, [0.0, 0.0]);
        assert!(root.r_span.is_finite() && root.r_span > 0.0);
        let _ = root.encode(f64::NAN, f64::NAN); // must not panic
    }

    #[test]
    fn bounding_extreme_extents_stay_finite() {
        // ±1.5e308 extents: hi − lo would overflow to inf; the halved
        // subtraction plus the cap keep the cell and its scale finite.
        let pool = ThreadPool::new(2);
        let pos = vec![-1.5e308f64, 1.5e308, 1.5e308, -1.5e308];
        let root = RootCell::bounding(&pool, &pos);
        assert!(root.cent.iter().all(|c| c.is_finite()));
        assert!(root.r_span.is_finite() && root.r_span > 0.0);
        assert!(root.scale().is_finite() && root.scale() > 0.0);
    }

    #[test]
    fn quadrant_at_all_levels() {
        // code with alternating quadrants 0,1,2,3,0,1,...
        let mut code = 0u64;
        for l in 0..MAX_LEVEL {
            code |= ((l % 4) as u64) << (2 * (MAX_LEVEL - 1 - l));
        }
        for l in 0..MAX_LEVEL {
            assert_eq!(quadrant_at(code, l), l % 4);
        }
    }

    #[test]
    fn f32_encoding_consistent() {
        let pool = ThreadPool::new(2);
        let pos32: Vec<f32> = vec![0.25, 0.75, -0.5, -0.25, 0.0, 0.0];
        let root = RootCell::bounding(&pool, &pos32);
        let mut out = vec![0u64; 3];
        encode_points(&pool, &pos32, &root, &mut out);
        // sanity: distinct points → distinct codes
        assert_ne!(out[0], out[1]);
    }
}
