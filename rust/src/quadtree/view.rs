//! Flat SoA "traversal view" of a summarized [`QuadTree`] — the layout the
//! tile-batched repulsive kernel consumes.
//!
//! The AoS [`Node`](super::Node) struct is convenient to build but hostile to
//! a vectorized traversal: every Eq. 9 test touches a 70+-byte struct to read
//! four scalars. The view scatters exactly the traversal-hot fields into
//! dense parallel arrays indexed by node id:
//!
//! - `com_x` / `com_y` — center of mass (needs [`summarize`](super::summarize)
//!   to have run);
//! - `width_sq` — precomputed `r_cell²`, the left side of Eq. 9 (the scalar
//!   kernel recomputes `w·w` at every visit);
//! - `count` — subtree mass, pre-converted to the float type so the kernel
//!   multiplies without a per-visit int→float cast;
//! - `children` — 4 dense `u32` slots per node ([`NO_NODE`] = absent);
//! - `leaf_start` / `leaf_end` — gathered-point range of a leaf (empty range
//!   for internal nodes, so `is_leaf` is one comparison).
//!
//! One node's view data spans ≤ 48 bytes across six arrays instead of one
//! scattered struct read, and the splat loads of the tile kernel hit at most
//! three cache lines per visited node. The view is materialized once per
//! iteration (after summarize) and the buffers are reused across iterations
//! via [`TraversalView::rebuild`].

use super::{QuadTree, NO_CHILD};
use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Dense-array sentinel for "no child" (the SoA analog of [`NO_CHILD`]).
pub const NO_NODE: u32 = u32::MAX;

/// SoA mirror of the traversal-hot node fields. See the module docs.
#[derive(Clone, Debug)]
pub struct TraversalView<T: Real> {
    pub com_x: Vec<T>,
    pub com_y: Vec<T>,
    pub width_sq: Vec<T>,
    /// Subtree point count as the kernel's float type.
    pub count: Vec<T>,
    /// `children[4*i..4*i+4]`, [`NO_NODE`] where absent.
    pub children: Vec<u32>,
    /// Leaf point range into `QuadTree::point_pos`; `start == end` ⇔ internal.
    pub leaf_start: Vec<u32>,
    pub leaf_end: Vec<u32>,
}

impl<T: Real> Default for TraversalView<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Real> TraversalView<T> {
    /// Empty view; fill with [`rebuild`](Self::rebuild) before use.
    pub fn new() -> Self {
        TraversalView {
            com_x: Vec::new(),
            com_y: Vec::new(),
            width_sq: Vec::new(),
            count: Vec::new(),
            children: Vec::new(),
            leaf_start: Vec::new(),
            leaf_end: Vec::new(),
        }
    }

    /// One-shot construction from a summarized tree.
    pub fn of(tree: &QuadTree<T>) -> Self {
        let mut v = Self::new();
        v.rebuild(tree);
        v
    }

    #[inline(always)]
    pub fn n_nodes(&self) -> usize {
        self.width_sq.len()
    }

    #[inline(always)]
    pub fn is_leaf(&self, ni: usize) -> bool {
        self.leaf_start[ni] != self.leaf_end[ni]
    }

    /// Re-materialize from `tree` (sequential), reusing buffer capacity.
    /// `tree` must already be summarized — `com` is read as-is.
    pub fn rebuild(&mut self, tree: &QuadTree<T>) {
        self.resize_for(tree.nodes.len());
        for ni in 0..tree.nodes.len() {
            self.fill_node(ni, tree);
        }
    }

    /// Parallel re-materialization (the per-iteration path: the view is
    /// rebuilt after every tree build + summarize).
    pub fn rebuild_parallel(&mut self, pool: &ThreadPool, tree: &QuadTree<T>) {
        let n_nodes = tree.nodes.len();
        if pool.n_threads() == 1 || n_nodes < 4096 {
            self.rebuild(tree);
            return;
        }
        self.resize_for(n_nodes);
        // Split borrows field-by-field so threads can scatter disjoint slots.
        let cx = SyncSlice::new(&mut self.com_x);
        let cy = SyncSlice::new(&mut self.com_y);
        let wsq = SyncSlice::new(&mut self.width_sq);
        let cnt = SyncSlice::new(&mut self.count);
        let ch = SyncSlice::new(&mut self.children);
        let ls = SyncSlice::new(&mut self.leaf_start);
        let le = SyncSlice::new(&mut self.leaf_end);
        parallel_for(pool, n_nodes, Schedule::Static, |range| {
            for ni in range {
                let node = &tree.nodes[ni];
                // SAFETY: disjoint — slot ni (and 4ni..4ni+4) per node
                unsafe {
                    *cx.get_mut(ni) = node.com[0];
                    *cy.get_mut(ni) = node.com[1];
                    *wsq.get_mut(ni) = node.width * node.width;
                    *cnt.get_mut(ni) = T::from_usize(node.count as usize);
                    for (q, &c) in node.children.iter().enumerate() {
                        *ch.get_mut(4 * ni + q) = if c == NO_CHILD { NO_NODE } else { c as u32 };
                    }
                    let leaf = node.is_leaf();
                    *ls.get_mut(ni) = if leaf { node.point_start } else { 0 };
                    *le.get_mut(ni) = if leaf { node.point_end } else { 0 };
                }
            }
        });
    }

    fn resize_for(&mut self, n_nodes: usize) {
        // Every slot is overwritten; resize only adjusts lengths (capacity is
        // retained across iterations, so steady-state rebuilds never allocate).
        self.com_x.resize(n_nodes, T::ZERO);
        self.com_y.resize(n_nodes, T::ZERO);
        self.width_sq.resize(n_nodes, T::ZERO);
        self.count.resize(n_nodes, T::ZERO);
        self.children.resize(4 * n_nodes, NO_NODE);
        self.leaf_start.resize(n_nodes, 0);
        self.leaf_end.resize(n_nodes, 0);
    }

    #[inline]
    fn fill_node(&mut self, ni: usize, tree: &QuadTree<T>) {
        let node = &tree.nodes[ni];
        self.com_x[ni] = node.com[0];
        self.com_y[ni] = node.com[1];
        self.width_sq[ni] = node.width * node.width;
        self.count[ni] = T::from_usize(node.count as usize);
        for (q, &c) in node.children.iter().enumerate() {
            self.children[4 * ni + q] = if c == NO_CHILD { NO_NODE } else { c as u32 };
        }
        let leaf = node.is_leaf();
        self.leaf_start[ni] = if leaf { node.point_start } else { 0 };
        self.leaf_end[ni] = if leaf { node.point_end } else { 0 };
    }
}

#[cfg(test)]
mod tests {
    use super::super::builder_morton::build_morton;
    use super::super::summarize::summarize_parallel;
    use super::*;
    use crate::common::rng::Rng;
    use crate::parallel::ThreadPool;

    fn summarized_tree(n: usize, seed: u64, threads: usize) -> (ThreadPool, QuadTree<f64>) {
        let mut rng = Rng::new(seed);
        let pos: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian() * 4.0).collect();
        let pool = ThreadPool::new(threads);
        let mut tree = build_morton(&pool, &pos);
        summarize_parallel(&pool, &mut tree);
        (pool, tree)
    }

    fn assert_view_matches(view: &TraversalView<f64>, tree: &QuadTree<f64>) {
        assert_eq!(view.n_nodes(), tree.nodes.len());
        for (ni, node) in tree.nodes.iter().enumerate() {
            assert_eq!(view.com_x[ni], node.com[0], "node {ni} com_x");
            assert_eq!(view.com_y[ni], node.com[1], "node {ni} com_y");
            assert_eq!(view.width_sq[ni], node.width * node.width, "node {ni}");
            assert_eq!(view.count[ni], node.count as f64, "node {ni} count");
            assert_eq!(view.is_leaf(ni), node.is_leaf(), "node {ni} leafness");
            for q in 0..4 {
                let want = if node.children[q] == NO_CHILD {
                    NO_NODE
                } else {
                    node.children[q] as u32
                };
                assert_eq!(view.children[4 * ni + q], want, "node {ni} child {q}");
            }
            if node.is_leaf() {
                assert_eq!(view.leaf_start[ni], node.point_start);
                assert_eq!(view.leaf_end[ni], node.point_end);
            }
        }
    }

    #[test]
    fn view_mirrors_tree_fields() {
        let (_, tree) = summarized_tree(700, 1, 4);
        let view = TraversalView::of(&tree);
        assert_view_matches(&view, &tree);
    }

    #[test]
    fn parallel_rebuild_matches_sequential() {
        let (pool, tree) = summarized_tree(5000, 2, 8);
        let seq = TraversalView::of(&tree);
        let mut par = TraversalView::new();
        par.rebuild_parallel(&pool, &tree);
        assert_view_matches(&par, &tree);
        assert_eq!(seq.com_x, par.com_x);
        assert_eq!(seq.children, par.children);
    }

    #[test]
    fn rebuild_reuses_buffers_across_shrink_and_grow() {
        let (_, big) = summarized_tree(3000, 3, 2);
        let (_, small) = summarized_tree(50, 4, 2);
        let mut view = TraversalView::of(&big);
        view.rebuild(&small);
        assert_view_matches(&view, &small);
        view.rebuild(&big);
        assert_view_matches(&view, &big);
    }

    #[test]
    fn single_point_tree_is_one_leaf() {
        let pool = ThreadPool::new(1);
        let mut tree = build_morton(&pool, &[0.5f64, -0.5]);
        summarize_parallel(&pool, &mut tree);
        let view = TraversalView::of(&tree);
        assert_eq!(view.n_nodes(), 1);
        assert!(view.is_leaf(0));
        assert_eq!((view.leaf_start[0], view.leaf_end[0]), (0, 1));
    }
}
