//! Summarization (pipeline step 4, paper §3.4): center-of-mass of every cell,
//! bottom-up — a parent's COM needs only its children's COMs and counts.
//!
//! - [`summarize_sequential`] — daal4py's single-threaded pass (the paper's
//!   Fig 6a shows it not scaling at all).
//! - [`summarize_parallel`] — Acc-t-SNE: the morton tree's parallel-built
//!   subtrees are summarized concurrently with dynamic scheduling (post-order
//!   within each block, which is contiguous memory), then the small
//!   sequential top region is folded in reverse BFS order. This is the
//!   locality-aware equivalent of the paper's level-by-level parallel sweep:
//!   both process all independent nodes concurrently bottom-up; ours walks
//!   each contiguous subtree block on one thread instead of striding levels
//!   across blocks. Falls back to the sequential pass when the tree has no
//!   parallel blocks (baseline trees).

use super::{Node, QuadTree, NO_CHILD};
use crate::common::float::Real;
use crate::parallel::{parallel_for, SyncSlice, Schedule, ThreadPool};

/// Sequential bottom-up summarization (explicit-stack post-order).
pub fn summarize_sequential<T: Real>(tree: &mut QuadTree<T>) {
    let point_pos = std::mem::take(&mut tree.point_pos);
    post_order_summarize(&mut tree.nodes, &point_pos, 0);
    tree.point_pos = point_pos;
}

/// Parallel summarization: disjoint subtrees are summarized concurrently
/// (dynamic scheduling — subtree sizes vary wildly on clustered data, paper
/// §3.3), then the small top region is folded sequentially, skipping the
/// already-done subtree roots. Works on any tree layout: uses the morton
/// builder's recorded `subtree_roots` when present, otherwise derives a
/// frontier by BFS from the root.
pub fn summarize_parallel<T: Real>(pool: &ThreadPool, tree: &mut QuadTree<T>) {
    if pool.n_threads() == 1 || tree.nodes.len() < 512 {
        summarize_sequential(tree);
        return;
    }
    let roots: Vec<u32> = if tree.subtree_roots.is_empty() {
        bfs_frontier(&tree.nodes, 4 * pool.n_threads())
    } else {
        tree.subtree_roots.clone()
    };
    if roots.len() < 2 {
        summarize_sequential(tree);
        return;
    }
    let point_pos = std::mem::take(&mut tree.point_pos);
    {
        let nodes = SyncSlice::new(&mut tree.nodes);
        let point_pos = &point_pos;
        let roots = &roots;
        parallel_for(pool, roots.len(), Schedule::Dynamic { grain: 1 }, |range| {
            for si in range {
                // SAFETY: disjoint — the frontier subtrees cover disjoint node sets;
                // the top region is only touched after this barrier.
                let nodes_mut = unsafe { nodes.slice_mut(0, nodes.len()) };
                post_order_summarize_with_stops(nodes_mut, point_pos, roots[si] as usize, None);
            }
        });
    }
    // Top region: one more post-order from the root that treats the computed
    // subtree roots as leaves (layout-agnostic — no index-order assumption).
    let mut done = vec![false; tree.nodes.len()];
    for &r in &roots {
        done[r as usize] = true;
    }
    post_order_summarize_with_stops(&mut tree.nodes, &point_pos, 0, Some(&done));
    tree.point_pos = point_pos;
}

/// BFS from the root until the frontier holds ≥ `target` nodes (or nothing
/// expands). Returned nodes are roots of disjoint subtrees covering all
/// descendants below the visited top region.
fn bfs_frontier<T: Real>(nodes: &[Node<T>], target: usize) -> Vec<u32> {
    let mut frontier: Vec<u32> = vec![0];
    while frontier.len() < target {
        let mut next = Vec::with_capacity(frontier.len() * 4);
        let mut expanded = false;
        for &f in &frontier {
            let node = &nodes[f as usize];
            if node.is_leaf() {
                next.push(f);
            } else {
                expanded = true;
                for &c in &node.children {
                    if c != NO_CHILD {
                        next.push(c as u32);
                    }
                }
            }
        }
        if !expanded {
            break;
        }
        frontier = next;
    }
    frontier
}

#[inline]
fn leaf_com<T: Real>(node: &Node<T>, point_pos: &[T]) -> [T; 2] {
    let (s, e) = (node.point_start as usize, node.point_end as usize);
    let mut acc = [T::ZERO; 2];
    for p in s..e {
        acc[0] += point_pos[2 * p];
        acc[1] += point_pos[2 * p + 1];
    }
    let inv = T::ONE / T::from_usize(e - s);
    [acc[0] * inv, acc[1] * inv]
}

#[inline]
fn children_com<T: Real>(nodes: &[Node<T>], node: &Node<T>) -> [T; 2] {
    let mut acc = [T::ZERO; 2];
    let mut cnt = T::ZERO;
    for &c in &node.children {
        if c == NO_CHILD {
            continue;
        }
        let ch = &nodes[c as usize];
        let m = T::from_usize(ch.count as usize);
        acc[0] += ch.com[0] * m;
        acc[1] += ch.com[1] * m;
        cnt += m;
    }
    let inv = T::ONE / cnt;
    [acc[0] * inv, acc[1] * inv]
}

/// Iterative post-order COM computation of the subtree rooted at `root`.
fn post_order_summarize<T: Real>(nodes: &mut [Node<T>], point_pos: &[T], root: usize) {
    post_order_summarize_with_stops(nodes, point_pos, root, None);
}

/// As [`post_order_summarize`], but nodes marked in `stops` are treated as
/// already summarized (their `com` is read, not recomputed) — used to fold
/// the top region above the parallel frontier.
fn post_order_summarize_with_stops<T: Real>(
    nodes: &mut [Node<T>],
    point_pos: &[T],
    root: usize,
    stops: Option<&[bool]>,
) {
    // state: (node, next child slot to visit)
    let mut stack: Vec<(usize, usize)> = vec![(root, 0)];
    while let Some(&mut (ni, ref mut slot)) = stack.last_mut() {
        if *slot == 0 && stops.map(|s| s[ni]).unwrap_or(false) {
            stack.pop(); // already summarized by the parallel phase
            continue;
        }
        if nodes[ni].is_leaf() {
            nodes[ni].com = leaf_com(&nodes[ni], point_pos);
            stack.pop();
            continue;
        }
        // find next existing child
        let mut advanced = false;
        while *slot < 4 {
            let c = nodes[ni].children[*slot];
            *slot += 1;
            if c != NO_CHILD {
                stack.push((c as usize, 0));
                advanced = true;
                break;
            }
        }
        if !advanced {
            nodes[ni].com = children_com(nodes, &nodes[ni].clone());
            stack.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::builder_baseline::build_baseline;
    use super::super::builder_morton::build_morton;
    use super::*;
    use crate::common::rng::Rng;

    fn random_pos(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 2.0).collect()
    }

    fn global_mean(pos: &[f64]) -> [f64; 2] {
        let n = pos.len() / 2;
        let mut m = [0.0; 2];
        for i in 0..n {
            m[0] += pos[2 * i];
            m[1] += pos[2 * i + 1];
        }
        [m[0] / n as f64, m[1] / n as f64]
    }

    #[test]
    fn sequential_root_com_is_global_mean() {
        let pos = random_pos(800, 1);
        let pool = ThreadPool::new(2);
        let mut tree = build_morton(&pool, &pos);
        summarize_sequential(&mut tree);
        let want = global_mean(&pos);
        for d in 0..2 {
            assert!((tree.root().com[d] - want[d]).abs() < 1e-9);
        }
        assert!(tree.com_residual() < 1e-12);
    }

    #[test]
    fn parallel_matches_sequential() {
        let pos = random_pos(3000, 2);
        let pool = ThreadPool::new(6);
        let mut t_seq = build_morton(&pool, &pos);
        let mut t_par = t_seq.clone();
        summarize_sequential(&mut t_seq);
        summarize_parallel(&pool, &mut t_par);
        for (a, b) in t_seq.nodes.iter().zip(t_par.nodes.iter()) {
            for d in 0..2 {
                assert!((a.com[d] - b.com[d]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn parallel_on_baseline_tree_falls_back() {
        let pos = random_pos(500, 3);
        let pool = ThreadPool::new(4);
        let mut tree = build_baseline(&pool, &pos);
        summarize_parallel(&pool, &mut tree);
        let want = global_mean(&pos);
        for d in 0..2 {
            assert!((tree.root().com[d] - want[d]).abs() < 1e-9);
        }
        assert!(tree.com_residual() < 1e-12);
    }

    #[test]
    fn leaf_com_is_point_mean() {
        let pos = vec![1.0f64, 2.0, 1.0, 2.0, 4.0, 6.0]; // two dupes + one
        let pool = ThreadPool::new(1);
        let mut tree = build_morton(&pool, &pos);
        summarize_sequential(&mut tree);
        // root com = mean of all three
        assert!((tree.root().com[0] - 2.0).abs() < 1e-12);
        assert!((tree.root().com[1] - (10.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn clustered_subtrees_parallel_correct() {
        let mut rng = Rng::new(4);
        let mut pos = Vec::new();
        for c in 0..5 {
            for _ in 0..400 {
                pos.push(c as f64 * 10.0 + 0.01 * rng.next_gaussian());
                pos.push(c as f64 * -7.0 + 0.01 * rng.next_gaussian());
            }
        }
        let pool = ThreadPool::new(8);
        let mut tree = build_morton(&pool, &pos);
        summarize_parallel(&pool, &mut tree);
        assert!(tree.com_residual() < 1e-12);
        let want = global_mean(&pos);
        for d in 0..2 {
            assert!((tree.root().com[d] - want[d]).abs() < 1e-9);
        }
    }
}
