//! Baseline quadtree builder — the daal4py algorithm the paper profiles
//! (§3.3): level-by-level BFS where splitting a cell re-partitions all of its
//! points into the four quadrants, "so each point is traversed as many times
//! as the depth of the tree for that point". Sequential, like daal4py's.
//!
//! Produces the same [`QuadTree`] shape as the morton builder (same bounding
//! square, same power-of-2 subdivision) so summarization and repulsion run on
//! either; only construction cost and memory layout differ. Points end up
//! gathered in BFS-discovery order — the scattered layout whose DFS-traversal
//! cache behaviour the paper's Z-order layout improves on.

use super::morton::{RootCell, MAX_LEVEL};
use super::{Node, QuadTree, NO_CHILD};
use crate::common::float::Real;
use crate::parallel::ThreadPool;

struct Pending {
    node_idx: u32,
    /// Original indices of the points in this cell (re-partitioned per level —
    /// the O(N·depth) cost center of the baseline).
    points: Vec<u32>,
    level: usize,
    center: [f64; 2],
    width: f64,
}

/// Build the quadtree by level-by-level re-partitioning (daal4py style).
/// `pool` is only used to compute the bounding box (as daal4py does); the
/// construction itself is sequential.
pub fn build_baseline<T: Real>(pool: &ThreadPool, pos: &[T]) -> QuadTree<T> {
    let n = pos.len() / 2;
    assert!(n > 0, "cannot build a tree over zero points");
    let root_cell = RootCell::bounding(pool, pos);
    let root_width = 2.0 * root_cell.r_span;

    let mut nodes: Vec<Node<T>> = Vec::with_capacity(2 * n);
    nodes.push(new_node::<T>(n as u32, root_cell.cent, root_width));
    let mut point_pos = vec![T::ZERO; 2 * n];
    let mut point_idx = Vec::with_capacity(n);

    let mut frontier = vec![Pending {
        node_idx: 0,
        points: (0..n as u32).collect(),
        level: 0,
        center: root_cell.cent,
        width: root_width,
    }];
    let mut depth = 0usize;

    while !frontier.is_empty() {
        let mut next = Vec::new();
        for cell in frontier.drain(..) {
            depth = depth.max(cell.level);
            let is_leaf = cell.points.len() == 1
                || cell.level >= MAX_LEVEL
                || all_coincident(pos, &cell.points);
            if is_leaf {
                let start = point_idx.len() as u32;
                for &p in &cell.points {
                    point_pos[2 * point_idx.len()] = pos[2 * p as usize];
                    point_pos[2 * point_idx.len() + 1] = pos[2 * p as usize + 1];
                    point_idx.push(p);
                }
                let node = &mut nodes[cell.node_idx as usize];
                node.point_start = start;
                node.point_end = point_idx.len() as u32;
                continue;
            }
            // Re-partition: walk every point of the cell (the per-level cost).
            let mut buckets: [Vec<u32>; 4] = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
            for &p in &cell.points {
                let x = pos[2 * p as usize].to_f64();
                let y = pos[2 * p as usize + 1].to_f64();
                let q = usize::from(x >= cell.center[0]) | (usize::from(y >= cell.center[1]) << 1);
                buckets[q].push(p);
            }
            for (q, bucket) in buckets.into_iter().enumerate() {
                if bucket.is_empty() {
                    continue;
                }
                let (c_center, c_width) = child_geometry(cell.center, cell.width, q);
                let idx = nodes.len() as u32;
                nodes.push(new_node::<T>(bucket.len() as u32, c_center, c_width));
                nodes[cell.node_idx as usize].children[q] = idx as i32;
                next.push(Pending {
                    node_idx: idx,
                    points: bucket,
                    level: cell.level + 1,
                    center: c_center,
                    width: c_width,
                });
            }
        }
        frontier = next;
    }

    QuadTree {
        nodes,
        point_pos,
        point_idx,
        subtree_roots: Vec::new(),
        depth,
    }
}

fn new_node<T: Real>(count: u32, center: [f64; 2], width: f64) -> Node<T> {
    Node {
        children: [NO_CHILD; 4],
        count,
        point_start: 0,
        point_end: 0,
        center: [T::from_f64(center[0]), T::from_f64(center[1])],
        width: T::from_f64(width),
        com: [T::ZERO; 2],
    }
}

#[inline]
fn child_geometry(center: [f64; 2], width: f64, q: usize) -> ([f64; 2], f64) {
    let off = width * 0.25;
    (
        [
            center[0] + if q & 1 == 1 { off } else { -off },
            center[1] + if q & 2 == 2 { off } else { -off },
        ],
        width * 0.5,
    )
}

fn all_coincident<T: Real>(pos: &[T], points: &[u32]) -> bool {
    let p0 = points[0] as usize;
    points.iter().all(|&p| {
        let p = p as usize;
        pos[2 * p] == pos[2 * p0] && pos[2 * p + 1] == pos[2 * p0 + 1]
    })
}

#[cfg(test)]
mod tests {
    use super::super::builder_morton::build_morton;
    use super::*;
    use crate::common::rng::Rng;
    use crate::quadtree::tree_stats;

    fn random_pos(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 3.0).collect()
    }

    #[test]
    fn valid_on_random_points() {
        for n in [1, 2, 7, 333, 2000] {
            let pos = random_pos(n, n as u64 + 100);
            let pool = ThreadPool::new(2);
            let tree = build_baseline(&pool, &pos);
            tree.validate().unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn identical_points_terminate() {
        let pos = vec![0.5f64; 2 * 40];
        let pool = ThreadPool::new(1);
        let tree = build_baseline(&pool, &pos);
        tree.validate().unwrap();
        assert_eq!(tree.root().count, 40);
        assert!(tree.depth <= 1);
    }

    #[test]
    fn near_coincident_and_non_finite_points_terminate_with_finite_geometry() {
        let pool = ThreadPool::new(2);
        let mut pos = vec![0.0f64; 2 * 24];
        for i in 0..24 {
            pos[2 * i] = 0.5 + i as f64 * 1e-300;
            pos[2 * i + 1] = 0.5;
        }
        let tree = build_baseline(&pool, &pos);
        tree.validate().unwrap();
        // NaN never equals itself, so the coincidence cutoff cannot fire for
        // a poisoned cell — the depth cap must still terminate the build with
        // finite cell geometry.
        pos[3] = f64::NAN;
        pos[10] = f64::NEG_INFINITY;
        let tree = build_baseline(&pool, &pos);
        tree.validate().unwrap();
        assert_eq!(tree.root().count, 24);
        assert!(tree.nodes.iter().all(|nd| {
            nd.width.to_f64().is_finite() && nd.center.iter().all(|c| c.to_f64().is_finite())
        }));
    }

    #[test]
    fn same_leaf_partition_as_morton_builder() {
        // Both builders subdivide the same root square with the same rule, so
        // leaf point-sets must coincide (morton grid vs float comparisons can
        // disagree only for points exactly on cell boundaries — the random
        // continuum makes that probability zero).
        let pos = random_pos(1500, 21);
        let pool = ThreadPool::new(4);
        let a = build_baseline(&pool, &pos);
        let b = build_morton(&pool, &pos);
        let (sa, sb) = (tree_stats(&a), tree_stats(&b));
        assert_eq!(sa.leaves, sb.leaves, "{sa:?} vs {sb:?}");
        assert_eq!(sa.depth, sb.depth, "{sa:?} vs {sb:?}");
        // identical multiset of leaf sizes at identical cells → compare sorted
        // (depth, count) pairs
        let sig = |t: &QuadTree<f64>| {
            let mut v: Vec<(u64, u64, u32)> = t
                .nodes
                .iter()
                .filter(|n| n.is_leaf())
                .map(|n| {
                    (
                        (n.center[0].to_f64() * 1e6).round() as u64,
                        (n.center[1].to_f64() * 1e6).round() as u64,
                        n.count,
                    )
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(sig(&a), sig(&b));
    }

    #[test]
    fn bfs_level_order_nodes() {
        // Parent index < child index (BFS append order).
        let pos = random_pos(300, 5);
        let pool = ThreadPool::new(1);
        let tree = build_baseline(&pool, &pos);
        for (i, node) in tree.nodes.iter().enumerate() {
            for &c in &node.children {
                if c != NO_CHILD {
                    assert!((c as usize) > i);
                }
            }
        }
    }
}
