//! Morton-code quadtree builder — the paper's §3.3 contribution.
//!
//! Pipeline: encode (Alg. 1, SIMD + multithreaded) → parallel radix sort →
//! gather points into Z-order → sequential top-level expansion until the
//! frontier holds ≥ `SUBTREE_FACTOR ×` threads nodes → parallel subtree
//! construction with dynamic scheduling, each subtree stored contiguously.
//!
//! Each point is touched once (vs once-per-level in the baseline): a node's
//! children are found by binary-searching quadrant-digit boundaries in its
//! sorted code range, so splitting costs O(log range) instead of O(range).
//!
//! Duplicate handling: a range whose codes are all identical (points closer
//! than the 2⁻³² grid) becomes a multi-point leaf immediately; the baseline
//! builder instead chains single-child nodes to the depth cap — both give the
//! same mass distribution, which is what the force computation consumes.
//!
//! Z-order persistence contract: the permutation the sort produces is not an
//! internal detail — it is published as [`QuadTree::layout_order`] and the
//! Z-order-persistent gradient loop ([`crate::tsne::workspace`]) feeds each
//! adopted layout back as the next build's input. That makes the input
//! *nearly sorted* every iteration (points move little per descent step), so
//! the build detects an already-sorted code sequence with one O(n) pass and
//! skips the radix sort entirely (late optimization, where per-step motion
//! drops below the 2⁻³² grid resolution); the small-n path's `sort_unstable`
//! (pdqsort) is O(n) on nearly-sorted input by construction.

use super::morton::{encode_points_simd, quadrant_at, RootCell, MAX_LEVEL};
use super::{Node, QuadTree, NO_CHILD};
use crate::common::float::Real;
use crate::parallel::sort::radix_sort_pairs;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Frontier nodes per thread before switching to parallel subtree builds
/// (paper: "sufficiently larger than the number of threads" for dynamic
/// scheduling to balance).
const SUBTREE_FACTOR: usize = 8;

struct Frontier {
    node_idx: u32,
    start: usize,
    end: usize,
    level: usize,
    center: [f64; 2],
    width: f64,
}

/// Below this point count the pool dispatch overhead (a broadcast per
/// phase: bbox, encode, 8 sort passes, gather, build, stitch) exceeds the
/// work itself; a single-thread build with no broadcasts wins. Crossover
/// measured at ~80–100k points on 24 cores (EXPERIMENTS.md §Perf).
const SMALL_N: usize = 65_536;

/// Build the quadtree of the embedding `pos` (interleaved x,y).
pub fn build_morton<T: Real>(pool: &ThreadPool, pos: &[T]) -> QuadTree<T> {
    let n = pos.len() / 2;
    assert!(n > 0, "cannot build a tree over zero points");
    if n < SMALL_N || pool.n_threads() == 1 {
        return build_morton_small(pos);
    }
    let root_cell = RootCell::bounding(pool, pos);

    // (1) Morton codes, SIMD + multithreaded.
    let mut codes = vec![0u64; n];
    encode_points_simd(pool, pos, &root_cell, &mut codes);

    // (2) Parallel radix sort of (code, original index). When the caller
    // feeds back the previous iteration's Z-order (the persistent-layout
    // gradient loop), the codes often arrive already sorted — one O(n) check
    // then skips all 8 radix passes and `order` stays the identity.
    let mut order: Vec<u32> = (0..n as u32).collect();
    if !codes_sorted(pool, &codes) {
        radix_sort_pairs(pool, &mut codes, &mut order);
    }

    // (3) Gather coordinates into Z-order (contiguous leaf ranges).
    let mut point_pos = vec![T::ZERO; 2 * n];
    {
        let ps = SyncSlice::new(&mut point_pos);
        let order = &order;
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                let src = order[i] as usize;
                // SAFETY: disjoint — slots 2i, 2i+1
                unsafe {
                    *ps.get_mut(2 * i) = pos[2 * src];
                    *ps.get_mut(2 * i + 1) = pos[2 * src + 1];
                }
            }
        });
    }

    let root_width = 2.0 * root_cell.r_span;
    let mut nodes: Vec<Node<T>> = Vec::with_capacity(2 * n);
    nodes.push(new_node::<T>(n as u32, root_cell.cent, root_width));

    // (4) Sequential top expansion, level by level (BFS), keeping top nodes
    // level-contiguous, until the frontier is wide enough for the pool.
    let target = (SUBTREE_FACTOR * pool.n_threads()).max(4);
    let mut frontier = vec![Frontier {
        node_idx: 0,
        start: 0,
        end: n,
        level: 0,
        center: root_cell.cent,
        width: root_width,
    }];
    let mut depth = 0usize;
    loop {
        // Finalize unsplittable entries as leaves; keep splittable ones.
        let mut splittable = Vec::with_capacity(frontier.len());
        for f in frontier.drain(..) {
            depth = depth.max(f.level);
            if is_leaf_range(&codes, f.start, f.end, f.level) {
                finalize_leaf(&mut nodes, &f);
            } else {
                splittable.push(f);
            }
        }
        if splittable.is_empty() || splittable.len() >= target {
            frontier = splittable;
            break;
        }
        let mut next = Vec::with_capacity(splittable.len() * 4);
        for f in splittable {
            split_node(&mut nodes, &codes, &f, &mut next);
        }
        frontier = next;
    }
    let top_len = nodes.len();

    // (5) Parallel subtree builds with dynamic scheduling. Each subtree is
    // appended as one contiguous block (paper: "store all the nodes ... in a
    // contiguous manner to aid data locality").
    let mut local_results: Vec<Option<(Vec<Node<T>>, Node<T>, usize)>> =
        (0..frontier.len()).map(|_| None).collect();
    {
        let res = SyncSlice::new(&mut local_results);
        let codes = &codes;
        let frontier = &frontier;
        parallel_for(pool, frontier.len(), Schedule::Dynamic { grain: 1 }, |range| {
            for fi in range {
                let f = &frontier[fi];
                let mut local: Vec<Node<T>> = Vec::new();
                let mut local_depth = f.level;
                let root = build_local(
                    codes,
                    f.start,
                    f.end,
                    f.level,
                    f.center,
                    f.width,
                    &mut local,
                    &mut local_depth,
                );
                // SAFETY: disjoint — slot fi
                unsafe { *res.get_mut(fi) = Some((local, root, local_depth)) };
            }
        });
    }
    // Stitch: compute block offsets, remap local child indices to global.
    let mut offsets = Vec::with_capacity(frontier.len());
    let mut total = top_len;
    for r in &local_results {
        let (local, _, d) = r.as_ref().expect("subtree built");
        offsets.push(total);
        total += local.len();
        depth = depth.max(*d);
    }
    nodes.resize(total, new_node::<T>(0, [0.0; 2], 1.0));
    {
        let ns = SyncSlice::new(&mut nodes);
        let local_results = &local_results;
        let offsets = &offsets;
        let frontier = &frontier;
        parallel_for(pool, frontier.len(), Schedule::Dynamic { grain: 1 }, |range| {
            for fi in range {
                let (local, root, _) = local_results[fi].as_ref().unwrap();
                let base = offsets[fi] as i32;
                let mut root = root.clone();
                remap_children(&mut root, base);
                // SAFETY: disjoint — frontier node slots are unique; block ranges disjoint
                unsafe { *ns.get_mut(frontier[fi].node_idx as usize) = root };
                for (li, node) in local.iter().enumerate() {
                    let mut node = node.clone();
                    remap_children(&mut node, base);
                    // SAFETY: disjoint — block offsets[fi]..offsets[fi]+local.len()
                    // is owned by this frontier entry
                    unsafe { *ns.get_mut(offsets[fi] + li) = node };
                }
            }
        });
    }

    QuadTree {
        nodes,
        point_pos,
        point_idx: order,
        subtree_roots: frontier.iter().map(|f| f.node_idx).collect(),
        depth,
    }
}

/// Single-thread morton build: same algorithm, zero pool dispatches.
fn build_morton_small<T: Real>(pos: &[T]) -> QuadTree<T> {
    let n = pos.len() / 2;
    // bbox (shared with the parallel path — identical by min/max associativity,
    // and it closes the same non-finite escape hatches)
    let root_cell = RootCell::bounding_seq(pos);
    // encode + sort
    let mut pairs: Vec<(u64, u32)> = (0..n)
        .map(|i| {
            (
                root_cell.encode(pos[2 * i].to_f64(), pos[2 * i + 1].to_f64()),
                i as u32,
            )
        })
        .collect();
    pairs.sort_unstable_by_key(|&(c, _)| c);
    let codes: Vec<u64> = pairs.iter().map(|&(c, _)| c).collect();
    let order: Vec<u32> = pairs.iter().map(|&(_, i)| i).collect();
    let mut point_pos = vec![T::ZERO; 2 * n];
    for (i, &src) in order.iter().enumerate() {
        point_pos[2 * i] = pos[2 * src as usize];
        point_pos[2 * i + 1] = pos[2 * src as usize + 1];
    }
    // recursive build into one buffer; root appended last, then moved to 0.
    let root_width = 2.0 * root_cell.r_span;
    let mut nodes: Vec<Node<T>> = Vec::with_capacity(2 * n);
    let mut depth = 0usize;
    let root = build_local(&codes, 0, n, 0, root_cell.cent, root_width, &mut nodes, &mut depth);
    nodes.push(root);
    let last = nodes.len() - 1;
    nodes.swap(0, last);
    // fix children of the swapped pair: root (now at 0) kept its child
    // indices (all < last); the node moved to `last` must be re-pointed by
    // its parent — find and patch (single scan, small n).
    if last != 0 {
        for node in nodes.iter_mut() {
            for c in node.children.iter_mut() {
                if *c == 0 {
                    *c = last as i32;
                } else if *c == last as i32 {
                    *c = 0;
                }
            }
        }
    }
    QuadTree {
        nodes,
        point_pos,
        point_idx: order,
        subtree_roots: Vec::new(),
        depth,
    }
}

/// Parallel "already sorted?" check: each thread scans its chunk plus the
/// boundary pair and flips a shared flag on the first inversion. One read
/// pass vs the radix sort's 8 read+write passes — cheap enough to run every
/// build, and it turns the persistent-layout steady state into a no-op sort.
fn codes_sorted(pool: &ThreadPool, codes: &[u64]) -> bool {
    use std::sync::atomic::{AtomicBool, Ordering};
    let sorted = AtomicBool::new(true);
    parallel_for(pool, codes.len().saturating_sub(1), Schedule::Static, |range| {
        if !sorted.load(Ordering::Relaxed) {
            return;
        }
        for i in range {
            if codes[i] > codes[i + 1] {
                sorted.store(false, Ordering::Relaxed);
                return;
            }
        }
    });
    sorted.load(Ordering::Relaxed)
}

fn new_node<T: Real>(count: u32, center: [f64; 2], width: f64) -> Node<T> {
    Node {
        children: [NO_CHILD; 4],
        count,
        point_start: 0,
        point_end: 0,
        center: [T::from_f64(center[0]), T::from_f64(center[1])],
        width: T::from_f64(width),
        com: [T::ZERO; 2],
    }
}

#[inline]
fn is_leaf_range(codes: &[u64], start: usize, end: usize, level: usize) -> bool {
    end - start == 1 || level >= MAX_LEVEL || codes[start] == codes[end - 1]
}

fn finalize_leaf<T: Real>(nodes: &mut [Node<T>], f: &Frontier) {
    let node = &mut nodes[f.node_idx as usize];
    node.point_start = f.start as u32;
    node.point_end = f.end as u32;
}

/// Quadrant boundaries of a sorted code range at `level`: binary search the
/// first index whose digit is ≥ q (O(log range) per split — the "touch each
/// point once" property).
#[inline]
fn quadrant_bounds(codes: &[u64], start: usize, end: usize, level: usize) -> [usize; 5] {
    let mut b = [start, end, end, end, end];
    let slice = &codes[start..end];
    for q in 1..4u64 {
        b[q as usize] = start + slice.partition_point(|&c| (quadrant_at(c, level) as u64) < q);
    }
    b[4] = end;
    b
}

#[inline]
fn child_geometry(center: [f64; 2], width: f64, q: usize) -> ([f64; 2], f64) {
    let cw = width * 0.5;
    let off = width * 0.25;
    (
        [
            center[0] + if q & 1 == 1 { off } else { -off },
            center[1] + if q & 2 == 2 { off } else { -off },
        ],
        cw,
    )
}

/// Split a top-region node; children are appended to `nodes` (BFS order) and
/// pushed on the next frontier.
fn split_node<T: Real>(
    nodes: &mut Vec<Node<T>>,
    codes: &[u64],
    f: &Frontier,
    next: &mut Vec<Frontier>,
) {
    let b = quadrant_bounds(codes, f.start, f.end, f.level);
    for q in 0..4 {
        let (s, e) = (b[q], b[q + 1]);
        if s == e {
            continue;
        }
        let (c_center, c_width) = child_geometry(f.center, f.width, q);
        let idx = nodes.len() as u32;
        nodes.push(new_node::<T>((e - s) as u32, c_center, c_width));
        nodes[f.node_idx as usize].children[q] = idx as i32;
        next.push(Frontier {
            node_idx: idx,
            start: s,
            end: e,
            level: f.level + 1,
            center: c_center,
            width: c_width,
        });
    }
}

/// Recursive subtree construction into a local buffer. Children are appended
/// (post-order) before the parent is returned; indices are local and remapped
/// to global by the caller.
#[allow(clippy::too_many_arguments)]
fn build_local<T: Real>(
    codes: &[u64],
    start: usize,
    end: usize,
    level: usize,
    center: [f64; 2],
    width: f64,
    out: &mut Vec<Node<T>>,
    depth: &mut usize,
) -> Node<T> {
    *depth = (*depth).max(level);
    let mut node = new_node::<T>((end - start) as u32, center, width);
    if is_leaf_range(codes, start, end, level) {
        node.point_start = start as u32;
        node.point_end = end as u32;
        return node;
    }
    let b = quadrant_bounds(codes, start, end, level);
    for q in 0..4 {
        let (s, e) = (b[q], b[q + 1]);
        if s == e {
            continue;
        }
        let (c_center, c_width) = child_geometry(center, width, q);
        let child = build_local(codes, s, e, level + 1, c_center, c_width, out, depth);
        out.push(child);
        node.children[q] = (out.len() - 1) as i32;
    }
    node
}

fn remap_children<T: Real>(node: &mut Node<T>, base: i32) {
    for c in node.children.iter_mut() {
        if *c != NO_CHILD {
            *c += base;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::quadtree::tree_stats;

    fn random_pos(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 3.0).collect()
    }

    #[test]
    fn valid_on_random_points() {
        for n in [1, 2, 5, 100, 2000] {
            let pos = random_pos(n, n as u64);
            let pool = ThreadPool::new(4);
            let tree = build_morton(&pool, &pos);
            tree.validate().unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(tree.n_points(), n);
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let pos = random_pos(3000, 7);
        let t1 = build_morton(&ThreadPool::new(1), &pos);
        let t8 = build_morton(&ThreadPool::new(8), &pos);
        // Same point layout (Z-order is thread-count independent)...
        assert_eq!(t1.point_idx, t8.point_idx);
        // ...same structure counts and depth even though node order differs
        // (t1 builds one subtree; t8 stitches many blocks).
        let (s1, s8) = (tree_stats(&t1), tree_stats(&t8));
        assert_eq!(s1.leaves, s8.leaves);
        assert_eq!(s1.depth, s8.depth);
        assert_eq!(s1.max_leaf_points, s8.max_leaf_points);
    }

    #[test]
    fn duplicates_become_multipoint_leaf() {
        let mut pos = random_pos(64, 9);
        // 8 copies of the same point
        for i in 0..8 {
            pos[2 * i] = 0.123;
            pos[2 * i + 1] = -0.456;
        }
        let pool = ThreadPool::new(4);
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        let st = tree_stats(&tree);
        assert!(st.max_leaf_points >= 8, "stats {st:?}");
    }

    #[test]
    fn all_identical_points() {
        let pos = vec![1.0f64; 2 * 50]; // 50 copies of (1,1)
        let pool = ThreadPool::new(4);
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        assert_eq!(tree.root().count, 50);
    }

    #[test]
    fn two_points() {
        let pos = vec![-1.0f64, -1.0, 1.0, 1.0];
        let pool = ThreadPool::new(2);
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        let st = tree_stats(&tree);
        assert_eq!(st.leaves, 2);
    }

    #[test]
    fn z_order_layout_is_sorted_codes() {
        let pos = random_pos(500, 11);
        let pool = ThreadPool::new(4);
        let tree = build_morton(&pool, &pos);
        let root = RootCell::bounding(&pool, &pos);
        let mut prev = 0u64;
        for i in 0..tree.n_points() {
            let c = root.encode(tree.point_pos[2 * i].to_f64(), tree.point_pos[2 * i + 1].to_f64());
            assert!(c >= prev, "gathered points must be in Z-order");
            prev = c;
        }
    }

    #[test]
    fn rebuild_from_zorder_is_identity_permutation() {
        // The persistent-layout loop's steady state: building from a point
        // array that is already in Z-order must return the identity layout
        // (and, on the parallel path, skip the radix sort — same observable).
        // 70_000 points crosses SMALL_N to exercise the sorted-skip branch.
        for (n, threads) in [(3000usize, 4usize), (70_000, 4)] {
            let pos = random_pos(n, n as u64 ^ 0x5EED);
            let pool = ThreadPool::new(threads);
            let t1 = build_morton(&pool, &pos);
            assert!(t1.layout_drift() > 0, "random input should not be pre-sorted");
            let t2 = build_morton(&pool, &t1.point_pos);
            assert_eq!(t2.layout_drift(), 0, "n={n}: Z-order input must be a fixed point");
            assert_eq!(t2.point_pos, t1.point_pos);
            t2.validate().unwrap();
        }
    }

    #[test]
    fn near_coincident_and_non_finite_points_build_finite_trees() {
        let pool = ThreadPool::new(4);
        // near-coincident: spread far below the 2⁻³² grid resolution, so all
        // codes collide into one multi-point leaf
        let mut pos = vec![0.0f64; 2 * 32];
        for i in 0..32 {
            pos[2 * i] = 1.0 + i as f64 * 1e-300;
            pos[2 * i + 1] = -1.0;
        }
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        let finite_geometry = |t: &QuadTree<f64>| {
            t.nodes.iter().all(|nd| {
                nd.width.to_f64().is_finite()
                    && nd.center.iter().all(|c| c.to_f64().is_finite())
            })
        };
        assert!(finite_geometry(&tree));
        // poisoned coordinates must not blow the cell geometry up either
        pos[7] = f64::NAN;
        pos[12] = f64::INFINITY;
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        assert!(finite_geometry(&tree));
        assert_eq!(tree.root().count, 32);
    }

    #[test]
    fn clustered_points_make_deep_unbalanced_tree() {
        let mut rng = Rng::new(13);
        let mut pos = Vec::with_capacity(2 * 1000);
        for _ in 0..900 {
            // dense cluster
            pos.push(0.001 * rng.next_gaussian());
            pos.push(0.001 * rng.next_gaussian());
        }
        for _ in 0..100 {
            pos.push(rng.next_gaussian() * 100.0);
            pos.push(rng.next_gaussian() * 100.0);
        }
        let pool = ThreadPool::new(4);
        let tree = build_morton(&pool, &pos);
        tree.validate().unwrap();
        assert!(tree.depth > 8, "depth {}", tree.depth);
    }
}
