//! Minimal criterion-replacement bench harness (criterion is unavailable in
//! this offline environment).
//!
//! Usage from a `harness = false` bench binary:
//! ```no_run
//! use acc_tsne::common::bench::Bencher;
//! let mut b = Bencher::new("morton_encode");
//! b.bench("scalar", || { /* work */ });
//! b.report();
//! ```
//! Each case is warmed up, then run until either `max_iters` iterations or
//! `max_secs` seconds elapse; mean/median/min and relative spread are printed
//! in a fixed-width table that the EXPERIMENTS.md capture scripts parse.

use crate::common::stats::{fmt_secs, Summary};
use std::time::Instant;

/// One benchmark group (≈ criterion's `BenchmarkGroup`).
pub struct Bencher {
    group: String,
    warmup_iters: usize,
    max_iters: usize,
    max_secs: f64,
    results: Vec<(String, Summary)>,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        Bencher {
            group: group.to_string(),
            warmup_iters: 1,
            max_iters: 10,
            max_secs: 5.0,
            results: Vec::new(),
        }
    }

    /// Tune sampling (e.g. 1 iteration for multi-second end-to-end runs).
    pub fn sampling(mut self, warmup: usize, max_iters: usize, max_secs: f64) -> Self {
        self.warmup_iters = warmup;
        self.max_iters = max_iters.max(1);
        self.max_secs = max_secs;
        self
    }

    /// Run one case; returns its summary (also recorded for `report`).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Summary {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.max_iters);
        let start = Instant::now();
        for _ in 0..self.max_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if start.elapsed().as_secs_f64() > self.max_secs {
                break;
            }
        }
        let s = Summary::of(&samples);
        self.results.push((name.to_string(), s));
        s
    }

    /// Record an externally-measured sample set under this group.
    pub fn record(&mut self, name: &str, samples: &[f64]) -> Summary {
        let s = Summary::of(samples);
        self.results.push((name.to_string(), s));
        s
    }

    /// Print the group's table; returns (name, mean_secs) pairs.
    pub fn report(&self) -> Vec<(String, f64)> {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<40} {:>10} {:>10} {:>10} {:>8} {:>5}",
            "case", "mean", "median", "min", "spread", "n"
        );
        for (name, s) in &self.results {
            println!(
                "{:<40} {:>10} {:>10} {:>10} {:>7.1}% {:>5}",
                name,
                fmt_secs(s.mean),
                fmt_secs(s.median),
                fmt_secs(s.min),
                100.0 * s.rel_spread(),
                s.n
            );
        }
        self.results
            .iter()
            .map(|(n, s)| (n.clone(), s.mean))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::new("test").sampling(1, 5, 1.0);
        let s = b.bench("noop", || 1 + 1);
        assert!(s.n >= 1 && s.n <= 5);
        let rep = b.report();
        assert_eq!(rep.len(), 1);
        assert_eq!(rep[0].0, "noop");
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bencher::new("test");
        let s = b.record("ext", &[1.0, 2.0, 3.0]);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn respects_time_budget() {
        let mut b = Bencher::new("budget").sampling(0, 1000, 0.05);
        let s = b.bench("sleepy", || std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(s.n < 1000);
    }
}
