//! Wall-clock timing and per-step time accounting.
//!
//! The paper's evaluation is built around per-step timings (Tables 5/6,
//! Figures 1b/6), so step accounting is a first-class type here: every t-SNE
//! run returns a [`StepTimes`] that the eval harness aggregates into the
//! paper's tables.

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the previous lap in seconds.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// The six pipeline steps of BH t-SNE (paper Figure 1a), plus the gradient
/// update which the paper folds into "other".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Step {
    Knn,
    Bsp,
    TreeBuild,
    Summarize,
    Attractive,
    Repulsive,
    Update,
}

impl Step {
    pub const ALL: [Step; 7] = [
        Step::Knn,
        Step::Bsp,
        Step::TreeBuild,
        Step::Summarize,
        Step::Attractive,
        Step::Repulsive,
        Step::Update,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Step::Knn => "KNN",
            Step::Bsp => "BSP",
            Step::TreeBuild => "TreeBuild",
            Step::Summarize => "Summarize",
            Step::Attractive => "Attractive",
            Step::Repulsive => "Repulsive",
            Step::Update => "Update",
        }
    }

    const fn idx(self) -> usize {
        match self {
            Step::Knn => 0,
            Step::Bsp => 1,
            Step::TreeBuild => 2,
            Step::Summarize => 3,
            Step::Attractive => 4,
            Step::Repulsive => 5,
            Step::Update => 6,
        }
    }
}

/// Accumulated seconds per pipeline step over a full run.
#[derive(Clone, Debug, Default)]
pub struct StepTimes {
    secs: [f64; 7],
}

impl StepTimes {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, step: Step, secs: f64) {
        self.secs[step.idx()] += secs;
    }

    /// Time a closure and charge it to `step`, returning its value.
    #[inline]
    pub fn time<R>(&mut self, step: Step, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.secs[step.idx()] += t.elapsed().as_secs_f64();
        r
    }

    pub fn get(&self, step: Step) -> f64 {
        self.secs[step.idx()]
    }

    /// Total across all steps.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Gradient-descent total (everything except KNN+BSP), the per-iteration cost.
    pub fn gradient_total(&self) -> f64 {
        self.total() - self.get(Step::Knn) - self.get(Step::Bsp)
    }

    pub fn merge(&mut self, other: &StepTimes) {
        for i in 0..7 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Percentage breakdown (paper Figure 1b).
    pub fn percentages(&self) -> Vec<(Step, f64)> {
        let total = self.total().max(f64::MIN_POSITIVE);
        Step::ALL
            .iter()
            .map(|&s| (s, 100.0 * self.get(s) / total))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.elapsed() >= 0.009);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let l1 = t.lap();
        assert!(l1 >= 0.004);
        assert!(t.elapsed() < l1);
    }

    #[test]
    fn step_accounting() {
        let mut st = StepTimes::new();
        st.add(Step::Knn, 1.0);
        st.add(Step::Knn, 0.5);
        st.add(Step::Repulsive, 2.0);
        assert_eq!(st.get(Step::Knn), 1.5);
        assert_eq!(st.total(), 3.5);
        assert_eq!(st.gradient_total(), 2.0);
    }

    #[test]
    fn time_closure_returns_value_and_charges() {
        let mut st = StepTimes::new();
        let v = st.time(Step::Bsp, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(st.get(Step::Bsp) >= 0.004);
    }

    #[test]
    fn percentages_sum_to_100() {
        let mut st = StepTimes::new();
        st.add(Step::Knn, 1.0);
        st.add(Step::Attractive, 3.0);
        let sum: f64 = st.percentages().iter().map(|(_, p)| p).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = StepTimes::new();
        a.add(Step::Update, 1.0);
        let mut b = StepTimes::new();
        b.add(Step::Update, 2.0);
        a.merge(&b);
        assert_eq!(a.get(Step::Update), 3.0);
    }
}
