//! Small statistics helpers used by the bench harness and the eval tables.

/// Summary statistics over a sample of measurements.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute summary stats. Panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        // total_cmp, not partial_cmp().unwrap(): one NaN measurement (a
        // poisoned timer, a 0/0 ratio) must not abort a whole bench run.
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            stddev: var.sqrt(),
        }
    }

    /// Relative spread (stddev/mean) — used to decide bench convergence.
    pub fn rel_spread(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Format seconds human-readably for tables (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 100.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.0}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[2.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn nan_sample_does_not_abort() {
        // NaN sorts last under the IEEE total order, so min/median stay
        // meaningful and the call must not panic.
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-6).ends_with("us"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with("s"));
        assert_eq!(fmt_secs(250.0), "250s");
    }
}
