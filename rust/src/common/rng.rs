//! Deterministic pseudo-random number generation.
//!
//! Offline build — no `rand` crate — so we own a small, fast, well-tested
//! generator: SplitMix64 for seeding and xoshiro256++ for the stream, plus
//! Box-Muller gaussians. Every experiment in the repo takes an explicit seed so
//! all tables/figures are exactly reproducible.

use crate::common::float::Real;

/// xoshiro256++ PRNG seeded via SplitMix64 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box-Muller.
    spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Identical seeds yield identical
    /// streams on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (used to hand one RNG per thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline(always)]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as `T`.
    #[inline]
    pub fn gaussian<T: Real>(&mut self) -> T {
        T::from_f64(self.next_gaussian())
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform<T: Real>(&mut self, lo: T, hi: T) -> T {
        lo + (hi - lo) * T::from_f64(self.next_f64())
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<E>(&mut self, data: &mut [E]) {
        for i in (1..data.len()).rev() {
            let j = self.next_below(i + 1);
            data.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_in_bounds_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
