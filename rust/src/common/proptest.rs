//! Minimal property-testing framework (proptest is unavailable offline).
//!
//! Provides the 20% of proptest we need: run a property over many random
//! inputs drawn from simple generators, and on failure report the seed and a
//! greedily-shrunk counterexample size. Deterministic per test (fixed base
//! seed xor'd with the case index) so failures are reproducible.

use crate::common::rng::Rng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xACC7_53E,
        }
    }
}

/// Run `prop` over `cfg.cases` RNG streams. `prop` returns `Err(msg)` to fail.
/// Panics with seed information on the first failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Generate a random vector length in [lo, hi] biased towards edge cases
/// (empty-ish and exact bounds show up often).
pub fn gen_len(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    match rng.next_below(8) {
        0 => lo,
        1 => hi,
        _ => lo + rng.next_below(hi - lo + 1),
    }
}

/// Random f64 vector with entries in [-scale, scale], occasionally inserting
/// duplicates and extreme values (the quadtree/morton edge cases).
pub fn gen_points(rng: &mut Rng, n: usize, scale: f64) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(-scale, scale)).collect();
    if n >= 4 && rng.next_below(3) == 0 {
        // Duplicate a point — trees must terminate despite identical coords.
        let src = rng.next_below(n);
        let dst = rng.next_below(n);
        v[dst] = v[src];
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", Config::default(), |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", Config { cases: 4, seed: 1 }, |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn gen_len_within_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let l = gen_len(&mut rng, 3, 17);
            assert!((3..=17).contains(&l));
        }
    }

    #[test]
    fn gen_points_in_range() {
        let mut rng = Rng::new(3);
        let pts = gen_points(&mut rng, 50, 2.0);
        assert_eq!(pts.len(), 50);
        assert!(pts.iter().all(|&p| (-2.0..=2.0).contains(&p)));
    }
}
