//! Floating-point abstraction so every step of the pipeline is generic over
//! `f32` / `f64` (paper Table S1 runs both precisions end-to-end).

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Scalar type used throughout the pipeline. Implemented for `f32` and `f64`.
///
/// This is deliberately smaller than `num_traits::Float` — it adds the few
/// extras we need (SIMD lane count, prefetch-friendly byte width, name for
/// reports) and keeps the trait object-safe-free and fully inlineable.
pub trait Real:
    Copy
    + Clone
    + Debug
    + Display
    + Default
    + PartialOrd
    + Send
    + Sync
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    const TWO: Self;
    const HALF: Self;
    /// Smallest positive normal — used as a divide-by-zero guard.
    const TINY: Self;
    const MAX_REAL: Self;
    const MIN_REAL: Self;
    /// Short name used in benchmark tables ("f32" / "f64").
    const NAME: &'static str;
    /// Number of SIMD lanes used by the hand-vectorized attractive kernel.
    /// 8 for f64 (AVX-512: 8 × 64-bit), 16 for f32.
    const LANES: usize;

    fn from_f64(v: f64) -> Self;
    fn from_usize(v: usize) -> Self;
    fn to_f64(self) -> f64;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn abs(self) -> Self;
    fn powi(self, p: i32) -> Self;
    fn min_r(self, other: Self) -> Self;
    fn max_r(self, other: Self) -> Self;
    fn is_finite_r(self) -> bool;
}

macro_rules! impl_real {
    ($t:ty, $name:expr, $lanes:expr) => {
        impl Real for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const TWO: Self = 2.0;
            const HALF: Self = 0.5;
            const TINY: Self = <$t>::MIN_POSITIVE;
            const MAX_REAL: Self = <$t>::MAX;
            const MIN_REAL: Self = <$t>::MIN;
            const NAME: &'static str = $name;
            const LANES: usize = $lanes;

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline(always)]
            fn from_usize(v: usize) -> Self {
                v as $t
            }
            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline(always)]
            fn sqrt(self) -> Self {
                self.sqrt()
            }
            #[inline(always)]
            fn exp(self) -> Self {
                self.exp()
            }
            #[inline(always)]
            fn ln(self) -> Self {
                self.ln()
            }
            #[inline(always)]
            fn abs(self) -> Self {
                self.abs()
            }
            #[inline(always)]
            fn powi(self, p: i32) -> Self {
                self.powi(p)
            }
            #[inline(always)]
            fn min_r(self, other: Self) -> Self {
                self.min(other)
            }
            #[inline(always)]
            fn max_r(self, other: Self) -> Self {
                self.max(other)
            }
            #[inline(always)]
            fn is_finite_r(self) -> bool {
                self.is_finite()
            }
        }
    };
}

impl_real!(f32, "f32", 16);
impl_real!(f64, "f64", 8);

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Real>() {
        assert_eq!(T::from_f64(2.5).to_f64(), 2.5);
        assert_eq!(T::from_usize(7).to_f64(), 7.0);
        assert!((T::from_f64(2.0).sqrt().to_f64() - std::f64::consts::SQRT_2).abs() < 1e-6);
        assert!(T::ONE.exp().to_f64() > 2.7 && T::ONE.exp().to_f64() < 2.72);
        assert_eq!(T::from_f64(-3.0).abs().to_f64(), 3.0);
        assert_eq!(T::from_f64(2.0).powi(3).to_f64(), 8.0);
        assert_eq!(T::from_f64(1.0).min_r(T::from_f64(2.0)).to_f64(), 1.0);
        assert_eq!(T::from_f64(1.0).max_r(T::from_f64(2.0)).to_f64(), 2.0);
        assert!(T::ONE.is_finite_r());
        assert!(!(T::ONE / T::ZERO).is_finite_r());
    }

    #[test]
    fn f32_ops() {
        roundtrip::<f32>();
        assert_eq!(f32::NAME, "f32");
        assert_eq!(f32::LANES, 16);
    }

    #[test]
    fn f64_ops() {
        roundtrip::<f64>();
        assert_eq!(f64::NAME, "f64");
        assert_eq!(f64::LANES, 8);
    }

    #[test]
    fn tiny_guard_is_positive() {
        assert!(f64::TINY > 0.0);
        assert!(f32::TINY > 0.0);
    }
}
