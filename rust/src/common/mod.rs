//! Shared low-level substrates: float abstraction, RNG, timing, statistics,
//! the bench harness, and a minimal property-testing framework.
//!
//! These exist because the build environment is fully offline: the usual
//! crates (`rand`, `criterion`, `proptest`) are unavailable, and the paper's
//! claims are about low-level behaviour anyway — owning these pieces keeps the
//! measured hot paths free of foreign code.

pub mod bench;
pub mod float;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use float::Real;
pub use rng::Rng;
pub use timer::Timer;
