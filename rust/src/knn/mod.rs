//! K-nearest-neighbors (pipeline step 1, paper §3.1).
//!
//! The paper reuses daal4py's KNN ("fairly efficient and scales well"), so
//! ours has the same design goals: blocked brute-force — cache-tiled distance
//! computation `‖q−c‖² = ‖q‖² + ‖c‖² − 2⟨q,c⟩` with a per-query bounded heap —
//! parallel over query blocks with dynamic scheduling.
//!
//! Engines implementing [`KnnEngine`]:
//! - [`BruteForceKnn`] (native Rust, default, this file);
//! - [`vptree::VpTreeKnn`] — the Multicore-TSNE baseline architecture;
//! - [`hnsw::HnswKnn`] — approximate (HNSW), the million-point path;
//! - `runtime::engines::XlaKnn` — the distance tile computed by the AOT
//!   Pallas `sqdist` kernel through PJRT (L1/L2 integration path).

pub mod hnsw;
pub mod select;
pub mod vptree;

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use select::KBest;

/// Neighbor lists for all points: `k` neighbors per point, distances are
/// **squared** Euclidean (the Gaussian kernel in Eq. 2 consumes d²).
#[derive(Clone, Debug)]
pub struct NeighborLists<T: Real> {
    pub n: usize,
    pub k: usize,
    /// `indices[i*k + j]` = j-th nearest neighbor of point i (self excluded).
    pub indices: Vec<u32>,
    /// Squared distances, ascending per row.
    pub distances_sq: Vec<T>,
}

impl<T: Real> NeighborLists<T> {
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[i * self.k..(i + 1) * self.k]
    }

    #[inline]
    pub fn dists(&self, i: usize) -> &[T] {
        &self.distances_sq[i * self.k..(i + 1) * self.k]
    }

    /// The first `k_new` entries of every row. Rows are ascending by
    /// distance, so this is exactly the `k_new`-nearest-neighbor result over
    /// the same data — the shrink that lets one deep KNN graph serve every
    /// smaller ⌊3·perplexity⌋ support (`tsne::Affinities::from_knn`).
    pub fn truncated(&self, k_new: usize) -> NeighborLists<T> {
        assert!(k_new <= self.k, "cannot grow a neighbor list ({k_new} > {})", self.k);
        let mut indices = Vec::with_capacity(self.n * k_new);
        let mut dists = Vec::with_capacity(self.n * k_new);
        for i in 0..self.n {
            indices.extend_from_slice(&self.neighbors(i)[..k_new]);
            dists.extend_from_slice(&self.dists(i)[..k_new]);
        }
        NeighborLists { n: self.n, k: k_new, indices, distances_sq: dists }
    }
}

/// A KNN engine (native or XLA-offloaded).
pub trait KnnEngine<T: Real> {
    fn name(&self) -> &'static str;
    /// Find the `k` nearest neighbors of every point in `data` (n×d), self
    /// excluded. `k < n` required.
    fn search(
        &self,
        pool: &ThreadPool,
        data: &[T],
        n: usize,
        d: usize,
        k: usize,
    ) -> NeighborLists<T>;
}

/// Cache-blocked brute-force KNN.
pub struct BruteForceKnn {
    /// Query rows per tile (per-thread working set).
    pub block_q: usize,
    /// Corpus rows per tile.
    pub block_c: usize,
}

impl Default for BruteForceKnn {
    fn default() -> Self {
        // 64×256 f64 dot tile = 128 KiB — fits L2 alongside the query rows.
        BruteForceKnn {
            block_q: 64,
            block_c: 256,
        }
    }
}

impl<T: Real> KnnEngine<T> for BruteForceKnn {
    fn name(&self) -> &'static str {
        "brute-force-native"
    }

    fn search(
        &self,
        pool: &ThreadPool,
        data: &[T],
        n: usize,
        d: usize,
        k: usize,
    ) -> NeighborLists<T> {
        assert!(k < n, "k ({k}) must be < n ({n})");
        assert_eq!(data.len(), n * d);
        let bq = self.block_q.clamp(1, n);
        let bc = self.block_c.clamp(1, n);

        // ‖x‖² for every point, parallel.
        let mut norms = vec![T::ZERO; n];
        {
            let ns = SyncSlice::new(&mut norms);
            parallel_for(pool, n, Schedule::Static, |range| {
                for i in range {
                    let row = &data[i * d..(i + 1) * d];
                    let mut acc = T::ZERO;
                    for &v in row {
                        acc += v * v;
                    }
                    // SAFETY: disjoint — slot i
                    unsafe { *ns.get_mut(i) = acc };
                }
            });
        }

        let n_qblocks = n.div_ceil(bq);
        let mut indices = vec![0u32; n * k];
        let mut dists = vec![T::ZERO; n * k];
        {
            let is = SyncSlice::new(&mut indices);
            let ds = SyncSlice::new(&mut dists);
            let norms = &norms;
            // Dynamic over query blocks: block cost is uniform but this keeps
            // the tail balanced when n_qblocks % threads != 0.
            // Feature-dim tile for the transposed corpus panel: bounds the
            // per-thread scratch at BC×DT elements (256×128×8B = 256 KiB)
            // so the panel streams through L2 while the dot tile stays hot.
            let dt = 128usize.min(d);
            parallel_for(pool, n_qblocks, Schedule::Dynamic { grain: 1 }, |range| {
                let mut dots = vec![T::ZERO; bq * bc];
                let mut panel = vec![T::ZERO; bc * dt]; // [j][ci] transposed corpus
                let mut heaps: Vec<KBest<T>> = Vec::with_capacity(bq);
                for qb in range {
                    let q0 = qb * bq;
                    let q1 = (q0 + bq).min(n);
                    heaps.clear();
                    heaps.resize_with(q1 - q0, || KBest::new(k));
                    let mut c0 = 0;
                    while c0 < n {
                        let c1 = (c0 + bc).min(n);
                        let cw = c1 - c0;
                        dots[..(q1 - q0) * bc].fill(T::ZERO);
                        // dots[qi][ci] = ⟨q, c⟩, accumulated over feature
                        // tiles; the corpus tile is transposed once per
                        // (tile, corpus block) so the innermost loop is a
                        // contiguous FMA over ci (auto-vectorizes to AVX-512).
                        let mut j0 = 0;
                        while j0 < d {
                            let j1 = (j0 + dt).min(d);
                            for j in j0..j1 {
                                let prow = &mut panel[(j - j0) * bc..(j - j0) * bc + cw];
                                for (ci, p) in prow.iter_mut().enumerate() {
                                    *p = data[(c0 + ci) * d + j];
                                }
                            }
                            for (qi, q) in (q0..q1).enumerate() {
                                let qrow = &data[q * d + j0..q * d + j1];
                                let drow = &mut dots[qi * bc..qi * bc + cw];
                                for (j, &qv) in qrow.iter().enumerate() {
                                    let prow = &panel[j * bc..j * bc + cw];
                                    for (dv, &pv) in drow.iter_mut().zip(prow.iter()) {
                                        *dv += qv * pv;
                                    }
                                }
                            }
                            j0 = j1;
                        }
                        for (qi, q) in (q0..q1).enumerate() {
                            let heap = &mut heaps[qi];
                            let nq = norms[q];
                            for (ci, c) in (c0..c1).enumerate() {
                                if c == q {
                                    continue; // exclude self
                                }
                                let dist = (nq + norms[c] - T::TWO * dots[qi * bc + ci])
                                    .max_r(T::ZERO);
                                heap.push(dist, c as u32);
                            }
                        }
                        c0 = c1;
                    }
                    for (qi, q) in (q0..q1).enumerate() {
                        let sorted = std::mem::replace(&mut heaps[qi], KBest::new(1)).into_sorted();
                        debug_assert_eq!(sorted.len(), k);
                        for (j, (dist, idx)) in sorted.into_iter().enumerate() {
                            // SAFETY: disjoint — rows q of indices/dists owned by this block
                            unsafe {
                                *is.get_mut(q * k + j) = idx;
                                *ds.get_mut(q * k + j) = dist;
                            }
                        }
                    }
                }
            });
        }
        NeighborLists {
            n,
            k,
            indices,
            distances_sq: dists,
        }
    }
}

/// Exact O(n²d) reference KNN — the oracle the blocked engine is tested against.
pub fn knn_reference<T: Real>(data: &[T], n: usize, d: usize, k: usize) -> NeighborLists<T> {
    assert!(k < n);
    let mut indices = vec![0u32; n * k];
    let mut dists = vec![T::ZERO; n * k];
    for i in 0..n {
        let mut cand: Vec<(T, u32)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let mut acc = T::ZERO;
                for t in 0..d {
                    let diff = data[i * d + t] - data[j * d + t];
                    acc += diff * diff;
                }
                (acc, j as u32)
            })
            .collect();
        // total_cmp, not partial_cmp().unwrap(): a NaN coordinate in hostile
        // or synthetic data must not abort the oracle the engines are
        // compared against (NaNs sort last under the IEEE total order).
        cand.sort_by(|a, b| a.0.to_f64().total_cmp(&b.0.to_f64()));
        for j in 0..k {
            indices[i * k + j] = cand[j].1;
            dists[i * k + j] = cand[j].0;
        }
    }
    NeighborLists {
        n,
        k,
        indices,
        distances_sq: dists,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    fn random_data(n: usize, d: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n * d).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn matches_reference_exactly_on_distances() {
        let n = 300;
        let d = 7;
        let k = 12;
        let data = random_data(n, d, 5);
        let pool = ThreadPool::new(4);
        let got = BruteForceKnn::default().search(&pool, &data, n, d, k);
        let want = knn_reference(&data, n, d, k);
        for i in 0..n {
            for j in 0..k {
                let g = got.distances_sq[i * k + j];
                let w = want.distances_sq[i * k + j];
                assert!(
                    (g - w).abs() < 1e-9 * (1.0 + w.abs()),
                    "row {i} pos {j}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn excludes_self_and_sorted() {
        let n = 200;
        let data = random_data(n, 4, 9);
        let pool = ThreadPool::new(3);
        let nl = BruteForceKnn::default().search(&pool, &data, n, 4, 8);
        for i in 0..n {
            assert!(nl.neighbors(i).iter().all(|&j| j as usize != i), "self in row {i}");
            let dr = nl.dists(i);
            assert!(dr.windows(2).all(|w| w[0] <= w[1]), "row {i} unsorted");
        }
    }

    #[test]
    fn block_boundary_sizes() {
        // n not divisible by either block size.
        let n = 130;
        let d = 3;
        let k = 5;
        let data = random_data(n, d, 2);
        let pool = ThreadPool::new(4);
        let eng = BruteForceKnn { block_q: 32, block_c: 48 };
        let got = eng.search(&pool, &data, n, d, k);
        let want = knn_reference(&data, n, d, k);
        for i in 0..n {
            assert_eq!(got.neighbors(i), want.neighbors(i), "row {i}");
        }
    }

    #[test]
    fn duplicate_points_ok() {
        let mut data = random_data(50, 4, 3);
        for j in 0..4 {
            data[4 + j] = data[j]; // point 1 == point 0
        }
        let pool = ThreadPool::new(2);
        let nl = BruteForceKnn::default().search(&pool, &data, 50, 4, 3);
        // nearest neighbor of 0 is its duplicate at distance ~0
        assert_eq!(nl.neighbors(0)[0], 1);
        assert!(nl.dists(0)[0] < 1e-12);
    }

    #[test]
    fn truncated_rows_equal_a_fresh_smaller_k_search() {
        let n = 150;
        let d = 5;
        let data = random_data(n, d, 11);
        let pool = ThreadPool::new(3);
        let deep = BruteForceKnn::default().search(&pool, &data, n, d, 20);
        let small = BruteForceKnn::default().search(&pool, &data, n, d, 7);
        let cut = deep.truncated(7);
        assert_eq!(cut.n, n);
        assert_eq!(cut.k, 7);
        assert_eq!(cut.indices, small.indices);
        assert_eq!(cut.distances_sq, small.distances_sq);
        // full-width truncation is the identity
        let same = deep.truncated(20);
        assert_eq!(same.indices, deep.indices);
        assert_eq!(same.distances_sq, deep.distances_sq);
    }

    #[test]
    fn reference_oracle_survives_nan_coordinates() {
        // One poisoned sample must not abort the oracle (total_cmp, not
        // partial_cmp().unwrap()); NaN distances sort last, so the finite
        // neighbors still come out front.
        let mut data = random_data(40, 3, 13);
        data[5 * 3] = f64::NAN;
        let nl = knn_reference(&data, 40, 3, 4);
        for j in nl.neighbors(0) {
            assert_ne!(*j, 5, "NaN point must not be a nearest neighbor of 0");
        }
        assert!(nl.dists(0).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn single_thread_matches_parallel() {
        let n = 257;
        let data = random_data(n, 5, 8);
        let p1 = ThreadPool::new(1);
        let p4 = ThreadPool::new(4);
        let a = BruteForceKnn::default().search(&p1, &data, n, 5, 10);
        let b = BruteForceKnn::default().search(&p4, &data, n, 5, 10);
        assert_eq!(a.indices, b.indices);
    }
}
