//! Vantage-point tree KNN — the neighbor search Multicore-TSNE (and
//! vdMaaten's original BH t-SNE code) actually uses, built here so the
//! `MulticoreLike` baseline reproduces that implementation's real KNN
//! behaviour: exact results, but pointer-chasing traversal with one query at
//! a time and no cache blocking (the contrast to the blocked engine the
//! paper inherits from daal4py).
//!
//! Construction: recursive median-split on distance to a vantage point
//! (vdMaaten's scheme). Search: branch-and-bound DFS with a bounded max-heap
//! (`KBest`) and the τ pruning radius. Parallel across queries.

use super::select::KBest;
use super::{KnnEngine, NeighborLists};
use crate::common::float::Real;
use crate::common::rng::Rng;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

struct VpNode<T> {
    /// Index (into the dataset) of the vantage point.
    point: u32,
    /// Points inside `threshold` of the vantage point go left.
    threshold: T,
    left: i32,
    right: i32,
}

/// An immutable VP-tree over a dataset (borrowed; the tree stores indices).
pub struct VpTree<'a, T: Real> {
    data: &'a [T],
    d: usize,
    nodes: Vec<VpNode<T>>,
    root: i32,
}

#[inline(always)]
fn dist_sq<T: Real>(data: &[T], d: usize, a: usize, b: usize) -> T {
    let (ra, rb) = (&data[a * d..(a + 1) * d], &data[b * d..(b + 1) * d]);
    let mut acc = T::ZERO;
    for (x, y) in ra.iter().zip(rb.iter()) {
        let diff = *x - *y;
        acc += diff * diff;
    }
    acc
}

impl<'a, T: Real> VpTree<'a, T> {
    /// Build over all `n` points of `data` (n × d). Deterministic for a
    /// given `seed` (vantage points are drawn randomly, as in vdMaaten).
    pub fn build(data: &'a [T], n: usize, d: usize, seed: u64) -> Self {
        assert_eq!(data.len(), n * d);
        let mut items: Vec<u32> = (0..n as u32).collect();
        let mut nodes = Vec::with_capacity(2 * n);
        let mut rng = Rng::new(seed);
        let root = Self::build_rec(data, d, &mut items[..], &mut nodes, &mut rng);
        VpTree {
            data,
            d,
            nodes,
            root,
        }
    }

    fn build_rec(
        data: &[T],
        d: usize,
        items: &mut [u32],
        nodes: &mut Vec<VpNode<T>>,
        rng: &mut Rng,
    ) -> i32 {
        if items.is_empty() {
            return -1;
        }
        // Random vantage point → swap to front.
        let pick = rng.next_below(items.len());
        items.swap(0, pick);
        let vp = items[0] as usize;
        if items.len() == 1 {
            let id = nodes.len() as i32;
            nodes.push(VpNode {
                point: vp as u32,
                threshold: T::ZERO,
                left: -1,
                right: -1,
            });
            return id;
        }
        // Median split of the rest by distance to the vantage point.
        let rest = &mut items[1..];
        let mid = rest.len() / 2;
        rest.select_nth_unstable_by(mid, |&a, &b| {
            dist_sq(data, d, vp, a as usize)
                .to_f64()
                .total_cmp(&dist_sq(data, d, vp, b as usize).to_f64())
        });
        let threshold = dist_sq(data, d, vp, rest[mid] as usize);
        let id = nodes.len();
        nodes.push(VpNode {
            point: vp as u32,
            threshold,
            left: -1,
            right: -1,
        });
        // Re-borrow items mutably in two halves (vp excluded).
        let (near, far) = items[1..].split_at_mut(mid);
        let left = Self::build_rec(data, d, near, nodes, rng);
        let right = Self::build_rec(data, d, far, nodes, rng);
        nodes[id].left = left;
        nodes[id].right = right;
        id as i32
    }

    /// k nearest neighbors of `query` (dataset index), self excluded.
    /// Returns (squared distance, index) ascending.
    pub fn knn(&self, query: usize, k: usize) -> Vec<(T, u32)> {
        let mut best = KBest::new(k);
        let mut stack: Vec<i32> = vec![self.root];
        while let Some(ni) = stack.pop() {
            if ni < 0 {
                continue;
            }
            let node = &self.nodes[ni as usize];
            let dist = dist_sq(self.data, self.d, query, node.point as usize);
            if node.point as usize != query {
                best.push(dist, node.point);
            }
            // τ² pruning: with squared distances, a child region can contain a
            // better candidate iff its distance bound beats the current τ.
            // Using the triangle inequality on true distances:
            //   |√dist − √threshold| < √τ  ⇔  explore the far side too.
            let tau = best.threshold().unwrap_or(T::MAX_REAL);
            let (first, second) = if dist < node.threshold {
                (node.left, node.right)
            } else {
                (node.right, node.left)
            };
            // Visit the near side unconditionally (push second so it pops
            // after the far-side check below... order: push far-conditional
            // first, near last so near is explored first).
            let explore_far = {
                let sd = dist.to_f64().sqrt();
                let st = node.threshold.to_f64().sqrt();
                let stau = tau.to_f64().sqrt();
                (sd - st).abs() < stau
            };
            if explore_far {
                stack.push(second);
            }
            stack.push(first);
        }
        best.into_sorted()
    }
}

/// [`KnnEngine`] backed by a VP-tree (the Multicore-TSNE KNN architecture).
pub struct VpTreeKnn {
    pub seed: u64,
}

impl Default for VpTreeKnn {
    fn default() -> Self {
        VpTreeKnn { seed: 0x5EED }
    }
}

impl<T: Real> KnnEngine<T> for VpTreeKnn {
    fn name(&self) -> &'static str {
        "vp-tree"
    }

    fn search(
        &self,
        pool: &ThreadPool,
        data: &[T],
        n: usize,
        d: usize,
        k: usize,
    ) -> NeighborLists<T> {
        assert!(k < n, "k must be < n");
        let tree = VpTree::build(data, n, d, self.seed);
        let mut indices = vec![0u32; n * k];
        let mut dists = vec![T::ZERO; n * k];
        {
            let is = SyncSlice::new(&mut indices);
            let ds = SyncSlice::new(&mut dists);
            let tree = &tree;
            parallel_for(pool, n, Schedule::Dynamic { grain: 64 }, |range| {
                for i in range {
                    let found = tree.knn(i, k);
                    debug_assert_eq!(found.len(), k);
                    for (j, (dist, idx)) in found.into_iter().enumerate() {
                        // SAFETY: disjoint — row i
                        unsafe {
                            *is.get_mut(i * k + j) = idx;
                            *ds.get_mut(i * k + j) = dist;
                        }
                    }
                }
            });
        }
        NeighborLists {
            n,
            k,
            indices,
            distances_sq: dists,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::knn_reference;
    use super::*;

    fn random_data(n: usize, d: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n * d).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn exact_vs_reference() {
        let (n, d, k) = (400, 6, 10);
        let data = random_data(n, d, 1);
        let pool = ThreadPool::new(4);
        let got: NeighborLists<f64> = VpTreeKnn::default().search(&pool, &data, n, d, k);
        let want = knn_reference(&data, n, d, k);
        for i in 0..n {
            for j in 0..k {
                let (g, w) = (got.distances_sq[i * k + j], want.distances_sq[i * k + j]);
                assert!((g - w).abs() < 1e-9 * (1.0 + w.abs()), "row {i} pos {j}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn excludes_self() {
        let data = random_data(100, 4, 2);
        let pool = ThreadPool::new(2);
        let nl: NeighborLists<f64> = VpTreeKnn::default().search(&pool, &data, 100, 4, 5);
        for i in 0..100 {
            assert!(nl.neighbors(i).iter().all(|&j| j as usize != i));
        }
    }

    #[test]
    fn handles_duplicates() {
        let mut data = random_data(60, 3, 3);
        for j in 0..3 {
            data[3 + j] = data[j];
            data[6 + j] = data[j];
        }
        let pool = ThreadPool::new(2);
        let nl: NeighborLists<f64> = VpTreeKnn::default().search(&pool, &data, 60, 3, 4);
        assert!(nl.dists(0)[0] < 1e-12);
        assert!(nl.dists(0)[1] < 1e-12);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let data = random_data(300, 5, 4);
        let a: NeighborLists<f64> =
            VpTreeKnn::default().search(&ThreadPool::new(1), &data, 300, 5, 8);
        let b: NeighborLists<f64> =
            VpTreeKnn::default().search(&ThreadPool::new(8), &data, 300, 5, 8);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn f32_works() {
        let data: Vec<f32> = random_data(200, 4, 5).iter().map(|&v| v as f32).collect();
        let pool = ThreadPool::new(2);
        let got: NeighborLists<f32> = VpTreeKnn::default().search(&pool, &data, 200, 4, 6);
        let want = knn_reference(&data, 200, 4, 6);
        for i in 0..200 {
            for j in 0..6 {
                let (g, w) = (got.distances_sq[i * 6 + j], want.distances_sq[i * 6 + j]);
                assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "row {i}");
            }
        }
    }
}
