//! Approximate KNN via HNSW (Malkov & Yashunin 2016) — the engine that takes
//! step 1 past the exact-search wall at 10⁶⁺ points.
//!
//! A layered skip-list graph: every point lives on layer 0, a geometrically
//! thinning subset on each layer above. A query greedily descends the sparse
//! upper layers to a good entry point, then runs an `ef`-bounded best-first
//! beam on layer 0. `ef_search` is the recall-vs-speed knob: wider beam, more
//! exact rows, more distance evaluations.
//!
//! **Determinism.** Construction is batched level-synchronous rather than
//! lock-sharded: a fixed doubling batch schedule (independent of thread
//! count) alternates a *parallel, read-only* candidate search against the
//! frozen graph with a *sequential, index-ordered* commit of the new links.
//! Every tie breaks on the (distance, index) lexicographic total order
//! (`select::KBest`'s order), so a fixed seed gives a bit-identical graph —
//! and bit-identical neighbor rows — at any thread count. The trade is that
//! points inside one batch do not see each other as candidates; with the
//! doubling schedule a batch is never larger than the committed graph (capped
//! at [`MAX_BATCH`]), which keeps the quality loss in the noise.
//!
//! Rows come out sorted ascending-(distance, index) like every other engine,
//! so the ⌊3u⌋-prefix re-fit contract holds *within one build*: truncating a
//! row is exactly the smaller-k search over the same graph. Across rebuilds
//! (different seed, params, or data) the approximate k-set itself may differ
//! — that is the documented difference from the exact engines.

use super::select::KBest;
use super::{KnnEngine, NeighborLists};
use crate::common::float::Real;
use crate::common::rng::Rng;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use std::cell::RefCell;

/// Default beam width for queries — the recall knob's resting position
/// (≥0.9 recall@k on the bench suite's Gaussian-mixture workload).
pub const DEFAULT_EF_SEARCH: usize = 64;

/// Layer cap: P(level ≥ 16) < (1/M)¹⁶ ≈ 0 for any sensible M.
const MAX_LEVEL: usize = 15;
/// Insertion batch cap — bounds the candidate staleness inside one batch.
const MAX_BATCH: usize = 4096;

/// Tunables for [`HnswIndex`]; recorded verbatim in the engine metadata of an
/// approximate [`KnnGraph`](crate::tsne::KnnGraph).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HnswParams {
    /// Links per node on upper layers (layer 0 holds `2M`).
    pub m: usize,
    /// Beam width while inserting — graph quality.
    pub ef_construction: usize,
    /// Beam width while querying — recall-vs-speed.
    pub ef_search: usize,
    /// Seeds the level assignment; same seed ⇒ bit-identical index.
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 200, ef_search: DEFAULT_EF_SEARCH, seed: 0x5EED }
    }
}

/// `a < b` under the (distance, index) lexicographic total order — the same
/// order `select::KBest` keeps, repeated here because that one is private.
#[inline(always)]
fn lt<T: Real>(a: &(T, u32), b: &(T, u32)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

#[inline(always)]
fn dist_sq<T: Real>(data: &[T], d: usize, a: usize, b: usize) -> T {
    let (ra, rb) = (&data[a * d..(a + 1) * d], &data[b * d..(b + 1) * d]);
    let mut acc = T::ZERO;
    for (x, y) in ra.iter().zip(rb.iter()) {
        let diff = *x - *y;
        acc += diff * diff;
    }
    acc
}

/// Plain binary min-heap over (distance, index) under [`lt`] — the beam's
/// expansion frontier. `std::collections::BinaryHeap` needs `Ord`, which
/// floats don't have; this is the 30-line alternative.
struct MinHeap<T: Real> {
    v: Vec<(T, u32)>,
}

impl<T: Real> MinHeap<T> {
    fn with_capacity(c: usize) -> Self {
        MinHeap { v: Vec::with_capacity(c) }
    }

    fn push(&mut self, e: (T, u32)) {
        self.v.push(e);
        let mut i = self.v.len() - 1;
        while i > 0 {
            let p = (i - 1) / 2;
            if lt(&self.v[i], &self.v[p]) {
                self.v.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn pop(&mut self) -> Option<(T, u32)> {
        if self.v.is_empty() {
            return None;
        }
        let last = self.v.len() - 1;
        self.v.swap(0, last);
        let out = self.v.pop();
        let n = self.v.len();
        let mut i = 0;
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut s = i;
            if l < n && lt(&self.v[l], &self.v[s]) {
                s = l;
            }
            if r < n && lt(&self.v[r], &self.v[s]) {
                s = r;
            }
            if s == i {
                break;
            }
            self.v.swap(i, s);
            i = s;
        }
        out
    }
}

/// Per-thread visited set: epoch-stamped marks instead of a cleared bitmap,
/// so a beam search costs O(visited), not O(n), per query. Lives in a
/// `thread_local` because the pool's workers persist across calls.
struct SearchScratch {
    stamp: Vec<u32>,
    epoch: u32,
}

impl SearchScratch {
    fn new() -> Self {
        SearchScratch { stamp: Vec::new(), epoch: 0 }
    }

    fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        if self.epoch == u32::MAX {
            self.stamp.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Mark `v` visited; `true` if it already was (this epoch).
    #[inline(always)]
    fn visit(&mut self, v: u32) -> bool {
        let s = &mut self.stamp[v as usize];
        if *s == self.epoch {
            true
        } else {
            *s = self.epoch;
            false
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<SearchScratch> = RefCell::new(SearchScratch::new());
}

/// An immutable HNSW index over a borrowed dataset. Build once, then run
/// [`Self::search_all`] at any `k`/`ef` — the bench suite's ef-sweep reuses
/// one index across the whole recall curve.
pub struct HnswIndex<'a, T: Real> {
    data: &'a [T],
    n: usize,
    d: usize,
    m: usize,
    m0: usize,
    levels: Vec<u8>,
    entry: u32,
    top: u8,
    /// Layer-0 adjacency, flat `n × m0` with per-node counts.
    links0: Vec<u32>,
    cnt0: Vec<u32>,
    /// `upper[v][l-1]` = v's neighbors on layer `l ≥ 1` (empty for most v).
    upper: Vec<Vec<Vec<u32>>>,
}

impl<'a, T: Real> HnswIndex<'a, T> {
    /// Number of indexed points.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// `true` only for an impossible empty index (`build` rejects n = 0);
    /// present so `len` satisfies the usual pair convention.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Build over all `n` points of `data` (n × d). Deterministic for a given
    /// `params.seed` at any pool width.
    pub fn build(
        pool: &ThreadPool,
        data: &'a [T],
        n: usize,
        d: usize,
        params: &HnswParams,
    ) -> Self {
        assert!(n > 0, "HNSW needs at least one point");
        assert_eq!(data.len(), n * d);
        let m = params.m.max(2);
        let m0 = 2 * m;
        let efc = params.ef_construction.max(m);
        // Geometric level assignment, one sequential RNG pass (order is part
        // of the determinism contract). -ln(0)·mult = +inf saturates under
        // `as usize`, so a zero draw lands on MAX_LEVEL rather than UB.
        let mult = 1.0 / (m as f64).ln();
        let mut rng = Rng::new(params.seed);
        let levels: Vec<u8> = (0..n)
            .map(|_| ((-rng.next_f64().ln() * mult) as usize).min(MAX_LEVEL) as u8)
            .collect();
        let upper = levels.iter().map(|&l| vec![Vec::new(); l as usize]).collect();
        let mut index = HnswIndex {
            data,
            n,
            d,
            m,
            m0,
            entry: 0,
            top: levels[0],
            levels,
            links0: vec![0u32; n * m0],
            cnt0: vec![0u32; n],
            upper,
        };
        // Batched level-synchronous insertion: phase A searches the frozen
        // graph in parallel, phase B commits links sequentially in index
        // order. The doubling schedule is a pure function of n.
        let mut committed = 1usize;
        while committed < n {
            let batch = committed.min(MAX_BATCH).min(n - committed);
            let base = committed;
            let mut found: Vec<Vec<Vec<(T, u32)>>> = Vec::new();
            found.resize_with(batch, Vec::new);
            {
                let fs = SyncSlice::new(&mut found);
                let frozen = &index;
                parallel_for(pool, batch, Schedule::Dynamic { grain: 8 }, |range| {
                    SCRATCH.with(|cell| {
                        let scratch = &mut *cell.borrow_mut();
                        for t in range {
                            let cands = frozen.insert_candidates(base + t, efc, scratch);
                            // SAFETY: disjoint — slot t
                            unsafe { *fs.get_mut(t) = cands };
                        }
                    })
                });
            }
            for t in 0..batch {
                index.commit(base + t, std::mem::take(&mut found[t]));
            }
            committed += batch;
        }
        index
    }

    #[inline(always)]
    fn dist(&self, a: usize, b: usize) -> T {
        dist_sq(self.data, self.d, a, b)
    }

    #[inline]
    fn neighbors(&self, v: usize, l: usize) -> &[u32] {
        if l == 0 {
            &self.links0[v * self.m0..v * self.m0 + self.cnt0[v] as usize]
        } else {
            match self.upper[v].get(l - 1) {
                Some(list) => list,
                None => &[],
            }
        }
    }

    fn neighbor_count(&self, v: usize, l: usize) -> usize {
        self.neighbors(v, l).len()
    }

    fn add_link(&mut self, from: usize, to: u32, l: usize) {
        if l == 0 {
            let c = self.cnt0[from] as usize;
            debug_assert!(c < self.m0);
            self.links0[from * self.m0 + c] = to;
            self.cnt0[from] += 1;
        } else {
            self.upper[from][l - 1].push(to);
        }
    }

    fn set_links(&mut self, v: usize, l: usize, sel: &[(T, u32)]) {
        if l == 0 {
            for (j, &(_, u)) in sel.iter().enumerate() {
                self.links0[v * self.m0 + j] = u;
            }
            self.cnt0[v] = sel.len() as u32;
        } else {
            let list = &mut self.upper[v][l - 1];
            list.clear();
            list.extend(sel.iter().map(|&(_, u)| u));
        }
    }

    /// Greedy hill-climb on layer `l` toward `q`; ties go to the smaller
    /// index so the walk is scan-order-free.
    fn greedy(&self, q: usize, mut ep: u32, mut dep: T, l: usize) -> (u32, T) {
        loop {
            let mut improved = false;
            let at = ep;
            for &v in self.neighbors(at as usize, l) {
                let dv = self.dist(q, v as usize);
                if dv < dep || (dv == dep && v < ep) {
                    dep = dv;
                    ep = v;
                    improved = true;
                }
            }
            if !improved {
                return (ep, dep);
            }
        }
    }

    /// Best-first beam on layer `l`: expand the closest frontier node until
    /// it is farther than the ef-th best. Returns the ef best found, sorted
    /// ascending-(distance, index).
    fn search_layer(
        &self,
        q: usize,
        ep: u32,
        dep: T,
        ef: usize,
        l: usize,
        scratch: &mut SearchScratch,
    ) -> Vec<(T, u32)> {
        scratch.begin(self.n);
        let mut w = KBest::new(ef);
        let mut cand = MinHeap::with_capacity(ef + 1);
        scratch.visit(ep);
        w.push(dep, ep);
        cand.push((dep, ep));
        while let Some((dc, c)) = cand.pop() {
            if let Some(t) = w.threshold() {
                if dc > t {
                    break;
                }
            }
            for &v in self.neighbors(c as usize, l) {
                if scratch.visit(v) {
                    continue;
                }
                let dv = self.dist(q, v as usize);
                let expand = match w.threshold() {
                    None => true,
                    Some(t) => dv <= t,
                };
                w.push(dv, v);
                if expand {
                    cand.push((dv, v));
                }
            }
        }
        w.into_sorted()
    }

    /// Phase A of an insertion: candidate lists for `q` on every layer it
    /// will join, computed read-only against the frozen graph.
    fn insert_candidates(
        &self,
        q: usize,
        efc: usize,
        scratch: &mut SearchScratch,
    ) -> Vec<Vec<(T, u32)>> {
        let lq = (self.levels[q] as usize).min(self.top as usize);
        let mut ep = self.entry;
        let mut dep = self.dist(q, ep as usize);
        let mut l = self.top as usize;
        while l > lq {
            let (e, de) = self.greedy(q, ep, dep, l);
            ep = e;
            dep = de;
            l -= 1;
        }
        let mut out = vec![Vec::new(); lq + 1];
        loop {
            let w = self.search_layer(q, ep, dep, efc, l, scratch);
            if let Some(&(d0, e0)) = w.first() {
                ep = e0;
                dep = d0;
            }
            out[l] = w;
            if l == 0 {
                return out;
            }
            l -= 1;
        }
    }

    /// Phase B: wire `q` into the graph. Sequential, index order — the only
    /// place the graph mutates.
    fn commit(&mut self, q: usize, cands: Vec<Vec<(T, u32)>>) {
        for (l, level_cands) in cands.into_iter().enumerate() {
            if level_cands.is_empty() {
                continue;
            }
            // Connect M per layer at insert time; layer 0's 2M capacity
            // absorbs reverse-link growth before pruning kicks in.
            let sel = self.select_heuristic(level_cands, self.m);
            for &(dqv, v) in &sel {
                self.add_link(q, v, l);
                self.add_link_rev(v as usize, q as u32, dqv, l);
            }
        }
        if self.levels[q] > self.top {
            self.top = self.levels[q];
            self.entry = q as u32;
        }
    }

    /// Malkov's neighbor-selection heuristic over an ascending candidate
    /// list: keep c unless some already-kept s is closer to c than q is
    /// (diversity), then backfill skipped candidates in order up to `cap`.
    /// Pure function of the (sorted) input — no RNG, no scan-order effects.
    fn select_heuristic(&self, cands: Vec<(T, u32)>, cap: usize) -> Vec<(T, u32)> {
        debug_assert!(cands.windows(2).all(|w| lt(&w[0], &w[1])));
        if cands.len() <= cap {
            return cands;
        }
        let mut sel: Vec<(T, u32)> = Vec::with_capacity(cap);
        let mut skipped: Vec<(T, u32)> = Vec::new();
        for &(dc, c) in &cands {
            if sel.len() == cap {
                break;
            }
            let dominated = sel.iter().any(|&(_, s)| self.dist(c as usize, s as usize) < dc);
            if dominated {
                skipped.push((dc, c));
            } else {
                sel.push((dc, c));
            }
        }
        for &p in &skipped {
            if sel.len() == cap {
                break;
            }
            sel.push(p);
        }
        sel
    }

    /// Reverse edge v → q; prune v's list with the same heuristic if full.
    fn add_link_rev(&mut self, v: usize, q: u32, dvq: T, l: usize) {
        let cap = if l == 0 { self.m0 } else { self.m };
        if self.neighbor_count(v, l) < cap {
            self.add_link(v, q, l);
            return;
        }
        let mut cands: Vec<(T, u32)> = self
            .neighbors(v, l)
            .iter()
            .map(|&u| (self.dist(v, u as usize), u))
            .collect();
        cands.push((dvq, q));
        cands.sort_by(|a, b| a.0.to_f64().total_cmp(&b.0.to_f64()).then_with(|| a.1.cmp(&b.1)));
        let sel = self.select_heuristic(cands, cap);
        self.set_links(v, l, &sel);
    }

    /// One query row: descend to layer 0, beam with `ef`, drop self, take k.
    fn query_row(
        &self,
        i: usize,
        k: usize,
        ef: usize,
        scratch: &mut SearchScratch,
    ) -> Vec<(T, u32)> {
        let mut ep = self.entry;
        let mut dep = self.dist(i, ep as usize);
        let mut l = self.top as usize;
        while l > 0 {
            let (e, de) = self.greedy(i, ep, dep, l);
            ep = e;
            dep = de;
            l -= 1;
        }
        let w = self.search_layer(i, ep, dep, ef, 0, scratch);
        let mut row: Vec<(T, u32)> =
            w.into_iter().filter(|&(_, v)| v as usize != i).take(k).collect();
        if row.len() < k {
            // The beam can come up short on degenerate graphs (heavy
            // duplication, tiny n). Exact fallback keeps every row a valid
            // k-list — persist-time validation rejects anything less.
            let mut best = KBest::new(k);
            for j in 0..self.n {
                if j != i {
                    best.push(self.dist(i, j), j as u32);
                }
            }
            row = best.into_sorted();
        }
        row
    }

    /// k approximate nearest neighbors of every indexed point, self excluded,
    /// rows ascending-(distance, index). The beam runs at
    /// `max(ef_search, k + 1)` (the query point itself occupies one slot).
    pub fn search_all(&self, pool: &ThreadPool, k: usize, ef_search: usize) -> NeighborLists<T> {
        assert!(k < self.n, "k ({k}) must be < n ({})", self.n);
        let ef = ef_search.max(k + 1);
        let mut indices = vec![0u32; self.n * k];
        let mut dists = vec![T::ZERO; self.n * k];
        {
            let is = SyncSlice::new(&mut indices);
            let ds = SyncSlice::new(&mut dists);
            parallel_for(pool, self.n, Schedule::Dynamic { grain: 32 }, |range| {
                SCRATCH.with(|cell| {
                    let scratch = &mut *cell.borrow_mut();
                    for i in range {
                        let row = self.query_row(i, k, ef, scratch);
                        debug_assert_eq!(row.len(), k);
                        for (j, (dist, idx)) in row.into_iter().enumerate() {
                            // SAFETY: disjoint — row i
                            unsafe {
                                *is.get_mut(i * k + j) = idx;
                                *ds.get_mut(i * k + j) = dist;
                            }
                        }
                    }
                })
            });
        }
        NeighborLists { n: self.n, k, indices, distances_sq: dists }
    }
}

/// [`KnnEngine`] backed by [`HnswIndex`] — approximate rows, one build + one
/// sweep per call. For an ef-sweep over one index, use [`HnswIndex`] direct.
pub struct HnswKnn {
    pub params: HnswParams,
}

impl Default for HnswKnn {
    fn default() -> Self {
        HnswKnn { params: HnswParams::default() }
    }
}

impl<T: Real> KnnEngine<T> for HnswKnn {
    fn name(&self) -> &'static str {
        "hnsw"
    }

    fn search(
        &self,
        pool: &ThreadPool,
        data: &[T],
        n: usize,
        d: usize,
        k: usize,
    ) -> NeighborLists<T> {
        assert!(k < n, "k ({k}) must be < n ({n})");
        assert_eq!(data.len(), n * d);
        let index = HnswIndex::build(pool, data, n, d, &self.params);
        index.search_all(pool, k, self.params.ef_search)
    }
}

#[cfg(test)]
mod tests {
    use super::super::knn_reference;
    use super::*;
    use crate::data::synthetic::gaussian_mixture;

    fn recall(got: &NeighborLists<f64>, want: &NeighborLists<f64>) -> f64 {
        let (n, k) = (want.n, want.k);
        let mut hits = 0usize;
        for i in 0..n {
            let truth: std::collections::HashSet<u32> =
                want.neighbors(i).iter().copied().collect();
            hits += got.neighbors(i).iter().filter(|j| truth.contains(j)).count();
        }
        hits as f64 / (n * k) as f64
    }

    fn assert_rows_valid<T: Real>(nl: &NeighborLists<T>) {
        for i in 0..nl.n {
            let row = nl.neighbors(i);
            assert!(row.iter().all(|&j| (j as usize) < nl.n && j as usize != i), "row {i}");
            let mut seen = row.to_vec();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), nl.k, "row {i} has duplicate neighbors");
            let dr = nl.dists(i);
            assert!(dr.iter().all(|v| v.is_finite_r()), "row {i} non-finite");
            for w in 0..nl.k - 1 {
                let a = (dr[w], row[w]);
                let b = (dr[w + 1], row[w + 1]);
                assert!(lt(&a, &b), "row {i} not ascending-(dist, idx) at {w}");
            }
        }
    }

    #[test]
    fn hnsw_recall_above_090_vs_exact_oracle() {
        let ds = gaussian_mixture::<f64>(1500, 8, 10, 6.0, 42);
        let pool = ThreadPool::new(4);
        let params = HnswParams { m: 12, ef_construction: 120, ..HnswParams::default() };
        let got = HnswKnn { params }.search(&pool, &ds.points, ds.n, ds.d, 10);
        let want = knn_reference(&ds.points, ds.n, ds.d, 10);
        let r = recall(&got, &want);
        assert!(r >= 0.9, "recall@10 = {r} at default ef_search");
        assert_rows_valid(&got);
    }

    #[test]
    fn hnsw_bit_identical_across_thread_counts() {
        let ds = gaussian_mixture::<f64>(700, 8, 6, 5.0, 7);
        let mut results = Vec::new();
        for nt in [1, 4, 8] {
            let pool = ThreadPool::new(nt);
            let nl: NeighborLists<f64> =
                HnswKnn::default().search(&pool, &ds.points, ds.n, ds.d, 9);
            results.push(nl);
        }
        for nl in &results[1..] {
            assert_eq!(nl.indices, results[0].indices, "indices differ across thread counts");
            assert_eq!(
                nl.distances_sq, results[0].distances_sq,
                "distances differ across thread counts"
            );
        }
    }

    #[test]
    fn hnsw_rows_sorted_unique_and_self_free() {
        let ds = gaussian_mixture::<f64>(400, 6, 5, 4.0, 11);
        let pool = ThreadPool::new(3);
        let nl: NeighborLists<f64> = HnswKnn::default().search(&pool, &ds.points, ds.n, ds.d, 12);
        assert_rows_valid(&nl);
    }

    #[test]
    fn hnsw_duplicate_heavy_and_coincident_clouds_stay_valid() {
        // (a) heavy duplication: the first 40 of 120 points coincide.
        let mut ds = gaussian_mixture::<f64>(120, 5, 3, 4.0, 13);
        for i in 1..40 {
            for j in 0..5 {
                ds.points[i * 5 + j] = ds.points[j];
            }
        }
        let pool = ThreadPool::new(4);
        let nl: NeighborLists<f64> = HnswKnn::default().search(&pool, &ds.points, 120, 5, 8);
        assert_rows_valid(&nl);
        assert!(nl.dists(0)[0] == 0.0, "a duplicate must be the nearest neighbor");
        // (b) fully coincident cloud: every distance is zero, rows must
        // still be k distinct non-self indices, identically at 1 and 4
        // threads.
        let cloud = vec![1.25f64; 32 * 4];
        let a: NeighborLists<f64> =
            HnswKnn::default().search(&ThreadPool::new(1), &cloud, 32, 4, 5);
        let b: NeighborLists<f64> =
            HnswKnn::default().search(&ThreadPool::new(4), &cloud, 32, 4, 5);
        assert_rows_valid(&a);
        assert!(a.distances_sq.iter().all(|&v| v == 0.0));
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn hnsw_truncated_prefix_matches_smaller_k_same_build() {
        // Per-build prefix stability: both searches share the index and the
        // effective beam (max(64, k+1) = 64), so the k=7 rows are exactly
        // the first 7 columns of the k=20 rows.
        let ds = gaussian_mixture::<f64>(500, 7, 4, 5.0, 17);
        let pool = ThreadPool::new(4);
        let index = HnswIndex::build(&pool, &ds.points, ds.n, ds.d, &HnswParams::default());
        let deep = index.search_all(&pool, 20, DEFAULT_EF_SEARCH);
        let small = index.search_all(&pool, 7, DEFAULT_EF_SEARCH);
        let cut = deep.truncated(7);
        assert_eq!(cut.indices, small.indices);
        assert_eq!(cut.distances_sq, small.distances_sq);
    }

    #[test]
    fn hnsw_f32_works() {
        let ds = gaussian_mixture::<f32>(600, 6, 4, 5.0, 23);
        let pool = ThreadPool::new(2);
        let got: NeighborLists<f32> = HnswKnn::default().search(&pool, &ds.points, ds.n, ds.d, 8);
        assert_rows_valid(&got);
        let data64: Vec<f64> = ds.points.iter().map(|&v| v as f64).collect();
        let want = knn_reference(&data64, ds.n, ds.d, 8);
        let got64 = NeighborLists::<f64> {
            n: got.n,
            k: got.k,
            indices: got.indices.clone(),
            distances_sq: got.distances_sq.iter().map(|&v| v as f64).collect(),
        };
        assert!(recall(&got64, &want) >= 0.85);
    }
}
