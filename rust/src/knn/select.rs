//! Bounded k-selection: a fixed-capacity max-heap keeping the k smallest
//! (distance, index) pairs seen. The KNN inner loop pushes every candidate;
//! the heap root is the current k-th best, giving an O(log k) accept path and
//! an O(1) reject path (the common case).
//!
//! Candidates are ordered by the **(distance, index) lexicographic total
//! order**, not by distance alone: exact distance ties (duplicate points)
//! resolve to the smaller index, so the selected k-set is a deterministic,
//! scan-order-independent function of the candidates — and the k₂ smallest
//! are always a prefix of the k₁ smallest for k₂ ≤ k₁. That prefix stability
//! is what lets a deep KNN graph re-fit smaller perplexities bit-identically
//! (`tsne::Affinities::from_knn` truncates rows).

use crate::common::float::Real;

/// `a < b` under the (distance, index) lexicographic total order.
#[inline(always)]
fn lt<T: Real>(a: &(T, u32), b: &(T, u32)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Max-heap over (distance, index) holding at most `k` best (smallest)
/// candidates.
#[derive(Clone, Debug)]
pub struct KBest<T: Real> {
    k: usize,
    heap: Vec<(T, u32)>,
}

impl<T: Real> KBest<T> {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        KBest {
            k,
            heap: Vec::with_capacity(k),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current acceptance threshold (k-th best distance), if full.
    #[inline]
    pub fn threshold(&self) -> Option<T> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Offer a candidate. Ties on distance resolve to the smaller index
    /// (the lexicographic total order), so the retained set never depends
    /// on the scan order or on `k` beyond the cut itself.
    #[inline]
    pub fn push(&mut self, dist: T, idx: u32) {
        if self.heap.len() < self.k {
            self.heap.push((dist, idx));
            self.sift_up(self.heap.len() - 1);
        } else if lt(&(dist, idx), &self.heap[0]) {
            self.heap[0] = (dist, idx);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if lt(&self.heap[parent], &self.heap[i]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut largest = i;
            if l < n && lt(&self.heap[largest], &self.heap[l]) {
                largest = l;
            }
            if r < n && lt(&self.heap[largest], &self.heap[r]) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into sorted order: distance ascending, index ascending within
    /// equal distances (the same total order `push` selects under).
    pub fn into_sorted(mut self) -> Vec<(T, u32)> {
        self.heap.sort_by(|a, b| {
            a.0.to_f64()
                .total_cmp(&b.0.to_f64())
                .then_with(|| a.1.cmp(&b.1))
        });
        self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut kb = KBest::<f64>::new(3);
        for (i, d) in [5.0, 1.0, 9.0, 2.0, 7.0, 0.5].iter().enumerate() {
            kb.push(*d, i as u32);
        }
        let out = kb.into_sorted();
        let dists: Vec<f64> = out.iter().map(|p| p.0).collect();
        assert_eq!(dists, vec![0.5, 1.0, 2.0]);
        let idxs: Vec<u32> = out.iter().map(|p| p.1).collect();
        assert_eq!(idxs, vec![5, 1, 3]);
    }

    #[test]
    fn underfull_returns_all() {
        let mut kb = KBest::<f32>::new(10);
        kb.push(3.0, 0);
        kb.push(1.0, 1);
        let out = kb.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, 1);
    }

    #[test]
    fn threshold_only_when_full() {
        let mut kb = KBest::<f64>::new(2);
        assert!(kb.threshold().is_none());
        kb.push(4.0, 0);
        assert!(kb.threshold().is_none());
        kb.push(2.0, 1);
        assert_eq!(kb.threshold(), Some(4.0));
        kb.push(1.0, 2);
        assert_eq!(kb.threshold(), Some(2.0));
    }

    #[test]
    fn ties_resolve_to_smaller_indices_independent_of_scan_order_and_k() {
        // Four zero-distance candidates plus one far one, in two scan
        // orders. The retained set must be the (dist, idx)-smallest k in
        // both, and the k=2 result must be a prefix of the k=3 result —
        // the contract Affinities::from_knn's truncation rests on.
        let scans: [&[(f64, u32)]; 2] = [
            &[(0.0, 7), (0.0, 2), (5.0, 1), (0.0, 9), (0.0, 4)],
            &[(0.0, 9), (5.0, 1), (0.0, 4), (0.0, 2), (0.0, 7)],
        ];
        for scan in scans {
            for (k, want) in [(2, vec![2u32, 4]), (3, vec![2, 4, 7])] {
                let mut kb = KBest::<f64>::new(k);
                for &(dist, idx) in scan {
                    kb.push(dist, idx);
                }
                let got: Vec<u32> = kb.into_sorted().iter().map(|p| p.1).collect();
                assert_eq!(got, want, "k = {k}");
            }
        }
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n = 1 + rng.next_below(200);
            let k = 1 + rng.next_below(20);
            let dists: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
            let mut kb = KBest::new(k);
            for (i, &d) in dists.iter().enumerate() {
                kb.push(d, i as u32);
            }
            let got: Vec<f64> = kb.into_sorted().iter().map(|p| p.0).collect();
            let mut want = dists.clone();
            want.sort_by(f64::total_cmp);
            want.truncate(k);
            assert_eq!(got, want);
        }
    }
}
