//! Bounded k-selection: a fixed-capacity max-heap keeping the k smallest
//! (distance, index) pairs seen. The KNN inner loop pushes every candidate;
//! the heap root is the current k-th best, giving an O(log k) accept path and
//! an O(1) reject path (the common case).

use crate::common::float::Real;

/// Max-heap over distance holding at most `k` best (smallest) candidates.
#[derive(Clone, Debug)]
pub struct KBest<T: Real> {
    k: usize,
    heap: Vec<(T, u32)>,
}

impl<T: Real> KBest<T> {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        KBest {
            k,
            heap: Vec::with_capacity(k),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current acceptance threshold (k-th best distance), if full.
    #[inline]
    pub fn threshold(&self) -> Option<T> {
        if self.heap.len() == self.k {
            Some(self.heap[0].0)
        } else {
            None
        }
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, dist: T, idx: u32) {
        if self.heap.len() < self.k {
            self.heap.push((dist, idx));
            self.sift_up(self.heap.len() - 1);
        } else if dist < self.heap[0].0 {
            self.heap[0] = (dist, idx);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 > self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut largest = i;
            if l < n && self.heap[l].0 > self.heap[largest].0 {
                largest = l;
            }
            if r < n && self.heap[r].0 > self.heap[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into (distance-ascending) sorted order.
    pub fn into_sorted(mut self) -> Vec<(T, u32)> {
        self.heap
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut kb = KBest::<f64>::new(3);
        for (i, d) in [5.0, 1.0, 9.0, 2.0, 7.0, 0.5].iter().enumerate() {
            kb.push(*d, i as u32);
        }
        let out = kb.into_sorted();
        let dists: Vec<f64> = out.iter().map(|p| p.0).collect();
        assert_eq!(dists, vec![0.5, 1.0, 2.0]);
        let idxs: Vec<u32> = out.iter().map(|p| p.1).collect();
        assert_eq!(idxs, vec![5, 1, 3]);
    }

    #[test]
    fn underfull_returns_all() {
        let mut kb = KBest::<f32>::new(10);
        kb.push(3.0, 0);
        kb.push(1.0, 1);
        let out = kb.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, 1);
    }

    #[test]
    fn threshold_only_when_full() {
        let mut kb = KBest::<f64>::new(2);
        assert!(kb.threshold().is_none());
        kb.push(4.0, 0);
        assert!(kb.threshold().is_none());
        kb.push(2.0, 1);
        assert_eq!(kb.threshold(), Some(4.0));
        kb.push(1.0, 2);
        assert_eq!(kb.threshold(), Some(2.0));
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n = 1 + rng.next_below(200);
            let k = 1 + rng.next_below(20);
            let dists: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
            let mut kb = KBest::new(k);
            for (i, &d) in dists.iter().enumerate() {
                kb.push(d, i as u32);
            }
            let got: Vec<f64> = kb.into_sorted().iter().map(|p| p.0).collect();
            let mut want = dists.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.truncate(k);
            assert_eq!(got, want);
        }
    }
}
