//! PJRT runtime: load the AOT-compiled HLO artifacts (L2/L1 output of
//! `make artifacts`) and execute them from the Rust hot path.
//!
//! Python is build-time only — once `artifacts/*.hlo.txt` exist, the binary
//! is self-contained: [`Runtime::load`] parses the HLO **text** (the
//! interchange format that survives the jax≥0.5 / xla_extension 0.5.1 proto
//! id mismatch, see python/compile/aot.py), compiles each module once on the
//! PJRT CPU client, and [`engines`] wrap the executables behind the same
//! traits the native engines implement.

pub mod engines;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One compiled artifact.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with the given literals; returns the flattened tuple outputs.
    /// Takes references so callers can reuse large input literals across calls.
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of '{}'", self.name))?;
        // aot.py lowers with return_tuple=True.
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT client plus every artifact in an artifacts directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Connect the CPU PJRT client and remember the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir })
    }

    /// Default artifacts location (repo-root relative).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    /// Compile one artifact by name (`knn_sqdist`, `attractive`, `morton`,
    /// `repulsive_dense`).
    pub fn compile(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        Ok(Artifact {
            name: name.to_string(),
            exe,
        })
    }
}

/// f32 literal from a slice with a shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal from a slice with a shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn missing_artifacts_dir_errors() {
        let r = Runtime::load("/nonexistent/path");
        assert!(r.is_err());
        let msg = format!("{:#}", r.err().unwrap());
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
