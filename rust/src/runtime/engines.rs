//! XLA-offloaded engines: the L1/L2 artifacts behind the same traits the
//! native Rust engines implement — the proof that all three layers compose.
//!
//! Shapes here are frozen at AOT time (see python/compile/aot.py and
//! artifacts/manifest.json); inputs are padded to the artifact tile and
//! outputs un-padded. All artifacts are f32; the engines accept any
//! [`Real`]/[`Scalar`] and convert at the boundary (the paper's own f32 mode,
//! Table S1, runs the whole pipeline in f32).

use super::{literal_f32, literal_i32, Artifact, Runtime};
use crate::common::float::Real;
use crate::knn::select::KBest;
use crate::knn::{KnnEngine, NeighborLists};
use crate::parallel::ThreadPool;
use crate::sparse::CsrMatrix;
use crate::tsne::{AttractiveEngine, Scalar};
use anyhow::Result;

// Artifact tile shapes — must match python/compile/kernels/* constants
// (pinned by python/tests/test_aot.py and artifacts/manifest.json).
pub const SQDIST_BQ: usize = 128;
pub const SQDIST_BC: usize = 128;
pub const SQDIST_D: usize = 32;
pub const ATTR_NSRC: usize = 4096;
pub const ATTR_B: usize = 256;
pub const ATTR_K: usize = 96;
pub const MORTON_N: usize = 1024;
pub const REP_B: usize = 256;
pub const REP_C: usize = 2048;

/// KNN with the distance tiles computed by the AOT `knn_sqdist` artifact
/// (Pallas `sqdist` kernel on the PJRT CPU client).
pub struct XlaKnn {
    art: Artifact,
}

impl XlaKnn {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(XlaKnn {
            art: rt.compile("knn_sqdist")?,
        })
    }
}

impl<T: Real> KnnEngine<T> for XlaKnn {
    fn name(&self) -> &'static str {
        "xla-sqdist"
    }

    fn search(
        &self,
        _pool: &ThreadPool,
        data: &[T],
        n: usize,
        d: usize,
        k: usize,
    ) -> NeighborLists<T> {
        assert!(k < n, "k must be < n");
        assert!(d <= SQDIST_D, "artifact frozen at d ≤ {SQDIST_D}, got {d}");
        // Pad feature dim with zeros (distance-invariant).
        let tile_of = |start: usize| -> Vec<f32> {
            let mut t = vec![0.0f32; SQDIST_BQ * SQDIST_D];
            for r in 0..SQDIST_BQ {
                let i = start + r;
                if i >= n {
                    break;
                }
                for j in 0..d {
                    t[r * SQDIST_D + j] = data[i * d + j].to_f64() as f32;
                }
            }
            t
        };
        let mut heaps: Vec<KBest<T>> = (0..n).map(|_| KBest::new(k)).collect();
        let mut q0 = 0;
        while q0 < n {
            let q_tile = literal_f32(&tile_of(q0), &[SQDIST_BQ as i64, SQDIST_D as i64])
                .expect("query literal");
            let mut c0 = 0;
            while c0 < n {
                let c_tile = literal_f32(&tile_of(c0), &[SQDIST_BC as i64, SQDIST_D as i64])
                    .expect("corpus literal");
                let out = self
                    .art
                    .run(&[&q_tile, &c_tile])
                    .expect("sqdist artifact execution");
                let dists: Vec<f32> = out[0].to_vec().expect("sqdist output");
                for qi in 0..SQDIST_BQ.min(n - q0) {
                    let i = q0 + qi;
                    for ci in 0..SQDIST_BC.min(n - c0) {
                        let j = c0 + ci;
                        if i == j {
                            continue;
                        }
                        let dsq = dists[qi * SQDIST_BC + ci].max(0.0);
                        heaps[i].push(T::from_f64(dsq as f64), j as u32);
                    }
                }
                c0 += SQDIST_BC;
            }
            q0 += SQDIST_BQ;
        }
        let mut indices = vec![0u32; n * k];
        let mut distances_sq = vec![T::ZERO; n * k];
        for (i, h) in heaps.into_iter().enumerate() {
            for (j, (dist, idx)) in h.into_sorted().into_iter().enumerate() {
                indices[i * k + j] = idx;
                distances_sq[i * k + j] = dist;
            }
        }
        NeighborLists {
            n,
            k,
            indices,
            distances_sq,
        }
    }
}

/// Attractive-force engine backed by the AOT `attractive` artifact
/// (XLA gathers + Pallas VPU tile). Supports n ≤ [`ATTR_NSRC`] (the gather
/// source is frozen at AOT time) and row nnz ≤ [`ATTR_K`].
pub struct XlaAttractive {
    art: Artifact,
}

impl XlaAttractive {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(XlaAttractive {
            art: rt.compile("attractive")?,
        })
    }
}

impl<T: Scalar> AttractiveEngine<T> for XlaAttractive {
    fn name(&self) -> &'static str {
        "xla-attractive"
    }

    fn compute(&self, _pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T], out: &mut [T]) {
        let n = p.n;
        assert!(n <= ATTR_NSRC, "attractive artifact frozen at n ≤ {ATTR_NSRC}");
        assert_eq!(out.len(), 2 * n);
        // Gather source: y padded to [ATTR_NSRC, 2] f32.
        let mut ysrc = vec![0.0f32; ATTR_NSRC * 2];
        for i in 0..2 * n {
            ysrc[i] = y[i].to_f64() as f32;
        }
        let y_lit = literal_f32(&ysrc, &[ATTR_NSRC as i64, 2]).expect("y literal");
        let mut b0 = 0;
        while b0 < n {
            let bsz = ATTR_B.min(n - b0);
            let mut rows = vec![0i32; ATTR_B];
            let mut idx = vec![0i32; ATTR_B * ATTR_K];
            let mut val = vec![0.0f32; ATTR_B * ATTR_K];
            for r in 0..bsz {
                let i = b0 + r;
                rows[r] = i as i32;
                let (cols, vals) = p.row(i);
                assert!(
                    cols.len() <= ATTR_K,
                    "row {i} has {} nnz > artifact K {ATTR_K}",
                    cols.len()
                );
                for (t, (c, v)) in cols.iter().zip(vals.iter()).enumerate() {
                    idx[r * ATTR_K + t] = *c as i32;
                    val[r * ATTR_K + t] = v.to_f64() as f32;
                }
            }
            let rows_lit = literal_i32(&rows, &[ATTR_B as i64]).unwrap();
            let idx_lit = literal_i32(&idx, &[ATTR_B as i64, ATTR_K as i64]).unwrap();
            let val_lit = literal_f32(&val, &[ATTR_B as i64, ATTR_K as i64]).unwrap();
            let outs = self
                .art
                .run(&[&y_lit, &rows_lit, &idx_lit, &val_lit])
                .expect("attractive artifact execution");
            let forces: Vec<f32> = outs[0].to_vec().expect("attractive output");
            for r in 0..bsz {
                out[2 * (b0 + r)] = T::from_f64(forces[2 * r] as f64);
                out[2 * (b0 + r) + 1] = T::from_f64(forces[2 * r + 1] as f64);
            }
            b0 += bsz;
        }
    }
}

/// Morton codes through the AOT `morton` artifact (batch = [`MORTON_N`]).
pub struct XlaMorton {
    art: Artifact,
}

impl XlaMorton {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(XlaMorton {
            art: rt.compile("morton")?,
        })
    }

    /// 32-bit codes (as u32) for up to [`MORTON_N`] points per call.
    pub fn encode(&self, pos: &[f32], cent: [f32; 2], r_span: f32) -> Result<Vec<u32>> {
        let n = pos.len() / 2;
        let mut codes = Vec::with_capacity(n);
        let mut b0 = 0;
        while b0 < n {
            let bsz = MORTON_N.min(n - b0);
            let mut pts = vec![0.0f32; MORTON_N * 2];
            pts[..2 * bsz].copy_from_slice(&pos[2 * b0..2 * (b0 + bsz)]);
            let pts_lit = literal_f32(&pts, &[MORTON_N as i64, 2])?;
            let cent_lit = literal_f32(&cent, &[2])?;
            let span_lit = xla::Literal::scalar(r_span);
            let outs = self.art.run(&[&pts_lit, &cent_lit, &span_lit])?;
            let got: Vec<i32> = outs[0].to_vec()?;
            codes.extend(got[..bsz].iter().map(|&c| c as u32));
            b0 += bsz;
        }
        Ok(codes)
    }
}

/// Dense repulsion tiles through the AOT `repulsive_dense` artifact.
pub struct XlaRepulsiveDense {
    art: Artifact,
}

impl XlaRepulsiveDense {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(XlaRepulsiveDense {
            art: rt.compile("repulsive_dense")?,
        })
    }

    /// Exact repulsion of `y` (n ≤ [`REP_C`]): returns (raw forces, Z) with
    /// self terms removed — same contract as
    /// [`crate::gradient::exact::exact_repulsive`].
    pub fn exact(&self, y: &[f32]) -> Result<(Vec<f32>, f32)> {
        let n = y.len() / 2;
        anyhow::ensure!(n <= REP_C, "repulsive_dense artifact frozen at n ≤ {REP_C}");
        // Corpus: y padded to REP_C with a far-away sentinel so padding
        // contributes ~0 to both raw and z.
        let mut corpus = vec![1e30f32; REP_C * 2];
        corpus[..2 * n].copy_from_slice(y);
        let c_lit = literal_f32(&corpus, &[REP_C as i64, 2])?;
        let mut raw = vec![0.0f32; 2 * n];
        let mut z = 0.0f32;
        let mut b0 = 0;
        while b0 < n {
            let bsz = REP_B.min(n - b0);
            let mut tile = vec![1e30f32; REP_B * 2];
            tile[..2 * bsz].copy_from_slice(&y[2 * b0..2 * (b0 + bsz)]);
            let tile_lit = literal_f32(&tile, &[REP_B as i64, 2])?;
            let outs = self.art.run(&[&tile_lit, &c_lit])?;
            let r: Vec<f32> = outs[0].to_vec()?;
            let zt: Vec<f32> = outs[1].to_vec()?;
            for i in 0..bsz {
                raw[2 * (b0 + i)] = r[2 * i];
                raw[2 * (b0 + i) + 1] = r[2 * i + 1];
                z += zt[i] - 1.0; // remove the self term (q(i,i) = 1)
            }
            b0 += bsz;
        }
        Ok((raw, z))
    }
}
