//! One function per paper table/figure. Every function prints its table and
//! writes `results/<id>.csv`; benches and the CLI both call these.

use super::{print_table, save_csv, ExpConfig};
use crate::common::timer::Step;
use crate::data::datasets::PaperDataset;
use crate::data::Dataset;
use crate::parallel::ThreadPool;
use crate::tsne::{
    run_tsne, Affinities, Implementation, RepulsiveVariant, StagePlan, TsneConfig, TsneResult,
    TsneSession,
};
use crate::viz;

fn gen(ds: PaperDataset, cfg: &ExpConfig) -> Dataset<f64> {
    let pool = ThreadPool::new(cfg.resolved_threads());
    ds.generate::<f64>(cfg.scale, cfg.seed, &pool)
}

fn tsne_cfg(cfg: &ExpConfig, threads: usize) -> TsneConfig {
    TsneConfig {
        n_iter: cfg.n_iter,
        seed: cfg.seed,
        n_threads: threads,
        ..TsneConfig::default()
    }
}

fn run(ds: &Dataset<f64>, cfg: &ExpConfig, imp: Implementation, threads: usize) -> TsneResult<f64> {
    run_tsne(&ds.points, ds.n, ds.d, &tsne_cfg(cfg, threads), imp)
}

/// Figure 1b — step-time profile of the daal4py-like baseline on the
/// mouse-brain analog, all cores.
pub fn fig1b_profile(cfg: &ExpConfig) -> Vec<Vec<String>> {
    let ds = gen(PaperDataset::Mouse1_3M, cfg);
    let r = run(&ds, cfg, Implementation::Daal4pyLike, cfg.resolved_threads());
    let rows: Vec<Vec<String>> = r
        .step_times
        .percentages()
        .iter()
        .map(|(s, pct)| {
            vec![
                s.name().to_string(),
                format!("{:.2}", r.step_times.get(*s)),
                format!("{pct:.1}%"),
            ]
        })
        .collect();
    print_table(
        &format!("Fig 1b: daal4py-like profile ({}, n={})", ds.name, ds.n),
        &["step", "seconds", "share"],
        &rows,
    );
    save_csv(cfg, "fig1b_profile", &["step", "seconds", "share"], &rows);
    rows
}

/// Figure 4 — end-to-end comparison of all five implementations across the
/// six datasets, all cores; speedups reported over sklearn-like.
pub fn fig4_end_to_end(cfg: &ExpConfig, datasets: &[PaperDataset]) -> Vec<Vec<String>> {
    let threads = cfg.resolved_threads();
    let mut rows = Vec::new();
    for &d in datasets {
        let ds = gen(d, cfg);
        let mut base_time = None;
        for imp in Implementation::ALL {
            let r = run(&ds, cfg, imp, threads);
            let t = r.step_times.total();
            if imp == Implementation::SklearnLike {
                base_time = Some(t);
            }
            let speedup = base_time.map(|b| b / t).unwrap_or(1.0);
            rows.push(vec![
                d.name().to_string(),
                format!("{}", ds.n),
                imp.name().to_string(),
                format!("{t:.2}"),
                format!("{speedup:.1}x"),
                format!("{:.3}", r.kl_divergence),
            ]);
        }
    }
    let headers = ["dataset", "n", "impl", "seconds", "speedup-vs-sklearn", "kl"];
    print_table(
        &format!("Fig 4: end-to-end, {} threads, scale {}", threads, cfg.scale),
        &headers,
        &rows,
    );
    save_csv(cfg, "fig4_end_to_end", &headers, &rows);
    rows
}

/// Table 3 — KL divergence of sklearn-like / daal4py-like / Acc-t-SNE across
/// the datasets, plus an Acc-t-SNE run from a second seed.
///
/// All four gradient runs per dataset descend from **one** [`Affinities`]
/// fit (the session API's fit-once/descend-many contract). That sharing is
/// legitimate here: the three implementations under comparison use the same
/// blocked KNN engine, and BSP parallelism changes only wall time, not the
/// calibrated `P` — Table 3 is an accuracy claim, so only `P` matters.
pub fn table3_accuracy(cfg: &ExpConfig, datasets: &[PaperDataset]) -> Vec<Vec<String>> {
    let threads = cfg.resolved_threads();
    let pool = ThreadPool::new(threads);
    let mut rows = Vec::new();
    for &d in datasets {
        let ds = gen(d, cfg);
        let tc = tsne_cfg(cfg, threads);
        let aff =
            Affinities::fit(&pool, &ds.points, ds.n, ds.d, tc.perplexity, &StagePlan::acc_tsne())
                .expect("valid fit");
        let kl_of = |imp: Implementation, seed: u64| -> f64 {
            let mut c = tc;
            c.seed = seed;
            let mut sess = TsneSession::new(&aff, StagePlan::preset(imp), c)
                .expect("preset plans validate");
            sess.run(c.n_iter);
            sess.finish().kl_divergence
        };
        rows.push(vec![
            d.name().to_string(),
            format!("{:.3}", kl_of(Implementation::SklearnLike, tc.seed)),
            format!("{:.3}", kl_of(Implementation::Daal4pyLike, tc.seed)),
            format!("{:.3}", kl_of(Implementation::AccTsne, tc.seed)),
            format!("{:.3}", kl_of(Implementation::AccTsne, tc.seed ^ 0xA11CE)),
        ]);
    }
    let headers = ["dataset", "sklearn", "daal4py", "acc-t-sne(optimized)", "acc-t-sne(seed B)"];
    print_table("Table 3: KL divergence (one affinity fit per dataset)", &headers, &rows);
    save_csv(cfg, "table3_accuracy", &headers, &rows);
    rows
}

/// Table 4 — single-thread end-to-end on the mouse analog, all implementations.
pub fn table4_single_thread(cfg: &ExpConfig) -> Vec<Vec<String>> {
    let ds = gen(PaperDataset::Mouse1_3M, cfg);
    let mut rows = Vec::new();
    let mut base = None;
    for imp in Implementation::ALL {
        let r = run(&ds, cfg, imp, 1);
        let t = r.step_times.total();
        if imp == Implementation::SklearnLike {
            base = Some(t);
        }
        rows.push(vec![
            imp.name().to_string(),
            format!("{t:.2}"),
            format!("{:.1}x", base.map(|b| b / t).unwrap_or(1.0)),
        ]);
    }
    let headers = ["implementation", "seconds", "speedup"];
    print_table(
        &format!("Table 4: single-thread end-to-end ({}, n={})", ds.name, ds.n),
        &headers,
        &rows,
    );
    save_csv(cfg, "table4_single_thread", &headers, &rows);
    rows
}

/// Figure 5 — end-to-end multicore scaling of all implementations on the
/// mouse analog (speedup vs own single-thread time).
pub fn fig5_scaling(cfg: &ExpConfig) -> Vec<Vec<String>> {
    let ds = gen(PaperDataset::Mouse1_3M, cfg);
    let sweep = cfg.core_sweep();
    let mut rows = Vec::new();
    for imp in Implementation::ALL {
        let mut base = None;
        for &threads in &sweep {
            let r = run(&ds, cfg, imp, threads);
            let t = r.step_times.total();
            if threads == 1 {
                base = Some(t);
            }
            rows.push(vec![
                imp.name().to_string(),
                threads.to_string(),
                format!("{t:.2}"),
                format!("{:.1}x", base.map(|b| b / t).unwrap_or(1.0)),
            ]);
        }
    }
    let headers = ["impl", "cores", "seconds", "speedup-vs-1core"];
    print_table(
        &format!("Fig 5: end-to-end scaling ({}, n={})", ds.name, ds.n),
        &headers,
        &rows,
    );
    save_csv(cfg, "fig5_scaling", &headers, &rows);
    rows
}

/// Tables 5 & 6 — per-step comparison daal4py-like vs Acc-t-SNE at a given
/// thread count (1 ⇒ Table 5, all cores ⇒ Table 6).
pub fn table56_steps(cfg: &ExpConfig, threads: usize) -> Vec<Vec<String>> {
    let ds = gen(PaperDataset::Mouse1_3M, cfg);
    let r_daal = run(&ds, cfg, Implementation::Daal4pyLike, threads);
    let r_acc = run(&ds, cfg, Implementation::AccTsne, threads);
    let steps = [
        Step::Bsp,
        Step::TreeBuild,
        Step::Summarize,
        Step::Attractive,
        Step::Repulsive,
    ];
    let mut rows: Vec<Vec<String>> = steps
        .iter()
        .map(|&s| {
            let (a, b) = (r_daal.step_times.get(s), r_acc.step_times.get(s));
            vec![
                s.name().to_string(),
                format!("{a:.3}"),
                format!("{b:.3}"),
                format!("{:.1}x", a / b.max(1e-12)),
            ]
        })
        .collect();
    let (ta, tb) = (
        r_daal.step_times.gradient_total() + r_daal.step_times.get(Step::Bsp),
        r_acc.step_times.gradient_total() + r_acc.step_times.get(Step::Bsp),
    );
    rows.push(vec![
        "TOTAL(excl. KNN)".to_string(),
        format!("{ta:.3}"),
        format!("{tb:.3}"),
        format!("{:.1}x", ta / tb.max(1e-12)),
    ]);
    let headers = ["step", "daal4py (s)", "acc-t-sne (s)", "speedup"];
    let which = if threads == 1 { "Table 5 (1 thread)" } else { "Table 6 (all cores)" };
    print_table(
        &format!("{which}: per-step ({}, n={}, {threads} threads)", ds.name, ds.n),
        &headers,
        &rows,
    );
    save_csv(
        cfg,
        &format!("table56_steps_t{threads}"),
        &headers,
        &rows,
    );
    rows
}

/// Figure 6a/6b — per-step multicore scaling for daal4py-like and Acc-t-SNE.
pub fn fig6_step_scaling(cfg: &ExpConfig) -> Vec<Vec<String>> {
    let ds = gen(PaperDataset::Mouse1_3M, cfg);
    let sweep = cfg.core_sweep();
    let steps = [
        Step::Knn,
        Step::Bsp,
        Step::TreeBuild,
        Step::Summarize,
        Step::Attractive,
        Step::Repulsive,
    ];
    let mut rows = Vec::new();
    for imp in [Implementation::Daal4pyLike, Implementation::AccTsne] {
        let mut base: Option<Vec<f64>> = None;
        for &threads in &sweep {
            let r = run(&ds, cfg, imp, threads);
            let t: Vec<f64> = steps.iter().map(|&s| r.step_times.get(s)).collect();
            if threads == 1 {
                base = Some(t.clone());
            }
            let b = base.as_ref().unwrap();
            for (i, &s) in steps.iter().enumerate() {
                rows.push(vec![
                    imp.name().to_string(),
                    s.name().to_string(),
                    threads.to_string(),
                    format!("{:.3}", t[i]),
                    format!("{:.1}x", b[i] / t[i].max(1e-12)),
                ]);
            }
        }
    }
    let headers = ["impl", "step", "cores", "seconds", "speedup-vs-1core"];
    print_table(
        &format!("Fig 6: per-step scaling ({}, n={})", ds.name, ds.n),
        &headers,
        &rows,
    );
    save_csv(cfg, "fig6_step_scaling", &headers, &rows);
    rows
}

/// Table S1 — Acc-t-SNE in f32 vs f64 across datasets.
pub fn table_s1_precision(cfg: &ExpConfig, datasets: &[PaperDataset]) -> Vec<Vec<String>> {
    let threads = cfg.resolved_threads();
    let mut rows = Vec::new();
    for &d in datasets {
        let ds = gen(d, cfg);
        let ds32 = ds.cast::<f32>();
        let r64 = run(&ds, cfg, Implementation::AccTsne, threads);
        let tc = tsne_cfg(cfg, threads);
        let r32 = run_tsne(&ds32.points, ds32.n, ds32.d, &tc, Implementation::AccTsne);
        let (t64, t32) = (r64.step_times.total(), r32.step_times.total());
        rows.push(vec![
            d.name().to_string(),
            format!("{t32:.2}"),
            format!("{:.3}", r32.kl_divergence),
            format!("{t64:.2}"),
            format!("{:.3}", r64.kl_divergence),
            format!("{:.2}x", t64 / t32.max(1e-12)),
        ]);
    }
    let headers = ["dataset", "time f32 (s)", "kl f32", "time f64 (s)", "kl f64", "speedup"];
    print_table("Table S1: single vs double precision (Acc-t-SNE)", &headers, &rows);
    save_csv(cfg, "tableS1_precision", &headers, &rows);
    rows
}

/// Table S1 extension — f32 *end-to-end* sweep of the repulsive kernel:
/// Acc-t-SNE in single precision with the scalar DFS vs the SIMD-tiled
/// kernel (16 lanes in f32, where the tile batching pays the most). The
/// micro-benches isolate the kernel; this shows its whole-pipeline payoff
/// with the per-run KL confirming the accept-set parity.
pub fn table_s1_f32_repulsive_sweep(
    cfg: &ExpConfig,
    datasets: &[PaperDataset],
) -> Vec<Vec<String>> {
    let threads = cfg.resolved_threads();
    let mut rows = Vec::new();
    for &d in datasets {
        let ds32 = gen(d, cfg).cast::<f32>();
        let mut scalar_rep_time = None;
        for variant in [RepulsiveVariant::Scalar, RepulsiveVariant::SimdTiled] {
            let mut tc = tsne_cfg(cfg, threads);
            tc.repulsive = Some(variant);
            let r = run_tsne(&ds32.points, ds32.n, ds32.d, &tc, Implementation::AccTsne);
            let rep_s = r.step_times.get(Step::Repulsive);
            if variant == RepulsiveVariant::Scalar {
                scalar_rep_time = Some(rep_s);
            }
            rows.push(vec![
                d.name().to_string(),
                variant.name().to_string(),
                format!("{:.2}", r.step_times.total()),
                format!("{rep_s:.3}"),
                format!("{:.1}x", scalar_rep_time.map(|b| b / rep_s.max(1e-12)).unwrap_or(1.0)),
                format!("{:.3}", r.kl_divergence),
            ]);
        }
    }
    let headers = ["dataset", "repulsive", "total (s)", "repulsive (s)", "rep speedup", "kl"];
    print_table("Table S1 (ext): f32 end-to-end, repulsive kernel sweep", &headers, &rows);
    save_csv(cfg, "tableS1_f32_repulsive_sweep", &headers, &rows);
    rows
}

/// Figures S1–S6 — embedding scatter plots per dataset (PPM + SVG + CSV).
pub fn figs_s_plots(cfg: &ExpConfig, datasets: &[PaperDataset]) -> Vec<Vec<String>> {
    let threads = cfg.resolved_threads();
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let mut rows = Vec::new();
    for &d in datasets {
        let ds = gen(d, cfg);
        let r = run(&ds, cfg, Implementation::AccTsne, threads);
        let base = cfg.out_dir.join(format!("figS_{}", d.name()));
        viz::write_ppm(base.with_extension("ppm"), &r.embedding, &ds.labels, 512).ok();
        viz::write_svg(base.with_extension("svg"), &r.embedding, &ds.labels, 512).ok();
        crate::data::io::write_embedding_csv(base.with_extension("csv"), &r.embedding, &ds.labels)
            .ok();
        rows.push(vec![
            d.name().to_string(),
            format!("{}", ds.n),
            format!("{:.3}", r.kl_divergence),
            base.with_extension("svg").display().to_string(),
        ]);
    }
    let headers = ["dataset", "n", "kl", "plot"];
    print_table("Figs S1–S6: embeddings", &headers, &rows);
    save_csv(cfg, "figS_plots", &headers, &rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            scale: 0.002,
            n_iter: 12,
            max_threads: 4,
            out_dir: std::env::temp_dir().join(format!("acc_eval_{}", std::process::id())),
            ..ExpConfig::default()
        }
    }

    #[test]
    fn fig1b_produces_all_steps() {
        let rows = fig1b_profile(&tiny_cfg());
        assert_eq!(rows.len(), 7); // all Step::ALL entries
    }

    #[test]
    fn table56_has_total_row() {
        let rows = table56_steps(&tiny_cfg(), 2);
        assert_eq!(rows.last().unwrap()[0], "TOTAL(excl. KNN)");
    }

    #[test]
    fn s1_f32_sweep_has_both_variants_per_dataset() {
        let rows = table_s1_f32_repulsive_sweep(&tiny_cfg(), &[PaperDataset::Digits]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][1], "scalar");
        assert_eq!(rows[1][1], "simd-tiled");
    }

    #[test]
    fn table3_has_second_seed_column_per_dataset() {
        let rows = table3_accuracy(&tiny_cfg(), &[PaperDataset::Digits]);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), 5, "dataset + 3 impl KLs + second-seed KL");
        for cell in &rows[0][1..] {
            assert!(cell.parse::<f64>().unwrap().is_finite());
        }
    }

    #[test]
    fn fig4_rows_cover_impls() {
        let rows = fig4_end_to_end(&tiny_cfg(), &[PaperDataset::Digits]);
        assert_eq!(rows.len(), Implementation::ALL.len());
        // acc-t-sne should not be slower than sklearn-like even at tiny scale
        let acc_row = rows.iter().find(|r| r[2] == "acc-t-sne").unwrap();
        let speedup: f64 = acc_row[4].trim_end_matches('x').parse().unwrap();
        assert!(speedup > 0.5, "unexpected slowdown: {speedup}");
    }
}
