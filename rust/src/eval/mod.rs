//! Evaluation harness: regenerates every table and figure of the paper's
//! §4 (see DESIGN.md §Experiment-index for the mapping). Each experiment
//! prints a fixed-width table and writes a CSV under `results/`.

pub mod experiments;

use std::path::PathBuf;

/// Shared experiment configuration. Scaled-down defaults keep the full bench
/// suite in CI time; set `ACC_TSNE_SCALE` / `ACC_TSNE_ITERS` (or CLI flags)
/// for paper-sized runs.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Fraction of each dataset's paper-size N.
    pub scale: f64,
    /// Gradient iterations (paper: 1000).
    pub n_iter: usize,
    pub seed: u64,
    /// Max threads for "all cores" experiments (0 ⇒ available cores).
    pub max_threads: usize,
    pub out_dir: PathBuf,
}

impl Default for ExpConfig {
    fn default() -> Self {
        let scale = std::env::var("ACC_TSNE_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.01);
        let n_iter = std::env::var("ACC_TSNE_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(150);
        ExpConfig {
            scale,
            n_iter,
            seed: 42,
            max_threads: 0,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl ExpConfig {
    pub fn resolved_threads(&self) -> usize {
        if self.max_threads == 0 {
            crate::parallel::pool::available_cores()
        } else {
            self.max_threads
        }
    }

    /// Thread counts for scaling sweeps: powers of two up to max, plus max.
    pub fn core_sweep(&self) -> Vec<usize> {
        let max = self.resolved_threads();
        let mut v = vec![];
        let mut c = 1;
        while c < max {
            v.push(c);
            c *= 2;
        }
        v.push(max);
        v.dedup();
        v
    }
}

/// Print a fixed-width table; returns nothing, purely cosmetic.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    println!("{}", fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().map(|w| w + 2).sum()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Write the rows as CSV under the experiment output dir.
pub fn save_csv(cfg: &ExpConfig, name: &str, headers: &[&str], rows: &[Vec<String>]) {
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let path = cfg.out_dir.join(format!("{name}.csv"));
    if let Err(e) = crate::data::io::write_csv(&path, &headers.join(","), rows) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("[csv] {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_sweep_covers_one_to_max() {
        let cfg = ExpConfig {
            max_threads: 12,
            ..ExpConfig::default()
        };
        let sweep = cfg.core_sweep();
        assert_eq!(sweep.first(), Some(&1));
        assert_eq!(sweep.last(), Some(&12));
        assert!(sweep.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn single_core_sweep() {
        let cfg = ExpConfig {
            max_threads: 1,
            ..ExpConfig::default()
        };
        assert_eq!(cfg.core_sweep(), vec![1]);
    }
}
