//! acc-tsne — CLI launcher for the Acc-t-SNE reproduction.
//!
//! ```text
//! acc-tsne run       --dataset mnist --impl acc-t-sne [--scale F --iters N --threads N --out emb.csv --plot out.svg --f32]
//! acc-tsne compare   [--scale F --iters N]           # Fig 4 + Table 3
//! acc-tsne scaling   [--scale F --iters N]           # Fig 5
//! acc-tsne steps     [--threads N]                   # Tables 5/6 (+ Fig 6 with --sweep)
//! acc-tsne profile                                   # Fig 1b
//! acc-tsne precision                                 # Table S1
//! acc-tsne viz                                       # Figs S1–S6
//! acc-tsne info                                      # system + dataset registry
//! ```

use acc_tsne::cli::Args;
use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::eval::{experiments, ExpConfig};
use acc_tsne::parallel::pool::available_cores;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{run_tsne, Implementation, Layout, RepulsiveVariant, TsneConfig};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

const COMMON_FLAGS: &[&str] = &[
    "dataset", "impl", "scale", "iters", "threads", "seed", "out", "plot", "f32", "sweep",
    "perplexity", "theta", "repulsive", "layout",
];

fn exp_config(args: &Args) -> Result<ExpConfig, String> {
    let mut cfg = ExpConfig::default();
    cfg.scale = args.get_parse("scale", cfg.scale)?;
    cfg.n_iter = args.get_parse("iters", cfg.n_iter)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.max_threads = args.get_parse("threads", cfg.max_threads)?;
    Ok(cfg)
}

fn real_main(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv)?;
    args.ensure_known(COMMON_FLAGS)?;
    let sub = args.subcommand.as_deref().unwrap_or("help");
    match sub {
        "run" => cmd_run(&args),
        "compare" => {
            let cfg = exp_config(&args)?;
            experiments::fig4_end_to_end(&cfg, &PaperDataset::ALL);
            experiments::table3_accuracy(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "scaling" => {
            let cfg = exp_config(&args)?;
            experiments::fig5_scaling(&cfg);
            Ok(())
        }
        "steps" => {
            let cfg = exp_config(&args)?;
            experiments::table56_steps(&cfg, 1);
            experiments::table56_steps(&cfg, cfg.resolved_threads());
            if args.has("sweep") {
                experiments::fig6_step_scaling(&cfg);
            }
            Ok(())
        }
        "profile" => {
            let cfg = exp_config(&args)?;
            experiments::fig1b_profile(&cfg);
            Ok(())
        }
        "precision" => {
            let cfg = exp_config(&args)?;
            experiments::table_s1_precision(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "viz" => {
            let cfg = exp_config(&args)?;
            experiments::figs_s_plots(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "info" => cmd_info(),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let dataset = args.get("dataset").unwrap_or("digits");
    let ds_kind = PaperDataset::from_name(dataset)
        .ok_or_else(|| format!("unknown dataset '{dataset}' (see `acc-tsne info`)"))?;
    let imp = Implementation::from_name(args.get("impl").unwrap_or("acc-t-sne"))
        .ok_or_else(|| "unknown --impl (sklearn|multicore|daal4py|acc-t-sne|fit-sne)".to_string())?;
    let exp = exp_config(args)?;
    let repulsive = match args.get("repulsive") {
        None => None,
        Some(s) => Some(RepulsiveVariant::from_name(s).ok_or_else(|| {
            format!("unknown --repulsive '{s}' (scalar|simd-tiled)")
        })?),
    };
    if repulsive.is_some() && imp == Implementation::FitSne {
        return Err(
            "--repulsive has no effect with --impl fit-sne (FFT replaces the BH kernel)"
                .to_string(),
        );
    }
    let layout = match args.get("layout") {
        None => None,
        Some(s) => Some(Layout::from_name(s).ok_or_else(|| {
            format!("unknown --layout '{s}' (original|zorder)")
        })?),
    };
    if layout == Some(Layout::Zorder) && imp == Implementation::FitSne {
        return Err(
            "--layout zorder has no effect with --impl fit-sne (no quadtree, no Z-order)"
                .to_string(),
        );
    }
    let cfg = TsneConfig {
        n_iter: exp.n_iter,
        seed: exp.seed,
        n_threads: exp.max_threads,
        perplexity: args.get_parse("perplexity", 30.0)?,
        theta: args.get_parse("theta", 0.5)?,
        repulsive,
        layout,
        ..TsneConfig::default()
    };
    let pool = ThreadPool::new(exp.resolved_threads());
    println!(
        "dataset={dataset} scale={} impl={} threads={} iters={}",
        exp.scale,
        imp.name(),
        exp.resolved_threads(),
        cfg.n_iter
    );
    let ds = ds_kind.generate::<f64>(exp.scale, exp.seed, &pool);
    println!("n={} d={}", ds.n, ds.d);

    let (kl, times, embedding, labels) = if args.has("f32") {
        let ds32 = ds.cast::<f32>();
        let r = run_tsne(&ds32.points, ds32.n, ds32.d, &cfg, imp);
        (
            r.kl_divergence,
            r.step_times,
            r.embedding.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            ds32.labels,
        )
    } else {
        let r = run_tsne(&ds.points, ds.n, ds.d, &cfg, imp);
        (r.kl_divergence, r.step_times, r.embedding, ds.labels)
    };

    println!("KL divergence = {kl:.4}");
    println!("total time    = {:.2}s", times.total());
    for (step, pct) in times.percentages() {
        println!("  {:<11} {:>8.3}s  {:>5.1}%", step.name(), times.get(step), pct);
    }
    if let Some(out) = args.get("out") {
        acc_tsne::data::io::write_embedding_csv(out, &embedding, &labels)
            .map_err(|e| format!("writing {out}: {e}"))?;
        println!("[csv] {out}");
    }
    if let Some(plot) = args.get("plot") {
        if plot.ends_with(".svg") {
            acc_tsne::viz::write_svg(plot, &embedding, &labels, 768)
        } else {
            acc_tsne::viz::write_ppm(plot, &embedding, &labels, 768)
        }
        .map_err(|e| format!("writing {plot}: {e}"))?;
        println!("[plot] {plot}");
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("acc-tsne — Barnes-Hut t-SNE (Chaudhary et al. 2022) reproduction");
    println!("cores available : {}", available_cores());
    println!(
        "implementations : {}",
        Implementation::ALL.map(|i| i.name()).join(", ")
    );
    println!("datasets (synthetic analogs @ paper shape):");
    for d in PaperDataset::ALL {
        let (n, dim, k) = d.spec();
        println!("  {:<14} n={:<9} d={:<6} classes={k}", d.name(), n, dim);
    }
    println!("artifacts dir   : artifacts/ (run `make artifacts`)");
    Ok(())
}

const HELP: &str = "\
acc-tsne <subcommand> [flags]
  run        one t-SNE run  (--dataset --impl --scale --iters --threads --out --plot --f32
             --repulsive scalar|simd-tiled  --layout original|zorder)
  compare    Fig 4 + Table 3 across datasets and implementations
  scaling    Fig 5 end-to-end multicore scaling
  steps      Tables 5/6 per-step comparison (--sweep adds Fig 6)
  profile    Fig 1b baseline profile
  precision  Table S1 f32 vs f64
  viz        Figs S1-S6 embedding plots
  info       system + dataset registry
common flags: --scale F  --iters N  --threads N  --seed N";
