//! acc-tsne — CLI launcher for the Acc-t-SNE reproduction.
//!
//! ```text
//! acc-tsne run       --dataset mnist --impl acc-t-sne [--scale F --iters N --threads N --out emb.csv --plot out.svg --f32
//!                    --min-grad-norm F --n-iter-without-progress N --snapshot-every N --adopt-threshold PCT]
//! acc-tsne compare   [--scale F --iters N]           # Fig 4 + Table 3
//! acc-tsne scaling   [--scale F --iters N]           # Fig 5
//! acc-tsne steps     [--threads N]                   # Tables 5/6 (+ Fig 6 with --sweep)
//! acc-tsne profile                                   # Fig 1b
//! acc-tsne precision                                 # Table S1
//! acc-tsne viz                                       # Figs S1–S6
//! acc-tsne info                                      # system + dataset registry
//! acc-tsne serve     [--addr HOST:PORT --threads N --cache-capacity N]   # embedding daemon
//! acc-tsne serve     --smoke N [--threads N --iters N --seed N]          # CI serving smoke
//! ```
//!
//! `run` drives the session API: it fits `Affinities` once (or loads a
//! saved fit via `--affinities`), builds a validated `StagePlan` from
//! `--impl`/`--repulsive`/`--layout`/`--adopt-threshold` (impossible
//! combinations are typed plan errors), then either runs the full `--iters`
//! budget or, when `--min-grad-norm` / `--n-iter-without-progress` are
//! given, stops early on convergence. `--snapshot-every N` streams
//! un-permuted KL/grad-norm snapshots.
//!
//! Persistence: `--save-affinities FILE` writes the fitted artifact for
//! cross-process reuse; `--save-knn FILE` writes the KNN graph alone, and
//! `--knn FILE` re-fits from it at the requested `--perplexity` without
//! re-running KNN (bit-identical to a fresh fit at that perplexity, for any
//! perplexity whose ⌊3u⌋ fits the graph's k); `--checkpoint FILE` writes a
//! session checkpoint at the end of the run (every N iterations with
//! `--checkpoint-every N`); and `--resume FILE` continues a checkpointed
//! session — bit-identical to an uninterrupted run at a fixed thread count.
//!
//! `serve` starts the `tsne::serve` daemon (see `docs/serving.md` for the
//! wire protocol): fitted affinities cached by data fingerprint, concurrent
//! sessions multiplexed round-robin over one shared pool, progressive
//! embedding frames streamed as they evolve. `--smoke N` instead runs the
//! self-verifying in-process smoke (N concurrent clients + a
//! disconnect→resume leg, every final frame checked bit-identical against a
//! direct session) — the CI serving tier's entry point.
//!
//! Exit codes: `0` success, `2` usage/flag errors, `3` fit errors (hostile
//! data, unsatisfiable perplexity), `4` persistence errors (corrupt or
//! mismatched artifacts, unwritable outputs), `5` invalid stage plans, `6`
//! gradient-loop divergence, `7` serving errors (bind/protocol/smoke
//! verification). Every failure prints one `error: ...` line on stderr.

use acc_tsne::cli::Args;
use acc_tsne::common::timer::StepTimes;
use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::eval::{experiments, ExpConfig};
use acc_tsne::parallel::pool::available_cores;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::serve::{self, ServeConfig, ServeError};
use acc_tsne::tsne::{
    Affinities, AttractiveVariant, Convergence, FitError, Implementation, KnnEngineKind, KnnGraph,
    Layout, ObserverControl, PlanError, RepulsiveVariant, Scalar, SessionCheckpoint, StagePlan,
    StopReason, TsneConfig, TsneResult, TsneSession,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(e.code);
        }
    }
}

/// Flag parsing / validation / impossible flag combinations.
const EXIT_USAGE: i32 = 2;
/// [`FitError`]: hostile input data or an unsatisfiable fit request.
const EXIT_FIT: i32 = 3;
/// [`acc_tsne::tsne::PersistError`]: a corrupt, mismatched, or unwritable
/// artifact (affinities, KNN graph, checkpoint, or output file).
const EXIT_PERSIST: i32 = 4;
/// [`PlanError`]: an invalid stage plan.
const EXIT_PLAN: i32 = 5;
/// [`acc_tsne::tsne::StepError`]: the gradient loop diverged.
const EXIT_STEP: i32 = 6;
/// [`ServeError`]: the serving daemon failed (bind, protocol, or a smoke
/// verification mismatch).
const EXIT_SERVE: i32 = 7;

/// A CLI failure: the one-line stderr message plus the exit code of its
/// error family, so scripts and CI can tell "you typed the wrong flag"
/// ([`EXIT_USAGE`]) from "your artifact is corrupt" ([`EXIT_PERSIST`])
/// without parsing stderr.
#[derive(Debug)]
struct CliError {
    code: i32,
    message: String,
}

impl CliError {
    fn usage(message: impl Into<String>) -> CliError {
        CliError { code: EXIT_USAGE, message: message.into() }
    }

    fn fit(message: impl Into<String>) -> CliError {
        CliError { code: EXIT_FIT, message: message.into() }
    }

    fn persist(message: impl Into<String>) -> CliError {
        CliError { code: EXIT_PERSIST, message: message.into() }
    }

    fn step(message: impl Into<String>) -> CliError {
        CliError { code: EXIT_STEP, message: message.into() }
    }

    #[cfg(test)]
    fn serve(message: impl Into<String>) -> CliError {
        CliError { code: EXIT_SERVE, message: message.into() }
    }

    /// Substring check on the stderr message (the CLI tests assert on it).
    #[cfg(test)]
    fn contains(&self, needle: &str) -> bool {
        self.message.contains(needle)
    }
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

/// The flag-parsing layer (`cli::Args`) reports plain strings — all usage
/// errors by construction.
impl From<String> for CliError {
    fn from(message: String) -> CliError {
        CliError::usage(message)
    }
}

impl From<FitError> for CliError {
    fn from(e: FitError) -> CliError {
        CliError::fit(e.to_string())
    }
}

impl From<PlanError> for CliError {
    fn from(e: PlanError) -> CliError {
        CliError { code: EXIT_PLAN, message: e.to_string() }
    }
}

impl From<ServeError> for CliError {
    fn from(e: ServeError) -> CliError {
        CliError { code: EXIT_SERVE, message: e.to_string() }
    }
}

const COMMON_FLAGS: &[&str] = &[
    "dataset", "impl", "auto-engine", "scale", "iters", "threads", "seed", "out", "plot", "f32",
    "sweep", "perplexity", "theta", "repulsive", "layout", "attractive", "adopt-threshold",
    "min-grad-norm", "n-iter-without-progress", "snapshot-every", "save-affinities",
    "affinities", "checkpoint", "checkpoint-every", "resume", "save-knn", "knn", "knn-engine",
    "ef-search",
];

/// The `serve` subcommand's own flag set — it shares nothing with the
/// experiment subcommands, so a `run` flag under `serve` is a loud typo.
const SERVE_FLAGS: &[&str] = &["addr", "threads", "smoke", "iters", "seed", "cache-capacity"];

fn exp_config(args: &Args) -> Result<ExpConfig, CliError> {
    let mut cfg = ExpConfig::default();
    cfg.scale = args.get_parse("scale", cfg.scale)?;
    cfg.n_iter = args.get_parse("iters", cfg.n_iter)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.max_threads = args.get_parse("threads", cfg.max_threads)?;
    Ok(cfg)
}

fn real_main(argv: &[String]) -> Result<(), CliError> {
    let args = Args::parse(argv)?;
    let sub = args.subcommand.as_deref().unwrap_or("help");
    // `serve` has its own flag vocabulary; everything else shares the
    // experiment flag set.
    if sub == "serve" {
        args.ensure_known(SERVE_FLAGS)?;
        return cmd_serve(&args);
    }
    args.ensure_known(COMMON_FLAGS)?;
    match sub {
        "run" => cmd_run(&args),
        "compare" => {
            let cfg = exp_config(&args)?;
            experiments::fig4_end_to_end(&cfg, &PaperDataset::ALL);
            experiments::table3_accuracy(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "scaling" => {
            let cfg = exp_config(&args)?;
            experiments::fig5_scaling(&cfg);
            Ok(())
        }
        "steps" => {
            let cfg = exp_config(&args)?;
            experiments::table56_steps(&cfg, 1);
            experiments::table56_steps(&cfg, cfg.resolved_threads());
            if args.has("sweep") {
                experiments::fig6_step_scaling(&cfg);
            }
            Ok(())
        }
        "profile" => {
            let cfg = exp_config(&args)?;
            experiments::fig1b_profile(&cfg);
            Ok(())
        }
        "precision" => {
            let cfg = exp_config(&args)?;
            experiments::table_s1_precision(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "viz" => {
            let cfg = exp_config(&args)?;
            experiments::figs_s_plots(&cfg, &PaperDataset::ALL);
            Ok(())
        }
        "info" => cmd_info(),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

/// Persistence knobs of the `run` subcommand (all optional).
#[derive(Clone, Copy, Debug, Default)]
struct PersistOpts<'a> {
    /// Write the fitted affinities here after the fit.
    save_affinities: Option<&'a str>,
    /// Load affinities from here instead of fitting (skips KNN/BSP).
    load_affinities: Option<&'a str>,
    /// Write the KNN graph here after the KNN step (re-fit at any smaller
    /// perplexity later with `--knn`, skipping KNN entirely).
    save_knn: Option<&'a str>,
    /// Load a KNN graph from here instead of running KNN; BSP runs at
    /// `--perplexity` (requires ⌊3·perplexity⌋ ≤ the graph's k).
    load_knn: Option<&'a str>,
    /// Write session checkpoints here.
    checkpoint: Option<&'a str>,
    /// Checkpoint every N iterations (0 ⇒ once, at the end of the run;
    /// only meaningful with `checkpoint`).
    checkpoint_every: usize,
    /// Resume a checkpointed session from here.
    resume: Option<&'a str>,
    /// Engine family the user demanded with `--knn-engine`; a loaded graph
    /// must match it (an approximate graph must not silently serve a run
    /// that asked for exact rows, or vice versa).
    knn_engine: Option<KnnEngineKind>,
}

/// Fit (or load) affinities, run one session (fresh or resumed; full budget
/// or convergence-controlled; optionally checkpointing as it goes), and fold
/// the fit times into the result — the CLI's generic f32/f64 body.
fn run_session<T: Scalar>(
    pool: &ThreadPool,
    points: &[T],
    n: usize,
    d: usize,
    plan: StagePlan,
    cfg: &TsneConfig,
    conv: Option<Convergence>,
    snapshot_every: usize,
    persist: PersistOpts<'_>,
) -> Result<TsneResult<T>, CliError> {
    // The resume checkpoint is read FIRST: a corrupt or mismatched file must
    // fail before the (possibly minutes-long) affinity fit, not after it.
    let resume_ck = match persist.resume {
        Some(path) => Some(
            SessionCheckpoint::<T>::load(path)
                .map_err(|e| CliError::persist(format!("resuming from {path}: {e}")))?,
        ),
        None => None,
    };
    // KNN wall time of a graph built/loaded here (the `Affinities::fit`
    // fast path records it itself); folded into the result below.
    let mut knn_times = StepTimes::new();
    let aff = match persist.load_affinities {
        Some(path) => {
            let aff = Affinities::load(path)
                .map_err(|e| CliError::persist(format!("loading affinities {path}: {e}")))?;
            if aff.n() != n {
                return Err(CliError::persist(format!(
                    "affinities {path} hold {} points but the dataset has {n}",
                    aff.n()
                )));
            }
            if (aff.perplexity() - cfg.perplexity).abs() > 1e-12 {
                eprintln!(
                    "warning: {path} was fitted at perplexity {}; it overrides the requested {}",
                    aff.perplexity(),
                    cfg.perplexity
                );
            }
            println!("[affinities] loaded {path} (n={}, nnz={})", aff.n(), aff.p().nnz());
            aff
        }
        None if persist.load_knn.is_some() || persist.save_knn.is_some() => {
            // The split fit: KNN graph first (loaded or built), then a
            // BSP-only re-fit — bit-identical to a plain fit at the same
            // perplexity, and the graph can be persisted for later sweeps.
            let graph = match persist.load_knn {
                Some(path) => {
                    let g = KnnGraph::<T>::load(path)
                        .map_err(|e| CliError::persist(format!("loading KNN graph {path}: {e}")))?;
                    // Engine family first (cheap, metadata-only), then the
                    // O(n·d) fingerprint check.
                    if let Some(kind) = persist.knn_engine {
                        g.require_engine(kind)
                            .map_err(|e| CliError::fit(format!("KNN graph {path}: {e}")))?;
                    }
                    g.verify_source(points, n, d)
                        .map_err(|e| CliError::fit(format!("KNN graph {path}: {e}")))?;
                    println!(
                        "[knn] loaded {path} (n={}, k={}, engine={})",
                        g.n(),
                        g.k(),
                        g.engine()
                    );
                    g
                }
                None => KnnGraph::build_for_perplexity(pool, points, n, d, cfg.perplexity, &plan)?,
            };
            if let Some(path) = persist.save_knn {
                graph
                    .save(path)
                    .map_err(|e| CliError::persist(format!("saving KNN graph {path}: {e}")))?;
                println!(
                    "[knn] saved {path} (n={}, k={} — re-fit any perplexity <= {} with --knn)",
                    graph.n(),
                    graph.k(),
                    graph.k() / 3
                );
            }
            knn_times.merge(graph.step_times());
            Affinities::from_knn(pool, &graph, cfg.perplexity, &plan)?
        }
        None => Affinities::fit(pool, points, n, d, cfg.perplexity, &plan)?,
    };
    if let Some(path) = persist.save_affinities {
        aff.save(path)
            .map_err(|e| CliError::persist(format!("saving affinities {path}: {e}")))?;
        println!("[affinities] saved {path} (nnz={})", aff.p().nnz());
    }
    let mut sess = match resume_ck {
        Some(ck) => {
            let path = persist.resume.unwrap();
            let sess = TsneSession::from_checkpoint(&aff, plan, *cfg, ck)
                .map_err(|e| CliError::persist(format!("resuming from {path}: {e}")))?;
            println!("[resume] {path} @ iteration {}", sess.iterations());
            sess
        }
        None => TsneSession::new(&aff, plan, *cfg)?,
    };
    if snapshot_every > 0 {
        sess.set_observer(snapshot_every, |snap| {
            println!(
                "  [snapshot] iter {:>5}  KL = {:.4}  |grad| = {:.3e}",
                snap.iter, snap.kl, snap.grad_norm
            );
            ObserverControl::Continue
        });
    }
    let budget = conv.map(|c| c.max_iter).unwrap_or(cfg.n_iter);
    let outcome = loop {
        // One chunk per checkpoint interval (or the whole budget at once).
        // Note for combined --checkpoint-every + --n-iter-without-progress:
        // run_until's progress window is per call by contract, so it restarts
        // at each checkpoint boundary.
        let target = match (persist.checkpoint, persist.checkpoint_every) {
            (Some(_), every) if every > 0 => (sess.iterations() + every).min(budget),
            _ => budget,
        };
        let out = match conv {
            Some(c) => sess.run_until(Convergence { max_iter: target, ..c }),
            None => {
                let remaining = target.saturating_sub(sess.iterations());
                sess.run(remaining)
            }
        };
        if let Some(path) = persist.checkpoint {
            // On divergence this persists the REWOUND (last-good) state, so
            // the artifact on disk is always resumable.
            sess.checkpoint(path)
                .map_err(|e| CliError::persist(format!("checkpointing to {path}: {e}")))?;
            println!("[checkpoint] {path} @ iteration {}", sess.iterations());
        }
        if out.reason != StopReason::MaxIter || sess.iterations() >= budget {
            break out;
        }
    };
    if outcome.reason == StopReason::Diverged {
        let rewound = match sess.last_good_iteration() {
            Some(it) => format!("session rewound to last-good iteration {it}"),
            None => "no last-good state to rewind to".to_string(),
        };
        return Err(CliError::step(format!(
            "gradient loop diverged (non-finite Z or gradient norm); {rewound} — lower the \
             learning rate or change --seed and retry"
        )));
    }
    if outcome.reason != StopReason::MaxIter {
        println!("converged: stopped after {} iterations ({:?})", outcome.n_iter, outcome.reason);
    }
    let mut r = sess.finish();
    r.step_times.merge(aff.step_times());
    r.step_times.merge(&knn_times);
    Ok(r)
}

fn cmd_run(args: &Args) -> Result<(), CliError> {
    let dataset = args.get("dataset").unwrap_or("digits");
    let ds_kind = PaperDataset::from_name(dataset).ok_or_else(|| {
        CliError::usage(format!("unknown dataset '{dataset}' (see `acc-tsne info`)"))
    })?;
    let imp: Implementation = args.get_parse("impl", Implementation::AccTsne)?;
    let exp = exp_config(args)?;

    // --auto-engine picks BH vs FFT repulsion from the dataset size (the
    // measured crossover, StagePlan::auto_for), so the plan can only be
    // resolved after the dataset exists — the flag therefore excludes the
    // overrides that name an engine explicitly.
    let auto_engine = args.has("auto-engine");
    if auto_engine && args.get("impl").is_some() {
        return Err(CliError::usage(
            "--auto-engine picks the repulsive engine from the dataset size; \
             it cannot combine with --impl",
        ));
    }
    if auto_engine && args.get("repulsive").is_some() {
        return Err(CliError::usage(
            "--auto-engine may pick the FFT engine, which takes no --repulsive override",
        ));
    }

    // KNN engine family is parsed once, up front: the same value drives the
    // plan override below AND the loaded-graph engine check in run_session.
    let knn_engine_req: Option<KnnEngineKind> = match args.get("knn-engine") {
        Some(s) => Some(s.parse().map_err(|e| CliError::usage(format!("--knn-engine: {e}")))?),
        None => None,
    };
    if args.get("ef-search").is_some() && knn_engine_req != Some(KnnEngineKind::Hnsw) {
        return Err(CliError::usage(
            "--ef-search tunes the HNSW query beam; it requires --knn-engine hnsw",
        ));
    }

    // Stage plan: preset for --impl, then the checked overrides — impossible
    // combinations come back as typed plan errors, before any data is built.
    // (With --auto-engine this pass only validates the overrides; the real
    // plan is re-derived from n once the dataset exists.)
    let apply_overrides = |mut plan: StagePlan| -> Result<StagePlan, CliError> {
        if let Some(s) = args.get("repulsive") {
            let v: RepulsiveVariant =
                s.parse().map_err(|e| CliError::usage(format!("--repulsive: {e}")))?;
            plan = plan.with_repulsive(v)?;
        }
        if let Some(s) = args.get("layout") {
            let l: Layout = s.parse().map_err(|e| CliError::usage(format!("--layout: {e}")))?;
            plan = plan.with_layout(l)?;
        }
        if let Some(s) = args.get("attractive") {
            let v: AttractiveVariant =
                s.parse().map_err(|e| CliError::usage(format!("--attractive: {e}")))?;
            plan = plan.with_attractive(v)?;
        }
        if let Some(s) = args.get("adopt-threshold") {
            let pct: usize = s.parse().map_err(|e| {
                CliError::usage(format!("--adopt-threshold: cannot parse '{s}': {e}"))
            })?;
            plan = plan.with_adopt_drift_pct(pct)?;
        }
        if let Some(kind) = knn_engine_req {
            plan = plan.with_knn_engine(kind)?;
        }
        if let Some(s) = args.get("ef-search") {
            let ef: usize = s
                .parse()
                .map_err(|e| CliError::usage(format!("--ef-search: cannot parse '{s}': {e}")))?;
            plan = plan.with_ef_search(ef)?;
        }
        Ok(plan)
    };
    let mut plan = apply_overrides(StagePlan::preset(imp))?;

    let cfg = TsneConfig {
        n_iter: exp.n_iter,
        seed: exp.seed,
        n_threads: exp.max_threads,
        perplexity: args.get_parse("perplexity", 30.0)?,
        theta: args.get_parse("theta", 0.5)?,
        ..TsneConfig::default()
    };

    // Convergence control: either flag switches run() → run_until().
    let min_grad_norm = args.get_parse("min-grad-norm", 0.0f64)?;
    if min_grad_norm < 0.0 {
        return Err(CliError::usage(format!("--min-grad-norm must be >= 0, got {min_grad_norm}")));
    }
    let n_no_progress = args.get_parse("n-iter-without-progress", 0usize)?;
    let conv = if min_grad_norm > 0.0 || n_no_progress > 0 {
        // Convergence is only evaluated after early exaggeration, and the
        // no-progress window additionally needs that many checked iterations
        // — warn when the budget makes the flags dead instead of silently
        // running it out.
        let checks_start = cfg.update.exaggeration_iters;
        let grad_norm_dead = cfg.n_iter <= checks_start;
        let window_dead = n_no_progress > 0 && cfg.n_iter <= checks_start + n_no_progress;
        if grad_norm_dead || window_dead {
            eprintln!(
                "warning: convergence checks start after the early-exaggeration phase \
                 ({checks_start} iters){} — --iters {} leaves them no room to fire",
                if window_dead && !grad_norm_dead {
                    " and the no-progress window needs that many checked iterations"
                } else {
                    ""
                },
                cfg.n_iter
            );
        }
        Some(Convergence {
            max_iter: cfg.n_iter,
            min_grad_norm,
            n_iter_without_progress: n_no_progress,
        })
    } else {
        None
    };
    let snapshot_every = args.get_parse("snapshot-every", 0usize)?;

    // Persistence flags — validated before any data is built so mistakes
    // fail in milliseconds, not after the fit.
    let persist = PersistOpts {
        save_affinities: args.get("save-affinities"),
        load_affinities: args.get("affinities"),
        save_knn: args.get("save-knn"),
        load_knn: args.get("knn"),
        checkpoint: args.get("checkpoint"),
        checkpoint_every: args.get_parse("checkpoint-every", 0usize)?,
        resume: args.get("resume"),
        knn_engine: knn_engine_req,
    };
    if persist.checkpoint_every > 0 && persist.checkpoint.is_none() {
        return Err(CliError::usage(
            "--checkpoint-every requires --checkpoint FILE (where to write)",
        ));
    }
    if persist.load_affinities.is_some()
        && (persist.load_knn.is_some() || persist.save_knn.is_some())
    {
        return Err(CliError::usage(
            "--affinities skips KNN and BSP entirely; it cannot combine with --knn/--save-knn",
        ));
    }
    // run_until's no-progress window is per call by contract, and the
    // checkpoint loop calls it once per chunk — a window at least as long as
    // the chunk restarts before it can ever fire.
    if persist.checkpoint_every > 0 && n_no_progress >= persist.checkpoint_every {
        eprintln!(
            "warning: --n-iter-without-progress {n_no_progress} cannot fire inside a \
             --checkpoint-every {} chunk (the progress window restarts at each checkpoint); \
             raise --checkpoint-every above it for the rule to matter",
            persist.checkpoint_every
        );
    }
    for (flag, path) in [
        ("affinities", persist.load_affinities),
        ("knn", persist.load_knn),
        ("resume", persist.resume),
    ] {
        if let Some(path) = path {
            if !std::path::Path::new(path).is_file() {
                return Err(CliError::usage(format!("--{flag}: no such file '{path}'")));
            }
        }
    }
    // Output paths: a typo'd directory must fail now, not after the fit.
    for (flag, path) in [
        ("save-affinities", persist.save_affinities),
        ("save-knn", persist.save_knn),
        ("checkpoint", persist.checkpoint),
    ] {
        if let Some(path) = path {
            let parent = std::path::Path::new(path).parent().unwrap_or(std::path::Path::new(""));
            if !parent.as_os_str().is_empty() && !parent.is_dir() {
                return Err(CliError::usage(format!(
                    "--{flag}: directory of '{path}' does not exist"
                )));
            }
        }
    }

    let pool = ThreadPool::new(exp.resolved_threads());
    println!(
        "dataset={dataset} scale={} impl={} threads={} iters={}",
        exp.scale,
        if auto_engine { "auto".to_string() } else { imp.to_string() },
        exp.resolved_threads(),
        cfg.n_iter
    );
    let ds = ds_kind.try_generate::<f64>(exp.scale, exp.seed, &pool).map_err(FitError::from)?;
    println!("n={} d={}", ds.n, ds.d);
    if auto_engine {
        plan = apply_overrides(StagePlan::auto_for(ds.n))?;
        println!(
            "[auto] n={} → {} repulsion (crossover at n={})",
            ds.n,
            if plan.fft_repulsion { "FFT" } else { "Barnes-Hut" },
            acc_tsne::tsne::FFT_CROSSOVER_N
        );
    }

    // The gen pool is reused for the affinity fit; the session owns its own
    // pools (same thread count) for the gradient phase.
    let (kl, n_iter, times, embedding, labels) = if args.has("f32") {
        let ds32 = ds.cast::<f32>();
        let r = run_session(
            &pool, &ds32.points, ds32.n, ds32.d, plan, &cfg, conv, snapshot_every, persist,
        )?;
        (
            r.kl_divergence,
            r.n_iter,
            r.step_times,
            r.embedding.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            ds32.labels,
        )
    } else {
        let r = run_session(
            &pool, &ds.points, ds.n, ds.d, plan, &cfg, conv, snapshot_every, persist,
        )?;
        (r.kl_divergence, r.n_iter, r.step_times, r.embedding, ds.labels)
    };

    println!("KL divergence = {kl:.4}  ({n_iter} iterations)");
    println!("total time    = {:.2}s", times.total());
    for (step, pct) in times.percentages() {
        println!("  {:<11} {:>8.3}s  {:>5.1}%", step.name(), times.get(step), pct);
    }
    if let Some(out) = args.get("out") {
        acc_tsne::data::io::write_embedding_csv(out, &embedding, &labels)
            .map_err(|e| CliError::persist(format!("writing {out}: {e}")))?;
        println!("[csv] {out}");
    }
    if let Some(plot) = args.get("plot") {
        if plot.ends_with(".svg") {
            acc_tsne::viz::write_svg(plot, &embedding, &labels, 768)
        } else {
            acc_tsne::viz::write_ppm(plot, &embedding, &labels, 768)
        }
        .map_err(|e| CliError::persist(format!("writing {plot}: {e}")))?;
        println!("[plot] {plot}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), CliError> {
    let threads: usize = args.get_parse("threads", 0usize)?;
    let cache_capacity: usize = args.get_parse("cache-capacity", 8usize)?;
    if cache_capacity == 0 {
        return Err(CliError::usage("--cache-capacity must be >= 1"));
    }
    if args.has("smoke") {
        return Err(CliError::usage("--smoke needs a client count (e.g. --smoke 8)"));
    }
    let smoke: usize = args.get_parse("smoke", 0usize)?;
    if smoke > 0 {
        let iters: usize = args.get_parse("iters", 40usize)?;
        let seed: u64 = args.get_parse("seed", 42u64)?;
        let report = serve::run_smoke(smoke, threads, iters, seed)?;
        println!(
            "[serve-smoke] {} concurrent clients on {} shared threads x {} iters: every \
             final frame bit-identical to a direct session (incl. a disconnect->resume leg)",
            report.clients, report.n_threads, report.n_iter
        );
        let s = &report.stats;
        println!(
            "[serve-smoke] steps={} p50={:.3e}s p99={:.3e}s completed={} detached={} \
             resumed={} cache hits/misses={}/{}",
            s.steps,
            s.step_p50_s,
            s.step_p99_s,
            s.sessions_completed,
            s.sessions_detached,
            s.sessions_resumed,
            s.cache_hits,
            s.cache_misses
        );
        return Ok(());
    }
    let cfg = ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7878").to_string(),
        n_threads: threads,
        cache_capacity,
        ..ServeConfig::default()
    };
    let server = serve::start(&cfg)?;
    println!(
        "[serve] listening on {} ({} threads shared across all sessions)",
        server.addr(),
        if threads == 0 { available_cores() } else { threads }
    );
    // The daemon runs until the process is killed; the accept and scheduler
    // threads do all the work.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_info() -> Result<(), CliError> {
    println!("acc-tsne — Barnes-Hut t-SNE (Chaudhary et al. 2022) reproduction");
    println!("cores available : {}", available_cores());
    println!(
        "implementations : {}",
        Implementation::ALL.map(|i| i.name()).join(", ")
    );
    println!("datasets (synthetic analogs @ paper shape):");
    for d in PaperDataset::ALL {
        let (n, dim, k) = d.spec();
        println!("  {:<14} n={:<9} d={:<6} classes={k}", d.name(), n, dim);
    }
    println!("artifacts dir   : artifacts/ (run `make artifacts`)");
    Ok(())
}

const HELP: &str = "\
acc-tsne <subcommand> [flags]
  run        one t-SNE run  (--dataset --impl --scale --iters --threads --out --plot --f32
             --auto-engine                                    # pick BH vs FFT repulsion from n
             --repulsive scalar|simd-tiled  --layout original|zorder  --adopt-threshold PCT
             --attractive scalar|prefetch|simd                # attractive-kernel variant
             --min-grad-norm F  --n-iter-without-progress N   # convergence-based early stop
             --snapshot-every N                               # stream KL/grad-norm snapshots
             --save-affinities FILE  --affinities FILE        # persist / reuse the fitted P
             --save-knn FILE  --knn FILE                      # persist / reuse the KNN graph
                                                              #  (re-fit perplexity, skip KNN)
             --knn-engine exact|hnsw                          # exact rows or approximate HNSW
             --ef-search N                                    # HNSW query beam (recall knob)
             --checkpoint FILE  --checkpoint-every N          # periodic session checkpoints
             --resume FILE                                    # continue a checkpointed run)
  compare    Fig 4 + Table 3 across datasets and implementations
  scaling    Fig 5 end-to-end multicore scaling
  steps      Tables 5/6 per-step comparison (--sweep adds Fig 6)
  profile    Fig 1b baseline profile
  precision  Table S1 f32 vs f64
  viz        Figs S1-S6 embedding plots
  info       system + dataset registry
  serve      embedding-as-a-service daemon (--addr HOST:PORT --threads N --cache-capacity N;
             --smoke N runs the self-verifying CI smoke instead — see docs/serving.md)
common flags: --scale F  --iters N  --threads N  --seed N";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    // All of these hit the plan/flag validation layer, which runs before any
    // dataset is generated — the tests never pay for an actual t-SNE run.

    #[test]
    fn auto_engine_excludes_explicit_engine_flags() {
        let e = real_main(&argv("run --auto-engine --impl fit-sne")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("--impl"), "{e}");
        let e = real_main(&argv("run --auto-engine --repulsive simd-tiled")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("--repulsive"), "{e}");
    }

    #[test]
    fn fit_sne_plus_bh_repulsive_override_is_a_typed_plan_error() {
        for v in ["simd-tiled", "scalar"] {
            let e = real_main(&argv(&format!("run --impl fit-sne --repulsive {v}"))).unwrap_err();
            assert!(e.contains("invalid stage plan"), "{e}");
            assert!(e.contains("Barnes-Hut"), "{e}");
        }
    }

    #[test]
    fn unknown_enum_values_list_the_choices() {
        let e = real_main(&argv("run --impl bogus")).unwrap_err();
        assert!(e.contains("acc-t-sne"), "{e}");
        let e = real_main(&argv("run --layout bogus")).unwrap_err();
        assert!(e.contains("zorder"), "{e}");
        let e = real_main(&argv("run --repulsive bogus")).unwrap_err();
        assert!(e.contains("simd-tiled"), "{e}");
        let e = real_main(&argv("run --attractive bogus")).unwrap_err();
        assert!(e.contains("--attractive"), "{e}");
        assert!(e.contains("prefetch"), "{e}");
    }

    #[test]
    fn adopt_threshold_is_range_checked() {
        let e = real_main(&argv("run --adopt-threshold 150")).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        let e = real_main(&argv("run --adopt-threshold banana")).unwrap_err();
        assert!(e.contains("adopt-threshold"), "{e}");
    }

    #[test]
    fn negative_min_grad_norm_is_rejected() {
        let e = real_main(&argv("run --min-grad-norm -0.5")).unwrap_err();
        assert!(e.contains("min-grad-norm"), "{e}");
    }

    #[test]
    fn unknown_flags_still_fail_loudly() {
        let e = real_main(&argv("run --min-grad-nrm 0.1")).unwrap_err();
        assert!(e.contains("unknown flag"), "{e}");
    }

    #[test]
    fn checkpoint_every_without_a_checkpoint_path_is_an_error() {
        let e = real_main(&argv("run --checkpoint-every 50")).unwrap_err();
        assert!(e.contains("--checkpoint"), "{e}");
        let e = real_main(&argv("run --checkpoint-every banana")).unwrap_err();
        assert!(e.contains("checkpoint-every"), "{e}");
    }

    #[test]
    fn output_paths_require_existing_directories() {
        let e = real_main(&argv("run --checkpoint /no/such/dir/run.ckpt")).unwrap_err();
        assert!(e.contains("does not exist"), "{e}");
        assert!(e.contains("checkpoint"), "{e}");
        let e = real_main(&argv("run --save-affinities /no/such/dir/p.aff")).unwrap_err();
        assert!(e.contains("save-affinities"), "{e}");
    }

    #[test]
    fn resume_and_affinities_require_existing_files() {
        let e = real_main(&argv("run --resume /no/such/checkpoint.bin")).unwrap_err();
        assert!(e.contains("no such file"), "{e}");
        assert!(e.contains("resume"), "{e}");
        let e = real_main(&argv("run --affinities /no/such/affinities.bin")).unwrap_err();
        assert!(e.contains("no such file"), "{e}");
        assert!(e.contains("affinities"), "{e}");
        let e = real_main(&argv("run --knn /no/such/graph.bin")).unwrap_err();
        assert!(e.contains("no such file"), "{e}");
        assert!(e.contains("knn"), "{e}");
    }

    #[test]
    fn save_knn_requires_an_existing_directory() {
        let e = real_main(&argv("run --save-knn /no/such/dir/graph.knn")).unwrap_err();
        assert!(e.contains("does not exist"), "{e}");
        assert!(e.contains("save-knn"), "{e}");
    }

    #[test]
    fn affinities_and_knn_flags_are_mutually_exclusive() {
        // Checked before any file IO or data generation, so nonexistent
        // paths are fine here.
        for extra in ["--knn g.knn", "--save-knn g.knn"] {
            let e = real_main(&argv(&format!("run --affinities p.aff {extra}"))).unwrap_err();
            assert!(e.contains("--affinities"), "{e}");
            assert!(e.contains("cannot combine"), "{e}");
        }
    }

    #[test]
    fn knn_engine_and_ef_search_flags_are_validated_before_any_data() {
        // Unknown engine names list the choices, at the usage exit code.
        let e = real_main(&argv("run --knn-engine annoy")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("hnsw"), "{e}");
        // The beam knob is meaningless without the approximate engine —
        // both "alone" and "with exact" are usage errors that name the fix.
        for cmd in ["run --ef-search 32", "run --ef-search 32 --knn-engine exact"] {
            let e = real_main(&argv(cmd)).unwrap_err();
            assert_eq!(e.code, EXIT_USAGE, "{e}");
            assert!(e.contains("--knn-engine hnsw"), "{e}");
        }
        // A zero beam is range-checked by the plan layer (typed plan error).
        let e = real_main(&argv("run --knn-engine hnsw --ef-search 0")).unwrap_err();
        assert_eq!(e.code, EXIT_PLAN, "{e}");
        assert!(e.contains("ef-search"), "{e}");
        let e = real_main(&argv("run --knn-engine hnsw --ef-search banana")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("ef-search"), "{e}");
    }

    #[test]
    fn loading_a_graph_from_the_wrong_engine_family_is_a_typed_fit_error() {
        // Build a tiny approximate graph, persist it, then demand exact rows
        // from it — the engine check fires before the fingerprint check, so
        // only dataset generation plus a 60-point HNSW build is paid.
        use acc_tsne::data::synthetic::gaussian_mixture;
        use acc_tsne::knn::hnsw::HnswParams;
        let ds = gaussian_mixture::<f64>(60, 5, 3, 4.0, 11);
        let pool = ThreadPool::new(2);
        let g = KnnGraph::<f64>::build_approximate(
            &pool,
            &ds.points,
            ds.n,
            ds.d,
            6,
            &HnswParams::default(),
        )
        .unwrap();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acc_tsne_cli_hnsw_graph_{}.bin", std::process::id()));
        g.save(path.to_str().unwrap()).unwrap();
        let e = real_main(&argv(&format!(
            "run --dataset digits --iters 1 --threads 2 --knn {} --knn-engine exact",
            path.display()
        )))
        .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert_eq!(e.code, EXIT_FIT, "{e}");
        assert!(e.contains("engine mismatch"), "{e}");
        assert!(e.contains("hnsw"), "{e}");
    }

    #[test]
    fn loading_a_non_knn_file_is_a_typed_persist_error() {
        // Same shape as the bad-checkpoint test: garbage bytes come back as
        // the persist layer's typed bad-magic message, not a panic. Only
        // dataset generation is paid — the graph loads before any KNN run.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acc_tsne_cli_bad_knn_{}.bin", std::process::id()));
        std::fs::write(&path, b"definitely not a knn graph").unwrap();
        let e = real_main(&argv(&format!(
            "run --dataset digits --iters 1 --threads 2 --knn {}",
            path.display()
        )))
        .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(e.contains("loading KNN graph"), "{e}");
        assert!(e.contains("magic"), "{e}");
    }

    #[test]
    fn resuming_from_a_non_checkpoint_file_is_a_typed_persist_error() {
        // An existing file with garbage content must fail with the persist
        // layer's typed message (bad magic), not a panic — and it fails
        // BEFORE the affinity fit (the checkpoint is read first), so this
        // test only pays for dataset generation.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acc_tsne_cli_bad_ckpt_{}.bin", std::process::id()));
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let e = real_main(&argv(&format!(
            "run --dataset digits --iters 1 --threads 2 --resume {}",
            path.display()
        )))
        .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(e.contains("resuming from"), "{e}");
        assert!(e.contains("magic"), "{e}");
    }

    // ── exit-code discipline ─────────────────────────────────────────────
    // Each error family carries its own process exit code, so scripts and CI
    // branch on $? instead of parsing stderr: 2 usage, 3 fit, 4 persist,
    // 5 plan, 6 divergence.

    #[test]
    fn serve_flags_are_validated_before_any_socket_is_bound() {
        // Experiment flags are typos under `serve` — its vocabulary is its own.
        let e = real_main(&argv("serve --dataset digits")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("unknown flag"), "{e}");
        let e = real_main(&argv("serve --smoke banana")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("smoke"), "{e}");
        // A bare --smoke parses as a switch; it must name the missing count.
        let e = real_main(&argv("serve --smoke")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("client count"), "{e}");
        let e = real_main(&argv("serve --cache-capacity 0")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        assert!(e.contains("cache-capacity"), "{e}");
    }

    #[test]
    fn serve_smoke_two_clients_verifies_bit_identity_end_to_end() {
        // The real serving path on a loopback socket: 2 concurrent clients +
        // the disconnect->resume leg, each final frame checked bitwise
        // against a direct in-process session. Small n/iters keep it fast.
        let report = serve::run_smoke(2, 2, 30, 9).expect("serve smoke");
        assert_eq!(report.clients, 2);
        assert!(report.stats.steps as usize >= 2 * report.n_iter);
        assert!(report.stats.sessions_completed >= 3, "2 clients + 1 resumed");
        assert_eq!(report.stats.sessions_detached, 1);
        assert_eq!(report.stats.sessions_resumed, 1);
        // Same dataset across all fresh sessions: exactly one fit.
        assert_eq!(report.stats.cache_misses, 1);
        assert!(report.stats.cache_hits >= 1);
    }

    #[test]
    fn usage_and_plan_errors_carry_their_exit_codes() {
        let e = real_main(&argv("run --min-grad-nrm 0.1")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        let e = real_main(&argv("run --dataset bogus")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        let e = real_main(&argv("run --checkpoint-every 50")).unwrap_err();
        assert_eq!(e.code, EXIT_USAGE, "{e}");
        let e = real_main(&argv("run --impl fit-sne --repulsive simd-tiled")).unwrap_err();
        assert_eq!(e.code, EXIT_PLAN, "{e}");
    }

    #[test]
    fn fit_errors_carry_the_fit_exit_code() {
        // A perplexity no tiny dataset can satisfy is rejected by the typed
        // fit layer — only dataset generation is paid, never a KNN run.
        let e = real_main(&argv(
            "run --dataset digits --scale 0.02 --threads 2 --iters 1 --perplexity 1000000",
        ))
        .unwrap_err();
        assert_eq!(e.code, EXIT_FIT, "{e}");
        assert!(e.contains("perplexity"), "{e}");
    }

    #[test]
    fn persist_errors_carry_the_persist_exit_code() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acc_tsne_cli_exit_code_{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let e = real_main(&argv(&format!(
            "run --dataset digits --scale 0.05 --iters 1 --threads 2 --resume {}",
            path.display()
        )))
        .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert_eq!(e.code, EXIT_PERSIST, "{e}");
    }

    #[test]
    fn typed_error_conversions_pick_the_right_family() {
        assert_eq!(CliError::from(String::from("bad flag")).code, EXIT_USAGE);
        assert_eq!(CliError::usage("x").code, EXIT_USAGE);
        assert_eq!(CliError::persist("x").code, EXIT_PERSIST);
        assert_eq!(CliError::step("x").code, EXIT_STEP);
        let e = CliError::from(FitError::NonFinite { row: 3, col: 1 });
        assert_eq!(e.code, EXIT_FIT);
        assert!(e.contains("non-finite"), "{e}");
        assert_eq!(CliError::serve("x").code, EXIT_SERVE);
        let e = CliError::from(ServeError::Protocol("bad magic".into()));
        assert_eq!(e.code, EXIT_SERVE);
        assert!(e.contains("bad magic"), "{e}");
        let codes = [EXIT_USAGE, EXIT_FIT, EXIT_PERSIST, EXIT_PLAN, EXIT_STEP, EXIT_SERVE];
        for (i, a) in codes.iter().enumerate() {
            assert!(*a != 0 && *a != 1, "family codes must not collide with the generic 0/1");
            for b in &codes[i + 1..] {
                assert_ne!(a, b, "exit codes must be distinct");
            }
        }
    }
}
