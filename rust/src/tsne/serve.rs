//! Embedding-as-a-service: a std-only TCP daemon multiplexing many
//! [`TsneSession`]s over one shared [`ThreadPool`].
//!
//! The crate already has every serving primitive: [`Affinities`] is
//! Cow-backed `Send + Sync` (fit once, share by reference), sessions are
//! stepwise with an observer streaming un-permuted snapshots, and
//! checkpoints resume bit-identically at a fixed thread count. This module
//! is the daemon that composes them:
//!
//! - **Artifact cache.** Fitted affinities are cached keyed by the same
//!   FNV-1a data fingerprint the persistence layer stamps into artifacts
//!   ([`CacheKey`]), so a client re-submitting the same bytes at the same
//!   perplexity skips KNN + BSP entirely and goes straight to the gradient
//!   loop. Eviction is LRU over the cache's own `Arc`s only — an evicted
//!   artifact stays alive for every session still stepping on it.
//! - **Fair round-robin scheduling.** [`ThreadPool::broadcast`] runs ONE
//!   parallel region at a time, so a scheduler thread hands out *turns*:
//!   each connection thread owns its session and blocks until granted, runs
//!   exactly one gradient step (or its initial fit) on the shared pool, and
//!   goes to the back of the ring. No session starves another; frame writes
//!   happen **outside** turns so a slow client stalls only its own stream.
//! - **Progressive streaming.** As the session observer fires, the latest
//!   un-permuted embedding ships as a length-prefixed, FNV-1a-checksummed
//!   frame built from the `data::io` codecs (wire layout below). A client
//!   disconnect (EOF or failed write) detaches the session gracefully: its
//!   checkpoint parks in a bounded resume map and a later request carrying
//!   the session id continues it — bit-identical to an uninterrupted run.
//!
//! # Wire protocol (version 1, all integers/floats little-endian)
//!
//! Request: `b"ACSRVRQ1"` magic, then `version: u32`, `resume_id: u64`
//! (`0` = fresh run), `n: u64`, `d: u64`, `n_iter: u64`,
//! `snapshot_every: u64` (`0` = final frame only), `seed: u64`,
//! `perplexity: f64`, `theta: f64`, `n·d` point coordinates as `f64`, and an
//! FNV-1a checksum (`u64`) over everything after the magic. Resume requests
//! carry `n = d = 0` and no points.
//!
//! Frame: `b"ACSRVFR1"` magic, then `kind: u32`, three generic header
//! fields (`a: u64`, `b: f64`, `c: f64`), `payload_len: u64`, the payload,
//! and an FNV-1a checksum over header + payload. Kinds: `0` Hello
//! (`a` = session id, payload = `[cache_hit: u8]`), `1` Snapshot and `2`
//! Final (`a` = iteration, `b` = KL, `c` = gradient norm, payload = the
//! embedding as interleaved x,y `f64`s in original point order), `3` Error
//! (`a` = a code from the CLI exit-code families, payload = UTF-8 message).
//!
//! See `docs/serving.md` for the full protocol walk-through and the
//! `serving.*` bench keys (`BENCH_serving.json`).

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::io::{
    read_f64_le, read_f64_slice_le, read_u32_le, read_u64_le, write_f64_le, write_f64_slice_le,
    write_u32_le, write_u64_le, Fnv1a64,
};
use crate::parallel::pool::{available_cores, ThreadPool};
use crate::tsne::persist::SessionCheckpoint;
use crate::tsne::session::data_fingerprint;
use crate::tsne::{
    Affinities, FitError, ObserverControl, PlanError, StagePlan, TsneConfig, TsneSession,
};

/// Request magic (8 bytes).
pub const REQUEST_MAGIC: &[u8; 8] = b"ACSRVRQ1";
/// Frame magic (8 bytes).
pub const FRAME_MAGIC: &[u8; 8] = b"ACSRVFR1";
/// Wire protocol version carried in every request.
pub const PROTOCOL_VERSION: u32 = 1;

/// Frame kinds (the `kind: u32` header field).
pub const FRAME_HELLO: u32 = 0;
pub const FRAME_SNAPSHOT: u32 = 1;
pub const FRAME_FINAL: u32 = 2;
pub const FRAME_ERROR: u32 = 3;

/// Error-frame codes, aligned with the CLI's per-family exit codes so a
/// scripted client can `exit $code` and mean the same thing as `acc-tsne`.
pub const WIRE_FIT: u64 = 3;
pub const WIRE_RESUME: u64 = 4;
pub const WIRE_PLAN: u64 = 5;
pub const WIRE_STEP: u64 = 6;
pub const WIRE_PROTOCOL: u64 = 7;
pub const WIRE_SHUTDOWN: u64 = 8;

/// Request header length after the magic (version + 6×u64 + 2×f64).
const REQUEST_HEAD_LEN: usize = 4 + 6 * 8 + 2 * 8;
/// Frame header length after the magic (kind + a + b + c + payload_len).
const FRAME_HEAD_LEN: usize = 4 + 8 + 8 + 8 + 8;
/// Hard cap on `d` — hostile requests must not allocate unboundedly.
const MAX_DIMS: u64 = 4096;
/// Hard cap on total request coordinates (`n·d` f64s, = 1 GiB of points).
const MAX_COORDS: u64 = 1 << 27;
/// Hard cap on a frame payload (an embedding is 2n f64s ≪ this).
const MAX_FRAME_PAYLOAD: u64 = (MAX_COORDS * 8) + 64;
/// Hard cap on requested iterations.
const MAX_ITERS: u64 = 1_000_000;
/// Step-latency samples kept for the p50/p99 stats (first 2²⁰ steps).
const STEP_SAMPLE_CAP: usize = 1 << 20;

/// Typed serving errors — the `serve` CLI family (exit code 7), each mapping
/// onto a wire code from the existing exit-code families.
#[derive(Debug)]
pub enum ServeError {
    /// Binding or configuring the listening socket failed.
    Bind(io::Error),
    /// Socket I/O failed mid-stream.
    Io(io::Error),
    /// Malformed request or frame: bad magic, version, checksum, or a size
    /// guard tripped.
    Protocol(String),
    /// The affinity fit failed (shape, non-finite data, perplexity bounds).
    Fit(FitError),
    /// The derived stage plan failed validation.
    Plan(PlanError),
    /// Resume requested for a session that is unknown, already resumed, or
    /// evicted from the bounded resume map.
    Resume(String),
    /// The gradient loop diverged beyond recovery.
    Step(String),
    /// The server is shutting down.
    Shutdown,
    /// A client-side bit-identity or smoke-test verification failed.
    Verify(String),
    /// The server answered with an error frame (client side).
    Remote { code: u64, message: String },
}

impl ServeError {
    /// The code carried by an error frame for this error.
    pub fn wire_code(&self) -> u64 {
        match self {
            ServeError::Fit(_) => WIRE_FIT,
            ServeError::Resume(_) => WIRE_RESUME,
            ServeError::Plan(_) => WIRE_PLAN,
            ServeError::Step(_) | ServeError::Verify(_) => WIRE_STEP,
            ServeError::Shutdown => WIRE_SHUTDOWN,
            ServeError::Protocol(_)
            | ServeError::Bind(_)
            | ServeError::Io(_)
            | ServeError::Remote { .. } => WIRE_PROTOCOL,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Bind(e) => write!(f, "cannot bind serve address: {e}"),
            ServeError::Io(e) => write!(f, "serve I/O error: {e}"),
            ServeError::Protocol(m) => write!(f, "protocol error: {m}"),
            ServeError::Fit(e) => write!(f, "fit failed: {e}"),
            ServeError::Plan(e) => write!(f, "invalid plan: {e}"),
            ServeError::Resume(m) => write!(f, "resume failed: {m}"),
            ServeError::Step(m) => write!(f, "gradient loop failed: {m}"),
            ServeError::Shutdown => write!(f, "server shutting down"),
            ServeError::Verify(m) => write!(f, "verification failed: {m}"),
            ServeError::Remote { code, message } => {
                write!(f, "server error (code {code}): {message}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<io::Error> for ServeError {
    fn from(e: io::Error) -> Self {
        ServeError::Io(e)
    }
}

impl From<FitError> for ServeError {
    fn from(e: FitError) -> Self {
        ServeError::Fit(e)
    }
}

impl From<PlanError> for ServeError {
    fn from(e: PlanError) -> Self {
        ServeError::Plan(e)
    }
}

// ---------------------------------------------------------------------------
// Wire codec
// ---------------------------------------------------------------------------

/// A client request: a fresh run (points + hyperparameters) or, with
/// `resume_id != 0`, the continuation of a detached session (`n = d = 0`,
/// no points — the server kept the checkpoint and the fitted artifact).
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub resume_id: u64,
    pub n: u64,
    pub d: u64,
    pub n_iter: u64,
    /// Stream a snapshot frame every this many iterations (`0` = only the
    /// final frame).
    pub snapshot_every: u64,
    pub seed: u64,
    pub perplexity: f64,
    pub theta: f64,
    /// `n × d` coordinates, row-major. Empty for resume requests.
    pub points: Vec<f64>,
}

impl Request {
    /// A resume request for `session_id` — no points, hyperparameters come
    /// from the detached session.
    pub fn resume(session_id: u64) -> Request {
        Request {
            resume_id: session_id,
            n: 0,
            d: 0,
            n_iter: 0,
            snapshot_every: 0,
            seed: 0,
            perplexity: 0.0,
            theta: 0.0,
            points: Vec::new(),
        }
    }
}

/// One server→client message. `Snapshot`/`Final` embeddings are interleaved
/// x,y `f64`s in the caller's original point order.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Hello { session_id: u64, cache_hit: bool },
    Snapshot { iter: u64, kl: f64, grad_norm: f64, embedding: Vec<f64> },
    Final { iter: u64, kl: f64, grad_norm: f64, embedding: Vec<f64> },
    Error { code: u64, message: String },
}

/// Serialize a request (see the module docs for the layout).
pub fn write_request<W: Write>(w: &mut W, req: &Request) -> io::Result<()> {
    let mut body = Vec::with_capacity(REQUEST_HEAD_LEN + req.points.len() * 8);
    write_u32_le(&mut body, PROTOCOL_VERSION)?;
    write_u64_le(&mut body, req.resume_id)?;
    write_u64_le(&mut body, req.n)?;
    write_u64_le(&mut body, req.d)?;
    write_u64_le(&mut body, req.n_iter)?;
    write_u64_le(&mut body, req.snapshot_every)?;
    write_u64_le(&mut body, req.seed)?;
    write_f64_le(&mut body, req.perplexity)?;
    write_f64_le(&mut body, req.theta)?;
    write_f64_slice_le(&mut body, &req.points)?;
    let mut h = Fnv1a64::new();
    h.update(&body);
    w.write_all(REQUEST_MAGIC)?;
    w.write_all(&body)?;
    write_u64_le(w, h.finish())?;
    w.flush()
}

/// Parse and validate a request. Every hostile shape — wrong magic or
/// version, a size guard tripping, a checksum mismatch — is a typed
/// [`ServeError`], never a panic or an unbounded allocation.
pub fn read_request<R: Read>(r: &mut R, max_points: usize) -> Result<Request, ServeError> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != REQUEST_MAGIC {
        return Err(ServeError::Protocol("bad request magic".into()));
    }
    let mut head = [0u8; REQUEST_HEAD_LEN];
    r.read_exact(&mut head)?;
    let mut hasher = Fnv1a64::new();
    hasher.update(&head);
    let mut c: &[u8] = &head;
    let ver = read_u32_le(&mut c)?;
    let resume_id = read_u64_le(&mut c)?;
    let n = read_u64_le(&mut c)?;
    let d = read_u64_le(&mut c)?;
    let n_iter = read_u64_le(&mut c)?;
    let snapshot_every = read_u64_le(&mut c)?;
    let seed = read_u64_le(&mut c)?;
    let perplexity = read_f64_le(&mut c)?;
    let theta = read_f64_le(&mut c)?;
    if ver != PROTOCOL_VERSION {
        return Err(ServeError::Protocol(format!(
            "unsupported protocol version {ver} (this server speaks {PROTOCOL_VERSION})"
        )));
    }
    if resume_id != 0 {
        if n != 0 || d != 0 {
            return Err(ServeError::Protocol(
                "resume requests must not carry points (n = d = 0)".into(),
            ));
        }
    } else {
        if n == 0 || d == 0 {
            return Err(ServeError::Protocol("empty dataset (n = 0 or d = 0)".into()));
        }
        if n > max_points as u64 {
            return Err(ServeError::Protocol(format!(
                "n = {n} exceeds this server's limit of {max_points} points"
            )));
        }
        if d > MAX_DIMS {
            return Err(ServeError::Protocol(format!("d = {d} exceeds the limit of {MAX_DIMS}")));
        }
        match n.checked_mul(d) {
            Some(coords) if coords <= MAX_COORDS => {}
            _ => {
                return Err(ServeError::Protocol(format!(
                    "n·d = {n}·{d} exceeds the coordinate limit of {MAX_COORDS}"
                )))
            }
        }
        if n_iter > MAX_ITERS {
            return Err(ServeError::Protocol(format!(
                "n_iter = {n_iter} exceeds the limit of {MAX_ITERS}"
            )));
        }
    }
    let coords = (n * d) as usize;
    let mut pbytes = vec![0u8; coords * 8];
    r.read_exact(&mut pbytes)?;
    hasher.update(&pbytes);
    let want = read_u64_le(r)?;
    if want != hasher.finish() {
        return Err(ServeError::Protocol("request checksum mismatch".into()));
    }
    let mut points = vec![0.0f64; coords];
    read_f64_slice_le(&mut &pbytes[..], &mut points)?;
    Ok(Request { resume_id, n, d, n_iter, snapshot_every, seed, perplexity, theta, points })
}

fn encode_frame_parts(frame: &Frame) -> (u32, u64, f64, f64, Vec<u8>) {
    match frame {
        Frame::Hello { session_id, cache_hit } => {
            (FRAME_HELLO, *session_id, 0.0, 0.0, vec![u8::from(*cache_hit)])
        }
        Frame::Snapshot { iter, kl, grad_norm, embedding } => {
            let mut p = Vec::with_capacity(embedding.len() * 8);
            write_f64_slice_le(&mut p, embedding).expect("Vec<u8> write is infallible");
            (FRAME_SNAPSHOT, *iter, *kl, *grad_norm, p)
        }
        Frame::Final { iter, kl, grad_norm, embedding } => {
            let mut p = Vec::with_capacity(embedding.len() * 8);
            write_f64_slice_le(&mut p, embedding).expect("Vec<u8> write is infallible");
            (FRAME_FINAL, *iter, *kl, *grad_norm, p)
        }
        Frame::Error { code, message } => {
            (FRAME_ERROR, *code, 0.0, 0.0, message.as_bytes().to_vec())
        }
    }
}

/// Serialize one frame (see the module docs for the layout).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    let (kind, a, b, c, payload) = encode_frame_parts(frame);
    let mut head = Vec::with_capacity(FRAME_HEAD_LEN);
    write_u32_le(&mut head, kind)?;
    write_u64_le(&mut head, a)?;
    write_f64_le(&mut head, b)?;
    write_f64_le(&mut head, c)?;
    write_u64_le(&mut head, payload.len() as u64)?;
    let mut h = Fnv1a64::new();
    h.update(&head);
    h.update(&payload);
    w.write_all(FRAME_MAGIC)?;
    w.write_all(&head)?;
    w.write_all(&payload)?;
    write_u64_le(w, h.finish())?;
    w.flush()
}

/// Parse one frame. Torn/short streams surface as [`ServeError::Io`], bit
/// flips as [`ServeError::Protocol`] (checksum mismatch) — never a panic.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, ServeError> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != FRAME_MAGIC {
        return Err(ServeError::Protocol("bad frame magic".into()));
    }
    let mut head = [0u8; FRAME_HEAD_LEN];
    r.read_exact(&mut head)?;
    let mut c: &[u8] = &head;
    let kind = read_u32_le(&mut c)?;
    let a = read_u64_le(&mut c)?;
    let b = read_f64_le(&mut c)?;
    let cc = read_f64_le(&mut c)?;
    let payload_len = read_u64_le(&mut c)?;
    if payload_len > MAX_FRAME_PAYLOAD {
        return Err(ServeError::Protocol(format!(
            "frame payload of {payload_len} bytes exceeds the limit of {MAX_FRAME_PAYLOAD}"
        )));
    }
    let mut payload = vec![0u8; payload_len as usize];
    r.read_exact(&mut payload)?;
    let mut h = Fnv1a64::new();
    h.update(&head);
    h.update(&payload);
    let want = read_u64_le(r)?;
    if want != h.finish() {
        return Err(ServeError::Protocol("frame checksum mismatch".into()));
    }
    match kind {
        FRAME_HELLO => {
            if payload.len() != 1 {
                return Err(ServeError::Protocol(format!(
                    "hello payload must be 1 byte, got {}",
                    payload.len()
                )));
            }
            Ok(Frame::Hello { session_id: a, cache_hit: payload[0] != 0 })
        }
        FRAME_SNAPSHOT | FRAME_FINAL => {
            if payload.len() % 8 != 0 {
                return Err(ServeError::Protocol(format!(
                    "embedding payload of {} bytes is not a whole number of f64s",
                    payload.len()
                )));
            }
            let mut e = vec![0.0f64; payload.len() / 8];
            read_f64_slice_le(&mut &payload[..], &mut e)?;
            if kind == FRAME_SNAPSHOT {
                Ok(Frame::Snapshot { iter: a, kl: b, grad_norm: cc, embedding: e })
            } else {
                Ok(Frame::Final { iter: a, kl: b, grad_norm: cc, embedding: e })
            }
        }
        FRAME_ERROR => Ok(Frame::Error {
            code: a,
            message: String::from_utf8_lossy(&payload).into_owned(),
        }),
        other => Err(ServeError::Protocol(format!("unknown frame kind {other}"))),
    }
}

// ---------------------------------------------------------------------------
// Artifact cache
// ---------------------------------------------------------------------------

/// Cache key for a fitted artifact: the FNV-1a fingerprint of the raw point
/// bytes (the same one the persistence formats stamp — a hit is exactly
/// "same bytes, same fit"), the shape, and the perplexity's bit pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub data_fp: u64,
    pub n: usize,
    pub d: usize,
    pub perplexity_bits: u64,
}

impl CacheKey {
    /// Key for `points` (n × d row-major) at `perplexity`.
    pub fn for_points(points: &[f64], n: usize, d: usize, perplexity: f64) -> CacheKey {
        CacheKey { data_fp: data_fingerprint(points), n, d, perplexity_bits: perplexity.to_bits() }
    }
}

struct CacheEntry {
    aff: Arc<Affinities<'static, f64>>,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<CacheKey, CacheEntry>,
    tick: u64,
}

/// Fingerprint-keyed LRU cache of fitted [`Affinities`]. Concurrent lookups
/// of the same key return clones of the same `Arc`; eviction drops only the
/// cache's reference, so artifacts under active sessions stay alive.
pub struct ArtifactCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ArtifactCache {
    /// A cache holding at most `capacity` artifacts (clamped to ≥ 1).
    pub fn new(capacity: usize) -> ArtifactCache {
        ArtifactCache {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner { map: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up a fitted artifact. A hit bumps the entry's LRU stamp.
    pub fn lookup(&self, key: &CacheKey) -> Option<Arc<Affinities<'static, f64>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.aff))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a fitted artifact, evicting least-recently-used entries beyond
    /// capacity.
    pub fn insert(&self, key: CacheKey, aff: Arc<Affinities<'static, f64>>) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, CacheEntry { aff, last_used: tick });
        while inner.map.len() > self.capacity {
            let oldest = inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    inner.map.remove(&k);
                }
                None => break,
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups that found an artifact.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that missed (each followed by a fit + insert on the serving
    /// path).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

enum Cmd {
    /// A connection thread wants turns for session `id`.
    Join { id: u64, grant_tx: SyncSender<()> },
    /// The granted turn finished. `step_secs` is `Some` only for gradient
    /// steps (fits and session builds don't pollute the step latency stats);
    /// `more = false` retires the session from the ring.
    Done { id: u64, more: bool, step_secs: Option<f64> },
    /// The connection thread is gone (any exit path — sent from a drop
    /// guard, so it always arrives after that thread's final `Done`).
    Exited { id: u64 },
}

struct Slot {
    id: u64,
    grant_tx: SyncSender<()>,
}

#[derive(Default)]
struct StatsInner {
    steps: u64,
    step_secs: Vec<f64>,
    sessions_completed: u64,
    sessions_detached: u64,
    sessions_resumed: u64,
    protocol_errors: u64,
}

struct Shared {
    pool: Arc<ThreadPool>,
    cache: ArtifactCache,
    cmd_tx: Sender<Cmd>,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    resume: Mutex<VecDeque<Detached>>,
    resume_capacity: usize,
    stats: Mutex<StatsInner>,
    max_points: usize,
}

/// A session parked by a client disconnect: everything needed to continue
/// it bit-identically — the shared artifact, the checkpoint, and the run
/// parameters the original request carried.
struct Detached {
    id: u64,
    aff: Arc<Affinities<'static, f64>>,
    ck: SessionCheckpoint<f64>,
    plan: StagePlan,
    cfg: TsneConfig,
    n_iter: usize,
    snapshot_every: usize,
}

/// The round-robin turn scheduler. One turn is outstanding at a time (the
/// pool runs one parallel region at a time); `Done` rotates the session to
/// the back of the ring, `Exited` retires it from wherever it is. Granting
/// uses `try_send` on a 1-slot channel: a receiver that disconnected (its
/// thread died) simply drops out of the ring.
fn scheduler_loop(shared: Arc<Shared>, cmd_rx: Receiver<Cmd>) {
    let mut ring: VecDeque<Slot> = VecDeque::new();
    let mut outstanding: Option<Slot> = None;
    loop {
        if shared.shutdown.load(Ordering::Acquire) && outstanding.is_none() {
            // Dropping the ring disconnects every parked grant channel,
            // unblocking its connection thread with a shutdown error.
            break;
        }
        let cmd = if outstanding.is_some() || ring.is_empty() {
            match cmd_rx.recv_timeout(Duration::from_millis(25)) {
                Ok(c) => Some(c),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        } else {
            cmd_rx.try_recv().ok()
        };
        match cmd {
            Some(Cmd::Join { id, grant_tx }) => ring.push_back(Slot { id, grant_tx }),
            Some(Cmd::Done { id, more, step_secs }) => {
                if let Some(s) = step_secs {
                    let mut st = shared.stats.lock().unwrap();
                    st.steps += 1;
                    if st.step_secs.len() < STEP_SAMPLE_CAP {
                        st.step_secs.push(s);
                    }
                }
                if outstanding.as_ref().is_some_and(|s| s.id == id) {
                    if let Some(slot) = outstanding.take() {
                        if more {
                            ring.push_back(slot);
                        }
                    }
                }
            }
            Some(Cmd::Exited { id }) => {
                if outstanding.as_ref().is_some_and(|s| s.id == id) {
                    outstanding = None;
                }
                ring.retain(|s| s.id != id);
            }
            None => {}
        }
        if outstanding.is_none() && !shared.shutdown.load(Ordering::Acquire) {
            while let Some(slot) = ring.pop_front() {
                if slot.grant_tx.try_send(()).is_ok() {
                    outstanding = Some(slot);
                    break;
                }
                // Disconnected receiver: the connection thread died; its
                // `Exited` may still be in flight. Drop the slot now.
            }
        }
    }
}

/// A connection thread's handle into the scheduler: join once, then block
/// for turns. The `Drop` impl announces the exit on every path (including
/// panics), so the scheduler can never deadlock on a dead session.
struct TurnHandle {
    id: u64,
    cmd_tx: Sender<Cmd>,
    grant_rx: Receiver<()>,
}

impl TurnHandle {
    fn join(shared: &Shared, id: u64) -> Result<TurnHandle, ServeError> {
        let (grant_tx, grant_rx) = mpsc::sync_channel(1);
        let cmd_tx = shared.cmd_tx.clone();
        cmd_tx.send(Cmd::Join { id, grant_tx }).map_err(|_| ServeError::Shutdown)?;
        Ok(TurnHandle { id, cmd_tx, grant_rx })
    }

    /// Block until granted, run `f` (which returns its result plus whether
    /// more turns are wanted), and report the turn back. `is_step` routes
    /// the turn's wall time into the step-latency stats.
    fn turn<R>(
        &self,
        is_step: bool,
        f: impl FnOnce() -> (R, bool),
    ) -> Result<R, ServeError> {
        self.grant_rx.recv().map_err(|_| ServeError::Shutdown)?;
        let t0 = Instant::now();
        let (out, more) = f();
        let step_secs = is_step.then(|| t0.elapsed().as_secs_f64());
        let _ = self.cmd_tx.send(Cmd::Done { id: self.id, more, step_secs });
        Ok(out)
    }
}

impl Drop for TurnHandle {
    fn drop(&mut self) {
        let _ = self.cmd_tx.send(Cmd::Exited { id: self.id });
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `"127.0.0.1:7878"` (`:0` picks a free port).
    pub addr: String,
    /// Shared-pool size; `0` ⇒ all available cores.
    pub n_threads: usize,
    /// Fitted-artifact cache capacity (LRU beyond this).
    pub cache_capacity: usize,
    /// How many detached sessions are kept resumable (FIFO beyond this).
    pub resume_capacity: usize,
    /// Per-request point-count limit.
    pub max_points: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            n_threads: 0,
            cache_capacity: 8,
            resume_capacity: 64,
            max_points: 1_000_000,
        }
    }
}

/// Aggregated serving statistics (see [`ServerHandle::stats`]).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Gradient steps scheduled across all sessions.
    pub steps: u64,
    /// Median per-step latency (seconds) over the recorded samples.
    pub step_p50_s: f64,
    /// 99th-percentile per-step latency (seconds).
    pub step_p99_s: f64,
    pub sessions_completed: u64,
    pub sessions_detached: u64,
    pub sessions_resumed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub protocol_errors: u64,
}

/// A running daemon. Dropping the handle shuts the server down (stops
/// accepting, finishes the outstanding turn, unparks waiting sessions with
/// a shutdown error).
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    sched: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound listen address (useful with `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the serving statistics.
    pub fn stats(&self) -> ServeStats {
        let inner = self.shared.stats.lock().unwrap();
        let mut samples = inner.step_secs.clone();
        samples.sort_by(f64::total_cmp);
        ServeStats {
            steps: inner.steps,
            step_p50_s: percentile(&samples, 0.50),
            step_p99_s: percentile(&samples, 0.99),
            sessions_completed: inner.sessions_completed,
            sessions_detached: inner.sessions_detached,
            sessions_resumed: inner.sessions_resumed,
            cache_hits: self.shared.cache.hits(),
            cache_misses: self.shared.cache.misses(),
            protocol_errors: inner.protocol_errors,
        }
    }

    /// Stop accepting, let the outstanding turn finish, and join the accept
    /// and scheduler threads. Idempotent. Connection threads are not joined:
    /// any still waiting for a turn exit promptly with a shutdown error.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sched.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Start the daemon: bind, spawn the scheduler and the accept loop, return
/// immediately. One OS thread per connection owns that client's session;
/// all of them share one [`ThreadPool`] through the turn scheduler.
pub fn start(cfg: &ServeConfig) -> Result<ServerHandle, ServeError> {
    let nt = if cfg.n_threads == 0 { available_cores() } else { cfg.n_threads };
    let listener = TcpListener::bind(&cfg.addr).map_err(ServeError::Bind)?;
    let addr = listener.local_addr().map_err(ServeError::Bind)?;
    listener.set_nonblocking(true).map_err(ServeError::Bind)?;
    let (cmd_tx, cmd_rx) = mpsc::channel();
    let shared = Arc::new(Shared {
        pool: Arc::new(ThreadPool::new(nt)),
        cache: ArtifactCache::new(cfg.cache_capacity),
        cmd_tx,
        shutdown: AtomicBool::new(false),
        next_id: AtomicU64::new(1),
        resume: Mutex::new(VecDeque::new()),
        resume_capacity: cfg.resume_capacity.max(1),
        stats: Mutex::new(StatsInner::default()),
        max_points: cfg.max_points,
    });
    let sched = {
        let shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("acc-tsne-serve-sched".into())
            .spawn(move || scheduler_loop(shared, cmd_rx))
            .map_err(ServeError::Io)?
    };
    let accept = {
        let shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("acc-tsne-serve-accept".into())
            .spawn(move || accept_loop(listener, shared))
            .map_err(ServeError::Io)?
    };
    Ok(ServerHandle { addr, shared, accept: Some(accept), sched: Some(sched) })
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conn_seq = 0u64;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                conn_seq += 1;
                let shared = Arc::clone(&shared);
                let _ = std::thread::Builder::new()
                    .name(format!("acc-tsne-serve-conn-{conn_seq}"))
                    .spawn(move || handle_conn(stream, shared));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(60)));
    if let Err(err) = serve_conn(&mut stream, &shared) {
        if matches!(err, ServeError::Protocol(_)) {
            shared.stats.lock().unwrap().protocol_errors += 1;
        }
        // A dead socket can't carry an error frame; everything else gets a
        // typed code + message so clients fail with a reason.
        if !matches!(err, ServeError::Io(_)) {
            let _ = write_frame(
                &mut stream,
                &Frame::Error { code: err.wire_code(), message: err.to_string() },
            );
        }
    }
}

fn serve_conn(stream: &mut TcpStream, shared: &Arc<Shared>) -> Result<(), ServeError> {
    let req = read_request(stream, shared.max_points)?;
    if shared.shutdown.load(Ordering::Acquire) {
        return Err(ServeError::Shutdown);
    }
    if req.resume_id != 0 {
        serve_resumed(stream, shared, req)
    } else {
        serve_fresh(stream, shared, req)
    }
}

fn serve_fresh(
    stream: &mut TcpStream,
    shared: &Arc<Shared>,
    req: Request,
) -> Result<(), ServeError> {
    let n = req.n as usize;
    let d = req.d as usize;
    let plan = StagePlan::auto_for(n);
    let cfg = TsneConfig {
        perplexity: req.perplexity,
        theta: req.theta,
        n_iter: req.n_iter as usize,
        seed: req.seed,
        n_threads: shared.pool.n_threads(),
        ..TsneConfig::default()
    };
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let turns = TurnHandle::join(shared, id)?;

    // First turn (serialized over the pool): cache lookup, fitting on a
    // miss. Re-checking *inside* the turn makes N same-data arrivals
    // deterministic — exactly one fits, the rest hit the cached Arc; no
    // double-fit stampede.
    let fitted = turns.turn(false, || {
        let key = CacheKey::for_points(&req.points, n, d, req.perplexity);
        let out = match shared.cache.lookup(&key) {
            Some(aff) => Ok((aff, true)),
            None => Affinities::fit(&shared.pool, &req.points, n, d, req.perplexity, &plan).map(
                |aff| {
                    let aff = Arc::new(aff);
                    shared.cache.insert(key, Arc::clone(&aff));
                    (aff, false)
                },
            ),
        };
        let more = out.is_ok();
        (out, more)
    })?;
    let (aff, cache_hit) = fitted?;

    // Second turn: session construction (Z-order adoption broadcasts).
    let built = turns.turn(false, || {
        let r = TsneSession::new_shared(&*aff, plan, cfg, Arc::clone(&shared.pool));
        let more = r.is_ok();
        (r, more)
    })?;
    let sess = built?;

    // The Hello only ships once the expensive part is done: its arrival
    // time *is* the cache-hit/miss latency a client observes.
    write_frame(stream, &Frame::Hello { session_id: id, cache_hit })?;
    drive(
        stream,
        shared,
        &turns,
        sess,
        &aff,
        plan,
        cfg,
        req.n_iter as usize,
        req.snapshot_every as usize,
        id,
    )
}

fn serve_resumed(
    stream: &mut TcpStream,
    shared: &Arc<Shared>,
    req: Request,
) -> Result<(), ServeError> {
    let det = {
        let mut q = shared.resume.lock().unwrap();
        let found = q
            .iter()
            .position(|dtc| dtc.id == req.resume_id)
            .and_then(|i| q.remove(i));
        match found {
            Some(det) => det,
            None => {
                return Err(ServeError::Resume(format!(
                    "no detached session {} (unknown, already resumed, or evicted)",
                    req.resume_id
                )))
            }
        }
    };
    let Detached { aff, ck, plan, cfg, n_iter, snapshot_every, .. } = det;
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let turns = TurnHandle::join(shared, id)?;
    let built = turns.turn(false, || {
        let r = TsneSession::from_checkpoint_shared(&*aff, plan, cfg, ck, Arc::clone(&shared.pool));
        let more = r.is_ok();
        (r, more)
    })?;
    let sess = built.map_err(|e| ServeError::Resume(e.to_string()))?;
    shared.stats.lock().unwrap().sessions_resumed += 1;
    // A resume never re-fits: the artifact rode along with the checkpoint.
    write_frame(stream, &Frame::Hello { session_id: id, cache_hit: true })?;
    drive(stream, shared, &turns, sess, &aff, plan, cfg, n_iter, snapshot_every, id)
}

/// Detect an orderly client hang-up without consuming stream bytes: a
/// zero-length peek is EOF, `WouldBlock` means a live-but-quiet client.
fn client_gone(stream: &TcpStream) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let gone = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

/// Park a session for later resume. Only this session's state moves; the
/// cached artifact, the pool, and every other stream are untouched.
fn detach(
    shared: &Shared,
    id: u64,
    aff: &Arc<Affinities<'static, f64>>,
    sess: &TsneSession<'_, f64>,
    plan: StagePlan,
    cfg: TsneConfig,
    n_iter: usize,
    snapshot_every: usize,
) {
    let ck = sess.to_checkpoint();
    let mut q = shared.resume.lock().unwrap();
    q.push_back(Detached { id, aff: Arc::clone(aff), ck, plan, cfg, n_iter, snapshot_every });
    while q.len() > shared.resume_capacity {
        q.pop_front();
    }
    drop(q);
    shared.stats.lock().unwrap().sessions_detached += 1;
}

/// The per-connection gradient loop: one step per granted turn, snapshot
/// frames written outside turns, disconnects detaching only this session.
#[allow(clippy::too_many_arguments)]
fn drive(
    stream: &mut TcpStream,
    shared: &Arc<Shared>,
    turns: &TurnHandle,
    mut sess: TsneSession<'_, f64>,
    aff: &Arc<Affinities<'static, f64>>,
    plan: StagePlan,
    cfg: TsneConfig,
    n_iter: usize,
    snapshot_every: usize,
    id: u64,
) -> Result<(), ServeError> {
    // The observer fires inside `step()` (inside the turn) and buffers the
    // un-permuted snapshot here; the socket write happens after the turn is
    // released, so a slow client never holds the pool.
    let pending: Rc<RefCell<Option<Frame>>> = Rc::new(RefCell::new(None));
    if snapshot_every > 0 {
        let buf = Rc::clone(&pending);
        sess.set_observer(snapshot_every, move |snap| {
            *buf.borrow_mut() = Some(Frame::Snapshot {
                iter: snap.iter as u64,
                kl: snap.kl,
                grad_norm: snap.grad_norm,
                embedding: snap.embedding.to_vec(),
            });
            ObserverControl::Continue
        });
    }
    while sess.iterations() < n_iter {
        if client_gone(stream) {
            detach(shared, id, aff, &sess, plan, cfg, n_iter, snapshot_every);
            return Ok(());
        }
        let stepped = turns.turn(true, || {
            let r = sess.step();
            let more = r.is_ok() && sess.iterations() < n_iter;
            (r, more)
        })?;
        if let Err(e) = stepped {
            return Err(ServeError::Step(e.to_string()));
        }
        let frame = pending.borrow_mut().take();
        if let Some(frame) = frame {
            // The very last snapshot ships as the Final frame instead.
            if sess.iterations() < n_iter && write_frame(stream, &frame).is_err() {
                detach(shared, id, aff, &sess, plan, cfg, n_iter, snapshot_every);
                return Ok(());
            }
        }
    }
    let last = Frame::Final {
        iter: sess.iterations() as u64,
        kl: sess.kl(),
        grad_norm: sess.last_grad_norm(),
        embedding: sess.embedding(),
    };
    if write_frame(stream, &last).is_err() {
        // Even a torn Final leaves the run resumable.
        detach(shared, id, aff, &sess, plan, cfg, n_iter, snapshot_every);
        return Ok(());
    }
    shared.stats.lock().unwrap().sessions_completed += 1;
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// What a completed client run saw.
#[derive(Clone, Debug)]
pub struct ClientRun {
    pub session_id: u64,
    /// Whether the server skipped KNN + BSP (artifact cache hit / resume).
    pub cache_hit: bool,
    /// Connect-to-Hello latency: the fit (cache miss) or lookup (hit) cost.
    pub hello_secs: f64,
    /// Progressive snapshot frames received before the final one.
    pub snapshots: usize,
    pub final_iter: u64,
    pub final_kl: f64,
    pub final_grad_norm: f64,
    /// Final embedding, interleaved x,y, original point order.
    pub embedding: Vec<f64>,
}

/// Run one request to completion against a serving daemon at `addr`.
pub fn run_client(addr: &str, req: &Request) -> Result<ClientRun, ServeError> {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    write_request(&mut stream, req)?;
    let (session_id, cache_hit) = match read_frame(&mut stream)? {
        Frame::Hello { session_id, cache_hit } => (session_id, cache_hit),
        Frame::Error { code, message } => return Err(ServeError::Remote { code, message }),
        other => {
            return Err(ServeError::Protocol(format!("expected a Hello frame, got {other:?}")))
        }
    };
    let hello_secs = t0.elapsed().as_secs_f64();
    let mut snapshots = 0usize;
    loop {
        match read_frame(&mut stream)? {
            Frame::Snapshot { .. } => snapshots += 1,
            Frame::Final { iter, kl, grad_norm, embedding } => {
                return Ok(ClientRun {
                    session_id,
                    cache_hit,
                    hello_secs,
                    snapshots,
                    final_iter: iter,
                    final_kl: kl,
                    final_grad_norm: grad_norm,
                    embedding,
                });
            }
            Frame::Error { code, message } => return Err(ServeError::Remote { code, message }),
            Frame::Hello { .. } => {
                return Err(ServeError::Protocol("unexpected second Hello frame".into()))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Smoke test (CI's `acc-tsne serve --smoke N`)
// ---------------------------------------------------------------------------

/// Outcome of [`run_smoke`] — by construction every client already verified
/// bit-identical against a direct in-process session before this returns.
#[derive(Clone, Debug)]
pub struct SmokeReport {
    pub clients: usize,
    pub n_threads: usize,
    pub n_iter: usize,
    pub stats: ServeStats,
}

fn assert_bits_equal(want: &[f64], got: &[f64], what: &str) -> Result<(), ServeError> {
    if want.len() != got.len() {
        return Err(ServeError::Verify(format!(
            "{what}: embedding length {} vs direct {}",
            got.len(),
            want.len()
        )));
    }
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        if w.to_bits() != g.to_bits() {
            return Err(ServeError::Verify(format!(
                "{what}: bit mismatch at coordinate {i}: served {g:e} vs direct {w:e}"
            )));
        }
    }
    Ok(())
}

/// Retry a resume request until the server has actually parked the detached
/// session (the disconnect is noticed between turns, so there is a small
/// window where the id is not yet resumable).
pub fn poll_resume(
    addr: &str,
    resume_id: u64,
    max_attempts: usize,
) -> Result<ClientRun, ServeError> {
    let mut last = String::new();
    for _ in 0..max_attempts {
        match run_client(addr, &Request::resume(resume_id)) {
            Ok(run) => return Ok(run),
            Err(ServeError::Remote { code, message }) if code == WIRE_RESUME => {
                last = message;
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e),
        }
    }
    Err(ServeError::Resume(format!("session {resume_id} never became resumable: {last}")))
}

/// End-to-end smoke: an in-process daemon on a loopback port, `n_clients`
/// concurrent streams over the same dataset (1 fit + N−1 cache hits, N
/// distinct optimizer seeds), a disconnect → resume leg, and a bitwise
/// comparison of every final frame against a direct [`TsneSession`] run at
/// the same thread count. This is what `acc-tsne serve --smoke N` runs and
/// what the CI serve job gates on.
pub fn run_smoke(
    n_clients: usize,
    n_threads: usize,
    n_iter: usize,
    seed: u64,
) -> Result<SmokeReport, ServeError> {
    let n_clients = n_clients.max(1);
    let nt = if n_threads == 0 { available_cores() } else { n_threads };
    // Enough iterations that the disconnect leg reliably hangs up mid-run.
    let n_iter = n_iter.max(30);
    let ds = crate::data::synthetic::gaussian_mixture::<f64>(256, 16, 4, 4.0, seed);
    let perplexity = 12.0;
    let theta = 0.5;
    let mut server = start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        n_threads: nt,
        ..ServeConfig::default()
    })?;
    let addr = server.addr().to_string();

    // N concurrent clients: same points ⇒ one fit, N−1 artifact-cache hits;
    // distinct seeds ⇒ N distinct trajectories multiplexed fairly.
    let mut joins = Vec::new();
    for i in 0..n_clients {
        let addr = addr.clone();
        let points = ds.points.clone();
        let (n, d) = (ds.n, ds.d);
        let client_seed = seed.wrapping_add(1 + i as u64);
        joins.push(std::thread::spawn(move || {
            run_client(
                &addr,
                &Request {
                    resume_id: 0,
                    n: n as u64,
                    d: d as u64,
                    n_iter: n_iter as u64,
                    snapshot_every: (n_iter / 4).max(1) as u64,
                    seed: client_seed,
                    perplexity,
                    theta,
                    points,
                },
            )
        }));
    }
    let mut runs = Vec::new();
    for j in joins {
        let run = j.join().map_err(|_| ServeError::Verify("client thread panicked".into()))??;
        if run.snapshots == 0 {
            return Err(ServeError::Verify("client saw no progressive frames".into()));
        }
        runs.push(run);
    }

    // Disconnect → resume leg: hang up right after the Hello; the server
    // must detach only that session, then continue it on request.
    let resume_seed = seed.wrapping_add(10_000);
    let detached_id = {
        let mut stream = TcpStream::connect(&addr)?;
        write_request(
            &mut stream,
            &Request {
                resume_id: 0,
                n: ds.n as u64,
                d: ds.d as u64,
                n_iter: n_iter as u64,
                snapshot_every: 0,
                seed: resume_seed,
                perplexity,
                theta,
                points: ds.points.clone(),
            },
        )?;
        match read_frame(&mut stream)? {
            Frame::Hello { session_id, .. } => session_id,
            Frame::Error { code, message } => return Err(ServeError::Remote { code, message }),
            other => {
                return Err(ServeError::Protocol(format!("expected a Hello frame, got {other:?}")))
            }
        }
        // `stream` drops here: the disconnect the server must survive.
    };
    let resumed = poll_resume(&addr, detached_id, 500)?;

    // Ground truth: direct in-process sessions at the same thread count.
    let pool = ThreadPool::new(nt);
    let plan = StagePlan::auto_for(ds.n);
    let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, perplexity, &plan)?;
    let base_cfg = TsneConfig {
        perplexity,
        theta,
        n_iter,
        n_threads: nt,
        ..TsneConfig::default()
    };
    for (i, run) in runs.iter().enumerate() {
        let cfg = TsneConfig { seed: seed.wrapping_add(1 + i as u64), ..base_cfg };
        let mut direct = TsneSession::new(&aff, plan, cfg)?;
        direct.run(n_iter);
        let want = direct.finish();
        assert_bits_equal(&want.embedding, &run.embedding, &format!("client {i}"))?;
    }
    let cfg = TsneConfig { seed: resume_seed, ..base_cfg };
    let mut direct = TsneSession::new(&aff, plan, cfg)?;
    direct.run(n_iter);
    let want = direct.finish();
    assert_bits_equal(&want.embedding, &resumed.embedding, "resumed client")?;

    let stats = server.stats();
    server.shutdown();
    Ok(SmokeReport { clients: n_clients, n_threads: nt, n_iter, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_mixture;

    fn sample_request() -> Request {
        Request {
            resume_id: 0,
            n: 3,
            d: 2,
            n_iter: 100,
            snapshot_every: 10,
            seed: 7,
            perplexity: 2.0,
            theta: 0.5,
            points: vec![0.0, 1.0, -2.5, std::f64::consts::PI, 4.0, 5.5],
        }
    }

    #[test]
    fn request_roundtrip_preserves_every_bit() {
        let req = sample_request();
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let got = read_request(&mut &buf[..], 1_000_000).unwrap();
        assert_eq!(got, req);
    }

    #[test]
    fn request_checksum_flip_is_a_typed_error() {
        let req = sample_request();
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        // Flip one bit in a point coordinate (after magic + header).
        let idx = 8 + REQUEST_HEAD_LEN + 3;
        buf[idx] ^= 0x40;
        match read_request(&mut &buf[..], 1_000_000) {
            Err(ServeError::Protocol(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected a checksum error, got {other:?}"),
        }
    }

    #[test]
    fn request_truncated_at_every_boundary_never_panics() {
        let req = sample_request();
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        for cut in 0..buf.len() {
            let r = read_request(&mut &buf[..cut], 1_000_000);
            assert!(r.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn request_size_guards_reject_hostile_headers() {
        // n beyond the server limit.
        let mut req = sample_request();
        req.n = 10_000_000;
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert!(matches!(read_request(&mut &buf[..], 1_000), Err(ServeError::Protocol(_))));
        // absurd d.
        let mut req = sample_request();
        req.d = MAX_DIMS + 1;
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert!(matches!(read_request(&mut &buf[..], 1_000_000), Err(ServeError::Protocol(_))));
        // n·d overflow attempt.
        let mut req = sample_request();
        req.n = u64::MAX / 2;
        req.d = 3;
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert!(matches!(
            read_request(&mut &buf[..], usize::MAX),
            Err(ServeError::Protocol(_))
        ));
        // resume requests must not carry points.
        let mut req = sample_request();
        req.resume_id = 42;
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert!(matches!(read_request(&mut &buf[..], 1_000_000), Err(ServeError::Protocol(_))));
    }

    #[test]
    fn frame_roundtrip_every_kind() {
        let frames = vec![
            Frame::Hello { session_id: 99, cache_hit: true },
            Frame::Hello { session_id: 1, cache_hit: false },
            Frame::Snapshot {
                iter: 50,
                kl: 1.25,
                grad_norm: 3.5e-3,
                embedding: vec![1.0, -2.0, 0.5, std::f64::consts::PI],
            },
            Frame::Final { iter: 1000, kl: 0.75, grad_norm: 1e-7, embedding: vec![0.0; 8] },
            Frame::Error { code: WIRE_FIT, message: "too few points".into() },
        ];
        for f in &frames {
            let mut buf = Vec::new();
            write_frame(&mut buf, f).unwrap();
            let got = read_frame(&mut &buf[..]).unwrap();
            assert_eq!(&got, f);
        }
        // All frames concatenated still parse in order (length-prefixed).
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut c: &[u8] = &buf;
        for f in &frames {
            assert_eq!(&read_frame(&mut c).unwrap(), f);
        }
    }

    #[test]
    fn frame_corruption_and_truncation_are_typed_errors() {
        let f = Frame::Snapshot {
            iter: 7,
            kl: 2.0,
            grad_norm: 0.1,
            embedding: vec![1.0, 2.0, 3.0, 4.0],
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // Bit flip anywhere after the magic → checksum or guard error.
        for idx in 8..buf.len() {
            let mut bad = buf.clone();
            bad[idx] ^= 0x01;
            assert!(read_frame(&mut &bad[..]).is_err(), "flip at {idx} must fail");
        }
        // Truncation at every boundary → Io error, no panic.
        for cut in 0..buf.len() {
            assert!(read_frame(&mut &buf[..cut]).is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn frame_payload_guard_rejects_absurd_lengths() {
        let f = Frame::Hello { session_id: 1, cache_hit: false };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // Patch payload_len (last 8 bytes of the header) to a huge value.
        let len_off = 8 + FRAME_HEAD_LEN - 8;
        buf[len_off..len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        match read_frame(&mut &buf[..]) {
            Err(ServeError::Protocol(m)) => assert!(m.contains("payload"), "{m}"),
            other => panic!("expected a payload guard error, got {other:?}"),
        }
    }

    fn tiny_affinities() -> Arc<Affinities<'static, f64>> {
        let ds = gaussian_mixture::<f64>(64, 4, 2, 4.0, 5);
        let pool = ThreadPool::new(2);
        let plan = StagePlan::acc_tsne();
        Arc::new(Affinities::fit(&pool, &ds.points, ds.n, ds.d, 5.0, &plan).expect("fit"))
    }

    #[test]
    fn cache_hit_returns_the_same_shared_artifact() {
        let cache = ArtifactCache::new(4);
        let ds = gaussian_mixture::<f64>(64, 4, 2, 4.0, 5);
        let key = CacheKey::for_points(&ds.points, ds.n, ds.d, 5.0);
        assert!(cache.lookup(&key).is_none());
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let aff = tiny_affinities();
        cache.insert(key, Arc::clone(&aff));
        let got = cache.lookup(&key).expect("hit");
        assert!(Arc::ptr_eq(&got, &aff), "hit must return the same shared Arc");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // A different perplexity is a different artifact.
        let other = CacheKey::for_points(&ds.points, ds.n, ds.d, 7.0);
        assert!(cache.lookup(&other).is_none());
        assert_ne!(key, other);
    }

    #[test]
    fn cache_key_tracks_data_bytes_exactly() {
        let ds = gaussian_mixture::<f64>(64, 4, 2, 4.0, 5);
        let k1 = CacheKey::for_points(&ds.points, ds.n, ds.d, 5.0);
        let mut tweaked = ds.points.clone();
        tweaked[17] = tweaked[17].next_up();
        let k2 = CacheKey::for_points(&tweaked, ds.n, ds.d, 5.0);
        assert_ne!(k1, k2, "a 1-ulp change must miss the cache");
    }

    #[test]
    fn cache_eviction_is_lru_and_never_kills_live_artifacts() {
        let cache = ArtifactCache::new(2);
        let aff = tiny_affinities();
        let keys: Vec<CacheKey> = (0..3)
            .map(|i| CacheKey { data_fp: i, n: 64, d: 4, perplexity_bits: 0 })
            .collect();
        cache.insert(keys[0], Arc::clone(&aff));
        cache.insert(keys[1], Arc::clone(&aff));
        // Touch key 0 so key 1 is the LRU.
        let held = cache.lookup(&keys[0]).expect("hit");
        cache.insert(keys[2], Arc::clone(&aff));
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(&keys[1]).is_none(), "LRU entry must be evicted");
        assert!(cache.lookup(&keys[0]).is_some());
        assert!(cache.lookup(&keys[2]).is_some());
        // The evicted artifact itself is still alive through our Arc: an
        // active session's borrow is never invalidated by eviction.
        assert!(held.n() == 64);
        assert!(Arc::strong_count(&aff) >= 2);
    }

    #[test]
    fn percentile_picks_sane_indices() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.99), 3.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!((percentile(&v, 0.5) - 50.0).abs() <= 1.0);
        assert!(percentile(&v, 0.99) >= 98.0);
    }
}
