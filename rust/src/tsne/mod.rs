//! The BH t-SNE pipeline and the five implementations the paper evaluates.
//!
//! The public API is staged around the pipeline's two lifetimes (Fig. 1a:
//! KNN+BSP run once, the gradient loop runs ~1000×):
//!
//! - [`KnnGraph`] (`session`) — the step-1 neighbor lists as a first-class,
//!   persistable artifact; [`Affinities::from_knn`] re-fits at any
//!   perplexity with ⌊3u⌋ ≤ k without re-running KNN (bit-identical to a
//!   fresh fit at that perplexity);
//! - [`Affinities`] (`session`) — the fitted KNN→BSP→symmetrize artifact;
//!   compute once, reuse across gradient runs; every hostile shape or
//!   out-of-range perplexity on the fitting paths is a typed [`FitError`];
//! - [`StagePlan`] (`plan`) — the public, validated stage table (KNN engine,
//!   BSP/tree/summarize parallelism, kernel variants, layout, adoption
//!   threshold) with the five [`Implementation`]s as preset constructors and
//!   impossible combinations rejected as typed [`PlanError`]s;
//! - [`TsneSession`] (`session`) — a resumable optimizer over
//!   `Affinities + StagePlan + TsneConfig`: [`step`](TsneSession::step) /
//!   [`run`](TsneSession::run) / [`run_until`](TsneSession::run_until)
//!   (sklearn-style `min_grad_norm` / `n_iter_without_progress` over the
//!   per-iteration gradient norm) plus an observer hook streaming
//!   un-permuted embedding snapshots with the current KL.
//!
//! Both artifacts persist (`persist`): [`Affinities::save`]/
//! [`Affinities::load`] serialize the fitted CSR `P` for cross-process
//! reuse, and [`TsneSession::checkpoint`]/[`TsneSession::restore`] make a
//! session survive a restart — a resumed run is bit-identical to an
//! uninterrupted one at a fixed thread count. One `Affinities` is `Sync`
//! and is borrowed (`&Affinities`) by every session built over it, so N
//! concurrent sessions share a single fit across threads. The [`serve`]
//! module composes all of the above into an embedding-as-a-service TCP
//! daemon (`acc-tsne serve`): fitted artifacts cached by data fingerprint,
//! concurrent sessions multiplexed round-robin over one shared pool, and
//! progressive snapshot frames streamed to clients.
//!
//! [`run_tsne`] remains the classic one-shot call — a thin, bit-identical
//! wrapper over fit + session — executing the full step sequence with every
//! step instrumented into a [`StepTimes`] (the paper's Tables 5/6 and
//! Figures 1b/6 are per-step timings).
//!
//! [`Implementation`] selects the architecture being modeled (see
//! DESIGN.md §Substitutions for the fidelity argument of each); the
//! corresponding [`StagePlan`] presets resolve to:
//!
//! | preset         | KNN            | BSP | tree          | summarize | attractive       | repulsive | layout   |
//! |----------------|----------------|-----|---------------|-----------|------------------|-----------|----------|
//! | `SklearnLike`  | blocked, par   | seq | baseline, seq | seq       | scalar, seq      | BH, seq   | original |
//! | `MulticoreLike`| VP-tree, par   | seq | baseline, seq | seq       | scalar, par      | BH, par   | original |
//! | `Daal4pyLike`  | blocked, par   | seq | baseline, seq | seq       | scalar, par      | BH, par   | original |
//! | `AccTsne`      | blocked, par   | par | morton, par   | par       | SIMD+prefetch, par| BH SIMD-tiled, par | Z-order |
//! | `FitSne`       | blocked, par   | seq | —             | —         | scalar, par      | FFT interp| any      |
//!
//! `FitSne` defaults to the original layout but composes with either (its
//! scatter/gather is layout-agnostic and never adopts a permutation);
//! [`StagePlan::auto_for`] picks BH or FFT repulsion from the dataset size
//! using the measured crossover ([`plan::FFT_CROSSOVER_N`]).

pub mod persist;
pub mod pipeline;
pub mod plan;
pub mod serve;
pub mod session;
pub mod workspace;

pub use persist::{PersistError, SessionCheckpoint};
pub use pipeline::{run_tsne, run_tsne_custom, run_tsne_with_p, AttractiveEngine, NativeAttractive};
pub use plan::{KnnEngineKind, PlanError, StagePlan, FFT_CROSSOVER_N};
pub use session::{
    Affinities, Convergence, FitError, KnnGraph, MIN_POINTS, ObserverControl, RunOutcome, Snapshot,
    StepError, StepInfo, StopReason, TsneSession,
};
pub use workspace::IterationWorkspace;

pub use crate::gradient::attractive::Variant as AttractiveVariant;

use crate::common::timer::StepTimes;
use crate::common::float::Real;
use crate::gradient::attractive::AttractiveSimd;
use crate::gradient::repulsive::RepulsiveSimd;
use crate::gradient::update::UpdateParams;

pub use crate::gradient::repulsive::RepulsiveVariant;

/// Crate-wide scalar bound: a [`Real`] with SIMD attractive and tile-batched
/// repulsive kernels (`f32` and `f64`).
pub trait Scalar: Real + AttractiveSimd + RepulsiveSimd {}
impl<T: Real + AttractiveSimd + RepulsiveSimd> Scalar for T {}

/// Which published implementation's architecture a run models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Implementation {
    /// scikit-learn `TSNE(method="barnes_hut")`: sequential gradient loop.
    SklearnLike,
    /// Ulyanov's Multicore-TSNE: parallel forces, sequential tree path,
    /// row-at-a-time (VP-tree-ish) KNN.
    MulticoreLike,
    /// daal4py v2021.6 BH t-SNE — the paper's baseline.
    Daal4pyLike,
    /// This paper's contribution.
    AccTsne,
    /// Linderman et al. FIt-SNE (FFT interpolation repulsion).
    FitSne,
}

impl Implementation {
    pub const ALL: [Implementation; 5] = [
        Implementation::SklearnLike,
        Implementation::MulticoreLike,
        Implementation::Daal4pyLike,
        Implementation::AccTsne,
        Implementation::FitSne,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Implementation::SklearnLike => "sklearn",
            Implementation::MulticoreLike => "multicore",
            Implementation::Daal4pyLike => "daal4py",
            Implementation::AccTsne => "acc-t-sne",
            Implementation::FitSne => "fit-sne",
        }
    }

    /// [`FromStr`](std::str::FromStr) without the error payload.
    pub fn from_name(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl std::fmt::Display for Implementation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Implementation {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .iter()
            .copied()
            .find(|i| i.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Self::ALL.iter().map(|i| i.name()).collect();
                format!("unknown implementation '{s}' (expected one of: {})", names.join(", "))
            })
    }
}

/// Memory layout of the per-iteration gradient state (embedding, forces,
/// optimizer state, and the CSR `P` the attractive sweep reads).
///
/// [`Layout::Zorder`] is the paper's cache story taken to its conclusion:
/// `build_morton` already sorts the embedding into Z-order every iteration —
/// the Z-order-persistent loop ([`workspace::IterationWorkspace`]) keeps ALL
/// per-point state in that order, re-adopting the fresh order only when it
/// drifts, so the attractive CSR sweep, the repulsive scatter, and the fused
/// combine+update pass all walk memory in spatial order. Exact-parity
/// contract: both layouts produce the same embedding to FP noise (asserted
/// by the layout-parity proptests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Gradient state stays in the caller's point order (the pre-refactor
    /// behaviour): every kernel gathers/scatters through the permutation.
    Original,
    /// Gradient state lives in the quadtree's Z-order; the embedding is
    /// un-permuted once at the end of the run.
    Zorder,
}

impl Layout {
    pub fn name(self) -> &'static str {
        match self {
            Layout::Original => "original",
            Layout::Zorder => "zorder",
        }
    }

    /// [`FromStr`](std::str::FromStr) without the error payload.
    pub fn from_name(s: &str) -> Option<Self> {
        s.parse().ok()
    }
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Layout {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "original" => Ok(Layout::Original),
            "zorder" | "z-order" => Ok(Layout::Zorder),
            _ => Err(format!("unknown layout '{s}' (expected: original, zorder)")),
        }
    }
}

/// Pipeline configuration (defaults = the paper's experimental setup:
/// sklearn defaults, 1000 iterations, θ = 0.5, perplexity 30).
#[derive(Clone, Copy, Debug)]
pub struct TsneConfig {
    pub perplexity: f64,
    pub theta: f64,
    pub n_iter: usize,
    pub seed: u64,
    /// 0 ⇒ all available cores.
    pub n_threads: usize,
    pub update: UpdateParams,
    /// Record per-step times every iteration (tiny overhead; on by default).
    pub collect_step_times: bool,
    /// Initialize the embedding from the data's top-2 principal components
    /// (sklearn `init="pca"`) instead of N(0, 1e-4) random.
    pub init_pca: bool,
    /// Repulsive kernel override **for the compat wrappers** ([`run_tsne`]
    /// and friends fold it into the plan); `None` uses the preset's default
    /// (SIMD-tiled for [`Implementation::AccTsne`], scalar elsewhere).
    /// Ignored by [`Implementation::FitSne`], whose FFT pipeline replaces the
    /// BH traversal entirely. Sessions built directly read
    /// [`StagePlan::repulsive_variant`] instead — set it there (the checked
    /// [`StagePlan::with_repulsive`] rejects impossible combinations).
    pub repulsive: Option<RepulsiveVariant>,
    /// Gradient-state layout override **for the compat wrappers**; `None`
    /// uses the preset's default (Z-order-persistent for
    /// [`Implementation::AccTsne`], original elsewhere — the A/B knob behind
    /// the layout-parity tests and `BENCH_gradient_loop.json`).
    /// [`Implementation::FitSne`] builds no tree, so a Z-order request there
    /// never adopts a permutation — it runs bit-identical to the original
    /// layout. Sessions built directly read [`StagePlan::layout`] instead
    /// (checked by [`StagePlan::with_layout`]).
    pub layout: Option<Layout>,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            perplexity: 30.0,
            theta: 0.5,
            n_iter: 1000,
            seed: 42,
            n_threads: 0,
            update: UpdateParams::default(),
            collect_step_times: true,
            init_pca: false,
            repulsive: None,
            layout: None,
        }
    }
}

/// Output of a run.
#[derive(Clone, Debug)]
pub struct TsneResult<T: Real> {
    /// Final embedding, interleaved x,y per point (original order).
    pub embedding: Vec<T>,
    /// KL divergence over the sparse-P support with the final BH/FFT Z
    /// (the value sklearn/daal4py report; paper Table 3).
    pub kl_divergence: f64,
    pub step_times: StepTimes,
    pub n_iter: usize,
    pub implementation: Implementation,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn implementation_names_roundtrip() {
        for imp in Implementation::ALL {
            assert_eq!(Implementation::from_name(imp.name()), Some(imp));
            // FromStr/Display agree with name()/from_name()
            assert_eq!(imp.to_string(), imp.name());
            assert_eq!(imp.name().parse::<Implementation>(), Ok(imp));
        }
        assert_eq!(Implementation::from_name("bogus"), None);
        let err = "bogus".parse::<Implementation>().unwrap_err();
        assert!(err.contains("acc-t-sne"), "error lists the choices: {err}");
    }

    #[test]
    fn default_config_matches_paper() {
        let c = TsneConfig::default();
        assert_eq!(c.perplexity, 30.0);
        assert_eq!(c.theta, 0.5);
        assert_eq!(c.n_iter, 1000);
        assert_eq!(c.update.early_exaggeration, 12.0);
        assert_eq!(c.update.exaggeration_iters, 250);
        assert_eq!(c.repulsive, None);
        assert_eq!(c.layout, None);
    }

    #[test]
    fn layout_names_roundtrip() {
        for l in [Layout::Original, Layout::Zorder] {
            assert_eq!(Layout::from_name(l.name()), Some(l));
            assert_eq!(l.to_string(), l.name());
            assert_eq!(l.name().parse::<Layout>(), Ok(l));
        }
        assert_eq!(Layout::from_name("z-order"), Some(Layout::Zorder));
        assert_eq!(Layout::from_name("bogus"), None);
        assert!("bogus".parse::<Layout>().unwrap_err().contains("original"));
    }

    #[test]
    fn repulsive_variant_names_roundtrip() {
        for v in [RepulsiveVariant::Scalar, RepulsiveVariant::SimdTiled] {
            assert_eq!(RepulsiveVariant::from_name(v.name()), Some(v));
            assert_eq!(v.to_string(), v.name());
            assert_eq!(v.name().parse::<RepulsiveVariant>(), Ok(v));
        }
        assert_eq!(RepulsiveVariant::from_name("bogus"), None);
        assert!("bogus".parse::<RepulsiveVariant>().unwrap_err().contains("simd-tiled"));
    }
}
