//! Session-based t-SNE: reusable affinities + a resumable, observable
//! optimizer.
//!
//! The pipeline (paper Fig. 1a) is two phases with very different lifetimes:
//! KNN + BSP + symmetrization run **once**, the gradient loop runs ~1000×.
//! The session API splits them accordingly:
//!
//! - [`KnnGraph`] — the step-1 artifact on its own: exact neighbor lists
//!   plus reuse metadata. KNN dominates the fit wall clock, and the ⌊3u⌋
//!   support of Eq. 2 only shrinks as the perplexity drops, so one graph
//!   built at `k` serves a BSP-only re-fit at every perplexity with
//!   ⌊3u⌋ ≤ k ([`Affinities::from_knn`]) — the multi-perplexity serving
//!   path. Persistable ([`KnnGraph::save`]/[`KnnGraph::load`]).
//! - [`Affinities`] — the fitted KNN→BSP→symmetrize artifact (the sparse CSR
//!   `P` plus its fit metadata). Compute it once, then drive any number of
//!   gradient runs from it with different seeds, layouts, or kernels.
//! - [`TsneSession`] — a resumable optimizer built from
//!   `Affinities + StagePlan + TsneConfig`. It owns the per-iteration
//!   workspace and exposes [`step`](TsneSession::step) (one gradient
//!   iteration), [`run`](TsneSession::run) (a fixed budget), and
//!   [`run_until`](TsneSession::run_until) (sklearn-style convergence
//!   control over the per-iteration gradient norm, which the fused
//!   combine+step sweep materializes for free). An observer hook fires every
//!   N iterations with an **un-permuted** embedding snapshot and the current
//!   KL — for early exit, checkpointing, or streaming visualization.
//!
//! The classic one-shot entry points ([`run_tsne`](super::run_tsne) and
//! friends) are thin compat wrappers over a session and produce bit-identical
//! output (asserted by the parity tests).
//!
//! Knob precedence: a session consumes the *plan's* stage knobs
//! (`layout`, `repulsive_variant`, …); the optional `TsneConfig::{layout,
//! repulsive}` override fields exist for the compat wrappers, which fold them
//! into the plan before the session is built.

use super::persist::{self, PersistError, SessionCheckpoint};
use super::pipeline::{AttractiveEngine, NativeAttractive};
use super::plan::{KnnEngineKind, PlanError, StagePlan};
use super::workspace::IterationWorkspace;
use super::{Layout, Scalar, TsneConfig, TsneResult};
use crate::common::timer::{Step, StepTimes};
use crate::data::io::Fnv1a64;
use crate::fitsne::{fitsne_repulsive_into, FitsneParams, FitsneWorkspace};
use crate::gradient::exact::kl_with_z;
use crate::gradient::repulsive::{repulsive_forces_into, RepulsiveVariant};
use crate::gradient::update::random_init;
use crate::knn::hnsw::{HnswKnn, HnswParams};
use crate::knn::{BruteForceKnn, KnnEngine, NeighborLists};
use crate::parallel::{pool::available_cores, ThreadPool};
use crate::perplexity::{binary_search_perplexity, ParMode};
use crate::quadtree::builder_baseline::build_baseline;
use crate::quadtree::builder_morton::build_morton;
use crate::quadtree::summarize::{summarize_parallel, summarize_sequential};
use crate::sparse::{symmetrize, CsrMatrix};
use std::borrow::Cow;
use std::path::Path;
use std::sync::Arc;

/// Fewest points an affinity fit accepts (below this the ⌊3u⌋ neighbor
/// support and the quadtree degenerate; the historical `assert!(n >= 8)`
/// made public, as the bound behind [`FitError::TooFewPoints`]).
pub const MIN_POINTS: usize = 8;

/// Why an affinity fit (or a KNN-graph build) could not run. Every
/// precondition reachable from the public fitting API —
/// [`Affinities::fit`], [`Affinities::from_knn`], [`Affinities::from_csr`],
/// [`KnnGraph::build`] — maps to a typed variant instead of a panic deep
/// inside the KNN or BSP kernels.
#[derive(Clone, Debug, PartialEq)]
pub enum FitError {
    /// `points.len()` disagrees with `n * d`.
    PointsShape { n: usize, d: usize, len: usize },
    /// Fewer than [`MIN_POINTS`] points.
    TooFewPoints { n: usize, min: usize },
    /// Perplexity is not a finite value ≥ 1.
    InvalidPerplexity { perplexity: f64 },
    /// The neighbor count cannot support this perplexity: BSP needs
    /// `perplexity <= k`. Reached when the ⌊3u⌋ support is clamped by a
    /// small `n` (the perplexity exceeds `n - 1`).
    PerplexityTooLarge { perplexity: f64, k: usize },
    /// KNN needs `1 <= k < n`.
    KOutOfRange { k: usize, n: usize },
    /// Re-fitting at this perplexity needs more neighbors per point than the
    /// [`KnnGraph`] stores — rebuild the graph with a larger `k`.
    GraphTooShallow { needed: usize, k: usize, perplexity: f64 },
    /// A loaded [`KnnGraph`] disagrees with the dataset it is being applied
    /// to (wrong `n`/`d`, or a different data fingerprint).
    GraphMismatch(String),
    /// A [`KnnGraph`]'s engine family is not the one the caller requested —
    /// e.g. an approximate (HNSW) graph where exact neighbor rows were
    /// demanded ([`KnnGraph::require_engine`]).
    GraphEngineMismatch { expected: &'static str, found: String },
    /// An externally supplied CSR failed structural validation.
    InvalidCsr(String),
    /// The input points contain a NaN or infinite coordinate; `row`/`col`
    /// locate the first offender in the n × d row-major layout. Caught at the
    /// fit boundary so a poisoned value never reaches the KNN distances, the
    /// perplexity search, or the quadtree.
    NonFinite { row: usize, col: usize },
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::PointsShape { n, d, len } => write!(
                f,
                "points slice has {len} values, expected n*d = {n}*{d} = {}",
                n.saturating_mul(*d)
            ),
            FitError::TooFewPoints { n, min } => {
                write!(f, "need at least {min} points, have {n}")
            }
            FitError::InvalidPerplexity { perplexity } => {
                write!(f, "perplexity must be a finite value >= 1, got {perplexity}")
            }
            FitError::PerplexityTooLarge { perplexity, k } => write!(
                f,
                "perplexity {perplexity} needs at least {} neighbors per point, have {k} \
                 (reduce the perplexity or use more points)",
                perplexity.ceil() as usize
            ),
            FitError::KOutOfRange { k, n } => {
                write!(f, "neighbor count k = {k} is out of range: KNN needs 1 <= k < n = {n}")
            }
            FitError::GraphTooShallow { needed, k, perplexity } => write!(
                f,
                "re-fitting at perplexity {perplexity} needs floor(3u) = {needed} neighbors \
                 per point, but the KNN graph stores only k = {k} (rebuild it with a larger k)"
            ),
            FitError::GraphMismatch(msg) => write!(f, "KNN graph mismatch: {msg}"),
            FitError::GraphEngineMismatch { expected, found } => write!(
                f,
                "KNN graph engine mismatch: the graph was built by '{found}' but {expected} \
                 neighbor rows were requested (rebuild the graph or change --knn-engine)"
            ),
            FitError::InvalidCsr(msg) => write!(f, "invalid CSR matrix: {msg}"),
            FitError::NonFinite { row, col } => write!(
                f,
                "input contains a non-finite value at point {row}, dimension {col} \
                 (clean the data before fitting)"
            ),
        }
    }
}

impl std::error::Error for FitError {}

impl From<crate::data::DataError> for FitError {
    fn from(e: crate::data::DataError) -> FitError {
        match e {
            crate::data::DataError::Shape { n, d, len } => FitError::PointsShape { n, d, len },
            crate::data::DataError::NonFinite { row, col } => FitError::NonFinite { row, col },
        }
    }
}

/// Index (row, column) of the first non-finite coordinate of an n × d
/// row-major point set, if any. O(n·d), branch-predictable — noise next to
/// the KNN pass it protects.
fn first_non_finite<T: Scalar>(points: &[T], d: usize) -> Option<(usize, usize)> {
    points
        .iter()
        .position(|v| !v.is_finite_r())
        .map(|i| (i / d.max(1), i % d.max(1)))
}

/// Perplexity sanity shared by every fitting entry point. `!(p >= 1.0)`
/// also catches NaN.
fn check_perplexity(perplexity: f64) -> Result<(), FitError> {
    if !perplexity.is_finite() || perplexity < 1.0 {
        return Err(FitError::InvalidPerplexity { perplexity });
    }
    Ok(())
}

/// FNV-1a fingerprint of the raw input points (each coordinate's f64 bit
/// pattern, little-endian). Lets a loaded [`KnnGraph`] be checked against
/// the dataset it is about to serve ([`KnnGraph::verify_source`]) at O(n·d)
/// cost — noise next to the KNN it replaces. Crate-visible: the serving
/// artifact cache ([`crate::tsne::serve`]) keys fitted affinities on the
/// same fingerprint, so a cache hit is exactly "same bytes, same fit".
pub(crate) fn data_fingerprint<T: Scalar>(points: &[T]) -> u64 {
    let mut h = Fnv1a64::new();
    for &v in points {
        h.update(&v.to_f64().to_le_bytes());
    }
    h.finish()
}

/// The persisted step-1 artifact: exact k-nearest-neighbor lists plus the
/// metadata needed to reuse them safely (`n`, `d`, a fingerprint of the
/// input points, the engine that built them).
///
/// KNN dominates the pipeline wall clock — the paper reports its speedups
/// "excl. KNN" for exactly this reason — yet the graph depends only on the
/// data and `k`, not on the perplexity: Eq. 2 consumes the ⌊3u⌋ *nearest*
/// of them, and that support only shrinks as `u` drops. So one graph built
/// at `k` serves a BSP-only re-fit at every perplexity with ⌊3u⌋ ≤ k
/// ([`Affinities::from_knn`]), and [`Self::save`]/[`Self::load`] make the
/// expensive step survive the process. A re-fit from a saved + loaded graph
/// is **bit-identical** to a fresh [`Affinities::fit`] at the same
/// perplexity, plan, and thread count (asserted by the refit parity tests).
#[derive(Clone, Debug)]
pub struct KnnGraph<T: Scalar> {
    knn: NeighborLists<T>,
    d: usize,
    data_fp: u64,
    engine: String,
    times: StepTimes,
}

impl<T: Scalar> KnnGraph<T> {
    /// Run the plan's KNN engine over `points` (n × d, row-major) for `k`
    /// neighbors per point. Validates every precondition up front — the
    /// engines' internal `assert!`s are unreachable from here.
    pub fn build(
        pool: &ThreadPool,
        points: &[T],
        n: usize,
        d: usize,
        k: usize,
        plan: &StagePlan,
    ) -> Result<KnnGraph<T>, FitError> {
        if plan.knn_engine == KnnEngineKind::Hnsw {
            let params = HnswParams { ef_search: plan.ef_search, ..HnswParams::default() };
            return Self::build_approximate(pool, points, n, d, k, &params);
        }
        Self::check_build_inputs(points, n, d, k)?;
        let data_fp = data_fingerprint(points);
        let blocked = BruteForceKnn::default();
        let vp = crate::knn::vptree::VpTreeKnn::default();
        let engine: &dyn KnnEngine<T> = if plan.knn_blocked { &blocked } else { &vp };
        let name = engine.name().to_string();
        let mut times = StepTimes::new();
        let knn = times.time(Step::Knn, || engine.search(pool, points, n, d, k));
        Ok(KnnGraph { knn, d, data_fp, engine: name, times })
    }

    /// Build an **approximate** graph with the HNSW subsystem
    /// ([`crate::knn::hnsw`]) — the million-point path. Same preconditions
    /// and artifact semantics as [`Self::build`]; the engine metadata records
    /// the full parameter set (`hnsw(m=…,efc=…,efs=…,seed=…)`), so a loaded
    /// graph is self-describing and [`Self::require_engine`] can reject an
    /// approximate graph where exact rows were demanded.
    ///
    /// Rows come back sorted ascending-(distance, index) like every exact
    /// engine's, so the ⌊3u⌋-prefix re-fit contract holds **per build**: one
    /// graph built at `k` re-fits BSP-only at every perplexity with
    /// ⌊3u⌋ ≤ k, bit-identically between the in-memory and the saved+loaded
    /// graph. Across *rebuilds* (another seed, other params, different
    /// `ef_search`-vs-`k` coupling) the approximate k-set itself may differ —
    /// that is the documented contrast to the exact engines.
    pub fn build_approximate(
        pool: &ThreadPool,
        points: &[T],
        n: usize,
        d: usize,
        k: usize,
        params: &HnswParams,
    ) -> Result<KnnGraph<T>, FitError> {
        Self::check_build_inputs(points, n, d, k)?;
        let data_fp = data_fingerprint(points);
        let engine = HnswKnn { params: *params };
        let name = format!(
            "hnsw(m={},efc={},efs={},seed={})",
            params.m, params.ef_construction, params.ef_search, params.seed
        );
        let mut times = StepTimes::new();
        let knn = times.time(Step::Knn, || KnnEngine::<T>::search(&engine, pool, points, n, d, k));
        Ok(KnnGraph { knn, d, data_fp, engine: name, times })
    }

    /// Shape/range/finiteness preconditions shared by every build path — the
    /// engines' internal `assert!`s stay unreachable from public code.
    fn check_build_inputs(points: &[T], n: usize, d: usize, k: usize) -> Result<(), FitError> {
        if n.checked_mul(d) != Some(points.len()) {
            return Err(FitError::PointsShape { n, d, len: points.len() });
        }
        if n < MIN_POINTS {
            return Err(FitError::TooFewPoints { n, min: MIN_POINTS });
        }
        if k == 0 || k >= n {
            return Err(FitError::KOutOfRange { k, n });
        }
        if let Some((row, col)) = first_non_finite(points, d) {
            return Err(FitError::NonFinite { row, col });
        }
        Ok(())
    }

    /// [`Self::build`] with the `k` a fresh [`Affinities::fit`] at this
    /// perplexity would use — ⌊3·perplexity⌋, clamped to `1..=n-1` (Eq. 2).
    /// Build at your *largest* sweep perplexity: every smaller one re-fits
    /// from the same graph.
    pub fn build_for_perplexity(
        pool: &ThreadPool,
        points: &[T],
        n: usize,
        d: usize,
        perplexity: f64,
        plan: &StagePlan,
    ) -> Result<KnnGraph<T>, FitError> {
        check_perplexity(perplexity)?;
        // Shape preconditions are build()'s job; only the perplexity-derived
        // ones live here.
        let k = k_for(perplexity, n);
        if perplexity > k as f64 {
            return Err(FitError::PerplexityTooLarge { perplexity, k });
        }
        Self::build(pool, points, n, d, k, plan)
    }

    /// Read a graph written by [`Self::save`]. Hostile inputs — truncation,
    /// bit flips, wrong magic, future versions, the wrong scalar width,
    /// out-of-range or self-loop neighbor rows, non-ascending or non-finite
    /// distances — come back as typed [`PersistError`]s, never panics.
    pub fn load(path: impl AsRef<Path>) -> Result<KnnGraph<T>, PersistError> {
        let (knn, d, data_fp, engine) = persist::read_knn_graph::<T>(path.as_ref())?;
        Ok(KnnGraph { knn, d, data_fp, engine, times: StepTimes::new() })
    }

    /// Write the graph to `path` in the versioned, checksummed binary format
    /// of [`crate::tsne::persist`]. Save → [`Self::load`] → save is
    /// byte-identical; build wall time is not persisted.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), PersistError> {
        self.save_on(&crate::data::io::RealFs, path)
    }

    /// [`Self::save`] on an explicit storage [`Medium`](crate::data::io::Medium)
    /// — the seam the fault-injection suite uses to fail writes at chosen
    /// boundaries.
    pub fn save_on<M: crate::data::io::Medium>(
        &self,
        medium: &M,
        path: impl AsRef<Path>,
    ) -> Result<(), PersistError> {
        persist::write_knn_graph(
            medium,
            path.as_ref(),
            &self.knn,
            self.d,
            self.data_fp,
            &self.engine,
        )
    }

    /// Check a (typically loaded) graph against the dataset it is about to
    /// serve: `n`, `d`, and the FNV-1a fingerprint of the raw points must
    /// all match. O(n·d).
    pub fn verify_source(&self, points: &[T], n: usize, d: usize) -> Result<(), FitError> {
        if self.knn.n != n || self.d != d {
            return Err(FitError::GraphMismatch(format!(
                "graph was built over n = {}, d = {}; the dataset is n = {n}, d = {d}",
                self.knn.n, self.d
            )));
        }
        if n.checked_mul(d) != Some(points.len()) {
            return Err(FitError::PointsShape { n, d, len: points.len() });
        }
        let fp = data_fingerprint(points);
        if fp != self.data_fp {
            return Err(FitError::GraphMismatch(format!(
                "data fingerprint {fp:#018x} does not match the graph's {:#018x} \
                 (the graph was built from different points)",
                self.data_fp
            )));
        }
        Ok(())
    }

    /// Number of points.
    #[inline]
    pub fn n(&self) -> usize {
        self.knn.n
    }

    /// Input dimensionality the graph was built over.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Neighbors stored per point. [`Affinities::from_knn`] serves any
    /// perplexity with ⌊3u⌋ ≤ k.
    #[inline]
    pub fn k(&self) -> usize {
        self.knn.k
    }

    /// Name of the engine that built the graph (`"brute-force-native"`,
    /// `"vp-tree"`, or `"hnsw(m=…,efc=…,efs=…,seed=…)"` with the build
    /// parameters recorded).
    #[inline]
    pub fn engine(&self) -> &str {
        &self.engine
    }

    /// Whether the rows are approximate (built by the HNSW subsystem) rather
    /// than exact — decided from the persisted engine metadata, so it holds
    /// for loaded graphs too.
    #[inline]
    pub fn is_approximate(&self) -> bool {
        self.engine.starts_with("hnsw")
    }

    /// Check that this graph's engine family is the one the caller wants —
    /// the typed guard the CLI runs before serving a loaded graph under
    /// `--knn-engine`: an approximate graph must not silently satisfy a run
    /// that demanded exact rows (or vice versa).
    pub fn require_engine(&self, kind: KnnEngineKind) -> Result<(), FitError> {
        let ok = match kind {
            KnnEngineKind::Hnsw => self.is_approximate(),
            KnnEngineKind::Exact => !self.is_approximate(),
        };
        if ok {
            return Ok(());
        }
        Err(FitError::GraphEngineMismatch {
            expected: match kind {
                KnnEngineKind::Exact => "exact",
                KnnEngineKind::Hnsw => "approximate (hnsw)",
            },
            found: self.engine.clone(),
        })
    }

    /// FNV-1a fingerprint of the input points (see [`Self::verify_source`]).
    #[inline]
    pub fn data_fingerprint(&self) -> u64 {
        self.data_fp
    }

    /// The neighbor lists themselves (rows ascending by squared distance).
    #[inline]
    pub fn neighbors(&self) -> &NeighborLists<T> {
        &self.knn
    }

    /// KNN wall time of the build (empty for [`Self::load`]).
    #[inline]
    pub fn step_times(&self) -> &StepTimes {
        &self.times
    }
}

/// The fitted affinity artifact: the symmetrized sparse `P` of paper Eq. 2
/// plus its fit metadata. Phase 1 of the pipeline (KNN → binary-search
/// perplexity → symmetrize), computed once and reused across gradient runs —
/// in-process (N concurrent sessions borrow one instance; `Affinities` is
/// `Sync`, asserted below), across processes
/// ([`save`](Self::save)/[`load`](Self::load)), and across owners: the `'p`
/// parameter is the lifetime of a borrowed `P` ([`Self::from_csr_ref`]);
/// fitted or owned artifacts are `Affinities<'static, T>`.
#[derive(Clone, Debug)]
pub struct Affinities<'p, T: Scalar> {
    p: Cow<'p, CsrMatrix<T>>,
    perplexity: f64,
    k: usize,
    times: StepTimes,
}

// Compile-time half of the serve-many-sessions audit: one fitted artifact is
// shared by `&Affinities` across session threads, so it must be Send + Sync
// (the runtime half is the concurrent-sessions bit-identity test).
const _: () = {
    const fn assert_send_sync<S: Send + Sync>() {}
    assert_send_sync::<Affinities<'static, f32>>();
    assert_send_sync::<Affinities<'static, f64>>();
};

/// ⌊3·perplexity⌋ neighbors (Eq. 2), clamped to `1..=n-1`.
fn k_for(perplexity: f64, n: usize) -> usize {
    ((3.0 * perplexity).floor() as usize).clamp(1, n.saturating_sub(1).max(1))
}

impl<T: Scalar> Affinities<'static, T> {
    /// Fit affinities for `points` (n × d, row-major): KNN over ⌊3·perplexity⌋
    /// neighbors with the plan's KNN engine, binary-search perplexity with the
    /// plan's BSP mode, then symmetrization. The KNN/BSP wall time is recorded
    /// in [`step_times`](Self::step_times).
    ///
    /// Equivalent to — and literally implemented as —
    /// [`KnnGraph::build_for_perplexity`] + [`Self::from_knn`], so a graph
    /// persisted from the first half re-fits bit-identically later. Every
    /// hostile shape (wrong `points` length, too few points, a perplexity
    /// that is non-finite, < 1, or larger than the clamped neighbor support)
    /// is a typed [`FitError`], not a panic.
    pub fn fit(
        pool: &ThreadPool,
        points: &[T],
        n: usize,
        d: usize,
        perplexity: f64,
        plan: &StagePlan,
    ) -> Result<Affinities<'static, T>, FitError> {
        // ⌊3u⌋ neighbors (Eq. 2). The blocked engine models daal4py's; the
        // VP-tree models Multicore-TSNE's (vdMaaten's code).
        let graph = KnnGraph::build_for_perplexity(pool, points, n, d, perplexity, plan)?;
        let mut aff = Self::from_knn(pool, &graph, perplexity, plan)?;
        aff.times.merge(graph.step_times());
        Ok(aff)
    }

    /// Re-fit affinities from an existing [`KnnGraph`] — BSP + symmetrize
    /// only, **no KNN**. The graph's rows are ascending under the
    /// (distance, index) total order the engines select with, so the
    /// ⌊3·perplexity⌋-nearest prefix of a `k`-deep row *is* the fresh
    /// ⌊3u⌋-NN result: the output is bit-identical to
    /// [`Self::fit`] at the same perplexity, plan, and thread count, whether
    /// the graph came from [`KnnGraph::build`] or a [`KnnGraph::load`]ed
    /// file. (One caveat: the VP-tree engine's branch-and-bound pruning can
    /// resolve *exact* distance ties at the cut differently between build
    /// depths; the blocked engine — every preset except multicore-like —
    /// scans all candidates and is exactly prefix-stable even under ties.)
    /// Requires ⌊3·perplexity⌋ ≤ [`KnnGraph::k`]
    /// ([`FitError::GraphTooShallow`] otherwise). BSP wall time is charged
    /// to [`step_times`](Self::step_times); KNN time stays with the graph.
    pub fn from_knn(
        pool: &ThreadPool,
        graph: &KnnGraph<T>,
        perplexity: f64,
        plan: &StagePlan,
    ) -> Result<Affinities<'static, T>, FitError> {
        check_perplexity(perplexity)?;
        let n = graph.n();
        if n < MIN_POINTS {
            return Err(FitError::TooFewPoints { n, min: MIN_POINTS });
        }
        let k_use = k_for(perplexity, n);
        if perplexity > k_use as f64 {
            return Err(FitError::PerplexityTooLarge { perplexity, k: k_use });
        }
        if k_use > graph.k() {
            return Err(FitError::GraphTooShallow { needed: k_use, k: graph.k(), perplexity });
        }
        let truncated;
        let knn: &NeighborLists<T> = if k_use == graph.k() {
            &graph.knn
        } else {
            truncated = graph.knn.truncated(k_use);
            &truncated
        };
        // BSP + symmetrization (charged to BSP, as daal4py does).
        let mut times = StepTimes::new();
        let p = times.time(Step::Bsp, || {
            let mode = if plan.bsp_parallel { ParMode::Parallel } else { ParMode::Sequential };
            let cond = binary_search_perplexity(pool, knn, perplexity, mode);
            symmetrize(pool, knn, &cond.p)
        });
        Ok(Affinities { p: Cow::Owned(p), perplexity, k: k_use, times })
    }

    /// Wrap an already-symmetrized CSR `P` (columns in the caller's point
    /// order), taking ownership. Benches isolating the gradient phase and
    /// callers with externally-computed affinities enter here; no KNN/BSP
    /// time is charged. [`Self::from_csr_ref`] is the borrowing sibling.
    ///
    /// Returns [`FitError::InvalidCsr`] if the *structural* CSR invariants
    /// the gradient loop relies on are violated
    /// ([`CsrMatrix::validate_structural`]) — an O(nnz) check, negligible
    /// next to a gradient run, that turns a silently corrupted embedding
    /// into a typed error. Sorted unique columns per row — what
    /// [`Self::fit`] produces — are recommended for gather locality but not
    /// required: the kernels stream row entries in storage order.
    pub fn from_csr(p: CsrMatrix<T>, perplexity: f64) -> Result<Affinities<'static, T>, FitError> {
        check_perplexity(perplexity)?;
        p.validate_structural().map_err(FitError::InvalidCsr)?;
        let k = k_for(perplexity, p.n);
        Ok(Affinities { p: Cow::Owned(p), perplexity, k, times: StepTimes::new() })
    }

    /// Read an artifact written by [`Self::save`]. The loaded instance feeds
    /// sessions whose output is bit-identical to ones fed by the in-memory
    /// fit (every field round-trips exactly, including the f64 bit patterns
    /// of `P`). Hostile inputs — truncation, bit flips, wrong magic, future
    /// versions, the wrong scalar width — come back as typed
    /// [`PersistError`]s, never panics.
    pub fn load(path: impl AsRef<Path>) -> Result<Affinities<'static, T>, PersistError> {
        let (p, perplexity, k) = persist::read_affinities::<T>(path.as_ref())?;
        Ok(Affinities { p: Cow::Owned(p), perplexity, k, times: StepTimes::new() })
    }
}

impl<'p, T: Scalar> Affinities<'p, T> {
    /// Wrap a **borrowed** already-symmetrized CSR `P` — the zero-copy
    /// sibling of [`Affinities::from_csr`] for callers that keep ownership of
    /// `P` (the compat wrapper `run_tsne_with_p` routes through this, so it
    /// no longer clones the caller's matrix). Same structural validation,
    /// same typed-error contract.
    pub fn from_csr_ref(
        p: &'p CsrMatrix<T>,
        perplexity: f64,
    ) -> Result<Affinities<'p, T>, FitError> {
        check_perplexity(perplexity)?;
        p.validate_structural().map_err(FitError::InvalidCsr)?;
        let k = k_for(perplexity, p.n);
        Ok(Affinities { p: Cow::Borrowed(p), perplexity, k, times: StepTimes::new() })
    }

    /// Write the artifact to `path` in the versioned, checksummed binary
    /// format of [`crate::tsne::persist`] (magic + version + endianness +
    /// scalar width + FNV-1a payload checksum). Save → [`Affinities::load`] →
    /// save is byte-identical. Fit wall times are *not* persisted: a loaded
    /// artifact starts with empty [`step_times`](Self::step_times), exactly
    /// like [`Affinities::from_csr`].
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), PersistError> {
        self.save_on(&crate::data::io::RealFs, path)
    }

    /// [`Self::save`] on an explicit storage [`Medium`](crate::data::io::Medium)
    /// — the seam the fault-injection suite uses to fail writes at chosen
    /// boundaries.
    pub fn save_on<M: crate::data::io::Medium>(
        &self,
        medium: &M,
        path: impl AsRef<Path>,
    ) -> Result<(), PersistError> {
        persist::write_affinities(medium, path.as_ref(), self.p(), self.perplexity, self.k)
    }

    /// Number of points.
    #[inline]
    pub fn n(&self) -> usize {
        self.p.n
    }

    /// The symmetrized sparse similarity matrix (original point order).
    #[inline]
    pub fn p(&self) -> &CsrMatrix<T> {
        &self.p
    }

    /// Perplexity the conditionals were calibrated to.
    #[inline]
    pub fn perplexity(&self) -> f64 {
        self.perplexity
    }

    /// Neighbors per point used by the KNN phase (⌊3·perplexity⌋, clamped).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// KNN + BSP wall time of the fit (empty for [`Affinities::from_csr`],
    /// [`Self::from_csr_ref`], and [`Affinities::load`]).
    #[inline]
    pub fn step_times(&self) -> &StepTimes {
        &self.times
    }
}

/// Convergence controls for [`TsneSession::run_until`] — sklearn's stopping
/// rules evaluated on the per-iteration gradient norm (which the fused
/// combine+step sweep already computes; no extra pass, no per-iteration KL).
///
/// Both criteria are checked only after the early-exaggeration phase
/// (`UpdateParams::exaggeration_iters`): the exaggerated objective's gradient
/// says nothing about convergence of the real one.
#[derive(Clone, Copy, Debug)]
pub struct Convergence {
    /// Hard iteration budget (total session iterations, counting any already
    /// stepped).
    pub max_iter: usize,
    /// Stop when the l2 gradient norm falls below this (sklearn
    /// `min_grad_norm`; `0.0` disables).
    pub min_grad_norm: f64,
    /// Stop when the best-seen gradient norm has not improved by at least
    /// 0.1% for this many consecutive iterations (sklearn
    /// `n_iter_without_progress`, applied to the gradient norm; `0` disables).
    pub n_iter_without_progress: usize,
}

impl Default for Convergence {
    /// sklearn's defaults: 1000 iterations, `min_grad_norm = 1e-7`,
    /// `n_iter_without_progress = 300`.
    fn default() -> Self {
        Convergence {
            max_iter: 1000,
            min_grad_norm: 1e-7,
            n_iter_without_progress: 300,
        }
    }
}

/// Why a [`TsneSession::run`]/[`TsneSession::run_until`] call returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The iteration budget was exhausted.
    MaxIter,
    /// The gradient norm fell below `min_grad_norm`.
    GradNorm,
    /// No gradient-norm progress for `n_iter_without_progress` iterations.
    NoProgress,
    /// The observer returned [`ObserverControl::Stop`].
    Observer,
    /// A [`TsneSession::step`] diverged (non-finite Z or gradient norm); the
    /// session was rewound to its last-good state — see [`StepError`].
    Diverged,
}

/// Why a gradient iteration was rejected by [`TsneSession::step`].
///
/// Divergence (an exploding learning rate, a hostile initial embedding, a
/// custom attractive engine emitting garbage) surfaces as a non-finite Z or
/// gradient norm in the fused update sweep. The session detects it **before**
/// the iteration counter advances, rewinds itself to the last-good in-memory
/// checkpoint (captured every [`TsneSession::set_guard_interval`] iterations),
/// and reports what happened — so a serving loop can damp the learning rate
/// and retry instead of dying. The rewound state is bit-identical to
/// [`TsneSession::from_checkpoint`] of the same snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum StepError {
    /// Iteration `iter` produced a non-finite Z and/or gradient norm.
    /// `rewound_to` is the iteration of the restored last-good state, or
    /// `None` if guarding was disabled and the session is left poisoned.
    Diverged { iter: usize, z: f64, grad_norm: f64, rewound_to: Option<usize> },
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StepError::Diverged { iter, z, grad_norm, rewound_to } => {
                write!(
                    f,
                    "gradient iteration {iter} diverged (Z = {z}, |grad| = {grad_norm}); "
                )?;
                match rewound_to {
                    Some(it) => write!(f, "session rewound to iteration {it}"),
                    None => write!(f, "no last-good state to rewind to (guarding disabled)"),
                }
            }
        }
    }
}

impl std::error::Error for StepError {}

/// Outcome of a [`TsneSession::run`]/[`TsneSession::run_until`] call.
#[derive(Clone, Copy, Debug)]
pub struct RunOutcome {
    /// Total iterations the session has performed (across all calls).
    pub n_iter: usize,
    pub reason: StopReason,
}

/// Per-iteration information returned by [`TsneSession::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    /// 0-based index of the iteration that just ran.
    pub iter: usize,
    /// l2 norm of the full KL gradient at this iteration.
    pub grad_norm: f64,
    /// The BH/FFT normalization term Z of this iteration.
    pub z: f64,
}

/// What the observer hook sees: an **un-permuted** embedding snapshot (the
/// caller's original point order, regardless of the internal Z-order layout)
/// plus the current KL divergence and gradient norm.
#[derive(Debug)]
pub struct Snapshot<'s, T: Scalar> {
    /// Iterations completed so far.
    pub iter: usize,
    /// Embedding in original point order, interleaved x,y (valid for the
    /// duration of the callback).
    pub embedding: &'s [T],
    /// KL divergence over the sparse-P support with the current Z.
    pub kl: f64,
    /// l2 gradient norm of the latest iteration.
    pub grad_norm: f64,
}

/// Observer verdict: keep optimizing or stop after this snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObserverControl {
    Continue,
    Stop,
}

type Observer<'a, T> = Box<dyn FnMut(&Snapshot<T>) -> ObserverControl + 'a>;

/// Relative improvement of the best-seen gradient norm below which an
/// iteration does not count as progress (guards `n_iter_without_progress`
/// against FP-noise "improvements" at the plateau).
const PROGRESS_REL_TOL: f64 = 1e-3;

/// Default spacing of the last-good divergence-guard snapshots: a checkpoint
/// capture is three O(n) copies, amortized to noise at this interval next to
/// the O(n log n) tree + force work of each iteration.
const GUARD_EVERY_DEFAULT: usize = 50;

/// How a session holds its parallel pool: exclusively owned (the default —
/// one pool per session, sized from `cfg.n_threads`) or shared with other
/// sessions (`Arc`, the serving path). [`ThreadPool::broadcast`] runs one
/// parallel region at a time, so sessions sharing a pool must have their
/// `step()` calls externally serialized — `tsne::serve`'s round-robin turn
/// scheduler does exactly that. The trajectory depends only on the pool's
/// thread *count*, so a shared pool of `k` threads is bit-identical to an
/// owned pool of `k` threads.
enum PoolRef {
    Owned(ThreadPool),
    Shared(Arc<ThreadPool>),
}

impl PoolRef {
    #[inline]
    fn get(&self) -> &ThreadPool {
        match self {
            PoolRef::Owned(p) => p,
            PoolRef::Shared(p) => p,
        }
    }
}

/// A resumable t-SNE optimizer over fitted [`Affinities`].
///
/// Owns the iteration workspace (embedding, force buffers, optimizer state,
/// and — in the Z-order layout — the permutation and re-indexed `P`) plus its
/// thread pools; borrows the affinities, so one [`Affinities`] instance can
/// drive many sessions. Construction validates the [`StagePlan`] and returns
/// a typed [`PlanError`] for impossible stage combinations.
pub struct TsneSession<'a, T: Scalar> {
    aff: &'a Affinities<'a, T>,
    plan: StagePlan,
    cfg: TsneConfig,
    pool: PoolRef,
    seq_pool: ThreadPool,
    ws: IterationWorkspace<T>,
    times: StepTimes,
    fit_params: FitsneParams,
    fit_ws: FitsneWorkspace,
    iter: usize,
    last_z: T,
    last_grad_norm: f64,
    attractive_override: Option<&'a dyn AttractiveEngine<T>>,
    observer: Option<(usize, Observer<'a, T>)>,
    snapshot_buf: Vec<T>,
    stop_requested: bool,
    guard_every: usize,
    last_good: Option<SessionCheckpoint<T>>,
}

impl<'a, T: Scalar> TsneSession<'a, T> {
    /// Build a session with the standard N(0, 1e-4) random initialization
    /// from `cfg.seed`.
    pub fn new(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
    ) -> Result<TsneSession<'a, T>, PlanError> {
        let y0 = random_init::<T>(aff.n(), cfg.seed);
        Self::with_init(aff, plan, cfg, y0)
    }

    /// Build a session from an explicit initial embedding (interleaved x,y in
    /// the caller's point order; e.g. a scaled PCA projection).
    pub fn with_init(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        y0: Vec<T>,
    ) -> Result<TsneSession<'a, T>, PlanError> {
        let nt = if cfg.n_threads == 0 { available_cores() } else { cfg.n_threads };
        Self::build(aff, plan, cfg, y0, PoolRef::Owned(ThreadPool::new(nt)))
    }

    /// [`Self::new`] on a caller-provided **shared** pool: every parallel
    /// region of this session broadcasts over `pool` instead of a pool of its
    /// own — the serving path, where N concurrent sessions multiplex one pool
    /// sized to the machine rather than spawning N × threads.
    ///
    /// Contract: [`ThreadPool::broadcast`] runs one parallel region at a
    /// time, so `step()` calls of sessions sharing a pool must not run
    /// concurrently (the `tsne::serve` scheduler serializes them into
    /// round-robin turns). `cfg.n_threads` is ignored; the trajectory is
    /// bit-identical to an owned-pool session with
    /// `n_threads = pool.n_threads()`.
    pub fn new_shared(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        pool: Arc<ThreadPool>,
    ) -> Result<TsneSession<'a, T>, PlanError> {
        let y0 = random_init::<T>(aff.n(), cfg.seed);
        Self::with_init_shared(aff, plan, cfg, y0, pool)
    }

    /// [`Self::with_init`] on a shared pool — see [`Self::new_shared`] for
    /// the serialization contract.
    pub fn with_init_shared(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        y0: Vec<T>,
        pool: Arc<ThreadPool>,
    ) -> Result<TsneSession<'a, T>, PlanError> {
        Self::build(aff, plan, cfg, y0, PoolRef::Shared(pool))
    }

    fn build(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        y0: Vec<T>,
        pool: PoolRef,
    ) -> Result<TsneSession<'a, T>, PlanError> {
        plan.validate()?;
        assert_eq!(y0.len(), 2 * aff.n(), "initial embedding must be 2n interleaved x,y");
        // The FFT path never builds a tree, so a Zorder plan simply never
        // adopts a permutation there — layout alone decides the workspace
        // shape on every preset.
        let zorder = plan.layout == Layout::Zorder;
        Ok(TsneSession {
            aff,
            plan,
            cfg,
            pool,
            seq_pool: ThreadPool::new(1),
            ws: IterationWorkspace::new(y0, cfg.update, zorder, plan.adopt_drift_pct),
            times: StepTimes::new(),
            fit_params: FitsneParams::default(),
            fit_ws: FitsneWorkspace::new(),
            iter: 0,
            last_z: T::ONE,
            last_grad_norm: f64::INFINITY,
            attractive_override: None,
            observer: None,
            snapshot_buf: Vec::new(),
            stop_requested: false,
            guard_every: GUARD_EVERY_DEFAULT,
            last_good: None,
        })
    }

    /// Replace the native attractive kernel with a custom engine (the
    /// XLA-offload integration path).
    ///
    /// Layout contract: with [`Layout::Zorder`] the engine is handed the
    /// workspace's **re-indexed** `P` and Z-ordered `y` — the interface
    /// contract (`out[2i..] = F_attr` of row `i` of the given `P`) is
    /// unchanged, but an engine that baked the *original* sparsity pattern
    /// into an AOT artifact must run on a plan with
    /// [`StagePlan::layout`]` = Layout::Original`.
    pub fn set_attractive_engine(&mut self, engine: &'a dyn AttractiveEngine<T>) {
        self.attractive_override = Some(engine);
    }

    /// Install an observer invoked every `every` iterations (clamped to ≥ 1)
    /// with an un-permuted embedding snapshot, the current KL, and the latest
    /// gradient norm. Returning [`ObserverControl::Stop`] makes the next
    /// [`run`](Self::run)/[`run_until`](Self::run_until) call return with
    /// [`StopReason::Observer`].
    pub fn set_observer<F>(&mut self, every: usize, f: F)
    where
        F: FnMut(&Snapshot<T>) -> ObserverControl + 'a,
    {
        self.observer = Some((every.max(1), Box::new(f)));
    }

    /// Iterations performed so far.
    #[inline]
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// l2 gradient norm of the latest iteration (`inf` before the first).
    #[inline]
    pub fn last_grad_norm(&self) -> f64 {
        self.last_grad_norm
    }

    /// Whether the observer requested a stop ([`ObserverControl::Stop`])
    /// since the last [`run`](Self::run)/[`run_until`](Self::run_until) call.
    /// Callers driving the session with bare [`step`](Self::step) should
    /// check this to honor observer stops; `run`/`run_until` clear it on
    /// entry and honor it internally.
    #[inline]
    pub fn stop_requested(&self) -> bool {
        self.stop_requested
    }

    /// The plan this session runs.
    #[inline]
    pub fn plan(&self) -> &StagePlan {
        &self.plan
    }

    /// How many times the FIt-SNE engine has rebuilt its kernel transforms
    /// (0 on tree-based plans). Steady-state FFT iterations at unchanged grid
    /// geometry do not move this counter — the crossover bench reports it as
    /// `fitsne.kernel_rebuilds`.
    #[inline]
    pub fn fitsne_kernel_rebuilds(&self) -> u64 {
        self.fit_ws.kernel_rebuilds()
    }

    /// Set how often the divergence guard refreshes its in-memory last-good
    /// checkpoint (default every 50 iterations; `0` disables guarding, after
    /// which a diverged [`step`](Self::step) cannot rewind and leaves the
    /// session poisoned). Capturing is read-only: it never perturbs the
    /// trajectory.
    pub fn set_guard_interval(&mut self, every: usize) {
        self.guard_every = every;
        if every == 0 {
            self.last_good = None;
        }
    }

    /// Iteration of the current last-good guard snapshot, if one has been
    /// captured.
    #[inline]
    pub fn last_good_iteration(&self) -> Option<usize> {
        self.last_good.as_ref().map(|ck| ck.iter)
    }

    /// Current embedding, un-permuted to the caller's original point order
    /// (a copy; the live state may be in Z-order).
    pub fn embedding(&self) -> Vec<T> {
        let mut out = Vec::new();
        self.ws.copy_original_order_into(&mut out);
        out
    }

    /// KL divergence of the current embedding over the sparse-P support,
    /// using the latest iteration's Z (meaningful after ≥ 1 step).
    pub fn kl(&mut self) -> f64 {
        self.ws.copy_original_order_into(&mut self.snapshot_buf);
        kl_with_z(self.aff.p(), &self.snapshot_buf, self.last_z.to_f64())
    }

    /// Run one gradient iteration: (tree build + summarize + BH repulsive) or
    /// FFT repulsive, attractive over the layout-order `P`, then the fused
    /// combine+descent sweep. Returns the iteration's gradient norm and Z.
    ///
    /// A non-finite Z or gradient norm is divergence: the iteration is
    /// rejected (the counter does not advance), the session rewinds to its
    /// last-good guard checkpoint, and a typed [`StepError`] reports both.
    /// Healthy iterations are bit-identical to what they were before the
    /// guard existed — the check only reads values the fused sweep already
    /// produced.
    pub fn step(&mut self) -> Result<StepInfo, StepError> {
        if self.guard_every > 0
            && (self.last_good.is_none() || self.iter % self.guard_every == 0)
        {
            self.last_good = Some(self.to_checkpoint());
        }
        let iter = self.iter;
        let native_engine = NativeAttractive(self.plan.attractive_variant);
        let Self {
            aff,
            ref plan,
            ref cfg,
            ref pool,
            ref seq_pool,
            ref mut ws,
            ref mut times,
            ref fit_params,
            ref mut fit_ws,
            attractive_override,
            ..
        } = *self;
        let pool: &ThreadPool = pool.get();
        let force_pool: &ThreadPool = if plan.forces_parallel { pool } else { seq_pool };
        let tree_pool: &ThreadPool = if plan.tree_parallel { pool } else { seq_pool };
        let attractive: &dyn AttractiveEngine<T> = match attractive_override {
            Some(e) => e,
            None => &native_engine,
        };
        let p = aff.p();

        let z: T = if plan.fft_repulsion {
            // FIt-SNE path: no tree; the FFT pipeline is the repulsive step.
            // The persistent workspace keeps the kernel transforms and all
            // grid buffers warm across iterations, so the steady-state step
            // is allocation-free like the BH hot loop.
            times.time(Step::Repulsive, || {
                fitsne_repulsive_into(force_pool, &ws.y, fit_params, fit_ws, &mut ws.rep_raw)
            })
        } else {
            // Steps 3–4: quadtree + summarization.
            let mut tree = times.time(Step::TreeBuild, || {
                if plan.morton_tree {
                    build_morton(tree_pool, &ws.y)
                } else {
                    build_baseline(tree_pool, &ws.y)
                }
            });
            // Layout maintenance (Z-order path only): adopt the fresh
            // Z-order when it drifted past the plan's threshold. Charged to
            // TreeBuild — it is the build's permutation being applied.
            times.time(Step::TreeBuild, || ws.maybe_adopt(pool, &mut tree, p));
            times.time(Step::Summarize, || {
                if plan.summarize_parallel {
                    summarize_parallel(pool, &mut tree)
                } else {
                    summarize_sequential(&mut tree)
                }
            });
            // Step 6: repulsive (view materialization charged to this step —
            // it exists only to feed the tiled kernel). In the adopted
            // Z-order layout the scatter through `point_idx` is the identity.
            times.time(Step::Repulsive, || {
                let v = match plan.repulsive_variant {
                    RepulsiveVariant::Scalar => None,
                    RepulsiveVariant::SimdTiled => {
                        ws.view.rebuild_parallel(force_pool, &tree);
                        Some(&ws.view)
                    }
                };
                repulsive_forces_into(
                    force_pool,
                    &tree,
                    v,
                    cfg.theta,
                    plan.repulsive_variant,
                    &mut ws.rep_raw,
                )
            })
        };

        // Step 5: attractive — over the layout-order P once adopted, so the
        // y-gathers walk Z-order neighborhoods instead of random slots.
        let p_iter: &CsrMatrix<T> = match &ws.p_z {
            Some(m) => m,
            None => p,
        };
        times.time(Step::Attractive, || {
            attractive.compute(force_pool, p_iter, &ws.y, &mut ws.attr)
        });

        // Update: ONE fused combine+update sweep (no separate combine pass);
        // the sweep also materializes the squared gradient norm for free.
        let norm_sq = times.time(Step::Update, || {
            ws.opt.fused_combine_step(pool, iter, &ws.attr, &ws.rep_raw, z, &mut ws.y)
        });

        self.last_z = z;
        self.last_grad_norm = norm_sq.to_f64().sqrt();
        let z_f = z.to_f64();
        if !self.last_grad_norm.is_finite() || !z_f.is_finite() {
            let grad_norm = self.last_grad_norm;
            let rewound_to = self.rewind_to_last_good();
            return Err(StepError::Diverged { iter, z: z_f, grad_norm, rewound_to });
        }
        self.iter += 1;
        let snapshot_due = matches!(&self.observer, Some((every, _)) if self.iter % *every == 0);
        if snapshot_due {
            self.emit_snapshot();
        }
        Ok(StepInfo { iter, grad_norm: self.last_grad_norm, z: z_f })
    }

    /// Restore the session to its last-good guard checkpoint, exactly the way
    /// [`Self::from_checkpoint`] would (fresh workspace from the un-permuted
    /// state, then the layout hint replayed) — the rewound trajectory is
    /// bit-identical to a clean restore of the same snapshot. Returns the
    /// restored iteration, or `None` when no guard snapshot exists (the
    /// session then stays poisoned).
    fn rewind_to_last_good(&mut self) -> Option<usize> {
        let ck = self.last_good.clone()?;
        let SessionCheckpoint {
            iter,
            last_z,
            last_grad_norm,
            y,
            velocity,
            gains,
            layout_perm,
            ..
        } = ck;
        let zorder = self.plan.layout == Layout::Zorder;
        self.ws = IterationWorkspace::new(y, self.cfg.update, zorder, self.plan.adopt_drift_pct);
        self.ws.opt.velocity.copy_from_slice(&velocity);
        self.ws.opt.gains.copy_from_slice(&gains);
        self.iter = iter;
        self.last_z = T::from_f64(last_z);
        self.last_grad_norm = last_grad_norm;
        if zorder {
            if let Some(perm) = layout_perm {
                self.ws
                    .adopt_permutation(self.pool.get(), &perm, self.aff.p())
                    .expect("guard checkpoint carries the permutation it was captured with");
            }
        }
        Some(iter)
    }

    /// Run `iters` more iterations (or until the observer requests a stop).
    /// A previous observer stop does not stick: each call starts fresh.
    ///
    /// A diverged step ends the call with [`StopReason::Diverged`] after the
    /// automatic rewind — retrying the identical trajectory would diverge
    /// identically, so the decision (damp the learning rate, re-seed, give
    /// up) goes back to the caller.
    pub fn run(&mut self, iters: usize) -> RunOutcome {
        self.stop_requested = false;
        for _ in 0..iters {
            if self.step().is_err() {
                return RunOutcome { n_iter: self.iter, reason: StopReason::Diverged };
            }
            if self.stop_requested {
                return RunOutcome { n_iter: self.iter, reason: StopReason::Observer };
            }
        }
        RunOutcome { n_iter: self.iter, reason: StopReason::MaxIter }
    }

    /// Run until a convergence criterion fires or `conv.max_iter` total
    /// iterations are reached. Criteria are evaluated on the per-iteration
    /// gradient norm, only after the early-exaggeration phase; see
    /// [`Convergence`].
    ///
    /// The progress bookkeeping (best-seen norm, no-progress streak) is
    /// **per call**: resuming after an early return restarts the
    /// `n_iter_without_progress` window from scratch, while `max_iter` keeps
    /// counting total session iterations.
    pub fn run_until(&mut self, conv: Convergence) -> RunOutcome {
        self.stop_requested = false;
        let mut best = f64::INFINITY;
        let mut since_progress = 0usize;
        while self.iter < conv.max_iter {
            let info = match self.step() {
                Ok(info) => info,
                Err(_) => return RunOutcome { n_iter: self.iter, reason: StopReason::Diverged },
            };
            if self.stop_requested {
                return RunOutcome { n_iter: self.iter, reason: StopReason::Observer };
            }
            // The exaggerated objective's gradient says nothing about
            // convergence of the real one: start checking only after the
            // early-exaggeration phase.
            if self.iter <= self.cfg.update.exaggeration_iters {
                continue;
            }
            if conv.min_grad_norm > 0.0 && info.grad_norm < conv.min_grad_norm {
                return RunOutcome { n_iter: self.iter, reason: StopReason::GradNorm };
            }
            if conv.n_iter_without_progress > 0 {
                if info.grad_norm < best * (1.0 - PROGRESS_REL_TOL) {
                    best = info.grad_norm;
                    since_progress = 0;
                } else {
                    since_progress += 1;
                    if since_progress >= conv.n_iter_without_progress {
                        return RunOutcome { n_iter: self.iter, reason: StopReason::NoProgress };
                    }
                }
            }
        }
        RunOutcome { n_iter: self.iter, reason: StopReason::MaxIter }
    }

    /// Capture the session's optimizer state as an in-memory
    /// [`SessionCheckpoint`]: embedding, velocity, and gains in **un-permuted
    /// original point order**, the iteration counter, and the convergence
    /// scalars (latest Z and gradient norm). The adopted Z-order permutation
    /// rides along as a layout *hint* (see [`SessionCheckpoint::layout_perm`]).
    ///
    /// Not captured (by design): the observer, a custom attractive engine,
    /// and the per-call progress bookkeeping of
    /// [`run_until`](Self::run_until) — the first two are process-local
    /// callbacks the caller re-installs, the last is per-call by its
    /// documented contract.
    pub fn to_checkpoint(&self) -> SessionCheckpoint<T> {
        let mut y = Vec::new();
        let mut velocity = Vec::new();
        let mut gains = Vec::new();
        self.ws.unpermute_pairs_into(&self.ws.y, &mut y);
        self.ws.unpermute_pairs_into(&self.ws.opt.velocity, &mut velocity);
        self.ws.unpermute_pairs_into(&self.ws.opt.gains, &mut gains);
        SessionCheckpoint {
            iter: self.iter,
            last_z: self.last_z.to_f64(),
            last_grad_norm: self.last_grad_norm,
            aff_nnz: self.aff.p().nnz(),
            aff_perplexity: self.aff.perplexity(),
            y,
            velocity,
            gains,
            layout_perm: self.ws.permutation().map(|p| p.to_vec()),
        }
    }

    /// Write a checkpoint file ([`Self::to_checkpoint`] + the versioned,
    /// checksummed format of [`crate::tsne::persist`]). The session is not
    /// perturbed: checkpointing mid-run leaves the trajectory bit-identical.
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> Result<(), PersistError> {
        self.to_checkpoint().save(path)
    }

    /// Rebuild a session from an in-memory checkpoint over the same
    /// affinities. The caller supplies the `plan`/`cfg` the original session
    /// ran with (they are deliberately not persisted — a checkpoint is pure
    /// optimizer state and may be resumed under a different layout or kernel
    /// variant).
    ///
    /// Bit-identity contract: resumed under the **same** plan, config, and
    /// thread count, the continued trajectory — and a final
    /// [`finish`](Self::finish) — matches an uninterrupted run exactly. Under
    /// [`Layout::Zorder`] that exactness comes from replaying the
    /// checkpoint's layout hint so every layout-dependent FP summation order
    /// is reproduced; resuming under a *different* layout is supported and
    /// agrees to FP noise (the layout-parity contract).
    pub fn from_checkpoint(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        ck: SessionCheckpoint<T>,
    ) -> Result<TsneSession<'a, T>, PersistError> {
        Self::from_checkpoint_impl(aff, plan, cfg, ck, None)
    }

    /// [`Self::from_checkpoint`] on a shared pool — the serving path's
    /// resume-after-disconnect. Same validation and bit-identity contract;
    /// same serialization contract as [`Self::new_shared`].
    pub fn from_checkpoint_shared(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        ck: SessionCheckpoint<T>,
        pool: Arc<ThreadPool>,
    ) -> Result<TsneSession<'a, T>, PersistError> {
        Self::from_checkpoint_impl(aff, plan, cfg, ck, Some(pool))
    }

    fn from_checkpoint_impl(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        ck: SessionCheckpoint<T>,
        shared_pool: Option<Arc<ThreadPool>>,
    ) -> Result<TsneSession<'a, T>, PersistError> {
        if ck.y.len() % 2 != 0
            || ck.velocity.len() != ck.y.len()
            || ck.gains.len() != ck.y.len()
        {
            return Err(PersistError::Corrupt(format!(
                "checkpoint state arrays disagree: y {}, velocity {}, gains {}",
                ck.y.len(),
                ck.velocity.len(),
                ck.gains.len()
            )));
        }
        if ck.n() != aff.n() {
            return Err(PersistError::Mismatch(format!(
                "checkpoint holds {} points, affinities hold {}",
                ck.n(),
                aff.n()
            )));
        }
        // Same-n but different fit: the checkpoint's affinity fingerprint
        // (nnz + perplexity) must match, or the optimizer state would be
        // silently continued against the wrong `P`.
        if ck.aff_nnz != aff.p().nnz() || ck.aff_perplexity != aff.perplexity() {
            return Err(PersistError::Mismatch(format!(
                "checkpoint descends from a different fit: nnz {} / perplexity {} \
                 vs the given affinities' nnz {} / perplexity {}",
                ck.aff_nnz,
                ck.aff_perplexity,
                aff.p().nnz(),
                aff.perplexity()
            )));
        }
        let SessionCheckpoint {
            iter,
            last_z,
            last_grad_norm,
            y,
            velocity,
            gains,
            layout_perm,
            ..
        } = ck;
        let mut sess = match shared_pool {
            Some(pool) => Self::with_init_shared(aff, plan, cfg, y, pool)?,
            None => Self::with_init(aff, plan, cfg, y)?,
        };
        sess.ws.opt.velocity.copy_from_slice(&velocity);
        sess.ws.opt.gains.copy_from_slice(&gains);
        sess.iter = iter;
        sess.last_z = T::from_f64(last_z);
        sess.last_grad_norm = last_grad_norm;
        if sess.plan.layout == Layout::Zorder {
            if let Some(perm) = layout_perm {
                let Self { ref pool, ref mut ws, aff, .. } = sess;
                ws.adopt_permutation(pool.get(), &perm, aff.p()).map_err(PersistError::Corrupt)?;
            }
        }
        Ok(sess)
    }

    /// Resume from a checkpoint file written by [`Self::checkpoint`]:
    /// [`SessionCheckpoint::load`] + [`Self::from_checkpoint`]. Typed
    /// [`PersistError`]s for hostile files and for a checkpoint whose point
    /// count disagrees with `aff`.
    pub fn restore(
        aff: &'a Affinities<'a, T>,
        plan: StagePlan,
        cfg: TsneConfig,
        path: impl AsRef<Path>,
    ) -> Result<TsneSession<'a, T>, PersistError> {
        Self::from_checkpoint(aff, plan, cfg, SessionCheckpoint::load(path)?)
    }

    /// Consume the session: un-permute the embedding back to the caller's
    /// point order (the run's single un-permute) and compute the final KL.
    /// `step_times` covers the gradient phase only — the compat wrappers
    /// merge the affinity fit's KNN/BSP times on top.
    pub fn finish(self) -> TsneResult<T> {
        let TsneSession { aff, plan, ws, times, iter, last_z, .. } = self;
        let y = ws.into_original_order();
        let kl = kl_with_z(aff.p(), &y, last_z.to_f64());
        TsneResult {
            embedding: y,
            kl_divergence: kl,
            step_times: times,
            n_iter: iter,
            implementation: plan.preset,
        }
    }

    fn emit_snapshot(&mut self) {
        if let Some((every, mut f)) = self.observer.take() {
            self.ws.copy_original_order_into(&mut self.snapshot_buf);
            let kl = kl_with_z(self.aff.p(), &self.snapshot_buf, self.last_z.to_f64());
            let snap = Snapshot {
                iter: self.iter,
                embedding: &self.snapshot_buf,
                kl,
                grad_norm: self.last_grad_norm,
            };
            if f(&snap) == ObserverControl::Stop {
                self.stop_requested = true;
            }
            self.observer = Some((every, f));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_mixture;
    use crate::tsne::Implementation;

    fn quick_cfg(n_iter: usize) -> TsneConfig {
        TsneConfig {
            perplexity: 10.0,
            n_iter,
            n_threads: 4,
            seed: 7,
            ..TsneConfig::default()
        }
    }

    fn fitted(n: usize, seed: u64) -> (crate::data::Dataset<f64>, Affinities<'static, f64>) {
        let ds = gaussian_mixture::<f64>(n, 8, 4, 8.0, seed);
        let pool = ThreadPool::new(4);
        let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, 10.0, &StagePlan::acc_tsne())
            .expect("valid fit");
        (ds, aff)
    }

    #[test]
    fn affinities_record_fit_metadata() {
        let (ds, aff) = fitted(300, 1);
        assert_eq!(aff.n(), ds.n);
        assert_eq!(aff.perplexity(), 10.0);
        assert_eq!(aff.k(), 30);
        assert!(aff.p().validate().is_ok());
        assert!(aff.step_times().get(Step::Knn) > 0.0);
        assert!(aff.step_times().get(Step::Bsp) > 0.0);
    }

    #[test]
    fn one_affinities_instance_drives_runs_with_different_seeds() {
        let (_ds, aff) = fitted(300, 2);
        let mut kls = Vec::new();
        let mut embeddings = Vec::new();
        for seed in [7u64, 1234] {
            let mut cfg = quick_cfg(80);
            cfg.seed = seed;
            let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
            sess.run(cfg.n_iter);
            let r = sess.finish();
            assert!(r.embedding.iter().all(|v| v.is_finite()), "seed {seed}");
            assert!(r.kl_divergence.is_finite() && r.kl_divergence > 0.0);
            kls.push(r.kl_divergence);
            embeddings.push(r.embedding);
        }
        // different seeds ⇒ genuinely different descents off the same P
        assert_ne!(embeddings[0], embeddings[1]);
        // ... converging to comparable quality
        let rel = (kls[0] - kls[1]).abs() / kls[0].max(kls[1]);
        assert!(rel < 0.5, "seed A {} vs seed B {}", kls[0], kls[1]);
    }

    #[test]
    fn session_is_resumable_and_counts_iterations() {
        let (_ds, aff) = fitted(200, 3);
        let cfg = quick_cfg(30);
        let mut a = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
        a.run(30);
        let ra = a.finish();
        let mut b = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
        b.run(10);
        assert_eq!(b.iterations(), 10);
        for _ in 0..5 {
            b.step().expect("healthy step");
        }
        let out = b.run(15);
        assert_eq!(out.n_iter, 30);
        assert_eq!(out.reason, StopReason::MaxIter);
        let rb = b.finish();
        // chunked stepping is the same trajectory as one run() call
        assert_eq!(ra.embedding, rb.embedding);
        assert_eq!(ra.kl_divergence, rb.kl_divergence);
    }

    #[test]
    fn fit_preconditions_are_typed_errors_not_panics() {
        let pool = ThreadPool::new(2);
        let plan = StagePlan::acc_tsne();
        let pts = vec![0.5f64; 4 * 3];
        // too few points (the old `assert!(n >= 8)`)
        match Affinities::fit(&pool, &pts, 4, 3, 2.0, &plan) {
            Err(FitError::TooFewPoints { n: 4, min }) => assert_eq!(min, MIN_POINTS),
            other => panic!("expected TooFewPoints, got {:?}", other.map(|_| ())),
        }
        // shape mismatch (the old `assert_eq!(points.len(), n * d)`)
        match Affinities::fit(&pool, &pts, 10, 3, 2.0, &plan) {
            Err(FitError::PointsShape { n: 10, d: 3, len: 12 }) => {}
            other => panic!("expected PointsShape, got {:?}", other.map(|_| ())),
        }
        // perplexity > n-1: would have asserted deep inside BSP before
        let pts = vec![0.25f64; 10 * 3];
        match Affinities::fit(&pool, &pts, 10, 3, 30.0, &plan) {
            Err(FitError::PerplexityTooLarge { k: 9, .. }) => {}
            other => panic!("expected PerplexityTooLarge, got {:?}", other.map(|_| ())),
        }
        // non-finite / sub-1 perplexities
        for bad in [f64::NAN, f64::INFINITY, 0.5, -3.0] {
            match Affinities::fit(&pool, &pts, 10, 3, bad, &plan) {
                Err(FitError::InvalidPerplexity { .. }) => {}
                other => panic!("perplexity {bad}: got {:?}", other.map(|_| ())),
            }
        }
        // the error message the garbled assert used to produce is now sane:
        // it names ⌈perplexity⌉ as the neighbor requirement, not perplexity
        // itself twice
        let msg = FitError::PerplexityTooLarge { perplexity: 30.0, k: 9 }.to_string();
        assert!(msg.contains("30 neighbors"), "{msg}");
        assert!(msg.contains("have 9"), "{msg}");
    }

    #[test]
    fn from_csr_rejects_corrupt_csr_with_typed_error() {
        // used to be a panic!("invalid CSR: ...")
        let bad = crate::sparse::CsrMatrix::<f64> {
            n: 3,
            row_ptr: vec![0, 2, 2, 3],
            col: vec![0, 7, 1], // column 7 out of range
            val: vec![0.5, 0.25, 0.25],
        };
        match Affinities::from_csr(bad.clone(), 2.0) {
            Err(FitError::InvalidCsr(msg)) => assert!(msg.contains("column"), "{msg}"),
            other => panic!("expected InvalidCsr, got {:?}", other.map(|_| ())),
        }
        match Affinities::from_csr_ref(&bad, 2.0) {
            Err(FitError::InvalidCsr(_)) => {}
            other => panic!("expected InvalidCsr, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn knn_graph_build_validates_k_range() {
        let pool = ThreadPool::new(2);
        let plan = StagePlan::acc_tsne();
        let pts = vec![0.5f64; 10 * 3];
        // the old `assert!(k < n)` inside the engines, now at the boundary
        for k in [0usize, 10, 11] {
            match KnnGraph::build(&pool, &pts, 10, 3, k, &plan) {
                Err(FitError::KOutOfRange { k: got, n: 10 }) => assert_eq!(got, k),
                other => panic!("k = {k}: expected KOutOfRange, got {:?}", other.map(|_| ())),
            }
        }
        assert!(KnnGraph::build(&pool, &pts, 10, 3, 9, &plan).is_ok());
    }

    #[test]
    fn hnsw_plan_builds_an_approximate_graph_with_param_metadata() {
        let ds = gaussian_mixture::<f64>(200, 6, 3, 6.0, 55);
        let pool = ThreadPool::new(4);
        let plan = StagePlan::acc_tsne()
            .with_knn_engine(KnnEngineKind::Hnsw)
            .unwrap()
            .with_ef_search(80)
            .unwrap();
        let graph = KnnGraph::build(&pool, &ds.points, ds.n, ds.d, 15, &plan).expect("build");
        assert!(graph.is_approximate());
        assert_eq!(graph.engine(), "hnsw(m=16,efc=200,efs=80,seed=24301)");
        assert!(graph.step_times().get(Step::Knn) > 0.0);
        graph.require_engine(KnnEngineKind::Hnsw).expect("hnsw graph serves hnsw");
        match graph.require_engine(KnnEngineKind::Exact) {
            Err(FitError::GraphEngineMismatch { expected: "exact", found }) => {
                assert!(found.starts_with("hnsw("), "{found}")
            }
            other => panic!("expected GraphEngineMismatch, got {:?}", other),
        }
        // the plan dispatch and the direct builder agree bit-for-bit
        let params = HnswParams { ef_search: 80, ..HnswParams::default() };
        let direct = KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 15, &params)
            .expect("build_approximate");
        assert_eq!(direct.neighbors().indices, graph.neighbors().indices);
        assert_eq!(direct.neighbors().distances_sq, graph.neighbors().distances_sq);
        // exact graphs refuse an hnsw demand symmetrically
        let exact = KnnGraph::build(&pool, &ds.points, ds.n, ds.d, 15, &StagePlan::acc_tsne())
            .expect("exact build");
        assert!(!exact.is_approximate());
        exact.require_engine(KnnEngineKind::Exact).expect("exact serves exact");
        assert!(matches!(
            exact.require_engine(KnnEngineKind::Hnsw),
            Err(FitError::GraphEngineMismatch { expected: "approximate (hnsw)", .. })
        ));
    }

    #[test]
    fn hnsw_refit_from_graph_is_bit_identical_and_bsp_only() {
        // The per-build re-fit contract on an approximate graph: one HNSW
        // graph built at k serves every perplexity with ⌊3u⌋ ≤ k, BSP-only,
        // bit-identically to a second from_knn over the same graph.
        let ds = gaussian_mixture::<f64>(250, 7, 4, 7.0, 91);
        let pool = ThreadPool::new(4);
        let plan = StagePlan::acc_tsne().with_knn_engine(KnnEngineKind::Hnsw).unwrap();
        let graph = KnnGraph::build(&pool, &ds.points, ds.n, ds.d, 45, &plan).expect("build");
        for u in [5.0, 10.0, 15.0] {
            let a = Affinities::from_knn(&pool, &graph, u, &plan).expect("refit");
            let b = Affinities::from_knn(&pool, &graph, u, &plan).expect("refit");
            assert_eq!(a.p().val, b.p().val, "u = {u}");
            assert_eq!(a.step_times().get(Step::Knn), 0.0, "re-fit must skip KNN");
        }
        match Affinities::from_knn(&pool, &graph, 20.0, &plan) {
            Err(FitError::GraphTooShallow { needed: 60, k: 45, .. }) => {}
            other => panic!("expected GraphTooShallow, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn refit_from_graph_is_bit_identical_to_fresh_fit() {
        // The tentpole contract, in-memory leg: build the graph at the ⌊3u⌋
        // of a LARGER perplexity, re-fit at a smaller one, and match a fresh
        // fit at that smaller perplexity exactly.
        let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 77);
        let pool = ThreadPool::new(4);
        let plan = StagePlan::acc_tsne();
        let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 20.0, &plan)
            .expect("valid build");
        assert_eq!(graph.k(), 60);
        assert_eq!(graph.engine(), "brute-force-native");
        assert!(graph.step_times().get(Step::Knn) > 0.0);
        graph.verify_source(&ds.points, ds.n, ds.d).expect("same data");
        for u in [5.0, 10.0, 20.0] {
            let refit = Affinities::from_knn(&pool, &graph, u, &plan).expect("u <= k/3");
            let fresh = Affinities::fit(&pool, &ds.points, ds.n, ds.d, u, &plan).expect("fit");
            assert_eq!(refit.k(), fresh.k(), "u = {u}");
            assert_eq!(refit.p().row_ptr, fresh.p().row_ptr, "u = {u}");
            assert_eq!(refit.p().col, fresh.p().col, "u = {u}");
            assert_eq!(refit.p().val, fresh.p().val, "u = {u}");
            assert_eq!(refit.step_times().get(Step::Knn), 0.0, "re-fit must skip KNN");
            assert!(refit.step_times().get(Step::Bsp) > 0.0);
        }
        // a perplexity whose ⌊3u⌋ outgrows the graph is a typed error
        match Affinities::from_knn(&pool, &graph, 25.0, &plan) {
            Err(FitError::GraphTooShallow { needed: 75, k: 60, .. }) => {}
            other => panic!("expected GraphTooShallow, got {:?}", other.map(|_| ())),
        }
        // a graph from different data is caught by the fingerprint
        let other = gaussian_mixture::<f64>(300, 8, 4, 8.0, 78);
        match graph.verify_source(&other.points, other.n, other.d) {
            Err(FitError::GraphMismatch(msg)) => assert!(msg.contains("fingerprint"), "{msg}"),
            other => panic!("expected GraphMismatch, got {other:?}"),
        }
    }

    #[test]
    fn invalid_plan_is_a_typed_err_not_a_panic() {
        let (_ds, aff) = fitted(200, 4);
        let mut plan = StagePlan::fit_sne();
        plan.repulsive_variant = RepulsiveVariant::SimdTiled;
        match TsneSession::new(&aff, plan, quick_cfg(5)) {
            Err(PlanError::FftBhRepulsive) => {}
            other => panic!("expected FftBhRepulsive, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn observer_sees_unpermuted_snapshots_and_can_stop() {
        let (_ds, aff) = fitted(300, 5);
        let cfg = quick_cfg(100);
        // Reference trajectory without an observer.
        let mut plain = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
        for _ in 0..20 {
            plain.step().expect("healthy step");
        }
        let y20 = plain.embedding();
        let n = aff.n();
        let seen = std::cell::RefCell::new(Vec::<(usize, f64, Vec<f64>)>::new());
        let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
        sess.set_observer(10, |snap| {
            assert_eq!(snap.embedding.len(), 2 * n);
            assert!(snap.kl.is_finite() && snap.kl > 0.0);
            assert!(snap.grad_norm.is_finite());
            seen.borrow_mut().push((snap.iter, snap.kl, snap.embedding.to_vec()));
            if snap.iter >= 20 { ObserverControl::Stop } else { ObserverControl::Continue }
        });
        let out = sess.run(100);
        assert_eq!(out.reason, StopReason::Observer);
        assert_eq!(out.n_iter, 20, "stop honored at the snapshot iteration");
        // a later run() is not poisoned by the previous Stop: the flag is
        // cleared on entry and the session resumes where it paused
        let out2 = sess.run(5);
        assert_eq!(out2.reason, StopReason::MaxIter);
        assert_eq!(out2.n_iter, 25);
        drop(sess); // release the observer's borrow of `seen`
        let seen = seen.into_inner();
        assert_eq!(seen.iter().map(|s| s.0).collect::<Vec<_>>(), vec![10, 20]);
        // the iter-20 snapshot matches the observer-free trajectory: the
        // observer gets the real (un-permuted) embedding and does not perturb
        // the optimization
        assert_eq!(seen[1].2, y20);
    }

    #[test]
    fn run_until_respects_the_budget_when_nothing_converges() {
        let (_ds, aff) = fitted(200, 6);
        let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), quick_cfg(0)).unwrap();
        let out = sess.run_until(Convergence {
            max_iter: 25,
            min_grad_norm: 0.0,
            n_iter_without_progress: 0,
        });
        assert_eq!(out.reason, StopReason::MaxIter);
        assert_eq!(out.n_iter, 25);
        assert_eq!(sess.finish().n_iter, 25);
    }

    #[test]
    fn borrowed_and_owned_affinities_feed_bit_identical_sessions() {
        let (_ds, aff) = fitted(250, 40);
        let p = aff.p().clone();
        let cfg = quick_cfg(12);
        fn run(a: &Affinities<'_, f64>, cfg: TsneConfig) -> Vec<f64> {
            let mut sess = TsneSession::new(a, StagePlan::acc_tsne(), cfg).unwrap();
            sess.run(cfg.n_iter);
            sess.finish().embedding
        }
        let owned = Affinities::from_csr(p.clone(), 10.0).expect("valid CSR");
        let borrowed = Affinities::from_csr_ref(&p, 10.0).expect("valid CSR");
        assert_eq!(borrowed.k(), owned.k());
        assert_eq!(run(&owned, cfg), run(&borrowed, cfg));
    }

    #[test]
    fn in_memory_checkpoint_round_trip_is_bit_identical() {
        // checkpoint at k, resume, run to n == uninterrupted n-iteration run,
        // for both layouts, at a fixed thread count.
        for plan in [
            StagePlan::acc_tsne(),
            StagePlan::acc_tsne().with_layout(Layout::Original).unwrap(),
        ] {
            let (_ds, aff) = fitted(300, 41);
            let cfg = quick_cfg(0);
            let mut uninterrupted = TsneSession::new(&aff, plan, cfg).unwrap();
            uninterrupted.run(40);
            let want = uninterrupted.finish();

            let mut first = TsneSession::new(&aff, plan, cfg).unwrap();
            first.run(15);
            let ck = first.to_checkpoint();
            drop(first);
            let mut resumed = TsneSession::from_checkpoint(&aff, plan, cfg, ck).unwrap();
            assert_eq!(resumed.iterations(), 15);
            resumed.run(25);
            let got = resumed.finish();
            assert_eq!(got.embedding, want.embedding, "layout {:?}", plan.layout);
            assert_eq!(got.kl_divergence, want.kl_divergence);
            assert_eq!(got.n_iter, want.n_iter);
        }
    }

    #[test]
    fn checkpoint_taken_under_zorder_restores_under_original_layout() {
        // The checkpoint is layout-free: state is stored un-permuted, so a
        // Z-order checkpoint resumes under the original layout (and vice
        // versa), agreeing to the usual cross-layout FP-noise tolerance.
        let (_ds, aff) = fitted(300, 43);
        let cfg = quick_cfg(0);
        let z_plan = StagePlan::acc_tsne();
        let o_plan = StagePlan::acc_tsne().with_layout(Layout::Original).unwrap();

        let mut z_sess = TsneSession::new(&aff, z_plan, cfg).unwrap();
        z_sess.run(20);
        let ck = z_sess.to_checkpoint();
        assert!(ck.layout_perm.is_some(), "20 early iterations must have adopted a layout");
        drop(z_sess);

        // same-layout resume is the bit-identical reference ...
        let mut same = TsneSession::from_checkpoint(&aff, z_plan, cfg, ck.clone()).unwrap();
        same.run(10);
        let want = same.finish();
        // ... cross-layout resume matches it to FP noise
        let mut crossed = TsneSession::from_checkpoint(&aff, o_plan, cfg, ck).unwrap();
        crossed.run(10);
        let got = crossed.finish();
        for i in 0..want.embedding.len() {
            assert!(
                (want.embedding[i] - got.embedding[i]).abs()
                    < 1e-6 * (1.0 + want.embedding[i].abs()),
                "idx {i}: zorder {} vs original {}",
                want.embedding[i],
                got.embedding[i]
            );
        }
    }

    #[test]
    fn checkpoint_restore_rejects_mismatched_affinities() {
        let (_ds, aff) = fitted(300, 44);
        let (_ds2, aff_small) = fitted(200, 45);
        let cfg = quick_cfg(0);
        let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
        sess.run(3);
        let ck = sess.to_checkpoint();
        match TsneSession::from_checkpoint(&aff_small, StagePlan::acc_tsne(), cfg, ck.clone()) {
            Err(PersistError::Mismatch(_)) => {}
            other => panic!("expected Mismatch, got {:?}", other.map(|_| ())),
        }
        // an invalid plan surfaces as the typed plan error
        let mut bad_plan = StagePlan::fit_sne();
        bad_plan.repulsive_variant = RepulsiveVariant::SimdTiled;
        match TsneSession::from_checkpoint(&aff, bad_plan, cfg, ck) {
            Err(PersistError::Plan(PlanError::FftBhRepulsive)) => {}
            other => panic!("expected Plan(FftBhRepulsive), got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn concurrent_sessions_share_one_affinities_bit_identically() {
        // The serve-many-sessions contract: N threads borrow ONE fitted
        // Affinities (it is Sync — compile-time assert at the top of this
        // module) and each session's output is bit-identical to the same
        // seed's serial run.
        let (_ds, aff) = fitted(300, 46);
        let seeds = [7u64, 11, 1234, 99];
        let serial: Vec<Vec<f64>> = seeds
            .iter()
            .map(|&seed| {
                let mut cfg = quick_cfg(25);
                cfg.seed = seed;
                let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), cfg).unwrap();
                sess.run(cfg.n_iter);
                sess.finish().embedding
            })
            .collect();
        let aff_ref = &aff;
        let concurrent: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = seeds
                .iter()
                .map(|&seed| {
                    s.spawn(move || {
                        let mut cfg = quick_cfg(25);
                        cfg.seed = seed;
                        let mut sess =
                            TsneSession::new(aff_ref, StagePlan::acc_tsne(), cfg).unwrap();
                        sess.run(cfg.n_iter);
                        sess.finish().embedding
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, (a, b)) in serial.iter().zip(&concurrent).enumerate() {
            assert_eq!(a, b, "seed {} diverged under concurrency", seeds[i]);
        }
    }

    #[test]
    fn non_finite_points_are_rejected_at_the_fit_boundary() {
        let pool = ThreadPool::new(2);
        let plan = StagePlan::acc_tsne();
        let mut pts: Vec<f64> = (0..20 * 3).map(|i| (i % 13) as f64 * 0.5).collect();
        pts[3 * 7 + 2] = f64::NAN;
        match Affinities::fit(&pool, &pts, 20, 3, 5.0, &plan) {
            Err(FitError::NonFinite { row: 7, col: 2 }) => {}
            other => panic!("expected NonFinite at (7, 2), got {:?}", other.map(|_| ())),
        }
        pts[3 * 7 + 2] = f64::NEG_INFINITY;
        match KnnGraph::build(&pool, &pts, 20, 3, 5, &plan) {
            Err(FitError::NonFinite { row: 7, col: 2 }) => {}
            other => panic!("expected NonFinite at (7, 2), got {:?}", other.map(|_| ())),
        }
        let msg = FitError::NonFinite { row: 7, col: 2 }.to_string();
        assert!(msg.contains("point 7") && msg.contains("dimension 2"), "{msg}");
        // the clean version of the same data fits
        pts[3 * 7 + 2] = 0.75;
        assert!(KnnGraph::build(&pool, &pts, 20, 3, 5, &plan).is_ok());
    }

    /// Delegates to the native attractive kernel, poisoning the output of one
    /// chosen call with NaN — the deterministic divergence trigger for the
    /// guard/rewind tests.
    struct PoisonEngine {
        native: NativeAttractive,
        poison_at: usize,
        calls: std::cell::Cell<usize>,
    }

    impl PoisonEngine {
        fn new(plan: &StagePlan, poison_at: usize) -> PoisonEngine {
            PoisonEngine {
                native: NativeAttractive(plan.attractive_variant),
                poison_at,
                calls: std::cell::Cell::new(0),
            }
        }
    }

    impl AttractiveEngine<f64> for PoisonEngine {
        fn name(&self) -> &'static str {
            "poison-once"
        }
        fn compute(
            &self,
            pool: &ThreadPool,
            p: &CsrMatrix<f64>,
            y: &[f64],
            out: &mut [f64],
        ) {
            let call = self.calls.get();
            self.calls.set(call + 1);
            self.native.compute(pool, p, y, out);
            if call == self.poison_at {
                for o in out.iter_mut() {
                    *o = f64::NAN;
                }
            }
        }
    }

    #[test]
    fn forced_divergence_rewinds_bit_identically_to_a_clean_restore() {
        let (_ds, aff) = fitted(300, 50);
        let cfg = quick_cfg(0);
        let plan = StagePlan::acc_tsne();

        // Poisoned session: guard every 10 iters, NaN injected on the step
        // at iteration 17 (the engine delegates natively before that, so the
        // trajectory is the healthy one bit for bit).
        let poison = PoisonEngine::new(&plan, 17);
        let mut sess = TsneSession::new(&aff, plan, cfg).unwrap();
        sess.set_guard_interval(10);
        sess.set_attractive_engine(&poison);
        for _ in 0..17 {
            sess.step().expect("healthy step");
        }
        assert_eq!(sess.last_good_iteration(), Some(10));
        match sess.step() {
            Err(StepError::Diverged { iter: 17, rewound_to: Some(10), grad_norm, .. }) => {
                assert!(!grad_norm.is_finite());
            }
            other => panic!("expected Diverged with rewind, got {other:?}"),
        }
        assert_eq!(sess.iterations(), 10, "rewound to the guard snapshot");
        // The poison call is spent: continuing replays the healthy kernel.
        for _ in 0..15 {
            sess.step().expect("healthy after rewind");
        }
        let got = sess.finish();

        // Clean restore of the same iteration-10 state via the public
        // checkpoint path — the rewind must match it bit for bit.
        let mut clean = TsneSession::new(&aff, plan, cfg).unwrap();
        clean.run(10);
        let ck = clean.to_checkpoint();
        drop(clean);
        let mut restored = TsneSession::from_checkpoint(&aff, plan, cfg, ck).unwrap();
        for _ in 0..15 {
            restored.step().expect("healthy step");
        }
        let want = restored.finish();
        assert_eq!(got.embedding, want.embedding);
        assert_eq!(got.kl_divergence, want.kl_divergence);
        assert_eq!(got.n_iter, want.n_iter);
    }

    #[test]
    fn disabled_guard_reports_divergence_without_rewind() {
        let (_ds, aff) = fitted(200, 51);
        let plan = StagePlan::acc_tsne();
        let poison = PoisonEngine::new(&plan, 3);
        let mut sess = TsneSession::new(&aff, plan, quick_cfg(0)).unwrap();
        sess.set_guard_interval(0);
        sess.set_attractive_engine(&poison);
        for _ in 0..3 {
            sess.step().expect("healthy step");
        }
        match sess.step() {
            Err(StepError::Diverged { iter: 3, rewound_to: None, .. }) => {}
            other => panic!("expected Diverged without rewind, got {other:?}"),
        }
        assert_eq!(sess.iterations(), 3, "counter does not advance past divergence");
        let msg = StepError::Diverged {
            iter: 3,
            z: f64::NAN,
            grad_norm: f64::NAN,
            rewound_to: None,
        }
        .to_string();
        assert!(msg.contains("iteration 3") && msg.contains("no last-good"), "{msg}");
    }

    #[test]
    fn run_surfaces_divergence_as_a_stop_reason() {
        let (_ds, aff) = fitted(200, 52);
        let plan = StagePlan::acc_tsne();
        let poison = PoisonEngine::new(&plan, 5);
        let mut sess = TsneSession::new(&aff, plan, quick_cfg(0)).unwrap();
        sess.set_attractive_engine(&poison);
        let out = sess.run(50);
        assert_eq!(out.reason, StopReason::Diverged);
        // default guard captured the initial state at iteration 0
        assert_eq!(out.n_iter, 0);
        assert!(sess.embedding().iter().all(|v| v.is_finite()), "rewound state is clean");
    }

    #[test]
    fn degenerate_inputs_run_the_full_pipeline_without_panics() {
        // All-coincident cloud: every KNN distance is zero, every BSP row
        // takes the uniform fallback, the quadtree is one multi-point leaf —
        // and the whole fit → session → checkpoint path stays finite.
        let pool = ThreadPool::new(4);
        let plan = StagePlan::acc_tsne();
        let n = 64;
        let pts = vec![1.25f64; n * 4];
        let aff = Affinities::fit(&pool, &pts, n, 4, 5.0, &plan).expect("coincident cloud fits");
        assert!(aff.p().val.iter().all(|v| v.is_finite() && *v >= 0.0));
        let mut sess = TsneSession::new(&aff, plan, quick_cfg(0)).unwrap();
        for _ in 0..10 {
            sess.step().expect("finite step");
        }
        let ck = sess.to_checkpoint();
        assert!(ck.y.iter().all(|v| v.is_finite()));
        let r = sess.finish();
        assert!(r.embedding.iter().all(|v| v.is_finite()));
        assert!(r.kl_divergence.is_finite());
    }

    #[test]
    fn fft_plan_runs_through_the_session() {
        let (_ds, aff) = fitted(200, 8);
        let mut sess = TsneSession::new(&aff, StagePlan::fit_sne(), quick_cfg(0)).unwrap();
        sess.run(10);
        assert!(sess.fitsne_kernel_rebuilds() >= 1, "FFT steps build the kernel cache");
        let r = sess.finish();
        assert!(r.embedding.iter().all(|v| v.is_finite()));
        assert_eq!(r.implementation, Implementation::FitSne);
        assert_eq!(r.step_times.get(Step::TreeBuild), 0.0, "FFT path builds no tree");
    }

    #[test]
    fn bh_plans_never_touch_the_fitsne_workspace() {
        let (_ds, aff) = fitted(200, 9);
        let mut sess = TsneSession::new(&aff, StagePlan::acc_tsne(), quick_cfg(0)).unwrap();
        sess.run(5);
        assert_eq!(sess.fitsne_kernel_rebuilds(), 0);
    }

    #[test]
    fn fitsne_zorder_layout_is_bit_identical_to_original() {
        // The lifted restriction: FitSne × Zorder is a valid plan, and since
        // the FFT path never builds a tree (so never adopts a permutation),
        // the session runs bit-identical to the original layout.
        let (_ds, aff) = fitted(250, 53);
        let cfg = quick_cfg(0);
        let zorder_plan = StagePlan::fit_sne().with_layout(Layout::Zorder).expect("lifted");
        let mut a = TsneSession::new(&aff, StagePlan::fit_sne(), cfg).unwrap();
        let mut b = TsneSession::new(&aff, zorder_plan, cfg).unwrap();
        for _ in 0..15 {
            a.step().expect("healthy step");
            b.step().expect("healthy step");
        }
        let (ra, rb) = (a.finish(), b.finish());
        assert_eq!(ra.embedding, rb.embedding);
        assert_eq!(ra.kl_divergence, rb.kl_divergence);
    }

    #[test]
    fn fitsne_divergence_rewinds_under_the_fft_preset() {
        // StepError::Diverged + last-good rewind must work on the FFT path
        // exactly like on the BH path (the guard reads the fused sweep's
        // outputs, which both engines share).
        let (_ds, aff) = fitted(250, 54);
        let cfg = quick_cfg(0);
        let plan = StagePlan::fit_sne();
        let poison = PoisonEngine::new(&plan, 12);
        let mut sess = TsneSession::new(&aff, plan, cfg).unwrap();
        sess.set_guard_interval(10);
        sess.set_attractive_engine(&poison);
        for _ in 0..12 {
            sess.step().expect("healthy step");
        }
        match sess.step() {
            Err(StepError::Diverged { iter: 12, rewound_to: Some(10), .. }) => {}
            other => panic!("expected Diverged with rewind, got {other:?}"),
        }
        assert_eq!(sess.iterations(), 10, "rewound to the guard snapshot");
        for _ in 0..5 {
            sess.step().expect("healthy after rewind");
        }
        assert!(sess.embedding().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fitsne_degenerate_inputs_run_the_full_pipeline() {
        // Coincident cloud under the FFT preset: the span→0 grid is held
        // finite by the min_intervals clamp, and the whole fit → session →
        // checkpoint path stays finite — same guarantee as the BH presets.
        let pool = ThreadPool::new(4);
        let plan = StagePlan::fit_sne();
        let n = 64;
        let pts = vec![1.25f64; n * 4];
        let aff = Affinities::fit(&pool, &pts, n, 4, 5.0, &plan).expect("coincident cloud fits");
        let mut sess = TsneSession::new(&aff, plan, quick_cfg(0)).unwrap();
        for _ in 0..10 {
            sess.step().expect("finite step");
        }
        let ck = sess.to_checkpoint();
        assert!(ck.y.iter().all(|v| v.is_finite()));
        let r = sess.finish();
        assert!(r.embedding.iter().all(|v| v.is_finite()));
        assert!(r.kl_divergence.is_finite());
    }

    #[test]
    fn shared_pool_session_bit_identical_to_owned_pool() {
        // The serving contract: a session broadcasting over a shared pool of
        // k threads must reproduce an owned-pool session with n_threads = k
        // exactly — the trajectory depends only on the thread count.
        let ds = gaussian_mixture::<f64>(300, 8, 4, 4.0, 3);
        let pool = ThreadPool::new(4);
        let plan = StagePlan::acc_tsne();
        let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, 10.0, &plan).expect("fit");
        let cfg = quick_cfg(40);
        let shared = Arc::new(ThreadPool::new(4));
        let mut owned = TsneSession::new(&aff, plan, cfg).expect("owned session");
        let mut shared_sess =
            TsneSession::new_shared(&aff, plan, cfg, Arc::clone(&shared)).expect("shared session");
        owned.run(40);
        shared_sess.run(40);
        // Mid-run checkpoints resume bit-identically on the shared pool too.
        let ck = shared_sess.to_checkpoint();
        let resumed = TsneSession::from_checkpoint_shared(&aff, plan, cfg, ck, shared)
            .expect("resume on shared pool");
        let ya = owned.finish().embedding;
        let yb = shared_sess.finish().embedding;
        let yc = resumed.finish().embedding;
        assert_eq!(ya.len(), yb.len());
        for i in 0..ya.len() {
            assert_eq!(ya[i].to_bits(), yb[i].to_bits(), "shared vs owned at {i}");
            assert_eq!(yb[i].to_bits(), yc[i].to_bits(), "resume parity at {i}");
        }
    }
}
