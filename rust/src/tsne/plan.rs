//! The public stage plan — which engine runs each pipeline stage.
//!
//! [`StagePlan`] is the validated, public successor of the private per-flavor
//! knob table the pipeline used to hide: one field per stage of Figure 1a
//! (KNN engine, BSP parallelism, tree builder, summarize mode, attractive and
//! repulsive kernel variants, gradient-state layout, Z-order adoption
//! threshold). The five [`Implementation`] values are **preset constructors**
//! ([`StagePlan::preset`] and the named forms below); a custom plan is a
//! preset with fields overridden — either through the checked `with_*`
//! setters or by mutating the public fields and calling
//! [`StagePlan::validate`].
//!
//! Invalid stage combinations are rejected *at plan-build time* with a typed
//! [`PlanError`] instead of ad-hoc CLI string checks or mid-run panics:
//! the FIt-SNE FFT pipeline builds no quadtree, so it can neither persist a
//! Z-order layout nor take a Barnes-Hut repulsive-kernel override.
//!
//! The plan is **not** part of a persisted artifact: a saved
//! [`Affinities`](super::Affinities) or session checkpoint is pure data, and
//! the plan is re-supplied at load/restore time (and re-validated — an
//! impossible plan surfaces as
//! [`PersistError::Plan`](super::PersistError::Plan)). That is what lets a
//! checkpoint taken under `layout = Zorder` resume under any layout or
//! kernel variant.

use super::{Implementation, Layout, TsneConfig};
use crate::gradient::attractive::Variant;
use crate::gradient::repulsive::RepulsiveVariant;
use crate::tsne::workspace::ADOPT_DRIFT_PCT;

/// A stage combination that cannot run. Returned by plan construction and
/// validation — never panicked mid-pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// The FIt-SNE FFT pipeline builds no quadtree, so there is no Z-order
    /// to persist: `layout = Zorder` cannot combine with `fft_repulsion`.
    FftLayoutZorder,
    /// The FIt-SNE FFT pipeline replaces the Barnes-Hut traversal entirely,
    /// so a BH repulsive-kernel override cannot combine with `fft_repulsion`.
    FftBhRepulsive,
    /// The Z-order adoption threshold is a percentage; values above 100 are
    /// meaningless (100 already means "never re-adopt").
    AdoptThresholdOutOfRange(usize),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::FftLayoutZorder => write!(
                f,
                "invalid stage plan: the FIt-SNE FFT pipeline builds no quadtree, \
                 so the Z-order layout does not apply (use layout=original)"
            ),
            PlanError::FftBhRepulsive => write!(
                f,
                "invalid stage plan: the FIt-SNE FFT pipeline replaces the \
                 Barnes-Hut traversal, so a BH repulsive-kernel override does not apply"
            ),
            PlanError::AdoptThresholdOutOfRange(pct) => write!(
                f,
                "invalid stage plan: Z-order adoption threshold {pct}% is out of range (0..=100)"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Which engine runs each pipeline stage — the public, validated successor
/// of the pipeline's former private `Flavor` table. See the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StagePlan {
    /// The preset this plan was derived from; labels
    /// [`TsneResult::implementation`](super::TsneResult::implementation).
    pub preset: Implementation,
    /// KNN engine: blocked brute force (daal4py's design) vs the
    /// row-at-a-time VP-tree-ish sweep (Multicore-TSNE's design).
    pub knn_blocked: bool,
    /// Binary-search perplexity: parallel over rows vs sequential.
    pub bsp_parallel: bool,
    /// Quadtree builder: morton (Z-order sort) vs baseline level-wise.
    pub morton_tree: bool,
    /// Tree construction on the full pool vs a single thread.
    pub tree_parallel: bool,
    /// Summarization (center-of-mass pass) parallel vs sequential.
    pub summarize_parallel: bool,
    /// Attractive-force kernel variant (scalar / +prefetch / +SIMD).
    pub attractive_variant: Variant,
    /// Repulsive-force kernel variant (scalar DFS / SIMD-tiled SoA).
    pub repulsive_variant: RepulsiveVariant,
    /// Force sweeps on the full pool vs a single thread.
    pub forces_parallel: bool,
    /// Replace the BH traversal with the FIt-SNE FFT interpolation pipeline.
    pub fft_repulsion: bool,
    /// Gradient-state memory layout (see [`Layout`]).
    pub layout: Layout,
    /// Re-adopt the tree's fresh Z-order when more than this percentage of
    /// points changed slots ([`Layout::Zorder`] only). `0` adopts on any
    /// drift; `100` never re-adopts (the state stays in the caller's order).
    pub adopt_drift_pct: usize,
}

impl Default for StagePlan {
    /// The paper's contribution ([`StagePlan::acc_tsne`]).
    fn default() -> Self {
        Self::acc_tsne()
    }
}

impl StagePlan {
    /// Preset for the given published implementation's architecture.
    pub fn preset(imp: Implementation) -> StagePlan {
        match imp {
            Implementation::SklearnLike => Self::sklearn_like(),
            Implementation::MulticoreLike => Self::multicore_like(),
            Implementation::Daal4pyLike => Self::daal4py_like(),
            Implementation::AccTsne => Self::acc_tsne(),
            Implementation::FitSne => Self::fit_sne(),
        }
    }

    /// scikit-learn `TSNE(method="barnes_hut")`: sequential gradient loop.
    pub fn sklearn_like() -> StagePlan {
        StagePlan {
            preset: Implementation::SklearnLike,
            knn_blocked: true,
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: false,
            fft_repulsion: false,
            layout: Layout::Original,
            adopt_drift_pct: ADOPT_DRIFT_PCT,
        }
    }

    /// Ulyanov's Multicore-TSNE: parallel forces, sequential tree path,
    /// row-at-a-time (VP-tree-ish) KNN.
    pub fn multicore_like() -> StagePlan {
        StagePlan {
            knn_blocked: false, // row-at-a-time distance sweep (VP-tree-ish locality)
            forces_parallel: true,
            preset: Implementation::MulticoreLike,
            ..Self::sklearn_like()
        }
    }

    /// daal4py v2021.6 BH t-SNE — the paper's baseline.
    pub fn daal4py_like() -> StagePlan {
        StagePlan {
            forces_parallel: true,
            preset: Implementation::Daal4pyLike,
            ..Self::sklearn_like()
        }
    }

    /// This paper's contribution: every stage parallel, SIMD kernels,
    /// Z-order-persistent gradient state.
    pub fn acc_tsne() -> StagePlan {
        StagePlan {
            preset: Implementation::AccTsne,
            knn_blocked: true,
            bsp_parallel: true,
            morton_tree: true,
            tree_parallel: true,
            summarize_parallel: true,
            attractive_variant: Variant::Simd,
            repulsive_variant: RepulsiveVariant::SimdTiled,
            forces_parallel: true,
            fft_repulsion: false,
            layout: Layout::Zorder,
            adopt_drift_pct: ADOPT_DRIFT_PCT,
        }
    }

    /// Linderman et al. FIt-SNE: FFT interpolation replaces the BH traversal
    /// (no quadtree, original layout).
    pub fn fit_sne() -> StagePlan {
        StagePlan {
            fft_repulsion: true,
            preset: Implementation::FitSne,
            ..Self::daal4py_like()
        }
    }

    /// Override the gradient-state layout. Rejected on FFT plans — there is
    /// no quadtree, hence no Z-order to persist.
    pub fn with_layout(mut self, layout: Layout) -> Result<StagePlan, PlanError> {
        self.layout = layout;
        self.validate()?;
        Ok(self)
    }

    /// Override the BH repulsive kernel. Rejected on FFT plans — the FFT
    /// pipeline replaces the traversal, so *any* override is a contradiction
    /// (stricter than [`Self::validate`], which only flags non-default
    /// variants a preset could not have produced).
    pub fn with_repulsive(mut self, variant: RepulsiveVariant) -> Result<StagePlan, PlanError> {
        if self.fft_repulsion {
            return Err(PlanError::FftBhRepulsive);
        }
        self.repulsive_variant = variant;
        self.validate()?;
        Ok(self)
    }

    /// Override the attractive-force kernel variant (scalar / +prefetch /
    /// +SIMD). Valid on every preset — the FIt-SNE pipeline replaces only
    /// the *repulsive* traversal; its attractive step is the same CSR sweep.
    pub fn with_attractive(mut self, variant: Variant) -> Result<StagePlan, PlanError> {
        self.attractive_variant = variant;
        self.validate()?;
        Ok(self)
    }

    /// Override the Z-order adoption threshold (percentage of drifted points
    /// above which the workspace re-adopts the tree's fresh order). Only
    /// consulted when the plan's layout is [`Layout::Zorder`]; on other
    /// layouts the field is carried but has no effect (deliberately not an
    /// error, so threshold and layout overrides compose in either order).
    pub fn with_adopt_drift_pct(mut self, pct: usize) -> Result<StagePlan, PlanError> {
        self.adopt_drift_pct = pct;
        self.validate()?;
        Ok(self)
    }

    /// Check the stage combination. Called by
    /// [`TsneSession::new`](super::TsneSession::new); exposed so hand-mutated
    /// plans can be checked eagerly.
    pub fn validate(&self) -> Result<(), PlanError> {
        if self.fft_repulsion && self.layout == Layout::Zorder {
            return Err(PlanError::FftLayoutZorder);
        }
        if self.fft_repulsion && self.repulsive_variant != RepulsiveVariant::Scalar {
            return Err(PlanError::FftBhRepulsive);
        }
        if self.adopt_drift_pct > 100 {
            return Err(PlanError::AdoptThresholdOutOfRange(self.adopt_drift_pct));
        }
        Ok(())
    }

    /// The historical `run_tsne(cfg, imp)` semantics: apply the config's
    /// optional overrides on top of the preset, with FIt-SNE *silently*
    /// ignoring the BH-only knobs (forced original layout, no repulsive
    /// override) — the compat wrappers must not turn previously-working calls
    /// into errors. New code should build plans explicitly instead.
    pub(crate) fn compat(imp: Implementation, cfg: &TsneConfig) -> StagePlan {
        let mut plan = Self::preset(imp);
        if plan.fft_repulsion {
            return plan;
        }
        if let Some(v) = cfg.repulsive {
            plan.repulsive_variant = v;
        }
        if let Some(l) = cfg.layout {
            plan.layout = l;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid_and_labelled() {
        for imp in Implementation::ALL {
            let plan = StagePlan::preset(imp);
            assert_eq!(plan.preset, imp);
            assert!(plan.validate().is_ok(), "{imp:?}");
        }
        assert_eq!(StagePlan::default(), StagePlan::acc_tsne());
    }

    #[test]
    fn fft_rejects_zorder_layout_with_typed_error() {
        let e = StagePlan::fit_sne().with_layout(Layout::Zorder).unwrap_err();
        assert_eq!(e, PlanError::FftLayoutZorder);
        assert!(e.to_string().contains("FIt-SNE"), "{e}");
        // original layout is fine on the FFT plan
        assert!(StagePlan::fit_sne().with_layout(Layout::Original).is_ok());
        // and zorder is fine everywhere else
        assert!(StagePlan::sklearn_like().with_layout(Layout::Zorder).is_ok());
    }

    #[test]
    fn fft_rejects_any_repulsive_override_with_typed_error() {
        for v in [RepulsiveVariant::Scalar, RepulsiveVariant::SimdTiled] {
            let e = StagePlan::fit_sne().with_repulsive(v).unwrap_err();
            assert_eq!(e, PlanError::FftBhRepulsive);
            assert!(e.to_string().contains("Barnes-Hut"), "{e}");
        }
        assert!(StagePlan::acc_tsne().with_repulsive(RepulsiveVariant::Scalar).is_ok());
    }

    #[test]
    fn attractive_override_composes_with_every_preset() {
        for imp in crate::tsne::Implementation::ALL {
            for v in Variant::ALL {
                let plan = StagePlan::preset(imp).with_attractive(v).unwrap();
                assert_eq!(plan.attractive_variant, v, "{imp:?}");
                assert!(plan.validate().is_ok());
            }
        }
    }

    #[test]
    fn adopt_threshold_is_range_checked() {
        assert!(StagePlan::acc_tsne().with_adopt_drift_pct(0).is_ok());
        assert!(StagePlan::acc_tsne().with_adopt_drift_pct(100).is_ok());
        let e = StagePlan::acc_tsne().with_adopt_drift_pct(101).unwrap_err();
        assert_eq!(e, PlanError::AdoptThresholdOutOfRange(101));
        assert!(e.to_string().contains("101"), "{e}");
    }

    #[test]
    fn validate_catches_hand_mutated_plans() {
        let mut plan = StagePlan::fit_sne();
        plan.layout = Layout::Zorder;
        assert_eq!(plan.validate(), Err(PlanError::FftLayoutZorder));
        let mut plan = StagePlan::fit_sne();
        plan.repulsive_variant = RepulsiveVariant::SimdTiled;
        assert_eq!(plan.validate(), Err(PlanError::FftBhRepulsive));
    }

    #[test]
    fn compat_keeps_historical_fitsne_tolerance() {
        // The old run_tsne silently forced original layout for FIt-SNE; the
        // compat resolver must preserve that instead of erroring.
        let cfg = TsneConfig {
            layout: Some(Layout::Zorder),
            repulsive: Some(RepulsiveVariant::SimdTiled),
            ..TsneConfig::default()
        };
        let plan = StagePlan::compat(Implementation::FitSne, &cfg);
        assert_eq!(plan.layout, Layout::Original);
        assert_eq!(plan.repulsive_variant, RepulsiveVariant::Scalar);
        assert!(plan.validate().is_ok());
        // non-FFT presets take the overrides verbatim
        let plan = StagePlan::compat(Implementation::SklearnLike, &cfg);
        assert_eq!(plan.layout, Layout::Zorder);
        assert_eq!(plan.repulsive_variant, RepulsiveVariant::SimdTiled);
    }
}
