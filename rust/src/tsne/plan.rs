//! The public stage plan — which engine runs each pipeline stage.
//!
//! [`StagePlan`] is the validated, public successor of the private per-flavor
//! knob table the pipeline used to hide: one field per stage of Figure 1a
//! (KNN engine, BSP parallelism, tree builder, summarize mode, attractive and
//! repulsive kernel variants, gradient-state layout, Z-order adoption
//! threshold). The five [`Implementation`] values are **preset constructors**
//! ([`StagePlan::preset`] and the named forms below); a custom plan is a
//! preset with fields overridden — either through the checked `with_*`
//! setters or by mutating the public fields and calling
//! [`StagePlan::validate`].
//!
//! Invalid stage combinations are rejected *at plan-build time* with a typed
//! [`PlanError`] instead of ad-hoc CLI string checks or mid-run panics:
//! the FIt-SNE FFT pipeline replaces the Barnes-Hut traversal entirely, so a
//! BH repulsive-kernel override cannot combine with it. (Layouts compose with
//! every engine: the FFT scatter/gather only reads `y[2i..2i+2]`, so it
//! consumes a morton-resident embedding as happily as the original order.)
//!
//! [`StagePlan::auto_for`] picks the repulsive engine from the dataset size
//! using the measured BH↔FIt crossover ([`FFT_CROSSOVER_N`]).
//!
//! The plan is **not** part of a persisted artifact: a saved
//! [`Affinities`](super::Affinities) or session checkpoint is pure data, and
//! the plan is re-supplied at load/restore time (and re-validated — an
//! impossible plan surfaces as
//! [`PersistError::Plan`](super::PersistError::Plan)). That is what lets a
//! checkpoint taken under `layout = Zorder` resume under any layout or
//! kernel variant.

use super::{Implementation, Layout, TsneConfig};
use crate::gradient::attractive::Variant;
use crate::gradient::repulsive::RepulsiveVariant;
use crate::knn::hnsw::DEFAULT_EF_SEARCH;
use crate::tsne::workspace::ADOPT_DRIFT_PCT;

/// Which KNN engine family builds the neighbor graph (pipeline step 1).
///
/// `Exact` covers both exact engines (the `knn_blocked` field picks blocked
/// brute force vs the VP-tree sweep); `Hnsw` switches
/// [`KnnGraph::build`](super::KnnGraph::build) to the approximate
/// [`knn::hnsw`](crate::knn::hnsw) subsystem, whose recall is tuned by
/// [`StagePlan::with_ef_search`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnnEngineKind {
    /// Exact neighbor rows (blocked brute force or VP-tree).
    Exact,
    /// Approximate rows from a deterministic-given-seed HNSW index.
    Hnsw,
}

impl KnnEngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            KnnEngineKind::Exact => "exact",
            KnnEngineKind::Hnsw => "hnsw",
        }
    }
}

impl std::str::FromStr for KnnEngineKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exact" => Ok(KnnEngineKind::Exact),
            "hnsw" => Ok(KnnEngineKind::Hnsw),
            other => Err(format!("unknown KNN engine '{other}' (expected exact|hnsw)")),
        }
    }
}

/// A stage combination that cannot run. Returned by plan construction and
/// validation — never panicked mid-pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// The FIt-SNE FFT pipeline replaces the Barnes-Hut traversal entirely,
    /// so a BH repulsive-kernel override cannot combine with `fft_repulsion`.
    FftBhRepulsive,
    /// The Z-order adoption threshold is a percentage; values above 100 are
    /// meaningless (100 already means "never re-adopt").
    AdoptThresholdOutOfRange(usize),
    /// `ef_search` is the HNSW query beam width; a beam of zero cannot
    /// return any neighbors.
    EfSearchOutOfRange(usize),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::FftBhRepulsive => write!(
                f,
                "invalid stage plan: the FIt-SNE FFT pipeline replaces the \
                 Barnes-Hut traversal, so a BH repulsive-kernel override does not apply"
            ),
            PlanError::AdoptThresholdOutOfRange(pct) => write!(
                f,
                "invalid stage plan: Z-order adoption threshold {pct}% is out of range (0..=100)"
            ),
            PlanError::EfSearchOutOfRange(ef) => write!(
                f,
                "invalid stage plan: ef-search {ef} is out of range (the HNSW query beam \
                 must hold at least one candidate)"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Which engine runs each pipeline stage — the public, validated successor
/// of the pipeline's former private `Flavor` table. See the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StagePlan {
    /// The preset this plan was derived from; labels
    /// [`TsneResult::implementation`](super::TsneResult::implementation).
    pub preset: Implementation,
    /// KNN engine: blocked brute force (daal4py's design) vs the
    /// row-at-a-time VP-tree-ish sweep (Multicore-TSNE's design).
    pub knn_blocked: bool,
    /// Binary-search perplexity: parallel over rows vs sequential.
    pub bsp_parallel: bool,
    /// Quadtree builder: morton (Z-order sort) vs baseline level-wise.
    pub morton_tree: bool,
    /// Tree construction on the full pool vs a single thread.
    pub tree_parallel: bool,
    /// Summarization (center-of-mass pass) parallel vs sequential.
    pub summarize_parallel: bool,
    /// Attractive-force kernel variant (scalar / +prefetch / +SIMD).
    pub attractive_variant: Variant,
    /// Repulsive-force kernel variant (scalar DFS / SIMD-tiled SoA).
    pub repulsive_variant: RepulsiveVariant,
    /// Force sweeps on the full pool vs a single thread.
    pub forces_parallel: bool,
    /// Replace the BH traversal with the FIt-SNE FFT interpolation pipeline.
    pub fft_repulsion: bool,
    /// Gradient-state memory layout (see [`Layout`]).
    pub layout: Layout,
    /// Re-adopt the tree's fresh Z-order when more than this percentage of
    /// points changed slots ([`Layout::Zorder`] only). `0` adopts on any
    /// drift; `100` never re-adopts (the state stays in the caller's order).
    pub adopt_drift_pct: usize,
    /// KNN engine family: exact rows or the approximate HNSW subsystem
    /// ([`KnnGraph::build`](super::KnnGraph::build) dispatches on this).
    pub knn_engine: KnnEngineKind,
    /// HNSW query beam width — the recall-vs-speed knob. Only consulted when
    /// `knn_engine` is [`KnnEngineKind::Hnsw`]; on exact plans the field is
    /// carried but has no effect (deliberately not an error, mirroring
    /// `adopt_drift_pct` on non-Zorder layouts, so the overrides compose).
    pub ef_search: usize,
}

impl Default for StagePlan {
    /// The paper's contribution ([`StagePlan::acc_tsne`]).
    fn default() -> Self {
        Self::acc_tsne()
    }
}

impl StagePlan {
    /// Preset for the given published implementation's architecture.
    pub fn preset(imp: Implementation) -> StagePlan {
        match imp {
            Implementation::SklearnLike => Self::sklearn_like(),
            Implementation::MulticoreLike => Self::multicore_like(),
            Implementation::Daal4pyLike => Self::daal4py_like(),
            Implementation::AccTsne => Self::acc_tsne(),
            Implementation::FitSne => Self::fit_sne(),
        }
    }

    /// scikit-learn `TSNE(method="barnes_hut")`: sequential gradient loop.
    pub fn sklearn_like() -> StagePlan {
        StagePlan {
            preset: Implementation::SklearnLike,
            knn_blocked: true,
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: false,
            fft_repulsion: false,
            layout: Layout::Original,
            adopt_drift_pct: ADOPT_DRIFT_PCT,
            knn_engine: KnnEngineKind::Exact,
            ef_search: DEFAULT_EF_SEARCH,
        }
    }

    /// Ulyanov's Multicore-TSNE: parallel forces, sequential tree path,
    /// row-at-a-time (VP-tree-ish) KNN.
    pub fn multicore_like() -> StagePlan {
        StagePlan {
            knn_blocked: false, // row-at-a-time distance sweep (VP-tree-ish locality)
            forces_parallel: true,
            preset: Implementation::MulticoreLike,
            ..Self::sklearn_like()
        }
    }

    /// daal4py v2021.6 BH t-SNE — the paper's baseline.
    pub fn daal4py_like() -> StagePlan {
        StagePlan {
            forces_parallel: true,
            preset: Implementation::Daal4pyLike,
            ..Self::sklearn_like()
        }
    }

    /// This paper's contribution: every stage parallel, SIMD kernels,
    /// Z-order-persistent gradient state.
    pub fn acc_tsne() -> StagePlan {
        StagePlan {
            preset: Implementation::AccTsne,
            knn_blocked: true,
            bsp_parallel: true,
            morton_tree: true,
            tree_parallel: true,
            summarize_parallel: true,
            attractive_variant: Variant::Simd,
            repulsive_variant: RepulsiveVariant::SimdTiled,
            forces_parallel: true,
            fft_repulsion: false,
            layout: Layout::Zorder,
            adopt_drift_pct: ADOPT_DRIFT_PCT,
            knn_engine: KnnEngineKind::Exact,
            ef_search: DEFAULT_EF_SEARCH,
        }
    }

    /// Linderman et al. FIt-SNE: FFT interpolation replaces the BH traversal
    /// (no quadtree; defaults to the original layout, and composes with
    /// [`Layout::Zorder`] — the scatter/gather is layout-agnostic).
    pub fn fit_sne() -> StagePlan {
        StagePlan {
            fft_repulsion: true,
            preset: Implementation::FitSne,
            ..Self::daal4py_like()
        }
    }

    /// Pick the engines from the dataset size: the full acc-t-SNE parallel
    /// stack, with the BH traversal swapped for the FFT pipeline *and* exact
    /// KNN swapped for the approximate HNSW subsystem once `n` crosses
    /// [`FFT_CROSSOVER_N`] — above it the O(n) interpolation beats the
    /// super-linear tree descend per step, and exact O(n·search) KNN becomes
    /// the dominant wall (approximate rows at default `ef_search` hold ≥0.9
    /// recall@k on the bench workload). Every other stage (BSP, attractive
    /// kernel, Z-order-resident state) stays at the paper's settings.
    pub fn auto_for(n: usize) -> StagePlan {
        if n >= FFT_CROSSOVER_N {
            StagePlan {
                fft_repulsion: true,
                // The FFT pipeline has no BH kernel to tile.
                repulsive_variant: RepulsiveVariant::Scalar,
                preset: Implementation::FitSne,
                knn_engine: KnnEngineKind::Hnsw,
                ..Self::acc_tsne()
            }
        } else {
            Self::acc_tsne()
        }
    }

    /// Override the KNN engine family. Valid on every preset: the neighbor
    /// graph feeds the same CSR affinities regardless of which engine built
    /// the rows.
    pub fn with_knn_engine(mut self, kind: KnnEngineKind) -> Result<StagePlan, PlanError> {
        self.knn_engine = kind;
        self.validate()?;
        Ok(self)
    }

    /// Override the HNSW query beam width (the recall-vs-speed knob). Only
    /// consulted when the plan's KNN engine is [`KnnEngineKind::Hnsw`]; on
    /// exact plans the field is carried but has no effect (deliberately not
    /// an error, so engine and beam overrides compose in either order).
    pub fn with_ef_search(mut self, ef: usize) -> Result<StagePlan, PlanError> {
        self.ef_search = ef;
        self.validate()?;
        Ok(self)
    }

    /// Override the gradient-state layout. Valid on every preset — the FFT
    /// pipeline never adopts a permutation (it builds no tree), so a Z-order
    /// plan there runs bit-identical to the original layout.
    pub fn with_layout(mut self, layout: Layout) -> Result<StagePlan, PlanError> {
        self.layout = layout;
        self.validate()?;
        Ok(self)
    }

    /// Override the BH repulsive kernel. Rejected on FFT plans — the FFT
    /// pipeline replaces the traversal, so *any* override is a contradiction
    /// (stricter than [`Self::validate`], which only flags non-default
    /// variants a preset could not have produced).
    pub fn with_repulsive(mut self, variant: RepulsiveVariant) -> Result<StagePlan, PlanError> {
        if self.fft_repulsion {
            return Err(PlanError::FftBhRepulsive);
        }
        self.repulsive_variant = variant;
        self.validate()?;
        Ok(self)
    }

    /// Override the attractive-force kernel variant (scalar / +prefetch /
    /// +SIMD). Valid on every preset — the FIt-SNE pipeline replaces only
    /// the *repulsive* traversal; its attractive step is the same CSR sweep.
    pub fn with_attractive(mut self, variant: Variant) -> Result<StagePlan, PlanError> {
        self.attractive_variant = variant;
        self.validate()?;
        Ok(self)
    }

    /// Override the Z-order adoption threshold (percentage of drifted points
    /// above which the workspace re-adopts the tree's fresh order). Only
    /// consulted when the plan's layout is [`Layout::Zorder`]; on other
    /// layouts the field is carried but has no effect (deliberately not an
    /// error, so threshold and layout overrides compose in either order).
    pub fn with_adopt_drift_pct(mut self, pct: usize) -> Result<StagePlan, PlanError> {
        self.adopt_drift_pct = pct;
        self.validate()?;
        Ok(self)
    }

    /// Check the stage combination. Called by
    /// [`TsneSession::new`](super::TsneSession::new); exposed so hand-mutated
    /// plans can be checked eagerly.
    pub fn validate(&self) -> Result<(), PlanError> {
        if self.fft_repulsion && self.repulsive_variant != RepulsiveVariant::Scalar {
            return Err(PlanError::FftBhRepulsive);
        }
        if self.adopt_drift_pct > 100 {
            return Err(PlanError::AdoptThresholdOutOfRange(self.adopt_drift_pct));
        }
        if self.ef_search == 0 {
            return Err(PlanError::EfSearchOutOfRange(self.ef_search));
        }
        Ok(())
    }

    /// The historical `run_tsne(cfg, imp)` semantics: apply the config's
    /// optional overrides on top of the preset, with FIt-SNE *silently*
    /// ignoring the repulsive-kernel knob (its pipeline has no BH kernel) —
    /// the compat wrappers must not turn previously-working calls into
    /// errors. The layout override applies to every preset; on the FFT path
    /// it is a no-op permutation, bit-identical to the original order. New
    /// code should build plans explicitly instead.
    pub(crate) fn compat(imp: Implementation, cfg: &TsneConfig) -> StagePlan {
        let mut plan = Self::preset(imp);
        if let Some(v) = cfg.repulsive {
            if !plan.fft_repulsion {
                plan.repulsive_variant = v;
            }
        }
        if let Some(l) = cfg.layout {
            plan.layout = l;
        }
        plan
    }
}

/// Dataset size at which the FIt-SNE FFT pipeline overtakes the SIMD-tiled
/// Barnes-Hut descend per gradient step, as picked by [`StagePlan::auto_for`].
///
/// Provisional constant pending the first committed `BENCH_fitsne.json`
/// baseline: the `crossover.*` keys emitted by `bench_micro_kernels` measure
/// both engines' per-step wall time on 1e4–2e5-point synthetic clouds, and
/// this constant should track the measured intersection once
/// `promote-baselines.yml` commits the numbers from a trusted CI runner.
pub const FFT_CROSSOVER_N: usize = 50_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid_and_labelled() {
        for imp in Implementation::ALL {
            let plan = StagePlan::preset(imp);
            assert_eq!(plan.preset, imp);
            assert!(plan.validate().is_ok(), "{imp:?}");
        }
        assert_eq!(StagePlan::default(), StagePlan::acc_tsne());
    }

    #[test]
    fn every_layout_composes_with_every_preset() {
        // The FFT scatter/gather is layout-agnostic, so Zorder × FitSne is a
        // legal plan (it simply never adopts a permutation).
        for imp in Implementation::ALL {
            for layout in [Layout::Original, Layout::Zorder] {
                let plan = StagePlan::preset(imp).with_layout(layout).unwrap();
                assert_eq!(plan.layout, layout, "{imp:?}");
                assert!(plan.validate().is_ok());
            }
        }
    }

    #[test]
    fn auto_preset_picks_the_engine_from_n() {
        let small = StagePlan::auto_for(FFT_CROSSOVER_N - 1);
        assert!(!small.fft_repulsion);
        assert_eq!(small, StagePlan::acc_tsne());
        let big = StagePlan::auto_for(FFT_CROSSOVER_N);
        assert!(big.fft_repulsion);
        assert_eq!(big.preset, Implementation::FitSne);
        // Every non-repulsive stage keeps the paper's parallel settings,
        // including the Z-order-resident state the lift made legal.
        assert_eq!(big.layout, Layout::Zorder);
        assert!(big.knn_blocked && big.bsp_parallel && big.forces_parallel);
        assert!(big.validate().is_ok());
        // The engine switch applies to step 1 too: exact KNN below the
        // crossover, the approximate HNSW subsystem above it.
        assert_eq!(small.knn_engine, KnnEngineKind::Exact);
        assert_eq!(big.knn_engine, KnnEngineKind::Hnsw);
        assert_eq!(big.ef_search, crate::knn::hnsw::DEFAULT_EF_SEARCH);
    }

    #[test]
    fn knn_engine_and_ef_search_overrides_compose_and_range_check() {
        let plan = StagePlan::acc_tsne()
            .with_knn_engine(KnnEngineKind::Hnsw)
            .unwrap()
            .with_ef_search(128)
            .unwrap();
        assert_eq!(plan.knn_engine, KnnEngineKind::Hnsw);
        assert_eq!(plan.ef_search, 128);
        // ef_search on an exact plan is carried-but-ignored, like
        // adopt_drift_pct on a non-Zorder layout.
        assert!(StagePlan::sklearn_like().with_ef_search(16).is_ok());
        let e = StagePlan::acc_tsne().with_ef_search(0).unwrap_err();
        assert_eq!(e, PlanError::EfSearchOutOfRange(0));
        assert!(e.to_string().contains("ef-search"), "{e}");
        // hand-mutated plans are caught by validate()
        let mut plan = StagePlan::acc_tsne();
        plan.ef_search = 0;
        assert_eq!(plan.validate(), Err(PlanError::EfSearchOutOfRange(0)));
        // the string form round-trips the CLI values
        assert_eq!("exact".parse::<KnnEngineKind>().unwrap(), KnnEngineKind::Exact);
        assert_eq!("hnsw".parse::<KnnEngineKind>().unwrap(), KnnEngineKind::Hnsw);
        assert!("annoy".parse::<KnnEngineKind>().unwrap_err().contains("exact|hnsw"));
        assert_eq!(KnnEngineKind::Hnsw.name(), "hnsw");
    }

    #[test]
    fn fft_rejects_any_repulsive_override_with_typed_error() {
        for v in [RepulsiveVariant::Scalar, RepulsiveVariant::SimdTiled] {
            let e = StagePlan::fit_sne().with_repulsive(v).unwrap_err();
            assert_eq!(e, PlanError::FftBhRepulsive);
            assert!(e.to_string().contains("Barnes-Hut"), "{e}");
        }
        assert!(StagePlan::acc_tsne().with_repulsive(RepulsiveVariant::Scalar).is_ok());
    }

    #[test]
    fn attractive_override_composes_with_every_preset() {
        for imp in crate::tsne::Implementation::ALL {
            for v in Variant::ALL {
                let plan = StagePlan::preset(imp).with_attractive(v).unwrap();
                assert_eq!(plan.attractive_variant, v, "{imp:?}");
                assert!(plan.validate().is_ok());
            }
        }
    }

    #[test]
    fn adopt_threshold_is_range_checked() {
        assert!(StagePlan::acc_tsne().with_adopt_drift_pct(0).is_ok());
        assert!(StagePlan::acc_tsne().with_adopt_drift_pct(100).is_ok());
        let e = StagePlan::acc_tsne().with_adopt_drift_pct(101).unwrap_err();
        assert_eq!(e, PlanError::AdoptThresholdOutOfRange(101));
        assert!(e.to_string().contains("101"), "{e}");
    }

    #[test]
    fn validate_catches_hand_mutated_plans() {
        let mut plan = StagePlan::fit_sne();
        plan.repulsive_variant = RepulsiveVariant::SimdTiled;
        assert_eq!(plan.validate(), Err(PlanError::FftBhRepulsive));
        let mut plan = StagePlan::acc_tsne();
        plan.adopt_drift_pct = 250;
        assert_eq!(plan.validate(), Err(PlanError::AdoptThresholdOutOfRange(250)));
    }

    #[test]
    fn compat_keeps_historical_fitsne_tolerance() {
        // The old run_tsne silently dropped BH-only knobs for FIt-SNE; the
        // repulsive override must still be ignored (no kernel to tile), while
        // the layout override — a no-op permutation on the FFT path — now
        // applies like on every other preset.
        let cfg = TsneConfig {
            layout: Some(Layout::Zorder),
            repulsive: Some(RepulsiveVariant::SimdTiled),
            ..TsneConfig::default()
        };
        let plan = StagePlan::compat(Implementation::FitSne, &cfg);
        assert_eq!(plan.layout, Layout::Zorder);
        assert_eq!(plan.repulsive_variant, RepulsiveVariant::Scalar);
        assert!(plan.validate().is_ok());
        // non-FFT presets take the overrides verbatim
        let plan = StagePlan::compat(Implementation::SklearnLike, &cfg);
        assert_eq!(plan.layout, Layout::Zorder);
        assert_eq!(plan.repulsive_variant, RepulsiveVariant::SimdTiled);
    }
}
