//! Versioned, checksummed, dependency-free persistence for the three
//! long-lived artifacts of the pipeline (no serde on the offline mirror —
//! the formats are hand-rolled over the [`crate::data::io`] primitives):
//!
//! - a [`KnnGraph`](super::KnnGraph) — the exact k-nearest-neighbor lists of
//!   step 1 plus the metadata that makes them safely reusable (n, d, a
//!   fingerprint of the input points, the engine name). KNN dominates the
//!   fit wall clock, and the ⌊3u⌋ support of Eq. 2 only ever *shrinks* as
//!   the perplexity drops — so one persisted graph turns a perplexity sweep
//!   into BSP-only re-fits ([`KnnGraph::save`](super::KnnGraph::save) /
//!   [`KnnGraph::load`](super::KnnGraph::load) /
//!   [`Affinities::from_knn`](super::Affinities::from_knn));
//! - the fitted [`Affinities`](super::Affinities) — the symmetrized CSR `P`
//!   plus its fit metadata. Barnes-Hut-SNE fixes the sparsity pattern of `P`
//!   at fit time, which is exactly what makes the artifact serializable and
//!   reusable across processes, seeds, layouts, and kernel variants
//!   ([`Affinities::save`](super::Affinities::save) /
//!   [`Affinities::load`](super::Affinities::load));
//! - a [`SessionCheckpoint`] — the optimizer state of a
//!   [`TsneSession`](super::TsneSession) (embedding, velocity, gains,
//!   iteration counter, convergence scalars) in **un-permuted original
//!   order**, so a checkpoint taken under the Z-order layout restores under
//!   any layout ([`TsneSession::checkpoint`](super::TsneSession::checkpoint)
//!   / [`TsneSession::restore`](super::TsneSession::restore)).
//!
//! ## File layout
//!
//! All formats share a 28-byte header followed by a format-specific payload:
//!
//! ```text
//! magic[8] | version u32 | endian tag u32 | scalar width u32 | checksum u64
//! ```
//!
//! Every multi-byte field is little-endian on disk regardless of host
//! byte order; the endian tag exists so a corrupt or foreign header is a
//! typed error instead of garbage lengths. The checksum is a 64-bit FNV-1a
//! over the payload bytes exactly as stored (covering `nnz`, `row_ptr`,
//! `col`, `val`, and every metadata field), patched into the header after
//! the payload is streamed out. Writes are atomic: the artifact is staged
//! as a `.tmp` sibling and renamed into place, so a crash mid-save never
//! destroys the previous good file.
//!
//! All writes go through the [`Medium`](crate::data::io::Medium) seam
//! (`save_on` on each artifact type takes an explicit medium; plain `save`
//! uses the real filesystem). The fault-injection suite drives the same
//! codec through media that fail at every write boundary, persist short
//! prefixes, or crash between staging and rename, and proves the previous
//! artifact always survives and a torn file never loads.
//!
//! ## Failure model
//!
//! Loading never panics on hostile input: wrong magic, a future format
//! version, a foreign endian tag, the wrong scalar width (an `f32` file
//! loaded as `f64`), truncation, trailing bytes, payload lengths that
//! disagree with the file size, and checksum mismatches each map to their
//! own [`PersistError`] variant. Payload lengths are validated against the
//! actual file size *before* any allocation, so a corrupt length field
//! cannot trigger an absurd allocation.

use super::plan::PlanError;
use crate::common::float::Real;
use crate::data::io::{
    read_f64_le, read_u32_le, read_u64_le, write_f64_le, write_u32_le, write_u64_le, Fnv1a64,
    Medium, RealFs,
};
use crate::knn::NeighborLists;
use crate::sparse::CsrMatrix;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Current on-disk format version (shared by both formats).
pub const FORMAT_VERSION: u32 = 1;

pub(crate) const AFFINITIES_MAGIC: &[u8; 8] = b"ACTSNEAF";
pub(crate) const CHECKPOINT_MAGIC: &[u8; 8] = b"ACTSNECK";
pub(crate) const KNN_MAGIC: &[u8; 8] = b"ACTSNEKN";
/// Longest engine-name string the KNN-graph format accepts. The field is a
/// short human-readable label; an absurd length is corruption, and bounding
/// it keeps the length-before-allocation guarantee meaningful.
const MAX_ENGINE_NAME: u64 = 256;
const ENDIAN_TAG: u32 = 0x0A0B_0C0D;
const HEADER_LEN: u64 = 28;
const CHECKSUM_OFFSET: u64 = 20;

/// Why a persisted artifact could not be written or read back. Every hostile
/// input maps to a typed variant — loading never panics.
#[derive(Debug)]
pub enum PersistError {
    /// Underlying filesystem error (open/create/read/write).
    Io(std::io::Error),
    /// The file ended before the declared payload did.
    Truncated,
    /// The first 8 bytes are not a known acc-tsne persist magic.
    BadMagic { found: [u8; 8] },
    /// The file was written by a newer format revision than this build reads.
    UnsupportedVersion { found: u32, supported: u32 },
    /// The header's endian tag is not the little-endian marker.
    EndiannessMismatch { found: u32 },
    /// The file stores a different scalar width (e.g. an `f32` artifact
    /// loaded as `Affinities<f64>`).
    ScalarWidthMismatch { found: u32, expected: u32 },
    /// The payload bytes do not hash to the stored checksum.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// The payload is internally inconsistent (lengths that disagree with
    /// the file size, trailing bytes, a CSR that fails structural
    /// validation, a non-bijective layout permutation, …).
    Corrupt(String),
    /// The artifact is valid but disagrees with the live objects it is being
    /// attached to (e.g. a checkpoint whose `n` differs from the affinities).
    Mismatch(String),
    /// The stage plan supplied at restore time failed validation.
    Plan(PlanError),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "io error: {e}"),
            PersistError::Truncated => write!(f, "file is truncated (unexpected end of data)"),
            PersistError::BadMagic { found } => {
                write!(f, "bad magic {found:?}: not an acc-tsne persist file")
            }
            PersistError::UnsupportedVersion { found, supported } => write!(
                f,
                "format version {found} is newer than the supported version {supported}"
            ),
            PersistError::EndiannessMismatch { found } => write!(
                f,
                "endian tag {found:#010x} is not the little-endian marker {ENDIAN_TAG:#010x}"
            ),
            PersistError::ScalarWidthMismatch { found, expected } => write!(
                f,
                "scalar width {found} bytes on disk, expected {expected} \
                 (f32 artifact loaded as f64, or vice versa)"
            ),
            PersistError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ),
            PersistError::Corrupt(msg) => write!(f, "corrupt payload: {msg}"),
            PersistError::Mismatch(msg) => write!(f, "artifact mismatch: {msg}"),
            PersistError::Plan(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PersistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PersistError::Io(e) => Some(e),
            PersistError::Plan(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            PersistError::Truncated
        } else {
            PersistError::Io(e)
        }
    }
}

impl From<PlanError> for PersistError {
    fn from(e: PlanError) -> Self {
        PersistError::Plan(e)
    }
}

/// The serializable optimizer state of a [`TsneSession`](super::TsneSession),
/// captured in **un-permuted original point order** (see
/// [`TsneSession::to_checkpoint`](super::TsneSession::to_checkpoint)).
///
/// `layout_perm` is the adopted Z-order permutation (`slot → original`) at
/// capture time, if any. It is a *layout hint*, not state: the arrays above
/// are always original-order, so a checkpoint restores under any layout;
/// restoring under [`Layout::Zorder`](super::Layout) replays the hint so the
/// resumed session's in-memory layout — and therefore its FP summation order
/// — is bit-identical to the uninterrupted run's.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCheckpoint<T: Real> {
    /// Iterations completed when the checkpoint was taken.
    pub iter: usize,
    /// The BH/FFT normalization term Z of the latest iteration.
    pub last_z: f64,
    /// l2 gradient norm of the latest iteration.
    pub last_grad_norm: f64,
    /// Consistency fingerprint of the affinities this session descended
    /// from: `nnz` of `P` and the fit perplexity. Restore refuses a
    /// same-`n` but different fit (wrong dataset, wrong artifact file,
    /// re-fit at another perplexity) with a typed
    /// [`PersistError::Mismatch`] instead of silently continuing the
    /// optimizer state against the wrong `P`.
    pub aff_nnz: usize,
    /// See [`Self::aff_nnz`].
    pub aff_perplexity: f64,
    /// Embedding, interleaved x,y, original point order.
    pub y: Vec<T>,
    /// Optimizer velocity, interleaved, original point order.
    pub velocity: Vec<T>,
    /// Optimizer gains, interleaved, original point order.
    pub gains: Vec<T>,
    /// Adopted Z-order layout (`perm[slot] = original`), if any.
    pub layout_perm: Option<Vec<u32>>,
}

impl<T: Real> SessionCheckpoint<T> {
    /// Number of points.
    #[inline]
    pub fn n(&self) -> usize {
        self.y.len() / 2
    }

    /// Write the checkpoint to `path` (format: module docs).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), PersistError> {
        self.save_on(&RealFs, path)
    }

    /// [`Self::save`] on an explicit storage [`Medium`] — the seam the
    /// fault-injection suite uses to fail writes at chosen boundaries.
    pub fn save_on<M: Medium>(
        &self,
        medium: &M,
        path: impl AsRef<Path>,
    ) -> Result<(), PersistError> {
        let n = self.n();
        if self.y.len() != 2 * n
            || self.velocity.len() != self.y.len()
            || self.gains.len() != self.y.len()
        {
            return Err(PersistError::Mismatch(format!(
                "checkpoint arrays disagree: y {}, velocity {}, gains {}",
                self.y.len(),
                self.velocity.len(),
                self.gains.len()
            )));
        }
        if let Some(perm) = &self.layout_perm {
            if perm.len() != n {
                return Err(PersistError::Mismatch(format!(
                    "layout_perm has {} entries for n = {n}",
                    perm.len()
                )));
            }
        }
        save_to_medium(medium, path.as_ref(), CHECKPOINT_MAGIC, scalar_width::<T>(), |w| {
            write_u64_le(w, n as u64)?;
            write_u64_le(w, self.iter as u64)?;
            write_f64_le(w, self.last_z)?;
            write_f64_le(w, self.last_grad_norm)?;
            write_u64_le(w, self.aff_nnz as u64)?;
            write_f64_le(w, self.aff_perplexity)?;
            let flags: u64 = if self.layout_perm.is_some() { 1 } else { 0 };
            write_u64_le(w, flags)?;
            for arr in [&self.y, &self.velocity, &self.gains] {
                for &v in arr.iter() {
                    write_scalar(w, v)?;
                }
            }
            if let Some(perm) = &self.layout_perm {
                for &s in perm.iter() {
                    write_u32_le(w, s)?;
                }
            }
            Ok(())
        })
    }

    /// Read a checkpoint written by [`Self::save`]. Typed errors for every
    /// hostile input; see the module docs for the failure model.
    pub fn load(path: impl AsRef<Path>) -> Result<SessionCheckpoint<T>, PersistError> {
        let (mut r, stored, file_len) =
            open_checked(path.as_ref(), CHECKPOINT_MAGIC, scalar_width::<T>())?;
        let n = read_u64_le(&mut r)? as usize;
        let iter = read_u64_le(&mut r)? as usize;
        let last_z = read_f64_le(&mut r)?;
        let last_grad_norm = read_f64_le(&mut r)?;
        let aff_nnz = read_u64_le(&mut r)? as usize;
        let aff_perplexity = read_f64_le(&mut r)?;
        let flags = read_u64_le(&mut r)?;
        if flags > 1 {
            return Err(PersistError::Corrupt(format!("unknown flags {flags:#x}")));
        }
        let has_perm = flags & 1 == 1;
        let w = scalar_width::<T>() as u64;
        let expected = (|| -> Option<u64> {
            let pairs = (n as u64).checked_mul(2)?;
            let state = pairs.checked_mul(w)?.checked_mul(3)?;
            let perm = if has_perm { (n as u64).checked_mul(4)? } else { 0 };
            HEADER_LEN
                .checked_add(56)?
                .checked_add(state)?
                .checked_add(perm)
        })()
        .ok_or_else(|| PersistError::Corrupt("payload length overflows".into()))?;
        check_file_len(expected, file_len)?;

        let mut buf = Vec::new();
        let mut y = Vec::new();
        let mut velocity = Vec::new();
        let mut gains = Vec::new();
        for arr in [&mut y, &mut velocity, &mut gains] {
            read_bytes(&mut r, 2 * n * w as usize, &mut buf)?;
            parse_scalars::<T>(&buf, arr);
        }
        let layout_perm = if has_perm {
            read_bytes(&mut r, n * 4, &mut buf)?;
            let mut perm = Vec::with_capacity(n);
            for c in buf.chunks_exact(4) {
                perm.push(u32::from_le_bytes(c.try_into().unwrap()));
            }
            Some(perm)
        } else {
            None
        };
        finish_checked(&r, stored)?;
        Ok(SessionCheckpoint {
            iter,
            last_z,
            last_grad_norm,
            aff_nnz,
            aff_perplexity,
            y,
            velocity,
            gains,
            layout_perm,
        })
    }
}

/// Write the fitted-affinities artifact: the CSR `P` + fit metadata.
/// Private plumbing for [`Affinities::save`](super::Affinities::save) (the
/// struct's fields live in `session.rs`).
pub(crate) fn write_affinities<T: Real, M: Medium>(
    medium: &M,
    path: &Path,
    p: &CsrMatrix<T>,
    perplexity: f64,
    k: usize,
) -> Result<(), PersistError> {
    save_to_medium(medium, path, AFFINITIES_MAGIC, scalar_width::<T>(), |w| {
        write_u64_le(w, p.n as u64)?;
        write_u64_le(w, k as u64)?;
        write_f64_le(w, perplexity)?;
        write_u64_le(w, p.nnz() as u64)?;
        for &rp in &p.row_ptr {
            write_u64_le(w, rp as u64)?;
        }
        for &c in &p.col {
            write_u32_le(w, c)?;
        }
        for &v in &p.val {
            write_scalar(w, v)?;
        }
        Ok(())
    })
}

/// Read back an affinities artifact: `(P, perplexity, k)`. Private plumbing
/// for [`Affinities::load`](super::Affinities::load).
pub(crate) fn read_affinities<T: Real>(
    path: &Path,
) -> Result<(CsrMatrix<T>, f64, usize), PersistError> {
    let (mut r, stored, file_len) = open_checked(path, AFFINITIES_MAGIC, scalar_width::<T>())?;
    let n = read_u64_le(&mut r)? as usize;
    let k = read_u64_le(&mut r)? as usize;
    let perplexity = read_f64_le(&mut r)?;
    let nnz = read_u64_le(&mut r)? as usize;
    let w = scalar_width::<T>() as u64;
    let expected = (|| -> Option<u64> {
        let row_ptr = (n as u64).checked_add(1)?.checked_mul(8)?;
        let col = (nnz as u64).checked_mul(4)?;
        let val = (nnz as u64).checked_mul(w)?;
        HEADER_LEN
            .checked_add(32)?
            .checked_add(row_ptr)?
            .checked_add(col)?
            .checked_add(val)
    })()
    .ok_or_else(|| PersistError::Corrupt("payload length overflows".into()))?;
    check_file_len(expected, file_len)?;

    let mut buf = Vec::new();
    read_bytes(&mut r, (n + 1) * 8, &mut buf)?;
    let mut row_ptr = Vec::with_capacity(n + 1);
    for c in buf.chunks_exact(8) {
        row_ptr.push(u64::from_le_bytes(c.try_into().unwrap()) as usize);
    }
    read_bytes(&mut r, nnz * 4, &mut buf)?;
    let mut col = Vec::with_capacity(nnz);
    for c in buf.chunks_exact(4) {
        col.push(u32::from_le_bytes(c.try_into().unwrap()));
    }
    read_bytes(&mut r, nnz * w as usize, &mut buf)?;
    let mut val = Vec::with_capacity(nnz);
    parse_scalars::<T>(&buf, &mut val);
    finish_checked(&r, stored)?;

    let p = CsrMatrix { n, row_ptr, col, val };
    p.validate_structural().map_err(PersistError::Corrupt)?;
    Ok((p, perplexity, k))
}

/// Write the KNN-graph artifact: neighbor lists + reuse metadata. Private
/// plumbing for [`KnnGraph::save`](super::KnnGraph::save) (the struct's
/// fields live in `session.rs`).
pub(crate) fn write_knn_graph<T: Real, M: Medium>(
    medium: &M,
    path: &Path,
    knn: &NeighborLists<T>,
    d: usize,
    data_fp: u64,
    engine: &str,
) -> Result<(), PersistError> {
    if engine.len() as u64 > MAX_ENGINE_NAME {
        return Err(PersistError::Mismatch(format!(
            "engine name is {} bytes, the format stores at most {MAX_ENGINE_NAME}",
            engine.len()
        )));
    }
    save_to_medium(medium, path, KNN_MAGIC, scalar_width::<T>(), |w| {
        write_u64_le(w, knn.n as u64)?;
        write_u64_le(w, d as u64)?;
        write_u64_le(w, knn.k as u64)?;
        write_u64_le(w, data_fp)?;
        write_u64_le(w, engine.len() as u64)?;
        w.write_all(engine.as_bytes())?;
        for &i in &knn.indices {
            write_u32_le(w, i)?;
        }
        for &v in &knn.distances_sq {
            write_scalar(w, v)?;
        }
        Ok(())
    })
}

/// Read back a KNN-graph artifact: `(neighbor lists, d, data fingerprint,
/// engine name)`. Private plumbing for
/// [`KnnGraph::load`](super::KnnGraph::load).
pub(crate) fn read_knn_graph<T: Real>(
    path: &Path,
) -> Result<(NeighborLists<T>, usize, u64, String), PersistError> {
    let (mut r, stored, file_len) = open_checked(path, KNN_MAGIC, scalar_width::<T>())?;
    let n = read_u64_le(&mut r)? as usize;
    let d = read_u64_le(&mut r)? as usize;
    let k = read_u64_le(&mut r)? as usize;
    let data_fp = read_u64_le(&mut r)?;
    let engine_len = read_u64_le(&mut r)?;
    if engine_len > MAX_ENGINE_NAME {
        return Err(PersistError::Corrupt(format!(
            "engine-name length {engine_len} exceeds the format limit {MAX_ENGINE_NAME}"
        )));
    }
    let w = scalar_width::<T>() as u64;
    let expected = (|| -> Option<u64> {
        let rows = (n as u64).checked_mul(k as u64)?;
        let idx = rows.checked_mul(4)?;
        let dist = rows.checked_mul(w)?;
        HEADER_LEN
            .checked_add(40)?
            .checked_add(engine_len)?
            .checked_add(idx)?
            .checked_add(dist)
    })()
    .ok_or_else(|| PersistError::Corrupt("payload length overflows".into()))?;
    check_file_len(expected, file_len)?;

    let mut buf = Vec::new();
    read_bytes(&mut r, engine_len as usize, &mut buf)?;
    let engine = std::str::from_utf8(&buf)
        .map_err(|_| PersistError::Corrupt("engine name is not UTF-8".into()))?
        .to_string();
    let nk = n * k;
    read_bytes(&mut r, nk * 4, &mut buf)?;
    let mut indices = Vec::with_capacity(nk);
    for c in buf.chunks_exact(4) {
        indices.push(u32::from_le_bytes(c.try_into().unwrap()));
    }
    read_bytes(&mut r, nk * w as usize, &mut buf)?;
    let mut distances_sq = Vec::with_capacity(nk);
    parse_scalars::<T>(&buf, &mut distances_sq);
    finish_checked(&r, stored)?;

    let knn = NeighborLists { n, k, indices, distances_sq };
    validate_knn_rows(&knn).map_err(PersistError::Corrupt)?;
    Ok((knn, d, data_fp, engine))
}

/// Row invariants of a loaded KNN graph: every neighbor index in range, not
/// the row itself, and unique within the row; squared distances finite,
/// non-negative, and ascending. The ⌊3u⌋ truncation in
/// [`Affinities::from_knn`](super::Affinities::from_knn) relies on ascending
/// rows meaning "the nearest neighbors come first", `sparse::symmetrize`'s
/// merge relies on each row being a *set* of neighbors, and a NaN distance
/// would otherwise flow silently into `P`.
fn validate_knn_rows<T: Real>(knn: &NeighborLists<T>) -> Result<(), String> {
    let mut seen: Vec<u32> = Vec::with_capacity(knn.k);
    for i in 0..knn.n {
        for (j, &c) in knn.neighbors(i).iter().enumerate() {
            if c as usize >= knn.n {
                return Err(format!("row {i} pos {j}: neighbor {c} out of range (n = {})", knn.n));
            }
            if c as usize == i {
                return Err(format!("row {i} lists itself as a neighbor"));
            }
        }
        seen.clear();
        seen.extend_from_slice(knn.neighbors(i));
        seen.sort_unstable();
        if seen.windows(2).any(|p| p[0] == p[1]) {
            return Err(format!("row {i} lists a neighbor more than once"));
        }
        let dr = knn.dists(i);
        if dr.iter().any(|&v| !v.is_finite_r() || v < T::ZERO) {
            return Err(format!("row {i} has a non-finite or negative distance"));
        }
        if dr.windows(2).any(|p| p[0] > p[1]) {
            return Err(format!("row {i} distances are not ascending"));
        }
    }
    Ok(())
}

/// Scalar width in bytes of the on-disk values (4 = f32, 8 = f64).
#[inline]
fn scalar_width<T: Real>() -> u32 {
    std::mem::size_of::<T>() as u32
}

#[inline]
fn write_scalar<T: Real, W: Write>(w: &mut W, v: T) -> std::io::Result<()> {
    if std::mem::size_of::<T>() == 4 {
        w.write_all(&(v.to_f64() as f32).to_le_bytes())
    } else {
        w.write_all(&v.to_f64().to_le_bytes())
    }
}

/// Parse a packed little-endian scalar array into `out` (cleared first).
fn parse_scalars<T: Real>(bytes: &[u8], out: &mut Vec<T>) {
    out.clear();
    if std::mem::size_of::<T>() == 4 {
        out.extend(bytes.chunks_exact(4).map(|c| {
            T::from_f64(f32::from_le_bytes(c.try_into().unwrap()) as f64)
        }));
    } else {
        out.extend(
            bytes
                .chunks_exact(8)
                .map(|c| T::from_f64(f64::from_le_bytes(c.try_into().unwrap()))),
        );
    }
}

/// `Write` adapter that feeds every byte through the FNV-1a checksum.
struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv1a64,
}

impl<W: Write> Write for HashingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let k = self.inner.write(buf)?;
        self.hash.update(&buf[..k]);
        Ok(k)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// `Read` adapter that feeds every byte through the FNV-1a checksum.
struct HashingReader<R: Read> {
    inner: R,
    hash: Fnv1a64,
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let k = self.inner.read(buf)?;
        self.hash.update(&buf[..k]);
        Ok(k)
    }
}

/// Write the artifact **atomically**: header + hashed payload go to a `.tmp`
/// sibling, the checksum is patched into its header, and only then is the
/// temp file renamed over `path`. A crash (or full disk) mid-save therefore
/// never destroys the previous good artifact — which is the whole point of
/// periodic checkpointing. The `.tmp` file is cleaned up on failure. All
/// storage operations go through `medium`, so tests can fail any of them.
fn save_to_medium<M: Medium, F>(
    medium: &M,
    path: &Path,
    magic: &[u8; 8],
    width: u32,
    payload: F,
) -> Result<(), PersistError>
where
    F: FnOnce(&mut HashingWriter<BufWriter<M::Writer>>) -> Result<(), PersistError>,
{
    let tmp = tmp_sibling(path);
    let result = write_file(medium, &tmp, magic, width, payload)
        .and_then(|()| medium.rename(&tmp, path).map_err(PersistError::from));
    if result.is_err() {
        medium.remove(&tmp).ok();
    }
    result
}

/// `<name>.tmp` in the same directory (same filesystem, so the rename in
/// [`save_to_path`] is atomic).
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path
        .file_name()
        .map(|s| s.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("artifact"));
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write header + hashed payload, then patch the checksum into the header.
fn write_file<M: Medium, F>(
    medium: &M,
    path: &Path,
    magic: &[u8; 8],
    width: u32,
    payload: F,
) -> Result<(), PersistError>
where
    F: FnOnce(&mut HashingWriter<BufWriter<M::Writer>>) -> Result<(), PersistError>,
{
    let file = medium.create(path)?;
    let mut w = BufWriter::new(file);
    w.write_all(magic)?;
    write_u32_le(&mut w, FORMAT_VERSION)?;
    write_u32_le(&mut w, ENDIAN_TAG)?;
    write_u32_le(&mut w, width)?;
    write_u64_le(&mut w, 0)?; // checksum placeholder, patched below
    let mut hw = HashingWriter { inner: w, hash: Fnv1a64::new() };
    payload(&mut hw)?;
    let checksum = hw.hash.finish();
    let mut w = hw.inner;
    w.flush()?;
    let mut file = w.into_inner().map_err(|e| PersistError::Io(e.into_error()))?;
    file.seek(SeekFrom::Start(CHECKSUM_OFFSET))?;
    file.write_all(&checksum.to_le_bytes())?;
    Ok(())
}

/// Open + validate the shared header; returns the hashing payload reader,
/// the stored checksum, and the total file length.
fn open_checked(
    path: &Path,
    magic: &[u8; 8],
    width: u32,
) -> Result<(HashingReader<BufReader<File>>, u64, u64), PersistError> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut found = [0u8; 8];
    r.read_exact(&mut found).map_err(PersistError::from)?;
    if &found != magic {
        return Err(PersistError::BadMagic { found });
    }
    let version = read_u32_le(&mut r)?;
    if version != FORMAT_VERSION {
        return Err(PersistError::UnsupportedVersion { found: version, supported: FORMAT_VERSION });
    }
    let endian = read_u32_le(&mut r)?;
    if endian != ENDIAN_TAG {
        return Err(PersistError::EndiannessMismatch { found: endian });
    }
    let found_width = read_u32_le(&mut r)?;
    if found_width != width {
        return Err(PersistError::ScalarWidthMismatch { found: found_width, expected: width });
    }
    let stored = read_u64_le(&mut r)?;
    Ok((HashingReader { inner: r, hash: Fnv1a64::new() }, stored, file_len))
}

/// Reject payload sizes that disagree with the actual file BEFORE allocating.
fn check_file_len(expected: u64, actual: u64) -> Result<(), PersistError> {
    if actual < expected {
        return Err(PersistError::Truncated);
    }
    if actual > expected {
        return Err(PersistError::Corrupt(format!(
            "{} trailing byte(s) after the payload",
            actual - expected
        )));
    }
    Ok(())
}

fn read_bytes<R: Read>(r: &mut R, len: usize, buf: &mut Vec<u8>) -> Result<(), PersistError> {
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf).map_err(PersistError::from)
}

/// Compare the streamed payload hash against the stored checksum.
fn finish_checked<R: Read>(r: &HashingReader<R>, stored: u64) -> Result<(), PersistError> {
    let computed = r.hash.finish();
    if computed != stored {
        return Err(PersistError::ChecksumMismatch { stored, computed });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("acc_tsne_persist_{}_{name}", std::process::id()));
        p
    }

    fn ring_p(n: usize) -> CsrMatrix<f64> {
        let mut row_ptr = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            col.push(((i + 1) % n) as u32);
            col.push(((i + 2) % n) as u32);
            val.push(0.25 + i as f64 * 1e-3);
            val.push(0.75 - i as f64 * 1e-3);
            row_ptr.push(col.len());
        }
        CsrMatrix { n, row_ptr, col, val }
    }

    #[test]
    fn affinities_payload_round_trips_exactly() {
        let path = tmp("aff_rt.bin");
        let p = ring_p(64);
        write_affinities(&RealFs, &path, &p, 12.5, 37).unwrap();
        let (q, perplexity, k) = read_affinities::<f64>(&path).unwrap();
        assert_eq!(q.n, p.n);
        assert_eq!(q.row_ptr, p.row_ptr);
        assert_eq!(q.col, p.col);
        assert_eq!(q.val, p.val);
        assert_eq!(perplexity, 12.5);
        assert_eq!(k, 37);
        // the atomic-write staging file must not linger
        assert!(!tmp_sibling(&path).exists(), "tmp sibling left behind");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_payload_round_trips_exactly_with_and_without_perm() {
        for perm in [None, Some((0..50u32).rev().collect::<Vec<u32>>())] {
            let path = tmp("ckpt_rt.bin");
            let ck = SessionCheckpoint::<f32> {
                iter: 123,
                last_z: 4.5,
                last_grad_norm: 1e-3,
                aff_nnz: 4321,
                aff_perplexity: 25.0,
                y: (0..100).map(|i| i as f32 * 0.5).collect(),
                velocity: (0..100).map(|i| -(i as f32)).collect(),
                gains: (0..100).map(|i| 1.0 + i as f32 * 0.01).collect(),
                layout_perm: perm,
            };
            ck.save(&path).unwrap();
            let back = SessionCheckpoint::<f32>::load(&path).unwrap();
            assert_eq!(back, ck);
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn inconsistent_checkpoint_is_refused_at_save_time() {
        let ck = SessionCheckpoint::<f64> {
            iter: 0,
            last_z: 1.0,
            last_grad_norm: 0.0,
            aff_nnz: 0,
            aff_perplexity: 10.0,
            y: vec![0.0; 10],
            velocity: vec![0.0; 8],
            gains: vec![1.0; 10],
            layout_perm: None,
        };
        match ck.save(tmp("bad_save.bin")) {
            Err(PersistError::Mismatch(_)) => {}
            other => panic!("expected Mismatch, got {other:?}"),
        }
    }

    #[test]
    fn scalar_codec_is_exact_for_both_widths() {
        let mut buf = Vec::new();
        for v in [0.0f64, -1.5, 1e-300, f64::MAX] {
            write_scalar(&mut buf, v).unwrap();
        }
        let mut out = Vec::new();
        parse_scalars::<f64>(&buf, &mut out);
        assert_eq!(out, vec![0.0, -1.5, 1e-300, f64::MAX]);
        let mut buf32 = Vec::new();
        for v in [0.25f32, -3.5e-30, f32::MIN_POSITIVE] {
            write_scalar(&mut buf32, v).unwrap();
        }
        let mut out32 = Vec::new();
        parse_scalars::<f32>(&buf32, &mut out32);
        assert_eq!(out32, vec![0.25, -3.5e-30, f32::MIN_POSITIVE]);
    }

    fn ring_knn(n: usize, k: usize) -> NeighborLists<f64> {
        let mut indices = Vec::with_capacity(n * k);
        let mut dists = Vec::with_capacity(n * k);
        for i in 0..n {
            for j in 1..=k {
                indices.push(((i + j) % n) as u32);
                dists.push(j as f64 * 0.5);
            }
        }
        NeighborLists { n, k, indices, distances_sq: dists }
    }

    #[test]
    fn knn_graph_payload_round_trips_exactly() {
        let path = tmp("knn_rt.bin");
        let knn = ring_knn(40, 6);
        write_knn_graph(&RealFs, &path, &knn, 17, 0xDEAD_BEEF_u64, "brute-force-native").unwrap();
        let (back, d, fp, engine) = read_knn_graph::<f64>(&path).unwrap();
        assert_eq!(back.n, knn.n);
        assert_eq!(back.k, knn.k);
        assert_eq!(back.indices, knn.indices);
        assert_eq!(back.distances_sq, knn.distances_sq);
        assert_eq!(d, 17);
        assert_eq!(fp, 0xDEAD_BEEF);
        assert_eq!(engine, "brute-force-native");
        assert!(!tmp_sibling(&path).exists(), "tmp sibling left behind");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn knn_graph_loader_rejects_invalid_rows() {
        // Each corruption targets the payload (re-written through the normal
        // writer so the checksum is valid) and must be caught by the row
        // validation, not by a panic downstream.
        let corruptions: [(&str, fn(&mut NeighborLists<f64>)); 5] = [
            ("out-of-range neighbor", |k| k.indices[0] = k.n as u32),
            ("self loop", |k| k.indices[0] = 0),
            ("duplicate neighbor", |k| k.indices[1] = k.indices[0]),
            ("NaN distance", |k| k.distances_sq[3] = f64::NAN),
            ("descending distances", |k| {
                k.distances_sq[0] = 9.0;
            }),
        ];
        for (what, corrupt) in corruptions {
            let mut knn = ring_knn(30, 4);
            corrupt(&mut knn);
            let path = tmp("knn_badrows.bin");
            write_knn_graph(&RealFs, &path, &knn, 5, 1, "brute-force-native").unwrap();
            match read_knn_graph::<f64>(&path) {
                Err(PersistError::Corrupt(msg)) => {
                    assert!(msg.contains("row"), "{what}: {msg}")
                }
                other => panic!("{what}: expected Corrupt, got {:?}", other.map(|_| ())),
            }
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn knn_graph_engine_name_length_is_bounded() {
        let knn = ring_knn(10, 2);
        let long = "x".repeat(300);
        match write_knn_graph(&RealFs, &tmp("knn_long.bin"), &knn, 3, 0, &long) {
            Err(PersistError::Mismatch(msg)) => assert!(msg.contains("engine"), "{msg}"),
            other => panic!("expected Mismatch, got {other:?}"),
        }
    }

    #[test]
    fn loading_the_wrong_artifact_kind_is_bad_magic() {
        let path = tmp("kind.bin");
        let p = ring_p(16);
        write_affinities(&RealFs, &path, &p, 5.0, 3).unwrap();
        match SessionCheckpoint::<f64>::load(&path) {
            Err(PersistError::BadMagic { found }) => assert_eq!(&found, AFFINITIES_MAGIC),
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_file(path).ok();
    }
}
