//! Z-order-persistent gradient-loop state — the [`IterationWorkspace`].
//!
//! `build_morton` sorts the embedding into Z-order every iteration; the
//! pre-refactor loop threw that permutation away, so the attractive CSR
//! sweep, the gradient combine, and the optimizer step all walked `y`,
//! `attr`, and `grad` in original order with scattered gathers. The
//! workspace makes Z-order the *native* layout of the whole loop instead:
//!
//! - it owns the embedding, force buffers, and optimizer state **in layout
//!   order**, plus the global `slot → original` permutation;
//! - after each tree build it compares the tree's fresh Z-order against the
//!   current layout ([`QuadTree::layout_drift`]) and **adopts** the new order
//!   only when more than [`ADOPT_DRIFT_PCT`]% of points moved slots —
//!   re-permuting `y` (a memcpy of the tree's already-gathered positions),
//!   velocity, gains, the composed permutation, and the CSR `P`
//!   ([`permute_symmetric_into`], amortized O(nnz)) in one go, then marking
//!   the tree's `point_idx` as identity so the repulsive kernels scatter
//!   sequentially;
//! - between adoptions the tree's `point_idx` is a near-identity map and the
//!   existing kernels need no changes at all;
//! - the embedding is un-permuted **once**, at the end of the run
//!   ([`IterationWorkspace::into_original_order`]).
//!
//! Allocation story: `attr`/`rep_raw`/`view` buffers are reused every
//! iteration; the permutation scratch, optimizer-state scratch, and the
//! Z-order `P` copy are allocated on the *first* adoption and reused by all
//! later ones. The per-iteration hot path allocates nothing beyond the tree
//! build itself.
//!
//! Parity contract: every value is merely *relocated*, never recomputed, and
//! `P`'s per-row entry order is preserved by [`permute_symmetric_into`], so
//! the Z-order loop matches the original-layout loop to FP noise (the only
//! divergence is summation order inside `recenter`'s mean and the BH Z
//! reduction). The layout-parity proptests assert ≤ 1e-6 relative.

use crate::common::float::Real;
use crate::gradient::update::{Optimizer, UpdateParams};
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use crate::quadtree::view::TraversalView;
use crate::quadtree::QuadTree;
use crate::sparse::{permute_symmetric_into, CsrMatrix};

/// Default adoption threshold: re-permute (adopt) only when more than this
/// percentage of points changed slots since the last adopted layout. Below
/// it the repulsive scatter through `point_idx` is ~identity and re-indexing
/// `P` (O(nnz)) would cost more than the locality it restores; above it the
/// scattered CSR gathers start missing again. Points move a lot early (adopt
/// almost every iteration) and barely at all late (adopt rarely, and the
/// builder's sorted-skip makes the re-sort itself a no-op). Tunable per run
/// via [`StagePlan::adopt_drift_pct`](crate::tsne::StagePlan::adopt_drift_pct)
/// (`bench_micro_kernels` carries the measuring sweep).
pub const ADOPT_DRIFT_PCT: usize = 5;

/// Persistent per-iteration state of the gradient loop, stored in the
/// current layout order (original until the first adoption, Z-order after).
pub struct IterationWorkspace<T: Real> {
    zorder: bool,
    adopted: bool,
    /// Adoption threshold in percent of drifted points (0 ⇒ adopt on any
    /// drift, 100 ⇒ never adopt).
    adopt_drift_pct: usize,
    /// Embedding, interleaved x,y per point, in layout order.
    pub y: Vec<T>,
    /// Attractive accumulation buffer (layout order, overwritten per iter).
    pub attr: Vec<T>,
    /// Raw repulsive accumulation buffer (layout order, overwritten per iter).
    pub rep_raw: Vec<T>,
    /// Optimizer state (velocity/gains live in layout order too).
    pub opt: Optimizer<T>,
    /// SoA traversal view for the tiled repulsive kernel (buffers reused).
    pub view: TraversalView<T>,
    /// Z-order copy of `P` (rows and columns in slot space); `None` until the
    /// first adoption — the pipeline reads the caller's `P` until then.
    pub(crate) p_z: Option<CsrMatrix<T>>,
    /// `perm[slot] = original index` of the adopted layout.
    perm: Vec<u32>,
    /// `inv_perm[original] = slot`.
    inv_perm: Vec<u32>,
    perm_scratch: Vec<u32>,
    state_scratch: Vec<T>,
}

impl<T: Real> IterationWorkspace<T> {
    /// Wrap an initial embedding (in the caller's original point order).
    /// `zorder` selects the persistent-layout mode; with it off the
    /// workspace is a plain buffer bundle and [`Self::maybe_adopt`] no-ops.
    /// `adopt_drift_pct` is the adoption threshold ([`ADOPT_DRIFT_PCT`] is
    /// the default — picked, not yet measured; `bench_micro_kernels`'
    /// adoption sweep exists to replace it with a measured value).
    pub fn new(y: Vec<T>, update: UpdateParams, zorder: bool, adopt_drift_pct: usize) -> Self {
        let n = y.len() / 2;
        assert_eq!(y.len(), 2 * n, "embedding must be interleaved x,y");
        let (perm, inv_perm) = if zorder {
            ((0..n as u32).collect(), (0..n as u32).collect())
        } else {
            (Vec::new(), Vec::new())
        };
        IterationWorkspace {
            zorder,
            adopted: false,
            adopt_drift_pct,
            y,
            attr: vec![T::ZERO; 2 * n],
            rep_raw: vec![T::ZERO; 2 * n],
            opt: Optimizer::new(n, update),
            view: TraversalView::new(),
            p_z: None,
            perm,
            inv_perm,
            perm_scratch: Vec::new(),
            state_scratch: Vec::new(),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.y.len() / 2
    }

    /// `slot → original` map of the adopted layout (`None` while the state
    /// is still in original order).
    pub fn permutation(&self) -> Option<&[u32]> {
        if self.adopted {
            Some(&self.perm)
        } else {
            None
        }
    }

    /// Adopt `tree`'s layout as the workspace layout if it drifted beyond
    /// the configured `adopt_drift_pct` from the current one. `tree` must have been built
    /// from `self.y` this iteration, and `p` must be the run's CSR `P` in
    /// ORIGINAL index space (the re-index always starts from it, so
    /// permutation error cannot compound across adoptions). On adoption the
    /// tree's `point_idx` is rewritten to the identity: tree slots ARE layout
    /// slots from here on, so the repulsive kernels scatter sequentially.
    ///
    /// Returns whether the layout changed.
    pub fn maybe_adopt(
        &mut self,
        pool: &ThreadPool,
        tree: &mut QuadTree<T>,
        p: &CsrMatrix<T>,
    ) -> bool {
        if !self.zorder {
            return false;
        }
        let n = self.n();
        debug_assert_eq!(tree.n_points(), n, "tree must be built from the workspace embedding");
        let drift = tree.layout_drift();
        if drift * 100 <= n * self.adopt_drift_pct {
            return false;
        }

        // First adoption allocates the scratch; later ones reuse it.
        self.perm_scratch.resize(n, 0);
        self.state_scratch.resize(2 * n, T::ZERO);

        // Compose the global permutation: the point now at slot t came from
        // layout slot tree.point_idx[t], which held original perm[...].
        {
            let new_to_old = tree.layout_order();
            let perm = &self.perm;
            let ps = SyncSlice::new(&mut self.perm_scratch);
            parallel_for(pool, n, Schedule::Static, |range| {
                for t in range {
                    // SAFETY: disjoint — slot t
                    unsafe { *ps.get_mut(t) = perm[new_to_old[t] as usize] };
                }
            });
        }
        std::mem::swap(&mut self.perm, &mut self.perm_scratch);
        {
            let perm = &self.perm;
            let inv = SyncSlice::new(&mut self.inv_perm);
            parallel_for(pool, n, Schedule::Static, |range| {
                for t in range {
                    // SAFETY: disjoint — perm is a bijection
                    unsafe { *inv.get_mut(perm[t] as usize) = t as u32 };
                }
            });
        }

        // Embedding: the builder already gathered y into the new order.
        self.y.copy_from_slice(&tree.point_pos);

        // Optimizer state rides along (values relocated, never recomputed).
        permute_pairs(pool, tree.layout_order(), &self.opt.velocity, &mut self.state_scratch);
        std::mem::swap(&mut self.opt.velocity, &mut self.state_scratch);
        permute_pairs(pool, tree.layout_order(), &self.opt.gains, &mut self.state_scratch);
        std::mem::swap(&mut self.opt.gains, &mut self.state_scratch);

        // P re-indexed into slot space, always from the original matrix.
        let p_z = self.p_z.get_or_insert_with(|| CsrMatrix {
            n,
            row_ptr: Vec::new(),
            col: Vec::new(),
            val: Vec::new(),
        });
        permute_symmetric_into(pool, p, &self.perm, &self.inv_perm, p_z);

        // The tree is now IN layout order: make its scatter map say so.
        {
            let ids = SyncSlice::new(&mut tree.point_idx);
            parallel_for(pool, n, Schedule::Static, |range| {
                for t in range {
                    // SAFETY: disjoint — slot t
                    unsafe { *ids.get_mut(t) = t as u32 };
                }
            });
        }
        self.adopted = true;
        true
    }

    /// Write the embedding, un-permuted to the caller's original point
    /// order, into `out` (resized to `2n`). The non-consuming sibling of
    /// [`Self::into_original_order`] — observer snapshots and mid-run KL
    /// evaluation use it without disturbing the layout-order state.
    pub fn copy_original_order_into(&self, out: &mut Vec<T>) {
        self.unpermute_pairs_into(&self.y, out);
    }

    /// Un-permute any layout-order interleaved per-point array (`2n` values:
    /// embedding, velocity, gains, …) into the caller's original point order.
    /// An identity copy while the state is still un-adopted. Checkpointing
    /// serializes every state array through this, so a checkpoint file is
    /// layout-free.
    pub fn unpermute_pairs_into(&self, src: &[T], out: &mut Vec<T>) {
        assert_eq!(src.len(), self.y.len(), "array must hold 2n interleaved values");
        out.resize(src.len(), T::ZERO);
        if !self.adopted {
            out.copy_from_slice(src);
            return;
        }
        for (slot, &orig) in self.perm.iter().enumerate() {
            out[2 * orig as usize] = src[2 * slot];
            out[2 * orig as usize + 1] = src[2 * slot + 1];
        }
    }

    /// Re-permute a workspace whose state is still in ORIGINAL order into the
    /// given layout — the restore path of a checkpointed session. `perm` is
    /// the adopted `slot → original` map saved in the checkpoint and `p` is
    /// the run's CSR `P` in original index space (re-indexed into slot space
    /// here, exactly as [`Self::maybe_adopt`] would have).
    ///
    /// Replaying the saved permutation makes the restored in-memory layout —
    /// and therefore every layout-dependent FP summation order — bit-identical
    /// to the checkpointed session's, which is what makes a resumed run match
    /// an uninterrupted one exactly.
    ///
    /// Returns `Err` (instead of panicking) when `perm` is not a bijection of
    /// `0..n` — checkpoints are external input.
    pub fn adopt_permutation(
        &mut self,
        pool: &ThreadPool,
        perm: &[u32],
        p: &CsrMatrix<T>,
    ) -> Result<(), String> {
        assert!(self.zorder, "adopt_permutation applies to the Z-order mode only");
        assert!(!self.adopted, "workspace must still be in original order");
        let n = self.n();
        if perm.len() != n {
            return Err(format!("layout permutation has {} entries for n = {n}", perm.len()));
        }
        let mut seen = vec![false; n];
        for &orig in perm {
            let o = orig as usize;
            if o >= n || seen[o] {
                return Err(format!("layout permutation is not a bijection of 0..{n}"));
            }
            seen[o] = true;
        }

        self.state_scratch.resize(2 * n, T::ZERO);
        self.perm.copy_from_slice(perm);
        for (slot, &orig) in perm.iter().enumerate() {
            self.inv_perm[orig as usize] = slot as u32;
        }
        // State rides into the layout: values relocated, never recomputed.
        permute_pairs(pool, perm, &self.y, &mut self.state_scratch);
        std::mem::swap(&mut self.y, &mut self.state_scratch);
        permute_pairs(pool, perm, &self.opt.velocity, &mut self.state_scratch);
        std::mem::swap(&mut self.opt.velocity, &mut self.state_scratch);
        permute_pairs(pool, perm, &self.opt.gains, &mut self.state_scratch);
        std::mem::swap(&mut self.opt.gains, &mut self.state_scratch);
        let p_z = self.p_z.get_or_insert_with(|| CsrMatrix {
            n,
            row_ptr: Vec::new(),
            col: Vec::new(),
            val: Vec::new(),
        });
        permute_symmetric_into(pool, p, &self.perm, &self.inv_perm, p_z);
        self.adopted = true;
        Ok(())
    }

    /// Consume the workspace, returning the embedding un-permuted to the
    /// caller's original point order (the run's single un-permute).
    pub fn into_original_order(mut self) -> Vec<T> {
        if !self.adopted {
            return self.y;
        }
        for (slot, &orig) in self.perm.iter().enumerate() {
            self.state_scratch[2 * orig as usize] = self.y[2 * slot];
            self.state_scratch[2 * orig as usize + 1] = self.y[2 * slot + 1];
        }
        self.state_scratch
    }
}

/// `dst[2t..2t+2] = src[2·new_to_old[t] ..]` — relocate interleaved per-point
/// pairs into a new layout (parallel; dst fully overwritten).
fn permute_pairs<T: Real>(pool: &ThreadPool, new_to_old: &[u32], src: &[T], dst: &mut [T]) {
    debug_assert_eq!(src.len(), dst.len());
    debug_assert_eq!(src.len(), 2 * new_to_old.len());
    let ds = SyncSlice::new(dst);
    parallel_for(pool, new_to_old.len(), Schedule::Static, |range| {
        for t in range {
            let s = new_to_old[t] as usize;
            // SAFETY: disjoint — slots 2t, 2t+1
            unsafe {
                *ds.get_mut(2 * t) = src[2 * s];
                *ds.get_mut(2 * t + 1) = src[2 * s + 1];
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::quadtree::builder_morton::build_morton;

    fn random_y(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * 3.0).collect()
    }

    /// Small ring-structured CSR (columns in original index space).
    fn ring_p(n: usize) -> CsrMatrix<f64> {
        let mut row_ptr = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            col.push(((i + 1) % n) as u32);
            col.push(((i + 3) % n) as u32);
            val.push(0.25 + i as f64 * 1e-3);
            val.push(0.75 - i as f64 * 1e-3);
            row_ptr.push(col.len());
        }
        CsrMatrix { n, row_ptr, col, val }
    }

    #[test]
    fn adoption_relocates_all_state_consistently() {
        let n = 500;
        let y0 = random_y(n, 1);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mut ws =
            IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        // distinct optimizer state so relocation is observable
        for i in 0..2 * n {
            ws.opt.velocity[i] = i as f64 * 0.5;
            ws.opt.gains[i] = 1.0 + i as f64 * 0.25;
        }
        let vel0 = ws.opt.velocity.clone();
        let gains0 = ws.opt.gains.clone();
        let mut tree = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut tree, &p), "random order must adopt");
        let perm = ws.permutation().unwrap().to_vec();
        // point_idx rewritten to identity
        assert!(tree.point_idx.iter().enumerate().all(|(t, &s)| s as usize == t));
        for (slot, &orig) in perm.iter().enumerate() {
            let o = orig as usize;
            assert_eq!(ws.y[2 * slot], y0[2 * o], "y slot {slot}");
            assert_eq!(ws.y[2 * slot + 1], y0[2 * o + 1]);
            assert_eq!(ws.opt.velocity[2 * slot], vel0[2 * o]);
            assert_eq!(ws.opt.velocity[2 * slot + 1], vel0[2 * o + 1]);
            assert_eq!(ws.opt.gains[2 * slot], gains0[2 * o]);
            assert_eq!(ws.opt.gains[2 * slot + 1], gains0[2 * o + 1]);
        }
        // P rows/cols in slot space: p_z[t] = p.row(perm[t]) with mapped cols
        let p_z = ws.p_z.as_ref().unwrap();
        let mut inv = vec![0u32; n];
        for (slot, &orig) in perm.iter().enumerate() {
            inv[orig as usize] = slot as u32;
        }
        for t in 0..n {
            let (zc, zv) = p_z.row(t);
            let (oc, ov) = p.row(perm[t] as usize);
            assert_eq!(zv, ov, "row {t} values must relocate in order");
            let want: Vec<u32> = oc.iter().map(|&c| inv[c as usize]).collect();
            assert_eq!(zc, &want[..], "row {t} columns must map to slot space");
        }
    }

    #[test]
    fn no_adoption_below_drift_threshold() {
        let n = 400;
        let y0 = random_y(n, 2);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mut ws = IterationWorkspace::new(y0, UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        let mut t1 = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut t1, &p));
        // rebuild from the adopted layout: zero drift → no re-adoption
        let mut t2 = build_morton(&pool, &ws.y);
        assert_eq!(t2.layout_drift(), 0);
        assert!(!ws.maybe_adopt(&pool, &mut t2, &p));
        // original-layout workspaces never adopt
        let mut ws_orig = IterationWorkspace::new(
            random_y(n, 3),
            UpdateParams::default(),
            false,
            ADOPT_DRIFT_PCT,
        );
        let mut t3 = build_morton(&pool, &ws_orig.y);
        assert!(!ws_orig.maybe_adopt(&pool, &mut t3, &p));
        assert!(ws_orig.p_z.is_none());
    }

    #[test]
    fn into_original_order_round_trips() {
        let n = 300;
        let y0 = random_y(n, 4);
        let pool = ThreadPool::new(2);
        let p = ring_p(n);
        let mut ws =
            IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        let mut tree = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut tree, &p));
        assert_ne!(ws.y, y0, "layout must actually differ");
        assert_eq!(ws.into_original_order(), y0);
    }

    #[test]
    fn adopt_threshold_zero_and_hundred_are_the_extremes() {
        let n = 400;
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        // pct=100: drift can never exceed n, so the layout is never adopted
        let mut ws100 = IterationWorkspace::new(random_y(n, 7), UpdateParams::default(), true, 100);
        let mut t100 = build_morton(&pool, &ws100.y);
        assert!(!ws100.maybe_adopt(&pool, &mut t100, &p));
        assert!(ws100.permutation().is_none());
        // pct=0: any nonzero drift triggers adoption
        let mut ws0 = IterationWorkspace::new(random_y(n, 8), UpdateParams::default(), true, 0);
        let mut t0 = build_morton(&pool, &ws0.y);
        assert!(t0.layout_drift() > 0, "random order must drift");
        assert!(ws0.maybe_adopt(&pool, &mut t0, &p));
    }

    #[test]
    fn copy_original_order_matches_into_original_order() {
        let n = 300;
        let y0 = random_y(n, 9);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mut ws =
            IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        let mut out = Vec::new();
        ws.copy_original_order_into(&mut out);
        assert_eq!(out, y0, "identity before adoption");
        let mut tree = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut tree, &p));
        ws.copy_original_order_into(&mut out);
        assert_ne!(out, ws.y, "snapshot is un-permuted, state stays in layout order");
        assert_eq!(out, ws.into_original_order());
    }

    #[test]
    fn adopt_permutation_reproduces_maybe_adopt_state_exactly() {
        // The restore path: replaying a saved permutation over original-order
        // state must land in the SAME in-memory state maybe_adopt produced.
        let n = 400;
        let y0 = random_y(n, 21);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mk = || {
            let mut ws =
                IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
            for i in 0..2 * n {
                ws.opt.velocity[i] = (i as f64).sin();
                ws.opt.gains[i] = 1.0 + (i as f64).cos().abs();
            }
            ws
        };
        let mut live = mk();
        let mut tree = build_morton(&pool, &live.y);
        assert!(live.maybe_adopt(&pool, &mut tree, &p));
        let perm = live.permutation().unwrap().to_vec();

        let mut restored = mk();
        restored.adopt_permutation(&pool, &perm, &p).unwrap();
        assert_eq!(restored.y, live.y);
        assert_eq!(restored.opt.velocity, live.opt.velocity);
        assert_eq!(restored.opt.gains, live.opt.gains);
        assert_eq!(restored.permutation().unwrap(), &perm[..]);
        let (a, b) = (restored.p_z.as_ref().unwrap(), live.p_z.as_ref().unwrap());
        assert_eq!(a.row_ptr, b.row_ptr);
        assert_eq!(a.col, b.col);
        assert_eq!(a.val, b.val);
    }

    #[test]
    fn adopt_permutation_rejects_non_bijections() {
        let n = 50;
        let pool = ThreadPool::new(2);
        let p = ring_p(n);
        let mut ws = IterationWorkspace::new(
            random_y(n, 22),
            UpdateParams::default(),
            true,
            ADOPT_DRIFT_PCT,
        );
        let mut dup: Vec<u32> = (0..n as u32).collect();
        dup[0] = 1; // slot 0 and 1 both claim original 1
        assert!(ws.adopt_permutation(&pool, &dup, &p).is_err());
        let short: Vec<u32> = (0..(n as u32 - 1)).collect();
        assert!(ws.adopt_permutation(&pool, &short, &p).is_err());
        let oob: Vec<u32> = (1..=n as u32).collect(); // contains n
        assert!(ws.adopt_permutation(&pool, &oob, &p).is_err());
        // state untouched by the failed attempts
        assert!(ws.permutation().is_none());
    }

    #[test]
    fn unpermute_pairs_into_covers_every_state_array() {
        let n = 200;
        let y0 = random_y(n, 23);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mut ws =
            IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        let vel0: Vec<f64> = (0..2 * n).map(|i| i as f64 * 0.25).collect();
        ws.opt.velocity.copy_from_slice(&vel0);
        let mut out = Vec::new();
        // identity before adoption
        ws.unpermute_pairs_into(&ws.opt.velocity, &mut out);
        assert_eq!(out, vel0);
        let mut tree = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut tree, &p));
        ws.unpermute_pairs_into(&ws.opt.velocity, &mut out);
        assert_eq!(out, vel0, "velocity un-permutes back to original order");
        ws.unpermute_pairs_into(&ws.y, &mut out);
        assert_eq!(out, y0, "and the embedding path matches copy_original_order_into");
    }

    #[test]
    fn repeated_adoption_composes_against_original() {
        // Two adoptions in sequence: the composed permutation must still map
        // slots straight back to ORIGINAL indices (no compounding error).
        let n = 350;
        let y0 = random_y(n, 5);
        let pool = ThreadPool::new(4);
        let p = ring_p(n);
        let mut ws =
            IterationWorkspace::new(y0.clone(), UpdateParams::default(), true, ADOPT_DRIFT_PCT);
        let mut t1 = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut t1, &p));
        let perm0 = ws.permutation().unwrap().to_vec();
        // Perturb the embedding enough to reshuffle the Z-order.
        let mut rng = Rng::new(6);
        for v in ws.y.iter_mut() {
            *v += rng.next_gaussian() * 2.0;
        }
        let y_mid = ws.y.clone();
        let mut t2 = build_morton(&pool, &ws.y);
        assert!(ws.maybe_adopt(&pool, &mut t2, &p), "perturbed order must re-adopt");
        let perm1 = ws.permutation().unwrap();
        // p_z row t must equal p row perm1[t] (re-indexed from ORIGINAL, so
        // two adoptions cannot compound permutation error)
        let p_z = ws.p_z.as_ref().unwrap();
        for t in 0..n {
            let (_, zv) = p_z.row(t);
            let (_, ov) = p.row(perm1[t] as usize);
            assert_eq!(zv, ov, "row {t}");
        }
        // Unwinding maps each mid-state slot s back to original owner
        // perm0[s]: back[2·perm0[s]] == y_mid[2s].
        let back = ws.into_original_order();
        for s in 0..n {
            let o = perm0[s] as usize;
            assert_eq!(back[2 * o], y_mid[2 * s], "slot {s}");
            assert_eq!(back[2 * o + 1], y_mid[2 * s + 1]);
        }
    }
}
