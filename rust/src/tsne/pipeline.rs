//! Pipeline assembly: wires the step modules together per implementation
//! flavor and times every step.
//!
//! ## The Z-order-persistent gradient loop
//!
//! [`gradient_loop`] is structured around an [`IterationWorkspace`]
//! (see [`super::workspace`]) that owns the embedding, force buffers, and
//! optimizer state in the current *layout order*. With [`Layout::Zorder`]
//! (the [`Implementation::AccTsne`] default) the workspace adopts each tree
//! build's Z-order whenever it drifts beyond the adoption threshold: the
//! embedding, velocity, gains, and a re-indexed copy of the CSR `P` all move
//! into Z-order, so every per-iteration sweep — repulsive scatter,
//! attractive CSR gather, and the **fused combine+update pass**
//! ([`Optimizer::fused_combine_step`](crate::gradient::update::Optimizer::fused_combine_step),
//! exactly one pass over the `2n` coordinates per iteration; there is no
//! separate `combine_gradient` sweep in the loop) — walks memory in spatial
//! order. The embedding is un-permuted once, after the last iteration.
//! [`Layout::Original`] keeps the caller's order throughout (the A/B
//! baseline for `BENCH_gradient_loop.json` and the parity proptests; both
//! layouts agree to FP noise). FIt-SNE builds no tree and always runs the
//! original layout.
//!
//! Note for [`AttractiveEngine`] overrides: with the Z-order layout the
//! engine is handed the workspace's re-indexed `P` and Z-ordered `y` — the
//! interface contract (`out[2i..] = F_attr` of row `i` of the given `P`) is
//! unchanged, but an engine that baked the original sparsity pattern into an
//! AOT artifact should be run with `layout: Some(Layout::Original)`.

use super::{Implementation, Layout, Scalar, TsneConfig, TsneResult};
use super::workspace::IterationWorkspace;
use crate::common::timer::{Step, StepTimes};
use crate::fitsne::{fitsne_repulsive_into, FitsneParams};
use crate::gradient::attractive::{attractive_forces, Variant};
use crate::gradient::exact::kl_with_z;
use crate::gradient::repulsive::{repulsive_forces_into, RepulsiveVariant};
use crate::gradient::update::random_init;
use crate::knn::{BruteForceKnn, KnnEngine, NeighborLists};
use crate::parallel::{pool::available_cores, ThreadPool};
use crate::perplexity::{binary_search_perplexity, ParMode};
use crate::quadtree::builder_baseline::build_baseline;
use crate::quadtree::builder_morton::build_morton;
use crate::quadtree::summarize::{summarize_parallel, summarize_sequential};
use crate::sparse::{symmetrize, CsrMatrix};

/// Pluggable attractive-force engine: native SIMD/scalar variants or the
/// AOT-compiled XLA artifact ([`crate::runtime::engines::XlaAttractive`]) —
/// the hook that lets the L1/L2 layers run inside the L3 hot path.
///
/// `compute` is always invoked from the coordinator thread (engines fan out
/// through the `pool` argument themselves if they want parallelism), so no
/// `Sync` bound: the PJRT executable handle is deliberately single-threaded.
pub trait AttractiveEngine<T: Scalar> {
    fn name(&self) -> &'static str;
    fn compute(&self, pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T], out: &mut [T]);
}

/// Default engine: the in-crate kernels of [`crate::gradient::attractive`].
pub struct NativeAttractive(pub Variant);

impl<T: Scalar> AttractiveEngine<T> for NativeAttractive {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn compute(&self, pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T], out: &mut [T]) {
        attractive_forces(pool, p, y, self.0, out);
    }
}

/// Per-flavor knobs (resolved from [`Implementation`]).
struct Flavor {
    knn_blocked: bool,
    bsp_parallel: bool,
    morton_tree: bool,
    tree_parallel: bool,
    summarize_parallel: bool,
    attractive_variant: Variant,
    repulsive_variant: RepulsiveVariant,
    forces_parallel: bool,
    fft_repulsion: bool,
    layout: Layout,
}

fn flavor(imp: Implementation) -> Flavor {
    match imp {
        Implementation::SklearnLike => Flavor {
            knn_blocked: true,
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: false,
            fft_repulsion: false,
            layout: Layout::Original,
        },
        Implementation::MulticoreLike => Flavor {
            knn_blocked: false, // row-at-a-time distance sweep (VP-tree-ish locality)
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: true,
            fft_repulsion: false,
            layout: Layout::Original,
        },
        Implementation::Daal4pyLike => Flavor {
            knn_blocked: true,
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: true,
            fft_repulsion: false,
            layout: Layout::Original,
        },
        Implementation::AccTsne => Flavor {
            knn_blocked: true,
            bsp_parallel: true,
            morton_tree: true,
            tree_parallel: true,
            summarize_parallel: true,
            attractive_variant: Variant::Simd,
            repulsive_variant: RepulsiveVariant::SimdTiled,
            forces_parallel: true,
            fft_repulsion: false,
            layout: Layout::Zorder,
        },
        Implementation::FitSne => Flavor {
            knn_blocked: true,
            bsp_parallel: false,
            morton_tree: false,
            tree_parallel: false,
            summarize_parallel: false,
            attractive_variant: Variant::Scalar,
            repulsive_variant: RepulsiveVariant::Scalar,
            forces_parallel: true,
            fft_repulsion: true,
            layout: Layout::Original,
        },
    }
}

/// Run t-SNE on `points` (n × d, row-major) with the given implementation.
pub fn run_tsne<T: Scalar>(
    points: &[T],
    n: usize,
    d: usize,
    cfg: &TsneConfig,
    imp: Implementation,
) -> TsneResult<T> {
    run_tsne_custom(points, n, d, cfg, imp, None)
}

/// As [`run_tsne`] but with an optional attractive-engine override (the
/// XLA-offload integration path).
pub fn run_tsne_custom<T: Scalar>(
    points: &[T],
    n: usize,
    d: usize,
    cfg: &TsneConfig,
    imp: Implementation,
    attractive_override: Option<&dyn AttractiveEngine<T>>,
) -> TsneResult<T> {
    assert_eq!(points.len(), n * d, "points must be n*d");
    assert!(n >= 8, "need at least 8 points");
    let fl = flavor(imp);
    let nt = if cfg.n_threads == 0 { available_cores() } else { cfg.n_threads };
    let pool = ThreadPool::new(nt);
    let mut times = StepTimes::new();

    // --- Step 1: KNN over ⌊3u⌋ neighbors (Eq. 2). The blocked engine models
    // daal4py's; the VP-tree models Multicore-TSNE's (vdMaaten's code).
    let k = ((3.0 * cfg.perplexity).floor() as usize).clamp(1, n - 1);
    let knn: NeighborLists<T> = times.time(Step::Knn, || {
        if fl.knn_blocked {
            BruteForceKnn::default().search(&pool, points, n, d, k)
        } else {
            crate::knn::vptree::VpTreeKnn::default().search(&pool, points, n, d, k)
        }
    });

    // --- Step 2: BSP (+ symmetrization, charged to BSP as daal4py does).
    let p = times.time(Step::Bsp, || {
        let mode = if fl.bsp_parallel { ParMode::Parallel } else { ParMode::Sequential };
        let cond = binary_search_perplexity(&pool, &knn, cfg.perplexity, mode);
        symmetrize(&pool, &knn, &cond.p)
    });
    drop(knn);

    // Optional PCA initialization (sklearn init="pca": top-2 PCs scaled so
    // the largest component has std 1e-4, then descent as usual).
    let init = if cfg.init_pca {
        let (proj, _) = crate::data::pca::pca(&pool, points, n, d, 2, 30, cfg.seed ^ 0x9CA);
        Some(scale_init(proj, n))
    } else {
        None
    };

    let (embedding, kl, iters, grad_times) =
        gradient_loop(&pool, &p, n, cfg, &fl, attractive_override, init);
    times.merge(&grad_times);

    TsneResult {
        embedding,
        kl_divergence: kl,
        step_times: times,
        n_iter: iters,
        implementation: imp,
    }
}

/// Run only the gradient phase on a precomputed P (benches isolate steps with
/// this; also lets Table 5/6 harnesses share one KNN across implementations).
pub fn run_tsne_with_p<T: Scalar>(
    pool: &ThreadPool,
    p: &CsrMatrix<T>,
    cfg: &TsneConfig,
    imp: Implementation,
) -> TsneResult<T> {
    let fl = flavor(imp);
    let (embedding, kl, iters, times) = gradient_loop(pool, p, p.n, cfg, &fl, None, None);
    TsneResult {
        embedding,
        kl_divergence: kl,
        step_times: times,
        n_iter: iters,
        implementation: imp,
    }
}

/// PCA projection → init scaling: sklearn scales PC1 to std 1e-4.
fn scale_init<T: Scalar>(mut proj: Vec<T>, n: usize) -> Vec<T> {
    let mut var = 0.0f64;
    for i in 0..n {
        var += proj[2 * i].to_f64().powi(2);
    }
    let std = (var / n as f64).sqrt().max(f64::MIN_POSITIVE);
    let s = T::from_f64(1e-4 / std);
    for v in proj.iter_mut() {
        *v *= s;
    }
    proj
}

#[allow(clippy::too_many_arguments)]
fn gradient_loop<T: Scalar>(
    pool: &ThreadPool,
    p: &CsrMatrix<T>,
    n: usize,
    cfg: &TsneConfig,
    fl: &Flavor,
    attractive_override: Option<&dyn AttractiveEngine<T>>,
    init: Option<Vec<T>>,
) -> (Vec<T>, f64, usize, StepTimes) {
    let mut times = StepTimes::new();
    let seq_pool = ThreadPool::new(1);
    let force_pool: &ThreadPool = if fl.forces_parallel { pool } else { &seq_pool };
    let tree_pool: &ThreadPool = if fl.tree_parallel { pool } else { &seq_pool };

    let native_engine = NativeAttractive(fl.attractive_variant);
    let attractive: &dyn AttractiveEngine<T> = match attractive_override {
        Some(e) => e,
        None => &native_engine,
    };

    let rep_variant = cfg.repulsive.unwrap_or(fl.repulsive_variant);
    // FIt-SNE builds no tree, hence has no Z-order to persist: force Original.
    let layout = if fl.fft_repulsion { Layout::Original } else { cfg.layout.unwrap_or(fl.layout) };
    // The workspace owns embedding, force buffers, optimizer state, and (in
    // the Z-order layout) the permutation + re-indexed P. Steady state
    // allocates nothing per iteration: force/view/scratch buffers are reused
    // and only the tree itself is rebuilt.
    let y0 = init.unwrap_or_else(|| random_init::<T>(n, cfg.seed));
    let mut ws = IterationWorkspace::new(y0, cfg.update, layout == Layout::Zorder);
    let fit_params = FitsneParams::default();
    let mut last_z = T::ONE;

    for iter in 0..cfg.n_iter {
        let z: T = if fl.fft_repulsion {
            // FIt-SNE path: no tree; the FFT pipeline is the repulsive step.
            times.time(Step::Repulsive, || {
                fitsne_repulsive_into(force_pool, &ws.y, &fit_params, &mut ws.rep_raw)
            })
        } else {
            // Steps 3–4: quadtree + summarization.
            let mut tree = times.time(Step::TreeBuild, || {
                if fl.morton_tree {
                    build_morton(tree_pool, &ws.y)
                } else {
                    build_baseline(tree_pool, &ws.y)
                }
            });
            // Layout maintenance (Z-order path only): adopt the fresh
            // Z-order when it drifted past the threshold. Charged to
            // TreeBuild — it is the build's permutation being applied.
            times.time(Step::TreeBuild, || ws.maybe_adopt(pool, &mut tree, p));
            times.time(Step::Summarize, || {
                if fl.summarize_parallel {
                    summarize_parallel(pool, &mut tree)
                } else {
                    summarize_sequential(&mut tree)
                }
            });
            // Step 6: repulsive (view materialization charged to this step —
            // it exists only to feed the tiled kernel). In the adopted
            // Z-order layout the scatter through `point_idx` is the identity.
            times.time(Step::Repulsive, || {
                let v = match rep_variant {
                    RepulsiveVariant::Scalar => None,
                    RepulsiveVariant::SimdTiled => {
                        ws.view.rebuild_parallel(force_pool, &tree);
                        Some(&ws.view)
                    }
                };
                repulsive_forces_into(force_pool, &tree, v, cfg.theta, rep_variant, &mut ws.rep_raw)
            })
        };
        last_z = z;

        // Step 5: attractive — over the layout-order P once adopted, so the
        // y-gathers walk Z-order neighborhoods instead of random slots.
        let p_iter: &CsrMatrix<T> = match &ws.p_z {
            Some(m) => m,
            None => p,
        };
        times.time(Step::Attractive, || {
            attractive.compute(force_pool, p_iter, &ws.y, &mut ws.attr)
        });

        // Update: ONE fused combine+update sweep (no separate combine pass).
        times.time(Step::Update, || {
            ws.opt.fused_combine_step(pool, iter, &ws.attr, &ws.rep_raw, z, &mut ws.y)
        });
    }

    // The run's single un-permute back to the caller's point order.
    let y = ws.into_original_order();
    let kl = kl_with_z(p, &y, last_z.to_f64());
    (y, kl, cfg.n_iter, times)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_mixture;

    fn quick_cfg(n_iter: usize) -> TsneConfig {
        TsneConfig {
            perplexity: 10.0,
            n_iter,
            n_threads: 4,
            seed: 7,
            ..TsneConfig::default()
        }
    }

    #[test]
    fn all_implementations_produce_finite_embeddings() {
        let ds = gaussian_mixture::<f64>(400, 8, 5, 6.0, 1);
        for imp in Implementation::ALL {
            let r = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(60), imp);
            assert_eq!(r.embedding.len(), 2 * ds.n);
            assert!(
                r.embedding.iter().all(|v| v.is_finite()),
                "{} produced non-finite embedding",
                imp.name()
            );
            assert!(r.kl_divergence.is_finite(), "{}", imp.name());
            assert!(r.step_times.total() > 0.0);
        }
    }

    #[test]
    fn kl_decreases_with_more_iterations() {
        let ds = gaussian_mixture::<f64>(500, 10, 5, 8.0, 2);
        let short = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(30), Implementation::AccTsne);
        let long = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(300), Implementation::AccTsne);
        assert!(
            long.kl_divergence < short.kl_divergence,
            "KL: {} !< {}",
            long.kl_divergence,
            short.kl_divergence
        );
    }

    #[test]
    fn implementations_converge_to_similar_kl() {
        // Table 3's claim: same accuracy across implementations.
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 3);
        let cfg = quick_cfg(250);
        let accs: Vec<f64> = [Implementation::Daal4pyLike, Implementation::AccTsne]
            .iter()
            .map(|&imp| run_tsne(&ds.points, ds.n, ds.d, &cfg, imp).kl_divergence)
            .collect();
        let rel = (accs[0] - accs[1]).abs() / accs[0].max(accs[1]);
        assert!(rel < 0.25, "daal4py-like {} vs acc {}", accs[0], accs[1]);
    }

    #[test]
    fn separated_clusters_stay_separated_in_embedding() {
        let ds = gaussian_mixture::<f64>(300, 6, 3, 12.0, 4);
        let r = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(250), Implementation::AccTsne);
        // mean within-cluster distance < mean between-cluster distance
        let mut within = (0.0, 0usize);
        let mut between = (0.0, 0usize);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                let dx = r.embedding[2 * i] - r.embedding[2 * j];
                let dy = r.embedding[2 * i + 1] - r.embedding[2 * j + 1];
                let dist = (dx * dx + dy * dy).sqrt();
                if ds.labels[i] == ds.labels[j] {
                    within = (within.0 + dist, within.1 + 1);
                } else {
                    between = (between.0 + dist, between.1 + 1);
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(b > 1.5 * w, "between {b} vs within {w}");
    }

    #[test]
    fn f32_run_close_to_f64() {
        let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 5);
        let ds32 = ds.cast::<f32>();
        let cfg = quick_cfg(150);
        let r64 = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let r32 = run_tsne(&ds32.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let rel = (r64.kl_divergence - r32.kl_divergence as f64).abs() / r64.kl_divergence;
        assert!(rel < 0.15, "f64 {} vs f32 {}", r64.kl_divergence, r32.kl_divergence);
    }

    #[test]
    fn pca_init_converges_and_differs_from_random() {
        let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 9);
        let mut c = quick_cfg(80);
        c.init_pca = true;
        let r_pca = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
        c.init_pca = false;
        let r_rand = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
        assert!(r_pca.kl_divergence.is_finite());
        assert_ne!(r_pca.embedding, r_rand.embedding);
        // both converge to comparable quality
        let rel = (r_pca.kl_divergence - r_rand.kl_divergence).abs()
            / r_rand.kl_divergence.max(r_pca.kl_divergence);
        assert!(rel < 0.5, "pca {} vs random {}", r_pca.kl_divergence, r_rand.kl_divergence);
    }

    #[test]
    fn repulsive_variants_agree_through_pipeline() {
        // Full-pipeline parity over a short horizon: the kernels agree to FP
        // noise per iteration, so 10 descent steps cannot meaningfully
        // diverge (a long horizon would — descent is chaotic — which is why
        // this is NOT a convergence comparison). Also exercises the tiled
        // path's view/buffer reuse across iterations inside run_tsne.
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 11);
        let mut cfg = quick_cfg(10);
        cfg.repulsive = Some(RepulsiveVariant::Scalar);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        cfg.repulsive = Some(RepulsiveVariant::SimdTiled);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        assert!(a.embedding.iter().all(|v| v.is_finite()));
        for i in 0..a.embedding.len() {
            assert!(
                (a.embedding[i] - b.embedding[i]).abs() < 1e-6 * (1.0 + a.embedding[i].abs()),
                "idx {i}: scalar {} vs tiled {}",
                a.embedding[i],
                b.embedding[i]
            );
        }
    }

    #[test]
    fn zorder_layout_matches_original_layout_through_pipeline() {
        // The layout refactor's exact-parity contract over a short horizon
        // (same argument as repulsive_variants_agree_through_pipeline: per
        // iteration the two layouts differ only by FP summation order, so 10
        // descent steps cannot meaningfully diverge).
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 17);
        let mut cfg = quick_cfg(10);
        cfg.layout = Some(crate::tsne::Layout::Original);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        cfg.layout = Some(crate::tsne::Layout::Zorder);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        assert!(a.embedding.iter().all(|v| v.is_finite()));
        for i in 0..a.embedding.len() {
            assert!(
                (a.embedding[i] - b.embedding[i]).abs() < 1e-6 * (1.0 + a.embedding[i].abs()),
                "idx {i}: original {} vs zorder {}",
                a.embedding[i],
                b.embedding[i]
            );
        }
    }

    #[test]
    fn zorder_is_the_acc_tsne_default() {
        // No layout override must be bit-identical to an explicit Zorder.
        let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 18);
        let cfg = quick_cfg(8);
        let default_run = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let mut cfg_z = cfg;
        cfg_z.layout = Some(crate::tsne::Layout::Zorder);
        let explicit = run_tsne(&ds.points, ds.n, ds.d, &cfg_z, Implementation::AccTsne);
        assert_eq!(default_run.embedding, explicit.embedding);
    }

    #[test]
    fn fitsne_forces_original_layout() {
        // No tree ⇒ no Z-order: a zorder request must be a bit-identical
        // no-op, not a crash.
        let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 19);
        let mut cfg = quick_cfg(8);
        cfg.layout = Some(crate::tsne::Layout::Zorder);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::FitSne);
        cfg.layout = Some(crate::tsne::Layout::Original);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::FitSne);
        assert_eq!(a.embedding, b.embedding);
    }

    #[test]
    fn run_with_precomputed_p_matches_steps() {
        let ds = gaussian_mixture::<f64>(200, 6, 3, 6.0, 6);
        let pool = ThreadPool::new(4);
        let knn = BruteForceKnn::default().search(&pool, &ds.points, ds.n, ds.d, 30);
        let cond = binary_search_perplexity(&pool, &knn, 10.0, ParMode::Parallel);
        let p = symmetrize(&pool, &knn, &cond.p);
        let r = run_tsne_with_p(&pool, &p, &quick_cfg(50), Implementation::AccTsne);
        assert!(r.kl_divergence.is_finite());
        assert_eq!(r.step_times.get(Step::Knn), 0.0);
    }
}
