//! One-shot pipeline entry points — thin compat wrappers over the session
//! API, plus the pluggable attractive-engine trait.
//!
//! The machinery that used to live here moved behind the public staged types:
//! the private per-flavor knob table became [`StagePlan`](super::StagePlan)
//! (`tsne::plan`), and the gradient loop became
//! [`TsneSession`](super::TsneSession) (`tsne::session`), which owns the
//! Z-order-persistent [`IterationWorkspace`](super::workspace) and exposes
//! `step`/`run`/`run_until` plus an observer hook. [`run_tsne`] /
//! [`run_tsne_custom`] / [`run_tsne_with_p`] remain as the classic
//! fit-and-run calls and are **bit-identical** to fitting [`Affinities`] and
//! stepping a session manually (asserted by the parity tests): they resolve
//! the plan with the historical override semantics (`cfg.repulsive` /
//! `cfg.layout` applied on top of the preset; FIt-SNE silently ignores the
//! BH repulsive-kernel knob, and a layout override there is a no-op
//! permutation — the FFT path never adopts one), run `cfg.n_iter` steps, and
//! merge the affinity-fit KNN/BSP times into the result.

use super::plan::StagePlan;
use super::session::{Affinities, TsneSession};
use super::{Implementation, Scalar, TsneConfig, TsneResult};
use crate::gradient::attractive::{attractive_forces, Variant};
use crate::parallel::{pool::available_cores, ThreadPool};
use crate::sparse::CsrMatrix;

/// Pluggable attractive-force engine: native SIMD/scalar variants or the
/// AOT-compiled XLA artifact ([`crate::runtime::engines::XlaAttractive`]) —
/// the hook that lets the L1/L2 layers run inside the L3 hot path.
///
/// `compute` is always invoked from the coordinator thread (engines fan out
/// through the `pool` argument themselves if they want parallelism), so no
/// `Sync` bound: the PJRT executable handle is deliberately single-threaded.
pub trait AttractiveEngine<T: Scalar> {
    fn name(&self) -> &'static str;
    fn compute(&self, pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T], out: &mut [T]);
}

/// Default engine: the in-crate kernels of [`crate::gradient::attractive`].
pub struct NativeAttractive(pub Variant);

impl<T: Scalar> AttractiveEngine<T> for NativeAttractive {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn compute(&self, pool: &ThreadPool, p: &CsrMatrix<T>, y: &[T], out: &mut [T]) {
        attractive_forces(pool, p, y, self.0, out);
    }
}

/// Run t-SNE on `points` (n × d, row-major) with the given implementation.
pub fn run_tsne<T: Scalar>(
    points: &[T],
    n: usize,
    d: usize,
    cfg: &TsneConfig,
    imp: Implementation,
) -> TsneResult<T> {
    run_tsne_custom(points, n, d, cfg, imp, None)
}

/// As [`run_tsne`] but with an optional attractive-engine override (the
/// XLA-offload integration path).
///
/// Note for overrides under the `AccTsne` default ([`super::Layout::Zorder`]):
/// the engine sees the workspace's re-indexed `P` and Z-ordered `y`. The
/// per-row contract is unchanged, but an engine with a *baked* original
/// sparsity pattern (an AOT artifact) should be run with
/// `cfg.layout = Some(Layout::Original)` — see
/// [`TsneSession::set_attractive_engine`].
pub fn run_tsne_custom<T: Scalar>(
    points: &[T],
    n: usize,
    d: usize,
    cfg: &TsneConfig,
    imp: Implementation,
    attractive_override: Option<&dyn AttractiveEngine<T>>,
) -> TsneResult<T> {
    let plan = StagePlan::compat(imp, cfg);
    let nt = if cfg.n_threads == 0 { available_cores() } else { cfg.n_threads };

    // Phase 1: the affinity fit (KNN + BSP + symmetrize), once. The classic
    // wrappers predate the typed FitError and stay infallible in signature:
    // a hostile shape still fails loudly, but with the typed error's message
    // (callers that want a Result use Affinities::fit directly).
    let fit_pool = ThreadPool::new(nt);
    let aff = Affinities::fit(&fit_pool, points, n, d, cfg.perplexity, &plan)
        .unwrap_or_else(|e| panic!("run_tsne: {e}"));

    // Optional PCA initialization (sklearn init="pca": top-2 PCs scaled so
    // the largest component has std 1e-4, then descent as usual).
    let init = if cfg.init_pca {
        let (proj, _) = crate::data::pca::pca(&fit_pool, points, n, d, 2, 30, cfg.seed ^ 0x9CA);
        Some(scale_init(proj, n))
    } else {
        None
    };
    drop(fit_pool);

    // Phase 2: one full-budget session.
    let mut sess = match init {
        Some(y0) => TsneSession::with_init(&aff, plan, *cfg, y0),
        None => TsneSession::new(&aff, plan, *cfg),
    }
    .expect("compat-resolved preset plans always validate");
    if let Some(engine) = attractive_override {
        sess.set_attractive_engine(engine);
    }
    sess.run(cfg.n_iter);
    let mut result = sess.finish();
    result.step_times.merge(aff.step_times());
    result
}

/// Run only the gradient phase on a precomputed P (benches isolate steps with
/// this; also lets the table harnesses share one KNN across implementations).
/// `pool` supplies the thread count; the session owns its own pools.
///
/// Equivalent to `Affinities::from_csr_ref` + a full-budget session — the
/// caller's `P` is **borrowed**, never copied (the `Cow`-backed `Affinities`
/// closed the old per-call clone); callers that reuse the affinities across
/// several runs should still build them directly and amortize the structural
/// validation too.
pub fn run_tsne_with_p<T: Scalar>(
    pool: &ThreadPool,
    p: &CsrMatrix<T>,
    cfg: &TsneConfig,
    imp: Implementation,
) -> TsneResult<T> {
    let plan = StagePlan::compat(imp, cfg);
    let aff = Affinities::from_csr_ref(p, cfg.perplexity)
        .unwrap_or_else(|e| panic!("run_tsne_with_p: {e}"));
    let mut cfg = *cfg;
    cfg.n_threads = pool.n_threads();
    let mut sess =
        TsneSession::new(&aff, plan, cfg).expect("compat-resolved preset plans always validate");
    sess.run(cfg.n_iter);
    sess.finish()
}

/// PCA projection → init scaling: sklearn scales PC1 to std 1e-4.
fn scale_init<T: Scalar>(mut proj: Vec<T>, n: usize) -> Vec<T> {
    let mut var = 0.0f64;
    for i in 0..n {
        var += proj[2 * i].to_f64().powi(2);
    }
    let std = (var / n as f64).sqrt().max(f64::MIN_POSITIVE);
    let s = T::from_f64(1e-4 / std);
    for v in proj.iter_mut() {
        *v *= s;
    }
    proj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::timer::Step;
    use crate::data::synthetic::gaussian_mixture;
    use crate::gradient::repulsive::RepulsiveVariant;
    use crate::knn::{BruteForceKnn, KnnEngine};
    use crate::perplexity::{binary_search_perplexity, ParMode};
    use crate::sparse::symmetrize;

    fn quick_cfg(n_iter: usize) -> TsneConfig {
        TsneConfig {
            perplexity: 10.0,
            n_iter,
            n_threads: 4,
            seed: 7,
            ..TsneConfig::default()
        }
    }

    #[test]
    fn all_implementations_produce_finite_embeddings() {
        let ds = gaussian_mixture::<f64>(400, 8, 5, 6.0, 1);
        for imp in Implementation::ALL {
            let r = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(60), imp);
            assert_eq!(r.embedding.len(), 2 * ds.n);
            assert!(
                r.embedding.iter().all(|v| v.is_finite()),
                "{} produced non-finite embedding",
                imp.name()
            );
            assert!(r.kl_divergence.is_finite(), "{}", imp.name());
            assert!(r.step_times.total() > 0.0);
        }
    }

    #[test]
    fn kl_decreases_with_more_iterations() {
        let ds = gaussian_mixture::<f64>(500, 10, 5, 8.0, 2);
        let short = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(30), Implementation::AccTsne);
        let long = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(300), Implementation::AccTsne);
        assert!(
            long.kl_divergence < short.kl_divergence,
            "KL: {} !< {}",
            long.kl_divergence,
            short.kl_divergence
        );
    }

    #[test]
    fn implementations_converge_to_similar_kl() {
        // Table 3's claim: same accuracy across implementations.
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 3);
        let cfg = quick_cfg(250);
        let accs: Vec<f64> = [Implementation::Daal4pyLike, Implementation::AccTsne]
            .iter()
            .map(|&imp| run_tsne(&ds.points, ds.n, ds.d, &cfg, imp).kl_divergence)
            .collect();
        let rel = (accs[0] - accs[1]).abs() / accs[0].max(accs[1]);
        assert!(rel < 0.25, "daal4py-like {} vs acc {}", accs[0], accs[1]);
    }

    #[test]
    fn separated_clusters_stay_separated_in_embedding() {
        let ds = gaussian_mixture::<f64>(300, 6, 3, 12.0, 4);
        let r = run_tsne(&ds.points, ds.n, ds.d, &quick_cfg(250), Implementation::AccTsne);
        // mean within-cluster distance < mean between-cluster distance
        let mut within = (0.0, 0usize);
        let mut between = (0.0, 0usize);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                let dx = r.embedding[2 * i] - r.embedding[2 * j];
                let dy = r.embedding[2 * i + 1] - r.embedding[2 * j + 1];
                let dist = (dx * dx + dy * dy).sqrt();
                if ds.labels[i] == ds.labels[j] {
                    within = (within.0 + dist, within.1 + 1);
                } else {
                    between = (between.0 + dist, between.1 + 1);
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let b = between.0 / between.1 as f64;
        assert!(b > 1.5 * w, "between {b} vs within {w}");
    }

    #[test]
    fn f32_run_close_to_f64() {
        let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 5);
        let ds32 = ds.cast::<f32>();
        let cfg = quick_cfg(150);
        let r64 = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let r32 = run_tsne(&ds32.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let rel = (r64.kl_divergence - r32.kl_divergence as f64).abs() / r64.kl_divergence;
        assert!(rel < 0.15, "f64 {} vs f32 {}", r64.kl_divergence, r32.kl_divergence);
    }

    #[test]
    fn pca_init_converges_and_differs_from_random() {
        let ds = gaussian_mixture::<f64>(300, 8, 4, 8.0, 9);
        let mut c = quick_cfg(80);
        c.init_pca = true;
        let r_pca = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
        c.init_pca = false;
        let r_rand = run_tsne(&ds.points, ds.n, ds.d, &c, Implementation::AccTsne);
        assert!(r_pca.kl_divergence.is_finite());
        assert_ne!(r_pca.embedding, r_rand.embedding);
        // both converge to comparable quality
        let rel = (r_pca.kl_divergence - r_rand.kl_divergence).abs()
            / r_rand.kl_divergence.max(r_pca.kl_divergence);
        assert!(rel < 0.5, "pca {} vs random {}", r_pca.kl_divergence, r_rand.kl_divergence);
    }

    #[test]
    fn repulsive_variants_agree_through_pipeline() {
        // Full-pipeline parity over a short horizon: the kernels agree to FP
        // noise per iteration, so 10 descent steps cannot meaningfully
        // diverge (a long horizon would — descent is chaotic — which is why
        // this is NOT a convergence comparison). Also exercises the tiled
        // path's view/buffer reuse across iterations inside run_tsne.
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 11);
        let mut cfg = quick_cfg(10);
        cfg.repulsive = Some(RepulsiveVariant::Scalar);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        cfg.repulsive = Some(RepulsiveVariant::SimdTiled);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        assert!(a.embedding.iter().all(|v| v.is_finite()));
        for i in 0..a.embedding.len() {
            assert!(
                (a.embedding[i] - b.embedding[i]).abs() < 1e-6 * (1.0 + a.embedding[i].abs()),
                "idx {i}: scalar {} vs tiled {}",
                a.embedding[i],
                b.embedding[i]
            );
        }
    }

    #[test]
    fn zorder_layout_matches_original_layout_through_pipeline() {
        // The layout refactor's exact-parity contract over a short horizon
        // (same argument as repulsive_variants_agree_through_pipeline: per
        // iteration the two layouts differ only by FP summation order, so 10
        // descent steps cannot meaningfully diverge).
        let ds = gaussian_mixture::<f64>(400, 8, 4, 8.0, 17);
        let mut cfg = quick_cfg(10);
        cfg.layout = Some(crate::tsne::Layout::Original);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        cfg.layout = Some(crate::tsne::Layout::Zorder);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        assert!(a.embedding.iter().all(|v| v.is_finite()));
        for i in 0..a.embedding.len() {
            assert!(
                (a.embedding[i] - b.embedding[i]).abs() < 1e-6 * (1.0 + a.embedding[i].abs()),
                "idx {i}: original {} vs zorder {}",
                a.embedding[i],
                b.embedding[i]
            );
        }
    }

    #[test]
    fn zorder_is_the_acc_tsne_default() {
        // No layout override must be bit-identical to an explicit Zorder.
        let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 18);
        let cfg = quick_cfg(8);
        let default_run = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
        let mut cfg_z = cfg;
        cfg_z.layout = Some(crate::tsne::Layout::Zorder);
        let explicit = run_tsne(&ds.points, ds.n, ds.d, &cfg_z, Implementation::AccTsne);
        assert_eq!(default_run.embedding, explicit.embedding);
    }

    #[test]
    fn fitsne_zorder_request_is_a_bit_identical_no_op() {
        // The FFT path builds no tree, so a Zorder plan never adopts a
        // permutation: through the compat wrapper a zorder request runs the
        // exact same trajectory as the original layout, bit for bit (the
        // combination is a legal plan since the layout lift).
        let ds = gaussian_mixture::<f64>(300, 6, 3, 6.0, 19);
        let mut cfg = quick_cfg(8);
        cfg.layout = Some(crate::tsne::Layout::Zorder);
        let a = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::FitSne);
        cfg.layout = Some(crate::tsne::Layout::Original);
        let b = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::FitSne);
        assert_eq!(a.embedding, b.embedding);
    }

    #[test]
    fn run_with_precomputed_p_matches_steps() {
        let ds = gaussian_mixture::<f64>(200, 6, 3, 6.0, 6);
        let pool = ThreadPool::new(4);
        let knn = BruteForceKnn::default().search(&pool, &ds.points, ds.n, ds.d, 30);
        let cond = binary_search_perplexity(&pool, &knn, 10.0, ParMode::Parallel);
        let p = symmetrize(&pool, &knn, &cond.p);
        let r = run_tsne_with_p(&pool, &p, &quick_cfg(50), Implementation::AccTsne);
        assert!(r.kl_divergence.is_finite());
        assert_eq!(r.step_times.get(Step::Knn), 0.0);
    }

    #[test]
    fn compat_wrapper_is_bit_identical_to_a_manually_stepped_session() {
        // THE compat contract of the API redesign: run_tsne == fit Affinities
        // + step a TsneSession cfg.n_iter times + finish, bit for bit.
        let ds = gaussian_mixture::<f64>(400, 8, 5, 6.0, 23);
        let cfg = quick_cfg(40);
        let wrapper = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);

        let plan = StagePlan::acc_tsne();
        let pool = ThreadPool::new(cfg.n_threads);
        let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
            .expect("valid fit");
        let mut sess = TsneSession::new(&aff, plan, cfg).unwrap();
        for _ in 0..cfg.n_iter {
            sess.step().expect("healthy step");
        }
        let manual = sess.finish();

        assert_eq!(wrapper.embedding, manual.embedding, "embeddings must be bit-identical");
        assert_eq!(wrapper.kl_divergence, manual.kl_divergence);
        assert_eq!(wrapper.n_iter, manual.n_iter);
        assert_eq!(wrapper.implementation, manual.implementation);
    }

    #[test]
    fn with_p_wrapper_matches_session_over_shared_affinities() {
        let ds = gaussian_mixture::<f64>(200, 6, 3, 6.0, 29);
        let pool = ThreadPool::new(4);
        let cfg = quick_cfg(30);
        let plan = StagePlan::acc_tsne();
        let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
            .expect("valid fit");
        let wrapper = run_tsne_with_p(&pool, aff.p(), &cfg, Implementation::AccTsne);
        let mut sess = TsneSession::new(&aff, plan, cfg).unwrap();
        sess.run(cfg.n_iter);
        let manual = sess.finish();
        assert_eq!(wrapper.embedding, manual.embedding);
        assert_eq!(wrapper.kl_divergence, manual.kl_divergence);
    }
}
