//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `acc-tsne <subcommand> [--flag value | --switch]...`.
//! Flags are typed at the call site: [`Args::get`], [`Args::get_parse`],
//! [`Args::has`]. Unknown flags are rejected so typos fail loudly.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags take the next token as value unless it starts
    /// with `--` (then they're switches).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut subcommand = None;
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.push(name.to_string());
                    i += 1;
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok.clone());
                i += 1;
            } else {
                return Err(format!("unexpected positional argument '{tok}'"));
            }
        }
        Ok(Args {
            subcommand,
            flags,
            switches,
        })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Typed flag with default; errors on unparseable values, forwarding the
    /// `FromStr` error (which for the crate's enums lists the valid choices).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name}: cannot parse '{v}': {e}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Reject flags/switches outside the allowed set (catches typos).
    pub fn ensure_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k} (allowed: {})", allowed.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&argv("run --dataset mnist --iters 100 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("mnist"));
        assert_eq!(a.get_parse::<usize>("iters", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn default_when_missing() {
        let a = Args::parse(&argv("run")).unwrap();
        assert_eq!(a.get_parse::<f64>("scale", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn rejects_bad_value() {
        let a = Args::parse(&argv("run --iters banana")).unwrap();
        assert!(a.get_parse::<usize>("iters", 1).is_err());
    }

    #[test]
    fn rejects_extra_positional() {
        assert!(Args::parse(&argv("run stray")).is_err());
    }

    #[test]
    fn ensure_known_catches_typos() {
        let a = Args::parse(&argv("run --datset mnist")).unwrap();
        assert!(a.ensure_known(&["dataset"]).is_err());
        let b = Args::parse(&argv("run --dataset mnist")).unwrap();
        assert!(b.ensure_known(&["dataset"]).is_ok());
    }
}
