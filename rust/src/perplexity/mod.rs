//! Binary-search perplexity (pipeline step 2, paper §3.2).
//!
//! For each point i, finds the Gaussian precision β_i = 1/2σ_i² such that the
//! conditional distribution over its ⌊3u⌋ KNN distances has perplexity u, then
//! emits the row-normalized conditionals p_{j|i} (Eq. 2).
//!
//! The paper's key observation: rows are independent, and prior
//! implementations (sklearn/daal4py) compute them sequentially; Acc-t-SNE
//! multithreads them (with Numba there, with our pool here). Both variants are
//! kept so the BSP rows of Tables 5/6 can be regenerated.

use crate::common::float::Real;
use crate::knn::NeighborLists;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Max binary-search iterations (vdMaaten's reference uses 50).
const MAX_ITER: usize = 50;
/// Entropy tolerance.
const TOL: f64 = 1e-5;

/// Run mode for baseline-vs-ours step comparisons.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParMode {
    /// Prior implementations: one thread walks all rows.
    Sequential,
    /// Acc-t-SNE: rows distributed across the pool.
    Parallel,
}

/// Result of the BSP step.
#[derive(Clone, Debug)]
pub struct Conditionals<T: Real> {
    /// Row-normalized conditional probabilities, aligned with
    /// `NeighborLists::indices` (n × k).
    pub p: Vec<T>,
    /// Fitted precisions β_i.
    pub betas: Vec<T>,
}

/// Solve one row: binary search β so that perplexity(p_{·|i}) = `perplexity`.
/// Writes normalized conditionals into `out` and returns β.
///
/// Matches the vdMaaten/sklearn `_binary_search_perplexity` logic: H computed
/// in nats, β doubled/halved until bracketed, then bisected.
pub fn bsp_row<T: Real>(dist_sq: &[T], perplexity: f64, out: &mut [T]) -> T {
    bsp_row_checked(dist_sq, perplexity, out).0
}

/// `bsp_row` plus an explicit convergence flag.
///
/// When the entropy search converges, the output is bit-identical to what
/// `bsp_row` has always produced. When it does not — the β bracket saturates
/// (all-equal or all-zero distances make the entropy flat in β), the
/// arithmetic goes non-finite, or the total probability mass underflows the
/// `T::TINY` clamp — the row degrades to the uniform distribution
/// `1/k` with a finite fallback β of 1, instead of whatever the last bisection
/// step left behind. Returns `(β, converged)`.
pub fn bsp_row_checked<T: Real>(dist_sq: &[T], perplexity: f64, out: &mut [T]) -> (T, bool) {
    debug_assert_eq!(dist_sq.len(), out.len());
    let desired_entropy = T::from_f64(perplexity.ln());
    let mut beta = T::ONE;
    let mut beta_min = T::MIN_REAL; // acts as -inf sentinel
    let mut beta_max = T::MAX_REAL; // +inf sentinel
    let tol = T::from_f64(TOL);
    let mut converged = false;

    for _ in 0..MAX_ITER {
        // p_j = exp(-β d_j²); accumulate Σp and Σ β d² p for the entropy.
        let mut sum_p = T::ZERO;
        let mut sum_disp = T::ZERO;
        for (o, &dsq) in out.iter_mut().zip(dist_sq.iter()) {
            let p = (-beta * dsq).exp();
            *o = p;
            sum_p += p;
            sum_disp += dsq * p;
        }
        let sum_p = sum_p.max_r(T::TINY);
        // H = ln Σp + β · (Σ d² p) / Σp
        let entropy = sum_p.ln() + beta * sum_disp / sum_p;
        let diff = entropy - desired_entropy;
        if diff.abs() <= tol {
            converged = true;
            break;
        }
        if diff > T::ZERO {
            // entropy too high → distribution too flat → increase β
            beta_min = beta;
            if beta_max == T::MAX_REAL {
                beta *= T::TWO;
            } else {
                beta = (beta + beta_max) * T::HALF;
            }
        } else {
            beta_max = beta;
            if beta_min == T::MIN_REAL {
                beta *= T::HALF;
            } else {
                beta = (beta + beta_min) * T::HALF;
            }
        }
    }
    // Normalize the final p row.
    let mut sum_p = T::ZERO;
    for (o, &dsq) in out.iter_mut().zip(dist_sq.iter()) {
        let p = (-beta * dsq).exp();
        *o = p;
        sum_p += p;
    }
    let inv = T::ONE / sum_p.max_r(T::TINY);
    // Underflowed mass is non-convergence: once Σp falls to the T::TINY
    // clamp, the entropy the search matched is an artifact of the clamp
    // (ln TINY + β·Σd²p/TINY sweeps through every target as Σp → TINY) and
    // the row cannot renormalize to mass 1.
    let mut finite = beta.is_finite_r() && sum_p > T::TINY;
    for o in out.iter_mut() {
        *o *= inv;
        finite = finite && o.is_finite_r();
    }
    if converged && finite {
        return (beta, true);
    }
    // Graceful degradation: uniform row, finite β. NaN conditionals would
    // otherwise poison the symmetrized P matrix and every later stage.
    let uniform = T::ONE / T::from_usize(out.len().max(1));
    for o in out.iter_mut() {
        *o = uniform;
    }
    (T::ONE, false)
}

/// BSP over all points (paper step 2).
pub fn binary_search_perplexity<T: Real>(
    pool: &ThreadPool,
    knn: &NeighborLists<T>,
    perplexity: f64,
    mode: ParMode,
) -> Conditionals<T> {
    let n = knn.n;
    let k = knn.k;
    // Last-resort contract check: the public fitting API (tsne::Affinities)
    // validates this at its boundary and returns FitError::PerplexityTooLarge
    // before ever reaching here.
    assert!(
        perplexity <= k as f64,
        "perplexity {perplexity} needs at least {} neighbors, have {k}",
        perplexity.ceil() as usize
    );
    let mut p = vec![T::ZERO; n * k];
    let mut betas = vec![T::ZERO; n];
    match mode {
        ParMode::Sequential => {
            for i in 0..n {
                betas[i] = bsp_row(knn.dists(i), perplexity, &mut p[i * k..(i + 1) * k]);
            }
        }
        ParMode::Parallel => {
            let ps = SyncSlice::new(&mut p);
            let bs = SyncSlice::new(&mut betas);
            parallel_for(pool, n, Schedule::Static, |range| {
                for i in range {
                    // SAFETY: disjoint — row i and slot i
                    let row = unsafe { ps.slice_mut(i * k, k) };
                    let beta = bsp_row(knn.dists(i), perplexity, row);
                    // SAFETY: disjoint — slot i
                    unsafe { *bs.get_mut(i) = beta };
                }
            });
        }
    }
    Conditionals { p, betas }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::knn::{BruteForceKnn, KnnEngine};

    fn perplexity_of(p: &[f64]) -> f64 {
        let h: f64 = p
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| -x * x.ln())
            .sum();
        h.exp()
    }

    #[test]
    fn row_hits_target_perplexity() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let k = 30;
            let dists: Vec<f64> = (0..k).map(|_| rng.next_f64() * 10.0 + 0.01).collect();
            let mut out = vec![0.0; k];
            bsp_row(&dists, 10.0, &mut out);
            let u = perplexity_of(&out);
            assert!((u - 10.0).abs() < 0.01, "perplexity {u}");
            assert!((out.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn closer_points_get_higher_p() {
        let dists = vec![0.1, 0.5, 1.0, 2.0, 4.0, 8.0];
        let mut out = vec![0.0; 6];
        bsp_row(&dists, 3.0, &mut out);
        assert!(out.windows(2).all(|w| w[0] >= w[1]), "{out:?}");
    }

    #[test]
    fn beta_adapts_to_density() {
        // Dense region (small distances) → larger β than sparse region.
        let mut dense_out = vec![0.0; 10];
        let mut sparse_out = vec![0.0; 10];
        let dense: Vec<f64> = (1..=10).map(|i| 0.01 * i as f64).collect();
        let sparse: Vec<f64> = (1..=10).map(|i| 10.0 * i as f64).collect();
        let b_dense = bsp_row(&dense, 5.0, &mut dense_out);
        let b_sparse = bsp_row(&sparse, 5.0, &mut sparse_out);
        assert!(b_dense > b_sparse * 10.0, "{b_dense} vs {b_sparse}");
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let mut rng = Rng::new(2);
        let n = 150;
        let d = 6;
        let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(4);
        let knn = BruteForceKnn::default().search(&pool, &data, n, d, 20);
        let seq = binary_search_perplexity(&pool, &knn, 6.0, ParMode::Sequential);
        let par = binary_search_perplexity(&pool, &knn, 6.0, ParMode::Parallel);
        assert_eq!(seq.p, par.p);
        assert_eq!(seq.betas, par.betas);
    }

    #[test]
    fn f32_also_converges() {
        let mut rng = Rng::new(3);
        let k = 24;
        let dists: Vec<f32> = (0..k).map(|_| (rng.next_f64() * 5.0 + 0.1) as f32).collect();
        let mut out = vec![0.0f32; k];
        bsp_row(&dists, 8.0, &mut out);
        let u = perplexity_of(&out.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!((u - 8.0).abs() < 0.05, "perplexity {u}");
    }

    #[test]
    fn all_zero_distances_yield_finite_uniform_row() {
        // Duplicate-heavy data puts all-zero squared distances in a row: the
        // Gaussian is flat at every β, the entropy search saturates, and the
        // row must still come out finite and uniform — never NaN.
        for k in [1usize, 2, 12, 64] {
            let dists = vec![0.0f64; k];
            let mut out = vec![-1.0; k];
            let beta = bsp_row(&dists, (k as f64).min(5.0).max(1.0), &mut out);
            assert!(beta.is_finite(), "k = {k}: beta = {beta}");
            let want = 1.0 / k as f64;
            for (j, &p) in out.iter().enumerate() {
                assert!(p.is_finite(), "k = {k} pos {j}: {p}");
                assert!((p - want).abs() < 1e-12, "k = {k} pos {j}: {p} != {want}");
            }
        }
    }

    #[test]
    fn checked_row_flags_uniform_fallback_on_flat_entropy() {
        // All-equal distances make the conditional distribution uniform at
        // every β: the entropy is pinned at ln k and the search can only
        // converge when the target perplexity is exactly k. Off-target rows
        // must degrade to the explicit uniform fallback, never garbage β.
        let dists = vec![3.25f64; 16];
        let mut out = vec![0.0; 16];
        let (beta, converged) = bsp_row_checked(&dists, 5.0, &mut out);
        assert!(!converged);
        assert_eq!(beta, 1.0);
        for &p in &out {
            assert_eq!(p, 1.0 / 16.0);
        }
    }

    #[test]
    fn checked_row_matches_unchecked_on_converging_input() {
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let k = 25;
            let dists: Vec<f64> = (0..k).map(|_| rng.next_f64() * 8.0 + 0.05).collect();
            let mut a = vec![0.0; k];
            let mut b = vec![0.0; k];
            let beta_a = bsp_row(&dists, 9.0, &mut a);
            let (beta_b, converged) = bsp_row_checked(&dists, 9.0, &mut b);
            assert!(converged);
            assert_eq!(beta_a, beta_b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn extreme_dynamic_range_stays_finite() {
        // 1e±30 distances overflow exp(-β d²) toward 0/1 long before the
        // bracket settles; whichever way the search ends, the row and β must
        // be finite.
        let dists = vec![1e30f64, 1e30, 1e-30, 1e-30, 1.0, 2.0];
        let mut out = vec![0.0; 6];
        let (beta, _) = bsp_row_checked(&dists, 3.0, &mut out);
        assert!(beta.is_finite(), "beta = {beta}");
        let sum: f64 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum = {sum}");
        for &p in &out {
            assert!(p.is_finite() && p >= 0.0, "p = {p}");
        }
    }

    #[test]
    fn assert_message_names_the_neighbor_requirement_not_the_perplexity_twice() {
        // The old message interpolated `perplexity` into both holes
        // ("perplexity 30 needs at least 30 neighbors"), which only read
        // sensibly by accident; the requirement is ⌈perplexity⌉ neighbors.
        let r = std::panic::catch_unwind(|| {
            let pool = ThreadPool::new(1);
            let knn = NeighborLists::<f64> {
                n: 4,
                k: 2,
                indices: vec![1, 2, 0, 2, 0, 1, 0, 1],
                distances_sq: vec![1.0; 8],
            };
            binary_search_perplexity(&pool, &knn, 7.5, ParMode::Sequential);
        });
        let err = r.expect_err("must still panic at this internal boundary");
        let msg = err.downcast_ref::<String>().expect("panic carries a String");
        assert!(msg.contains("needs at least 8 neighbors"), "{msg}");
        assert!(msg.contains("have 2"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "perplexity")]
    fn rejects_perplexity_above_k() {
        let pool = ThreadPool::new(1);
        let knn = NeighborLists::<f64> {
            n: 4,
            k: 2,
            indices: vec![1, 2, 0, 2, 0, 1, 0, 1],
            distances_sq: vec![1.0; 8],
        };
        binary_search_perplexity(&pool, &knn, 30.0, ParMode::Parallel);
    }
}
