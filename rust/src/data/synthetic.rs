//! Synthetic dataset generators.
//!
//! Two families:
//! - [`gaussian_mixture`] — k isotropic clusters in d dims; the workhorse
//!   analog for the image datasets (MNIST/CIFAR/...): t-SNE sees cluster
//!   structure, not pixels.
//! - [`scrna_like`] — single-cell RNA-seq analog for the mouse-brain dataset:
//!   anisotropic log-normal clusters of very unequal sizes plus dropout
//!   sparsity, then (in [`super::datasets`]) reduced with our PCA to 20 PCs
//!   like the paper's pipeline. The unequal cluster mass is what stresses the
//!   quadtree balance — the property the paper's dynamic scheduling targets.

use super::Dataset;
use crate::common::float::Real;
use crate::common::rng::Rng;

/// `k` Gaussian clusters in `d` dims. `separation` scales the distance between
/// cluster centers relative to the unit within-cluster spread.
pub fn gaussian_mixture<T: Real>(
    n: usize,
    d: usize,
    k: usize,
    separation: f64,
    seed: u64,
) -> Dataset<T> {
    assert!(n > 0 && d > 0 && k > 0);
    let mut rng = Rng::new(seed);
    let centers: Vec<f64> = (0..k * d).map(|_| rng.next_gaussian() * separation).collect();
    let mut points = vec![T::ZERO; n * d];
    let mut labels = vec![0u16; n];
    for i in 0..n {
        let c = i % k; // balanced clusters
        labels[i] = c as u16;
        for j in 0..d {
            points[i * d + j] = T::from_f64(centers[c * d + j] + rng.next_gaussian());
        }
    }
    Dataset::try_new(format!("gmm-n{n}-d{d}-k{k}"), points, labels, n, d)
        .expect("gaussian_mixture must generate finite data (separation too large?)")
}

/// scRNA-seq-like generator: `k` clusters with Zipf-ish sizes, per-cluster
/// anisotropic scales, log-normal expression, and `dropout` probability of
/// zeroing an entry (the defining sparsity of scRNA counts).
pub fn scrna_like<T: Real>(
    n: usize,
    genes: usize,
    k: usize,
    dropout: f64,
    seed: u64,
) -> Dataset<T> {
    assert!(n > 0 && genes > 0 && k > 0);
    let mut rng = Rng::new(seed);
    // Zipf-like cluster weights → very unbalanced cluster sizes.
    let weights: Vec<f64> = (1..=k).map(|i| 1.0 / i as f64).collect();
    let wsum: f64 = weights.iter().sum();
    let mut assignment: Vec<u16> = Vec::with_capacity(n);
    for c in 0..k {
        let cnt = ((weights[c] / wsum) * n as f64).ceil() as usize;
        for _ in 0..cnt {
            if assignment.len() < n {
                assignment.push(c as u16);
            }
        }
    }
    while assignment.len() < n {
        assignment.push(0);
    }
    rng.shuffle(&mut assignment);

    let centers: Vec<f64> = (0..k * genes).map(|_| rng.next_gaussian() * 2.0).collect();
    let scales: Vec<f64> = (0..k).map(|_| 0.5 + rng.next_f64()).collect();
    let mut points = vec![T::ZERO; n * genes];
    for i in 0..n {
        let c = assignment[i] as usize;
        for j in 0..genes {
            if rng.next_f64() < dropout {
                continue; // dropout: entry stays zero
            }
            // log-normal-ish expression around the cluster center
            let v = (centers[c * genes + j] + scales[c] * rng.next_gaussian()).exp().ln_1p();
            points[i * genes + j] = T::from_f64(v);
        }
    }
    Dataset::try_new(format!("scrna-n{n}-g{genes}-k{k}"), points, assignment, n, genes)
        .expect("scrna_like must generate finite expression values")
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmm_shapes_and_labels() {
        let ds = gaussian_mixture::<f64>(100, 8, 5, 4.0, 1);
        assert_eq!(ds.n, 100);
        assert_eq!(ds.d, 8);
        assert_eq!(ds.points.len(), 800);
        assert!(ds.labels.iter().all(|&l| l < 5));
        // every cluster present
        for c in 0..5u16 {
            assert!(ds.labels.contains(&c));
        }
    }

    #[test]
    fn gmm_deterministic() {
        let a = gaussian_mixture::<f64>(50, 4, 3, 2.0, 7);
        let b = gaussian_mixture::<f64>(50, 4, 3, 2.0, 7);
        assert_eq!(a.points, b.points);
        let c = gaussian_mixture::<f64>(50, 4, 3, 2.0, 8);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn gmm_clusters_are_separated() {
        // With large separation, within-cluster distance << between-cluster.
        let ds = gaussian_mixture::<f64>(200, 16, 4, 10.0, 3);
        let dist = |a: usize, b: usize| -> f64 {
            ds.row(a)
                .iter()
                .zip(ds.row(b))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        let mut within = 0.0;
        let mut between = 0.0;
        let mut nw = 0;
        let mut nb = 0;
        for i in 0..50 {
            for j in (i + 1)..50 {
                if ds.labels[i] == ds.labels[j] {
                    within += dist(i, j);
                    nw += 1;
                } else {
                    between += dist(i, j);
                    nb += 1;
                }
            }
        }
        assert!(between / nb as f64 > 2.0 * within / nw as f64);
    }

    #[test]
    fn scrna_unbalanced_and_sparse() {
        let ds = scrna_like::<f64>(1000, 50, 8, 0.5, 11);
        assert_eq!(ds.n, 1000);
        // cluster 0 (heaviest Zipf weight) much larger than cluster 7
        let count = |c: u16| ds.labels.iter().filter(|&&l| l == c).count();
        assert!(count(0) > 2 * count(7), "zipf imbalance expected");
        // dropout produces many exact zeros
        let zeros = ds.points.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros as f64 > 0.3 * ds.points.len() as f64);
        // but data is not all zero
        assert!(zeros < ds.points.len());
    }
}
