//! Datasets: synthetic generators shaped like the paper's six benchmarks,
//! a PCA substrate (the scRNA pipeline preprocesses with PCA → 20 PCs),
//! and simple IO for embeddings/results.
//!
//! The paper's datasets (MNIST, CIFAR-10, mouse brain 1.3M, …) are not
//! available offline; per the substitution rule we generate shape-matched
//! Gaussian-mixture datasets — t-SNE's cost profile depends on N, D, K and
//! embedding geometry, not on pixel content (see DESIGN.md §Substitutions).

pub mod datasets;
pub mod io;
pub mod pca;
pub mod synthetic;

use crate::common::float::Real;

/// Typed rejection of hostile input data, raised at the loader boundary so a
/// NaN in a CSV (or a mis-shaped buffer) never reaches the fitting pipeline.
/// [`crate::tsne::FitError`] has a lossless `From` conversion for it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataError {
    /// `points.len()` is not `n * d` (or `n * d` overflows `usize`).
    Shape { n: usize, d: usize, len: usize },
    /// First NaN/±inf in the data, by point and feature index.
    NonFinite { row: usize, col: usize },
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DataError::Shape { n, d, len } => {
                write!(f, "points length {len} does not match {n} points x {d} dims")
            }
            DataError::NonFinite { row, col } => write!(
                f,
                "input contains a non-finite value at point {row}, dimension {col} \
                 (clean the data before fitting)"
            ),
        }
    }
}

impl std::error::Error for DataError {}

/// Locate the first non-finite entry of a row-major `n x d` buffer, reported
/// as `(row, col)`.
pub fn first_non_finite<T: Real>(points: &[T], d: usize) -> Option<(usize, usize)> {
    points
        .iter()
        .position(|v| !v.is_finite_r())
        .map(|i| (i / d.max(1), i % d.max(1)))
}

/// An in-memory dataset: `n` points × `d` features, row-major, with class
/// labels (used only for coloring the S1–S6 plots, never by the algorithm).
#[derive(Clone, Debug)]
pub struct Dataset<T: Real> {
    pub name: String,
    pub points: Vec<T>,
    pub labels: Vec<u16>,
    pub n: usize,
    pub d: usize,
}

impl<T: Real> Dataset<T> {
    pub fn new(
        name: impl Into<String>,
        points: Vec<T>,
        labels: Vec<u16>,
        n: usize,
        d: usize,
    ) -> Self {
        assert_eq!(points.len(), n * d, "points length must be n*d");
        assert_eq!(labels.len(), n, "labels length must be n");
        Dataset {
            name: name.into(),
            points,
            labels,
            n,
            d,
        }
    }

    /// Validated constructor for externally-sourced data: rejects mis-shaped
    /// buffers and non-finite values instead of panicking or letting NaN
    /// propagate into `fit`. Labels must still be caller-consistent (they are
    /// produced by our own loaders, never parsed from hostile input).
    pub fn try_new(
        name: impl Into<String>,
        points: Vec<T>,
        labels: Vec<u16>,
        n: usize,
        d: usize,
    ) -> Result<Self, DataError> {
        if n.checked_mul(d) != Some(points.len()) {
            return Err(DataError::Shape {
                n,
                d,
                len: points.len(),
            });
        }
        assert_eq!(labels.len(), n, "labels length must be n");
        if let Some((row, col)) = first_non_finite(&points, d) {
            return Err(DataError::NonFinite { row, col });
        }
        Ok(Dataset {
            name: name.into(),
            points,
            labels,
            n,
            d,
        })
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.points[i * self.d..(i + 1) * self.d]
    }

    /// Convert precision (f64 dataset → f32 run for Table S1).
    pub fn cast<U: Real>(&self) -> Dataset<U> {
        Dataset {
            name: self.name.clone(),
            points: self.points.iter().map(|&v| U::from_f64(v.to_f64())).collect(),
            labels: self.labels.clone(),
            n: self.n,
            d: self.d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access() {
        let ds = Dataset::new("t", vec![1.0f64, 2.0, 3.0, 4.0], vec![0, 1], 2, 2);
        assert_eq!(ds.row(0), &[1.0, 2.0]);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn cast_roundtrip() {
        let ds = Dataset::new("t", vec![1.5f64, -2.5], vec![0], 1, 2);
        let f32ds: Dataset<f32> = ds.cast();
        assert_eq!(f32ds.points, vec![1.5f32, -2.5]);
        assert_eq!(f32ds.name, "t");
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new("t", vec![1.0f64; 5], vec![0, 1], 2, 2);
    }

    #[test]
    fn try_new_rejects_shape_and_non_finite() {
        assert_eq!(
            Dataset::try_new("t", vec![1.0f64; 5], vec![0, 1], 2, 2).unwrap_err(),
            DataError::Shape { n: 2, d: 2, len: 5 }
        );
        let mut pts = vec![0.25f64; 6];
        pts[5] = f64::NAN;
        assert_eq!(
            Dataset::try_new("t", pts, vec![0, 1, 2], 3, 2).unwrap_err(),
            DataError::NonFinite { row: 2, col: 1 }
        );
        let ds = Dataset::try_new("t", vec![0.25f64; 6], vec![0, 1, 2], 3, 2).unwrap();
        assert_eq!(ds.n, 3);
        let msg = DataError::NonFinite { row: 2, col: 1 }.to_string();
        assert!(msg.contains("point 2") && msg.contains("dimension 1"), "{msg}");
    }
}
