//! Datasets: synthetic generators shaped like the paper's six benchmarks,
//! a PCA substrate (the scRNA pipeline preprocesses with PCA → 20 PCs),
//! and simple IO for embeddings/results.
//!
//! The paper's datasets (MNIST, CIFAR-10, mouse brain 1.3M, …) are not
//! available offline; per the substitution rule we generate shape-matched
//! Gaussian-mixture datasets — t-SNE's cost profile depends on N, D, K and
//! embedding geometry, not on pixel content (see DESIGN.md §Substitutions).

pub mod datasets;
pub mod io;
pub mod pca;
pub mod synthetic;

use crate::common::float::Real;

/// An in-memory dataset: `n` points × `d` features, row-major, with class
/// labels (used only for coloring the S1–S6 plots, never by the algorithm).
#[derive(Clone, Debug)]
pub struct Dataset<T: Real> {
    pub name: String,
    pub points: Vec<T>,
    pub labels: Vec<u16>,
    pub n: usize,
    pub d: usize,
}

impl<T: Real> Dataset<T> {
    pub fn new(
        name: impl Into<String>,
        points: Vec<T>,
        labels: Vec<u16>,
        n: usize,
        d: usize,
    ) -> Self {
        assert_eq!(points.len(), n * d, "points length must be n*d");
        assert_eq!(labels.len(), n, "labels length must be n");
        Dataset {
            name: name.into(),
            points,
            labels,
            n,
            d,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.points[i * self.d..(i + 1) * self.d]
    }

    /// Convert precision (f64 dataset → f32 run for Table S1).
    pub fn cast<U: Real>(&self) -> Dataset<U> {
        Dataset {
            name: self.name.clone(),
            points: self.points.iter().map(|&v| U::from_f64(v.to_f64())).collect(),
            labels: self.labels.clone(),
            n: self.n,
            d: self.d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access() {
        let ds = Dataset::new("t", vec![1.0f64, 2.0, 3.0, 4.0], vec![0, 1], 2, 2);
        assert_eq!(ds.row(0), &[1.0, 2.0]);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn cast_roundtrip() {
        let ds = Dataset::new("t", vec![1.5f64, -2.5], vec![0], 1, 2);
        let f32ds: Dataset<f32> = ds.cast();
        assert_eq!(f32ds.points, vec![1.5f32, -2.5]);
        assert_eq!(f32ds.name, "t");
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new("t", vec![1.0f64; 5], vec![0, 1], 2, 2);
    }
}
