//! Principal component analysis by subspace (block power) iteration.
//!
//! The paper's mouse-brain pipeline runs t-SNE on the first 20 principal
//! components of the scRNA matrix; this module is that preprocessing
//! substrate. Covariance-based: G = Xcᵀ·Xc / (n-1) built in parallel, then
//! block power iteration with Gram–Schmidt orthonormalization.

use crate::common::float::Real;
use crate::common::rng::Rng;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Project `data` (n×d row-major) onto its top-`k` principal components.
/// Returns (projected n×k, explained variance per component).
pub fn pca<T: Real>(
    pool: &ThreadPool,
    data: &[T],
    n: usize,
    d: usize,
    k: usize,
    iters: usize,
    seed: u64,
) -> (Vec<T>, Vec<f64>) {
    assert_eq!(data.len(), n * d);
    assert!(k <= d, "k must be <= d");
    // Column means.
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            mean[j] += data[i * d + j].to_f64();
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    // Covariance G = (X - mean)ᵀ (X - mean) / (n - 1), parallel over rows of G.
    let mut g = vec![0.0f64; d * d];
    {
        let gs = SyncSlice::new(&mut g);
        parallel_for(pool, d, Schedule::Dynamic { grain: 8 }, |range| {
            for a in range {
                // SAFETY: disjoint — row `a` of G is owned by this iteration
                let row = unsafe { gs.slice_mut(a * d, d) };
                for i in 0..n {
                    let xa = data[i * d + a].to_f64() - mean[a];
                    if xa == 0.0 {
                        continue;
                    }
                    for b in 0..d {
                        row[b] += xa * (data[i * d + b].to_f64() - mean[b]);
                    }
                }
                let denom = (n.max(2) - 1) as f64;
                for v in row.iter_mut() {
                    *v /= denom;
                }
            }
        });
    }
    // Block power iteration on G for the top-k eigenvectors.
    let mut rng = Rng::new(seed);
    let mut v: Vec<f64> = (0..k * d).map(|_| rng.next_gaussian()).collect();
    orthonormalize(&mut v, k, d);
    let mut gv = vec![0.0f64; k * d];
    for _ in 0..iters {
        // gv = G · vᵀ per component (G symmetric)
        {
            let gvs = SyncSlice::new(&mut gv);
            let v_ref = &v;
            parallel_for(pool, k * d, Schedule::Static, |range| {
                for idx in range {
                    let c = idx / d;
                    let row = idx % d;
                    let mut acc = 0.0;
                    for b in 0..d {
                        acc += g[row * d + b] * v_ref[c * d + b];
                    }
                    // SAFETY: disjoint — one slot per idx
                    unsafe { *gvs.get_mut(idx) = acc };
                }
            });
        }
        std::mem::swap(&mut v, &mut gv);
        orthonormalize(&mut v, k, d);
    }
    // Eigenvalues (explained variance): λ_c = v_cᵀ G v_c
    let mut eigvals = vec![0.0f64; k];
    for c in 0..k {
        let vc = &v[c * d..(c + 1) * d];
        let mut acc = 0.0;
        for a in 0..d {
            let mut dot = 0.0;
            for b in 0..d {
                dot += g[a * d + b] * vc[b];
            }
            acc += vc[a] * dot;
        }
        eigvals[c] = acc;
    }
    // Project: out[i][c] = (x_i - mean) · v_c, parallel over points.
    let mut out = vec![T::ZERO; n * k];
    {
        let os = SyncSlice::new(&mut out);
        let v_ref = &v;
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                for c in 0..k {
                    let mut acc = 0.0;
                    for j in 0..d {
                        acc += (data[i * d + j].to_f64() - mean[j]) * v_ref[c * d + j];
                    }
                    // SAFETY: disjoint — row i owned by this iteration
                    unsafe { *os.get_mut(i * k + c) = T::from_f64(acc) };
                }
            }
        });
    }
    (out, eigvals)
}

/// Modified Gram–Schmidt on k row vectors of length d.
fn orthonormalize(v: &mut [f64], k: usize, d: usize) {
    for c in 0..k {
        for p in 0..c {
            let (head, tail) = v.split_at_mut(c * d);
            let prev = &head[p * d..(p + 1) * d];
            let cur = &mut tail[..d];
            let dot: f64 = prev.iter().zip(cur.iter()).map(|(a, b)| a * b).sum();
            for (x, y) in cur.iter_mut().zip(prev.iter()) {
                *x -= dot * y;
            }
        }
        let cur = &mut v[c * d..(c + 1) * d];
        let norm: f64 = cur.iter().map(|x| x * x).sum::<f64>().sqrt().max(f64::MIN_POSITIVE);
        for x in cur.iter_mut() {
            *x /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn recovers_dominant_direction() {
        // Points along (1,1,0)/√2 with small noise: PC1 ≈ that direction.
        let mut rng = Rng::new(1);
        let n = 500;
        let d = 3;
        let mut data = vec![0.0f64; n * d];
        for i in 0..n {
            let t = rng.next_gaussian() * 10.0;
            data[i * d] = t + 0.01 * rng.next_gaussian();
            data[i * d + 1] = t + 0.01 * rng.next_gaussian();
            data[i * d + 2] = 0.01 * rng.next_gaussian();
        }
        let pool = ThreadPool::new(4);
        let (proj, eig) = pca(&pool, &data, n, d, 2, 50, 42);
        assert_eq!(proj.len(), n * 2);
        // PC1 variance should dominate
        assert!(eig[0] > 50.0 * eig[1], "eig {eig:?}");
        // Projection onto PC1 should correlate with t = (x+y)/2 up to sign.
        let mut corr = 0.0;
        for i in 0..n {
            let t = 0.5 * (data[i * d] + data[i * d + 1]);
            corr += t * proj[i * 2];
        }
        assert!(corr.abs() > 1.0);
    }

    #[test]
    fn components_orthonormal() {
        let mut v = vec![1.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 1.0];
        orthonormalize(&mut v, 3, 3);
        for a in 0..3 {
            for b in 0..3 {
                let dot: f64 = (0..3).map(|j| v[a * 3 + j] * v[b * 3 + j]).sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-9, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn projection_centered() {
        let mut rng = Rng::new(2);
        let n = 200;
        let d = 6;
        let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian() + 5.0).collect();
        let pool = ThreadPool::new(2);
        let (proj, _) = pca(&pool, &data, n, d, 3, 30, 1);
        for c in 0..3 {
            let mean: f64 = (0..n).map(|i| proj[i * 3 + c]).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-6, "component {c} mean {mean}");
        }
    }
}
