//! Registry of the paper's six benchmark datasets as shape-matched synthetic
//! analogs (§4.2 of the paper; DESIGN.md §Substitutions).
//!
//! Every generator takes a `scale ∈ (0, 1]` applied to the paper's full N, so
//! the same harness runs CI-sized (seconds) and paper-sized (hours) sweeps.

use super::pca::pca;
use super::synthetic::{gaussian_mixture, scrna_like};
use super::{first_non_finite, DataError, Dataset};
use crate::common::float::Real;
use crate::parallel::ThreadPool;

/// The six datasets of paper §4.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    /// scikit-learn Digits: 1797 × 64, 10 classes.
    Digits,
    /// MNIST: 70000 × 784, 10 classes.
    Mnist,
    /// CIFAR-10: 60000 × 3072, 10 classes.
    Cifar10,
    /// Fashion-MNIST: 70000 × 784, 10 classes.
    FashionMnist,
    /// SVHN: 99289 × 3072, 10 classes.
    Svhn,
    /// Mouse brain 1.3M: 1,291,337 × 20 (post-PCA), ~30 cell types.
    Mouse1_3M,
}

impl PaperDataset {
    pub const ALL: [PaperDataset; 6] = [
        PaperDataset::Digits,
        PaperDataset::Mnist,
        PaperDataset::Cifar10,
        PaperDataset::FashionMnist,
        PaperDataset::Svhn,
        PaperDataset::Mouse1_3M,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PaperDataset::Digits => "digits",
            PaperDataset::Mnist => "mnist",
            PaperDataset::Cifar10 => "cifar10",
            PaperDataset::FashionMnist => "fashion-mnist",
            PaperDataset::Svhn => "svhn",
            PaperDataset::Mouse1_3M => "mouse-1.3M",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|d| d.name() == name)
    }

    /// (full N, feature dim, classes) per the paper.
    pub fn spec(self) -> (usize, usize, usize) {
        match self {
            PaperDataset::Digits => (1_797, 64, 10),
            PaperDataset::Mnist => (70_000, 784, 10),
            PaperDataset::Cifar10 => (60_000, 3_072, 10),
            PaperDataset::FashionMnist => (70_000, 784, 10),
            PaperDataset::Svhn => (99_289, 3_072, 10),
            PaperDataset::Mouse1_3M => (1_291_337, 20, 30),
        }
    }

    /// Number of points at a given scale (≥ 512 so the quadtree is non-trivial,
    /// except Digits which is naturally small and used at full size).
    pub fn n_at_scale(self, scale: f64) -> usize {
        let (n_full, _, _) = self.spec();
        if self == PaperDataset::Digits {
            return n_full; // tiny already
        }
        ((n_full as f64 * scale).round() as usize).clamp(512, n_full)
    }

    /// Generate the synthetic analog.
    ///
    /// Mouse-1.3M follows the paper's pipeline: generate an scRNA-like count
    /// matrix (1000 genes) and reduce to 20 PCs with our PCA — so the points
    /// t-SNE sees carry realistic anisotropy and cluster imbalance.
    /// The image datasets are Gaussian mixtures at the paper's raw dims.
    pub fn generate<T: Real>(self, scale: f64, seed: u64, pool: &ThreadPool) -> Dataset<T> {
        self.try_generate(scale, seed, pool)
            .expect("paper-dataset generators must produce finite data")
    }

    /// [`Self::generate`] with the loader-boundary guardrail surfaced as a
    /// typed error: any non-finite value in the generated (or PCA-projected)
    /// matrix is reported by `(row, col)` instead of flowing into `fit`.
    pub fn try_generate<T: Real>(
        self,
        scale: f64,
        seed: u64,
        pool: &ThreadPool,
    ) -> Result<Dataset<T>, DataError> {
        let n = self.n_at_scale(scale);
        let (_, d, k) = self.spec();
        let mut ds = match self {
            PaperDataset::Mouse1_3M => {
                let genes = 200; // scaled-down gene count; PCA keeps 20 PCs as in the paper
                let raw = scrna_like::<T>(n, genes, k, 0.6, seed);
                let (proj, _) = pca(pool, &raw.points, n, genes, d, 30, seed ^ 0xD1CE);
                Dataset::try_new("", proj, raw.labels, n, d)?
            }
            // Image-like datasets: cluster separation tuned so KNN graphs have
            // mixed-class neighborhoods like real image features do.
            PaperDataset::Digits => gaussian_mixture::<T>(n, d, k, 2.5, seed),
            PaperDataset::Cifar10 | PaperDataset::Svhn => gaussian_mixture::<T>(n, d, k, 0.8, seed),
            _ => gaussian_mixture::<T>(n, d, k, 1.5, seed),
        };
        if let Some((row, col)) = first_non_finite(&ds.points, ds.d) {
            return Err(DataError::NonFinite { row, col });
        }
        ds.name = format!("{}@{:.3}", self.name(), scale);
        Ok(ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper() {
        assert_eq!(PaperDataset::Digits.spec(), (1_797, 64, 10));
        assert_eq!(PaperDataset::Mnist.spec(), (70_000, 784, 10));
        assert_eq!(PaperDataset::Cifar10.spec(), (60_000, 3_072, 10));
        assert_eq!(PaperDataset::FashionMnist.spec(), (70_000, 784, 10));
        assert_eq!(PaperDataset::Svhn.spec(), (99_289, 3_072, 10));
        assert_eq!(PaperDataset::Mouse1_3M.spec(), (1_291_337, 20, 30));
    }

    #[test]
    fn scale_clamps() {
        assert_eq!(PaperDataset::Mnist.n_at_scale(1.0), 70_000);
        assert_eq!(PaperDataset::Mnist.n_at_scale(1e-9), 512);
        assert_eq!(PaperDataset::Digits.n_at_scale(0.01), 1_797);
    }

    #[test]
    fn generate_small_analogs() {
        let pool = ThreadPool::new(2);
        for ds in [PaperDataset::Mnist, PaperDataset::Mouse1_3M] {
            let d = ds.generate::<f64>(0.01, 42, &pool);
            let (_, dim, _) = ds.spec();
            assert_eq!(d.d, dim);
            assert!(d.n >= 512);
            assert!(d.points.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn name_roundtrip() {
        for ds in PaperDataset::ALL {
            assert_eq!(PaperDataset::from_name(ds.name()), Some(ds));
        }
        assert_eq!(PaperDataset::from_name("nope"), None);
    }
}
