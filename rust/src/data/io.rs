//! Result/embedding IO: CSV writers the eval harness and viz use, a tiny
//! binary matrix format for caching expensive artifacts between runs, and the
//! dependency-free binary primitives (little-endian field codecs + an FNV-1a
//! checksum) that [`crate::tsne::persist`] builds its versioned formats on.

use crate::common::float::Real;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, Write};
use std::path::Path;

/// The seam between artifact writers and the storage they target.
///
/// Production code uses [`RealFs`]; the fault-injection tests substitute
/// media that fail at chosen write boundaries, persist short prefixes, or
/// "crash" between staging and rename — proving the atomic-save protocol of
/// [`crate::tsne::persist`] keeps the previous artifact intact under every
/// such fault. Only the write side is abstracted: torn files produced by a
/// faulty medium land on the real filesystem and are re-opened through the
/// normal load path, which must reject them with a typed error.
pub trait Medium {
    /// Writable artifact handle; seekable so a header checksum can be
    /// patched after the payload is streamed out.
    type Writer: Write + Seek;

    /// Create (truncating) a file for writing.
    fn create(&self, path: &Path) -> std::io::Result<Self::Writer>;

    /// Atomically move a fully-written staging file over the final path.
    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()>;

    /// Remove a staging file after a failed save (best-effort cleanup).
    fn remove(&self, path: &Path) -> std::io::Result<()>;
}

/// The production [`Medium`]: the real filesystem.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealFs;

impl Medium for RealFs {
    type Writer = File;

    fn create(&self, path: &Path) -> std::io::Result<File> {
        File::create(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        std::fs::rename(from, to)
    }

    fn remove(&self, path: &Path) -> std::io::Result<()> {
        std::fs::remove_file(path)
    }
}

/// Incremental 64-bit FNV-1a hash — the integrity checksum of the persisted
/// binary formats. Not cryptographic: it detects truncation and bit flips,
/// which is all an on-disk artifact cache needs, with zero dependencies.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a64(u64);

impl Fnv1a64 {
    const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv1a64(Self::OFFSET_BASIS)
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(Self::PRIME);
        }
        self.0 = h;
    }

    #[inline]
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Write a `u32` little-endian.
pub fn write_u32_le<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

/// Write a `u64` little-endian.
pub fn write_u64_le<W: Write>(w: &mut W, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

/// Write an `f64` little-endian (bit pattern preserved exactly).
pub fn write_f64_le<W: Write>(w: &mut W, v: f64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

/// Read a little-endian `u32`.
pub fn read_u32_le<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read a little-endian `u64`.
pub fn read_u64_le<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read a little-endian `f64` (bit pattern preserved exactly).
pub fn read_f64_le<R: Read>(r: &mut R) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// Write a whole `f64` slice little-endian (bit patterns preserved exactly) —
/// the bulk sibling of [`write_f64_le`], used by the serving wire protocol
/// for embedding payloads.
pub fn write_f64_slice_le<W: Write>(w: &mut W, vs: &[f64]) -> std::io::Result<()> {
    for &v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read little-endian `f64`s into `out`, filling it completely — the bulk
/// sibling of [`read_f64_le`].
pub fn read_f64_slice_le<R: Read>(r: &mut R, out: &mut [f64]) -> std::io::Result<()> {
    let mut b = [0u8; 8];
    for v in out.iter_mut() {
        r.read_exact(&mut b)?;
        *v = f64::from_le_bytes(b);
    }
    Ok(())
}

/// Write an embedding (n×2) with labels as CSV: `x,y,label`.
pub fn write_embedding_csv<T: Real>(
    path: impl AsRef<Path>,
    y: &[T],
    labels: &[u16],
) -> std::io::Result<()> {
    let n = labels.len();
    assert_eq!(y.len(), n * 2);
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "x,y,label")?;
    for i in 0..n {
        writeln!(w, "{},{},{}", y[2 * i].to_f64(), y[2 * i + 1].to_f64(), labels[i])?;
    }
    w.flush()
}

/// Write generic CSV rows (used by every bench to dump its table).
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &str,
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{header}")?;
    for row in rows {
        writeln!(w, "{}", row.join(","))?;
    }
    w.flush()
}

const MAGIC: &[u8; 8] = b"ACCTSNE1";

/// Binary matrix dump: magic, rows, cols, f64 little-endian data.
pub fn write_matrix_bin(
    path: impl AsRef<Path>,
    data: &[f64],
    rows: usize,
    cols: usize,
) -> std::io::Result<()> {
    write_matrix_bin_on(&RealFs, path.as_ref(), data, rows, cols)
}

/// [`write_matrix_bin`] on an explicit [`Medium`].
pub fn write_matrix_bin_on<M: Medium>(
    medium: &M,
    path: &Path,
    data: &[f64],
    rows: usize,
    cols: usize,
) -> std::io::Result<()> {
    assert_eq!(data.len(), rows * cols);
    let mut w = BufWriter::new(medium.create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(rows as u64).to_le_bytes())?;
    w.write_all(&(cols as u64).to_le_bytes())?;
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()
}

/// Length-before-allocation guard (acc-lint rule C1): a decoded shape must
/// match the bytes actually present before it is allowed to size a buffer,
/// so a torn or hostile header cannot trigger a huge allocation.
fn check_payload_len(expected: u64, actual: u64) -> std::io::Result<()> {
    if expected != actual {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("file length {actual} does not match header-implied {expected}"),
        ));
    }
    Ok(())
}

/// Read a matrix written by [`write_matrix_bin`]. Errors on bad magic/shape,
/// and on a header whose shape does not match the file's length (checked
/// before any shape-sized allocation).
pub fn read_matrix_bin(path: impl AsRef<Path>) -> std::io::Result<(Vec<f64>, usize, usize)> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not an acc-tsne matrix file",
        ));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    let total = rows
        .checked_mul(cols)
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "shape overflow"))?;
    let expected = (total as u64)
        .checked_mul(8)
        .and_then(|b| b.checked_add(24))
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "shape overflow"))?;
    check_payload_len(expected, file_len)?;
    let mut data = vec![0.0f64; total];
    for v in data.iter_mut() {
        r.read_exact(&mut b8)?;
        *v = f64::from_le_bytes(b8);
    }
    Ok((data, rows, cols))
}

/// Read a simple numeric CSV (header skipped): returns flat rows + width.
pub fn read_csv_numeric(path: impl AsRef<Path>) -> std::io::Result<(Vec<f64>, usize)> {
    let r = BufReader::new(File::open(path)?);
    let mut data = Vec::new();
    let mut width = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.chars().any(|c| c.is_alphabetic()) {
            continue; // header
        }
        if line.trim().is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> = line.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let vals = vals.map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {lineno}: {e}"))
        })?;
        if width == 0 {
            width = vals.len();
        } else if vals.len() != width {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("ragged row at line {lineno}"),
            ));
        }
        data.extend(vals);
    }
    Ok((data, width))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("acc_tsne_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a test vectors.
        let empty = Fnv1a64::new();
        assert_eq!(empty.finish(), 0xcbf2_9ce4_8422_2325);
        let mut a = Fnv1a64::new();
        a.update(b"a");
        assert_eq!(a.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut foobar = Fnv1a64::new();
        foobar.update(b"foobar");
        assert_eq!(foobar.finish(), 0x85944171f73967e8);
        // incremental updates == one-shot
        let mut split = Fnv1a64::new();
        split.update(b"foo");
        split.update(b"bar");
        assert_eq!(split.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn le_field_codecs_round_trip() {
        let mut buf = Vec::new();
        write_u32_le(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64_le(&mut buf, u64::MAX - 7).unwrap();
        write_f64_le(&mut buf, -0.1).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_u32_le(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64_le(&mut r).unwrap(), u64::MAX - 7);
        assert_eq!(read_f64_le(&mut r).unwrap().to_bits(), (-0.1f64).to_bits());
        assert!(r.is_empty());
        // short reads error instead of fabricating values
        let mut short = &buf[..2];
        assert!(read_u32_le(&mut short).is_err());
    }

    #[test]
    fn matrix_bin_roundtrip() {
        let p = tmp("mat.bin");
        let data = vec![1.0, 2.5, -3.0, 4.0, 5.0, 6.0];
        write_matrix_bin(&p, &data, 2, 3).unwrap();
        let (back, r, c) = read_matrix_bin(&p).unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(back, data);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_bin_rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC0000000000000000").unwrap();
        assert!(read_matrix_bin(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn embedding_csv_roundtrip() {
        let p = tmp("emb.csv");
        let y = vec![0.0f64, 1.0, 2.0, 3.0];
        write_embedding_csv(&p, &y, &[7, 9]).unwrap();
        let (data, w) = read_csv_numeric(&p).unwrap();
        assert_eq!(w, 3);
        assert_eq!(data, vec![0.0, 1.0, 7.0, 2.0, 3.0, 9.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "a,b\n1,2\n3\n").unwrap();
        assert!(read_csv_numeric(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
