//! Result/embedding IO: CSV writers the eval harness and viz use, and a tiny
//! binary matrix format for caching expensive artifacts between runs.

use crate::common::float::Real;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write an embedding (n×2) with labels as CSV: `x,y,label`.
pub fn write_embedding_csv<T: Real>(
    path: impl AsRef<Path>,
    y: &[T],
    labels: &[u16],
) -> std::io::Result<()> {
    let n = labels.len();
    assert_eq!(y.len(), n * 2);
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "x,y,label")?;
    for i in 0..n {
        writeln!(w, "{},{},{}", y[2 * i].to_f64(), y[2 * i + 1].to_f64(), labels[i])?;
    }
    w.flush()
}

/// Write generic CSV rows (used by every bench to dump its table).
pub fn write_csv(path: impl AsRef<Path>, header: &str, rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{header}")?;
    for row in rows {
        writeln!(w, "{}", row.join(","))?;
    }
    w.flush()
}

const MAGIC: &[u8; 8] = b"ACCTSNE1";

/// Binary matrix dump: magic, rows, cols, f64 little-endian data.
pub fn write_matrix_bin(path: impl AsRef<Path>, data: &[f64], rows: usize, cols: usize) -> std::io::Result<()> {
    assert_eq!(data.len(), rows * cols);
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(rows as u64).to_le_bytes())?;
    w.write_all(&(cols as u64).to_le_bytes())?;
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()
}

/// Read a matrix written by [`write_matrix_bin`]. Errors on bad magic/shape.
pub fn read_matrix_bin(path: impl AsRef<Path>) -> std::io::Result<(Vec<f64>, usize, usize)> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad magic: not an acc-tsne matrix file",
        ));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    let total = rows
        .checked_mul(cols)
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "shape overflow"))?;
    let mut data = vec![0.0f64; total];
    for v in data.iter_mut() {
        r.read_exact(&mut b8)?;
        *v = f64::from_le_bytes(b8);
    }
    Ok((data, rows, cols))
}

/// Read a simple numeric CSV (header skipped): returns flat rows + width.
pub fn read_csv_numeric(path: impl AsRef<Path>) -> std::io::Result<(Vec<f64>, usize)> {
    let r = BufReader::new(File::open(path)?);
    let mut data = Vec::new();
    let mut width = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if lineno == 0 && line.chars().any(|c| c.is_alphabetic()) {
            continue; // header
        }
        if line.trim().is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> = line.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let vals = vals.map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {lineno}: {e}"))
        })?;
        if width == 0 {
            width = vals.len();
        } else if vals.len() != width {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("ragged row at line {lineno}"),
            ));
        }
        data.extend(vals);
    }
    Ok((data, width))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("acc_tsne_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn matrix_bin_roundtrip() {
        let p = tmp("mat.bin");
        let data = vec![1.0, 2.5, -3.0, 4.0, 5.0, 6.0];
        write_matrix_bin(&p, &data, 2, 3).unwrap();
        let (back, r, c) = read_matrix_bin(&p).unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(back, data);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn matrix_bin_rejects_bad_magic() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC0000000000000000").unwrap();
        assert!(read_matrix_bin(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn embedding_csv_roundtrip() {
        let p = tmp("emb.csv");
        let y = vec![0.0f64, 1.0, 2.0, 3.0];
        write_embedding_csv(&p, &y, &[7, 9]).unwrap();
        let (data, w) = read_csv_numeric(&p).unwrap();
        assert_eq!(w, 3);
        assert_eq!(data, vec![0.0, 1.0, 7.0, 2.0, 3.0, 9.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "a,b\n1,2\n3\n").unwrap();
        assert!(read_csv_numeric(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
