//! Sparse similarity matrix: CSR storage and the t-SNE symmetrization
//! `p_ij = (p_{j|i} + p_{i|j}) / 2N` over the KNN support (paper Eq. 2).
//!
//! The attractive-force step (Algorithm 2) streams rows of this matrix, so
//! its layout — columns ascending per row, contiguous val/col arrays — is
//! part of the memory-behaviour story the paper tells.

use crate::common::float::Real;
use crate::knn::NeighborLists;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Compressed-sparse-row matrix.
#[derive(Clone, Debug)]
pub struct CsrMatrix<T: Real> {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub col: Vec<u32>,
    pub val: Vec<T>,
}

impl<T: Real> CsrMatrix<T> {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[T]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col[s..e], &self.val[s..e])
    }

    /// Sum of all stored values.
    pub fn sum(&self) -> T {
        let mut acc = T::ZERO;
        for &v in &self.val {
            acc += v;
        }
        acc
    }

    /// Weak structural validation: row_ptr shape/bounds/monotonicity, col/val
    /// length agreement, and column range — the invariants the gradient
    /// kernels rely on, WITHOUT the ascending-columns canonical-form check of
    /// [`Self::validate`]. This is the gate for externally-sourced matrices
    /// ([`Affinities::from_csr`](crate::tsne::Affinities::from_csr) and the
    /// persisted-affinities loader): entry order within a row is a layout
    /// choice, not a correctness requirement.
    pub fn validate_structural(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.n + 1 {
            return Err(format!(
                "row_ptr must have n+1 = {} entries, has {}",
                self.n + 1,
                self.row_ptr.len()
            ));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.col.len() {
            return Err("row_ptr must span 0..=nnz".into());
        }
        if self.col.len() != self.val.len() {
            return Err("col/val length mismatch".into());
        }
        if self.row_ptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("row_ptr not monotone".into());
        }
        if self.col.iter().any(|&c| c as usize >= self.n) {
            return Err("column index out of range".into());
        }
        Ok(())
    }

    /// Structural validation (used by tests and debug assertions).
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.n + 1 {
            return Err("row_ptr length".into());
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.col.len() {
            return Err("row_ptr bounds".into());
        }
        if self.col.len() != self.val.len() {
            return Err("col/val length mismatch".into());
        }
        for i in 0..self.n {
            if self.row_ptr[i] > self.row_ptr[i + 1] {
                return Err(format!("row_ptr not monotone at {i}"));
            }
            let (cols, _) = self.row(i);
            if !cols.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("row {i} columns not strictly ascending"));
            }
            if cols.iter().any(|&c| c as usize >= self.n) {
                return Err(format!("row {i} column out of range"));
            }
        }
        Ok(())
    }

    /// Entry lookup by binary search (tests only — O(log nnz_row)).
    pub fn get(&self, i: usize, j: usize) -> T {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(pos) => vals[pos],
            Err(_) => T::ZERO,
        }
    }
}

/// Symmetrize conditional probabilities `cond_p[i*k + t] = p_{neighbors[i][t] | i}`
/// into the joint CSR matrix `P` with `p_ij = (p_{j|i} + p_{i|j}) / (2N)`.
///
/// Fully parallel: (1) sort each row's (neighbor, p) pairs by neighbor index,
/// (2) build the reverse adjacency (who lists me?) with atomic counters,
/// (3) merge forward and reverse lists per row.
pub fn symmetrize<T: Real>(
    pool: &ThreadPool,
    knn: &NeighborLists<T>,
    cond_p: &[T],
) -> CsrMatrix<T> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let n = knn.n;
    let k = knn.k;
    assert_eq!(cond_p.len(), n * k);

    // (1) Per-row sorted copies of (neighbor, p).
    let mut fwd: Vec<(u32, T)> = vec![(0, T::ZERO); n * k];
    {
        let fs = SyncSlice::new(&mut fwd);
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                // SAFETY: disjoint — row i
                let row = unsafe { fs.slice_mut(i * k, k) };
                for t in 0..k {
                    row[t] = (knn.indices[i * k + t], cond_p[i * k + t]);
                }
                row.sort_unstable_by_key(|&(c, _)| c);
            }
        });
    }

    // (2) Reverse adjacency: rev[j] = list of (i, p_{j|i}) for i listing j.
    let rev_counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    parallel_for(pool, n * k, Schedule::Static, |range| {
        for idx in range {
            let j = knn.indices[idx] as usize;
            rev_counts[j].fetch_add(1, Ordering::Relaxed);
        }
    });
    let mut rev_ptr = vec![0usize; n + 1];
    for j in 0..n {
        rev_ptr[j + 1] = rev_ptr[j] + rev_counts[j].load(Ordering::Relaxed);
    }
    let rev_cursor: Vec<AtomicUsize> = rev_ptr[..n].iter().map(|&p| AtomicUsize::new(p)).collect();
    let mut rev: Vec<(u32, T)> = vec![(0, T::ZERO); n * k];
    {
        let rs = SyncSlice::new(&mut rev);
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                for t in 0..k {
                    let j = knn.indices[i * k + t] as usize;
                    let pos = rev_cursor[j].fetch_add(1, Ordering::Relaxed);
                    // SAFETY: disjoint — fetch_add hands out unique positions
                    unsafe { *rs.get_mut(pos) = (i as u32, cond_p[i * k + t]) };
                }
            }
        });
    }
    // Sort each reverse row (scatter order is nondeterministic).
    {
        let rs = SyncSlice::new(&mut rev);
        let rev_ptr = &rev_ptr;
        parallel_for(pool, n, Schedule::Dynamic { grain: 64 }, |range| {
            for j in range {
                let (s, e) = (rev_ptr[j], rev_ptr[j + 1]);
                // SAFETY: disjoint — reverse row j
                let row = unsafe { rs.slice_mut(s, e - s) };
                row.sort_unstable_by_key(|&(c, _)| c);
            }
        });
    }

    // (3a) Count union sizes per row.
    let mut row_len = vec![0usize; n + 1];
    {
        let rl = SyncSlice::new(&mut row_len);
        let fwd = &fwd;
        let rev = &rev;
        let rev_ptr = &rev_ptr;
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                let a = &fwd[i * k..(i + 1) * k];
                let b = &rev[rev_ptr[i]..rev_ptr[i + 1]];
                // SAFETY: disjoint — slot i+1
                unsafe { *rl.get_mut(i + 1) = merge_count(a, b) };
            }
        });
    }
    for i in 0..n {
        row_len[i + 1] += row_len[i];
    }
    let row_ptr = row_len;
    let nnz = row_ptr[n];

    // (3b) Fill.
    let mut col = vec![0u32; nnz];
    let mut val = vec![T::ZERO; nnz];
    {
        let cs = SyncSlice::new(&mut col);
        let vs = SyncSlice::new(&mut val);
        let fwd = &fwd;
        let rev = &rev;
        let rev_ptr = &rev_ptr;
        let row_ptr = &row_ptr;
        let inv_2n = T::ONE / (T::TWO * T::from_usize(n));
        parallel_for(pool, n, Schedule::Static, |range| {
            for i in range {
                let a = &fwd[i * k..(i + 1) * k];
                let b = &rev[rev_ptr[i]..rev_ptr[i + 1]];
                let (s, e) = (row_ptr[i], row_ptr[i + 1]);
                // SAFETY: disjoint — output row i
                let (ocol, oval) = unsafe { (cs.slice_mut(s, e - s), vs.slice_mut(s, e - s)) };
                merge_fill(a, b, inv_2n, ocol, oval);
            }
        });
    }

    let m = CsrMatrix { n, row_ptr, col, val };
    debug_assert!(m.validate().is_ok());
    m
}

/// Re-index a (symmetric) CSR matrix into a new point ordering, writing into
/// a caller-owned `dst` whose buffers are reused across calls (the Z-order-
/// persistent gradient loop re-permutes `P` only when the embedding layout
/// drifts, so steady-state adoptions allocate nothing).
///
/// `new_to_old[t]` is the source index of the point now stored at slot `t`;
/// `old_to_new` is its inverse. The result is the symmetric permutation
/// `dst[t][u] = src[new_to_old[t]][new_to_old[u]]`.
///
/// Entries within a row are relocated, NOT re-sorted: dst row `t` keeps the
/// entry order of src row `new_to_old[t]`. Two consequences the pipeline
/// relies on: (1) a row sum over the permuted matrix is bit-identical to the
/// same row's sum over the source (exact FP parity for the attractive sweep),
/// and (2) permuting by a permutation and then by its inverse reproduces the
/// source exactly. The price: the result does not satisfy the
/// ascending-columns invariant of [`CsrMatrix::validate`] — it is a
/// traversal layout, not a canonical matrix.
pub fn permute_symmetric_into<T: Real>(
    pool: &ThreadPool,
    src: &CsrMatrix<T>,
    new_to_old: &[u32],
    old_to_new: &[u32],
    dst: &mut CsrMatrix<T>,
) {
    let n = src.n;
    assert_eq!(new_to_old.len(), n, "new_to_old must have n entries");
    assert_eq!(old_to_new.len(), n, "old_to_new must have n entries");
    let nnz = src.nnz();
    dst.n = n;
    dst.row_ptr.resize(n + 1, 0);
    dst.row_ptr[0] = 0;
    for t in 0..n {
        let o = new_to_old[t] as usize;
        dst.row_ptr[t + 1] = dst.row_ptr[t] + (src.row_ptr[o + 1] - src.row_ptr[o]);
    }
    debug_assert_eq!(dst.row_ptr[n], nnz);
    dst.col.resize(nnz, 0);
    dst.val.resize(nnz, T::ZERO);
    {
        let cs = SyncSlice::new(&mut dst.col);
        let vs = SyncSlice::new(&mut dst.val);
        let row_ptr = &dst.row_ptr;
        parallel_for(pool, n, Schedule::Static, |range| {
            for t in range {
                let o = new_to_old[t] as usize;
                let (s, e) = (src.row_ptr[o], src.row_ptr[o + 1]);
                let d = row_ptr[t];
                for (k, idx) in (s..e).enumerate() {
                    // SAFETY: disjoint — output row t
                    unsafe {
                        *cs.get_mut(d + k) = old_to_new[src.col[idx] as usize];
                        *vs.get_mut(d + k) = src.val[idx];
                    }
                }
            }
        });
    }
}

/// Count the size of the sorted-merge union of two (col, val) lists.
fn merge_count<T: Copy>(a: &[(u32, T)], b: &[(u32, T)]) -> usize {
    let (mut ia, mut ib, mut cnt) = (0, 0, 0);
    while ia < a.len() && ib < b.len() {
        match a[ia].0.cmp(&b[ib].0) {
            std::cmp::Ordering::Less => ia += 1,
            std::cmp::Ordering::Greater => ib += 1,
            std::cmp::Ordering::Equal => {
                ia += 1;
                ib += 1;
            }
        }
        cnt += 1;
    }
    cnt + (a.len() - ia) + (b.len() - ib)
}

/// Merge two sorted (col, val) lists into `(p_a + p_b) * inv_2n` union rows.
fn merge_fill<T: Real>(
    a: &[(u32, T)],
    b: &[(u32, T)],
    inv_2n: T,
    ocol: &mut [u32],
    oval: &mut [T],
) {
    let (mut ia, mut ib, mut o) = (0, 0, 0);
    while ia < a.len() && ib < b.len() {
        let (ca, va) = a[ia];
        let (cb, vb) = b[ib];
        match ca.cmp(&cb) {
            std::cmp::Ordering::Less => {
                ocol[o] = ca;
                oval[o] = va * inv_2n;
                ia += 1;
            }
            std::cmp::Ordering::Greater => {
                ocol[o] = cb;
                oval[o] = vb * inv_2n;
                ib += 1;
            }
            std::cmp::Ordering::Equal => {
                ocol[o] = ca;
                oval[o] = (va + vb) * inv_2n;
                ia += 1;
                ib += 1;
            }
        }
        o += 1;
    }
    while ia < a.len() {
        ocol[o] = a[ia].0;
        oval[o] = a[ia].1 * inv_2n;
        ia += 1;
        o += 1;
    }
    while ib < b.len() {
        ocol[o] = b[ib].0;
        oval[o] = b[ib].1 * inv_2n;
        ib += 1;
        o += 1;
    }
    debug_assert_eq!(o, ocol.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::knn::{BruteForceKnn, KnnEngine};

    fn make_knn_and_p(n: usize, d: usize, k: usize, seed: u64) -> (NeighborLists<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f64> = (0..n * d).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(2);
        let knn = BruteForceKnn::default().search(&pool, &data, n, d, k);
        // Arbitrary positive row-normalized conditional probabilities.
        let mut p = vec![0.0f64; n * k];
        for i in 0..n {
            let mut s = 0.0;
            for t in 0..k {
                p[i * k + t] = 0.1 + rng.next_f64();
                s += p[i * k + t];
            }
            for t in 0..k {
                p[i * k + t] /= s;
            }
        }
        (knn, p)
    }

    #[test]
    fn symmetric_and_normalized() {
        let (knn, p) = make_knn_and_p(120, 5, 8, 1);
        let pool = ThreadPool::new(4);
        let m = symmetrize(&pool, &knn, &p);
        m.validate().unwrap();
        // symmetry
        for i in 0..m.n {
            let (cols, _) = m.row(i);
            for &j in cols {
                let a = m.get(i, j as usize);
                let b = m.get(j as usize, i);
                assert!((a - b).abs() < 1e-15, "P[{i}][{j}]={a} vs P[{j}][{i}]={b}");
            }
        }
        // total mass: Σ p_ij = Σ_i Σ_t (p_cond)/2N * 2 (each pair counted from
        // both sides) = Σ rows (=n) / N = 1.
        assert!((m.sum() - 1.0).abs() < 1e-9, "sum = {}", m.sum());
    }

    #[test]
    fn matches_dense_reference() {
        let (knn, p) = make_knn_and_p(60, 4, 6, 2);
        let n = knn.n;
        let k = knn.k;
        // dense conditional
        let mut dense = vec![0.0f64; n * n];
        for i in 0..n {
            for t in 0..k {
                dense[i * n + knn.indices[i * k + t] as usize] = p[i * k + t];
            }
        }
        let pool = ThreadPool::new(3);
        let m = symmetrize(&pool, &knn, &p);
        for i in 0..n {
            for j in 0..n {
                let want = (dense[i * n + j] + dense[j * n + i]) / (2.0 * n as f64);
                let got = m.get(i, j);
                assert!((want - got).abs() < 1e-15, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (knn, p) = make_knn_and_p(200, 6, 10, 3);
        let m1 = symmetrize(&ThreadPool::new(1), &knn, &p);
        let m8 = symmetrize(&ThreadPool::new(8), &knn, &p);
        assert_eq!(m1.row_ptr, m8.row_ptr);
        assert_eq!(m1.col, m8.col);
        assert_eq!(m1.val, m8.val);
    }

    fn random_permutation(n: usize, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
        let mut perm: Vec<u32> = (0..n as u32).collect();
        // Fisher-Yates
        for i in (1..n).rev() {
            let j = rng.next_below(i + 1);
            perm.swap(i, j);
        }
        let mut inv = vec![0u32; n];
        for (slot, &orig) in perm.iter().enumerate() {
            inv[orig as usize] = slot as u32;
        }
        (perm, inv)
    }

    #[test]
    fn permute_symmetric_matches_dense_reindex() {
        let (knn, p) = make_knn_and_p(80, 4, 7, 5);
        let pool = ThreadPool::new(4);
        let m = symmetrize(&pool, &knn, &p);
        let n = m.n;
        let mut rng = Rng::new(99);
        let (perm, inv) = random_permutation(n, &mut rng);
        let mut a =
            CsrMatrix::<f64> { n: 0, row_ptr: Vec::new(), col: Vec::new(), val: Vec::new() };
        permute_symmetric_into(&pool, &m, &perm, &inv, &mut a);
        // dense check: a[t][u] == m[perm[t]][perm[u]]
        let mut dense_a = vec![0.0f64; n * n];
        for t in 0..n {
            let (s, e) = (a.row_ptr[t], a.row_ptr[t + 1]);
            for idx in s..e {
                dense_a[t * n + a.col[idx] as usize] += a.val[idx];
            }
        }
        for t in 0..n {
            for u in 0..n {
                let want = m.get(perm[t] as usize, perm[u] as usize);
                let got = dense_a[t * n + u];
                assert!((want - got).abs() < 1e-15, "({t},{u}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn permute_symmetric_round_trips_exactly() {
        // permute ∘ unpermute = id, bit-for-bit (entry order is preserved,
        // the contract the Z-order pipeline's FP-parity argument rests on).
        let (knn, p) = make_knn_and_p(150, 5, 9, 6);
        let pool = ThreadPool::new(4);
        let m = symmetrize(&pool, &knn, &p);
        let mut rng = Rng::new(7);
        let (perm, inv) = random_permutation(m.n, &mut rng);
        let mut fwd =
            CsrMatrix::<f64> { n: 0, row_ptr: Vec::new(), col: Vec::new(), val: Vec::new() };
        let mut back =
            CsrMatrix::<f64> { n: 0, row_ptr: Vec::new(), col: Vec::new(), val: Vec::new() };
        permute_symmetric_into(&pool, &m, &perm, &inv, &mut fwd);
        permute_symmetric_into(&pool, &fwd, &inv, &perm, &mut back);
        assert_eq!(back.n, m.n);
        assert_eq!(back.row_ptr, m.row_ptr);
        assert_eq!(back.col, m.col);
        assert_eq!(back.val, m.val);
        // identity permutation is a no-op copy
        let ident: Vec<u32> = (0..m.n as u32).collect();
        permute_symmetric_into(&pool, &m, &ident, &ident, &mut fwd);
        assert_eq!(fwd.col, m.col);
        assert_eq!(fwd.val, m.val);
    }

    #[test]
    fn validate_catches_corruption() {
        let (knn, p) = make_knn_and_p(30, 3, 4, 4);
        let pool = ThreadPool::new(2);
        let mut m = symmetrize(&pool, &knn, &p);
        m.col[0] = m.n as u32 + 5; // out of range
        assert!(m.validate().is_err());
    }

    #[test]
    fn structural_validation_allows_any_entry_order_but_catches_shape_corruption() {
        let (knn, p) = make_knn_and_p(40, 3, 5, 8);
        let pool = ThreadPool::new(2);
        let m = symmetrize(&pool, &knn, &p);
        assert!(m.validate_structural().is_ok());
        // a descending-column (traversal-layout) row fails canonical validate
        // but passes the structural check
        let z = CsrMatrix::<f64> {
            n: 3,
            row_ptr: vec![0, 2, 2, 3],
            col: vec![2, 0, 1],
            val: vec![0.5, 0.25, 0.25],
        };
        assert!(z.validate().is_err(), "descending rows are not canonical");
        assert!(z.validate_structural().is_ok());
        // shape corruption is still caught
        let mut bad = m.clone();
        bad.col[0] = bad.n as u32;
        assert!(bad.validate_structural().is_err());
        let mut bad = m.clone();
        bad.row_ptr[1] = bad.row_ptr[2] + 1;
        assert!(bad.validate_structural().is_err());
        let mut bad = m.clone();
        bad.val.pop();
        assert!(bad.validate_structural().is_err());
        let mut bad = m;
        bad.row_ptr.pop();
        assert!(bad.validate_structural().is_err());
    }
}
