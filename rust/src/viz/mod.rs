//! Embedding scatter-plot renderer (paper Figures S1–S6).
//!
//! Renders an n×2 embedding colored by class label to a binary PPM (P6) or an
//! SVG. No image crates offline, and PPM is sufficient for eyeballing and
//! diffable in tests.

use crate::common::float::Real;
use std::io::Write;
use std::path::Path;

/// Distinct colors for up to 30 classes (HSV wheel, precomputed).
pub fn label_color(label: u16) -> [u8; 3] {
    let h = (label as f64 * 360.0 / 10.0) % 360.0; // 10-hue wheel, cycles
    let v = if (label / 10) % 2 == 0 { 0.95 } else { 0.6 }; // darker every cycle
    hsv_to_rgb(h, 0.85, v)
}

fn hsv_to_rgb(h: f64, s: f64, v: f64) -> [u8; 3] {
    let c = v * s;
    let hp = h / 60.0;
    let x = c * (1.0 - ((hp % 2.0) - 1.0).abs());
    let (r, g, b) = match hp as u32 {
        0 => (c, x, 0.0),
        1 => (x, c, 0.0),
        2 => (0.0, c, x),
        3 => (0.0, x, c),
        4 => (x, 0.0, c),
        _ => (c, 0.0, x),
    };
    let m = v - c;
    [
        ((r + m) * 255.0) as u8,
        ((g + m) * 255.0) as u8,
        ((b + m) * 255.0) as u8,
    ]
}

/// Rasterize the embedding into an RGB buffer (white background, one 2×2 dot
/// per point). Returns (buffer, width, height).
pub fn rasterize<T: Real>(y: &[T], labels: &[u16], size: usize) -> (Vec<u8>, usize, usize) {
    let n = labels.len();
    assert_eq!(y.len(), 2 * n);
    let mut img = vec![255u8; size * size * 3];
    if n == 0 {
        return (img, size, size);
    }
    let (mut lo, mut hi) = ([f64::INFINITY; 2], [f64::NEG_INFINITY; 2]);
    for i in 0..n {
        for d in 0..2 {
            let v = y[2 * i + d].to_f64();
            lo[d] = lo[d].min(v);
            hi[d] = hi[d].max(v);
        }
    }
    let span = [
        (hi[0] - lo[0]).max(f64::MIN_POSITIVE),
        (hi[1] - lo[1]).max(f64::MIN_POSITIVE),
    ];
    let margin = 0.03;
    let usable = size as f64 * (1.0 - 2.0 * margin);
    for i in 0..n {
        let px = ((y[2 * i].to_f64() - lo[0]) / span[0] * usable + size as f64 * margin) as usize;
        let py =
            ((y[2 * i + 1].to_f64() - lo[1]) / span[1] * usable + size as f64 * margin) as usize;
        let color = label_color(labels[i]);
        for dx in 0..2 {
            for dy in 0..2 {
                let (x, yy) = ((px + dx).min(size - 1), (py + dy).min(size - 1));
                let o = (yy * size + x) * 3;
                img[o..o + 3].copy_from_slice(&color);
            }
        }
    }
    (img, size, size)
}

/// Write a binary PPM (P6) scatter plot.
pub fn write_ppm<T: Real>(
    path: impl AsRef<Path>,
    y: &[T],
    labels: &[u16],
    size: usize,
) -> std::io::Result<()> {
    let (img, w, h) = rasterize(y, labels, size);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P6\n{w} {h}\n255\n")?;
    f.write_all(&img)?;
    f.flush()
}

/// Write an SVG scatter plot (for the docs; vector, label-colored circles).
pub fn write_svg<T: Real>(
    path: impl AsRef<Path>,
    y: &[T],
    labels: &[u16],
    size: usize,
) -> std::io::Result<()> {
    let n = labels.len();
    assert_eq!(y.len(), 2 * n);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(
        f,
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{size}\" height=\"{size}\" viewBox=\"0 0 {size} {size}\">"
    )?;
    writeln!(f, "<rect width=\"100%\" height=\"100%\" fill=\"white\"/>")?;
    if n > 0 {
        let (mut lo, mut hi) = ([f64::INFINITY; 2], [f64::NEG_INFINITY; 2]);
        for i in 0..n {
            for d in 0..2 {
                let v = y[2 * i + d].to_f64();
                lo[d] = lo[d].min(v);
                hi[d] = hi[d].max(v);
            }
        }
        let span = [
            (hi[0] - lo[0]).max(f64::MIN_POSITIVE),
            (hi[1] - lo[1]).max(f64::MIN_POSITIVE),
        ];
        let usable = size as f64 * 0.94;
        for i in 0..n {
            let px = (y[2 * i].to_f64() - lo[0]) / span[0] * usable + size as f64 * 0.03;
            let py = (y[2 * i + 1].to_f64() - lo[1]) / span[1] * usable + size as f64 * 0.03;
            let [r, g, b] = label_color(labels[i]);
            writeln!(
                f,
                "<circle cx=\"{px:.1}\" cy=\"{py:.1}\" r=\"1.5\" fill=\"rgb({r},{g},{b})\" fill-opacity=\"0.7\"/>"
            )?;
        }
    }
    writeln!(f, "</svg>")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("acc_tsne_viz_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn colors_distinct_for_first_ten_labels() {
        let colors: Vec<[u8; 3]> = (0..10).map(label_color).collect();
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_ne!(colors[i], colors[j], "labels {i} and {j} share a color");
            }
        }
    }

    #[test]
    fn rasterize_marks_points() {
        let y = vec![0.0f64, 0.0, 1.0, 1.0, -1.0, 0.5];
        let (img, w, h) = rasterize(&y, &[0, 1, 2], 64);
        assert_eq!((w, h), (64, 64));
        let colored = img.chunks(3).filter(|c| c != &[255, 255, 255]).count();
        assert!(colored >= 3, "at least the three dots must be colored");
    }

    #[test]
    fn ppm_header_and_size() {
        let p = tmp("plot.ppm");
        let y = vec![0.0f64, 0.0, 1.0, 1.0];
        write_ppm(&p, &y, &[0, 1], 32).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n32 32\n255\n"));
        assert_eq!(bytes.len(), 13 + 32 * 32 * 3);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn svg_contains_circles() {
        let p = tmp("plot.svg");
        let y = vec![0.0f64, 0.0, 2.0, 3.0];
        write_svg(&p, &y, &[0, 5], 100).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("<svg"));
        assert_eq!(s.matches("<circle").count(), 2);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn degenerate_single_point() {
        let (img, _, _) = rasterize(&[5.0f64, 5.0], &[3], 16);
        assert!(img.chunks(3).any(|c| c != [255, 255, 255]));
    }
}
