//! Embedding quality metrics.
//!
//! - KL divergence (paper Table 3 / S1): re-exported from the gradient oracle
//!   ([`exact_kl`] for exact-Z small-N evaluation; runs report the BH-Z
//!   variant computed inside the pipeline).
//! - [`neighbor_preservation`]: fraction of high-dimensional k-NN retained in
//!   the embedding — a structural check the paper's scatter plots (S1–S6)
//!   make visually; we make it numeric so tests can assert it.

pub use crate::gradient::exact::{exact_kl, kl_with_z};

use crate::common::float::Real;
use crate::knn::{BruteForceKnn, KnnEngine};
use crate::parallel::ThreadPool;

/// Mean fraction of each point's `k` high-dim neighbors that are also among
/// its `k` low-dim neighbors (1.0 = perfect local-structure preservation).
pub fn neighbor_preservation<T: Real>(
    pool: &ThreadPool,
    high: &[T],
    n: usize,
    d: usize,
    embedding: &[T],
    k: usize,
) -> f64 {
    assert_eq!(embedding.len(), 2 * n);
    let eng = BruteForceKnn::default();
    let hi = eng.search(pool, high, n, d, k);
    let lo = eng.search(pool, embedding, n, 2, k);
    let mut preserved = 0usize;
    for i in 0..n {
        let hset: std::collections::HashSet<u32> = hi.neighbors(i).iter().copied().collect();
        preserved += lo.neighbors(i).iter().filter(|j| hset.contains(j)).count();
    }
    preserved as f64 / (n * k) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn identity_embedding_of_2d_data_preserves_everything() {
        let mut rng = Rng::new(1);
        let n = 200;
        let data: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(2);
        let np = neighbor_preservation(&pool, &data, n, 2, &data, 10);
        assert_eq!(np, 1.0);
    }

    #[test]
    fn random_embedding_preserves_nothing_much() {
        let mut rng = Rng::new(2);
        let n = 300;
        let data: Vec<f64> = (0..8 * n).map(|_| rng.next_gaussian()).collect();
        let emb: Vec<f64> = (0..2 * n).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::new(2);
        let np = neighbor_preservation(&pool, &data, n, 8, &emb, 10);
        assert!(np < 0.2, "random embedding preservation {np}");
    }
}
