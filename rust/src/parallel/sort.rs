//! Parallel LSD radix sort of (u64 key, u32 payload) pairs.
//!
//! This is the backbone of the morton-code quadtree builder (paper §3.3 /
//! Burtscher-Pingali style): points are sorted by 64-bit morton code once per
//! gradient iteration, so the sort must scale. LSD radix with 8-bit digits:
//! per pass, threads histogram their chunk, a (256 × nt) transposed exclusive
//! scan assigns deterministic scatter offsets, then threads scatter. The sort
//! is stable and the output is identical regardless of thread count.

use super::par_for::static_chunk;
use super::pool::ThreadPool;
use super::scan::exclusive_scan_seq;
use super::SyncSlice;

const RADIX_BITS: usize = 8;
const RADIX: usize = 1 << RADIX_BITS; // 256
const PASSES: usize = 64 / RADIX_BITS; // 8

/// Sort `keys` (with `payload` permuted alongside) ascending by key.
/// Skips passes whose digit is constant across all keys (common for morton
/// codes that occupy < 64 bits).
pub fn radix_sort_pairs(pool: &ThreadPool, keys: &mut Vec<u64>, payload: &mut Vec<u32>) {
    let n = keys.len();
    assert_eq!(n, payload.len(), "keys/payload length mismatch");
    if n <= 1 {
        return;
    }
    if n < 32_768 || pool.n_threads() == 1 {
        // Sequential fallback: comparison sort on zipped pairs is simpler and
        // fast enough below the parallel break-even point.
        let mut zipped: Vec<(u64, u32)> =
            keys.iter().copied().zip(payload.iter().copied()).collect();
        zipped.sort_unstable_by_key(|&(k, _)| k);
        for (i, (k, p)) in zipped.into_iter().enumerate() {
            keys[i] = k;
            payload[i] = p;
        }
        return;
    }

    let nt = pool.n_threads();
    let mut keys_tmp = vec![0u64; n];
    let mut pay_tmp = vec![0u32; n];
    // OR of all keys tells us which digit positions actually vary.
    let all_or = keys.iter().fold(0u64, |a, &k| a | k);

    let mut src_is_orig = true;
    for pass in 0..PASSES {
        let shift = pass * RADIX_BITS;
        if (all_or >> shift) & (RADIX as u64 - 1) == 0 && pass > 0 {
            continue; // digit constant zero → already ordered w.r.t. it
        }
        {
            let (src_k, src_p, dst_k, dst_p): (&[u64], &[u32], &mut [u64], &mut [u32]) =
                if src_is_orig {
                    (keys, payload, &mut keys_tmp, &mut pay_tmp)
                } else {
                    (&keys_tmp, &pay_tmp, keys, payload)
                };
            radix_pass(pool, nt, shift, src_k, src_p, dst_k, dst_p);
        }
        src_is_orig = !src_is_orig;
    }
    if !src_is_orig {
        keys.copy_from_slice(&keys_tmp);
        payload.copy_from_slice(&pay_tmp);
    }
}

fn radix_pass(
    pool: &ThreadPool,
    nt: usize,
    shift: usize,
    src_k: &[u64],
    src_p: &[u32],
    dst_k: &mut [u64],
    dst_p: &mut [u32],
) {
    let n = src_k.len();
    // hist[tid * RADIX + digit]
    let mut hist = vec![0usize; nt * RADIX];
    {
        let h = SyncSlice::new(&mut hist);
        pool.broadcast(|tid| {
            let (s, e) = static_chunk(n, nt, tid);
            // SAFETY: disjoint — each tid owns hist[tid*RADIX .. (tid+1)*RADIX]
            let local = unsafe { h.slice_mut(tid * RADIX, RADIX) };
            for &k in &src_k[s..e] {
                local[((k >> shift) as usize) & (RADIX - 1)] += 1;
            }
        });
    }
    // Transpose-scan: offsets ordered by (digit, tid) so the scatter is stable.
    let mut offsets = vec![0usize; nt * RADIX];
    {
        let mut flat = vec![0usize; nt * RADIX];
        let mut idx = 0;
        for digit in 0..RADIX {
            for tid in 0..nt {
                flat[idx] = hist[tid * RADIX + digit];
                idx += 1;
            }
        }
        exclusive_scan_seq(&mut flat);
        let mut idx = 0;
        for digit in 0..RADIX {
            for tid in 0..nt {
                offsets[tid * RADIX + digit] = flat[idx];
                idx += 1;
            }
        }
    }
    {
        let dk = SyncSlice::new(dst_k);
        let dp = SyncSlice::new(dst_p);
        let off = SyncSlice::new(&mut offsets);
        pool.broadcast(|tid| {
            let (s, e) = static_chunk(n, nt, tid);
            // SAFETY: disjoint — offsets[tid*RADIX..] owned by tid; dst positions are
            // unique because each (digit, tid) offset range is disjoint.
            let local_off = unsafe { off.slice_mut(tid * RADIX, RADIX) };
            for i in s..e {
                let k = src_k[i];
                let digit = ((k >> shift) as usize) & (RADIX - 1);
                let pos = local_off[digit];
                local_off[digit] += 1;
                // SAFETY: disjoint — each (digit, tid) offset range is unique,
                // so no two threads write the same dst position
                unsafe {
                    *dk.get_mut(pos) = k;
                    *dp.get_mut(pos) = src_p[i];
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    fn check_sorted(pool: &ThreadPool, mut keys: Vec<u64>, seed_tag: &str) {
        let n = keys.len();
        let mut payload: Vec<u32> = (0..n as u32).collect();
        let orig = keys.clone();
        radix_sort_pairs(pool, &mut keys, &mut payload);
        // sorted
        assert!(keys.windows(2).all(|w| w[0] <= w[1]), "{seed_tag}: not sorted");
        // payload consistent: keys[i] == orig[payload[i]]
        for i in 0..n {
            assert_eq!(keys[i], orig[payload[i] as usize], "{seed_tag}: payload broken at {i}");
        }
        // permutation
        let mut seen = vec![false; n];
        for &p in &payload {
            assert!(!seen[p as usize], "{seed_tag}: duplicate payload");
            seen[p as usize] = true;
        }
    }

    #[test]
    fn sorts_random_large() {
        let mut rng = Rng::new(1);
        let pool = ThreadPool::new(6);
        let keys: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
        check_sorted(&pool, keys, "random-large");
    }

    #[test]
    fn sorts_small_sequential_path() {
        let mut rng = Rng::new(2);
        let pool = ThreadPool::new(4);
        let keys: Vec<u64> = (0..100).map(|_| rng.next_u64() % 50).collect();
        check_sorted(&pool, keys, "small");
    }

    #[test]
    fn sorts_with_duplicates_and_zeros() {
        let pool = ThreadPool::new(4);
        let mut keys = vec![0u64; 20_000];
        let mut rng = Rng::new(3);
        for k in keys.iter_mut().take(10_000) {
            *k = rng.next_u64() % 16; // heavy duplicates
        }
        check_sorted(&pool, keys, "dupes");
    }

    #[test]
    fn sorts_morton_like_sparse_bits() {
        // Morton codes of bounded depth leave high bits zero → pass skipping.
        let mut rng = Rng::new(4);
        let pool = ThreadPool::new(6);
        let keys: Vec<u64> = (0..30_000).map(|_| rng.next_u64() & 0x3FFF_FFFF).collect();
        check_sorted(&pool, keys, "sparse-bits");
    }

    #[test]
    fn stability_deterministic_across_thread_counts() {
        let mut rng = Rng::new(5);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64() % 1000).collect();
        let mut results = Vec::new();
        for nt in [1, 2, 6] {
            let pool = ThreadPool::new(nt);
            let mut k = keys.clone();
            let mut p: Vec<u32> = (0..keys.len() as u32).collect();
            radix_sort_pairs(&pool, &mut k, &mut p);
            results.push(p);
        }
        // Note: nt=1 path uses sort_unstable, so compare only parallel runs
        // for exact payload equality; all must be sorted + valid permutations.
        assert_eq!(results[1], results[2], "parallel runs must be deterministic");
    }

    #[test]
    fn empty_and_singleton() {
        let pool = ThreadPool::new(4);
        let mut k: Vec<u64> = vec![];
        let mut p: Vec<u32> = vec![];
        radix_sort_pairs(&pool, &mut k, &mut p);
        let mut k = vec![42u64];
        let mut p = vec![0u32];
        radix_sort_pairs(&pool, &mut k, &mut p);
        assert_eq!(k, vec![42]);
    }

    #[test]
    fn already_sorted_and_reversed() {
        let pool = ThreadPool::new(4);
        check_sorted(&pool, (0..20_000u64).collect(), "sorted");
        check_sorted(&pool, (0..20_000u64).rev().collect(), "reversed");
    }
}
