//! Parallel exclusive prefix sums.
//!
//! Used by the radix-sort scatter phase and by level construction in the
//! morton quadtree builder (turning per-node child counts into offsets).

use super::par_for::static_chunk;
use super::pool::ThreadPool;
use super::SyncSlice;

/// In-place exclusive prefix sum; returns the grand total.
/// `[3, 1, 4]` becomes `[0, 3, 4]` and returns `8`.
pub fn exclusive_scan_seq(data: &mut [usize]) -> usize {
    let mut acc = 0usize;
    for v in data.iter_mut() {
        let x = *v;
        *v = acc;
        acc += x;
    }
    acc
}

/// Parallel in-place exclusive prefix sum; returns the grand total.
///
/// Three-phase: per-chunk local sums → sequential scan of chunk totals
/// (nt elements — negligible) → per-chunk local exclusive scan with offset.
pub fn exclusive_scan(pool: &ThreadPool, data: &mut [usize]) -> usize {
    let n = data.len();
    let nt = pool.n_threads();
    if nt == 1 || n < 4096 {
        return exclusive_scan_seq(data);
    }
    let mut chunk_totals = vec![0usize; nt];
    {
        let totals = SyncSlice::new(&mut chunk_totals);
        let d = &*data;
        pool.broadcast(|tid| {
            let (s, e) = static_chunk(n, nt, tid);
            // SAFETY: disjoint — one slot per tid
            unsafe { *totals.get_mut(tid) = d[s..e].iter().sum() };
        });
    }
    let total = exclusive_scan_seq(&mut chunk_totals);
    {
        let d = SyncSlice::new(data);
        let offsets = &chunk_totals;
        pool.broadcast(|tid| {
            let (s, e) = static_chunk(n, nt, tid);
            // SAFETY: disjoint — static chunks never overlap
            let chunk = unsafe { d.slice_mut(s, e - s) };
            let mut acc = offsets[tid];
            for v in chunk.iter_mut() {
                let x = *v;
                *v = acc;
                acc += x;
            }
        });
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn seq_scan_basic() {
        let mut v = vec![3, 1, 4, 1, 5];
        let total = exclusive_scan_seq(&mut v);
        assert_eq!(v, vec![0, 3, 4, 8, 9]);
        assert_eq!(total, 14);
    }

    #[test]
    fn seq_scan_empty() {
        let mut v: Vec<usize> = vec![];
        assert_eq!(exclusive_scan_seq(&mut v), 0);
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Rng::new(42);
        for n in [0, 1, 100, 4096, 10_001, 100_000] {
            let orig: Vec<usize> = (0..n).map(|_| rng.next_below(1000)).collect();
            let mut seq = orig.clone();
            let mut par = orig.clone();
            let ts = exclusive_scan_seq(&mut seq);
            let pool = ThreadPool::new(6);
            let tp = exclusive_scan(&pool, &mut par);
            assert_eq!(ts, tp, "total mismatch n={n}");
            assert_eq!(seq, par, "scan mismatch n={n}");
        }
    }
}
