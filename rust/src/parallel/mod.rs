//! Parallelization substrate — the OpenMP analog the paper's techniques assume.
//!
//! The paper parallelizes with OpenMP (`#pragma omp parallel for` with static
//! and dynamic scheduling). Offline, with no rayon, we own the equivalent:
//!
//! - [`pool::ThreadPool`] — persistent workers, caller participates as thread 0,
//!   exact thread-count control (needed for the Fig 5/6 scaling sweeps).
//! - [`par_for`] — static / dynamic(grain) loop scheduling over index ranges.
//! - [`scan`] — parallel exclusive prefix sums.
//! - [`sort`] — parallel LSD radix sort for (morton code, point index) pairs.

pub mod par_for;
pub mod pool;
pub mod scan;
pub mod sort;

pub use par_for::{parallel_for, parallel_for_idx, Schedule};
pub use pool::ThreadPool;

/// Shared mutable slice for disjoint parallel writes.
///
/// Rust's aliasing rules forbid `&mut [T]` captured by a `Fn` closure running
/// on several threads; the paper's algorithms (scatter into per-point force
/// arrays, radix scatter, subtree construction) all write *disjoint* index
/// sets per thread. `SyncSlice` is the narrow unsafe escape hatch for that
/// pattern; every use site documents its disjointness argument.
#[derive(Clone, Copy)]
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: a SyncSlice is a borrowed view of a `&mut [T]`; sending it moves
// only a pointer + length, and T: Send means the elements may be written
// from another thread. Disjointness of writes is each use site's obligation.
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
// SAFETY: sharing `&SyncSlice` across threads exposes only `get_mut`/
// `slice_mut`, both themselves `unsafe fn` whose contracts (disjoint
// indices, in-bounds) are what make the concurrent writes sound.
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SyncSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `slot` i. Safety: no two threads may touch the same index
    /// concurrently, and `i < len`.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        // SAFETY: caller contract (doc above): `i < len`, no concurrent
        // access to the same index, so the produced `&mut T` is unique.
        unsafe { &mut *self.ptr.add(i) }
    }

    /// Reborrow a disjoint subrange as a regular mutable slice.
    /// Safety: ranges handed to different threads must not overlap.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        // SAFETY: caller contract (doc above): the range is in bounds and
        // ranges handed to different threads never overlap.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_slice_disjoint_writes() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 1000];
        let s = SyncSlice::new(&mut data);
        parallel_for(&pool, 1000, Schedule::Static, |range| {
            for i in range {
                // SAFETY: disjoint — parallel_for ranges never overlap
                unsafe { *s.get_mut(i) = i * 2 };
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }
}
