//! Persistent thread pool with caller participation.
//!
//! Design constraints from the paper's evaluation:
//! - **Exact thread-count control.** Fig 5/6 sweep 1..32 cores; a pool of
//!   `n` means exactly `n` OS threads do work (`n-1` workers + the caller as
//!   thread 0). `n = 1` never spawns and never synchronizes, so single-thread
//!   baselines (Tables 4/5) measure the pure algorithm.
//! - **Low dispatch overhead.** One `Mutex`+`Condvar` epoch broadcast per
//!   parallel region (~a few µs), amortized across 1000 gradient iterations.
//!   A parallel region is `broadcast(f)`: run `f(tid)` on every thread, then
//!   barrier.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased pointer to the current parallel region's closure.
/// Valid only while `broadcast` is blocked, which is exactly when workers run it.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls from many threads are fine),
// and the pointer is only dereferenced while `broadcast` keeps the closure
// alive on the caller's stack (see the epoch protocol in `broadcast`).
unsafe impl Send for JobPtr {}

struct State {
    job: Option<JobPtr>,
    epoch: u64,
    shutdown: bool,
}

struct Inner {
    state: Mutex<State>,
    start_cv: Condvar,
    done_cv: Condvar,
    remaining: AtomicUsize,
    done_lock: Mutex<()>,
}

/// Persistent pool of `n - 1` workers; the constructing thread acts as tid 0.
pub struct ThreadPool {
    inner: Arc<Inner>,
    n_threads: usize,
    handles: Vec<JoinHandle<()>>,
    /// Overlap guard: the single job-slot/epoch protocol supports ONE
    /// in-flight `broadcast` at a time — two concurrent regions on the same
    /// pool would race the slot and dangle the lifetime-erased closure
    /// pointer. Callers sharing a pool across threads (`tsne::serve`'s turn
    /// scheduler) must serialize their parallel regions; this flag turns a
    /// violation into a debug assertion instead of silent UB.
    busy: AtomicBool,
}

impl ThreadPool {
    /// Create a pool that will run parallel regions on `n_threads` threads.
    /// `n_threads = 0` is clamped to 1.
    pub fn new(n_threads: usize) -> Self {
        let n_threads = n_threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                job: None,
                epoch: 0,
                shutdown: false,
            }),
            start_cv: Condvar::new(),
            done_cv: Condvar::new(),
            remaining: AtomicUsize::new(0),
            done_lock: Mutex::new(()),
        });
        let mut handles = Vec::new();
        for tid in 1..n_threads {
            let inner = Arc::clone(&inner);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("acc-tsne-worker-{tid}"))
                    .spawn(move || worker_loop(inner, tid))
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            inner,
            n_threads,
            handles,
            busy: AtomicBool::new(false),
        }
    }

    /// Create a pool sized to all available hardware threads.
    pub fn with_all_cores() -> Self {
        Self::new(available_cores())
    }

    #[inline]
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Run `f(tid)` on every thread of the pool (tid in `0..n_threads`), with
    /// the caller executing tid 0. Returns after all threads finish (barrier).
    pub fn broadcast<F: Fn(usize) + Sync>(&self, f: F) {
        if self.n_threads == 1 {
            f(0);
            return;
        }
        let was_busy = self.busy.swap(true, Ordering::Acquire);
        debug_assert!(
            !was_busy,
            "concurrent ThreadPool::broadcast on one pool — parallel regions \
             sharing a pool must be externally serialized"
        );
        let nworkers = self.n_threads - 1;
        // SAFETY: erase the closure's lifetime: workers only dereference the pointer
        // between the epoch bump below and the `remaining == 0` barrier, and
        // this function does not return before that barrier.
        let job: JobPtr = unsafe {
            JobPtr(std::mem::transmute::<
                *const (dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(&f as &(dyn Fn(usize) + Sync) as *const _))
        };
        {
            let mut st = self.inner.state.lock().unwrap();
            self.inner.remaining.store(nworkers, Ordering::Release);
            st.job = Some(job);
            st.epoch += 1;
            drop(st);
            self.inner.start_cv.notify_all();
        }
        // Caller participates as tid 0.
        f(0);
        // Barrier: wait for all workers.
        if self.inner.remaining.load(Ordering::Acquire) != 0 {
            let mut guard = self.inner.done_lock.lock().unwrap();
            while self.inner.remaining.load(Ordering::Acquire) != 0 {
                guard = self.inner.done_cv.wait(guard).unwrap();
            }
        }
        self.busy.store(false, Ordering::Release);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            st.epoch += 1;
        }
        self.inner.start_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>, tid: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            while st.epoch == seen_epoch && !st.shutdown {
                st = inner.start_cv.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen_epoch = st.epoch;
            st.job
        };
        if let Some(JobPtr(ptr)) = job {
            // Safety: `broadcast` keeps the closure alive until the barrier.
            let f = unsafe { &*ptr };
            f(tid);
            if inner.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let _g = inner.done_lock.lock().unwrap();
                inner.done_cv.notify_all();
            }
        }
    }
}

/// Number of threads to use when the caller does not pin one (config
/// `n_threads = 0`, [`ThreadPool::with_all_cores`]).
///
/// Overridable via the `ACC_TSNE_NUM_THREADS` environment variable (with
/// `RAYON_NUM_THREADS` honored as the conventional alias) — CI's
/// thread-count matrix pins the parity/determinism test legs with it.
/// Unset, empty, unparseable, or zero values fall back to the hardware
/// thread count.
pub fn available_cores() -> usize {
    for var in ["ACC_TSNE_NUM_THREADS", "RAYON_NUM_THREADS"] {
        if let Some(n) = std::env::var(var).ok().as_deref().and_then(parse_thread_override) {
            return n;
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Parse a thread-count override: positive integers only; everything else
/// (empty, garbage, `0`) means "no override".
fn parse_thread_override(v: &str) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n > 0 => Some(n),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_tids_run_exactly_once() {
        for n in [1, 2, 4, 7] {
            let pool = ThreadPool::new(n);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.broadcast(|tid| {
                hits[tid].fetch_add(1, Ordering::Relaxed);
            });
            for (tid, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "tid {tid} of {n}");
            }
        }
    }

    #[test]
    fn broadcast_is_a_barrier() {
        let pool = ThreadPool::new(4);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            pool.broadcast(|_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn reusable_across_regions_with_different_closures() {
        let pool = ThreadPool::new(3);
        let a = AtomicU64::new(0);
        let b = AtomicU64::new(0);
        pool.broadcast(|tid| {
            a.fetch_add(tid as u64, Ordering::Relaxed);
        });
        pool.broadcast(|tid| {
            b.fetch_add((tid * 10) as u64, Ordering::Relaxed);
        });
        assert_eq!(a.load(Ordering::Relaxed), 0 + 1 + 2);
        assert_eq!(b.load(Ordering::Relaxed), 0 + 10 + 20);
    }

    #[test]
    fn zero_threads_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.n_threads(), 1);
        let hit = AtomicU64::new(0);
        pool.broadcast(|_| {
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn thread_override_accepts_positive_integers_only() {
        assert_eq!(parse_thread_override("4"), Some(4));
        assert_eq!(parse_thread_override(" 8 "), Some(8));
        assert_eq!(parse_thread_override("1"), Some(1));
        assert_eq!(parse_thread_override("0"), None, "0 means hardware default");
        assert_eq!(parse_thread_override(""), None);
        assert_eq!(parse_thread_override("four"), None);
        assert_eq!(parse_thread_override("-2"), None);
        // whatever the environment says, the resolved count is usable
        assert!(available_cores() >= 1);
    }

    #[test]
    fn nested_data_capture_by_ref() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let sum = AtomicU64::new(0);
        pool.broadcast(|tid| {
            let local: u64 = data.iter().skip(tid).step_by(4).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..100).sum::<u64>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(8);
        pool.broadcast(|_| {});
        drop(pool); // must not hang
    }
}
