//! Loop scheduling over index ranges — the `#pragma omp for` analog.
//!
//! Two schedules, mirroring the paper's usage:
//! - `Static`: contiguous equal chunks, one per thread. Used when iterations
//!   are uniform (morton encoding, BSP, attractive/repulsive over points).
//! - `Dynamic { grain }`: threads pull `grain`-sized chunks from an atomic
//!   counter. Used when work per item varies wildly (subtree construction —
//!   paper §3.3 "dynamic thread scheduling over the nodes").

use super::pool::ThreadPool;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Static,
    Dynamic { grain: usize },
}

/// Run `f` over disjoint subranges covering `0..n` on all pool threads.
pub fn parallel_for<F>(pool: &ThreadPool, n: usize, sched: Schedule, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = pool.n_threads();
    if nt == 1 {
        f(0..n);
        return;
    }
    match sched {
        Schedule::Static => {
            pool.broadcast(|tid| {
                let (start, end) = static_chunk(n, nt, tid);
                if start < end {
                    f(start..end);
                }
            });
        }
        Schedule::Dynamic { grain } => {
            let grain = grain.max(1);
            let cursor = AtomicUsize::new(0);
            pool.broadcast(|_tid| loop {
                let start = cursor.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                f(start..end);
            });
        }
    }
}

/// Convenience: per-index closure with static scheduling.
pub fn parallel_for_idx<F>(pool: &ThreadPool, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for(pool, n, Schedule::Static, |range| {
        for i in range {
            f(i);
        }
    });
}

/// Contiguous chunk boundaries for static scheduling; distributes the
/// remainder one extra element to the first `n % nt` threads.
#[inline]
pub fn static_chunk(n: usize, nt: usize, tid: usize) -> (usize, usize) {
    let base = n / nt;
    let rem = n % nt;
    let start = tid * base + tid.min(rem);
    let len = base + usize::from(tid < rem);
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn static_chunks_partition_exactly() {
        for n in [0, 1, 5, 100, 101, 1024] {
            for nt in [1, 2, 3, 8, 17] {
                let mut covered = 0;
                let mut prev_end = 0;
                for tid in 0..nt {
                    let (s, e) = static_chunk(n, nt, tid);
                    assert_eq!(s, prev_end);
                    prev_end = e;
                    covered += e - s;
                }
                assert_eq!(prev_end, n);
                assert_eq!(covered, n);
            }
        }
    }

    fn sum_check(sched: Schedule, nt: usize, n: usize) {
        let pool = ThreadPool::new(nt);
        let sum = AtomicU64::new(0);
        parallel_for(&pool, n, sched, |range| {
            let local: u64 = range.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 * (n as u64 - 1)) / 2);
    }

    #[test]
    fn static_covers_all_indices() {
        sum_check(Schedule::Static, 4, 10_000);
        sum_check(Schedule::Static, 1, 1_000);
        sum_check(Schedule::Static, 16, 17);
    }

    #[test]
    fn dynamic_covers_all_indices() {
        sum_check(Schedule::Dynamic { grain: 64 }, 4, 10_000);
        sum_check(Schedule::Dynamic { grain: 1 }, 8, 1_000);
        sum_check(Schedule::Dynamic { grain: 100_000 }, 4, 1_000);
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(4);
        parallel_for(&pool, 0, Schedule::Static, |_| panic!("must not run"));
        parallel_for(&pool, 0, Schedule::Dynamic { grain: 8 }, |_| {
            panic!("must not run")
        });
    }

    #[test]
    fn ranges_are_disjoint_dynamic() {
        let pool = ThreadPool::new(8);
        let n = 5000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(&pool, n, Schedule::Dynamic { grain: 7 }, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_idx_runs_each_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        parallel_for_idx(&pool, 257, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
