//! # Acc-t-SNE
//!
//! A production-quality reproduction of *"Accelerating Barnes-Hut t-SNE Algorithm
//! by Efficient Parallelization on Multi-Core CPUs"* (Chaudhary et al., Intel, 2022)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate implements the full Barnes-Hut t-SNE pipeline — KNN, binary-search
//! perplexity, quadtree construction, summarization, attractive and repulsive
//! force computation — together with every baseline the paper compares against
//! (scikit-learn-like, Multicore-TSNE-like, daal4py-like, FIt-SNE) and a benchmark
//! harness that regenerates every table and figure in the paper's evaluation.
//!
//! ## Layers
//! - **L3 (this crate)**: the parallel coordinator — thread pool, per-step
//!   schedulers, CLI, metrics, benchmarks.
//! - **L2/L1 (python/compile)**: JAX graphs calling Pallas kernels, AOT-lowered to
//!   HLO text in `artifacts/`, executed from [`runtime`] via PJRT.
//!
//! ## Quickstart
//!
//! The public API is staged around the pipeline's two lifetimes: fit the
//! affinities **once** ([`tsne::Affinities`]), then drive any number of
//! gradient descents from them through a [`tsne::TsneSession`] built from a
//! validated [`tsne::StagePlan`] — with stepwise control, convergence-based
//! stopping, and an observer streaming un-permuted embedding snapshots:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::parallel::ThreadPool;
//! use acc_tsne::tsne::{
//!     Affinities, Convergence, ObserverControl, StagePlan, TsneConfig, TsneSession,
//! };
//!
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let cfg = TsneConfig::default();
//!
//! // Phase 1 — KNN → perplexity search → symmetrize, computed once. Hostile
//! // shapes and out-of-range perplexities are typed FitErrors, not panics.
//! let plan = StagePlan::acc_tsne(); // presets: sklearn_like()/daal4py_like()/fit_sne()/...
//! let pool = ThreadPool::with_all_cores();
//! let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
//!     .expect("valid shape and perplexity");
//!
//! // Phase 2 — a resumable optimizer over the fitted affinities.
//! let mut session = TsneSession::new(&aff, plan, cfg).expect("preset plans validate");
//! session.set_observer(100, |snap| {
//!     println!("iter {:>4}: KL = {:.3}  |grad| = {:.2e}", snap.iter, snap.kl, snap.grad_norm);
//!     ObserverControl::Continue // or Stop, for observer-driven early exit
//! });
//! let outcome = session.run_until(Convergence {
//!     max_iter: 1000,
//!     min_grad_norm: 1e-7,          // sklearn-style stopping rules,
//!     n_iter_without_progress: 300, // evaluated on the free per-iter grad norm
//! });
//! let result = session.finish();
//! println!("KL = {:.3} after {} iterations ({:?})",
//!          result.kl_divergence, outcome.n_iter, outcome.reason);
//!
//! // The same `aff` can now seed more sessions (different seeds/plans) —
//! // the KNN+BSP phase is never recomputed.
//! let mut cfg_b = cfg;
//! cfg_b.seed = 1234;
//! let mut session_b = TsneSession::new(&aff, plan, cfg_b).unwrap();
//! session_b.run(500);
//! ```
//!
//! ### Persistence: save/load the fit, checkpoint/resume a session
//!
//! Both artifacts survive the process. [`tsne::Affinities::save`] /
//! [`tsne::Affinities::load`] serialize the fitted `P` (a versioned,
//! checksummed, dependency-free binary format) so the expensive KNN→BSP
//! phase is paid once per dataset, ever; [`tsne::TsneSession::checkpoint`] /
//! [`tsne::TsneSession::restore`] serialize the optimizer state in
//! un-permuted original order, and a resumed run is **bit-identical** to an
//! uninterrupted one at a fixed thread count:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::parallel::ThreadPool;
//! use acc_tsne::tsne::{Affinities, StagePlan, TsneConfig, TsneSession};
//!
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let plan = StagePlan::acc_tsne();
//! let cfg = TsneConfig::default();
//! let pool = ThreadPool::with_all_cores();
//!
//! // Fit once, persist, and reuse from any process.
//! let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
//!     .expect("valid fit");
//! aff.save("digits.affinities").expect("write artifact");
//! let aff = Affinities::<f64>::load("digits.affinities").expect("read artifact");
//!
//! // Run half the budget, checkpoint, and stop (crash, deploy, restart...).
//! let mut session = TsneSession::new(&aff, plan, cfg).expect("preset plans validate");
//! session.run(500);
//! session.checkpoint("run.ckpt").expect("write checkpoint");
//! drop(session);
//!
//! // Later / elsewhere: restore and finish — bit-identical to a run that
//! // never stopped (hostile files come back as typed PersistErrors).
//! let mut session = TsneSession::restore(&aff, plan, cfg, "run.ckpt").expect("valid checkpoint");
//! assert_eq!(session.iterations(), 500);
//! session.run(500);
//! let result = session.finish();
//! println!("KL = {:.3}", result.kl_divergence);
//! ```
//!
//! ### Fit KNN once, sweep perplexities
//!
//! KNN dominates the fit wall clock, but the neighbor graph depends only on
//! the data and `k` — the perplexity enters in step 2 (BSP), which consumes
//! the ⌊3u⌋ *nearest* stored neighbors. [`tsne::KnnGraph`] makes that split
//! first-class: build (or load) the graph once at your **largest** sweep
//! perplexity, then [`tsne::Affinities::from_knn`] re-fits at every smaller
//! one in BSP-only time — **bit-identical** to a fresh full fit at that
//! perplexity, whether the graph is fresh from [`tsne::KnnGraph::build`] or
//! round-tripped through [`tsne::KnnGraph::save`]/[`tsne::KnnGraph::load`]:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::parallel::ThreadPool;
//! use acc_tsne::tsne::{Affinities, KnnGraph, StagePlan, TsneConfig, TsneSession};
//!
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let plan = StagePlan::acc_tsne();
//! let pool = ThreadPool::with_all_cores();
//!
//! // KNN once, at the largest perplexity of the sweep (k = ⌊3·50⌋ = 150).
//! let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 50.0, &plan)
//!     .expect("valid shape and perplexity");
//! graph.save("digits.knn").expect("write artifact");
//!
//! // Later / elsewhere: reload, check it matches this dataset, and sweep —
//! // each re-fit runs BSP + symmetrize only, never KNN.
//! let graph = KnnGraph::<f64>::load("digits.knn").expect("read artifact");
//! graph.verify_source(&ds.points, ds.n, ds.d).expect("same data");
//! for perplexity in [10.0, 30.0, 50.0] {
//!     let aff = Affinities::from_knn(&pool, &graph, perplexity, &plan)
//!         .expect("floor(3u) fits the graph's k");
//!     let cfg = TsneConfig { perplexity, ..TsneConfig::default() };
//!     let mut session = TsneSession::new(&aff, plan, cfg).expect("preset plans validate");
//!     session.run(1000);
//!     println!("perplexity {perplexity}: KL = {:.3}", session.finish().kl_divergence);
//! }
//! ```
//!
//! ### Scaling to millions of points
//!
//! Exact KNN is O(n²·d): already the dominant cost at paper scale, and an
//! outright wall at n = 10⁶ (~10¹³ distance evaluations). The approximate
//! path swaps it for the HNSW subsystem ([`knn::hnsw`]) — a parallel,
//! deterministic-given-seed hierarchical small-world graph whose build and
//! query are both near-linear in n. At the default query beam
//! ([`knn::hnsw::DEFAULT_EF_SEARCH`]) it holds ≥ 0.9 recall@k on clustered
//! data (the `knn_recall.*` keys of `BENCH_knn.json` track the measured
//! recall/speed frontier), and t-SNE is forgiving of the remainder: the
//! missing fraction of true neighbors perturbs `P` far less than the
//! perplexity approximation already does.
//!
//! [`tsne::StagePlan::auto_for`] selects it automatically above
//! [`tsne::FFT_CROSSOVER_N`] (alongside FFT repulsion), or opt in explicitly
//! with [`tsne::StagePlan::with_knn_engine`] /
//! [`tsne::KnnGraph::build_approximate`]; the CLI spells it
//! `acc-tsne run --knn-engine hnsw [--ef-search N]`. The approximate graph
//! is a first-class [`tsne::KnnGraph`]: it persists with its parameters in
//! the engine metadata, fingerprint-checks against the source data, and
//! re-fits BSP-only at any perplexity with ⌊3u⌋ ≤ k — bit-identical between
//! the in-memory and the reloaded graph. One caveat is inherent: the
//! ⌊3u⌋-prefix contract holds **per build**. Rebuilding with other
//! parameters (or another seed) may change the approximate k-sets
//! themselves, so persist the graph and sweep from the artifact —
//! [`tsne::KnnGraph::require_engine`] rejects a graph whose engine family
//! does not match what the run asked for. A full million-point walkthrough
//! (graph → artifact → FFT descent → neighbor-preservation spot check)
//! lives in `examples/million_points.rs`:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::knn::hnsw::HnswParams;
//! use acc_tsne::parallel::ThreadPool;
//! use acc_tsne::tsne::{Affinities, KnnGraph, StagePlan, TsneConfig, TsneSession};
//!
//! let ds = gaussian_mixture::<f64>(1_000_000, 16, 32, 6.0, 42);
//! let pool = ThreadPool::with_all_cores();
//!
//! // Approximate KNN once, at the largest sweep perplexity (k = ⌊3·30⌋ = 90).
//! let graph =
//!     KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, 90, &HnswParams::default())
//!         .expect("valid build");
//! graph.save("million.knn").expect("write artifact");
//!
//! // auto_for picks FFT repulsion AND the HNSW engine above the crossover.
//! let plan = StagePlan::auto_for(ds.n);
//! let aff = Affinities::from_knn(&pool, &graph, 30.0, &plan).expect("floor(3u) <= k");
//! let cfg = TsneConfig { perplexity: 30.0, ..TsneConfig::default() };
//! let mut session = TsneSession::new(&aff, plan, cfg).expect("auto plans validate");
//! session.run(1000);
//! println!("KL = {:.3}", session.finish().kl_divergence);
//! ```
//!
//! ### Choosing a repulsive engine
//!
//! Two interchangeable repulsive engines sit behind the same session API.
//! **Barnes-Hut** (`StagePlan::acc_tsne()`) walks a summarized quadtree per
//! point — O(n log n), the paper's headline path, fastest at small-to-medium
//! n. **FIt-SNE** (`StagePlan::fit_sne()`) scatters charges onto a bounded
//! interpolation grid and convolves via FFT — O(n) in the embedding size,
//! so its per-step cost overtakes BH as n grows. The FFT engine keeps a
//! persistent workspace inside the session: scatter/pad buffers are reused
//! across iterations (steady-state steps are allocation-free) and the
//! kernel-grid transforms are cached on a quantized span lattice, rebuilt
//! only when the embedding's bounding box actually changes grid geometry.
//! Both engines compose with either memory [`tsne::Layout`].
//!
//! [`tsne::StagePlan::auto_for`] picks the engine from the dataset size
//! (crossover at [`tsne::FFT_CROSSOVER_N`] points; the
//! `crossover.*` keys of `BENCH_fitsne.json` track the measured break-even),
//! and the CLI exposes the same choice as `acc-tsne run --auto-engine`:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::parallel::ThreadPool;
//! use acc_tsne::tsne::{Affinities, StagePlan, TsneConfig, TsneSession};
//!
//! let ds = gaussian_mixture::<f64>(100_000, 16, 10, 4.0, 42);
//! let plan = StagePlan::auto_for(ds.n); // n >= FFT_CROSSOVER_N → FFT repulsion
//! let cfg = TsneConfig::default();
//! let pool = ThreadPool::with_all_cores();
//! let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
//!     .expect("valid fit");
//! let mut session = TsneSession::new(&aff, plan, cfg).expect("auto plans validate");
//! session.run(1000);
//! println!("KL = {:.3}", session.finish().kl_divergence);
//! ```
//!
//! ### Serving embeddings
//!
//! [`tsne::serve`] turns the session API into a long-lived embedding
//! service: a dependency-free TCP daemon (`acc-tsne serve`) that fingerprints
//! each request's data, caches the fitted [`tsne::Affinities`] (a repeat of
//! the same bytes skips KNN + BSP entirely), multiplexes every client's
//! descent over **one** shared thread pool with fair round-robin step
//! scheduling, and streams progressive length-prefixed, checksummed
//! embedding frames. A client that disconnects mid-stream is detached — its
//! session parks as a checkpoint and can be resumed by id, landing
//! bit-identical to a run that never disconnected. The wire protocol is
//! documented in `docs/serving.md`; `acc-tsne serve --smoke N` runs the
//! self-verifying proof (N concurrent clients, bitwise comparison against
//! direct sessions) that CI gates on, and the `serving.*` keys of
//! `BENCH_serving.json` track per-step latency percentiles and session
//! throughput at 1/4/8-client fleets:
//!
//! ```no_run
//! use acc_tsne::data::synthetic::gaussian_mixture;
//! use acc_tsne::tsne::serve::{self, run_client, Request, ServeConfig};
//!
//! // Daemon side (usually `acc-tsne serve --addr 127.0.0.1:7878`):
//! let server = serve::start(&ServeConfig::default()).expect("bind");
//! let addr = server.addr().to_string();
//!
//! // Client side: one request = one descent, streamed progressively.
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let run = run_client(&addr, &Request {
//!     resume_id: 0,
//!     n: ds.n as u64,
//!     d: ds.d as u64,
//!     n_iter: 1000,
//!     snapshot_every: 100, // progressive frames; 0 = final frame only
//!     seed: 42,
//!     perplexity: 30.0,
//!     theta: 0.5,
//!     points: ds.points.clone(),
//! }).expect("served run");
//! println!("{} snapshots, final KL = {:.3}, cache hit: {}",
//!          run.snapshots, run.final_kl, run.cache_hit);
//! // A second client with the same bytes reuses the cached fit (cache_hit).
//! ```
//!
//! ### Robustness guarantees
//!
//! The pipeline is hardened end to end against hostile data and injected
//! faults — the guarantees below are enforced by the integration suites
//! (`tests/fault_injection.rs`, `tests/integration_persist.rs`,
//! `tests/proptests.rs`) across 1/4/8 threads:
//!
//! - **Finite-input validation at the fit boundary.** [`tsne::Affinities::fit`]
//!   and [`tsne::KnnGraph::build`] reject any NaN/∞ coordinate with
//!   [`tsne::FitError::NonFinite`] locating the first offender by
//!   `(row, col)` — a poisoned value never reaches the KNN distances, the
//!   perplexity search, or the quadtree. The dataset loaders
//!   ([`data::datasets`], [`data::Dataset::try_new`]) run the same check and
//!   report a typed [`data::DataError`].
//! - **Perplexity search degrades gracefully.** A row whose binary search
//!   cannot converge (pathological distance spreads, zero variance) falls
//!   back to a uniform distribution over its neighbors — sklearn's behavior
//!   — instead of emitting NaN weights.
//! - **Degenerate geometry is survivable.** Coincident and near-coincident
//!   clouds (spreads below f64 precision) produce finite quadtrees and
//!   finite forces in both tree builders; non-finite coordinates clamp to
//!   the grid edge instead of corrupting the bounding box.
//! - **Divergence is detected and rewound.** [`tsne::TsneSession::step`]
//!   checks Z and the gradient norm every iteration; a non-finite value
//!   becomes a typed [`tsne::StepError::Diverged`] and the session rewinds
//!   itself to an in-memory last-good checkpoint (captured every
//!   [`tsne::TsneSession::set_guard_interval`] iterations), bit-identical to
//!   restoring the same snapshot from disk.
//! - **Artifacts are crash-safe.** Every save ([`tsne::Affinities::save`],
//!   [`tsne::KnnGraph::save`], [`tsne::SessionCheckpoint::save`]) stages to
//!   a temp sibling and renames; the fault-injection harness proves that a
//!   write error, short write, or crash at **every** flush boundary leaves
//!   the previous artifact byte-identical and loadable, and that a torn
//!   file never loads — it is rejected with a typed
//!   [`tsne::PersistError`], never a panic or silently-wrong data.
//!
//! The CLI maps these families to distinct exit codes (usage 2, fit 3,
//! persistence 4, plan 5, divergence 6, serving 7) with a one-line stderr
//! message.
//!
//! The invariants behind these guarantees (IEEE `total_cmp` ordering, no
//! nondeterminism sources in result-affecting modules, length-before-
//! allocation in every codec, typed errors instead of panics on the
//! persist/serve surfaces, `// SAFETY:` on every `unsafe`) are enforced
//! mechanically by the first-party linter in `tools/acc-lint`, a hard CI
//! gate — rules, allowlist policy, and the sanitizer tier (TSan + Miri)
//! are documented in `docs/static-analysis.md`.
//!
//! The classic one-shot call is still there, as a thin wrapper that is
//! bit-identical to fitting affinities and stepping a session manually:
//!
//! ```no_run
//! use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};
//! use acc_tsne::data::synthetic::gaussian_mixture;
//!
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let cfg = TsneConfig { n_iter: 500, ..TsneConfig::default() };
//! let result = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
//! println!("KL divergence = {:.3}", result.kl_divergence);
//! ```
#![feature(portable_simd)]
#![allow(clippy::needless_range_loop)]
// Every unsafe operation inside an `unsafe fn` needs its own `unsafe {}`
// block (each with a `// SAFETY:` comment — enforced by acc-lint rule U1).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod common;
pub mod data;
pub mod eval;
pub mod fitsne;
pub mod gradient;
pub mod knn;
pub mod metrics;
pub mod parallel;
pub mod perplexity;
pub mod quadtree;
/// PJRT/XLA execution of the AOT artifacts. Requires the `xla` cargo feature
/// (and vendored `xla-rs` + `anyhow` crates, unavailable on the offline
/// mirror) — the native pipeline never needs it.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sparse;
pub mod tsne;
pub mod viz;
