//! # Acc-t-SNE
//!
//! A production-quality reproduction of *"Accelerating Barnes-Hut t-SNE Algorithm
//! by Efficient Parallelization on Multi-Core CPUs"* (Chaudhary et al., Intel, 2022)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate implements the full Barnes-Hut t-SNE pipeline — KNN, binary-search
//! perplexity, quadtree construction, summarization, attractive and repulsive
//! force computation — together with every baseline the paper compares against
//! (scikit-learn-like, Multicore-TSNE-like, daal4py-like, FIt-SNE) and a benchmark
//! harness that regenerates every table and figure in the paper's evaluation.
//!
//! ## Layers
//! - **L3 (this crate)**: the parallel coordinator — thread pool, per-step
//!   schedulers, CLI, metrics, benchmarks.
//! - **L2/L1 (python/compile)**: JAX graphs calling Pallas kernels, AOT-lowered to
//!   HLO text in `artifacts/`, executed from [`runtime`] via PJRT.
//!
//! ## Quickstart
//! ```no_run
//! use acc_tsne::tsne::{TsneConfig, Implementation, run_tsne};
//! use acc_tsne::data::synthetic::gaussian_mixture;
//!
//! let ds = gaussian_mixture::<f64>(2_000, 16, 10, 4.0, 42);
//! let cfg = TsneConfig { n_iter: 500, ..TsneConfig::default() };
//! let result = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);
//! println!("KL divergence = {:.3}", result.kl_divergence);
//! ```
#![feature(portable_simd)]
#![allow(clippy::needless_range_loop)]

pub mod cli;
pub mod common;
pub mod data;
pub mod eval;
pub mod fitsne;
pub mod gradient;
pub mod knn;
pub mod metrics;
pub mod parallel;
pub mod perplexity;
pub mod quadtree;
/// PJRT/XLA execution of the AOT artifacts. Requires the `xla` cargo feature
/// (and vendored `xla-rs` + `anyhow` crates, unavailable on the offline
/// mirror) — the native pipeline never needs it.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sparse;
pub mod tsne;
pub mod viz;
