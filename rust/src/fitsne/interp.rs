//! Polynomial (Lagrange) interpolation onto the FIt-SNE grid.
//!
//! Each grid interval carries `P` equispaced interpolation nodes at relative
//! positions (k + 0.5)/P. A point's charge is scattered to its interval's
//! nodes with Lagrange basis weights; potentials are gathered back with the
//! same weights (Linderman et al. 2019, §"Polynomial interpolation").

/// Interpolation nodes per interval (FIt-SNE default p = 3).
pub const P_NODES: usize = 3;

/// Relative node positions inside the unit interval.
#[inline]
pub fn node_positions() -> [f64; P_NODES] {
    let mut pos = [0.0; P_NODES];
    for (k, p) in pos.iter_mut().enumerate() {
        *p = (k as f64 + 0.5) / P_NODES as f64;
    }
    pos
}

/// Lagrange basis weights at relative position `t ∈ [0,1)`:
/// `w_k(t) = Π_{m≠k} (t - x_m) / (x_k - x_m)`.
#[inline]
pub fn lagrange_weights(t: f64) -> [f64; P_NODES] {
    let x = node_positions();
    let mut w = [0.0; P_NODES];
    for k in 0..P_NODES {
        let mut num = 1.0;
        let mut den = 1.0;
        for m in 0..P_NODES {
            if m == k {
                continue;
            }
            num *= t - x[m];
            den *= x[k] - x[m];
        }
        w[k] = num / den;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    #[test]
    fn partition_of_unity() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let t = rng.next_f64();
            let w = lagrange_weights(t);
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "t={t}: sum={s}");
        }
    }

    #[test]
    fn exact_at_nodes() {
        let x = node_positions();
        for (k, &xk) in x.iter().enumerate() {
            let w = lagrange_weights(xk);
            for m in 0..P_NODES {
                let want = if m == k { 1.0 } else { 0.0 };
                assert!((w[m] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reproduces_polynomials_up_to_degree() {
        // Lagrange interpolation over P nodes is exact for degree ≤ P-1.
        let mut rng = Rng::new(2);
        let x = node_positions();
        for _ in 0..50 {
            let (a, b, c) = (rng.next_gaussian(), rng.next_gaussian(), rng.next_gaussian());
            let f = |t: f64| a + b * t + c * t * t; // degree 2 = P_NODES-1
            let t = rng.next_f64();
            let w = lagrange_weights(t);
            let interp: f64 = (0..P_NODES).map(|k| w[k] * f(x[k])).sum();
            assert!((interp - f(t)).abs() < 1e-10, "t={t}");
        }
    }
}
